"""L1 Pallas kernels for TAG's heterogeneous GNN."""

from .gat_attention import gat_attention  # noqa: F401
from .ref import gat_attention_ref, leaky_relu, masked_softmax  # noqa: F401
