"""Pure-jnp oracle for the masked multi-head GAT attention aggregation.

This is the correctness reference for the Pallas kernel in
``gat_attention.py``.  The computation is the inner hot-spot of one
heterogeneous-GAT message-passing step (one edge type):

    t[n, s, h] = q[n, h] + kv[s, h] + ke[n, s, h]        (additive GAT logits)
    l[n, s, h] = LeakyReLU(t, slope)
    p[n, :, h] = masked softmax over sources s
    out[n, h, :] = sum_s p[n, s, h] * v[s, h, :]

Rows whose mask is all-zero produce all-zero outputs (no NaNs) — this is
what lets padded / absent nodes flow through the network harmlessly.

Shapes:
    q    (N, H)        destination-node logit contribution
    kv   (S, H)        source-node logit contribution
    ke   (N, S, H)     edge-feature logit contribution
    v    (S, H, D)     per-head source values
    mask (N, S)        1.0 = edge present, 0.0 = absent/padded
    out  (N, H, D)
"""

import jax.numpy as jnp

LEAKY_SLOPE = 0.2
NEG_INF = -1e30
DENOM_EPS = 1e-30


def leaky_relu(x, slope=LEAKY_SLOPE):
    return jnp.where(x >= 0, x, slope * x)


def masked_softmax(scores, mask):
    """Softmax over the last axis; fully-masked rows yield all zeros.

    ``scores``: (..., S); ``mask``: broadcastable (..., S) with {0,1}.
    """
    neg = jnp.where(mask > 0, scores, NEG_INF)
    m = jnp.max(neg, axis=-1, keepdims=True)
    # Clamp m so that all-masked rows (max == NEG_INF) exp() to zero rather
    # than NaN via (NEG_INF - NEG_INF).
    m = jnp.maximum(m, NEG_INF / 2)
    e = jnp.exp(neg - m) * (mask > 0)
    z = jnp.sum(e, axis=-1, keepdims=True)
    return e / jnp.maximum(z, DENOM_EPS)


def gat_attention_ref(q, kv, ke, v, mask):
    """Reference masked multi-head GAT attention aggregation.

    See module docstring for shapes.
    """
    t = q[:, None, :] + kv[None, :, :] + ke  # (N, S, H)
    logits = leaky_relu(t)
    p = masked_softmax(
        jnp.transpose(logits, (0, 2, 1)),  # (N, H, S)
        mask[:, None, :],
    )  # (N, H, S)
    # out[n, h, d] = sum_s p[n, h, s] * v[s, h, d]
    out = jnp.einsum("nhs,shd->nhd", p, v)
    return out
