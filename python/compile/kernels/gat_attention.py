"""Pallas kernel: fused masked multi-head GAT attention aggregation.

This is the L1 compute hot-spot of TAG's heterogeneous GNN: every GAT
layer performs, per edge type, a dense masked attention over the (padded)
adjacency between destination nodes and source nodes.  The Pallas kernel
fuses logit construction (additive GAT form), LeakyReLU, the numerically
stable masked softmax and the value aggregation, so the (N, S, H) logit
tensor never round-trips through HBM.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid iterates over
(head, dst-block); for each step the kernel holds one (BN, S) slab of edge
logits + mask in VMEM, computes the row-wise masked softmax with a running
max/denominator, and contracts against the (S, D) value slab on the MXU.
On this image the kernel is executed with ``interpret=True`` (the CPU PJRT
plugin cannot run Mosaic custom-calls); the blocking structure is still
what a real TPU lowering would use.

The backward pass is supplied via ``jax.custom_vjp`` (flash-attention
style recompute using the same masked-softmax formulation), so the kernel
is usable inside the AOT-lowered training step as well.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import DENOM_EPS, LEAKY_SLOPE, NEG_INF, leaky_relu, masked_softmax

# Destination-rows processed per grid step.  Chosen so a (BLOCK_N, S) f32
# slab plus the (S, D) value slab fit comfortably in VMEM for the padded
# problem sizes used by TAG (S <= 64, D <= 32).
BLOCK_N = 16


def _gat_attention_kernel(q_ref, kv_ref, ke_ref, v_ref, mask_ref, o_ref):
    """One (head h, dst-block nb) grid step.

    Block shapes (leading grid dims already sliced away):
        q_ref    (BN,)      dst logits for head h
        kv_ref   (S,)       src logits for head h
        ke_ref   (BN, S)    edge logits for head h
        v_ref    (S, D)     values for head h
        mask_ref (BN, S)
        o_ref    (BN, D)
    """
    q = q_ref[...]
    kv = kv_ref[...]
    ke = ke_ref[...]
    mask = mask_ref[...]

    t = q[:, None] + kv[None, :] + ke  # (BN, S)
    logits = jnp.where(t >= 0, t, LEAKY_SLOPE * t)
    neg = jnp.where(mask > 0, logits, NEG_INF)
    m = jnp.maximum(jnp.max(neg, axis=1, keepdims=True), NEG_INF / 2)
    e = jnp.exp(neg - m) * (mask > 0)
    z = jnp.sum(e, axis=1, keepdims=True)
    p = e / jnp.maximum(z, DENOM_EPS)  # (BN, S)
    # MXU contraction: (BN, S) @ (S, D).
    o_ref[...] = p @ v_ref[...]


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def gat_attention(q, kv, ke, v, mask):
    """Fused masked multi-head GAT attention (see ref.gat_attention_ref).

    Shapes: q (N, H), kv (S, H), ke (N, S, H), v (S, H, D), mask (N, S)
    -> out (N, H, D).  N must be a multiple of BLOCK_N (TAG pads to
    N_MAX/M_MAX so this always holds for the AOT shapes).
    """
    return _gat_attention_fwd_impl(q, kv, ke, v, mask)


def _gat_attention_fwd_impl(q, kv, ke, v, mask):
    n, h = q.shape
    s = kv.shape[0]
    d = v.shape[2]
    block_n = min(BLOCK_N, n)
    if n % block_n != 0:
        raise ValueError(f"N={n} must be a multiple of the block size {block_n}")
    grid = (h, n // block_n)

    out = pl.pallas_call(
        _gat_attention_kernel,
        grid=grid,
        in_specs=[
            # q (N, H) -> (BN,) for head hh, block nb (None squeezes the dim)
            pl.BlockSpec((block_n, None), lambda hh, nb: (nb, hh)),
            # kv (S, H) -> (S,)
            pl.BlockSpec((s, None), lambda hh, nb: (0, hh)),
            # ke (N, S, H) -> (BN, S)
            pl.BlockSpec((block_n, s, None), lambda hh, nb: (nb, 0, hh)),
            # v (S, H, D) -> (S, D)
            pl.BlockSpec((s, None, d), lambda hh, nb: (0, hh, 0)),
            # mask (N, S) -> (BN, S)
            pl.BlockSpec((block_n, s), lambda hh, nb: (nb, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, None, d), lambda hh, nb: (nb, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h, d), q.dtype),
        interpret=True,
    )(q, kv, ke, v, mask)
    return out


def _probs(q, kv, ke, mask):
    """Recompute the (N, H, S) attention probabilities (flash-style)."""
    t = q[:, None, :] + kv[None, :, :] + ke  # (N, S, H)
    logits = leaky_relu(t)
    return masked_softmax(jnp.transpose(logits, (0, 2, 1)), mask[:, None, :]), t


def _gat_attention_fwd(q, kv, ke, v, mask):
    out = _gat_attention_fwd_impl(q, kv, ke, v, mask)
    return out, (q, kv, ke, v, mask)


def _gat_attention_bwd(res, g):
    q, kv, ke, v, mask = res
    p, t = _probs(q, kv, ke, mask)  # p: (N, H, S), t: (N, S, H)

    # g: (N, H, D)
    # dL/dp[n,h,s] = sum_d g[n,h,d] * v[s,h,d]
    g_p = jnp.einsum("nhd,shd->nhs", g, v)
    # dL/dv[s,h,d] = sum_n p[n,h,s] * g[n,h,d]
    g_v = jnp.einsum("nhs,nhd->shd", p, g)
    # softmax jacobian: g_logit = p * (g_p - sum_s p * g_p)
    dot = jnp.sum(p * g_p, axis=-1, keepdims=True)
    g_logits = p * (g_p - dot)  # (N, H, S)
    g_t = jnp.transpose(g_logits, (0, 2, 1))  # (N, S, H)
    g_t = g_t * jnp.where(t >= 0, 1.0, LEAKY_SLOPE)
    # mask is non-differentiable but already encoded: fully masked rows have
    # p == 0 => g_logits == 0, and masked entries have p == 0 as well.
    g_q = jnp.sum(g_t, axis=1)  # (N, H)
    g_kv = jnp.sum(g_t, axis=0)  # (S, H)
    g_ke = g_t
    g_mask = jnp.zeros_like(mask)
    return g_q, g_kv, g_ke, g_v, g_mask


gat_attention.defvjp(_gat_attention_fwd, _gat_attention_bwd)
