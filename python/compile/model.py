"""L2: TAG's heterogeneous GNN — forward, decoder, loss and Adam train step.

This module defines the strategy-creator network of the paper (§4.2.1):
a 4-layer heterogeneous GAT over a unified graph that contains both
computation nodes (op groups) and device nodes (homogeneous GPU groups),
three edge types (op-op tensors, dev-dev links, op-dev placements), per-
edge-type weights ``gamma`` (1.0 same-type, 0.1 cross-type), multi-head
additive attention with edge features, and a thin decoder that scores
candidate strategy slices (P_i, O_i) for the op group whose strategy is
produced next.

Everything is written against *fixed AOT shapes* (padded with masks) so the
two entry points — ``infer`` and ``train_step`` — can be lowered once to
HLO text and executed from the Rust coordinator via PJRT.  All parameters
live in a single flat f32 vector so the Rust side handles exactly one
parameter literal (plus two Adam moment literals).

Feature layout (must match rust/src/gnn/features.rs — see Table 1 of the
paper):

    op node (F_OP = 11):
        0  computation time          log1p(ms), averaged over device types
        1  parameter size            log1p(MB)
        2-6 replication plan one-hot [undecided, AllReduce, PS, Duplicate, MP]
        7  makespan                  log1p(ms)  (simulator feedback, 0 if none)
        8  idle time before output transfer   log1p(ms)
        9  decided flag
        10 is-next flag (this op group's strategy is produced next)

    device node (F_DEV = 7):
        0  #GPUs in group / 8
        1  memory capacity           log1p(GB)
        2  intra-group bandwidth     log1p(Gbps)
        3  peak memory usage         fraction of capacity (feedback)
        4  idling percentage         (feedback)
        5  attached switch degree    log1p (0 on flat cliques)
        6  mean route hops to the other groups / 4

    op-op edge   (1): log1p(tensor MB)
    dev-dev edge (4): log1p(routed bottleneck Gbps), link idling
                      percentage, route hops / 8, log1p(route latency us)
    op-dev edge  (1): placement bit (current partial strategy)

The device-side structure features (5/6 and the dev-dev hop/latency
columns) come from the Rust topology's link graph (cluster::linkgraph);
flat clique topologies degenerate to (0 switches, 1-hop, 0 latency).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import gat_attention

# ---------------------------------------------------------------- constants
N_OP = 64  # max op groups (paper uses <= 60)
N_DEV = 16  # max device groups
N_CAND = 128  # max candidate strategy slices per decision
F_OP = 11  # raw op-node features
F_DEV = 7  # raw device-node features (incl. link-graph structure)
F_EDGE_OO = 1
F_EDGE_DD = 4  # routed bw, link idle, route hops, route latency
F_EDGE_OD = 1
HIDDEN = 64  # embedding width F
HEADS = 4
HEAD_DIM = HIDDEN // HEADS
LAYERS = 4
DEC_HIDDEN = 128
B_INFER = 8  # inference batch (leaf evaluations batched by the coordinator)
B_TRAIN = 16  # training batch

GAMMA_SAME = 1.0
GAMMA_CROSS = 0.1

ADAM_LR = 1e-3
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
GRAD_CLIP = 1.0

# Edge types: (name, src entity, dst entity, raw edge feature dim)
ETYPES = [
    ("oo", "op", "op", F_EDGE_OO),
    ("dd", "dev", "dev", F_EDGE_DD),
    ("od", "dev", "op", F_EDGE_OD),  # messages dev -> op
    ("do", "op", "dev", F_EDGE_OD),  # messages op -> dev
]

# ------------------------------------------------------------- param spec


def param_spec():
    """Ordered (name, shape) list — the single source of truth for the
    layout of the flat parameter vector."""
    spec = [
        ("enc_op_w", (F_OP, HIDDEN)),
        ("enc_op_b", (HIDDEN,)),
        ("enc_dev_w", (F_DEV, HIDDEN)),
        ("enc_dev_b", (HIDDEN,)),
    ]
    for l in range(LAYERS):
        for name, _src, _dst, fe in ETYPES:
            p = f"l{l}_{name}"
            spec += [
                (f"{p}_wn", (HIDDEN, HIDDEN)),  # source/dst node transform
                (f"{p}_bn", (HIDDEN,)),
                (f"{p}_we", (fe, HIDDEN)),  # edge-feature transform
                (f"{p}_asrc", (HEADS, HEAD_DIM)),
                (f"{p}_adst", (HEADS, HEAD_DIM)),
                (f"{p}_aedge", (HEADS, HEAD_DIM)),
            ]
        spec += [
            (f"l{l}_self_op_w", (HIDDEN, HIDDEN)),
            (f"l{l}_self_op_b", (HIDDEN,)),
            (f"l{l}_self_dev_w", (HIDDEN, HIDDEN)),
            (f"l{l}_self_dev_b", (HIDDEN,)),
        ]
    spec += [
        ("dec_w1", (2 * HIDDEN + 4, DEC_HIDDEN)),
        ("dec_b1", (DEC_HIDDEN,)),
        ("dec_w2", (DEC_HIDDEN, 1)),
        ("dec_b2", (1,)),
    ]
    return spec


_SPEC = param_spec()
PARAM_COUNT = int(sum(int(np.prod(s)) for _, s in _SPEC))


def init_params(seed=0):
    """Glorot-ish init, returned as the flat f32 vector."""
    rng = np.random.RandomState(seed)
    chunks = []
    for name, shape in _SPEC:
        if name.endswith("_b") or name.endswith("_bn") or "_b" == name[-2:]:
            chunks.append(np.zeros(shape, np.float32).ravel())
        elif len(shape) == 2:
            scale = np.sqrt(2.0 / (shape[0] + shape[1]))
            chunks.append((rng.randn(*shape) * scale).astype(np.float32).ravel())
        else:
            scale = np.sqrt(1.0 / max(1, int(np.prod(shape))))
            chunks.append((rng.randn(*shape) * scale).astype(np.float32).ravel())
    flat = np.concatenate(chunks)
    assert flat.size == PARAM_COUNT
    return flat


def unflatten(flat):
    """Flat f32 vector -> dict of named arrays (static slices, jit-safe)."""
    params = {}
    off = 0
    for name, shape in _SPEC:
        size = int(np.prod(shape))
        params[name] = flat[off : off + size].reshape(shape)
        off += size
    return params


# ------------------------------------------------------------- GNN forward


def _etype_attention(p, prefix, h_src, h_dst, edge_feat, mask):
    """One edge type's multi-head attention aggregation (via the L1 kernel).

    h_src (S, HIDDEN), h_dst (N, HIDDEN), edge_feat (N, S, FE), mask (N, S)
    -> (N, HIDDEN)
    """
    z_src = h_src @ p[f"{prefix}_wn"] + p[f"{prefix}_bn"]  # (S, HIDDEN)
    z_dst = h_dst @ p[f"{prefix}_wn"] + p[f"{prefix}_bn"]  # (N, HIDDEN)
    z_edge = edge_feat @ p[f"{prefix}_we"]  # (N, S, HIDDEN)

    n = h_dst.shape[0]
    s = h_src.shape[0]
    zsh = z_src.reshape(s, HEADS, HEAD_DIM)
    zdh = z_dst.reshape(n, HEADS, HEAD_DIM)
    zeh = z_edge.reshape(n, s, HEADS, HEAD_DIM)

    q = jnp.einsum("nhd,hd->nh", zdh, p[f"{prefix}_adst"])  # (N, H)
    kv = jnp.einsum("shd,hd->sh", zsh, p[f"{prefix}_asrc"])  # (S, H)
    ke = jnp.einsum("nshd,hd->nsh", zeh, p[f"{prefix}_aedge"])  # (N, S, H)

    out = gat_attention(q, kv, ke, zsh, mask)  # (N, HEADS, HEAD_DIM)
    return out.reshape(n, HIDDEN)


def gnn_forward(p, feats):
    """Run the heterogeneous GNN; returns (op embeddings, dev embeddings).

    ``feats`` is a dict of one position's feature arrays (unbatched):
        op_feats (N_OP, F_OP), dev_feats (N_DEV, F_DEV),
        oo_e (N_OP, N_OP, F_EDGE_OO), oo_mask (N_OP, N_OP),
        dd_e (N_DEV, N_DEV, F_EDGE_DD), dd_mask (N_DEV, N_DEV),
        od_place (N_OP, N_DEV), op_mask (N_OP,), dev_mask (N_DEV,)
    """
    h_op = jax.nn.relu(feats["op_feats"] @ p["enc_op_w"] + p["enc_op_b"])
    h_dev = jax.nn.relu(feats["dev_feats"] @ p["enc_dev_w"] + p["enc_dev_b"])

    # Zero out padded nodes so they contribute nothing anywhere.
    h_op = h_op * feats["op_mask"][:, None]
    h_dev = h_dev * feats["dev_mask"][:, None]

    od_e = feats["od_place"][:, :, None]  # (N_OP, N_DEV, 1)
    do_e = jnp.transpose(feats["od_place"])[:, :, None]  # (N_DEV, N_OP, 1)
    # Placement edges exist where an op group is (tentatively) placed;
    # additionally every op sees every live device weakly so that undecided
    # ops can still read device state.  mask = placement OR live-pair.
    live_pair = feats["op_mask"][:, None] * feats["dev_mask"][None, :]
    od_mask = jnp.maximum(feats["od_place"], 0.25 * live_pair)
    od_mask = jnp.where(od_mask > 0, 1.0, 0.0) * live_pair
    do_mask = jnp.transpose(od_mask)

    for l in range(LAYERS):
        a_oo = _etype_attention(
            p, f"l{l}_oo", h_op, h_op, feats["oo_e"], feats["oo_mask"]
        )
        a_dd = _etype_attention(
            p, f"l{l}_dd", h_dev, h_dev, feats["dd_e"], feats["dd_mask"]
        )
        a_od = _etype_attention(p, f"l{l}_od", h_dev, h_op, od_e, od_mask)
        a_do = _etype_attention(p, f"l{l}_do", h_op, h_dev, do_e, do_mask)

        pre_op = (
            h_op @ p[f"l{l}_self_op_w"]
            + p[f"l{l}_self_op_b"]
            + GAMMA_SAME * a_oo
            + GAMMA_CROSS * a_od
        )
        pre_dev = (
            h_dev @ p[f"l{l}_self_dev_w"]
            + p[f"l{l}_self_dev_b"]
            + GAMMA_SAME * a_dd
            + GAMMA_CROSS * a_do
        )
        h_op = (h_op + jax.nn.relu(pre_op)) * feats["op_mask"][:, None]
        h_dev = (h_dev + jax.nn.relu(pre_dev)) * feats["dev_mask"][:, None]

    return h_op, h_dev


def decoder_logits(p, h_op, h_dev, feats):
    """Score candidate strategy slices for the `next` op group.

    Candidate arrays:
        cand_p (N_CAND, N_DEV)  binary placement rows
        cand_o (N_CAND, 4)      one-hot replication option
        cand_mask (N_CAND,)     1 = real candidate
        next_onehot (N_OP,)     selects the op group under decision
    Returns masked logits (N_CAND,).
    """
    e_op = feats["next_onehot"] @ h_op  # (HIDDEN,)
    placed = feats["cand_p"] @ h_dev  # (N_CAND, HIDDEN)
    e_b = jnp.broadcast_to(e_op, (N_CAND, HIDDEN))
    x = jnp.concatenate([placed, e_b, feats["cand_o"]], axis=-1)
    hdec = jax.nn.relu(x @ p["dec_w1"] + p["dec_b1"])
    scores = (hdec @ p["dec_w2"] + p["dec_b2"])[:, 0]  # (N_CAND,)
    return jnp.where(feats["cand_mask"] > 0, scores, -1e9)


FEATURE_NAMES = [
    ("op_feats", (N_OP, F_OP)),
    ("dev_feats", (N_DEV, F_DEV)),
    ("oo_e", (N_OP, N_OP, F_EDGE_OO)),
    ("oo_mask", (N_OP, N_OP)),
    ("dd_e", (N_DEV, N_DEV, F_EDGE_DD)),
    ("dd_mask", (N_DEV, N_DEV)),
    ("od_place", (N_OP, N_DEV)),
    ("op_mask", (N_OP,)),
    ("dev_mask", (N_DEV,)),
    ("next_onehot", (N_OP,)),
    ("cand_p", (N_CAND, N_DEV)),
    ("cand_o", (N_CAND, 4)),
    ("cand_mask", (N_CAND,)),
]


def _position_priors(p, feats):
    h_op, h_dev = gnn_forward(p, feats)
    logits = decoder_logits(p, h_op, h_dev, feats)
    return jax.nn.softmax(logits)


def _feats_dict(args):
    return {name: a for (name, _), a in zip(FEATURE_NAMES, args)}


def infer(params_flat, *feature_args):
    """AOT entry point: batched prior probabilities.

    feature_args: one array per FEATURE_NAMES entry, each with a leading
    batch dim B_INFER.  Returns priors (B_INFER, N_CAND).
    """
    p = unflatten(params_flat)

    def one(*args):
        return _position_priors(p, _feats_dict(args))

    return jax.vmap(one)(*feature_args)


# ---------------------------------------------------------------- training


def _position_loss(p, feats, target_pi):
    h_op, h_dev = gnn_forward(p, feats)
    logits = decoder_logits(p, h_op, h_dev, feats)
    logp = jax.nn.log_softmax(logits)
    # Cross entropy against the MCTS visit distribution (§4.2.2).
    return -jnp.sum(target_pi * logp)


def loss_fn(params_flat, feature_args, target_pi, example_mask):
    p = unflatten(params_flat)

    def one(args, pi):
        return _position_loss(p, _feats_dict(args), pi)

    losses = jax.vmap(one)(feature_args, target_pi)  # (B_TRAIN,)
    denom = jnp.maximum(jnp.sum(example_mask), 1.0)
    return jnp.sum(losses * example_mask) / denom


def train_step(params_flat, m, v, step, *rest):
    """AOT entry point: one Adam step on a batch of MCTS examples.

    rest = feature arrays (each with leading B_TRAIN), then
    target_pi (B_TRAIN, N_CAND), example_mask (B_TRAIN,).
    Returns (new_params, new_m, new_v, loss).
    """
    nf = len(FEATURE_NAMES)
    feature_args = tuple(rest[:nf])
    target_pi = rest[nf]
    example_mask = rest[nf + 1]

    loss, g = jax.value_and_grad(loss_fn)(
        params_flat, feature_args, target_pi, example_mask
    )
    # Global-norm gradient clipping.
    gnorm = jnp.sqrt(jnp.sum(g * g))
    scale = jnp.minimum(1.0, GRAD_CLIP / jnp.maximum(gnorm, 1e-12))
    g = g * scale

    t = step + 1.0
    m2 = ADAM_B1 * m + (1 - ADAM_B1) * g
    v2 = ADAM_B2 * v + (1 - ADAM_B2) * g * g
    mhat = m2 / (1 - ADAM_B1**t)
    vhat = v2 / (1 - ADAM_B2**t)
    new_params = params_flat - ADAM_LR * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return new_params, m2, v2, loss


# ------------------------------------------------------------ shape helpers


def infer_input_specs():
    """ShapeDtypeStructs for jax.jit(infer).lower(...)."""
    f32 = jnp.float32
    specs = [jax.ShapeDtypeStruct((PARAM_COUNT,), f32)]
    for _, shape in FEATURE_NAMES:
        specs.append(jax.ShapeDtypeStruct((B_INFER,) + shape, f32))
    return specs


def train_input_specs():
    f32 = jnp.float32
    specs = [
        jax.ShapeDtypeStruct((PARAM_COUNT,), f32),  # params
        jax.ShapeDtypeStruct((PARAM_COUNT,), f32),  # m
        jax.ShapeDtypeStruct((PARAM_COUNT,), f32),  # v
        jax.ShapeDtypeStruct((), f32),  # step
    ]
    for _, shape in FEATURE_NAMES:
        specs.append(jax.ShapeDtypeStruct((B_TRAIN,) + shape, f32))
    specs.append(jax.ShapeDtypeStruct((B_TRAIN, N_CAND), f32))  # target_pi
    specs.append(jax.ShapeDtypeStruct((B_TRAIN,), f32))  # example_mask
    return specs


def infer_wrapped(*args):
    return (infer(*args),)


def train_wrapped(*args):
    return train_step(*args)
