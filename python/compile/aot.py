"""AOT export: lower TAG's GNN entry points to HLO *text* for the Rust side.

HLO text (NOT ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 crate links) rejects; the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/load_hlo/.

Outputs (under --out-dir, default ../artifacts relative to python/):
    gnn_infer.hlo.txt   batched prior inference   (B_INFER positions)
    gnn_train.hlo.txt   one Adam training step    (B_TRAIN examples)
    params_init.bin     initial flat f32 params (little-endian)
    manifest.txt        shapes/constants consumed by rust/src/gnn/
"""

import argparse
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def manifest_text() -> str:
    lines = ["# TAG GNN AOT manifest: `const NAME VALUE` and `input FN IDX NAME DIMS`"]
    for k in (
        "N_OP",
        "N_DEV",
        "N_CAND",
        "F_OP",
        "F_DEV",
        "HIDDEN",
        "HEADS",
        "LAYERS",
        "B_INFER",
        "B_TRAIN",
        "PARAM_COUNT",
    ):
        lines.append(f"const {k} {getattr(model, k)}")
    idx = 0
    lines.append(f"input infer {idx} params {model.PARAM_COUNT}")
    idx += 1
    for name, shape in model.FEATURE_NAMES:
        dims = ",".join(str(d) for d in (model.B_INFER,) + shape)
        lines.append(f"input infer {idx} {name} {dims}")
        idx += 1
    idx = 0
    for name in ("params", "m", "v"):
        lines.append(f"input train {idx} {name} {model.PARAM_COUNT}")
        idx += 1
    lines.append(f"input train {idx} step 1")
    idx += 1
    for name, shape in model.FEATURE_NAMES:
        dims = ",".join(str(d) for d in (model.B_TRAIN,) + shape)
        lines.append(f"input train {idx} {name} {dims}")
        idx += 1
    lines.append(f"input train {idx} target_pi {model.B_TRAIN},{model.N_CAND}")
    idx += 1
    lines.append(f"input train {idx} example_mask {model.B_TRAIN}")
    return "\n".join(lines) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="(compat) path of infer hlo")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    out_dir = args.out_dir
    if args.out is not None:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    print(f"[aot] param count = {model.PARAM_COUNT}")

    infer_lowered = jax.jit(model.infer_wrapped).lower(*model.infer_input_specs())
    infer_hlo = to_hlo_text(infer_lowered)
    with open(os.path.join(out_dir, "gnn_infer.hlo.txt"), "w") as f:
        f.write(infer_hlo)
    print(f"[aot] gnn_infer.hlo.txt: {len(infer_hlo)} chars")

    train_lowered = jax.jit(model.train_wrapped).lower(*model.train_input_specs())
    train_hlo = to_hlo_text(train_lowered)
    with open(os.path.join(out_dir, "gnn_train.hlo.txt"), "w") as f:
        f.write(train_hlo)
    print(f"[aot] gnn_train.hlo.txt: {len(train_hlo)} chars")

    params = model.init_params(args.seed)
    params.astype("<f4").tofile(os.path.join(out_dir, "params_init.bin"))
    print(f"[aot] params_init.bin: {params.nbytes} bytes")

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write(manifest_text())

    # Back-compat marker for the Makefile's single-file dependency target.
    marker = os.path.join(out_dir, "model.hlo.txt")
    with open(marker, "w") as f:
        f.write(infer_hlo)
    print("[aot] done")


if __name__ == "__main__":
    main()
