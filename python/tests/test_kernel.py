"""L1 correctness: Pallas GAT-attention kernel vs the pure-jnp oracle.

This is the CORE numeric signal for the compile path: the kernel that the
AOT-lowered HLO embeds must agree with ``ref.gat_attention_ref`` over a
sweep of shapes, masks and magnitudes (hypothesis), and its custom VJP
must agree with jax autodiff of the reference.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gat_attention, gat_attention_ref
from compile.kernels.gat_attention import BLOCK_N

jax.config.update("jax_platform_name", "cpu")

# N must be a multiple of the kernel block (or smaller than it).
VALID_N = [1, 2, 4, 8, 16, 32, 48, 64]


def _rand(rng, *shape):
    return jnp.asarray(rng.randn(*shape).astype(np.float32))


def _rand_mask(rng, n, s, p=0.6):
    return jnp.asarray((rng.rand(n, s) < p).astype(np.float32))


def _mk(rng, n, s, h, d, mask_p=0.6, scale=1.0):
    q = _rand(rng, n, h) * scale
    kv = _rand(rng, s, h) * scale
    ke = _rand(rng, n, s, h) * scale
    v = _rand(rng, s, h, d)
    mask = _rand_mask(rng, n, s, mask_p)
    return q, kv, ke, v, mask


def test_matches_ref_basic():
    rng = np.random.RandomState(0)
    args = _mk(rng, 16, 24, 4, 8)
    out = gat_attention(*args)
    ref = gat_attention_ref(*args)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(
    n=st.sampled_from(VALID_N),
    s=st.integers(min_value=1, max_value=40),
    h=st.integers(min_value=1, max_value=6),
    d=st.integers(min_value=1, max_value=24),
    mask_p=st.sampled_from([0.0, 0.1, 0.5, 0.9, 1.0]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matches_ref_hypothesis(n, s, h, d, mask_p, seed):
    rng = np.random.RandomState(seed)
    args = _mk(rng, n, s, h, d, mask_p)
    out = gat_attention(*args)
    ref = gat_attention_ref(*args)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)
    assert not np.any(np.isnan(np.asarray(out)))


@settings(max_examples=10, deadline=None)
@given(
    scale=st.sampled_from([1e-3, 1.0, 10.0, 50.0]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_numerical_stability_large_logits(scale, seed):
    """Large logits must not overflow thanks to the running-max trick."""
    rng = np.random.RandomState(seed)
    args = _mk(rng, 16, 16, 2, 4, 0.5, scale)
    out = gat_attention(*args)
    ref = gat_attention_ref(*args)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)
    assert np.all(np.isfinite(np.asarray(out)))


def test_all_masked_rows_are_zero():
    rng = np.random.RandomState(1)
    q, kv, ke, v, _ = _mk(rng, 16, 8, 4, 4)
    mask = jnp.zeros((16, 8), jnp.float32)
    out = gat_attention(q, kv, ke, v, mask)
    np.testing.assert_array_equal(np.asarray(out), np.zeros((16, 4, 4), np.float32))


def test_partial_masked_rows():
    """Row 0 fully masked, others full: only row 0 must be zero."""
    rng = np.random.RandomState(2)
    q, kv, ke, v, _ = _mk(rng, 16, 8, 2, 4)
    mask = jnp.ones((16, 8), jnp.float32).at[0].set(0.0)
    out = np.asarray(gat_attention(q, kv, ke, v, mask))
    assert np.all(out[0] == 0.0)
    assert np.any(out[1:] != 0.0)


def test_single_unmasked_source_copies_value():
    """With one live source the softmax is 1 and the output == its value."""
    rng = np.random.RandomState(3)
    q, kv, ke, v, _ = _mk(rng, 8, 8, 2, 4)
    mask = jnp.zeros((8, 8), jnp.float32).at[:, 3].set(1.0)
    out = np.asarray(gat_attention(q, kv, ke, v, mask))
    expect = np.broadcast_to(np.asarray(v)[3][None], (8, 2, 4))
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-6)


def test_softmax_invariance_to_logit_shift():
    """Adding a constant to q shifts all logits of a row equally ->
    identical probabilities -> identical output (LeakyReLU is monotonic but
    not shift-invariant, so compare in the linear region: all logits > 0)."""
    rng = np.random.RandomState(4)
    q, kv, ke, v, mask = _mk(rng, 16, 8, 2, 4, 1.0)
    q, kv, ke = jnp.abs(q) + 5.0, jnp.abs(kv), jnp.abs(ke)
    out1 = gat_attention(q, kv, ke, v, mask)
    out2 = gat_attention(q + 3.0, kv, ke, v, mask)
    np.testing.assert_allclose(out1, out2, rtol=1e-4, atol=1e-5)


def test_block_boundary_shapes():
    """N exactly at and above BLOCK_N exercises the grid tiling."""
    rng = np.random.RandomState(5)
    for n in (BLOCK_N, 2 * BLOCK_N, 4 * BLOCK_N):
        args = _mk(rng, n, 12, 3, 5)
        np.testing.assert_allclose(
            gat_attention(*args),
            gat_attention_ref(*args),
            rtol=1e-4,
            atol=1e-5,
        )


def test_invalid_n_raises():
    rng = np.random.RandomState(6)
    args = _mk(rng, 24, 8, 2, 4)  # 24 not a multiple of 16
    with pytest.raises(ValueError):
        gat_attention(*args)


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([4, 8, 16, 32]),
    s=st.integers(min_value=2, max_value=20),
    h=st.integers(min_value=1, max_value=4),
    d=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_custom_vjp_matches_autodiff_of_ref(n, s, h, d, seed):
    """The hand-written backward (used inside the AOT train step) must
    agree with jax autodiff through the pure-jnp reference."""
    rng = np.random.RandomState(seed)
    q, kv, ke, v, mask = _mk(rng, n, s, h, d, 0.7)

    def f_kernel(q, kv, ke, v):
        return jnp.sum(jnp.sin(gat_attention(q, kv, ke, v, mask)))

    def f_ref(q, kv, ke, v):
        return jnp.sum(jnp.sin(gat_attention_ref(q, kv, ke, v, mask)))

    gk = jax.grad(f_kernel, argnums=(0, 1, 2, 3))(q, kv, ke, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2, 3))(q, kv, ke, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_vmap_matches_loop():
    """The model vmaps the kernel over the batch dim; verify equivalence."""
    rng = np.random.RandomState(7)
    batch = [_mk(rng, 16, 8, 2, 4) for _ in range(3)]
    stacked = [jnp.stack([b[i] for b in batch]) for i in range(5)]
    out_vmap = jax.vmap(gat_attention)(*stacked)
    for i, args in enumerate(batch):
        np.testing.assert_allclose(
            out_vmap[i], gat_attention(*args), rtol=1e-5, atol=1e-6
        )
