"""L2 correctness: hetero-GNN forward, decoder, loss and Adam train step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")


def _random_feats(rng, batch=None):
    """Random but structurally valid feature set (one position)."""

    def mk(shape, scale=1.0):
        full = shape if batch is None else (batch,) + shape
        return jnp.asarray(rng.rand(*full).astype(np.float32) * scale)

    n, m, a = model.N_OP, model.N_DEV, model.N_CAND
    n_live, m_live, a_live = 10, 3, 12

    feats = {}
    feats["op_feats"] = mk((n, model.F_OP))
    feats["dev_feats"] = mk((m, model.F_DEV))
    feats["oo_e"] = mk((n, n, model.F_EDGE_OO))
    oo_mask = (rng.rand(n, n) < 0.2).astype(np.float32)
    oo_mask[n_live:, :] = 0
    oo_mask[:, n_live:] = 0
    feats["oo_mask"] = _b(jnp.asarray(oo_mask), batch)
    feats["dd_e"] = mk((m, m, model.F_EDGE_DD))
    dd_mask = np.ones((m, m), np.float32)
    dd_mask[m_live:, :] = 0
    dd_mask[:, m_live:] = 0
    feats["dd_mask"] = _b(jnp.asarray(dd_mask), batch)
    place = (rng.rand(n, m) < 0.3).astype(np.float32)
    place[n_live:, :] = 0
    place[:, m_live:] = 0
    feats["od_place"] = _b(jnp.asarray(place), batch)
    opm = np.zeros(n, np.float32)
    opm[:n_live] = 1
    feats["op_mask"] = _b(jnp.asarray(opm), batch)
    devm = np.zeros(m, np.float32)
    devm[:m_live] = 1
    feats["dev_mask"] = _b(jnp.asarray(devm), batch)
    nxt = np.zeros(n, np.float32)
    nxt[2] = 1
    feats["next_onehot"] = _b(jnp.asarray(nxt), batch)
    cand_p = (rng.rand(a, m) < 0.4).astype(np.float32)
    cand_p[:, m_live:] = 0
    feats["cand_p"] = _b(jnp.asarray(cand_p), batch)
    cand_o = np.zeros((a, 4), np.float32)
    cand_o[np.arange(a), rng.randint(0, 4, a)] = 1
    feats["cand_o"] = _b(jnp.asarray(cand_o), batch)
    cm = np.zeros(a, np.float32)
    cm[:a_live] = 1
    feats["cand_mask"] = _b(jnp.asarray(cm), batch)
    return feats


def _b(x, batch):
    if batch is None:
        return x
    return jnp.broadcast_to(x, (batch,) + x.shape)


@pytest.fixture(scope="module")
def params():
    return jnp.asarray(model.init_params(0))


def test_param_count_matches_spec(params):
    assert params.shape == (model.PARAM_COUNT,)
    p = model.unflatten(params)
    assert p["dec_w2"].shape == (model.DEC_HIDDEN, 1)
    total = sum(int(np.prod(v.shape)) for v in p.values())
    assert total == model.PARAM_COUNT


def test_forward_shapes_and_finite(params):
    rng = np.random.RandomState(0)
    feats = _random_feats(rng)
    p = model.unflatten(params)
    h_op, h_dev = model.gnn_forward(p, feats)
    assert h_op.shape == (model.N_OP, model.HIDDEN)
    assert h_dev.shape == (model.N_DEV, model.HIDDEN)
    assert np.all(np.isfinite(np.asarray(h_op)))
    assert np.all(np.isfinite(np.asarray(h_dev)))


def test_padded_nodes_have_zero_embeddings(params):
    rng = np.random.RandomState(1)
    feats = _random_feats(rng)
    p = model.unflatten(params)
    h_op, h_dev = model.gnn_forward(p, feats)
    np.testing.assert_array_equal(np.asarray(h_op)[10:], 0.0)
    np.testing.assert_array_equal(np.asarray(h_dev)[3:], 0.0)


def test_priors_are_masked_distribution(params):
    rng = np.random.RandomState(2)
    feats = _random_feats(rng)
    p = model.unflatten(params)
    pr = np.asarray(model._position_priors(p, feats))
    assert pr.shape == (model.N_CAND,)
    np.testing.assert_allclose(pr.sum(), 1.0, rtol=1e-5)
    # Masked candidates get (numerically) zero probability.
    assert pr[12:].max() < 1e-12
    assert np.all(pr >= 0)


def test_infer_batched_matches_single(params):
    rng = np.random.RandomState(3)
    feats = _random_feats(rng, batch=model.B_INFER)
    args = [feats[name] for name, _ in model.FEATURE_NAMES]
    out = np.asarray(model.infer(params, *args))
    assert out.shape == (model.B_INFER, model.N_CAND)
    p = model.unflatten(params)
    single = {name: feats[name][0] for name, _ in model.FEATURE_NAMES}
    pr0 = np.asarray(model._position_priors(p, single))
    np.testing.assert_allclose(out[0], pr0, rtol=1e-5, atol=1e-7)


def test_padded_positions_are_harmless(params):
    """A fully-zero (padded) batch slot must not produce NaNs."""
    args = [
        jnp.zeros((model.B_INFER,) + shape, jnp.float32)
        for _, shape in model.FEATURE_NAMES
    ]
    out = np.asarray(model.infer(params, *args))
    assert np.all(np.isfinite(out))


def _train_batch(rng):
    feats = _random_feats(rng, batch=model.B_TRAIN)
    args = [feats[name] for name, _ in model.FEATURE_NAMES]
    pi = np.zeros((model.B_TRAIN, model.N_CAND), np.float32)
    pi[:, :12] = rng.rand(model.B_TRAIN, 12).astype(np.float32)
    pi /= pi.sum(axis=1, keepdims=True)
    mask = np.ones(model.B_TRAIN, np.float32)
    return args, jnp.asarray(pi), jnp.asarray(mask)


def test_gradient_direction_reduces_loss(params):
    """Descending along the analytic gradient must reduce the CE loss."""
    rng = np.random.RandomState(4)
    args, pi, mask = _train_batch(rng)
    loss0, g = jax.value_and_grad(model.loss_fn)(params, tuple(args), pi, mask)
    gn2 = float(jnp.sum(g * g))
    assert gn2 > 0
    eps = 1e-2 / np.sqrt(gn2)
    loss1 = model.loss_fn(params - eps * g, tuple(args), pi, mask)
    assert float(loss1) < float(loss0)


def test_train_step_adam_finite_and_moving(params):
    rng = np.random.RandomState(40)
    args, pi, mask = _train_batch(rng)
    m = jnp.zeros_like(params)
    v = jnp.zeros_like(params)
    p = params
    for t in range(3):
        p, m, v, loss = model.train_step(p, m, v, jnp.float32(t), *args, pi, mask)
        assert np.isfinite(float(loss))
    assert np.all(np.isfinite(np.asarray(p)))
    assert float(jnp.max(jnp.abs(p - params))) > 0


def test_train_step_respects_example_mask(params):
    """Masked-out examples must not influence the gradient."""
    rng = np.random.RandomState(5)
    args, pi, _ = _train_batch(rng)
    m = jnp.zeros_like(params)
    v = jnp.zeros_like(params)

    mask_half = np.ones(model.B_TRAIN, np.float32)
    mask_half[model.B_TRAIN // 2 :] = 0

    # Corrupt the masked-out half's targets; results must be identical.
    pi2 = np.asarray(pi).copy()
    pi2[model.B_TRAIN // 2 :] = 1.0 / model.N_CAND
    r1 = model.train_step(params, m, v, 0.0, *args, pi, jnp.asarray(mask_half))
    r2 = model.train_step(
        params, m, v, 0.0, *args, jnp.asarray(pi2), jnp.asarray(mask_half)
    )
    np.testing.assert_allclose(np.asarray(r1[0]), np.asarray(r2[0]), atol=1e-7)
    np.testing.assert_allclose(float(r1[3]), float(r2[3]), rtol=1e-6)


def test_train_step_grad_clipping_keeps_params_finite(params):
    rng = np.random.RandomState(6)
    args, pi, mask = _train_batch(rng)
    # Hugely scaled features stress the gradients.
    args = [a * 100.0 for a in args]
    m = jnp.zeros_like(params)
    v = jnp.zeros_like(params)
    p2, m2, v2, loss = model.train_step(params, m, v, 0.0, *args, pi, mask)
    assert np.all(np.isfinite(np.asarray(p2)))
    delta = np.abs(np.asarray(p2) - np.asarray(params)).max()
    # Adam with bias correction at t=1: per-step delta ~ lr.
    assert delta <= 5 * model.ADAM_LR


def test_init_params_deterministic():
    a = model.init_params(0)
    b = model.init_params(0)
    c = model.init_params(1)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


def test_infer_input_specs_consistent_with_manifest():
    specs = model.infer_input_specs()
    assert specs[0].shape == (model.PARAM_COUNT,)
    assert len(specs) == 1 + len(model.FEATURE_NAMES)
    tspecs = model.train_input_specs()
    assert len(tspecs) == 4 + len(model.FEATURE_NAMES) + 2
