//! Bench: the routed link-graph layer — route-table construction (paid
//! once per topology, cold) vs cached route queries (the per-evaluation
//! hot path), and what contention-aware simulation costs on top of the
//! flat-matrix model.
//!
//! Uses the largest hierarchical preset (`multi_rack`: 32 GPUs, 12
//! machines, 4 racks behind an oversubscribed spine) and its flattened
//! clique collapse as the baseline.

use tag::cluster::presets::multi_rack;
use tag::cluster::Topology;
use tag::dist::Lowering;
use tag::graph::grouping::group_ops;
use tag::models;
use tag::profile::{unique_gpus, CommModel, CostModel};
use tag::strategy::{enumerate_actions, Strategy};
use tag::util::bench;

fn main() {
    println!("== routing: route-table construction (cold) ==");
    // Preset construction includes graph build + widest-path routing for
    // all device pairs + derived-matrix extraction + validation.
    let build = bench("construct[multi_rack]", 1.0, || {
        let t = multi_rack();
        assert!(t.is_routed());
    });
    let topo = multi_rack();
    println!(
        "    -> {} devices, {} nodes, {} links routed in {:.2} ms",
        topo.num_devices(),
        topo.link_graph().num_nodes(),
        topo.link_graph().num_links(),
        build * 1e3
    );

    println!("\n== routing: cached route queries (warm) ==");
    let devs = topo.devices();
    bench("bw_gbps[all pairs]", 1.0, || {
        let mut acc = 0.0;
        for (i, &a) in devs.iter().enumerate() {
            for &b in &devs[i + 1..] {
                acc += topo.bw_gbps(a, b);
            }
        }
        assert!(acc > 0.0);
    });
    bench("link_profile[all devices]", 1.0, || {
        let p = topo.link_profile(&devs);
        assert!(p.bottleneck_gbps > 0.0);
    });

    println!("\n== simulation: contention-aware (routed) vs naive bottleneck (flat) ==");
    let flat = Topology::new(
        "multi-rack-flattened",
        topo.groups.clone(),
        topo.inter_bw_gbps.clone(),
    );
    let model = models::by_name("VGG19", 0.25).unwrap();
    let cost = CostModel::profile(&model.ops, &unique_gpus(&topo), 0.0, 1);
    let gg = group_ops(&model, &cost, 24, 7);
    let comm = CommModel::fit(3);
    let low_routed = Lowering::new(&gg, &topo, &cost, &comm);
    let low_flat = Lowering::new(&gg, &flat, &cost, &comm);
    let strategies: Vec<Strategy> = enumerate_actions(&topo)
        .into_iter()
        .map(|a| Strategy::uniform(gg.num_groups(), a))
        .collect();
    let n = strategies.len();
    let t_flat = bench(&format!("evaluate[flat x{n}]"), 1.0, || {
        for s in &strategies {
            assert!(low_flat.evaluate_uncached(s).time > 0.0);
        }
    });
    let t_routed = bench(&format!("evaluate[routed x{n}]"), 1.0, || {
        for s in &strategies {
            assert!(low_routed.evaluate_uncached(s).time > 0.0);
        }
    });
    println!(
        "    -> contention overhead: {:.1}% per evaluation ({:.1} vs {:.1} us)",
        100.0 * (t_routed / t_flat - 1.0),
        t_routed / n as f64 * 1e6,
        t_flat / n as f64 * 1e6,
    );

    // The per-mask link-profile memo: after one pass every placement's
    // O(n²) bottleneck/latency profile is a cache hit.
    let (hits, misses) = low_routed.mask_memo_stats();
    println!(
        "    -> mask link-profile memo: {hits} hits / {misses} misses ({:.0}% hit rate)",
        100.0 * low_routed.mask_memo_hit_rate()
    );
}
