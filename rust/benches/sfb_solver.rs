//! Bench: the SFB branch-and-bound ILP (the Cbc replacement).  The paper
//! reports Cbc solves these "reliably within hundreds of milliseconds";
//! our exact solver should be comfortably inside that envelope on the
//! same per-gradient subproblems.

use tag::cluster::presets::sfb_pair;
use tag::graph::grouping::group_ops;
use tag::models;
use tag::profile::{unique_gpus, CostModel};
use tag::sfb::{extract_problem, solve};
use tag::util::{bench, Stopwatch};

fn main() {
    let topo = sfb_pair();
    println!("== SFB ILP: real per-gradient subproblems ==");
    for name in ["VGG19", "Transformer", "BERT-Small"] {
        let model = models::by_name(name, 0.25).unwrap();
        let cost = CostModel::profile(&model.ops, &unique_gpus(&topo), 0.0, 1);
        let gg = group_ops(&model, &cost, 24, 7);
        let pairs = model.grad_apply_pairs();
        let problems: Vec<_> = pairs
            .iter()
            .filter_map(|&(g, _)| extract_problem(&model, &gg, &cost, g, 2, 1.25e9))
            .map(|(p, _)| p)
            .collect();
        if problems.is_empty() {
            println!("{name}: no extractable problems");
            continue;
        }
        let max_n = problems.iter().map(|p| p.node_time.len()).max().unwrap();
        let m = bench(
            &format!("solve-all[{name}: {} problems, max {max_n} nodes]", problems.len()),
            1.0,
            || {
                for p in &problems {
                    let s = solve(p);
                    assert!(s.objective <= 1e-12);
                }
            },
        );
        println!(
            "    -> {:.3} ms per problem (paper: Cbc 'hundreds of ms')",
            m * 1e3 / problems.len() as f64
        );
        let worst = problems
            .iter()
            .map(|p| {
                let t = Stopwatch::start();
                let _ = solve(p);
                t.elapsed_ms()
            })
            .fold(0.0f64, f64::max);
        println!("    -> worst single problem: {worst:.2} ms");
    }
}
