//! Bench: the multilevel partitioner (METIS replacement) on the real
//! model graphs — op grouping is a per-job, per-topology preprocessing
//! step and must stay in the sub-second range even for BERT-Large's
//! ~18k-op graph.

use tag::cluster::presets::testbed;
use tag::graph::grouping::{group_ops, DEFAULT_GROUPS};
use tag::models;
use tag::partition::{check_balance, partition, PartGraph};
use tag::profile::{unique_gpus, CostModel};
use tag::util::{bench, Rng};

fn main() {
    let topo = testbed();
    println!("== grouping: profile + partition real model graphs ==");
    for (name, scale) in [("VGG19", 1.0), ("InceptionV3", 1.0), ("BERT-Large", 1.0)] {
        let model = models::by_name(name, scale).unwrap();
        let n = model.len();
        let cost = CostModel::profile(&model.ops, &unique_gpus(&topo), 0.0, 1);
        let m = bench(&format!("group_ops[{name}: {n} ops -> 60]"), 2.0, || {
            let gg = group_ops(&model, &cost, DEFAULT_GROUPS, 7);
            assert!(gg.num_groups() <= DEFAULT_GROUPS);
        });
        println!("    -> {:.0}k ops/s", n as f64 / m / 1e3);
    }

    println!("\n== raw partitioner: synthetic meshes ==");
    for side in [50usize, 100, 160] {
        let n = side * side;
        let mut g = PartGraph::new(n);
        let mut rng = Rng::new(3);
        for r in 0..side {
            for c in 0..side {
                let i = r * side + c;
                if c + 1 < side {
                    g.add_edge(i, i + 1, rng.uniform(0.5, 2.0));
                }
                if r + 1 < side {
                    g.add_edge(i, i + side, rng.uniform(0.5, 2.0));
                }
            }
        }
        bench(&format!("partition[{n}-node mesh -> 60]"), 1.0, || {
            let labels = partition(&g, 60, 2.0, 7);
            // Recursive bisection compounds per-level imbalance; the
            // k-way guarantee is soft — allow 2.5x on these stress meshes.
            assert!(check_balance(&g, &labels, 60, 2.5));
        });
    }
}
