//! Bench: fleet replay throughput — FIFO whole-cluster baseline vs
//! residual-aware best-fit on the oversubscribed `multi_rack` preset.
//! Reports both the wall time of the replay itself (scheduler + per-job
//! searches) and the *virtual* schedule quality each policy produced
//! (makespan / mean JCT / utilization), since the latter is the number
//! the policy exists to move.

use tag::api::SharedPlanner;
use tag::cluster::presets::multi_rack;
use tag::fleet::{generate_jobs, replay, FleetConfig, Policy};
use tag::util::bench;

fn main() {
    let topo = multi_rack();
    let jobs = generate_jobs(&topo, 7, 12, 15.0);
    println!(
        "== fleet replay: {} jobs on {} ({} GPUs) ==",
        jobs.len(),
        topo.name,
        topo.num_devices()
    );
    for policy in [Policy::Fifo, Policy::BestFit] {
        let cfg = FleetConfig { policy, iterations: 16, max_groups: 10, ..FleetConfig::default() };
        // Fresh planner per measured run: a warm cache would turn the
        // second policy's searches into lookups and skew the wall time.
        let wall = bench(&format!("fleet-replay[{}]", policy.name()), 2.0, || {
            let planner = SharedPlanner::builder().build();
            let report = replay(&planner, &topo, &jobs, &cfg).expect("replay");
            assert_eq!(report.jobs.len(), jobs.len());
        });
        let planner = SharedPlanner::builder().build();
        let report = replay(&planner, &topo, &jobs, &cfg).expect("replay");
        println!(
            "  -> {}: wall {:.3}s  makespan {:.1}s  mean jct {:.1}s  utilization {:.3}\n",
            policy.name(),
            wall,
            report.makespan_s,
            report.mean_jct_s,
            report.utilization
        );
    }
}
