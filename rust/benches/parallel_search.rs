//! Bench: tree-parallel MCTS scaling (1/2/4/8 workers) on the `cloud()`
//! preset — the §4.2.2 "deployment in seconds" claim as a curve.
//!
//! Two sections:
//!
//! 1. **Engine scaling** — `search::run_search` with a cold memo table
//!    per run, so each worker count pays the full lower+simulate load;
//!    wall-clock search time should be monotonically non-increasing from
//!    1 → 4 workers on a multi-core host (8 may flatten out once the
//!    memo/arena contention meets the core count).
//! 2. **Plan telemetry** — the same sweep through `api::Planner`,
//!    printing the per-worker iteration counts and memo hit rates each
//!    `DeploymentPlan` records, i.e. the scaling curve as it lands in
//!    served plan JSON.

use tag::api::{PlanRequest, Planner};
use tag::cluster::presets::cloud;
use tag::coordinator::{prepare, SearchConfig};
use tag::dist::Lowering;
use tag::mcts::UniformPrior;
use tag::models;
use tag::search::{run_search, Parallelism, SearchProblem};
use tag::strategy::enumerate_actions;
use tag::util::{bench, fmt_secs};

const ITERS: usize = 240;
const WORKER_COUNTS: [usize; 4] = [1, 2, 4, 8];

fn main() {
    let topo = cloud();
    let cfg = SearchConfig {
        max_groups: 16,
        mcts_iterations: ITERS,
        seed: 1,
        apply_sfb: false,
        profile_noise: 0.0,
        parallelism: Parallelism::default(),
        deadline_ms: None,
        delta: true,
    };
    let prep = prepare(models::by_name("VGG19", 0.25).unwrap(), &topo, &cfg);
    let actions = enumerate_actions(&topo);

    println!("== tree-parallel search: {ITERS}-iteration searches on cloud() ==");
    let mut curve = Vec::new();
    for &workers in &WORKER_COUNTS {
        let m = bench(&format!("search{ITERS}[workers={workers}]"), 1.5, || {
            // Fresh Lowering (and thus cold memo) per run: every worker
            // count pays full evaluation cost, so the curve measures
            // parallel speed-up, not caching.
            let low = Lowering::new(&prep.gg, &topo, &prep.cost, &prep.comm);
            let prob = SearchProblem {
                gg: &prep.gg,
                topo: &topo,
                cost: &prep.cost,
                comm: &prep.comm,
                actions: &actions,
            };
            let out = run_search(
                &prob,
                &low,
                (0..workers).map(|_| UniformPrior).collect(),
                ITERS,
                1,
                Parallelism::workers(workers),
                true,
                false,
                None,
            );
            assert_eq!(out.result.iterations, ITERS);
            assert!(out.result.best_time > 0.0);
        });
        curve.push((workers, m));
        println!("    -> {:.0} iterations/s", ITERS as f64 / m);
    }
    println!("\n    scaling curve (workers, search time):");
    let t1 = curve[0].1;
    for &(workers, t) in &curve {
        println!(
            "      {workers:>2} workers: {:>12}  speed-up {:.2}x",
            fmt_secs(t),
            t1 / t
        );
    }

    println!("\n== delta evaluation under tree-parallel search ==");
    for &workers in &[1usize, 4] {
        let mut arms = [0.0f64; 2];
        for (i, &delta) in [true, false].iter().enumerate() {
            let label = if delta { "on" } else { "off" };
            let m = bench(&format!("search{ITERS}[workers={workers},delta {label}]"), 1.5, || {
                // Fresh Lowering per run (cold memo + cold fragments):
                // the off arm pays full lowering+simulation for every
                // unique strategy; the on arm shares fragments and
                // frontier-restarts across all workers' evaluations.
                let low = Lowering::new(&prep.gg, &topo, &prep.cost, &prep.comm);
                low.set_delta(delta);
                let prob = SearchProblem {
                    gg: &prep.gg,
                    topo: &topo,
                    cost: &prep.cost,
                    comm: &prep.comm,
                    actions: &actions,
                };
                let out = run_search(
                    &prob,
                    &low,
                    (0..workers).map(|_| UniformPrior).collect(),
                    ITERS,
                    1,
                    Parallelism::workers(workers),
                    true,
                    false,
                    None,
                );
                assert_eq!(out.result.iterations, ITERS);
                assert!(out.result.best_time > 0.0);
            });
            arms[i] = m;
            println!("    -> {:.0} iterations/s", ITERS as f64 / m);
        }
        println!(
            "    workers={workers}: delta speed-up {:.2}x (on {} vs off {})",
            arms[1] / arms[0],
            fmt_secs(arms[0]),
            fmt_secs(arms[1]),
        );
    }

    println!("\n== the same sweep as plan telemetry (api::Planner) ==");
    for &workers in &WORKER_COUNTS {
        let planner = Planner::builder().without_cache().build();
        let request = PlanRequest::new(models::by_name("VGG19", 0.25).unwrap(), cloud())
            .budget(ITERS, 16)
            .seed(1)
            .sfb(false)
            .workers(workers);
        let outcome = planner.plan(&request).expect("plan");
        let tl = &outcome.plan.telemetry;
        let per: Vec<usize> = (0..workers)
            .map(|w| tl.metric(&format!("worker{w}_iterations")).unwrap_or(0.0) as usize)
            .collect();
        println!(
            "    workers={workers}: search {}  speedup {:.2}x  hit_rate {:.2}  per-worker {:?}",
            fmt_secs(outcome.overhead_s),
            outcome.plan.times.speedup,
            tl.metric("memo_hit_rate").unwrap_or(0.0),
            per,
        );
    }
}
