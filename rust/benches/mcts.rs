//! Bench: MCTS search throughput (iterations/second) with uniform
//! priors — the L3 search loop that Fig. 8's TAG bar is built from —
//! plus the effect of the `dist` transposition table on that loop
//! (cold = fresh memo per search, warm = memo shared across searches,
//! the steady state of self-play / repeated coordinator sessions).

use tag::cluster::presets::testbed;
use tag::dist::Lowering;
use tag::graph::grouping::group_ops;
use tag::mcts::{Mcts, UniformPrior};
use tag::models;
use tag::profile::{unique_gpus, CommModel, CostModel};
use tag::strategy::enumerate_actions;
use tag::util::bench;

fn main() {
    let topo = testbed();
    println!("== MCTS: 50-iteration searches (uniform priors) ==");
    for name in ["VGG19", "InceptionV3", "BERT-Small"] {
        let model = models::by_name(name, 0.25).unwrap();
        let cost = CostModel::profile(&model.ops, &unique_gpus(&topo), 0.0, 1);
        for groups in [12, 24, 48] {
            let gg = group_ops(&model, &cost, groups, 7);
            let comm = CommModel::fit(3);
            let low = Lowering::new(&gg, &topo, &cost, &comm);
            let actions = enumerate_actions(&topo);
            let m = bench(&format!("search50[{name}/g{groups}]"), 1.5, || {
                let mut mcts = Mcts::new(&low, actions.clone(), UniformPrior, 1);
                let r = mcts.search(50);
                assert!(r.best_time > 0.0);
            });
            println!("    -> {:.0} iterations/s", 50.0 / m);
        }
    }

    println!("\n== MCTS: memoized vs cold repeated searches ==");
    {
        let model = models::by_name("VGG19", 0.25).unwrap();
        let cost = CostModel::profile(&model.ops, &unique_gpus(&topo), 0.0, 1);
        let gg = group_ops(&model, &cost, 24, 7);
        let comm = CommModel::fit(3);
        let low = Lowering::new(&gg, &topo, &cost, &comm);
        let actions = enumerate_actions(&topo);
        // Cold: drop the transposition table before every search, so each
        // of the 50 iterations re-lowers and re-simulates.
        let cold = bench("search50[cold memo]", 1.5, || {
            low.clear_memo();
            let mut mcts = Mcts::new(&low, actions.clone(), UniformPrior, 1);
            assert!(mcts.search(50).best_time > 0.0);
        });
        // Warm: the table persists across searches — every evaluation of a
        // previously-seen effective strategy is a cache hit.
        low.clear_memo();
        let warm = bench("search50[warm memo]", 1.5, || {
            let mut mcts = Mcts::new(&low, actions.clone(), UniformPrior, 1);
            assert!(mcts.search(50).best_time > 0.0);
        });
        let (hits, misses) = low.memo_stats();
        println!(
            "    -> warm search speed-up: {:.1}x ({hits} hits / {misses} misses across runs)",
            cold / warm
        );
    }
}
