//! Bench: MCTS search throughput (iterations/second) with uniform
//! priors — the L3 search loop that Fig. 8's TAG bar is built from —
//! plus the effect of the `dist` transposition table on that loop
//! (cold = fresh memo per search, warm = memo shared across searches,
//! the steady state of self-play / repeated coordinator sessions).

use tag::cluster::presets::testbed;
use tag::dist::Lowering;
use tag::graph::grouping::group_ops;
use tag::mcts::{Mcts, UniformPrior};
use tag::models;
use tag::profile::{unique_gpus, CommModel, CostModel};
use tag::strategy::{enumerate_actions, Strategy};
use tag::util::{bench, Rng};

fn main() {
    let topo = testbed();
    println!("== MCTS: 50-iteration searches (uniform priors) ==");
    for name in ["VGG19", "InceptionV3", "BERT-Small"] {
        let model = models::by_name(name, 0.25).unwrap();
        let cost = CostModel::profile(&model.ops, &unique_gpus(&topo), 0.0, 1);
        for groups in [12, 24, 48] {
            let gg = group_ops(&model, &cost, groups, 7);
            let comm = CommModel::fit(3);
            let low = Lowering::new(&gg, &topo, &cost, &comm);
            let actions = enumerate_actions(&topo);
            let m = bench(&format!("search50[{name}/g{groups}]"), 1.5, || {
                let mut mcts = Mcts::new(&low, actions.clone(), UniformPrior, 1);
                let r = mcts.search(50);
                assert!(r.best_time > 0.0);
            });
            println!("    -> {:.0} iterations/s", 50.0 / m);
        }
    }

    println!("\n== MCTS: memoized vs cold repeated searches ==");
    {
        let model = models::by_name("VGG19", 0.25).unwrap();
        let cost = CostModel::profile(&model.ops, &unique_gpus(&topo), 0.0, 1);
        let gg = group_ops(&model, &cost, 24, 7);
        let comm = CommModel::fit(3);
        let low = Lowering::new(&gg, &topo, &cost, &comm);
        let actions = enumerate_actions(&topo);
        // Cold: drop the transposition table before every search, so each
        // of the 50 iterations re-lowers and re-simulates.
        let cold = bench("search50[cold memo]", 1.5, || {
            low.clear_memo();
            let mut mcts = Mcts::new(&low, actions.clone(), UniformPrior, 1);
            assert!(mcts.search(50).best_time > 0.0);
        });
        // Warm: the table persists across searches — every evaluation of a
        // previously-seen effective strategy is a cache hit.
        low.clear_memo();
        let warm = bench("search50[warm memo]", 1.5, || {
            let mut mcts = Mcts::new(&low, actions.clone(), UniformPrior, 1);
            assert!(mcts.search(50).best_time > 0.0);
        });
        let (hits, misses) = low.memo_stats();
        println!(
            "    -> warm search speed-up: {:.1}x ({hits} hits / {misses} misses across runs)",
            cold / warm
        );
    }

    println!("\n== delta evaluation: 1-flip walk, incremental vs full ==");
    {
        // The dominant evaluation pattern of MCTS expansion: each child
        // strategy differs from its parent in one group.  Walk a seeded
        // 1-flip chain with delta on (fragment store + frontier-restart
        // simulation) and off (full lower-and-simulate every step), and
        // verify the two arms produce bit-identical results.
        const STEPS: usize = 64;
        let model = models::by_name("VGG19", 0.25).unwrap();
        let cost = CostModel::profile(&model.ops, &unique_gpus(&topo), 0.0, 1);
        let gg = group_ops(&model, &cost, 24, 7);
        let comm = CommModel::fit(3);
        let actions = enumerate_actions(&topo);
        let ng = gg.num_groups();
        let walk = |low: &Lowering| -> f64 {
            let mut rng = Rng::new(41);
            let mut s = Strategy::dp_allreduce(ng, &topo);
            let mut acc = 0.0;
            for _ in 0..STEPS {
                s.slots[rng.below(ng)] = Some(*rng.choose(&actions));
                acc += low.evaluate(&s).time;
            }
            acc
        };
        let low_on = Lowering::new(&gg, &topo, &cost, &comm);
        let low_off = Lowering::new(&gg, &topo, &cost, &comm);
        low_off.set_delta(false);
        let sum_on = walk(&low_on);
        let sum_off = walk(&low_off);
        assert_eq!(
            sum_on.to_bits(),
            sum_off.to_bits(),
            "delta walk diverged from the full walk"
        );
        // Clear the memo each run so every step re-evaluates: the off
        // arm pays full lowering+simulation, the on arm its delta path.
        let m_on = bench("evalwalk[delta on]", 1.5, || {
            low_on.clear_memo();
            assert!(walk(&low_on) > 0.0);
        });
        println!("    -> {:.0} evals/s", STEPS as f64 / m_on);
        let m_off = bench("evalwalk[delta off]", 1.5, || {
            low_off.clear_memo();
            assert!(walk(&low_off) > 0.0);
        });
        println!("    -> {:.0} evals/s", STEPS as f64 / m_off);
        let stats = low_on.delta_stats();
        println!(
            "    -> delta speed-up: {:.1}x (delta_hit_rate {:.3}, frontier_restart_frac {:.3}, fragment_hit_rate {:.3})",
            m_off / m_on,
            stats.delta_hit_rate(),
            stats.frontier_restart_frac(),
            low_on.fragment_hit_rate(),
        );
        let json = format!(
            "{{\n  \"bench\": \"delta_flip_walk\",\n  \"model\": \"VGG19\",\n  \"groups\": 24,\n  \"steps\": {STEPS},\n  \"evals_per_s_on\": {:.1},\n  \"evals_per_s_off\": {:.1},\n  \"speedup\": {:.3},\n  \"delta_hit_rate\": {:.4},\n  \"frontier_restart_frac\": {:.4},\n  \"fragment_hit_rate\": {:.4},\n  \"checksum_bits_equal\": true\n}}\n",
            STEPS as f64 / m_on,
            STEPS as f64 / m_off,
            m_off / m_on,
            stats.delta_hit_rate(),
            stats.frontier_restart_frac(),
            low_on.fragment_hit_rate(),
        );
        if let Err(e) = std::fs::write("BENCH_delta.json", &json) {
            eprintln!("    (could not write BENCH_delta.json: {e})");
        } else {
            println!("    wrote BENCH_delta.json");
        }
    }
}
