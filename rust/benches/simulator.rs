//! Bench: the discrete-event simulator — the inner loop of every MCTS
//! evaluation, so its latency bounds search throughput (Fig. 8 / §Perf).
//!
//! Measures strategy evaluation (lower + simulate + feedback) per model
//! on the testbed, plus the raw engine on a synthetic task soup.

use tag::cluster::presets::testbed;
use tag::dist::Lowering;
use tag::graph::grouping::group_ops;
use tag::models;
use tag::profile::{unique_gpus, CommModel, CostModel};
use tag::sim::{simulate, Task, TaskGraph, TaskKind};
use tag::strategy::{enumerate_actions, Strategy};
use tag::util::{bench, Rng};

fn main() {
    let topo = testbed();
    println!("== simulator: full strategy evaluation (group-level) ==");
    for name in models::MODEL_NAMES {
        let model = models::by_name(name, 0.25).unwrap();
        let cost = CostModel::profile(&model.ops, &unique_gpus(&topo), 0.0, 1);
        let gg = group_ops(&model, &cost, 32, 7);
        let comm = CommModel::fit(3);
        let low = Lowering::new(&gg, &topo, &cost, &comm);
        let dp = Strategy::dp_allreduce(gg.num_groups(), &topo);
        bench(&format!("evaluate[{name}]"), 1.0, || {
            let out = low.evaluate_uncached(&dp);
            assert!(out.time > 0.0);
        });
    }

    // The dist memo layer: a repeated-strategy workload (what MCTS
    // produces — the same effective deployments evaluated over and over)
    // through the uncached path vs the transposition table.
    println!("\n== dist memo: cold vs warm evaluate (repeated strategies) ==");
    {
        let model = models::by_name("VGG19", 0.25).unwrap();
        let cost = CostModel::profile(&model.ops, &unique_gpus(&topo), 0.0, 1);
        let gg = group_ops(&model, &cost, 32, 7);
        let comm = CommModel::fit(3);
        let low = Lowering::new(&gg, &topo, &cost, &comm);
        let strategies: Vec<Strategy> = enumerate_actions(&topo)
            .into_iter()
            .map(|a| Strategy::uniform(gg.num_groups(), a))
            .collect();
        let n = strategies.len();
        let cold = bench(&format!("evaluate[cold x{n} strategies]"), 1.0, || {
            for s in &strategies {
                assert!(low.evaluate_uncached(s).time > 0.0);
            }
        });
        // Warm-up fill, then measure pure cache-hit evaluation.
        for s in &strategies {
            let _ = low.evaluate(s);
        }
        let warm = bench(&format!("evaluate[warm x{n} strategies]"), 1.0, || {
            for s in &strategies {
                assert!(low.evaluate(s).time > 0.0);
            }
        });
        let (hits, misses) = low.memo_stats();
        println!(
            "    -> memo speed-up: {:.1}x (cold {:.1} us vs warm {:.1} us per evaluate; \
             {hits} hits / {misses} misses)",
            cold / warm,
            cold / n as f64 * 1e6,
            warm / n as f64 * 1e6,
        );
    }

    println!("\n== raw engine: synthetic task graphs ==");
    for (n_tasks, n_res) in [(1_000, 16), (10_000, 32), (50_000, 64)] {
        let mut rng = Rng::new(5);
        let mut tg = TaskGraph::new(n_res);
        for i in 0..n_tasks {
            let deps: Vec<usize> = (0..2)
                .filter_map(|_| if i > 0 { Some(rng.below(i)) } else { None })
                .collect();
            tg.push(Task {
                resource: rng.below(n_res),
                duration: rng.uniform(1e-5, 1e-3),
                deps,
                kind: TaskKind::Marker,
                load: None,
            });
        }
        let m = bench(&format!("engine[{n_tasks} tasks/{n_res} res]"), 1.0, || {
            let s = simulate(&tg);
            assert!(s.makespan > 0.0);
        });
        println!("    -> {:.1}k simulated tasks/s", n_tasks as f64 / m / 1e3);
    }
}
