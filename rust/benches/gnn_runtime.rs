//! Bench: the PJRT-compiled GNN — inference latency at batch 1 vs the
//! full batch-8 artifact (the batching ablation behind the coordinator's
//! batched leaf-evaluation service), and the Adam train-step latency.
//!
//! Requires `make artifacts`.

use tag::cluster::presets::testbed;
use tag::dist::Lowering;
use tag::gnn::features::{FeatureBuilder, B_INFER, B_TRAIN, N_CAND};
use tag::gnn::{params, GnnService};
use tag::graph::grouping::group_ops;
use tag::models;
use tag::profile::{unique_gpus, CommModel, CostModel};
use tag::strategy::{enumerate_actions, Strategy};
use tag::util::bench;

fn main() {
    let Ok(svc) = GnnService::load("artifacts") else {
        println!("artifacts missing — run `make artifacts` first");
        return;
    };
    let p = params::load_params("artifacts/params_init.bin").unwrap();

    // A realistic position.
    let topo = testbed();
    let model = models::vgg19(8, 0.25);
    let cost = CostModel::profile(&model.ops, &unique_gpus(&topo), 0.0, 1);
    let gg = group_ops(&model, &cost, 24, 7);
    let comm = CommModel::fit(3);
    let low = Lowering::new(&gg, &topo, &cost, &comm);
    let actions = enumerate_actions(&topo);
    let fb = FeatureBuilder::new(&gg, &topo, &actions);
    let s = Strategy::empty(gg.num_groups());
    let out = low.evaluate(&s);
    let pos = fb.build(&s, &out, low.order[0]);

    println!("== GNN inference (PJRT CPU, AOT artifact, Pallas GAT kernel) ==");
    let t1 = bench("infer[batch 1 of 8 slots]", 2.0, || {
        let r = svc.infer_batch(&p, &[&pos]).unwrap();
        assert_eq!(r[0].len(), N_CAND);
    });
    let refs: Vec<&_> = (0..B_INFER).map(|_| &pos).collect();
    let t8 = bench("infer[batch 8 of 8 slots]", 2.0, || {
        let r = svc.infer_batch(&p, &refs).unwrap();
        assert_eq!(r.len(), B_INFER);
    });
    println!(
        "    -> per-position cost: {:.2} ms solo vs {:.2} ms batched ({:.1}x batching win)",
        t1 * 1e3,
        t8 * 1e3 / B_INFER as f64,
        t1 / (t8 / B_INFER as f64)
    );

    println!("\n== feature building (L3 side) ==");
    bench("feature_build", 1.0, || {
        let q = fb.build(&s, &out, low.order[0]);
        assert!(q.op_mask[0] > 0.0);
    });

    println!("\n== train step (Adam over B_TRAIN examples) ==");
    let zeros = vec![0.0f32; p.len()];
    let mut pi = vec![0.0f32; N_CAND];
    pi[0] = 1.0;
    let positions: Vec<&_> = (0..B_TRAIN).map(|_| &pos).collect();
    let pis: Vec<Vec<f32>> = (0..B_TRAIN).map(|_| pi.clone()).collect();
    let mask = vec![1.0f32; B_TRAIN];
    let tt = bench("train_step[batch 16]", 2.0, || {
        let (p2, _, _, loss) = svc
            .train_step(&p, &zeros, &zeros, 0.0, &positions, &pis, &mask)
            .unwrap();
        assert!(loss.is_finite());
        assert_eq!(p2.len(), p.len());
    });
    println!("    -> {:.2} ms per example", tt * 1e3 / B_TRAIN as f64);
}
