//! Bench: end-to-end strategy search wall time per model — the quantity
//! behind Fig. 8's "TAG" bar (prepare + MCTS + SFB on a fresh topology)
//! and the top-level number a user experiences.

use tag::cluster::presets::testbed;
use tag::coordinator::{prepare, search_session, SearchConfig};
use tag::models;
use tag::util::bench;

fn main() {
    let topo = testbed();
    println!("== end-to-end: prepare + 100-iteration search + SFB ==");
    for name in models::MODEL_NAMES {
        let cfg = SearchConfig {
            max_groups: 24,
            mcts_iterations: 100,
            seed: 1,
            apply_sfb: true,
            profile_noise: 0.0,
            parallelism: Default::default(),
            deadline_ms: None,
            delta: true,
        };
        // Prepare once (profiling + grouping), bench the search.
        let model = models::by_name(name, 0.25).unwrap();
        let prep = prepare(model, &topo, &cfg);
        bench(&format!("search100[{name}]"), 2.0, || {
            let res = search_session(&prep, &topo, None, &cfg);
            assert!(res.speedup > 0.5);
        });
    }

    println!("\n== preprocessing (profile + METIS grouping), paper-size ==");
    for name in ["InceptionV3", "BERT-Large"] {
        let cfg = SearchConfig::default();
        bench(&format!("prepare[{name} @ scale 1.0]"), 2.0, || {
            let model = models::by_name(name, 1.0).unwrap();
            let prep = prepare(model, &topo, &cfg);
            assert!(prep.gg.num_groups() <= 60);
        });
    }
}
