//! Bench: warm plan repair vs cold re-plan on a degraded topology — the
//! recovery-latency claim behind `tag repair`.  The warm path transplants
//! the surviving strategy and spends a quarter of the iteration budget;
//! the cold path re-plans the residual cluster from scratch with the
//! full budget.  Both must land a valid plan; the point is how much
//! cheaper recovery is when the survivors seed the search.

use tag::api::{PlanRequest, Planner};
use tag::cluster::presets::{multi_rack, testbed};
use tag::cluster::{generate_trace, Topology};
use tag::models;
use tag::util::bench;

fn compare(topo: &Topology, iters: usize) {
    let model = models::by_name("VGG19", 0.25).unwrap();
    let request = PlanRequest::new(model, topo.clone()).budget(iters, 12).seed(7);
    let planner = Planner::builder().without_cache().build();
    let prior = planner.plan(&request).expect("prior plan").plan;

    // One seeded fault spec per topology, drawn deterministically.
    let faults = generate_trace(topo, 11, 1).pop().expect("one spec");
    let residual = faults.apply(topo).expect("spec applies");
    let mut cold_request = request.clone();
    cold_request.topology = residual.topology;

    let warm = bench(&format!("repair-warm[{} {}]", topo.name, faults.encode()), 2.0, || {
        let out = planner.repair(&request, &prior, &faults).expect("repair");
        assert!(out.plan.times.speedup >= 1.0 - 1e-9);
    });
    let cold = bench(&format!("replan-cold[{}]", topo.name), 2.0, || {
        let out = planner.plan(&cold_request).expect("cold plan");
        assert!(out.plan.times.speedup >= 1.0 - 1e-9);
    });
    println!(
        "  -> repair recovers {:.2}x faster than a cold re-plan\n",
        cold / warm.max(1e-12)
    );
}

fn main() {
    println!("== plan repair vs cold re-plan (150-iteration budget) ==");
    for topo in [testbed(), multi_rack()] {
        compare(&topo, 150);
    }
}
