//! Bench: `tag serve` loopback throughput — the full network path
//! (TCP connect → HTTP parse → route → plan → respond) in three
//! serving regimes:
//!
//! * **cold cache** — every request a fresh seed: pays a full search,
//!   the daemon's worst case;
//! * **warm cache** — one request repeated: fingerprint-keyed
//!   [`PlanCache`](tag::api::PlanCache) hit, the steady state of
//!   repeat traffic (serving overhead ≈ transport + JSON encode);
//! * **coalesced burst** — 8 concurrent identical requests on a fresh
//!   seed: the singleflight rides them all on ONE search, so the
//!   per-request cost approaches (search / 8) + transport.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use tag::api::SharedPlanner;
use tag::serve::{ServeConfig, Server};
use tag::util::bench;

fn request_for(seed: u64) -> String {
    format!(r#"{{"model":"VGG19","iterations":30,"max_groups":10,"seed":{seed}}}"#)
}

fn post_plan(addr: SocketAddr, body: &str) -> u16 {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let raw = format!(
        "POST /plan HTTP/1.1\r\ncontent-length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(raw.as_bytes()).expect("send");
    let mut response = String::new();
    stream.read_to_string(&mut response).expect("recv");
    response
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .expect("status line")
}

fn main() {
    let config = ServeConfig {
        port: 0,
        workers: 8,
        queue_depth: 64,
        read_timeout: Duration::from_secs(120),
        ..ServeConfig::default()
    };
    let server = Server::bind(config, SharedPlanner::builder().build()).expect("bind");
    let addr = server.local_addr();
    let daemon = std::thread::spawn(move || server.run().expect("serve"));
    println!("== tag serve loopback throughput (VGG19/0.25, 30 iters) ==");

    let mut seed = 1_000u64;
    let cold = bench("serve[cold cache, fresh seed]", 2.0, || {
        seed += 1;
        assert_eq!(post_plan(addr, &request_for(seed)), 200);
    });

    let warm_body = request_for(1);
    assert_eq!(post_plan(addr, &warm_body), 200); // populate the cache
    let warm = bench("serve[warm cache, repeated request]", 1.0, || {
        assert_eq!(post_plan(addr, &warm_body), 200);
    });

    const BURST: usize = 8;
    let mut burst_seed = 2_000_000u64;
    let burst = bench("serve[coalesced 8-client burst, fresh seed]", 2.0, || {
        burst_seed += 1;
        let body = request_for(burst_seed);
        let clients: Vec<_> = (0..BURST)
            .map(|_| {
                let body = body.clone();
                std::thread::spawn(move || assert_eq!(post_plan(addr, &body), 200))
            })
            .collect();
        for client in clients {
            client.join().unwrap();
        }
    });

    println!("\n    cold search        {:>10.2} ms/request", cold * 1e3);
    println!("    warm cache         {:>10.2} ms/request", warm * 1e3);
    println!(
        "    coalesced burst    {:>10.2} ms/request ({BURST} clients, one search)",
        burst * 1e3 / BURST as f64
    );
    println!(
        "    cache speed-up {:.0}x, coalescing amortization {:.1}x",
        cold / warm.max(1e-9),
        cold / (burst / BURST as f64).max(1e-9)
    );

    // Clean shutdown so the bench process exits without leaking the
    // daemon thread.
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.write_all(b"POST /shutdown HTTP/1.1\r\n\r\n").unwrap();
    let mut out = String::new();
    let _ = stream.read_to_string(&mut out);
    daemon.join().unwrap();
}
