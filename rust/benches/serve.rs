//! Bench: `tag serve` loopback throughput — the full network path
//! (TCP connect → HTTP parse → route → plan → respond) in the daemon's
//! serving regimes:
//!
//! * **cold cache** — every request a fresh seed: pays a full search,
//!   the daemon's worst case;
//! * **warm cache** — one request repeated: fingerprint-keyed
//!   [`PlanCache`](tag::api::PlanCache) hit, the steady state of
//!   repeat traffic (serving overhead ≈ transport + JSON encode);
//! * **coalesced burst** — 8 concurrent identical requests on a fresh
//!   seed: the singleflight rides them all on ONE search, so the
//!   per-request cost approaches (search / 8) + transport;
//! * **saturation curve** — C concurrent clients hammering the warm
//!   cache, keep-alive (one persistent connection per client) vs the
//!   pre-keep-alive baseline (one connection per request): what
//!   connection reuse plus parallel accept buys at each concurrency;
//! * **boot latency** — time-to-first-plan for a fresh daemon (full
//!   search) vs one warm-booted from a populated plan store (pure
//!   cache hit).
//!
//! Results land in `BENCH_serve.json`.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use tag::api::SharedPlanner;
use tag::serve::{ServeConfig, Server};
use tag::util::{bench, Stopwatch};

fn request_for(seed: u64) -> String {
    format!(r#"{{"model":"VGG19","iterations":30,"max_groups":10,"seed":{seed}}}"#)
}

/// One-shot client: `Connection: close`, read to EOF.  This is exactly
/// the pre-keep-alive serving contract, so it doubles as the baseline
/// arm of the saturation curve.
fn post_plan(addr: SocketAddr, body: &str) -> u16 {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let raw = format!(
        "POST /plan HTTP/1.1\r\nconnection: close\r\ncontent-length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(raw.as_bytes()).expect("send");
    let mut response = String::new();
    stream.read_to_string(&mut response).expect("recv");
    response
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .expect("status line")
}

/// Persistent client: `requests` sequential round-trips on ONE
/// connection, each response consumed by its Content-Length framing.
fn post_plan_keep_alive(addr: SocketAddr, body: &str, requests: usize) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let raw = format!(
        "POST /plan HTTP/1.1\r\ncontent-length: {}\r\n\r\n{body}",
        body.len()
    );
    for _ in 0..requests {
        stream.write_all(raw.as_bytes()).expect("send");
        let mut head = String::new();
        let mut len = 0usize;
        loop {
            let mut line = String::new();
            assert!(reader.read_line(&mut line).expect("read") > 0, "early EOF");
            if line == "\r\n" {
                break;
            }
            if let Some(v) = line.to_ascii_lowercase().strip_prefix("content-length:") {
                len = v.trim().parse().expect("length");
            }
            head.push_str(&line);
        }
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        let mut body = vec![0u8; len];
        reader.read_exact(&mut body).expect("body");
    }
}

/// One saturation cell: C clients × R warm-cache requests each.
/// Returns aggregate requests/s.
fn saturation_cell(addr: SocketAddr, clients: usize, per_client: usize, keep_alive: bool) -> f64 {
    let body = request_for(1);
    let watch = Stopwatch::start();
    let threads: Vec<_> = (0..clients)
        .map(|_| {
            let body = body.clone();
            std::thread::spawn(move || {
                if keep_alive {
                    post_plan_keep_alive(addr, &body, per_client);
                } else {
                    for _ in 0..per_client {
                        assert_eq!(post_plan(addr, &body), 200);
                    }
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    (clients * per_client) as f64 / watch.elapsed_s()
}

fn start_daemon(config: ServeConfig) -> (SocketAddr, std::thread::JoinHandle<()>) {
    let server = Server::bind(config, SharedPlanner::builder().build()).expect("bind");
    let addr = server.local_addr();
    (addr, std::thread::spawn(move || server.run().expect("serve")))
}

fn stop_daemon(addr: SocketAddr, daemon: std::thread::JoinHandle<()>) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.write_all(b"POST /shutdown HTTP/1.1\r\nconnection: close\r\n\r\n").unwrap();
    let mut out = String::new();
    let _ = stream.read_to_string(&mut out);
    daemon.join().unwrap();
}

fn main() {
    let config = ServeConfig {
        port: 0,
        workers: 8,
        queue_depth: 64,
        read_timeout: Duration::from_secs(120),
        ..ServeConfig::default()
    };
    let (addr, daemon) = start_daemon(config.clone());
    println!("== tag serve loopback throughput (VGG19/0.25, 30 iters) ==");

    let mut seed = 1_000u64;
    let cold = bench("serve[cold cache, fresh seed]", 2.0, || {
        seed += 1;
        assert_eq!(post_plan(addr, &request_for(seed)), 200);
    });

    let warm_body = request_for(1);
    assert_eq!(post_plan(addr, &warm_body), 200); // populate the cache
    let warm = bench("serve[warm cache, repeated request]", 1.0, || {
        assert_eq!(post_plan(addr, &warm_body), 200);
    });

    const BURST: usize = 8;
    let mut burst_seed = 2_000_000u64;
    let burst = bench("serve[coalesced 8-client burst, fresh seed]", 2.0, || {
        burst_seed += 1;
        let body = request_for(burst_seed);
        let clients: Vec<_> = (0..BURST)
            .map(|_| {
                let body = body.clone();
                std::thread::spawn(move || assert_eq!(post_plan(addr, &body), 200))
            })
            .collect();
        for client in clients {
            client.join().unwrap();
        }
    });

    println!("\n    cold search        {:>10.2} ms/request", cold * 1e3);
    println!("    warm cache         {:>10.2} ms/request", warm * 1e3);
    println!(
        "    coalesced burst    {:>10.2} ms/request ({BURST} clients, one search)",
        burst * 1e3 / BURST as f64
    );
    println!(
        "    cache speed-up {:.0}x, coalescing amortization {:.1}x",
        cold / warm.max(1e-9),
        cold / (burst / BURST as f64).max(1e-9)
    );

    // ------------------------------------------------- saturation curve
    // Warm-cache traffic (search cost off the table) so the curve
    // isolates the serving path: connection setup, parse, route,
    // encode.  The close arm is the pre-keep-alive daemon's contract
    // at the same worker count.
    const PER_CLIENT: usize = 100;
    println!(
        "\n== saturation: {} workers, {} acceptors, {} warm requests/client ==",
        config.workers, config.accept_threads, PER_CLIENT
    );
    println!("    {:>8} {:>16} {:>16} {:>8}", "clients", "close req/s", "keep-alive req/s", "gain");
    let mut curve = Vec::new();
    for clients in [1usize, 2, 4, 8, 16] {
        let rps_close = saturation_cell(addr, clients, PER_CLIENT, false);
        let rps_keep = saturation_cell(addr, clients, PER_CLIENT, true);
        println!(
            "    {clients:>8} {rps_close:>16.0} {rps_keep:>16.0} {:>7.2}x",
            rps_keep / rps_close.max(1e-9)
        );
        curve.push((clients, rps_close, rps_keep));
    }
    stop_daemon(addr, daemon);

    // ------------------------------------------------- boot latency
    // Populate a plan store, then compare time-to-first-plan for a
    // cold daemon (no store: full search) against a warm-booted one
    // (journal replayed into the cache at bind).
    let store_dir = std::env::temp_dir().join(format!("tag-bench-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store_dir);
    let store_config = ServeConfig {
        store_dir: Some(store_dir.to_string_lossy().to_string()),
        ..config.clone()
    };
    let (addr, daemon) = start_daemon(store_config.clone());
    for seed in 1..=3u64 {
        assert_eq!(post_plan(addr, &request_for(seed)), 200);
    }
    stop_daemon(addr, daemon);

    let watch = Stopwatch::start();
    let (addr, daemon) = start_daemon(config.clone());
    assert_eq!(post_plan(addr, &request_for(1)), 200);
    let cold_boot = watch.elapsed_s();
    stop_daemon(addr, daemon);

    let watch = Stopwatch::start();
    let (addr, daemon) = start_daemon(store_config);
    assert_eq!(post_plan(addr, &request_for(1)), 200);
    let warm_boot = watch.elapsed_s();
    stop_daemon(addr, daemon);
    let _ = std::fs::remove_dir_all(&store_dir);

    println!("\n== boot-to-first-plan ==");
    println!("    cold boot (no store)   {:>10.2} ms", cold_boot * 1e3);
    println!("    warm boot (plan store) {:>10.2} ms", warm_boot * 1e3);
    println!("    warm-boot speed-up {:.1}x", cold_boot / warm_boot.max(1e-9));

    let curve_json: Vec<String> = curve
        .iter()
        .map(|(clients, close, keep)| {
            format!(
                "    {{\"clients\": {clients}, \"close_rps\": {close:.1}, \
                 \"keep_alive_rps\": {keep:.1}}}"
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"serve_loopback\",\n  \"model\": \"VGG19\",\n  \"workers\": {},\n  \"accept_threads\": {},\n  \"per_client_requests\": {PER_CLIENT},\n  \"cold_ms_per_request\": {:.3},\n  \"warm_ms_per_request\": {:.3},\n  \"coalesced_ms_per_request\": {:.3},\n  \"saturation\": [\n{}\n  ],\n  \"cold_boot_first_plan_ms\": {:.3},\n  \"warm_boot_first_plan_ms\": {:.3}\n}}\n",
        config.workers,
        config.accept_threads,
        cold * 1e3,
        warm * 1e3,
        burst * 1e3 / BURST as f64,
        curve_json.join(",\n"),
        cold_boot * 1e3,
        warm_boot * 1e3,
    );
    if let Err(e) = std::fs::write("BENCH_serve.json", &json) {
        eprintln!("    (could not write BENCH_serve.json: {e})");
    } else {
        println!("    wrote BENCH_serve.json");
    }
}
