//! Integration tests for the fleet scheduler: lease/release exactness
//! across presets, non-overlap of concurrent leases, byte-deterministic
//! replay, the headline FIFO vs best-fit comparison on an
//! oversubscribed cluster, and the live `/fleet/*` endpoints over real
//! TCP.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use tag::api::{fingerprint, SharedPlanner};
use tag::cluster::presets::{cloud, multi_rack, nvlink_island, testbed};
use tag::cluster::{DeviceId, Topology};
use tag::fleet::{
    best_fit_devices, generate_jobs, replay, ClusterState, FleetConfig, JobSpec, Lease, Policy,
};
use tag::serve::{ServeConfig, Server};
use tag::util::Rng;

/// Seeded lease/release churn on one topology: random best-fit leases
/// and random releases, with exactness and exclusivity invariants
/// checked at every step.  Afterwards the cluster must be
/// indistinguishable from a fresh one — the same canonical lease
/// materializes a fingerprint-identical slice on both.
fn churn(base: Topology, seed: u64) {
    let base_print = fingerprint::topology(&base);
    let total = base.num_devices();
    let mut state = ClusterState::new(base.clone()).unwrap();
    let mut rng = Rng::new(seed);
    let mut held: Vec<Lease> = Vec::new();
    for _ in 0..60 {
        if rng.chance(0.55) {
            let want = rng.range(1, (total / 3).max(1));
            if let Some(devices) = best_fit_devices(&state, want) {
                assert_eq!(devices.len(), want);
                let lease = state.lease(&devices).unwrap();
                lease.topology.validate().unwrap();
                assert_eq!(lease.topology.num_devices(), want);
                held.push(lease);
            }
        } else if !held.is_empty() {
            let i = rng.below(held.len());
            let gone = held.swap_remove(i);
            let returned = state.release(gone.id).unwrap();
            assert_eq!(returned, gone.devices);
        }
        // Exclusivity: active leases partition the leased set.
        let mut seen = vec![false; total];
        for lease in &held {
            for &d in &lease.devices {
                let flat = state.base().device_flat_index(d);
                assert!(!seen[flat], "lease overlap at ({}, {})", d.group, d.idx);
                seen[flat] = true;
            }
        }
        let marked = seen.iter().filter(|&&s| s).count();
        assert_eq!(marked, state.leased_devices(), "ledger agrees with leases");
        assert_eq!(state.free_devices() + state.leased_devices(), total);
    }
    for lease in held.drain(..) {
        state.release(lease.id).unwrap();
    }
    assert_eq!((state.active_leases(), state.free_devices()), (0, total));
    assert_eq!(
        fingerprint::topology(&state.free_view().unwrap().topology),
        base_print,
        "drained cluster is the base, bit for bit"
    );
    // Stronger than the free view: the churned state and a fresh state
    // materialize the same slice for the same grant.
    let probe: Vec<DeviceId> = base.devices().into_iter().take((total / 2).max(1)).collect();
    let churned = state.lease(&probe).unwrap();
    let fresh = ClusterState::new(base).unwrap().lease(&probe).unwrap();
    assert_eq!(
        fingerprint::topology(&churned.topology),
        fingerprint::topology(&fresh.topology),
        "churn leaves no residue in materialized slices"
    );
}

#[test]
fn lease_release_restores_every_preset_exactly() {
    for (i, base) in [testbed(), cloud(), nvlink_island(), multi_rack()].into_iter().enumerate() {
        churn(base, 0xF1EE7 + i as u64);
    }
}

#[test]
fn concurrent_best_fit_leases_never_overlap() {
    let mut state = ClusterState::new(multi_rack()).unwrap();
    let mut held = Vec::new();
    // Grab 4-GPU slices until the cluster is saturated.
    while let Some(devices) = best_fit_devices(&state, 4) {
        held.push(state.lease(&devices).unwrap());
    }
    assert_eq!(held.len(), 8, "32 devices / 4 per lease");
    assert_eq!(state.free_devices(), 0);
    let mut seen = std::collections::HashSet::new();
    for lease in &held {
        for &d in &lease.devices {
            assert!(seen.insert((d.group, d.idx)), "duplicate grant ({}, {})", d.group, d.idx);
        }
    }
    assert_eq!(seen.len(), 32);
}

fn quick_config(policy: Policy) -> FleetConfig {
    FleetConfig { policy, iterations: 8, max_groups: 10, ..FleetConfig::default() }
}

#[test]
fn replay_is_byte_deterministic_for_a_fixed_seed() {
    let topo = multi_rack();
    let jobs = generate_jobs(&topo, 7, 6, 15.0);
    let cfg = quick_config(Policy::BestFit);
    // Two FRESH planners: determinism must come from the schedule and
    // the search, not from shared cache state.
    let a = replay(&SharedPlanner::builder().build(), &topo, &jobs, &cfg).unwrap();
    let b = replay(&SharedPlanner::builder().build(), &topo, &jobs, &cfg).unwrap();
    assert_eq!(a.render(), b.render(), "replay is reproducible byte for byte");
    assert_eq!(a.jobs.len(), 6);
    assert!(a.makespan_s > 0.0 && a.utilization > 0.0);
}

/// The acceptance scenario: an oversubscribed burst of 4-GPU jobs on
/// `multi_rack` (32 GPUs, 3.75:1 spine oversubscription).  FIFO grants
/// each job the whole cluster and serializes; best-fit packs eight
/// concurrent 4-GPU slices.
#[test]
fn residual_aware_beats_fifo_on_an_oversubscribed_multi_rack() {
    let topo = multi_rack();
    let jobs: Vec<JobSpec> = (0..8)
        .map(|id| JobSpec {
            id,
            model: "VGG19".to_string(),
            scale: 0.25,
            gpus: 4,
            steps: 200.0,
            arrival_s: id as f64,
            seed: 11,
        })
        .collect();
    let planner = SharedPlanner::builder().build();
    let fifo = replay(&planner, &topo, &jobs, &quick_config(Policy::Fifo)).unwrap();
    let best = replay(&planner, &topo, &jobs, &quick_config(Policy::BestFit)).unwrap();

    assert_eq!(fifo.jobs.len(), 8);
    assert_eq!(best.jobs.len(), 8);
    // FIFO runs one at a time; best-fit overlaps every job.
    assert!(
        best.makespan_s < fifo.makespan_s,
        "best-fit {:.3}s should beat fifo {:.3}s",
        best.makespan_s,
        fifo.makespan_s
    );
    assert!(
        best.mean_jct_s < fifo.mean_jct_s,
        "best-fit jct {:.3}s vs fifo {:.3}s",
        best.mean_jct_s,
        fifo.mean_jct_s
    );
    assert!(
        best.utilization > fifo.utilization,
        "best-fit utilization {:.3} vs fifo {:.3}",
        best.utilization,
        fifo.utilization
    );
    // FIFO plans the whole 12-group cluster; best-fit plans slices.
    assert!(fifo.jobs.iter().all(|j| j.groups == topo.num_groups()));
    assert!(best.jobs.iter().all(|j| j.groups <= 2), "4-GPU slices span at most two groups");
    // FIFO's identical whole-cluster jobs reuse one search; best-fit
    // slices live in different racks (different switch attachment), so
    // each is its own cache key.
    assert!(fifo.cache_hits >= 6, "fifo repeats hit the cache ({})", fifo.cache_hits);
    assert_eq!(best.plans, 8);
}

// ---------------------------------------------------------------- live

fn http(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, String, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut raw = format!("{method} {path} HTTP/1.1\r\nconnection: close\r\n");
    if let Some(body) = body {
        raw.push_str(&format!("content-length: {}\r\n", body.len()));
    }
    raw.push_str("\r\n");
    if let Some(body) = body {
        raw.push_str(body);
    }
    stream.write_all(raw.as_bytes()).expect("send");
    let mut response = String::new();
    stream.read_to_string(&mut response).expect("recv");
    let (head, body) = response.split_once("\r\n\r\n").expect("framed response");
    let status: u16 = head.split(' ').nth(1).and_then(|s| s.parse().ok()).expect("status");
    (status, head.to_ascii_lowercase(), body.to_string())
}

#[test]
fn fleet_endpoints_lease_plan_and_release_over_tcp() {
    let config = ServeConfig {
        port: 0,
        workers: 2,
        fleet_topology: "testbed".to_string(),
        ..ServeConfig::default()
    };
    let server = Server::bind(config, SharedPlanner::builder().build()).expect("bind");
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || server.run().expect("serve"));

    let submit = r#"{"model":"VGG19","iterations":20,"max_groups":8,"seed":1,"gpus":2}"#;
    let (status, _, body) = http(addr, "POST", "/fleet/submit", Some(submit));
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"job\":0"), "{body}");
    assert!(body.contains("\"iter_time_s\":"), "{body}");

    let (status, _, ledger) = http(addr, "GET", "/fleet/status", None);
    assert_eq!(status, 200);
    assert!(ledger.contains("\"leased\":2"), "{ledger}");
    let (status, _, metrics) = http(addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    assert!(metrics.contains("tag_fleet_devices_leased 2\n"), "{metrics}");
    assert!(metrics.contains("tag_fleet_submitted_total 1\n"), "{metrics}");

    // Demands past the free pool shed with a Retry-After hint.
    let big = r#"{"model":"VGG19","iterations":20,"max_groups":8,"gpus":16}"#;
    let (status, head, _) = http(addr, "POST", "/fleet/submit", Some(big));
    assert_eq!(status, 503);
    assert!(head.contains("retry-after:"), "{head}");

    let (status, _, body) = http(addr, "POST", "/fleet/complete", Some(r#"{"job":0}"#));
    assert_eq!(status, 200, "{body}");
    let (_, _, after) = http(addr, "GET", "/fleet/status", None);
    assert!(after.contains("\"leased\":0"), "{after}");
    assert!(after.contains("\"completed\":1"), "{after}");

    let (status, _, _) = http(addr, "POST", "/shutdown", None);
    assert_eq!(status, 200);
    handle.join().unwrap();
}
