//! Property-based tests over randomized inputs (the vendored dependency
//! set has no proptest; these use the crate's deterministic RNG with
//! many-case sweeps, shrinking manually by keeping cases tiny).
//!
//! Invariants covered:
//!  * partitioner: labels valid, balanced, deterministic, cut <= total
//!  * simulator: makespan >= critical path and >= per-resource load;
//!    monotone in added durations
//!  * SFB ILP: objective matches a brute-force enumeration on small
//!    instances; never positive
//!  * comm model: monotone in bytes, inverse-monotone in bandwidth
//!  * strategies: evaluation finite for arbitrary random strategies
//!  * dist memo: cached and cache-bypassed evaluation bit-identical
//!  * delta evaluation: the incremental (fragment-cached + frontier
//!    restart) path is bit-identical to full lower-and-simulate — time,
//!    OOM verdict and every Feedback vector — over seeded single- and
//!    multi-group flips, on flat and routed presets, sequentially and
//!    with parallel workers over one shared cache bundle
//!  * cluster generator: random flat and hierarchical topologies always
//!    validate; bandwidth symmetric; routes exist between all device
//!    pairs; a route's bottleneck never exceeds any traversed link
//!  * observability: an installed tracer never perturbs plan bytes
//!    (workers=1) or evaluation outcomes (shared-cache workers)

use tag::cluster::generator::{random_hierarchical_topology, random_topology};
use tag::cluster::presets::{multi_rack, sfb_pair, testbed};
use tag::dist::{EvalCaches, Lowering, SimOutcome, DELTA_MAX_FLIPS};
use tag::graph::grouping::group_ops;
use tag::models;
use tag::partition::{check_balance, partition, PartGraph};
use tag::profile::{unique_gpus, CommModel, CostModel};
use tag::sfb::{solve, SfbProblem};
use tag::sim::{simulate, Task, TaskGraph, TaskKind};
use tag::strategy::{enumerate_actions, Strategy};
use tag::util::Rng;

fn random_part_graph(rng: &mut Rng, n: usize) -> PartGraph {
    let mut g = PartGraph::new(n);
    for i in 0..n {
        g.node_w[i] = rng.uniform(0.1, 5.0);
    }
    let edges = n * 2;
    for _ in 0..edges {
        let a = rng.below(n);
        let b = rng.below(n);
        if a != b {
            g.add_edge(a, b, rng.uniform(0.1, 10.0));
        }
    }
    g
}

#[test]
fn prop_partitioner_valid_balanced_deterministic() {
    for case in 0..40 {
        let mut rng = Rng::new(case);
        let n = rng.range(8, 200);
        let k = rng.range(2, 8).min(n);
        let g = random_part_graph(&mut rng, n);
        let labels = partition(&g, k, 2.0, case);
        assert_eq!(labels.len(), n);
        assert!(labels.iter().all(|&l| l < k), "case {case}");
        assert!(check_balance(&g, &labels, k, 2.0), "case {case}: imbalance");
        assert_eq!(labels, partition(&g, k, 2.0, case), "case {case}: nondet");
        let total_w: f64 =
            g.adj.iter().flatten().map(|&(_, w)| w).sum::<f64>() / 2.0;
        assert!(g.cut(&labels) <= total_w + 1e-9);
    }
}

fn random_task_graph(rng: &mut Rng, n: usize, r: usize) -> TaskGraph {
    let mut tg = TaskGraph::new(r);
    for i in 0..n {
        let mut deps = Vec::new();
        if i > 0 {
            for _ in 0..rng.below(3) {
                deps.push(rng.below(i));
            }
            deps.dedup();
        }
        tg.push(Task {
            resource: rng.below(r),
            duration: rng.uniform(0.0, 1.0),
            deps,
            kind: TaskKind::Marker,
            load: None,
        });
    }
    tg
}

#[test]
fn prop_generator_topologies_route_soundly() {
    // Random flat AND hierarchical topologies: always valid, bandwidth
    // symmetric, a route between every device pair, and every route's
    // bottleneck bounded by each traversed link's bandwidth (with exact
    // min equality) and its latency equal to the links' sum.
    for case in 0..40 {
        let mut rng = Rng::new(8000 + case);
        for topo in [random_topology(&mut rng), random_hierarchical_topology(&mut rng)] {
            topo.validate().unwrap_or_else(|e| panic!("case {case} {}: {e}", topo.name));
            let devs = topo.devices();
            let links = topo.link_graph().links();
            for (i, &a) in devs.iter().enumerate() {
                for &b in &devs[i + 1..] {
                    assert_eq!(
                        topo.bw_gbps(a, b).to_bits(),
                        topo.bw_gbps(b, a).to_bits(),
                        "case {case} {}: asymmetric bandwidth",
                        topo.name
                    );
                    let route = topo.route(a, b);
                    assert!(
                        !route.links.is_empty(),
                        "case {case} {}: no route {a:?} -> {b:?}",
                        topo.name
                    );
                    let mut min_bw = f64::INFINITY;
                    let mut lat = 0.0;
                    for &lid in route.links.iter() {
                        let link = &links[lid as usize];
                        assert!(
                            route.bottleneck_gbps <= link.bw_gbps + 1e-12,
                            "case {case} {}: bottleneck exceeds a traversed link",
                            topo.name
                        );
                        min_bw = min_bw.min(link.bw_gbps);
                        lat += link.latency_s;
                    }
                    assert_eq!(
                        route.bottleneck_gbps.to_bits(),
                        min_bw.to_bits(),
                        "case {case} {}: bottleneck is not the traversed min",
                        topo.name
                    );
                    assert!(
                        (route.latency_s - lat).abs() < 1e-15,
                        "case {case} {}: latency is not the traversed sum",
                        topo.name
                    );
                }
            }
        }
    }
}

/// The pre-PR-3 engine, verbatim: wake events (`tag >= n` encodes "wake
/// resource `tag - n`") and idle-until-ready head blocking.  Kept as a
/// reference oracle for the simplified `now.max(ready)` dispatch — the
/// idle branch is unreachable because tasks are enqueued exactly at
/// their ready times, and `prop_simplified_engine_matches_wake_event_reference`
/// below proves the two engines schedule identically on the corpus.
mod wake_event_reference {
    use std::cmp::Ordering;
    use std::collections::BinaryHeap;
    use tag::sim::TaskGraph;

    #[derive(PartialEq)]
    struct Key(f64, usize);
    impl Eq for Key {}
    impl PartialOrd for Key {
        fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
            Some(self.cmp(other))
        }
    }
    impl Ord for Key {
        fn cmp(&self, other: &Self) -> Ordering {
            other
                .0
                .partial_cmp(&self.0)
                .unwrap_or(Ordering::Equal)
                .then_with(|| other.1.cmp(&self.1))
        }
    }

    pub struct RefSchedule {
        pub start: Vec<f64>,
        pub finish: Vec<f64>,
        pub busy: Vec<f64>,
        pub makespan: f64,
    }

    #[allow(clippy::too_many_arguments)]
    fn try_start(
        r: usize,
        now: f64,
        tg: &TaskGraph,
        n: usize,
        queues: &mut [BinaryHeap<Key>],
        resource_free: &mut [bool],
        start: &mut [f64],
        busy: &mut [f64],
        events: &mut BinaryHeap<Key>,
    ) {
        if !resource_free[r] {
            return;
        }
        let Some(&Key(ready, id)) = queues[r].peek() else {
            return;
        };
        if ready > now {
            events.push(Key(ready, n + r));
            return;
        }
        queues[r].pop();
        start[id] = now;
        let f = now + tg.tasks[id].duration;
        busy[r] += tg.tasks[id].duration;
        resource_free[r] = false;
        events.push(Key(f, id));
    }

    pub fn simulate(tg: &TaskGraph) -> RefSchedule {
        let n = tg.tasks.len();
        let nr = tg.num_resources;
        let mut indeg = vec![0usize; n];
        let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut ready_at = vec![0.0f64; n];
        let mut queues: Vec<BinaryHeap<Key>> = (0..nr).map(|_| BinaryHeap::new()).collect();
        let mut resource_free = vec![true; nr];
        let mut events: BinaryHeap<Key> = BinaryHeap::new();
        for (i, t) in tg.tasks.iter().enumerate() {
            indeg[i] = t.deps.len();
            for &d in &t.deps {
                succs[d].push(i);
            }
        }
        let mut start = vec![f64::NAN; n];
        let mut finish = vec![f64::NAN; n];
        let mut busy = vec![0.0; nr];
        for i in 0..n {
            if indeg[i] == 0 {
                queues[tg.tasks[i].resource].push(Key(0.0, i));
            }
        }
        for r in 0..nr {
            try_start(
                r,
                0.0,
                tg,
                n,
                &mut queues,
                &mut resource_free,
                &mut start,
                &mut busy,
                &mut events,
            );
        }
        while let Some(Key(t_ev, tag)) = events.pop() {
            if tag >= n {
                try_start(
                    tag - n,
                    t_ev,
                    tg,
                    n,
                    &mut queues,
                    &mut resource_free,
                    &mut start,
                    &mut busy,
                    &mut events,
                );
                continue;
            }
            let id = tag;
            let now = t_ev;
            finish[id] = t_ev;
            let r = tg.tasks[id].resource;
            resource_free[r] = true;
            for &s in &succs[id] {
                indeg[s] -= 1;
                ready_at[s] = ready_at[s].max(t_ev);
                if indeg[s] == 0 {
                    queues[tg.tasks[s].resource].push(Key(ready_at[s], s));
                }
            }
            try_start(
                r,
                now,
                tg,
                n,
                &mut queues,
                &mut resource_free,
                &mut start,
                &mut busy,
                &mut events,
            );
            for &s in &succs[id] {
                let rs = tg.tasks[s].resource;
                try_start(
                    rs,
                    now,
                    tg,
                    n,
                    &mut queues,
                    &mut resource_free,
                    &mut start,
                    &mut busy,
                    &mut events,
                );
            }
        }
        let makespan = finish.iter().copied().fold(0.0f64, f64::max);
        RefSchedule { start, finish, busy, makespan }
    }
}

#[test]
fn prop_simplified_engine_matches_wake_event_reference() {
    // The PR-2 review suspected the idle-until-ready wake branch was
    // unreachable; PR 3 simplified dispatch to `now.max(ready)`.  Prove
    // the two engines produce bit-identical schedules on the random
    // corpus (same generator as the other simulator properties).
    for case in 0..60 {
        let mut rng = Rng::new(5000 + case);
        let n = rng.range(5, 150);
        let r = rng.range(1, 8);
        let tg = random_task_graph(&mut rng, n, r);
        let s = simulate(&tg);
        let s_ref = wake_event_reference::simulate(&tg);
        assert_eq!(s.makespan.to_bits(), s_ref.makespan.to_bits(), "case {case}");
        for i in 0..n {
            assert_eq!(s.start[i].to_bits(), s_ref.start[i].to_bits(), "case {case} task {i}");
            assert_eq!(
                s.finish[i].to_bits(),
                s_ref.finish[i].to_bits(),
                "case {case} task {i}"
            );
        }
        for res in 0..r {
            assert_eq!(s.busy[res].to_bits(), s_ref.busy[res].to_bits(), "case {case}");
        }
    }
}

#[test]
fn prop_simulator_lower_bounds_and_monotonicity() {
    for case in 0..40 {
        let mut rng = Rng::new(1000 + case);
        let n = rng.range(5, 120);
        let r = rng.range(1, 8);
        let tg = random_task_graph(&mut rng, n, r);
        let s = simulate(&tg);

        // Makespan >= busiest resource's total load.
        for res in 0..r {
            assert!(s.makespan >= s.busy[res] - 1e-9, "case {case}");
        }
        // Makespan >= critical path (longest dependency chain).
        let mut cp = vec![0.0f64; n];
        for i in 0..n {
            let dep_max = tg.tasks[i]
                .deps
                .iter()
                .map(|&d| cp[d])
                .fold(0.0f64, f64::max);
            cp[i] = dep_max + tg.tasks[i].duration;
        }
        let crit = cp.iter().copied().fold(0.0f64, f64::max);
        assert!(s.makespan >= crit - 1e-9, "case {case}");

        // Start/finish sanity.
        for i in 0..n {
            assert!(s.finish[i] >= s.start[i] - 1e-12);
            for &d in &tg.tasks[i].deps {
                assert!(s.start[i] >= s.finish[d] - 1e-9, "case {case}: dep order");
            }
        }

        // Monotonicity: growing one task's duration never shrinks the
        // makespan... (true for work-conserving FIFO with fixed priority
        // order only in expectation; we check weak monotonicity against
        // growing ALL durations, which is safe).
        let mut tg2 = tg.clone();
        for t in &mut tg2.tasks {
            t.duration *= 1.5;
        }
        let s2 = simulate(&tg2);
        assert!(s2.makespan >= s.makespan - 1e-9, "case {case}");
    }
}

/// Brute-force reference for the SFB ILP on tiny instances.
fn brute_force(p: &SfbProblem) -> f64 {
    let n = p.node_time.len();
    let dd = p.d as f64;
    let mut best = 0.0f64;
    for mask in 0u32..(1 << n) {
        let alpha = |i: usize| mask & (1 << i) != 0;
        // Constraint: alpha_k needs a duplicated consumer (k != g).
        let mut ok = true;
        for k in 0..n {
            if k != p.g_idx && alpha(k) {
                let has = p.edges.iter().any(|&(j, i, _)| j == k && alpha(i));
                if !has {
                    ok = false;
                    break;
                }
            }
        }
        if !ok {
            continue;
        }
        let mut cost = 0.0;
        for i in 0..n {
            if alpha(i) {
                cost += (dd - 1.0) * p.node_time[i]
                    + dd * (dd - 1.0) * p.boundary_bytes[i] / p.tau;
            }
        }
        for &(j, i, l) in &p.edges {
            if alpha(i) && !alpha(j) {
                cost += dd * (dd - 1.0) * l / p.tau;
            }
        }
        if alpha(p.g_idx) {
            cost -= 2.0 * (dd - 1.0) / dd * p.grad_bytes / p.tau;
        }
        best = best.min(cost);
    }
    best
}

#[test]
fn prop_sfb_solver_matches_brute_force() {
    for case in 0..60 {
        let mut rng = Rng::new(2000 + case);
        let n = rng.range(2, 10);
        let mut edges = Vec::new();
        for i in 1..n {
            // random DAG edges j < i
            let deg = rng.range(1, 2.min(i));
            for _ in 0..deg {
                edges.push((rng.below(i), i, rng.uniform(1e3, 50e6)));
            }
        }
        let p = SfbProblem {
            node_time: (0..n).map(|_| rng.uniform(0.0, 1e-3)).collect(),
            edges,
            boundary_bytes: (0..n).map(|_| rng.uniform(0.0, 20e6)).collect(),
            g_idx: n - 1,
            d: rng.range(2, 8),
            tau: rng.uniform(1e8, 1e10),
            grad_bytes: rng.uniform(0.0, 300e6),
        };
        let sol = solve(&p);
        assert!(sol.optimal, "case {case}");
        let bf = brute_force(&p);
        assert!(
            (sol.objective - bf).abs() < 1e-9 * (1.0 + bf.abs()),
            "case {case}: solver {} vs brute force {}",
            sol.objective,
            bf
        );
        assert!(sol.objective <= 1e-12);
    }
}

#[test]
fn prop_comm_model_monotonicity() {
    let m = CommModel::fit(4);
    let mut rng = Rng::new(3000);
    for _ in 0..50 {
        let b1 = rng.uniform(1e3, 5e8);
        let b2 = b1 * rng.uniform(1.0, 4.0);
        let bw = rng.uniform(1e8, 3e10);
        assert!(m.transfer_time(b2, bw) >= m.transfer_time(b1, bw) - 1e-12);
        let bw2 = bw * rng.uniform(1.0, 4.0);
        assert!(m.transfer_time(b1, bw2) <= m.transfer_time(b1, bw) + 1e-12);
    }
}

#[test]
fn prop_memo_cached_and_uncached_bit_identical() {
    // 100 random (partial and complete) strategies across 4 random
    // topologies: the transposition table must return outcomes that are
    // bit-identical to a fresh lowering+simulation, both on the filling
    // pass and on repeated hits.
    let model = models::by_name("VGG19", 0.25).unwrap();
    for case in 0..4 {
        let mut rng = Rng::new(7000 + case);
        let topo = random_topology(&mut rng);
        let cost = CostModel::profile(&model.ops, &unique_gpus(&topo), 0.0, 1);
        let gg = group_ops(&model, &cost, 12, case);
        let comm = CommModel::fit(3);
        let low = Lowering::new(&gg, &topo, &cost, &comm);
        let actions = enumerate_actions(&topo);
        for _ in 0..25 {
            let mut s = Strategy::empty(gg.num_groups());
            for g in 0..gg.num_groups() {
                if rng.chance(0.85) {
                    s.slots[g] = Some(*rng.choose(&actions));
                }
            }
            let cold = low.evaluate_uncached(&s);
            let warm1 = low.evaluate(&s);
            let warm2 = low.evaluate(&s);
            assert_eq!(cold, warm1, "case {case}: fill differs from bypass");
            assert_eq!(warm1, warm2, "case {case}: hit differs from fill");
        }
        let (hits, _misses) = low.memo_stats();
        assert!(hits >= 25, "case {case}: memo never hit ({hits})");
    }
}

/// Bit-exact outcome comparison: `to_bits` on every float (stricter
/// than `==`, which would let `-0.0 == 0.0` or differing NaN payloads
/// slip through), plus the OOM verdict.
fn assert_outcomes_bit_identical(fast: &SimOutcome, slow: &SimOutcome, ctx: &str) {
    assert_eq!(fast.time.to_bits(), slow.time.to_bits(), "{ctx}: time");
    assert_eq!(fast.oom, slow.oom, "{ctx}: oom");
    let pairs = [
        (&fast.feedback.group_makespan, &slow.feedback.group_makespan, "group_makespan"),
        (
            &fast.feedback.group_idle_before_send,
            &slow.feedback.group_idle_before_send,
            "group_idle_before_send",
        ),
        (
            &fast.feedback.devgroup_peak_mem_frac,
            &slow.feedback.devgroup_peak_mem_frac,
            "devgroup_peak_mem_frac",
        ),
        (&fast.feedback.devgroup_idle, &slow.feedback.devgroup_idle, "devgroup_idle"),
    ];
    for (a, b, name) in pairs {
        assert_eq!(a.len(), b.len(), "{ctx}: {name} length");
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: {name}[{i}]");
        }
    }
    assert_eq!(fast.feedback.link_idle.len(), slow.feedback.link_idle.len(), "{ctx}: link_idle");
    for (i, (ra, rb)) in
        fast.feedback.link_idle.iter().zip(slow.feedback.link_idle.iter()).enumerate()
    {
        assert_eq!(ra.len(), rb.len(), "{ctx}: link_idle[{i}] length");
        for (j, (x, y)) in ra.iter().zip(rb.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: link_idle[{i}][{j}]");
        }
    }
}

#[test]
fn prop_delta_evaluation_bit_identical_to_full() {
    // Seeded walks of single- and multi-group flips on three presets
    // (incl. the routed `multi_rack`, whose transfers carry link loads
    // and contention): the delta-enabled evaluation must be bit-exact
    // against a delta-disabled oracle Lowering that always lowers and
    // simulates from scratch.
    let model = models::by_name("VGG19", 0.25).unwrap();
    for (pi, topo) in [testbed(), sfb_pair(), multi_rack()].into_iter().enumerate() {
        let cost = CostModel::profile(&model.ops, &unique_gpus(&topo), 0.0, 1);
        let gg = group_ops(&model, &cost, 12, pi as u64);
        let comm = CommModel::fit(3);
        let low = Lowering::new(&gg, &topo, &cost, &comm);
        assert!(low.delta_enabled(), "delta defaults on");
        let oracle = Lowering::new(&gg, &topo, &cost, &comm);
        oracle.set_delta(false);
        let actions = enumerate_actions(&topo);
        let ng = gg.num_groups();
        let mut rng = Rng::new(9000 + pi as u64);
        let mut s = Strategy::dp_allreduce(ng, &topo);
        for step in 0..24 {
            // Half the walk flips one group (the delta sweet spot), the
            // rest flips up to the neighbor-eligibility cap.
            let flips =
                if step % 2 == 0 { 1 } else { 1 + rng.below(DELTA_MAX_FLIPS) };
            for _ in 0..flips {
                s.slots[rng.below(ng)] = Some(*rng.choose(&actions));
            }
            let fast = low.evaluate(&s);
            let slow = oracle.evaluate_uncached(&s);
            assert_outcomes_bit_identical(
                &fast,
                &slow,
                &format!("preset {} step {step}", topo.name),
            );
        }
        let stats = low.delta_stats();
        assert!(
            stats.delta_evals >= 1,
            "preset {}: the delta path never fired ({stats:?})",
            topo.name
        );
        assert!(low.fragment_hit_rate() > 0.0, "preset {}: fragments never hit", topo.name);
        let (ohits, omisses) = oracle.fragment_stats();
        assert_eq!((ohits, omisses), (0, 0), "delta-off oracle must bypass the store");
    }
}

#[test]
fn prop_delta_bit_identical_across_shared_cache_workers() {
    // The serving/search configuration: several workers, each with its
    // own Lowering but all over ONE shared EvalCaches bundle (memo +
    // fragment store + mask profiles), evaluating interleaved flip
    // walks concurrently.  Every worker checks its own outcomes against
    // a private delta-off oracle, so a cross-thread fragment collision
    // or a stale memo entry surfaces as a bit mismatch here.
    let model = models::by_name("VGG19", 0.25).unwrap();
    let topo = multi_rack();
    let cost = CostModel::profile(&model.ops, &unique_gpus(&topo), 0.0, 1);
    let gg = group_ops(&model, &cost, 10, 3);
    let comm = CommModel::fit(3);
    let actions = enumerate_actions(&topo);
    let ng = gg.num_groups();
    for workers in [1usize, 4] {
        let caches = EvalCaches::new();
        std::thread::scope(|scope| {
            for w in 0..workers {
                let caches = caches.clone();
                let (gg, topo, cost, comm, actions) = (&gg, &topo, &cost, &comm, &actions);
                scope.spawn(move || {
                    let low = Lowering::with_caches(gg, topo, cost, comm, caches);
                    let oracle = Lowering::new(gg, topo, cost, comm);
                    oracle.set_delta(false);
                    let mut rng = Rng::new(9500 + w as u64);
                    let mut s = Strategy::dp_allreduce(ng, topo);
                    for step in 0..12 {
                        for _ in 0..(1 + rng.below(2)) {
                            s.slots[rng.below(ng)] = Some(*rng.choose(actions));
                        }
                        let fast = low.evaluate(&s);
                        let slow = oracle.evaluate_uncached(&s);
                        assert_outcomes_bit_identical(
                            &fast,
                            &slow,
                            &format!("workers={workers} worker {w} step {step}"),
                        );
                    }
                });
            }
        });
    }
}

#[test]
fn prop_tracing_never_perturbs_plan_bytes_or_evaluations() {
    use tag::api::{PlanRequest, Planner};
    use tag::obs::Tracer;

    // workers=1 — the exact sequential engine: a fresh planner run
    // under an installed tracer must produce a byte-identical encoded
    // plan to an untraced run.  Spans read the monotonic clock but
    // write only to their own buffers, so nothing they observe may
    // reach plan bytes, fingerprints or RNG state.
    let request =
        PlanRequest::new(models::by_name("VGG19", 0.25).unwrap(), multi_rack())
            .budget(40, 10)
            .seed(11);
    let untraced = Planner::builder().build().plan(&request).unwrap().plan.encode();
    let tracer = Tracer::enabled("prop");
    let traced = {
        let _g = tracer.install();
        Planner::builder().build().plan(&request).unwrap().plan.encode()
    };
    let trace = tracer.finish().expect("enabled tracer yields a trace");
    assert!(!trace.spans.is_empty(), "the planner emitted no spans under tracing");
    assert_eq!(untraced, traced, "tracing perturbed plan bytes at workers=1");

    // workers=4 — tree-parallel search is seed-stable but
    // schedule-dependent (thread interleaving picks among equal-value
    // expansions), so whole-plan bytes are not comparable run to run
    // even without tracing.  The contract is checked where parallel
    // workers actually share state: evaluation over one shared
    // EvalCaches bundle.  The same seeded flip walks run once untraced
    // and once traced (fresh shared caches each time); every outcome
    // must match bit for bit.
    let model = models::by_name("VGG19", 0.25).unwrap();
    let topo = multi_rack();
    let cost = CostModel::profile(&model.ops, &unique_gpus(&topo), 0.0, 1);
    let gg = group_ops(&model, &cost, 10, 3);
    let comm = CommModel::fit(3);
    let actions = enumerate_actions(&topo);
    let ng = gg.num_groups();
    let walk = |tracer: &Tracer| -> Vec<Vec<SimOutcome>> {
        let caches = EvalCaches::new();
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..4usize)
                .map(|w| {
                    let caches = caches.clone();
                    let tracer = tracer.clone();
                    let (gg, topo, cost, comm, actions) =
                        (&gg, &topo, &cost, &comm, &actions);
                    scope.spawn(move || {
                        let _g = tracer.install();
                        let _s = tag::obs::span_arg("prop.worker", w as i64);
                        let low = Lowering::with_caches(gg, topo, cost, comm, caches);
                        let mut rng = Rng::new(9700 + w as u64);
                        let mut s = Strategy::dp_allreduce(ng, topo);
                        let mut outs = Vec::new();
                        for _ in 0..12 {
                            for _ in 0..(1 + rng.below(2)) {
                                s.slots[rng.below(ng)] = Some(*rng.choose(actions));
                            }
                            outs.push(low.evaluate(&s));
                        }
                        outs
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    };
    let reference = walk(&Tracer::disabled());
    let tracer = Tracer::enabled("prop-workers");
    let traced = walk(&tracer);
    let trace = tracer.finish().expect("enabled tracer yields a trace");
    assert!(
        trace.spans.iter().any(|s| s.name == "prop.worker"),
        "worker spans never recorded"
    );
    for (w, (a, b)) in reference.iter().zip(&traced).enumerate() {
        assert_eq!(a.len(), b.len());
        for (step, (x, y)) in a.iter().zip(b).enumerate() {
            assert_outcomes_bit_identical(x, y, &format!("traced worker {w} step {step}"));
        }
    }
}

#[test]
fn prop_random_strategies_evaluate_finitely() {
    for case in 0..12 {
        let mut rng = Rng::new(4000 + case);
        let topo = random_topology(&mut rng);
        let model = models::by_name("InceptionV3", 0.25).unwrap();
        let cost = CostModel::profile(&model.ops, &unique_gpus(&topo), 0.0, 1);
        let gg = group_ops(&model, &cost, 16, case);
        let comm = CommModel::fit(3);
        let low = Lowering::new(&gg, &topo, &cost, &comm);
        let actions = enumerate_actions(&topo);
        for _ in 0..5 {
            let mut s = Strategy::empty(gg.num_groups());
            for g in 0..gg.num_groups() {
                if rng.chance(0.8) {
                    s.slots[g] = Some(*rng.choose(&actions));
                }
            }
            let out = low.evaluate(&s);
            assert!(out.time.is_finite() && out.time > 0.0, "case {case}");
            for f in &out.feedback.devgroup_idle {
                assert!((0.0..=1.0).contains(f));
            }
        }
    }
}
