//! Mathematical-equivalence tests for the op-level compiler (§4.3.1):
//! whatever strategy is applied, the distributed graph must preserve the
//! semantics the auxiliary-op rules encode.  We verify the structural
//! invariants that imply equivalence (the simulator never executes real
//! numerics, so these are the compiler's correctness contract).

use tag::cluster::presets::{sfb_pair, testbed};
use tag::cluster::Topology;
use tag::dist::rewrite::rewrite;
use tag::graph::grouping::{group_ops, GroupGraph};
use tag::graph::ir::{CompGraph, OpKind, Splittability};
use tag::models;
use tag::profile::{unique_gpus, CostModel};
use tag::strategy::{Action, ReplOption, Strategy};
use tag::util::Rng;

fn setup(topo: &Topology, seed: u64) -> (CompGraph, GroupGraph) {
    let m = models::bert(4, false, 0.25);
    let cost = CostModel::profile(&m.ops, &unique_gpus(topo), 0.0, 1);
    let gg = group_ops(&m, &cost, 16, seed);
    (m, gg)
}

/// Check the §4.3.1 equivalence invariants on a rewritten graph.
fn check_invariants(orig: &CompGraph, d: &tag::dist::rewrite::DistGraph) {
    let g = &d.graph;
    assert!(g.check_acyclic());

    // 1. Every original variable appears exactly as many times as its
    //    group's replication count — and each Apply consumes either a
    //    sync op output or an aggregated (AddN) gradient.
    let orig_vars = orig.ops.iter().filter(|o| o.is_param()).count();
    let dist_vars = g.ops.iter().filter(|o| o.is_param()).count();
    assert!(dist_vars >= orig_vars, "variables lost in rewrite");

    // 2. NoSplit consumers never read a sharded tensor directly: their
    //    inputs must be full tensors (unsharded producers, Concat, AddN
    //    or sync ops).
    for op in &g.ops {
        if op.splittability == Splittability::NoSplit {
            for &i in &op.inputs {
                let p = &g.ops[i];
                let full_source = p.op_type == "ConcatV2"
                    || p.op_type == "AddN"
                    || p.op_type == "NcclAllReduce"
                    || p.op_type == "PsUpdate"
                    || p.op_type == "Split"
                    || !p.name.contains("/rep")
                    || p.is_param();
                assert!(
                    full_source || p.name.contains("/rep"),
                    "NoSplit op {} reads suspicious input {}",
                    op.name,
                    p.name
                );
            }
        }
    }

    // 3. Gradient producers keep their Sum splittability, Apply ops keep
    //    NoSplit (the analyzer invariants survive rewriting).
    assert!(tag::graph::analyzer::check_annotations(g).is_empty());
}

#[test]
fn invariants_hold_for_all_uniform_strategies() {
    let topo = sfb_pair();
    let (m, gg) = setup(&topo, 3);
    for option in ReplOption::ALL {
        let s = Strategy::uniform(
            gg.num_groups(),
            Action { mask: tag::strategy::full_mask(&topo), option },
        );
        let d = rewrite(&m, &gg, &topo, &s);
        check_invariants(&m, &d);
    }
}

#[test]
fn invariants_hold_for_random_mixed_strategies() {
    let topo = testbed();
    let (m, gg) = setup(&topo, 5);
    let actions = tag::strategy::enumerate_actions(&topo);
    let mut rng = Rng::new(99);
    for _ in 0..10 {
        let mut s = Strategy::empty(gg.num_groups());
        for g in 0..gg.num_groups() {
            s.slots[g] = Some(*rng.choose(&actions));
        }
        let d = rewrite(&m, &gg, &topo, &s);
        check_invariants(&m, &d);
    }
}

#[test]
fn grad_sync_count_matches_replicated_groups() {
    let topo = sfb_pair();
    let (m, gg) = setup(&topo, 7);
    let s = Strategy::dp_allreduce(gg.num_groups(), &topo);
    let d = rewrite(&m, &gg, &topo, &s);
    let n_sync = d.inserted.get("NcclAllReduce").copied().unwrap_or(0);
    assert_eq!(n_sync, m.grad_apply_pairs().len());
    // Each sync op reads every replica of its gradient (2 devices here).
    for op in &d.graph.ops {
        if op.op_type == "NcclAllReduce" {
            assert_eq!(op.inputs.len(), 2, "{}", op.name);
        }
    }
}

#[test]
fn batch_conservation_under_dp() {
    // Sum of replica batch fractions == 1 for every batch-splittable op:
    // verified through the flops conservation of the rewritten graph.
    let topo = sfb_pair();
    let (m, gg) = setup(&topo, 9);
    let s = Strategy::dp_allreduce(gg.num_groups(), &topo);
    let d = rewrite(&m, &gg, &topo, &s);
    let grad_extra: f64 = d
        .graph
        .ops
        .iter()
        .filter(|o| o.op_type == "NcclAllReduce" || o.op_type == "AddN")
        .map(|o| o.flops)
        .sum();
    let core = d.graph.total_flops() - grad_extra;
    let ratio = core / m.total_flops();
    assert!(
        (0.95..1.25).contains(&ratio),
        "flops conservation violated: {ratio}"
    );
}

#[test]
fn placeholders_and_variables_never_split() {
    let topo = sfb_pair();
    let (m, gg) = setup(&topo, 11);
    let s = Strategy::dp_allreduce(gg.num_groups(), &topo);
    let d = rewrite(&m, &gg, &topo, &s);
    for op in &d.graph.ops {
        if matches!(op.kind, OpKind::Variable) {
            // Full parameter bytes on every replica (never sharded).
            assert!(op.param_bytes > 0.0);
        }
    }
    let _ = m;
}
