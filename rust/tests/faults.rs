//! Fault-tolerance integration: seeded fault traces always yield sound
//! residual topologies, plan repair on a degraded cluster avoids dead
//! hardware deterministically, and the serving daemon survives a
//! panicking backend with a clean `500` (chaos-style, over real TCP).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use tag::api::{
    BackendOutcome, PlanRequest, Planner, SearchBackend, SearchContext, SharedPlanner,
};
use tag::cluster::presets::{multi_rack, nvlink_island, testbed};
use tag::cluster::{generate_trace, FaultSpec};
use tag::models;
use tag::serve::{ServeConfig, Server};

#[test]
fn seeded_fault_traces_yield_sound_residuals() {
    for topo in [testbed(), multi_rack(), nvlink_island()] {
        let specs = generate_trace(&topo, 42, 12);
        assert!(!specs.is_empty(), "no specs drawn for {}", topo.name);
        for spec in &specs {
            // The grammar round-trips.
            assert_eq!(&FaultSpec::parse(&spec.encode()).unwrap(), spec);

            let residual = spec.apply(&topo).expect("trace specs always apply");
            let t = &residual.topology;
            t.validate().unwrap_or_else(|e| panic!("{}: {e}", t.name));
            assert_eq!(
                t.num_devices(),
                topo.num_devices() - residual.dead_devices.len(),
                "{}",
                t.name
            );

            // Dense renumbering: the map covers every residual group
            // exactly once.
            let mut seen = vec![false; t.num_groups()];
            for &m in residual.group_map.iter().flatten() {
                assert!(!seen[m], "{}: residual group {m} mapped twice", t.name);
                seen[m] = true;
            }
            assert!(seen.iter().all(|&s| s), "{}: unmapped residual group", t.name);

            // Sound routes: every surviving group pair keeps a positive,
            // symmetric bottleneck bandwidth (a disconnected residual is
            // rejected by `apply`, never returned).
            for i in 0..t.num_groups() {
                for j in 0..t.num_groups() {
                    if i == j {
                        continue;
                    }
                    let bw = t.inter_bw_gbps[i][j];
                    assert!(bw > 0.0, "{}: bw[{i}][{j}] = {bw}", t.name);
                    assert!(
                        (bw - t.inter_bw_gbps[j][i]).abs() < 1e-9,
                        "{}: asymmetric residual matrix",
                        t.name
                    );
                }
            }

            // The all-groups placement mask survives remapping into the
            // residual's (smaller) group space.
            let full = u16::MAX >> (16 - topo.num_groups());
            let mapped = residual.remap_mask(full);
            assert!(mapped != 0, "{}: full mask remapped to nothing", t.name);
            assert_eq!(u32::from(mapped) >> t.num_groups(), 0, "{}", t.name);
        }
        // Determinism: the same seed draws the same trace.
        assert_eq!(generate_trace(&topo, 42, 12), specs);
    }
}

#[test]
fn repair_on_multi_rack_avoids_dead_hardware_and_is_deterministic() {
    let topo = multi_rack();
    let model = models::by_name("VGG19", 0.25).unwrap();
    let request = PlanRequest::new(model, topo.clone()).budget(60, 10).seed(7);
    let planner = Planner::builder().build();
    let prior = planner.plan(&request).expect("prior plan").plan;

    let faults = FaultSpec::parse("kill:0.0").unwrap();
    let out = planner.repair(&request, &prior, &faults).expect("repair");
    let plan = &out.plan;
    assert_eq!(plan.backend, "repair");
    assert!(plan.topology_name.contains("kill:0.0"), "{}", plan.topology_name);
    assert!(plan.times.speedup >= 1.0 - 1e-9, "repair lost to residual DP");

    // Every placement mask stays inside the residual's group space —
    // nothing is placed on (or beyond) dead hardware.
    let residual = faults.apply(&topo).unwrap();
    let ng = residual.topology.num_groups();
    for a in plan.strategy.slots.iter().flatten() {
        assert!(a.mask != 0, "empty placement mask");
        assert_eq!(u32::from(a.mask) >> ng, 0, "mask {:#b} escapes {ng} groups", a.mask);
    }

    // Warm start: a feasible surviving strategy bounds the repair from
    // above (the incumbent is only ever replaced by something better).
    if let Some(warm) = out.warm_time {
        assert!(
            plan.times.final_time <= warm + 1e-12,
            "repair ({}) worse than its own warm start ({warm})",
            plan.times.final_time
        );
    }

    // Determinism: same (request, prior, faults) → byte-identical plan.
    let again = planner.repair(&request, &prior, &faults).expect("repair again");
    assert_eq!(again.plan.encode(), plan.encode());
}

/// A backend that always panics mid-search — the chaos probe for the
/// daemon's panic isolation.
struct PanicBackend;

impl SearchBackend for PanicBackend {
    fn name(&self) -> &'static str {
        "panic-injector"
    }

    fn fingerprint_token(&self) -> u64 {
        0xdead
    }

    fn search(&self, _ctx: &SearchContext<'_>) -> BackendOutcome {
        panic!("injected backend panic (chaos test)")
    }
}

/// One-shot HTTP client: returns (status, body).
fn http(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut raw = format!("{method} {path} HTTP/1.1\r\nconnection: close\r\n");
    if let Some(body) = body {
        raw.push_str(&format!("content-length: {}\r\n", body.len()));
    }
    raw.push_str("\r\n");
    if let Some(body) = body {
        raw.push_str(body);
    }
    stream.write_all(raw.as_bytes()).expect("send");
    let mut response = String::new();
    stream.read_to_string(&mut response).expect("recv");
    let (head, body) = response.split_once("\r\n\r\n").expect("framed response");
    let status: u16 = head.split(' ').nth(1).and_then(|s| s.parse().ok()).expect("status");
    (status, body.to_string())
}

#[test]
fn serve_survives_a_panicking_backend_with_500s() {
    let config = ServeConfig {
        port: 0,
        workers: 2,
        queue_depth: 8,
        read_timeout: Duration::from_secs(10),
        ..ServeConfig::default()
    };
    let planner = SharedPlanner::builder().backend(PanicBackend).build();
    let server = Server::bind(config, planner).expect("bind");
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || server.run().expect("serve"));

    let body = r#"{"model":"VGG19","iterations":10,"max_groups":8}"#;
    let (status, text) = http(addr, "POST", "/plan", Some(body));
    assert_eq!(status, 500, "{text}");

    // The worker survived: the daemon keeps answering and reports the
    // caught panic in both readiness and metrics.
    let (status, health) = http(addr, "GET", "/healthz", None);
    assert_eq!(status, 200, "{health}");
    assert!(health.contains("\"status\":\"ok\""), "{health}");
    assert!(health.contains("\"panics_total\":1"), "{health}");

    let (status, text) = http(addr, "POST", "/plan", Some(body));
    assert_eq!(status, 500, "second panic also isolated: {text}");

    let (status, metrics) = http(addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    assert!(metrics.contains("tag_panics_total 2"), "{metrics}");
    assert!(metrics.contains("tag_responses_total{status=\"500\"} 2"), "{metrics}");

    let (status, _) = http(addr, "POST", "/shutdown", None);
    assert_eq!(status, 200);
    handle.join().unwrap();
}

#[test]
fn repair_round_trips_through_the_daemon() {
    let config = ServeConfig {
        port: 0,
        workers: 2,
        queue_depth: 8,
        read_timeout: Duration::from_secs(10),
        ..ServeConfig::default()
    };
    let server = Server::bind(config, SharedPlanner::builder().build()).expect("bind");
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || server.run().expect("serve"));

    let body = r#"{"model":"VGG19","iterations":30,"max_groups":10,"seed":3}"#;
    let (status, plan_json) = http(addr, "POST", "/plan", Some(body));
    assert_eq!(status, 200, "{plan_json}");
    let repair_body = format!(
        r#"{{"model":"VGG19","iterations":30,"max_groups":10,"seed":3,"faults":"kill:0.0","plan":{plan_json}}}"#
    );
    let (status, repaired) = http(addr, "POST", "/repair", Some(&repair_body));
    assert_eq!(status, 200, "{repaired}");
    let plan = tag::api::DeploymentPlan::decode(&repaired).expect("repaired plan JSON");
    assert_eq!(plan.backend, "repair");
    assert!(plan.topology_name.contains("kill:0.0"), "{}", plan.topology_name);

    let (status, _) = http(addr, "POST", "/shutdown", None);
    assert_eq!(status, 200);
    handle.join().unwrap();
}
