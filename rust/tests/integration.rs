//! Cross-module integration tests: the full pipeline (model zoo ->
//! analyzer -> profiler -> grouping -> lowering -> simulator -> MCTS ->
//! SFB), plus the runtime + GNN path when artifacts are present.

use tag::cluster::presets::{cloud, homogeneous, sfb_pair, testbed};
use tag::cluster::{generator::random_topologies, Topology};
use tag::coordinator::{prepare, search_session, SearchConfig};
use tag::dist::Lowering;
use tag::models;
use tag::strategy::{baselines, enumerate_actions, Strategy};

fn cfg(iters: usize, seed: u64) -> SearchConfig {
    SearchConfig {
        max_groups: 12,
        mcts_iterations: iters,
        seed,
        apply_sfb: true,
        profile_noise: 0.0,
        parallelism: Default::default(),
        deadline_ms: None,
        delta: true,
    }
}

#[test]
fn every_model_searches_on_every_preset_topology() {
    for topo in [testbed(), cloud(), homogeneous(), sfb_pair()] {
        for name in models::MODEL_NAMES {
            let model = models::by_name(name, 0.25).unwrap();
            let c = cfg(40, 3);
            let prep = prepare(model, &topo, &c);
            let res = search_session(&prep, &topo, None, &c);
            assert!(
                res.time.is_finite() && res.time > 0.0,
                "{name} on {}",
                topo.name
            );
            assert!(
                res.speedup >= 1.0 - 1e-9,
                "{name} on {}: TAG lost to DP ({:.3}x)",
                topo.name,
                res.speedup
            );
        }
    }
}

#[test]
fn tag_beats_or_matches_all_baselines_everywhere() {
    // The paper's core claim (Fig. 5): TAG >= every baseline on the
    // heterogeneous testbed, for every model.
    let topo = testbed();
    for name in models::MODEL_NAMES {
        let model = models::by_name(name, 0.25).unwrap();
        let c = cfg(150, 5);
        let prep = prepare(model, &topo, &c);
        let low = Lowering::new(&prep.gg, &topo, &prep.cost, &prep.comm);
        let acts = enumerate_actions(&topo);
        let ng = prep.gg.num_groups();
        let res = search_session(&prep, &topo, None, &c);
        let t_tag = res.dp_time / res.speedup;

        let baselines: Vec<(&str, f64)> = vec![
            ("DP", low.evaluate(&baselines::dp_nccl(ng, &topo)).time),
            ("DP-P", low.evaluate(&baselines::dp_nccl_p(ng, &topo)).time),
            ("Horovod", low.evaluate(&baselines::horovod(ng, &topo)).time),
            ("Baechi", low.evaluate(&baselines::baechi_msct(&low)).time),
            (
                "FlexFlow",
                low.evaluate(&baselines::flexflow_mcmc(&low, &acts, 100, 5)).time,
            ),
            ("HeteroG", low.evaluate(&baselines::heterog_like(&low)).time),
        ];
        for (bname, t) in baselines {
            assert!(
                t_tag <= t * 1.05,
                "{name}: TAG ({t_tag:.4}s) lost to {bname} ({t:.4}s)"
            );
        }
    }
}

#[test]
fn random_topologies_never_crash_the_pipeline() {
    for (i, topo) in random_topologies(77, 15).iter().enumerate() {
        let model = models::by_name("BERT-Small", 0.25).unwrap();
        let c = cfg(25, 100 + i as u64);
        let prep = prepare(model, topo, &c);
        let res = search_session(&prep, topo, None, &c);
        assert!(res.time.is_finite());
        assert!(res.speedup >= 1.0 - 1e-9);
    }
}

#[test]
fn op_level_rewrite_consistent_with_group_level_strategy() {
    let topo = testbed();
    let model = models::vgg19(8, 0.25);
    let c = cfg(60, 9);
    let prep = prepare(model, &topo, &c);
    let res = search_session(&prep, &topo, None, &c);
    let dist = tag::dist::rewrite::rewrite(&prep.graph, &prep.gg, &topo, &res.strategy);
    assert!(dist.graph.check_acyclic());
    assert_eq!(dist.graph.len(), dist.placement.len());
    // Every device used by the strategy appears in the placement.
    let used: std::collections::HashSet<_> = dist.placement.iter().copied().collect();
    assert!(!used.is_empty());
}

#[test]
fn profiling_noise_does_not_flip_the_headline() {
    // With realistic 3% measurement noise the search must still beat DP.
    let topo = testbed();
    let model = models::vgg19(8, 0.25);
    let mut c = cfg(80, 11);
    c.profile_noise = 0.03;
    let prep = prepare(model, &topo, &c);
    let res = search_session(&prep, &topo, None, &c);
    assert!(res.speedup > 1.2, "speedup {:.2}", res.speedup);
}

#[test]
fn oom_strategies_are_rejected_by_search() {
    // BERT-Large (paper batch 16) on the 11 GB pair: single-device
    // placements OOM while batch-split DP fits.  The search must return
    // a feasible (non-OOM) strategy even though much of its action space
    // is infeasible (the paper's interactive-feasibility argument, §3.3).
    let topo = sfb_pair();
    let model = models::bert(16, true, 1.0);
    let c = cfg(60, 13);
    let prep = prepare(model, &topo, &c);
    let low = Lowering::new(&prep.gg, &topo, &prep.cost, &prep.comm);
    let solo = Strategy::uniform(
        prep.gg.num_groups(),
        tag::strategy::Action {
            mask: 0b1,
            option: tag::strategy::ReplOption::AllReduce,
        },
    );
    assert!(low.evaluate(&solo).oom, "precondition: single-GPU must OOM");
    let res = search_session(&prep, &topo, None, &c);
    let out = low.evaluate(&res.strategy);
    assert!(!out.oom, "search returned an OOM strategy");
}

#[test]
fn cloud_topology_exercises_16_device_groups_limit() {
    let topo: Topology = cloud();
    assert!(topo.num_groups() <= 16);
    let model = models::transformer(16, 0.25);
    let c = cfg(40, 17);
    let prep = prepare(model, &topo, &c);
    let res = search_session(&prep, &topo, None, &c);
    assert!(res.speedup >= 1.0 - 1e-9);
}

#[test]
fn gnn_guided_search_with_artifacts() {
    if !std::path::Path::new("artifacts/gnn_infer.hlo.txt").exists() {
        eprintln!("skipping (run `make artifacts`)");
        return;
    }
    // The runtime may be the PJRT stub even when artifact files exist;
    // only a loadable service makes this test meaningful.
    let backend = match tag::api::GnnMctsBackend::from_artifacts(
        "artifacts",
        "artifacts/params_init.bin",
    ) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("skipping (GNN backend unavailable: {e})");
            return;
        }
    };
    let planner = tag::api::Planner::builder().backend(backend).build();
    let request =
        tag::api::PlanRequest::new(models::inception_v3(8, 0.25), testbed())
            .budget(40, 12)
            .seed(19);
    let plan = planner.plan(&request).expect("plan").plan;
    assert_eq!(plan.backend, "gnn-mcts");
    assert!(plan.times.speedup >= 1.0 - 1e-9);
    assert!(plan.telemetry.metric("gnn_evals").unwrap_or(0.0) > 0.0);
}
