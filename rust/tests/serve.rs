//! End-to-end loopback tests for the `tag serve` planning daemon: real
//! TCP connections against a daemon on an ephemeral port, exercising
//! the serving guarantees the README states — coalescing of concurrent
//! identical requests into one search with byte-identical responses,
//! HTTP/1.1 keep-alive (sequential and pipelined requests on one
//! connection, idle reaping, per-connection request caps), warm boots
//! from the persistent plan store, the shared GNN backend under
//! concurrency, live `/metrics`, bounded-queue load shedding with
//! `503`, and graceful drain on shutdown.  Zero non-std dependencies,
//! clients included.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::{Arc, Barrier};
use std::time::Duration;

use tag::api::{DeploymentPlan, SharedPlanner};
use tag::serve::{ServeConfig, Server};

/// Start a daemon with an explicit config (the port is forced
/// ephemeral); returns its address and the `run()` thread handle
/// (joins clean after `POST /shutdown`).
fn start_with(
    config: ServeConfig,
    planner: SharedPlanner,
) -> (SocketAddr, std::thread::JoinHandle<()>) {
    let config = ServeConfig { port: 0, ..config };
    let server = Server::bind(config, planner).expect("bind");
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || server.run().expect("serve"));
    (addr, handle)
}

fn start_server(workers: usize, queue_depth: usize) -> (SocketAddr, std::thread::JoinHandle<()>) {
    start_with(
        ServeConfig {
            workers,
            queue_depth,
            read_timeout: Duration::from_secs(10),
            ..ServeConfig::default()
        },
        SharedPlanner::builder().build(),
    )
}

/// Minimal one-shot HTTP/1.1 client: sends `Connection: close` and
/// reads to EOF.  Returns (status, headers, body).
fn http(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> (u16, String, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut raw = format!("{method} {path} HTTP/1.1\r\nconnection: close\r\n");
    if let Some(body) = body {
        raw.push_str(&format!("content-length: {}\r\n", body.len()));
    }
    raw.push_str("\r\n");
    if let Some(body) = body {
        raw.push_str(body);
    }
    stream.write_all(raw.as_bytes()).expect("send");
    let mut response = String::new();
    stream.read_to_string(&mut response).expect("recv");
    let (head, body) = response.split_once("\r\n\r\n").expect("framed response");
    let status: u16 = head
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line in {head:?}"));
    (status, head.to_ascii_lowercase(), body.to_string())
}

fn post_plan(addr: SocketAddr, body: &str) -> (u16, String) {
    let (status, _, response) = http(addr, "POST", "/plan", Some(body));
    (status, response)
}

/// A persistent (keep-alive) client: many requests on one connection,
/// each response read by its `Content-Length` framing.
struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Self {
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        let reader = BufReader::new(stream.try_clone().expect("clone stream"));
        Self { stream, reader }
    }

    fn send_raw(&mut self, raw: &[u8]) {
        self.stream.write_all(raw).expect("send");
    }

    fn send(&mut self, method: &str, path: &str, body: Option<&str>) {
        let mut raw = format!("{method} {path} HTTP/1.1\r\n");
        if let Some(body) = body {
            raw.push_str(&format!("content-length: {}\r\n", body.len()));
        }
        raw.push_str("\r\n");
        if let Some(body) = body {
            raw.push_str(body);
        }
        self.send_raw(raw.as_bytes());
    }

    /// Read one framed response: (status, lowercased head, body).
    fn read_response(&mut self) -> (u16, String, String) {
        let mut head = String::new();
        loop {
            let mut line = String::new();
            let n = self.reader.read_line(&mut line).expect("read head");
            assert!(n > 0, "connection closed mid-head (after {head:?})");
            if line == "\r\n" {
                break;
            }
            head.push_str(&line);
        }
        let head = head.to_ascii_lowercase();
        let status: u16 = head
            .split(' ')
            .nth(1)
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(|| panic!("bad status line in {head:?}"));
        let len: usize = head
            .lines()
            .find_map(|l| l.strip_prefix("content-length:"))
            .and_then(|v| v.trim().parse().ok())
            .unwrap_or_else(|| panic!("no content-length in {head:?}"));
        let mut body = vec![0u8; len];
        self.reader.read_exact(&mut body).expect("read body");
        (status, head, String::from_utf8(body).expect("utf-8 body"))
    }

    /// The server closed its end: the next read sees EOF.
    fn assert_eof(&mut self) {
        let mut rest = String::new();
        self.reader.read_to_string(&mut rest).expect("read eof");
        assert!(rest.is_empty(), "unexpected trailing bytes: {rest:?}");
    }
}

/// Pull a `name value` line out of the `/metrics` exposition.
fn metric(addr: SocketAddr, name: &str) -> f64 {
    let (status, _, text) = http(addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    text.lines()
        .find_map(|line| {
            let (n, v) = line.rsplit_once(' ')?;
            if n == name {
                v.parse().ok()
            } else {
                None
            }
        })
        .unwrap_or_else(|| panic!("metric {name} missing from:\n{text}"))
}

fn shutdown(addr: SocketAddr) {
    // The queue may still be draining; retry through transient 503s.
    for _ in 0..600 {
        let (status, _, _) = http(addr, "POST", "/shutdown", None);
        if status == 200 {
            return;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    panic!("shutdown never accepted");
}

/// Fresh per-test scratch directory under the system temp dir.
fn tempdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tag-serve-test-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

const SMALL_PLAN: &str = r#"{"model":"VGG19","iterations":30,"max_groups":10,"seed":3}"#;

#[test]
fn health_metrics_and_unknown_routes() {
    let (addr, handle) = start_server(2, 16);
    let (status, _, body) = http(addr, "GET", "/healthz", None);
    assert_eq!(status, 200);
    assert!(body.contains("\"status\":\"ok\""), "{body}");
    assert!(body.contains("\"workers\":2"), "{body}");
    assert!(body.contains("\"panics_total\":0"), "{body}");
    let (status, head, _) = http(addr, "GET", "/plan", None);
    assert_eq!(status, 405);
    assert!(head.contains("allow: post"), "{head}");
    let (status, _, _) = http(addr, "GET", "/nowhere", None);
    assert_eq!(status, 404);
    assert_eq!(metric(addr, "tag_requests_total{endpoint=\"/healthz\"}"), 1.0);
    shutdown(addr);
    handle.join().unwrap();
}

#[test]
fn concurrent_identical_requests_coalesce_to_one_search_with_identical_bytes() {
    let (addr, handle) = start_server(4, 32);
    const CLIENTS: usize = 6;
    let barrier = Arc::new(Barrier::new(CLIENTS));
    let responses: Vec<(u16, String)> = (0..CLIENTS)
        .map(|_| {
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                barrier.wait();
                post_plan(addr, SMALL_PLAN)
            })
        })
        .collect::<Vec<_>>()
        .into_iter()
        .map(|h| h.join().unwrap())
        .collect();

    let (status, first_body) = &responses[0];
    assert_eq!(*status, 200, "{first_body}");
    for (status, body) in &responses {
        assert_eq!(*status, 200);
        assert_eq!(body, first_body, "coalesced/cached responses are byte-identical");
    }
    let plan = DeploymentPlan::decode(first_body).expect("valid plan JSON");
    assert_eq!(plan.model_name, "VGG19");
    assert_eq!(plan.telemetry.seed, 3);

    // Scraped FIRST: each `/metrics` scrape is itself a 200 response
    // (counted after its render), so only the very first scrape after
    // the burst sees exactly the burst's responses.
    assert_eq!(metric(addr, "tag_responses_total{status=\"200\"}"), CLIENTS as f64);

    // Exactly one search happened for the whole burst: every other
    // request either joined the in-flight search (coalesced) or hit
    // the plan cache after it landed.  This invariant is
    // schedule-independent — only the coalesced/hit split varies.
    assert_eq!(metric(addr, "tag_searches_total"), 1.0);
    assert_eq!(metric(addr, "tag_plan_cache_misses"), 1.0);
    let coalesced = metric(addr, "tag_coalesced_total");
    let cache_hits = metric(addr, "tag_plan_cache_hits");
    assert_eq!(
        coalesced + cache_hits,
        (CLIENTS - 1) as f64,
        "every non-leader was answered without a search"
    );
    assert!(metric(addr, "tag_plan_cache_hit_rate") > 0.0 || coalesced >= 5.0);

    shutdown(addr);
    handle.join().unwrap();
}

#[test]
fn distinct_requests_produce_distinct_plans() {
    let (addr, handle) = start_server(2, 16);
    let (s1, body1) = post_plan(addr, SMALL_PLAN);
    let (s2, body2) = post_plan(
        addr,
        r#"{"model":"VGG19","iterations":30,"max_groups":10,"seed":4}"#,
    );
    assert_eq!((s1, s2), (200, 200));
    let p1 = DeploymentPlan::decode(&body1).unwrap();
    let p2 = DeploymentPlan::decode(&body2).unwrap();
    assert_ne!(p1.config_fingerprint, p2.config_fingerprint, "seeds partition plans");
    assert_eq!(p1.model_fingerprint, p2.model_fingerprint, "same model resolution");
    assert_eq!(metric(addr, "tag_searches_total"), 2.0);
    shutdown(addr);
    handle.join().unwrap();
}

#[test]
fn malformed_plan_bodies_are_rejected_and_the_daemon_survives() {
    let (addr, handle) = start_server(1, 16);
    for bad in [
        "not json at all",
        r#"{"model":"NoSuchNet"}"#,
        r#"{"model":"VGG19","turbo":true}"#,
        r#"{"model":"VGG19","iterations":999999999}"#,
    ] {
        let (status, body) = post_plan(addr, bad);
        assert_eq!(status, 400, "{bad} -> {body}");
    }
    let (status, body) = post_plan(addr, SMALL_PLAN);
    assert_eq!(status, 200, "daemon still serves after rejections: {body}");
    shutdown(addr);
    handle.join().unwrap();
}

#[test]
fn keep_alive_serves_sequential_requests_byte_identical_to_fresh_connections() {
    let (addr, handle) = start_server(2, 16);
    let mut client = Client::connect(addr);
    let mut bodies = Vec::new();
    for i in 0..3 {
        client.send("POST", "/plan", Some(SMALL_PLAN));
        let (status, head, body) = client.read_response();
        assert_eq!(status, 200, "request {i}: {body}");
        assert!(head.contains("connection: keep-alive"), "request {i}: {head}");
        bodies.push(body);
    }
    assert_eq!(bodies[0], bodies[1]);
    assert_eq!(bodies[1], bodies[2]);
    // A fresh one-shot connection sees the same bytes: the transport
    // (keep-alive vs close) never leaks into the payload.
    let (status, fresh) = post_plan(addr, SMALL_PLAN);
    assert_eq!(status, 200);
    assert_eq!(fresh, bodies[0], "keep-alive and one-shot responses are byte-identical");
    // One search served all four: the rest were cache hits.
    assert_eq!(metric(addr, "tag_searches_total"), 1.0);
    drop(client);
    shutdown(addr);
    handle.join().unwrap();
}

#[test]
fn pipelined_requests_are_answered_in_order() {
    let (addr, handle) = start_server(1, 16);
    let mut client = Client::connect(addr);
    // Both requests in one write; one worker answers them in order
    // because responses are Content-Length framed and the second
    // request waits in the connection's BufReader.
    client.send_raw(b"GET /healthz HTTP/1.1\r\n\r\nGET /nowhere HTTP/1.1\r\n\r\n");
    let (status, _, body) = client.read_response();
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"status\":\"ok\""), "{body}");
    let (status, _, _) = client.read_response();
    assert_eq!(status, 404, "second pipelined response, in order");
    drop(client);
    shutdown(addr);
    handle.join().unwrap();
}

#[test]
fn request_head_split_across_writes_still_parses() {
    let (addr, handle) = start_server(1, 16);
    let mut client = Client::connect(addr);
    client.send_raw(b"GET /heal");
    std::thread::sleep(Duration::from_millis(50));
    client.send_raw(b"thz HTTP/1.1\r\nconnect");
    std::thread::sleep(Duration::from_millis(50));
    client.send_raw(b"ion: close\r\n\r\n");
    let (status, head, body) = client.read_response();
    assert_eq!(status, 200, "{body}");
    assert!(head.contains("connection: close"), "{head}");
    client.assert_eof();
    shutdown(addr);
    handle.join().unwrap();
}

#[test]
fn connection_close_token_is_case_insensitive() {
    let (addr, handle) = start_server(1, 16);
    let mut client = Client::connect(addr);
    client.send_raw(b"GET /healthz HTTP/1.1\r\nConnection: CLOSE\r\n\r\n");
    let (status, head, _) = client.read_response();
    assert_eq!(status, 200);
    assert!(head.contains("connection: close"), "{head}");
    client.assert_eof();
    shutdown(addr);
    handle.join().unwrap();
}

#[test]
fn http10_defaults_to_close_and_idle_connections_are_reaped() {
    let (addr, handle) = start_with(
        ServeConfig {
            workers: 2,
            queue_depth: 16,
            read_timeout: Duration::from_millis(300),
            ..ServeConfig::default()
        },
        SharedPlanner::builder().build(),
    );

    // HTTP/1.0 without an explicit keep-alive token closes.
    let mut client = Client::connect(addr);
    client.send_raw(b"GET /healthz HTTP/1.0\r\n\r\n");
    let (status, head, _) = client.read_response();
    assert_eq!(status, 200);
    assert!(head.contains("connection: close"), "{head}");
    client.assert_eof();

    // A connection that never sends a request is reaped silently after
    // the idle timeout: no 408, no bytes, just EOF.
    let mut silent = Client::connect(addr);
    silent.assert_eof();

    // A keep-alive connection is reaped after one idle timeout between
    // requests — the first request is still answered normally.
    let mut idle = Client::connect(addr);
    idle.send("GET", "/healthz", None);
    let (status, head, _) = idle.read_response();
    assert_eq!(status, 200);
    assert!(head.contains("connection: keep-alive"), "{head}");
    idle.assert_eof();

    shutdown(addr);
    handle.join().unwrap();
}

#[test]
fn per_connection_request_cap_is_enforced() {
    let (addr, handle) = start_with(
        ServeConfig {
            workers: 1,
            queue_depth: 16,
            max_requests_per_conn: 2,
            read_timeout: Duration::from_secs(10),
            ..ServeConfig::default()
        },
        SharedPlanner::builder().build(),
    );
    let mut client = Client::connect(addr);
    // Three pipelined requests: the cap closes the connection after
    // the second response; the third request is never read.
    client.send_raw(
        b"GET /healthz HTTP/1.1\r\n\r\nGET /healthz HTTP/1.1\r\n\r\nGET /healthz HTTP/1.1\r\n\r\n",
    );
    let (status, head, _) = client.read_response();
    assert_eq!(status, 200);
    assert!(head.contains("connection: keep-alive"), "{head}");
    let (status, head, _) = client.read_response();
    assert_eq!(status, 200);
    assert!(head.contains("connection: close"), "cap reached: {head}");
    client.assert_eof();
    shutdown(addr);
    handle.join().unwrap();
}

#[test]
fn duplicate_content_length_headers_are_rejected() {
    let (addr, handle) = start_server(1, 16);
    let mut client = Client::connect(addr);
    client.send_raw(
        b"POST /plan HTTP/1.1\r\ncontent-length: 4\r\nContent-Length: 4\r\n\r\nabcd",
    );
    let (status, head, body) = client.read_response();
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("duplicate Content-Length"), "{body}");
    assert!(head.contains("connection: close"), "framing errors close: {head}");
    client.assert_eof();
    shutdown(addr);
    handle.join().unwrap();
}

#[test]
fn warm_store_restart_answers_previously_planned_requests_without_searching() {
    let dir = tempdir("warm-restart");
    let store_dir = dir.to_string_lossy().to_string();
    let config = ServeConfig {
        workers: 2,
        queue_depth: 16,
        read_timeout: Duration::from_secs(10),
        store_dir: Some(store_dir),
        ..ServeConfig::default()
    };

    // First daemon lifetime: plan once, journaling the result.
    let (addr, handle) = start_with(config.clone(), SharedPlanner::builder().build());
    let (status, first_body) = post_plan(addr, SMALL_PLAN);
    assert_eq!(status, 200, "{first_body}");
    assert_eq!(metric(addr, "tag_searches_total"), 1.0);
    assert_eq!(metric(addr, "tag_plan_store_appends"), 1.0);
    assert_eq!(metric(addr, "tag_plan_store_entries"), 1.0);
    shutdown(addr);
    handle.join().unwrap();

    // Second daemon lifetime, same directory: the journal warms the
    // cache at boot, so the identical request is a pure cache hit —
    // no search executed, byte-identical body.
    let (addr, handle) = start_with(config, SharedPlanner::builder().build());
    assert_eq!(metric(addr, "tag_plan_store_loads"), 1.0);
    let (status, warm_body) = post_plan(addr, SMALL_PLAN);
    assert_eq!(status, 200, "{warm_body}");
    assert_eq!(warm_body, first_body, "warm-boot responses are byte-identical");
    assert_eq!(metric(addr, "tag_searches_total"), 0.0, "no search after a warm boot");
    assert_eq!(metric(addr, "tag_plan_cache_hits"), 1.0);
    shutdown(addr);
    handle.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_store_tail_never_fails_boot_and_good_records_stay_warm() {
    let dir = tempdir("corrupt-tail");
    let store_dir = dir.to_string_lossy().to_string();
    let config = ServeConfig {
        workers: 2,
        queue_depth: 16,
        read_timeout: Duration::from_secs(10),
        store_dir: Some(store_dir),
        ..ServeConfig::default()
    };

    let (addr, handle) = start_with(config.clone(), SharedPlanner::builder().build());
    let (status, first_body) = post_plan(addr, SMALL_PLAN);
    assert_eq!(status, 200, "{first_body}");
    shutdown(addr);
    handle.join().unwrap();

    // Tear the journal tail, as a crash mid-append would.
    let journal = dir.join("plans.journal");
    let mut file = std::fs::OpenOptions::new().append(true).open(&journal).unwrap();
    file.write_all(b"tagplan1 torn-by-a-crash").unwrap();
    drop(file);

    // The daemon boots anyway: the corrupt tail is dropped and
    // counted, the good record still warms the cache.
    let (addr, handle) = start_with(config, SharedPlanner::builder().build());
    assert_eq!(metric(addr, "tag_plan_store_corrupt_total"), 1.0);
    assert_eq!(metric(addr, "tag_plan_store_loads"), 1.0);
    let (status, warm_body) = post_plan(addr, SMALL_PLAN);
    assert_eq!(status, 200);
    assert_eq!(warm_body, first_body);
    assert_eq!(metric(addr, "tag_searches_total"), 0.0);
    shutdown(addr);
    handle.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gnn_backend_serves_concurrent_plan_requests_through_the_pool() {
    // Stub artifacts: enough for `GnnService::load` (manifest, params,
    // HLO text files); inference itself runs on the PJRT stub and
    // degrades to uniform priors, which is exactly the serving path —
    // the point here is one `Send + Sync` backend shared by the whole
    // worker pool over real TCP.
    let dir = tempdir("gnn-artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.txt"),
        "const PARAM_COUNT 8\ninput infer 0 params 8\ninput train 0 params 8\n",
    )
    .unwrap();
    tag::gnn::params::save_params(dir.join("params_init.bin"), &[0.1f32; 8]).unwrap();
    std::fs::write(dir.join("gnn_infer.hlo.txt"), "HloModule stub_infer\n").unwrap();
    std::fs::write(dir.join("gnn_train.hlo.txt"), "HloModule stub_train\n").unwrap();

    let backend = tag::api::GnnMctsBackend::from_artifacts(
        &dir.to_string_lossy(),
        &dir.join("params_init.bin").to_string_lossy(),
    )
    .expect("stub artifacts load");
    let planner = SharedPlanner::builder().backend(backend).build();
    let (addr, handle) = start_with(
        ServeConfig {
            workers: 4,
            queue_depth: 16,
            read_timeout: Duration::from_secs(10),
            ..ServeConfig::default()
        },
        planner,
    );

    const CLIENTS: usize = 4;
    let barrier = Arc::new(Barrier::new(CLIENTS));
    let responses: Vec<(u16, String)> = (0..CLIENTS)
        .map(|seed| {
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                barrier.wait();
                post_plan(
                    addr,
                    &format!(
                        r#"{{"model":"VGG19","iterations":25,"max_groups":8,"seed":{}}}"#,
                        100 + seed
                    ),
                )
            })
        })
        .collect::<Vec<_>>()
        .into_iter()
        .map(|h| h.join().unwrap())
        .collect();

    for (status, body) in &responses {
        assert_eq!(*status, 200, "{body}");
        let plan = DeploymentPlan::decode(body).expect("valid plan");
        assert_eq!(plan.backend, "gnn-mcts", "the learned backend served this plan");
        assert!(plan.telemetry.metric("gnn_evals").unwrap_or(0.0) > 0.0, "{body}");
    }
    assert_eq!(metric(addr, "tag_searches_total"), CLIENTS as f64, "distinct seeds");

    shutdown(addr);
    handle.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn saturated_queue_sheds_with_503_and_retry_after() {
    // One worker, queue depth one.  Two idle connections occupy the
    // worker (blocked reading) and the queue slot; the next connection
    // must be shed at the door without being read.
    let (addr, handle) = start_server(1, 1);
    let hold_worker = TcpStream::connect(addr).unwrap();
    std::thread::sleep(Duration::from_millis(150)); // worker picks it up
    let hold_queue = TcpStream::connect(addr).unwrap();
    std::thread::sleep(Duration::from_millis(150)); // fills the queue

    let (status, head, body) = http(addr, "GET", "/healthz", None);
    assert_eq!(status, 503, "{body}");
    // The hint is derived from the live queue: base 1s + ceil(1 queued
    // / 1 worker) = 2, not the constant the config started from.
    assert!(head.contains("retry-after: 2"), "shed responses advertise derived retry: {head}");

    // Release the worker and the queue; the daemon recovers.  (While
    // saturated even `/metrics` would be shed, so the authoritative
    // shed count is scraped after the drain.)
    drop(hold_worker);
    drop(hold_queue);
    let mut ok = false;
    for _ in 0..200 {
        let (status, _, _) = http(addr, "GET", "/healthz", None);
        if status == 200 {
            ok = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    assert!(ok, "daemon recovers after the queue drains");
    assert!(metric(addr, "tag_shed_total") >= 1.0, "shed connections are counted");
    shutdown(addr);
    handle.join().unwrap();
}

#[test]
fn debug_trace_and_explain_round_trip_over_http() {
    use tag::api::json::Json;

    let (addr, handle) = start_server(2, 16);
    let (status, plan_body) = post_plan(addr, SMALL_PLAN);
    assert_eq!(status, 200, "{plan_body}");

    // The plan request was traced into the flight recorder; the export
    // must be valid Chrome trace-event JSON whose spans nest correctly.
    let (status, _, text) = http(addr, "GET", "/debug/trace", None);
    assert_eq!(status, 200);
    let export = Json::parse(&text).expect("trace export parses as JSON");
    let events = export.field("traceEvents").unwrap().as_arr().unwrap();

    struct Span {
        pid: u64,
        tid: u64,
        depth: u64,
        start: f64,
        end: f64,
        dur: f64,
    }
    let mut spans = Vec::new();
    for e in events {
        if e.get("ph").and_then(|p| p.as_str().ok()) != Some("X") {
            continue;
        }
        let ts = e.field("ts").unwrap().as_f64().unwrap();
        let dur = e.field("dur").unwrap().as_f64().unwrap();
        spans.push(Span {
            pid: e.field("pid").unwrap().as_u64().unwrap(),
            tid: e.field("tid").unwrap().as_u64().unwrap(),
            depth: e.field("args").and_then(|a| a.field("depth")).unwrap().as_u64().unwrap(),
            start: ts,
            end: ts + dur,
            dur,
        });
    }
    assert!(!spans.is_empty(), "no complete events in {text}");

    // Spans on one thread nest by interval containment: every span at
    // depth d > 0 sits inside a depth d-1 span on its (pid, tid), and
    // each thread's root covers at least the sum of its direct
    // children's durations (same-depth spans are disjoint by stack
    // discipline).
    const EPS: f64 = 1e-6;
    for s in spans.iter().filter(|s| s.depth > 0) {
        let nested = spans.iter().any(|p| {
            (p.pid, p.tid) == (s.pid, s.tid)
                && p.depth + 1 == s.depth
                && p.start <= s.start + EPS
                && s.end <= p.end + EPS
        });
        assert!(nested, "depth-{} span [{}, {}] has no enclosing parent", s.depth, s.start, s.end);
    }
    for root in spans.iter().filter(|s| s.depth == 0) {
        let child_sum: f64 = spans
            .iter()
            .filter(|s| (s.pid, s.tid, s.depth) == (root.pid, root.tid, 1))
            .map(|s| s.dur)
            .sum();
        assert!(
            root.dur + EPS >= child_sum,
            "root span ({} µs) shorter than its children combined ({child_sum} µs)",
            root.dur
        );
    }
    assert!(metric(addr, "tag_traces_recorded_total") >= 1.0);

    // `POST /explain` re-simulates the served plan deterministically.
    let explain_body = format!(
        r#"{{"model":"VGG19","iterations":30,"max_groups":10,"seed":3,"plan":{plan_body}}}"#
    );
    let (status, _, report) = http(addr, "POST", "/explain", Some(&explain_body));
    assert_eq!(status, 200, "{report}");
    let report = Json::parse(&report).expect("explain report parses");
    assert!(report.field("reproduces_reported_time").unwrap().as_bool().unwrap());
    assert!(
        report
            .field("critical_path")
            .and_then(|cp| cp.field("attributed_fraction"))
            .unwrap()
            .as_f64()
            .unwrap()
            >= 0.95
    );

    shutdown(addr);
    handle.join().unwrap();
}

#[test]
fn graceful_shutdown_drains_in_flight_and_queued_requests() {
    let (addr, handle) = start_server(2, 16);
    // Three searches with distinct seeds (no coalescing): more work
    // than workers, so at least one request is queued when shutdown
    // arrives.
    let requests: Vec<_> = (10..13)
        .map(|seed| {
            std::thread::spawn(move || {
                post_plan(
                    addr,
                    &format!(
                        r#"{{"model":"VGG19","iterations":30,"max_groups":10,"seed":{seed}}}"#
                    ),
                )
            })
        })
        .collect();
    std::thread::sleep(Duration::from_millis(200)); // all admitted
    shutdown(addr);
    // Every admitted request still gets a full answer during the drain.
    for request in requests {
        let (status, body) = request.join().unwrap();
        assert_eq!(status, 200, "drained request answered: {body}");
        assert!(DeploymentPlan::decode(&body).is_ok());
    }
    handle.join().unwrap();
    // The listener is gone: new connections are refused.
    assert!(
        TcpStream::connect_timeout(&addr, Duration::from_millis(500)).is_err(),
        "daemon no longer accepts connections"
    );
}
