//! End-to-end loopback tests for the `tag serve` planning daemon: real
//! TCP connections against a daemon on an ephemeral port, exercising
//! the serving guarantees the README states — coalescing of concurrent
//! identical requests into one search with byte-identical responses,
//! live `/metrics`, bounded-queue load shedding with `503`, and
//! graceful drain on shutdown.  Zero non-std dependencies, clients
//! included.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Barrier};
use std::time::Duration;

use tag::api::{DeploymentPlan, SharedPlanner};
use tag::serve::{ServeConfig, Server};

/// Start a daemon on an ephemeral port; returns its address and the
/// `run()` thread handle (joins clean after `POST /shutdown`).
fn start_server(workers: usize, queue_depth: usize) -> (SocketAddr, std::thread::JoinHandle<()>) {
    let config = ServeConfig {
        port: 0,
        workers,
        queue_depth,
        read_timeout: Duration::from_secs(10),
        ..ServeConfig::default()
    };
    let server = Server::bind(config, SharedPlanner::builder().build()).expect("bind");
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || server.run().expect("serve"));
    (addr, handle)
}

/// Minimal HTTP/1.1 client: one request, read to EOF (the daemon
/// closes every connection).  Returns (status, headers, body).
fn http(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> (u16, String, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut raw = format!("{method} {path} HTTP/1.1\r\n");
    if let Some(body) = body {
        raw.push_str(&format!("content-length: {}\r\n", body.len()));
    }
    raw.push_str("\r\n");
    if let Some(body) = body {
        raw.push_str(body);
    }
    stream.write_all(raw.as_bytes()).expect("send");
    let mut response = String::new();
    stream.read_to_string(&mut response).expect("recv");
    let (head, body) = response.split_once("\r\n\r\n").expect("framed response");
    let status: u16 = head
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line in {head:?}"));
    (status, head.to_ascii_lowercase(), body.to_string())
}

fn post_plan(addr: SocketAddr, body: &str) -> (u16, String) {
    let (status, _, response) = http(addr, "POST", "/plan", Some(body));
    (status, response)
}

/// Pull a `name value` line out of the `/metrics` exposition.
fn metric(addr: SocketAddr, name: &str) -> f64 {
    let (status, _, text) = http(addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    text.lines()
        .find_map(|line| {
            let (n, v) = line.rsplit_once(' ')?;
            if n == name {
                v.parse().ok()
            } else {
                None
            }
        })
        .unwrap_or_else(|| panic!("metric {name} missing from:\n{text}"))
}

fn shutdown(addr: SocketAddr) {
    // The queue may still be draining; retry through transient 503s.
    for _ in 0..600 {
        let (status, _, _) = http(addr, "POST", "/shutdown", None);
        if status == 200 {
            return;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    panic!("shutdown never accepted");
}

const SMALL_PLAN: &str = r#"{"model":"VGG19","iterations":30,"max_groups":10,"seed":3}"#;

#[test]
fn health_metrics_and_unknown_routes() {
    let (addr, handle) = start_server(2, 16);
    let (status, _, body) = http(addr, "GET", "/healthz", None);
    assert_eq!(status, 200);
    assert!(body.contains("\"status\":\"ok\""), "{body}");
    assert!(body.contains("\"workers\":2"), "{body}");
    assert!(body.contains("\"panics_total\":0"), "{body}");
    let (status, head, _) = http(addr, "GET", "/plan", None);
    assert_eq!(status, 405);
    assert!(head.contains("allow: post"), "{head}");
    let (status, _, _) = http(addr, "GET", "/nowhere", None);
    assert_eq!(status, 404);
    assert_eq!(metric(addr, "tag_requests_total{endpoint=\"/healthz\"}"), 1.0);
    shutdown(addr);
    handle.join().unwrap();
}

#[test]
fn concurrent_identical_requests_coalesce_to_one_search_with_identical_bytes() {
    let (addr, handle) = start_server(4, 32);
    const CLIENTS: usize = 6;
    let barrier = Arc::new(Barrier::new(CLIENTS));
    let responses: Vec<(u16, String)> = (0..CLIENTS)
        .map(|_| {
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                barrier.wait();
                post_plan(addr, SMALL_PLAN)
            })
        })
        .collect::<Vec<_>>()
        .into_iter()
        .map(|h| h.join().unwrap())
        .collect();

    let (status, first_body) = &responses[0];
    assert_eq!(*status, 200, "{first_body}");
    for (status, body) in &responses {
        assert_eq!(*status, 200);
        assert_eq!(body, first_body, "coalesced/cached responses are byte-identical");
    }
    let plan = DeploymentPlan::decode(first_body).expect("valid plan JSON");
    assert_eq!(plan.model_name, "VGG19");
    assert_eq!(plan.telemetry.seed, 3);

    // Scraped FIRST: each `/metrics` scrape is itself a 200 response
    // (counted after its render), so only the very first scrape after
    // the burst sees exactly the burst's responses.
    assert_eq!(metric(addr, "tag_responses_total{status=\"200\"}"), CLIENTS as f64);

    // Exactly one search happened for the whole burst: every other
    // request either joined the in-flight search (coalesced) or hit
    // the plan cache after it landed.  This invariant is
    // schedule-independent — only the coalesced/hit split varies.
    assert_eq!(metric(addr, "tag_searches_total"), 1.0);
    assert_eq!(metric(addr, "tag_plan_cache_misses"), 1.0);
    let coalesced = metric(addr, "tag_coalesced_total");
    let cache_hits = metric(addr, "tag_plan_cache_hits");
    assert_eq!(
        coalesced + cache_hits,
        (CLIENTS - 1) as f64,
        "every non-leader was answered without a search"
    );
    assert!(metric(addr, "tag_plan_cache_hit_rate") > 0.0 || coalesced >= 5.0);

    shutdown(addr);
    handle.join().unwrap();
}

#[test]
fn distinct_requests_produce_distinct_plans() {
    let (addr, handle) = start_server(2, 16);
    let (s1, body1) = post_plan(addr, SMALL_PLAN);
    let (s2, body2) = post_plan(
        addr,
        r#"{"model":"VGG19","iterations":30,"max_groups":10,"seed":4}"#,
    );
    assert_eq!((s1, s2), (200, 200));
    let p1 = DeploymentPlan::decode(&body1).unwrap();
    let p2 = DeploymentPlan::decode(&body2).unwrap();
    assert_ne!(p1.config_fingerprint, p2.config_fingerprint, "seeds partition plans");
    assert_eq!(p1.model_fingerprint, p2.model_fingerprint, "same model resolution");
    assert_eq!(metric(addr, "tag_searches_total"), 2.0);
    shutdown(addr);
    handle.join().unwrap();
}

#[test]
fn malformed_plan_bodies_are_rejected_and_the_daemon_survives() {
    let (addr, handle) = start_server(1, 16);
    for bad in [
        "not json at all",
        r#"{"model":"NoSuchNet"}"#,
        r#"{"model":"VGG19","turbo":true}"#,
        r#"{"model":"VGG19","iterations":999999999}"#,
    ] {
        let (status, body) = post_plan(addr, bad);
        assert_eq!(status, 400, "{bad} -> {body}");
    }
    let (status, body) = post_plan(addr, SMALL_PLAN);
    assert_eq!(status, 200, "daemon still serves after rejections: {body}");
    shutdown(addr);
    handle.join().unwrap();
}

#[test]
fn saturated_queue_sheds_with_503_and_retry_after() {
    // One worker, queue depth one.  Two idle connections occupy the
    // worker (blocked reading) and the queue slot; the next connection
    // must be shed at the door without being read.
    let (addr, handle) = start_server(1, 1);
    let hold_worker = TcpStream::connect(addr).unwrap();
    std::thread::sleep(Duration::from_millis(150)); // worker picks it up
    let hold_queue = TcpStream::connect(addr).unwrap();
    std::thread::sleep(Duration::from_millis(150)); // fills the queue

    let (status, head, body) = http(addr, "GET", "/healthz", None);
    assert_eq!(status, 503, "{body}");
    // The hint is derived from the live queue: base 1s + ceil(1 queued
    // / 1 worker) = 2, not the constant the config started from.
    assert!(head.contains("retry-after: 2"), "shed responses advertise derived retry: {head}");

    // Release the worker and the queue; the daemon recovers.  (While
    // saturated even `/metrics` would be shed, so the authoritative
    // shed count is scraped after the drain.)
    drop(hold_worker);
    drop(hold_queue);
    let mut ok = false;
    for _ in 0..200 {
        let (status, _, _) = http(addr, "GET", "/healthz", None);
        if status == 200 {
            ok = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    assert!(ok, "daemon recovers after the queue drains");
    assert!(metric(addr, "tag_shed_total") >= 1.0, "shed connections are counted");
    shutdown(addr);
    handle.join().unwrap();
}

#[test]
fn graceful_shutdown_drains_in_flight_and_queued_requests() {
    let (addr, handle) = start_server(2, 16);
    // Three searches with distinct seeds (no coalescing): more work
    // than workers, so at least one request is queued when shutdown
    // arrives.
    let requests: Vec<_> = (10..13)
        .map(|seed| {
            std::thread::spawn(move || {
                post_plan(
                    addr,
                    &format!(
                        r#"{{"model":"VGG19","iterations":30,"max_groups":10,"seed":{seed}}}"#
                    ),
                )
            })
        })
        .collect();
    std::thread::sleep(Duration::from_millis(200)); // all admitted
    shutdown(addr);
    // Every admitted request still gets a full answer during the drain.
    for request in requests {
        let (status, body) = request.join().unwrap();
        assert_eq!(status, 200, "drained request answered: {body}");
        assert!(DeploymentPlan::decode(&body).is_ok());
    }
    handle.join().unwrap();
    // The listener is gone: new connections are refused.
    assert!(
        TcpStream::connect_timeout(&addr, Duration::from_millis(500)).is_err(),
        "daemon no longer accepts connections"
    );
}
