//! API-surface integration tests: planner determinism (with and without
//! the plan cache), lossless plan JSON round-trips, full baseline
//! coverage on the paper's preset topologies, the flat-matrix ⇒
//! clique-link-graph equivalence contract, and the hierarchical
//! (routed, contention-aware) planning path.

use tag::api::{
    fingerprint, BaselineSweepBackend, DeploymentPlan, MctsBackend, PlanRequest,
    Planner, BASELINE_NAMES,
};
use tag::cluster::presets::{
    cloud, homogeneous, multi_rack, nvlink_island, sfb_pair, testbed,
};
use tag::cluster::Topology;
use tag::coordinator::{prepare, SearchConfig};
use tag::dist::Lowering;
use tag::mcts::{Mcts, UniformPrior};
use tag::models;
use tag::search::{run_search, Parallelism, SearchProblem};
use tag::strategy::{baselines, enumerate_actions, Strategy};

fn request(seed: u64) -> PlanRequest {
    PlanRequest::new(models::vgg19(8, 0.25), testbed()).budget(40, 12).seed(seed)
}

#[test]
fn plans_are_deterministic_with_cache_on_and_off() {
    // Cache off: two independent searches must agree bit-for-bit.
    let cold = Planner::builder().without_cache().build();
    let a = cold.plan(&request(3)).unwrap();
    let b = cold.plan(&request(3)).unwrap();
    assert!(!a.cache_hit && !b.cache_hit);
    assert_eq!(a.plan, b.plan);

    // Cache on: the served copy is the same plan again.
    let warm = Planner::builder().build();
    let c = warm.plan(&request(3)).unwrap();
    let d = warm.plan(&request(3)).unwrap();
    assert!(!c.cache_hit && d.cache_hit);
    assert_eq!(c.plan, d.plan);

    // Across planners and cache modes: still identical.
    assert_eq!(a.plan, c.plan);

    // And so is the serialized form (byte-level determinism).
    assert_eq!(a.plan.encode(), d.plan.encode());
}

/// The pre-link-graph topology fingerprint, reimplemented verbatim:
/// group inventory + flat matrix, nothing else.  Clique topologies must
/// keep exactly this fingerprint so every plan cached before the
/// refactor stays addressable.
fn flat_fingerprint_reference(topo: &Topology) -> u64 {
    let mut h = fingerprint::Fnv::new();
    h.write_usize(topo.num_groups());
    for g in &topo.groups {
        h.write_str(g.gpu.name);
        h.write_f64(g.gpu.peak_tflops);
        h.write_f64(g.gpu.efficiency);
        h.write_f64(g.gpu.mem_gb);
        h.write_usize(g.count);
        h.write_f64(g.intra_bw_gbps);
    }
    for row in &topo.inter_bw_gbps {
        for &bw in row {
            h.write_f64(bw);
        }
    }
    h.finish()
}

#[test]
fn clique_link_graph_reproduces_the_flat_matrix_bit_for_bit() {
    // The equivalence contract of the link-graph refactor: for every
    // preset, (1) routed bandwidth queries reproduce the flat matrix /
    // intra lookups exactly, (2) the O(n²) bottleneck agrees with an
    // inline flat reference, (3) clique routes add no hops or latency,
    // and (4) the topology fingerprint is byte-identical to the
    // pre-refactor scheme.
    for topo in [testbed(), cloud(), homogeneous(), sfb_pair()] {
        assert!(!topo.is_routed(), "{}: flat presets stay cliques", topo.name);
        let devs = topo.devices();
        let mut flat_min = f64::INFINITY;
        for (i, &a) in devs.iter().enumerate() {
            for &b in &devs[i..] {
                let expect = if a == b {
                    f64::INFINITY
                } else if a.group == b.group {
                    topo.groups[a.group].intra_bw_gbps
                } else {
                    topo.inter_bw_gbps[a.group][b.group]
                };
                assert_eq!(
                    topo.bw_gbps(a, b).to_bits(),
                    expect.to_bits(),
                    "{}: bw({a:?}, {b:?})",
                    topo.name
                );
                if a != b {
                    flat_min = flat_min.min(expect);
                    assert_eq!(topo.route(a, b).hops(), 1);
                    assert_eq!(topo.route_latency_s(a, b), 0.0);
                }
            }
        }
        assert_eq!(
            topo.bottleneck_bw_gbps(&devs).to_bits(),
            flat_min.to_bits(),
            "{}: bottleneck",
            topo.name
        );
        assert_eq!(
            fingerprint::topology(&topo),
            flat_fingerprint_reference(&topo),
            "{}: clique fingerprints must stay pre-refactor-identical",
            topo.name
        );
    }
}

#[test]
fn rebuilt_flat_topology_serves_identical_plans() {
    // A Topology reconstructed from a preset's public (groups, matrix)
    // view is the same deployment problem: same fingerprint, same plan,
    // and it *hits* the first topology's cache entry.
    let orig = request(3);
    let rebuilt = PlanRequest::new(
        models::vgg19(8, 0.25),
        Topology::new("rebuilt", orig.topology.groups.clone(), orig.topology.inter_bw_gbps.clone()),
    )
    .budget(40, 12)
    .seed(3);
    let planner = Planner::builder().build();
    let a = planner.plan(&orig).unwrap();
    let b = planner.plan(&rebuilt).unwrap();
    assert!(!a.cache_hit && b.cache_hit);
    assert_eq!(a.plan, b.plan);
    assert_eq!(a.plan.encode(), b.plan.encode());
}

#[test]
fn hierarchical_preset_plans_end_to_end_with_contention() {
    // A routed preset goes through the full Planner path...
    let planner = Planner::builder().build();
    let req = |topo: &Topology| {
        PlanRequest::new(models::vgg19(8, 0.25), topo.clone()).budget(30, 10).seed(3)
    };
    let routed = nvlink_island();
    let out = planner.plan(&req(&routed)).unwrap();
    assert!(out.plan.times.final_time.is_finite() && out.plan.times.final_time > 0.0);
    assert!(out.plan.times.speedup >= 1.0 - 1e-9);
    let back = DeploymentPlan::decode(&out.plan.encode()).unwrap();
    assert_eq!(back, out.plan);

    // ...and its simulated times differ from the naive bottleneck model
    // (the same cluster flattened to its derived pairwise matrix):
    // routed paths charge per-hop latency and concurrent transfers
    // share links, which per-flow bottlenecks cannot see.
    let flattened =
        Topology::new("flattened", routed.groups.clone(), routed.inter_bw_gbps.clone());
    let flat_out = planner.plan(&req(&flattened)).unwrap();
    assert_ne!(
        out.plan.topology_fingerprint, flat_out.plan.topology_fingerprint,
        "routed and flattened topologies must never share cache entries"
    );
    let cfg = req(&routed).search_config();
    let prep = prepare(models::vgg19(8, 0.25), &routed, &cfg);
    let low_routed = Lowering::new(&prep.gg, &routed, &prep.cost, &prep.comm);
    let low_flat = Lowering::new(&prep.gg, &flattened, &prep.cost, &prep.comm);
    let dp = Strategy::dp_allreduce(prep.gg.num_groups(), &routed);
    let t_routed = low_routed.evaluate(&dp).time;
    let t_flat = low_flat.evaluate(&dp).time;
    assert!(
        t_routed > t_flat,
        "contention + path latency must cost more than the naive bottleneck model \
         (routed {t_routed} vs flat {t_flat})"
    );

    // The largest hierarchical preset also plans end to end.
    let big = planner.plan(&req(&multi_rack())).unwrap();
    assert!(big.plan.times.final_time.is_finite() && big.plan.times.speedup >= 1.0 - 1e-9);
}

#[test]
fn plan_json_round_trip_is_lossless() {
    let planner = Planner::builder().without_cache().build();
    // Cover both SFB-on (Some(time_with_sfb), Some(sfb)) and SFB-off.
    for req in [request(5), request(5).sfb(false)] {
        let plan = planner.plan(&req).unwrap().plan;
        let json = plan.encode();
        let back = DeploymentPlan::decode(&json).expect("decode");
        assert_eq!(back, plan);
        assert_eq!(back.encode(), json, "re-encode must be byte-identical");
        // The rehydrated strategy drives the engine identically.
        let cfg = req.search_config();
        let prep = prepare(req.model.clone(), &req.topology, &cfg);
        let low = Lowering::new(&prep.gg, &req.topology, &prep.cost, &prep.comm);
        let out = low.evaluate(&back.strategy.to_strategy());
        assert!((out.time - plan.times.time).abs() < 1e-12);
    }
}

#[test]
fn equal_problems_share_cache_entries_across_request_values() {
    // Fingerprints key on structure: a *new* but identical request value
    // (fresh model generation, renamed topology) must hit the cache.
    let planner = Planner::builder().build();
    let first = planner.plan(&request(7)).unwrap();
    let mut renamed = request(7);
    renamed.topology.name = "testbed-imposter".into();
    let second = planner.plan(&renamed).unwrap();
    assert!(!first.cache_hit && second.cache_hit);
    assert_eq!(first.plan, second.plan);
}

#[test]
fn backend_identity_partitions_the_cache() {
    // The same request through differently-configured backends must not
    // share plans: the backend token is part of the config fingerprint.
    let sweep = Planner::builder().backend(BaselineSweepBackend::new()).build();
    let mut rootless =
        Planner::builder().backend(MctsBackend::new().root_sweep(false)).build();
    let k_default = Planner::builder().build().key_for(&request(3));
    assert_ne!(k_default, sweep.key_for(&request(3)));
    assert_ne!(k_default, rootless.key_for(&request(3)));
    assert_ne!(sweep.key_for(&request(3)), rootless.key_for(&request(3)));
    // And the plans really differ in provenance.
    assert_eq!(sweep.plan(&request(3)).unwrap().plan.backend, "baseline-sweep");
    assert_eq!(rootless.plan(&request(3)).unwrap().plan.backend, "mcts");
}

#[test]
fn every_baseline_generator_runs_on_preset_topologies() {
    // Satellite requirement: each `strategy::baselines` generator on at
    // least two `cluster::presets` topologies — no panic, finite times.
    for topo in [testbed(), sfb_pair(), homogeneous()] {
        let cfg = SearchConfig {
            max_groups: 10,
            mcts_iterations: 30,
            seed: 1,
            apply_sfb: false,
            profile_noise: 0.0,
            parallelism: Default::default(),
            deadline_ms: None,
            delta: true,
        };
        let prep = prepare(models::vgg19(8, 0.25), &topo, &cfg);
        let low = Lowering::new(&prep.gg, &topo, &prep.cost, &prep.comm);
        let actions = enumerate_actions(&topo);
        let ng = prep.gg.num_groups();
        let strategies = vec![
            ("dp_nccl", baselines::dp_nccl(ng, &topo)),
            ("dp_nccl_p", baselines::dp_nccl_p(ng, &topo)),
            ("horovod", baselines::horovod(ng, &topo)),
            ("expert", baselines::expert(ng, &topo)),
            ("flexflow_mcmc", baselines::flexflow_mcmc(&low, &actions, 30, 1)),
            ("baechi_msct", baselines::baechi_msct(&low)),
            ("heterog_like", baselines::heterog_like(&low)),
        ];
        for (name, s) in strategies {
            assert!(s.is_complete(), "{name} on {} incomplete", topo.name);
            let out = low.evaluate(&s);
            assert!(
                out.time.is_finite() && out.time > 0.0,
                "{name} on {}: time {}",
                topo.name,
                out.time
            );
        }
    }
}

#[test]
fn baseline_sweep_backend_covers_the_roster_on_two_presets() {
    for topo in [testbed(), sfb_pair()] {
        let planner = Planner::builder().backend(BaselineSweepBackend::new()).build();
        let req = PlanRequest::new(models::inception_v3(8, 0.25), topo.clone())
            .budget(30, 10)
            .seed(2)
            .sfb(false);
        let plan = planner.plan(&req).unwrap().plan;
        for name in BASELINE_NAMES {
            let t = plan
                .telemetry
                .metric(name)
                .unwrap_or_else(|| panic!("{name} row missing on {}", topo.name));
            assert!(t.is_finite() && t > 0.0, "{name} on {}: {t}", topo.name);
        }
        // The sweep's chosen plan never loses to its own DP row.
        assert!(plan.times.final_time <= plan.telemetry.metric("DP-NCCL").unwrap() + 1e-12);
    }
}

#[test]
fn workers_one_is_byte_identical_to_the_sequential_engine() {
    // Engine level: the tree-parallel engine with one worker must retrace
    // the pre-refactor sequential search exactly — same RNG stream, same
    // floating-point arithmetic, same memo traffic.
    let topo = testbed();
    let cfg = SearchConfig {
        max_groups: 12,
        mcts_iterations: 40,
        seed: 3,
        apply_sfb: false,
        profile_noise: 0.0,
        parallelism: Default::default(),
        deadline_ms: None,
        delta: true,
    };
    let prep = prepare(models::vgg19(8, 0.25), &topo, &cfg);
    let actions = enumerate_actions(&topo);

    let seq_low = Lowering::new(&prep.gg, &topo, &prep.cost, &prep.comm);
    let mut mcts = Mcts::new(&seq_low, actions.clone(), UniformPrior, cfg.seed);
    let seq = mcts.search(cfg.mcts_iterations);

    let par_low = Lowering::new(&prep.gg, &topo, &prep.cost, &prep.comm);
    let prob = SearchProblem {
        gg: &prep.gg,
        topo: &topo,
        cost: &prep.cost,
        comm: &prep.comm,
        actions: &actions,
    };
    let par = run_search(
        &prob,
        &par_low,
        vec![UniformPrior],
        cfg.mcts_iterations,
        cfg.seed,
        Parallelism::default(),
        true,
        false,
        None,
    );
    assert_eq!(par.result.best, seq.best);
    assert_eq!(par.result.best_time.to_bits(), seq.best_time.to_bits());
    assert_eq!(par.result.best_reward.to_bits(), seq.best_reward.to_bits());
    assert_eq!(par.result.dp_time.to_bits(), seq.dp_time.to_bits());
    assert_eq!(par.result.iterations, seq.iterations);
    assert_eq!(par.result.first_beats_dp, seq.first_beats_dp);
    // Same memo hit/miss sequence as the sequential lowering.
    assert_eq!(par_low.memo_stats(), seq_low.memo_stats());

    // Plan level: an explicit `.workers(1)` request is the same plan —
    // and the same cache identity — byte for byte.
    let a = Planner::builder().without_cache().build();
    let b = Planner::builder().without_cache().build();
    let p1 = a.plan(&request(3)).unwrap();
    let p2 = b.plan(&request(3).workers(1)).unwrap();
    assert_eq!(p1.plan, p2.plan);
    assert_eq!(p1.plan.encode(), p2.plan.encode());
}

#[test]
fn parallel_workers_smoke_and_telemetry_roundtrip() {
    // 4 tree-parallel workers: the plan is well-formed, per-worker
    // iteration counts are the exact static split, memo/eval hit rates
    // ride in telemetry, and everything round-trips through JSON.
    let planner = Planner::builder().without_cache().build();
    let out = planner.plan(&request(3).workers(4)).unwrap();
    let p = &out.plan;
    assert!(p.times.final_time.is_finite() && p.times.final_time > 0.0);
    assert!(p.times.speedup > 0.0);
    assert_eq!(p.telemetry.iterations, 40);
    assert_eq!(p.telemetry.metric("workers"), Some(4.0));
    let per: Vec<f64> = (0..4)
        .map(|w| p.telemetry.metric(&format!("worker{w}_iterations")).expect("worker row"))
        .collect();
    assert_eq!(per.iter().sum::<f64>() as usize, p.telemetry.iterations);
    assert_eq!(per, vec![10.0, 10.0, 10.0, 10.0]);
    let hit_rate = p.telemetry.metric("memo_hit_rate").expect("memo_hit_rate row");
    assert!((0.0..=1.0).contains(&hit_rate));
    assert!(hit_rate > 0.0, "workers must share the memo table");

    let back = DeploymentPlan::decode(&p.encode()).expect("decode");
    assert_eq!(&back, p);
    assert_eq!(back.telemetry.metric("workers"), Some(4.0));

    // Parallel plans never alias sequential ones in the cache.
    assert_ne!(
        planner.key_for(&request(3)).config,
        planner.key_for(&request(3).workers(4)).config
    );
}

#[test]
fn prepared_state_survives_budget_changes_but_plans_differ() {
    // Same (model, topology, prepare-knobs), different search budget:
    // the planner reuses prepared state yet produces distinct cached
    // entries with possibly different strategies.
    let planner = Planner::builder().build();
    let small = planner.plan(&request(3)).unwrap();
    let big = planner
        .plan(&PlanRequest::new(models::vgg19(8, 0.25), testbed()).budget(80, 12).seed(3))
        .unwrap();
    assert!(!big.cache_hit);
    assert_eq!(
        small.plan.model_fingerprint, big.plan.model_fingerprint,
        "same structural problem"
    );
    assert_ne!(small.plan.config_fingerprint, big.plan.config_fingerprint);
    // More iterations never hurt the found strategy's base time (the
    // longer run's search prefix is the shorter run).
    assert!(big.plan.times.time <= small.plan.times.time + 1e-12);
}
