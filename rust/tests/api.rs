//! API-surface integration tests: planner determinism (with and without
//! the plan cache), lossless plan JSON round-trips, and full baseline
//! coverage on the paper's preset topologies.

use tag::api::{
    BaselineSweepBackend, DeploymentPlan, MctsBackend, PlanRequest, Planner,
    BASELINE_NAMES,
};
use tag::cluster::presets::{homogeneous, sfb_pair, testbed};
use tag::coordinator::{prepare, SearchConfig};
use tag::dist::Lowering;
use tag::mcts::{Mcts, UniformPrior};
use tag::models;
use tag::search::{run_search, Parallelism, SearchProblem};
use tag::strategy::{baselines, enumerate_actions};

fn request(seed: u64) -> PlanRequest {
    PlanRequest::new(models::vgg19(8, 0.25), testbed()).budget(40, 12).seed(seed)
}

#[test]
fn plans_are_deterministic_with_cache_on_and_off() {
    // Cache off: two independent searches must agree bit-for-bit.
    let mut cold = Planner::builder().without_cache().build();
    let a = cold.plan(&request(3));
    let b = cold.plan(&request(3));
    assert!(!a.cache_hit && !b.cache_hit);
    assert_eq!(a.plan, b.plan);

    // Cache on: the served copy is the same plan again.
    let mut warm = Planner::builder().build();
    let c = warm.plan(&request(3));
    let d = warm.plan(&request(3));
    assert!(!c.cache_hit && d.cache_hit);
    assert_eq!(c.plan, d.plan);

    // Across planners and cache modes: still identical.
    assert_eq!(a.plan, c.plan);

    // And so is the serialized form (byte-level determinism).
    assert_eq!(a.plan.encode(), d.plan.encode());
}

#[test]
fn plan_json_round_trip_is_lossless() {
    let mut planner = Planner::builder().without_cache().build();
    // Cover both SFB-on (Some(time_with_sfb), Some(sfb)) and SFB-off.
    for req in [request(5), request(5).sfb(false)] {
        let plan = planner.plan(&req).plan;
        let json = plan.encode();
        let back = DeploymentPlan::decode(&json).expect("decode");
        assert_eq!(back, plan);
        assert_eq!(back.encode(), json, "re-encode must be byte-identical");
        // The rehydrated strategy drives the engine identically.
        let cfg = req.search_config();
        let prep = prepare(req.model.clone(), &req.topology, &cfg);
        let low = Lowering::new(&prep.gg, &req.topology, &prep.cost, &prep.comm);
        let out = low.evaluate(&back.strategy.to_strategy());
        assert!((out.time - plan.times.time).abs() < 1e-12);
    }
}

#[test]
fn equal_problems_share_cache_entries_across_request_values() {
    // Fingerprints key on structure: a *new* but identical request value
    // (fresh model generation, renamed topology) must hit the cache.
    let mut planner = Planner::builder().build();
    let first = planner.plan(&request(7));
    let mut renamed = request(7);
    renamed.topology.name = "testbed-imposter".into();
    let second = planner.plan(&renamed);
    assert!(!first.cache_hit && second.cache_hit);
    assert_eq!(first.plan, second.plan);
}

#[test]
fn backend_identity_partitions_the_cache() {
    // The same request through differently-configured backends must not
    // share plans: the backend token is part of the config fingerprint.
    let mut sweep = Planner::builder().backend(BaselineSweepBackend::new()).build();
    let mut rootless =
        Planner::builder().backend(MctsBackend::new().root_sweep(false)).build();
    let k_default = Planner::builder().build().key_for(&request(3));
    assert_ne!(k_default, sweep.key_for(&request(3)));
    assert_ne!(k_default, rootless.key_for(&request(3)));
    assert_ne!(sweep.key_for(&request(3)), rootless.key_for(&request(3)));
    // And the plans really differ in provenance.
    assert_eq!(sweep.plan(&request(3)).plan.backend, "baseline-sweep");
    assert_eq!(rootless.plan(&request(3)).plan.backend, "mcts");
}

#[test]
fn every_baseline_generator_runs_on_preset_topologies() {
    // Satellite requirement: each `strategy::baselines` generator on at
    // least two `cluster::presets` topologies — no panic, finite times.
    for topo in [testbed(), sfb_pair(), homogeneous()] {
        let cfg = SearchConfig {
            max_groups: 10,
            mcts_iterations: 30,
            seed: 1,
            apply_sfb: false,
            profile_noise: 0.0,
            parallelism: Default::default(),
        };
        let prep = prepare(models::vgg19(8, 0.25), &topo, &cfg);
        let low = Lowering::new(&prep.gg, &topo, &prep.cost, &prep.comm);
        let actions = enumerate_actions(&topo);
        let ng = prep.gg.num_groups();
        let strategies = vec![
            ("dp_nccl", baselines::dp_nccl(ng, &topo)),
            ("dp_nccl_p", baselines::dp_nccl_p(ng, &topo)),
            ("horovod", baselines::horovod(ng, &topo)),
            ("expert", baselines::expert(ng, &topo)),
            ("flexflow_mcmc", baselines::flexflow_mcmc(&low, &actions, 30, 1)),
            ("baechi_msct", baselines::baechi_msct(&low)),
            ("heterog_like", baselines::heterog_like(&low)),
        ];
        for (name, s) in strategies {
            assert!(s.is_complete(), "{name} on {} incomplete", topo.name);
            let out = low.evaluate(&s);
            assert!(
                out.time.is_finite() && out.time > 0.0,
                "{name} on {}: time {}",
                topo.name,
                out.time
            );
        }
    }
}

#[test]
fn baseline_sweep_backend_covers_the_roster_on_two_presets() {
    for topo in [testbed(), sfb_pair()] {
        let mut planner =
            Planner::builder().backend(BaselineSweepBackend::new()).build();
        let req = PlanRequest::new(models::inception_v3(8, 0.25), topo.clone())
            .budget(30, 10)
            .seed(2)
            .sfb(false);
        let plan = planner.plan(&req).plan;
        for name in BASELINE_NAMES {
            let t = plan
                .telemetry
                .metric(name)
                .unwrap_or_else(|| panic!("{name} row missing on {}", topo.name));
            assert!(t.is_finite() && t > 0.0, "{name} on {}: {t}", topo.name);
        }
        // The sweep's chosen plan never loses to its own DP row.
        assert!(plan.times.final_time <= plan.telemetry.metric("DP-NCCL").unwrap() + 1e-12);
    }
}

#[test]
fn workers_one_is_byte_identical_to_the_sequential_engine() {
    // Engine level: the tree-parallel engine with one worker must retrace
    // the pre-refactor sequential search exactly — same RNG stream, same
    // floating-point arithmetic, same memo traffic.
    let topo = testbed();
    let cfg = SearchConfig {
        max_groups: 12,
        mcts_iterations: 40,
        seed: 3,
        apply_sfb: false,
        profile_noise: 0.0,
        parallelism: Default::default(),
    };
    let prep = prepare(models::vgg19(8, 0.25), &topo, &cfg);
    let actions = enumerate_actions(&topo);

    let seq_low = Lowering::new(&prep.gg, &topo, &prep.cost, &prep.comm);
    let mut mcts = Mcts::new(&seq_low, actions.clone(), UniformPrior, cfg.seed);
    let seq = mcts.search(cfg.mcts_iterations);

    let par_low = Lowering::new(&prep.gg, &topo, &prep.cost, &prep.comm);
    let prob = SearchProblem {
        gg: &prep.gg,
        topo: &topo,
        cost: &prep.cost,
        comm: &prep.comm,
        actions: &actions,
    };
    let par = run_search(
        &prob,
        &par_low,
        vec![UniformPrior],
        cfg.mcts_iterations,
        cfg.seed,
        Parallelism::default(),
        true,
        false,
    );
    assert_eq!(par.result.best, seq.best);
    assert_eq!(par.result.best_time.to_bits(), seq.best_time.to_bits());
    assert_eq!(par.result.best_reward.to_bits(), seq.best_reward.to_bits());
    assert_eq!(par.result.dp_time.to_bits(), seq.dp_time.to_bits());
    assert_eq!(par.result.iterations, seq.iterations);
    assert_eq!(par.result.first_beats_dp, seq.first_beats_dp);
    // Same memo hit/miss sequence as the sequential lowering.
    assert_eq!(par_low.memo_stats(), seq_low.memo_stats());

    // Plan level: an explicit `.workers(1)` request is the same plan —
    // and the same cache identity — byte for byte.
    let mut a = Planner::builder().without_cache().build();
    let mut b = Planner::builder().without_cache().build();
    let p1 = a.plan(&request(3));
    let p2 = b.plan(&request(3).workers(1));
    assert_eq!(p1.plan, p2.plan);
    assert_eq!(p1.plan.encode(), p2.plan.encode());
}

#[test]
fn parallel_workers_smoke_and_telemetry_roundtrip() {
    // 4 tree-parallel workers: the plan is well-formed, per-worker
    // iteration counts are the exact static split, memo/eval hit rates
    // ride in telemetry, and everything round-trips through JSON.
    let mut planner = Planner::builder().without_cache().build();
    let out = planner.plan(&request(3).workers(4));
    let p = &out.plan;
    assert!(p.times.final_time.is_finite() && p.times.final_time > 0.0);
    assert!(p.times.speedup > 0.0);
    assert_eq!(p.telemetry.iterations, 40);
    assert_eq!(p.telemetry.metric("workers"), Some(4.0));
    let per: Vec<f64> = (0..4)
        .map(|w| p.telemetry.metric(&format!("worker{w}_iterations")).expect("worker row"))
        .collect();
    assert_eq!(per.iter().sum::<f64>() as usize, p.telemetry.iterations);
    assert_eq!(per, vec![10.0, 10.0, 10.0, 10.0]);
    let hit_rate = p.telemetry.metric("memo_hit_rate").expect("memo_hit_rate row");
    assert!((0.0..=1.0).contains(&hit_rate));
    assert!(hit_rate > 0.0, "workers must share the memo table");

    let back = DeploymentPlan::decode(&p.encode()).expect("decode");
    assert_eq!(&back, p);
    assert_eq!(back.telemetry.metric("workers"), Some(4.0));

    // Parallel plans never alias sequential ones in the cache.
    assert_ne!(
        planner.key_for(&request(3)).config,
        planner.key_for(&request(3).workers(4)).config
    );
}

#[test]
fn prepared_state_survives_budget_changes_but_plans_differ() {
    // Same (model, topology, prepare-knobs), different search budget:
    // the planner reuses prepared state yet produces distinct cached
    // entries with possibly different strategies.
    let mut planner = Planner::builder().build();
    let small = planner.plan(&request(3));
    let big = planner.plan(&PlanRequest::new(models::vgg19(8, 0.25), testbed())
        .budget(80, 12)
        .seed(3));
    assert!(!big.cache_hit);
    assert_eq!(
        small.plan.model_fingerprint, big.plan.model_fingerprint,
        "same structural problem"
    );
    assert_ne!(small.plan.config_fingerprint, big.plan.config_fingerprint);
    // More iterations never hurt the found strategy's base time (the
    // longer run's search prefix is the shorter run).
    assert!(big.plan.times.time <= small.plan.times.time + 1e-12);
}
