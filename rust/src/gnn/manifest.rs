//! Parse `artifacts/manifest.txt` emitted by `python/compile/aot.py`:
//! `const NAME VALUE` lines and `input FN IDX NAME d0,d1,...` lines.
//! This is the single source of truth tying the Rust feature builder to
//! the AOT-lowered HLO input signature.

use std::collections::HashMap;

use crate::bail;
use crate::util::error::{Context, Result};

#[derive(Clone, Debug)]
pub struct InputSpec {
    pub index: usize,
    pub name: String,
    pub dims: Vec<i64>,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    consts: HashMap<String, i64>,
    inputs: HashMap<String, Vec<InputSpec>>,
}

impl Manifest {
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("read manifest {:?}", path.as_ref()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let mut m = Manifest::default();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            match parts.as_slice() {
                ["const", name, value] => {
                    m.consts.insert(name.to_string(), value.parse()?);
                }
                ["input", func, idx, name, dims] => {
                    let spec = InputSpec {
                        index: idx.parse()?,
                        name: name.to_string(),
                        dims: dims
                            .split(',')
                            .map(|d| d.parse::<i64>())
                            .collect::<Result<_, _>>()?,
                    };
                    m.inputs.entry(func.to_string()).or_default().push(spec);
                }
                _ => bail!("manifest line {}: unparseable: {line}", ln + 1),
            }
        }
        for specs in m.inputs.values_mut() {
            specs.sort_by_key(|s| s.index);
            for (i, s) in specs.iter().enumerate() {
                crate::ensure!(s.index == i, "input indices not dense");
            }
        }
        Ok(m)
    }

    pub fn constant(&self, name: &str) -> i64 {
        *self
            .consts
            .get(name)
            .unwrap_or_else(|| panic!("manifest missing const {name}"))
    }

    pub fn inputs_for(&self, func: &str) -> &[InputSpec] {
        self.inputs
            .get(func)
            .unwrap_or_else(|| panic!("manifest missing function {func}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "# comment\n\
        const N_OP 64\n\
        const PARAM_COUNT 122497\n\
        input infer 0 params 122497\n\
        input infer 1 op_feats 8,64,11\n\
        input train 0 params 122497\n";

    #[test]
    fn parses_consts_and_inputs() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.constant("N_OP"), 64);
        let infer = m.inputs_for("infer");
        assert_eq!(infer.len(), 2);
        assert_eq!(infer[1].name, "op_feats");
        assert_eq!(infer[1].dims, vec![8, 64, 11]);
        assert_eq!(m.inputs_for("train").len(), 1);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("what is this").is_err());
    }

    #[test]
    fn real_manifest_consistent_with_rust_constants() {
        let Ok(m) = Manifest::load("artifacts/manifest.txt") else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        use crate::gnn::features as f;
        assert_eq!(m.constant("N_OP") as usize, f::N_OP);
        assert_eq!(m.constant("N_DEV") as usize, f::N_DEV);
        assert_eq!(m.constant("N_CAND") as usize, f::N_CAND);
        assert_eq!(m.constant("F_OP") as usize, f::F_OP);
        assert_eq!(m.constant("F_DEV") as usize, f::F_DEV);
        // Input order must match the Rust feature array order.
        let names: Vec<&str> = m.inputs_for("infer").iter().map(|s| s.name.as_str()).collect();
        let expect: Vec<&str> = std::iter::once("params")
            .chain(f::FEATURE_ORDER.iter().copied())
            .collect();
        assert_eq!(names, expect);
    }
}
