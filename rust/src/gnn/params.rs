//! Flat f32 parameter vectors on disk (little-endian, the layout
//! `python/compile/model.py::param_spec` defines).  The Rust side never
//! needs the structure — one params vector, two Adam moment vectors.

use crate::util::error::{Context, Result};

pub fn load_params(path: impl AsRef<std::path::Path>) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path.as_ref())
        .with_context(|| format!("read params {:?}", path.as_ref()))?;
    crate::ensure!(bytes.len() % 4 == 0, "params file not a multiple of 4 bytes");
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

pub fn save_params(path: impl AsRef<std::path::Path>, params: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(params.len() * 4);
    for p in params {
        bytes.extend_from_slice(&p.to_le_bytes());
    }
    std::fs::write(path.as_ref(), bytes)
        .with_context(|| format!("write params {:?}", path.as_ref()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("tag_params_test.bin");
        let data = vec![0.0f32, -1.5, 3.25, f32::MAX, f32::MIN_POSITIVE];
        save_params(&dir, &data).unwrap();
        let back = load_params(&dir).unwrap();
        assert_eq!(back, data);
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn init_params_match_manifest_count() {
        let Ok(params) = load_params("artifacts/params_init.bin") else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let m = crate::gnn::Manifest::load("artifacts/manifest.txt").unwrap();
        assert_eq!(params.len() as i64, m.constant("PARAM_COUNT"));
        assert!(params.iter().all(|p| p.is_finite()));
        // Glorot init: nonzero spread.
        let nonzero = params.iter().filter(|&&p| p != 0.0).count();
        assert!(nonzero > params.len() / 2);
    }
}
