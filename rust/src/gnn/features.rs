//! Fixed-shape feature tensors for the AOT-compiled GNN (paper Table 1).
//!
//! Shapes, padding and normalization here must match
//! `python/compile/model.py` exactly — `manifest.rs` tests pin the
//! constants and the input order.
//!
//! Feature layout (documented in model.py):
//!   op node (11): log1p(comp ms), log1p(param MB),
//!                 one-hot[undecided, AR, PS, Dup, MP],
//!                 log1p(makespan ms), log1p(idle-before-send ms),
//!                 decided, is-next
//!   dev node (7): #GPUs/8, log1p(mem GB), log1p(intra Gbps),
//!                 peak-mem fraction, idle fraction,
//!                 log1p(attached switch degree), mean route hops / 4
//!   op-op edge (1): log1p(tensor MB);
//!   dev-dev edge (4): log1p(routed Gbps), link idle, route hops / 8,
//!                 log1p(route latency us);
//!   op-dev edge (1): placement bit.
//!
//! The dev-node and dev-dev topology-structure features (switch degree,
//! route length, path latency) come from the topology's link graph —
//! for flat cliques they collapse to (0, 1-hop, 0 latency), so the GNN
//! sees graph-structured topologies rather than bare matrices and the
//! unseen-topology generalization experiments exercise genuinely
//! routed inputs.

use crate::cluster::Topology;
use crate::dist::SimOutcome;
use crate::graph::grouping::GroupGraph;
use crate::strategy::{Action, Strategy};

pub const N_OP: usize = 64;
pub const N_DEV: usize = 16;
pub const N_CAND: usize = 128;
pub const F_OP: usize = 11;
pub const F_DEV: usize = 7;
/// Raw dev-dev edge feature depth (model.py F_EDGE_DD).
pub const F_DD: usize = 4;
pub const B_INFER: usize = 8;
pub const B_TRAIN: usize = 16;

/// Feature array order — must equal model.py FEATURE_NAMES.
pub const FEATURE_ORDER: [&str; 13] = [
    "op_feats",
    "dev_feats",
    "oo_e",
    "oo_mask",
    "dd_e",
    "dd_mask",
    "od_place",
    "op_mask",
    "dev_mask",
    "next_onehot",
    "cand_p",
    "cand_o",
    "cand_mask",
];

/// One position's feature arrays (flat, row-major, fixed shapes).
#[derive(Clone, Debug)]
pub struct Position {
    pub op_feats: Vec<f32>,    // N_OP * F_OP
    pub dev_feats: Vec<f32>,   // N_DEV * F_DEV
    pub oo_e: Vec<f32>,        // N_OP * N_OP
    pub oo_mask: Vec<f32>,     // N_OP * N_OP
    pub dd_e: Vec<f32>,        // N_DEV * N_DEV * F_DD
    pub dd_mask: Vec<f32>,     // N_DEV * N_DEV
    pub od_place: Vec<f32>,    // N_OP * N_DEV
    pub op_mask: Vec<f32>,     // N_OP
    pub dev_mask: Vec<f32>,    // N_DEV
    pub next_onehot: Vec<f32>, // N_OP
    pub cand_p: Vec<f32>,      // N_CAND * N_DEV
    pub cand_o: Vec<f32>,      // N_CAND * 4
    pub cand_mask: Vec<f32>,   // N_CAND
}

impl Position {
    pub fn zero() -> Self {
        Self {
            op_feats: vec![0.0; N_OP * F_OP],
            dev_feats: vec![0.0; N_DEV * F_DEV],
            oo_e: vec![0.0; N_OP * N_OP],
            oo_mask: vec![0.0; N_OP * N_OP],
            dd_e: vec![0.0; N_DEV * N_DEV * F_DD],
            dd_mask: vec![0.0; N_DEV * N_DEV],
            od_place: vec![0.0; N_OP * N_DEV],
            op_mask: vec![0.0; N_OP],
            dev_mask: vec![0.0; N_DEV],
            next_onehot: vec![0.0; N_OP],
            cand_p: vec![0.0; N_CAND * N_DEV],
            cand_o: vec![0.0; N_CAND * 4],
            cand_mask: vec![0.0; N_CAND],
        }
    }

    /// Arrays in FEATURE_ORDER (for batching into literals).
    pub fn arrays(&self) -> [&[f32]; 13] {
        [
            &self.op_feats,
            &self.dev_feats,
            &self.oo_e,
            &self.oo_mask,
            &self.dd_e,
            &self.dd_mask,
            &self.od_place,
            &self.op_mask,
            &self.dev_mask,
            &self.next_onehot,
            &self.cand_p,
            &self.cand_o,
            &self.cand_mask,
        ]
    }
}

fn log1p_ms(seconds: f64) -> f32 {
    ((seconds * 1e3).max(0.0)).ln_1p() as f32
}

fn log1p_mb(bytes: f64) -> f32 {
    ((bytes / 1e6).max(0.0)).ln_1p() as f32
}

/// Builds positions for one (model, topology, action set) context.
pub struct FeatureBuilder<'a> {
    pub gg: &'a GroupGraph,
    pub topo: &'a Topology,
    pub actions: &'a [Action],
    /// Ablation switch (§5.5 / Fig. 7): zero out the simulator-feedback
    /// features (part 3 of Table 1) when false.
    pub use_feedback: bool,
}

impl<'a> FeatureBuilder<'a> {
    pub fn new(gg: &'a GroupGraph, topo: &'a Topology, actions: &'a [Action]) -> Self {
        assert!(gg.num_groups() <= N_OP, "too many op groups for AOT shape");
        assert!(topo.num_groups() <= N_DEV, "too many device groups");
        assert!(actions.len() <= N_CAND, "too many candidate actions");
        Self { gg, topo, actions, use_feedback: true }
    }

    /// Build the feature tensors for deciding `next_group` under the
    /// partial `strategy` whose simulated feedback is `out`.
    pub fn build(&self, strategy: &Strategy, out: &SimOutcome, next_group: usize) -> Position {
        let mut p = Position::zero();
        let ng = self.gg.num_groups();
        let m = self.topo.num_groups();
        let fb = &out.feedback;

        // ---- op nodes
        for g in 0..ng {
            let row = &mut p.op_feats[g * F_OP..(g + 1) * F_OP];
            let grp = &self.gg.groups[g];
            row[0] = log1p_ms(grp.comp_time);
            row[1] = log1p_mb(grp.param_bytes);
            let opt = match strategy.slots[g] {
                None => 0,
                Some(a) => 1 + a.option.index(),
            };
            row[2 + opt] = 1.0;
            if self.use_feedback {
                row[7] = log1p_ms(fb.group_makespan.get(g).copied().unwrap_or(0.0));
                row[8] =
                    log1p_ms(fb.group_idle_before_send.get(g).copied().unwrap_or(0.0));
            }
            row[9] = if strategy.slots[g].is_some() { 1.0 } else { 0.0 };
            row[10] = if g == next_group { 1.0 } else { 0.0 };
            p.op_mask[g] = 1.0;
        }
        p.next_onehot[next_group] = 1.0;

        // ---- device nodes
        for d in 0..m {
            let row = &mut p.dev_feats[d * F_DEV..(d + 1) * F_DEV];
            let grp = &self.topo.groups[d];
            row[0] = grp.count as f32 / 8.0;
            row[1] = (grp.gpu.mem_gb).ln_1p() as f32;
            row[2] = (grp.intra_bw_gbps).ln_1p() as f32;
            if self.use_feedback {
                row[3] = fb.devgroup_peak_mem_frac.get(d).copied().unwrap_or(0.0) as f32;
                row[4] = fb.devgroup_idle.get(d).copied().unwrap_or(0.0) as f32;
            }
            // Topology-graph structure (0 / 1-hop degenerate on cliques).
            row[5] = (self.topo.switch_degree(d) as f64).ln_1p() as f32;
            row[6] = self.topo.mean_group_hops(d) as f32 / 4.0;
            p.dev_mask[d] = 1.0;
        }

        // ---- op-op edges (symmetrized tensor volume)
        for i in 0..ng {
            for j in 0..ng {
                let bytes = self.gg.edges[i][j] + self.gg.edges[j][i];
                if bytes > 0.0 {
                    p.oo_e[i * N_OP + j] = log1p_mb(bytes);
                    p.oo_mask[i * N_OP + j] = 1.0;
                }
            }
        }

        // ---- dev-dev edges (routed: per-hop bandwidth, path length,
        // path latency come from the link graph's route table)
        for a in 0..m {
            for b in 0..m {
                if a == b {
                    continue;
                }
                let idx = (a * N_DEV + b) * F_DD;
                p.dd_e[idx] = (self.topo.group_bw_gbps(a, b)).ln_1p() as f32;
                if self.use_feedback {
                    p.dd_e[idx + 1] = fb
                        .link_idle
                        .get(a)
                        .and_then(|r| r.get(b))
                        .copied()
                        .unwrap_or(0.0) as f32;
                }
                let route = self.topo.group_route(a, b);
                p.dd_e[idx + 2] = route.hops() as f32 / 8.0;
                p.dd_e[idx + 3] = ((route.latency_s * 1e6).max(0.0)).ln_1p() as f32;
                p.dd_mask[a * N_DEV + b] = 1.0;
            }
        }

        // ---- op-dev placement edges (decided groups only)
        for g in 0..ng {
            if let Some(a) = strategy.slots[g] {
                for d in 0..m {
                    if a.mask & (1 << d) != 0 {
                        p.od_place[g * N_DEV + d] = 1.0;
                    }
                }
            }
        }

        // ---- candidates
        for (ci, a) in self.actions.iter().enumerate() {
            for d in 0..m {
                if a.mask & (1 << d) != 0 {
                    p.cand_p[ci * N_DEV + d] = 1.0;
                }
            }
            p.cand_o[ci * 4 + a.option.index()] = 1.0;
            p.cand_mask[ci] = 1.0;
        }

        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets::testbed;
    use crate::dist::Lowering;
    use crate::graph::grouping::group_ops;
    use crate::models;
    use crate::profile::{unique_gpus, CommModel, CostModel};
    use crate::strategy::{enumerate_actions, ReplOption};

    fn setup() -> (GroupGraph, Topology, Vec<Action>, SimOutcome, Strategy) {
        let topo = testbed();
        let m = models::vgg19(8, 0.25);
        let cost = CostModel::profile(&m.ops, &unique_gpus(&topo), 0.0, 1);
        let gg = group_ops(&m, &cost, 12, 7);
        let comm = CommModel::fit(3);
        let low = Lowering::new(&gg, &topo, &cost, &comm);
        let mut s = Strategy::empty(gg.num_groups());
        s.slots[0] = Some(Action { mask: 0b1, option: ReplOption::Ps });
        let out = low.evaluate(&s);
        let actions = enumerate_actions(&topo);
        (gg, topo, actions, out, s)
    }

    #[test]
    fn shapes_and_masks() {
        let (gg, topo, actions, out, s) = setup();
        let fb = FeatureBuilder::new(&gg, &topo, &actions);
        let p = fb.build(&s, &out, 1);
        assert_eq!(p.op_feats.len(), N_OP * F_OP);
        let live_ops = p.op_mask.iter().filter(|&&x| x > 0.0).count();
        assert_eq!(live_ops, gg.num_groups());
        let live_dev = p.dev_mask.iter().filter(|&&x| x > 0.0).count();
        assert_eq!(live_dev, topo.num_groups());
        let live_cand = p.cand_mask.iter().filter(|&&x| x > 0.0).count();
        assert_eq!(live_cand, actions.len());
        // All values finite.
        for arr in p.arrays() {
            assert!(arr.iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn decided_and_next_flags() {
        let (gg, topo, actions, out, s) = setup();
        let fb = FeatureBuilder::new(&gg, &topo, &actions);
        let p = fb.build(&s, &out, 3);
        // Group 0 is decided with PS (one-hot slot 2 -> col 4).
        assert_eq!(p.op_feats[2 + 1 + 1], 1.0); // row 0, col 2+opt(PS=1+1)
        assert_eq!(p.op_feats[9], 1.0); // decided flag
        // Group 3 is next.
        assert_eq!(p.op_feats[3 * F_OP + 10], 1.0);
        assert_eq!(p.next_onehot[3], 1.0);
        // Undecided group 1: one-hot col 2 set.
        assert_eq!(p.op_feats[F_OP + 2], 1.0);
        assert_eq!(p.op_feats[F_OP + 9], 0.0);
    }

    #[test]
    fn placement_edges_match_mask() {
        let (gg, topo, actions, out, s) = setup();
        let fb = FeatureBuilder::new(&gg, &topo, &actions);
        let p = fb.build(&s, &out, 1);
        // Group 0 placed on device group 0 only.
        assert_eq!(p.od_place[0], 1.0);
        for d in 1..topo.num_groups() {
            assert_eq!(p.od_place[d], 0.0);
        }
        // Undecided groups have no placement edges.
        for d in 0..N_DEV {
            assert_eq!(p.od_place[N_DEV + d], 0.0);
        }
        let _ = gg;
    }

    #[test]
    fn feedback_ablation_zeroes_part3() {
        let (gg, topo, actions, out, s) = setup();
        let mut fb = FeatureBuilder::new(&gg, &topo, &actions);
        fb.use_feedback = false;
        let p = fb.build(&s, &out, 1);
        for g in 0..gg.num_groups() {
            assert_eq!(p.op_feats[g * F_OP + 7], 0.0);
            assert_eq!(p.op_feats[g * F_OP + 8], 0.0);
        }
        for d in 0..topo.num_groups() {
            assert_eq!(p.dev_feats[d * F_DEV + 3], 0.0);
            assert_eq!(p.dev_feats[d * F_DEV + 4], 0.0);
        }
        // Raw features still present.
        assert!(p.op_feats[0] > 0.0);
    }

    #[test]
    fn topology_structure_features_distinguish_routed_graphs() {
        // On a flat clique: no switches, 1-hop routes, zero latency.
        let (gg, topo, actions, out, s) = setup();
        let fb = FeatureBuilder::new(&gg, &topo, &actions);
        let p = fb.build(&s, &out, 1);
        assert_eq!(p.dev_feats[5], 0.0, "clique devices attach to no switch");
        assert_eq!(p.dev_feats[6], 0.25, "clique routes are all 1 hop");
        let idx = F_DD; // row (a=0, b=1): dev 0 -> dev 1
        assert_eq!(p.dd_e[idx + 2], 1.0 / 8.0);
        assert_eq!(p.dd_e[idx + 3], 0.0);

        // On a hierarchical topology the structure features light up.
        let htopo = crate::cluster::presets::nvlink_island();
        let m = models::vgg19(8, 0.25);
        let cost = CostModel::profile(&m.ops, &unique_gpus(&htopo), 0.0, 1);
        let hgg = group_ops(&m, &cost, 12, 7);
        let comm = CommModel::fit(3);
        let low = Lowering::new(&hgg, &htopo, &cost, &comm);
        let hs = Strategy::empty(hgg.num_groups());
        let hout = low.evaluate(&hs);
        let hacts = enumerate_actions(&htopo);
        let hfb = FeatureBuilder::new(&hgg, &htopo, &hacts);
        let hp = hfb.build(&hs, &hout, 0);
        assert!(hp.dev_feats[5] > 0.0, "switch degree visible");
        let idx = F_DD; // row (a=0, b=1): island 0 -> island 1
        assert_eq!(hp.dd_e[idx + 2], 4.0 / 8.0);
        assert!(hp.dd_e[idx + 3] > 0.0);
    }

    #[test]
    fn candidate_encoding_roundtrip() {
        let (gg, topo, actions, out, s) = setup();
        let fb = FeatureBuilder::new(&gg, &topo, &actions);
        let p = fb.build(&s, &out, 0);
        for (ci, a) in actions.iter().enumerate() {
            let mask_bits: u16 = (0..topo.num_groups())
                .filter(|&d| p.cand_p[ci * N_DEV + d] > 0.0)
                .map(|d| 1u16 << d)
                .sum();
            assert_eq!(mask_bits, a.mask);
            let opt = (0..4).find(|&o| p.cand_o[ci * 4 + o] > 0.0).unwrap();
            assert_eq!(opt, a.option.index());
        }
        let _ = gg;
    }
}
