//! Compiled-GNN service: batched prior inference + the Adam train step,
//! and the [`PriorProvider`] bridge that plugs the GNN into MCTS.
//!
//! Everything here talks to the two AOT artifacts
//! (`gnn_infer.hlo.txt`, `gnn_train.hlo.txt`) through PJRT — Python is
//! never involved at this point.

use std::collections::HashMap;
use std::path::Path;

use super::features::{Position, B_INFER, B_TRAIN, N_CAND};
use super::manifest::Manifest;
use crate::dist::SimOutcome;
use crate::mcts::PriorProvider;
use crate::runtime::{literal_f32, scalar_f32, to_vec_f32, Executable, Literal, Runtime};
use crate::strategy::{Action, Strategy};
use crate::util::error::{Context, Result};

pub struct GnnService {
    pub manifest: Manifest,
    runtime: Runtime,
    infer: Executable,
    train: Executable,
    pub param_count: usize,
}

impl GnnService {
    pub fn load(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifact_dir.as_ref();
        let manifest = Manifest::load(dir.join("manifest.txt"))?;
        Self::check_feature_shapes(&manifest)?;
        let runtime = Runtime::cpu()?;
        let infer = runtime
            .load_hlo_text(dir.join("gnn_infer.hlo.txt"))
            .context("load infer artifact")?;
        let train = runtime
            .load_hlo_text(dir.join("gnn_train.hlo.txt"))
            .context("load train artifact")?;
        let param_count = manifest.constant("PARAM_COUNT") as usize;
        Ok(Self { manifest, runtime, infer, train, param_count })
    }

    /// Fail fast when the AOT artifacts were compiled against different
    /// feature shapes than this build (e.g. artifacts predating the
    /// link-graph features, F_DEV 5 → 7 / dd_e depth 2 → 4).  Without
    /// this, every inference errors at batch time and the search
    /// silently degrades to uniform priors.
    fn check_feature_shapes(manifest: &Manifest) -> Result<()> {
        let zero = Position::zero();
        let arrays = zero.arrays();
        for spec in manifest.inputs_for("infer").iter().skip(1) {
            let idx = super::features::FEATURE_ORDER
                .iter()
                .position(|&n| n == spec.name)
                .with_context(|| format!("manifest input `{}` unknown to this build", spec.name))?;
            let per: i64 = spec.dims[1..].iter().product();
            crate::ensure!(
                per as usize == arrays[idx].len(),
                "artifact feature `{}` has {} elements per position but this build \
                 expects {} — stale artifacts; rerun `make artifacts`",
                spec.name,
                per,
                arrays[idx].len()
            );
        }
        Ok(())
    }

    pub fn platform(&self) -> String {
        self.runtime.platform()
    }

    /// Stack up to B positions into the batched feature literals.
    fn batch_literals(
        &self,
        positions: &[&Position],
        batch: usize,
        dims_of: &[super::manifest::InputSpec],
    ) -> Result<Vec<Literal>> {
        crate::ensure!(positions.len() <= batch, "batch overflow");
        let mut out = Vec::with_capacity(dims_of.len());
        for spec in dims_of {
            let per: i64 = spec.dims[1..].iter().product();
            let mut flat = vec![0.0f32; (batch as i64 * per) as usize];
            for (bi, pos) in positions.iter().enumerate() {
                let arrays = pos.arrays();
                let idx = super::features::FEATURE_ORDER
                    .iter()
                    .position(|&n| n == spec.name)
                    .with_context(|| format!("unknown feature {}", spec.name))?;
                let src = arrays[idx];
                crate::ensure!(
                    src.len() == per as usize,
                    "feature {} length {} != {}",
                    spec.name,
                    src.len(),
                    per
                );
                flat[bi * per as usize..(bi + 1) * per as usize].copy_from_slice(src);
            }
            out.push(literal_f32(&flat, &spec.dims)?);
        }
        Ok(out)
    }

    /// Prior probabilities for up to B_INFER positions; returns one
    /// N_CAND-length normalized vector per input position.
    pub fn infer_batch(
        &self,
        params: &[f32],
        positions: &[&Position],
    ) -> Result<Vec<Vec<f32>>> {
        crate::ensure!(params.len() == self.param_count, "param count mismatch");
        let specs = self.manifest.inputs_for("infer");
        let mut inputs =
            vec![literal_f32(params, &[self.param_count as i64])?];
        inputs.extend(self.batch_literals(positions, B_INFER, &specs[1..])?);
        let out = self.infer.run(&inputs)?;
        let flat = to_vec_f32(&out[0])?;
        crate::ensure!(flat.len() == B_INFER * N_CAND);
        Ok(positions
            .iter()
            .enumerate()
            .map(|(bi, _)| flat[bi * N_CAND..(bi + 1) * N_CAND].to_vec())
            .collect())
    }

    /// One Adam step over up to B_TRAIN examples.
    /// Returns (new params, new m, new v, loss).
    #[allow(clippy::too_many_arguments)]
    pub fn train_step(
        &self,
        params: &[f32],
        m: &[f32],
        v: &[f32],
        step: f32,
        positions: &[&Position],
        target_pi: &[Vec<f32>],
        example_mask: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, f32)> {
        crate::ensure!(positions.len() == target_pi.len());
        crate::ensure!(positions.len() <= B_TRAIN);
        let specs = self.manifest.inputs_for("train");
        let pc = self.param_count as i64;
        let mut inputs = vec![
            literal_f32(params, &[pc])?,
            literal_f32(m, &[pc])?,
            literal_f32(v, &[pc])?,
            scalar_f32(step),
        ];
        inputs.extend(self.batch_literals(positions, B_TRAIN, &specs[4..specs.len() - 2])?);
        // target_pi (B_TRAIN, N_CAND)
        let mut pi_flat = vec![0.0f32; B_TRAIN * N_CAND];
        for (bi, pi) in target_pi.iter().enumerate() {
            crate::ensure!(pi.len() <= N_CAND);
            pi_flat[bi * N_CAND..bi * N_CAND + pi.len()].copy_from_slice(pi);
        }
        inputs.push(literal_f32(&pi_flat, &[B_TRAIN as i64, N_CAND as i64])?);
        // example mask
        let mut mask = vec![0.0f32; B_TRAIN];
        mask[..example_mask.len()].copy_from_slice(example_mask);
        inputs.push(literal_f32(&mask, &[B_TRAIN as i64])?);

        let out = self.train.run(&inputs)?;
        crate::ensure!(out.len() == 4, "train step must return 4 outputs");
        let new_p = to_vec_f32(&out[0])?;
        let new_m = to_vec_f32(&out[1])?;
        let new_v = to_vec_f32(&out[2])?;
        let loss = to_vec_f32(&out[3])?[0];
        Ok((new_p, new_m, new_v, loss))
    }
}

/// [`PriorProvider`] backed by the compiled GNN, with a per-search cache
/// keyed on (decided slots, next group).
pub struct GnnPrior<'a> {
    pub svc: &'a GnnService,
    pub builder: super::features::FeatureBuilder<'a>,
    pub params: Vec<f32>,
    cache: HashMap<(Vec<u32>, usize), Vec<f32>>,
    pub evals: usize,
}

impl<'a> GnnPrior<'a> {
    pub fn new(
        svc: &'a GnnService,
        builder: super::features::FeatureBuilder<'a>,
        params: Vec<f32>,
    ) -> Self {
        Self { svc, builder, params, cache: HashMap::new(), evals: 0 }
    }

    fn key(strategy: &Strategy, group: usize) -> (Vec<u32>, usize) {
        let slots: Vec<u32> = strategy
            .slots
            .iter()
            .map(|s| match s {
                None => u32::MAX,
                Some(a) => (a.mask as u32) << 2 | a.option.index() as u32,
            })
            .collect();
        (slots, group)
    }
}

impl PriorProvider for GnnPrior<'_> {
    fn priors(
        &mut self,
        state: &Strategy,
        group: usize,
        outcome: &SimOutcome,
        actions: &[Action],
    ) -> Vec<f32> {
        let key = Self::key(state, group);
        if let Some(hit) = self.cache.get(&key) {
            return hit[..actions.len()].to_vec();
        }
        let pos = self.builder.build(state, outcome, group);
        self.evals += 1;
        match self.svc.infer_batch(&self.params, &[&pos]) {
            Ok(pr) => {
                let mut full = pr.into_iter().next().unwrap();
                // Smooth with a uniform component (AlphaZero-style): a
                // confidently-wrong prior must not be able to starve the
                // PUCT exploration term on out-of-distribution inputs.
                let eps = 0.25f32;
                let u = 1.0 / actions.len() as f32;
                for p in full.iter_mut().take(actions.len()) {
                    *p = (1.0 - eps) * *p + eps * u;
                }
                let out = full[..actions.len()].to_vec();
                self.cache.insert(key, full);
                out
            }
            Err(e) => {
                // Degrade to uniform rather than aborting a search.  Warn
                // once per process: a serving daemon on the stub runtime
                // hits this on every eval, and per-eval stderr writes
                // would swamp the daemon's log.
                static WARN_ONCE: std::sync::Once = std::sync::Once::new();
                WARN_ONCE.call_once(|| {
                    eprintln!(
                        "GNN inference failed ({e}); falling back to uniform \
                         (warning suppressed after first occurrence)"
                    );
                });
                vec![1.0 / actions.len() as f32; actions.len()]
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets::testbed;
    use crate::dist::Lowering;
    use crate::gnn::features::FeatureBuilder;
    use crate::graph::grouping::group_ops;
    use crate::models;
    use crate::profile::{unique_gpus, CommModel, CostModel};
    use crate::strategy::enumerate_actions;

    fn service() -> Option<GnnService> {
        if !std::path::Path::new("artifacts/gnn_infer.hlo.txt").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(GnnService::load("artifacts").unwrap())
    }

    #[test]
    fn stale_artifact_shapes_rejected_at_load() {
        // A manifest compiled before the link-graph features (dd_e depth
        // 2 instead of 4) must fail the shape check with a rerun hint,
        // not surface later as per-batch inference errors.
        use crate::gnn::features::{F_DD, N_DEV};
        let stale = format!(
            "input infer 0 params 10\ninput infer 1 dd_e 8,{N_DEV},{N_DEV},2\n"
        );
        let m = Manifest::parse(&stale).unwrap();
        let err = GnnService::check_feature_shapes(&m).unwrap_err().to_string();
        assert!(err.contains("stale artifacts"), "{err}");
        let fresh = format!(
            "input infer 0 params 10\ninput infer 1 dd_e 8,{N_DEV},{N_DEV},{F_DD}\n"
        );
        let m = Manifest::parse(&fresh).unwrap();
        assert!(GnnService::check_feature_shapes(&m).is_ok());
        // Unknown feature names are rejected too.
        let unknown = "input infer 0 params 10\ninput infer 1 mystery 8,2\n";
        let m = Manifest::parse(unknown).unwrap();
        assert!(GnnService::check_feature_shapes(&m).is_err());
    }

    #[test]
    fn infer_produces_masked_distributions() {
        let Some(svc) = service() else { return };
        let topo = testbed();
        let m = models::vgg19(8, 0.25);
        let cost = CostModel::profile(&m.ops, &unique_gpus(&topo), 0.0, 1);
        let gg = group_ops(&m, &cost, 12, 7);
        let comm = CommModel::fit(3);
        let low = Lowering::new(&gg, &topo, &cost, &comm);
        let actions = enumerate_actions(&topo);
        let fb = FeatureBuilder::new(&gg, &topo, &actions);
        let s = Strategy::empty(gg.num_groups());
        let out = low.evaluate(&s);
        let pos = fb.build(&s, &out, low.order[0]);

        let params =
            crate::gnn::params::load_params("artifacts/params_init.bin").unwrap();
        let priors = svc.infer_batch(&params, &[&pos]).unwrap();
        assert_eq!(priors.len(), 1);
        let p = &priors[0];
        let sum: f32 = p.iter().sum();
        assert!((sum - 1.0).abs() < 1e-3, "sum {sum}");
        // Masked candidates ~ zero probability.
        for ci in actions.len()..N_CAND {
            assert!(p[ci] < 1e-6);
        }
        // Batched inference matches itself across slots.
        let priors2 = svc.infer_batch(&params, &[&pos, &pos]).unwrap();
        for (a, b) in priors2[0].iter().zip(&priors2[1]) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn train_step_runs_and_changes_params() {
        let Some(svc) = service() else { return };
        let topo = testbed();
        let m = models::vgg19(8, 0.25);
        let cost = CostModel::profile(&m.ops, &unique_gpus(&topo), 0.0, 1);
        let gg = group_ops(&m, &cost, 12, 7);
        let comm = CommModel::fit(3);
        let low = Lowering::new(&gg, &topo, &cost, &comm);
        let actions = enumerate_actions(&topo);
        let fb = FeatureBuilder::new(&gg, &topo, &actions);
        let s = Strategy::empty(gg.num_groups());
        let out = low.evaluate(&s);
        let pos = fb.build(&s, &out, low.order[0]);

        let params =
            crate::gnn::params::load_params("artifacts/params_init.bin").unwrap();
        let zeros = vec![0.0f32; params.len()];
        let mut pi = vec![0.0f32; N_CAND];
        pi[0] = 0.7;
        pi[1] = 0.3;
        let (p2, m2, v2, loss) = svc
            .train_step(&params, &zeros, &zeros, 0.0, &[&pos], &[pi], &[1.0])
            .unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert_eq!(p2.len(), params.len());
        assert!(p2.iter().zip(&params).any(|(a, b)| a != b));
        assert!(m2.iter().any(|&x| x != 0.0));
        assert!(v2.iter().any(|&x| x != 0.0));
    }
}
