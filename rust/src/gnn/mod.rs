//! The Rust side of TAG's heterogeneous GNN (paper §4.2.1).
//!
//! The network itself lives in `python/compile/model.py` and is AOT-
//! lowered to HLO text by `make artifacts`; this module owns everything
//! needed to *use* it from the search hot path:
//!
//! * [`manifest`] — parse the AOT shape manifest,
//! * [`params`] — flat f32 parameter (and Adam moment) vectors on disk,
//! * [`features`] — build the fixed-shape feature tensors of Table 1
//!   from (group graph, topology, partial strategy, simulator feedback),
//! * [`service`] — compiled-executable wrapper: batched prior inference
//!   and the Adam train step, plus the [`mcts::PriorProvider`]
//!   implementation backed by it.
//!
//! [`mcts::PriorProvider`]: crate::mcts::PriorProvider

pub mod features;
pub mod manifest;
pub mod params;
pub mod service;

pub use features::{FeatureBuilder, Position};
pub use manifest::Manifest;
pub use service::{GnnPrior, GnnService};
