//! Discrete-event simulator (paper §4.3.2).
//!
//! The paper's simulator keeps one FIFO queue per device, inserts an op
//! into its queue when all inputs are ready, and tracks tensor lifetimes
//! by reference counting for peak-memory estimation.  This module
//! implements that engine over an abstract [`TaskGraph`]: *resources*
//! (device compute slots, machine buses, machine NICs, a collective
//! channel) execute *tasks* serially in ready-order; the [`dist`]
//! compiler lowers (group graph, topology, strategy) into such a task
//! graph and interprets the schedule for memory and feedback features.
//!
//! ## Link contention
//!
//! Tasks may additionally carry a [`LinkLoad`]: the physical link ids
//! (into the topology's [`crate::cluster::LinkGraph`]) the task's bytes
//! traverse plus the bandwidth-scalable share of its duration.  The
//! engine keeps a per-link occupancy count and stretches the scalable
//! share by the worst sharing factor along the path — concurrent
//! transfers through one oversubscribed spine link each get a fraction
//! of it.  Tasks without loads behave exactly as before the contention
//! model existed (bit-identical schedules), which is how flat clique
//! topologies keep their pre-link-graph behavior.
//!
//! ## Frontier restart
//!
//! [`Simulator::resume`] re-runs only the tail of a simulation: given a
//! previous [`Schedule`], a task mapping and a *divergence horizon* (a
//! time before which the caller proves the two task graphs dispatch
//! identically), it replays every mapped task that started before the
//! horizon and runs the event loop for the rest — bit-identical to a
//! full run.  The [`dist::fragments`] incremental-evaluation layer
//! computes those horizons for neighboring search strategies.
//!
//! [`dist`]: crate::dist
//! [`dist::fragments`]: crate::dist::fragments

pub mod engine;

pub use engine::{critical_path, simulate, CriticalSegment, Schedule, Simulator};

/// What a task models — used for runtime-feedback attribution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TaskKind {
    /// One replica of an op group's computation: (group, device group).
    Compute { group: usize, dev_group: usize },
    /// A tensor transfer between groups: (producer group, consumer group,
    /// src device group, dst device group).
    Transfer { from: usize, to: usize, src_dg: usize, dst_dg: usize },
    /// Gradient synchronization for a group (AllReduce or PS).
    Sync { group: usize },
    /// Zero-duration structural marker (barriers etc.).
    Marker,
}

/// The physical-link footprint of a transfer task: which links its
/// bytes traverse and how much of its duration scales with the
/// bandwidth share it gets on them.  The effective duration becomes
/// `duration + scalable_s * sharing` where `sharing` is the worst
/// per-link occupancy (including this transfer) at dispatch time — a
/// start-time snapshot that keeps the engine event-driven.
#[derive(Clone, Debug, PartialEq)]
pub struct LinkLoad {
    /// Link ids into the topology's link graph; must be `< num_links`.
    /// Shared with the route table (`Arc`), so stamping a task is a
    /// refcount bump, not an allocation.
    pub links: std::sync::Arc<[u32]>,
    /// Seconds of pure bandwidth time at an uncontended full share.
    pub scalable_s: f64,
}

#[derive(Clone, Debug)]
pub struct Task {
    pub resource: usize,
    /// Fixed duration share (latency, or the whole duration for tasks
    /// without a [`LinkLoad`]).
    pub duration: f64,
    pub deps: Vec<usize>,
    pub kind: TaskKind,
    /// Contention footprint; `None` = no link sharing (the duration is
    /// taken verbatim).
    pub load: Option<LinkLoad>,
}

/// A simulation input: tasks + the number of serial resources + the
/// number of physical links the tasks' [`LinkLoad`]s may reference.
#[derive(Clone, Debug, Default)]
pub struct TaskGraph {
    pub tasks: Vec<Task>,
    pub num_resources: usize,
    pub num_links: usize,
}

impl TaskGraph {
    pub fn new(num_resources: usize) -> Self {
        Self { tasks: Vec::new(), num_resources, num_links: 0 }
    }

    pub fn push(&mut self, t: Task) -> usize {
        // A NaN (or negative/infinite) duration would silently corrupt the
        // engine's heap ordering — fail fast at construction time instead.
        assert!(
            t.duration.is_finite() && t.duration >= 0.0,
            "task duration must be finite and non-negative, got {}",
            t.duration
        );
        if let Some(load) = &t.load {
            assert!(
                load.scalable_s.is_finite() && load.scalable_s >= 0.0,
                "scalable duration must be finite and non-negative, got {}",
                load.scalable_s
            );
            debug_assert!(load.links.iter().all(|&l| (l as usize) < self.num_links));
        }
        debug_assert!(t.resource < self.num_resources);
        debug_assert!(t.deps.iter().all(|&d| d < self.tasks.len()));
        self.tasks.push(t);
        self.tasks.len() - 1
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(resource: usize, duration: f64, deps: &[usize]) -> Task {
        Task { resource, duration, deps: deps.to_vec(), kind: TaskKind::Marker, load: None }
    }

    #[test]
    fn chain_on_one_resource() {
        let mut tg = TaskGraph::new(1);
        let a = tg.push(t(0, 1.0, &[]));
        let b = tg.push(t(0, 2.0, &[a]));
        tg.push(t(0, 3.0, &[b]));
        let s = simulate(&tg);
        assert_eq!(s.makespan, 6.0);
        assert_eq!(s.finish[2], 6.0);
        assert_eq!(s.start[1], 1.0);
    }

    #[test]
    fn independent_tasks_parallel_across_resources() {
        let mut tg = TaskGraph::new(3);
        for r in 0..3 {
            tg.push(t(r, 2.0, &[]));
        }
        let s = simulate(&tg);
        assert_eq!(s.makespan, 2.0);
    }

    #[test]
    fn resource_serialization() {
        // Two independent tasks on the same resource must serialize.
        let mut tg = TaskGraph::new(1);
        tg.push(t(0, 2.0, &[]));
        tg.push(t(0, 2.0, &[]));
        let s = simulate(&tg);
        assert_eq!(s.makespan, 4.0);
    }

    #[test]
    fn diamond_dependencies() {
        let mut tg = TaskGraph::new(4);
        let a = tg.push(t(0, 1.0, &[]));
        let b = tg.push(t(1, 5.0, &[a]));
        let c = tg.push(t(2, 2.0, &[a]));
        tg.push(t(3, 1.0, &[b, c]));
        let s = simulate(&tg);
        assert_eq!(s.makespan, 7.0); // 1 + max(5,2) + 1
        assert_eq!(s.start[3], 6.0);
    }

    #[test]
    fn fifo_ready_order_respected() {
        // b becomes ready before c; the shared resource must run b first
        // even though c was pushed earlier... both ready at same time ->
        // tie broken by id.
        let mut tg = TaskGraph::new(2);
        let a = tg.push(t(0, 1.0, &[]));
        let slow = tg.push(t(0, 3.0, &[a])); // ready at 1
        let fast = tg.push(t(1, 0.5, &[a])); // other resource, ready at 1
        let on_shared = tg.push(t(1, 1.0, &[])); // ready at 0 on resource 1
        let s = simulate(&tg);
        assert_eq!(s.start[on_shared], 0.0);
        assert_eq!(s.start[fast], 1.0);
        let _ = slow;
    }

    #[test]
    fn busy_time_accounting() {
        let mut tg = TaskGraph::new(2);
        tg.push(t(0, 4.0, &[]));
        tg.push(t(1, 1.0, &[]));
        let s = simulate(&tg);
        assert_eq!(s.busy[0], 4.0);
        assert_eq!(s.busy[1], 1.0);
        assert!((s.idle_fraction(1) - 0.75).abs() < 1e-12);
        assert_eq!(s.idle_fraction(0), 0.0);
    }

    #[test]
    fn zero_duration_markers() {
        let mut tg = TaskGraph::new(1);
        let a = tg.push(t(0, 0.0, &[]));
        let b = tg.push(t(0, 1.0, &[a]));
        let s = simulate(&tg);
        assert_eq!(s.finish[b], 1.0);
    }

    #[test]
    fn empty_graph() {
        let tg = TaskGraph::new(1);
        let s = simulate(&tg);
        assert_eq!(s.makespan, 0.0);
    }

    #[test]
    #[should_panic(expected = "finite and non-negative")]
    fn nan_duration_rejected_at_push() {
        let mut tg = TaskGraph::new(1);
        tg.push(t(0, f64::NAN, &[]));
    }

    #[test]
    #[should_panic(expected = "finite and non-negative")]
    fn negative_duration_rejected_at_push() {
        let mut tg = TaskGraph::new(1);
        tg.push(t(0, -1.0, &[]));
    }

    #[test]
    #[should_panic(expected = "scalable duration")]
    fn nan_scalable_duration_rejected_at_push() {
        let mut tg = TaskGraph::new(1);
        tg.num_links = 1;
        let mut task = t(0, 0.0, &[]);
        task.load = Some(LinkLoad { links: vec![0].into(), scalable_s: f64::NAN });
        tg.push(task);
    }
}
