//! The event-driven scheduling core: serial resources, FIFO-by-ready-time
//! queues (matching TensorFlow's default executor behaviour that the
//! paper's simulator mimics), deterministic tie-breaking by task id.
//!
//! Two properties the `dist` hot path depends on:
//!
//! * **Only-ready dispatch** — a resource never starts a task before its
//!   ready time.  A task enters its resource's queue at the exact moment
//!   its last dependency finishes, and the event loop only advances time
//!   through those completions, so every queue head is already ready when
//!   the resource looks at it: dispatch is simply `now.max(ready)` (the
//!   `max` is belt-and-braces; `ready <= now` is invariant).  The old
//!   idle-until-ready wake-event machinery this replaces was unreachable
//!   — `rust/tests/properties.rs` keeps it alive as a reference oracle
//!   and checks schedules are identical over the random corpus.
//! * **Buffer reuse** — [`Simulator`] keeps the indegree/successor/queue
//!   buffers across runs; `dist::Lowering` evaluates hundreds of task
//!   graphs per search, and reallocation would dominate the simulation
//!   itself.  [`simulate`] stays as the one-shot convenience wrapper.
//!
//! ## Link contention
//!
//! A task with a [`LinkLoad`](super::LinkLoad) occupies its physical
//! links for its whole execution.  At dispatch the engine bumps each
//! link's occupancy counter and stretches the task's bandwidth-scalable
//! share by the worst counter along the path (including itself):
//! `effective = duration + scalable_s * max_occupancy`.  The share is a
//! *start-time snapshot* — later arrivals slow themselves, not already
//! in-flight transfers — an approximation that keeps the engine
//! single-pass and deterministic.  Tasks without loads (all tasks
//! lowered from flat clique topologies) take `duration` verbatim, so
//! their schedules are bit-identical to the pre-contention engine.
//!
//! ## Frontier restart ([`Simulator::resume`])
//!
//! The incremental-evaluation path in `dist` re-simulates a task graph
//! that differs from a previously simulated one only in a few groups'
//! tasks.  Because dispatch is only-ready and event-ordered, the
//! executed prefix of a simulation is a pure function of the tasks whose
//! ready times precede the first divergence: `resume` **replays** the
//! previous [`Schedule`]'s values for every unchanged task that started
//! before a caller-proven divergence horizon (restoring queue contents,
//! in-flight events, link occupancy, and per-resource busy sums
//! bit-exactly — [`Schedule::eff`] records each task's
//! contention-stretched duration for precisely this purpose), then runs
//! the ordinary event loop ([`drain`]) over the remaining cone.  The
//! result is bit-identical to a from-scratch [`Simulator::run`] of the
//! same graph; `rust/tests/properties.rs` pins this over a random flip
//! corpus.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::TaskGraph;

/// Simulation output: per-task schedule + per-resource utilization.
#[derive(Clone, Debug, Default)]
pub struct Schedule {
    pub start: Vec<f64>,
    pub finish: Vec<f64>,
    /// Effective (contention-stretched) duration actually charged per
    /// task.  Not always bit-equal to `finish - start` under floating
    /// point, which is why the dispatch-time value is recorded: the
    /// frontier-restart replay must reproduce `busy` sums exactly.
    pub eff: Vec<f64>,
    pub busy: Vec<f64>,
    pub makespan: f64,
}

impl Schedule {
    /// Fraction of the makespan a resource spent idle.
    pub fn idle_fraction(&self, resource: usize) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        (1.0 - self.busy[resource] / self.makespan).clamp(0.0, 1.0)
    }
}

/// Min-heap key: (time, id) with deterministic ordering.
#[derive(PartialEq)]
struct Key(f64, usize);

impl Eq for Key {}
impl PartialOrd for Key {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Key {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed for BinaryHeap (max-heap) -> min-heap behaviour.
        other
            .0
            .partial_cmp(&self.0)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.1.cmp(&self.1))
    }
}

/// Reusable simulation engine.  `run` never allocates the dependency
/// buffers after the first call at a given problem size.
#[derive(Default)]
pub struct Simulator {
    indeg: Vec<usize>,
    succs: Vec<Vec<usize>>,
    ready_at: Vec<f64>,
    queues: Vec<BinaryHeap<Key>>,
    resource_free: Vec<bool>,
    events: BinaryHeap<Key>,
    /// In-flight transfer count per physical link id.
    link_active: Vec<u32>,
}

/// Try to start work on resource `r` at time `now`.  Tasks are enqueued
/// exactly when they become ready, so the head's ready time never lies
/// in the future; `now.max(ready)` keeps only-ready dispatch explicit.
/// Starting a task with a link load bumps its links' occupancy and
/// stretches the scalable share by the worst sharing factor.
#[allow(clippy::too_many_arguments)]
fn try_start(
    r: usize,
    now: f64,
    tg: &TaskGraph,
    queues: &mut [BinaryHeap<Key>],
    resource_free: &mut [bool],
    link_active: &mut [u32],
    start: &mut [f64],
    eff: &mut [f64],
    busy: &mut [f64],
    events: &mut BinaryHeap<Key>,
) {
    if !resource_free[r] {
        return;
    }
    let Some(Key(ready, id)) = queues[r].pop() else {
        return;
    };
    let begin = now.max(ready);
    let task = &tg.tasks[id];
    let mut dur = task.duration;
    if let Some(load) = &task.load {
        let mut sharing = 0u32;
        for &l in load.links.iter() {
            link_active[l as usize] += 1;
            sharing = sharing.max(link_active[l as usize]);
        }
        dur += load.scalable_s * sharing as f64;
    }
    start[id] = begin;
    eff[id] = dur;
    busy[r] += dur;
    resource_free[r] = false;
    events.push(Key(begin + dur, id));
}

/// The event loop shared by [`Simulator::run`] and
/// [`Simulator::resume`]: pop completions in (time, id) order, release
/// successors at their exact ready times, and refill the freed resource
/// plus any resource whose queue just gained a task.  Returns the number
/// of completions processed.
#[allow(clippy::too_many_arguments)]
fn drain(
    tg: &TaskGraph,
    indeg: &mut [usize],
    succs: &[Vec<usize>],
    ready_at: &mut [f64],
    queues: &mut [BinaryHeap<Key>],
    resource_free: &mut [bool],
    link_active: &mut [u32],
    events: &mut BinaryHeap<Key>,
    start: &mut [f64],
    finish: &mut [f64],
    eff: &mut [f64],
    busy: &mut [f64],
) -> usize {
    let mut completed = 0usize;
    while let Some(Key(t_ev, id)) = events.pop() {
        let now = t_ev;
        finish[id] = t_ev;
        completed += 1;
        let r = tg.tasks[id].resource;
        resource_free[r] = true;
        if let Some(load) = &tg.tasks[id].load {
            for &l in load.links.iter() {
                link_active[l as usize] -= 1;
            }
        }
        // Release successors (enqueued exactly at their ready time).
        for &s in &succs[id] {
            indeg[s] -= 1;
            ready_at[s] = ready_at[s].max(t_ev);
            if indeg[s] == 0 {
                queues[tg.tasks[s].resource].push(Key(ready_at[s], s));
            }
        }
        // Start next work on this resource and any resource whose queue
        // just gained a task.
        try_start(
            r,
            now,
            tg,
            queues,
            resource_free,
            link_active,
            start,
            eff,
            busy,
            events,
        );
        for &s in &succs[id] {
            let rs = tg.tasks[s].resource;
            try_start(
                rs,
                now,
                tg,
                queues,
                resource_free,
                link_active,
                start,
                eff,
                busy,
                events,
            );
        }
    }
    completed
}

impl Simulator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Clear and resize the reusable buffers for a graph of `n` tasks on
    /// `nr` resources.
    fn reset(&mut self, n: usize, nr: usize, num_links: usize) {
        self.indeg.clear();
        self.indeg.resize(n, 0);
        self.ready_at.clear();
        self.ready_at.resize(n, 0.0);
        for s in self.succs.iter_mut() {
            s.clear();
        }
        if self.succs.len() < n {
            self.succs.resize_with(n, Vec::new);
        }
        for q in self.queues.iter_mut() {
            q.clear();
        }
        if self.queues.len() < nr {
            self.queues.resize_with(nr, BinaryHeap::new);
        }
        self.resource_free.clear();
        self.resource_free.resize(nr, true);
        self.events.clear();
        self.link_active.clear();
        self.link_active.resize(num_links, 0);
    }

    /// Run the task graph to completion. Panics on dependency cycles
    /// (impossible for graphs built through `TaskGraph::push`).
    pub fn run(&mut self, tg: &TaskGraph) -> Schedule {
        let n = tg.tasks.len();
        let nr = tg.num_resources;
        self.reset(n, nr, tg.num_links);

        let Simulator { indeg, succs, ready_at, queues, resource_free, events, link_active } =
            self;

        for (i, t) in tg.tasks.iter().enumerate() {
            indeg[i] = t.deps.len();
            for &d in &t.deps {
                succs[d].push(i);
            }
        }

        let mut start = vec![f64::NAN; n];
        let mut finish = vec![f64::NAN; n];
        let mut eff = vec![0.0; n];
        let mut busy = vec![0.0; nr];

        for i in 0..n {
            if indeg[i] == 0 {
                queues[tg.tasks[i].resource].push(Key(0.0, i));
            }
        }
        for r in 0..nr {
            try_start(
                r,
                0.0,
                tg,
                queues,
                resource_free,
                link_active,
                &mut start,
                &mut eff,
                &mut busy,
                events,
            );
        }

        let completed = drain(
            tg,
            indeg,
            succs,
            ready_at,
            queues,
            resource_free,
            link_active,
            events,
            &mut start,
            &mut finish,
            &mut eff,
            &mut busy,
        );

        assert_eq!(completed, n, "dependency cycle or unreachable tasks");
        let makespan = finish.iter().copied().fold(0.0f64, f64::max);
        Schedule { start, finish, eff, busy, makespan }
    }

    /// Re-simulate `tg` by replaying the prefix of a previous schedule
    /// up to a divergence `horizon` and event-looping the rest.
    ///
    /// `map[i]` gives, for each task of `tg`, the id of a task in the
    /// previously simulated graph that is **provably identical** up to
    /// and including its dependency structure (`usize::MAX` = no such
    /// task).  `prev` is that previous graph's schedule.  The caller
    /// must guarantee the *divergence-horizon contract*:
    ///
    /// 1. every task of the previous graph that started before `horizon`
    ///    is mapped to by some task of `tg`, and
    /// 2. every unmapped task of `tg` (and every task of the previous
    ///    graph not mapped to) becomes ready at or after `horizon`.
    ///
    /// Under that contract a from-scratch [`Simulator::run`] of `tg`
    /// executes the mapped prefix with exactly the previous schedule's
    /// times, so replaying it is bit-identical: replay restores per-task
    /// start/finish/eff, per-resource busy sums (in dispatch order —
    /// same-start ties on a serial resource can only involve
    /// zero-duration tasks, whose `+0.0` contributions are
    /// order-immune), queued-but-undispatched tasks at their exact ready
    /// keys, in-flight completion events, and link occupancy.  `horizon`
    /// must be positive and finite; callers handle the degenerate cases
    /// (no divergence / divergence at t=0) themselves.
    pub fn resume(
        &mut self,
        tg: &TaskGraph,
        prev: &Schedule,
        map: &[usize],
        horizon: f64,
    ) -> Schedule {
        let n = tg.tasks.len();
        let nr = tg.num_resources;
        debug_assert_eq!(map.len(), n);
        debug_assert!(horizon > 0.0 && horizon.is_finite());
        self.reset(n, nr, tg.num_links);

        let Simulator { indeg, succs, ready_at, queues, resource_free, events, link_active } =
            self;

        for (i, t) in tg.tasks.iter().enumerate() {
            for &d in &t.deps {
                succs[d].push(i);
            }
        }

        let mut start = vec![f64::NAN; n];
        let mut finish = vec![f64::NAN; n];
        let mut eff = vec![0.0; n];
        let mut busy = vec![0.0; nr];
        let mut completed = 0usize;
        // Completed strictly before the horizon (replayed and finished).
        let mut done = vec![false; n];

        let replayed = |i: usize| map[i] != usize::MAX && prev.start[map[i]] < horizon;

        // ---- phase 1: replay the executed prefix in dispatch order.
        let mut replay: Vec<usize> = (0..n).filter(|&i| replayed(i)).collect();
        replay.sort_by(|&a, &b| {
            prev.start[map[a]]
                .partial_cmp(&prev.start[map[b]])
                .unwrap_or(Ordering::Equal)
                .then(a.cmp(&b))
        });
        for &i in &replay {
            let o = map[i];
            start[i] = prev.start[o];
            finish[i] = prev.finish[o];
            eff[i] = prev.eff[o];
            busy[tg.tasks[i].resource] += prev.eff[o];
            if prev.finish[o] < horizon {
                done[i] = true;
                completed += 1;
            } else {
                // In flight at the horizon: its completion event is still
                // pending, its resource is occupied, its links are held.
                resource_free[tg.tasks[i].resource] = false;
                events.push(Key(prev.finish[o], i));
                if let Some(load) = &tg.tasks[i].load {
                    for &l in load.links.iter() {
                        link_active[l as usize] += 1;
                    }
                }
            }
        }

        // ---- phase 2: reconstruct indegrees, ready times, and queue
        // contents for everything not yet dispatched.
        for i in 0..n {
            if replayed(i) {
                continue;
            }
            let mut live = 0usize;
            let mut ready = 0.0f64;
            for &d in &tg.tasks[i].deps {
                if done[d] {
                    ready = ready.max(finish[d]);
                } else {
                    live += 1;
                }
            }
            indeg[i] = live;
            ready_at[i] = ready;
            if live == 0 {
                queues[tg.tasks[i].resource].push(Key(ready, i));
            }
        }

        // Belt-and-braces: a no-op on a consistent frontier (every free
        // resource has an empty queue), but guarantees progress instead
        // of a completion-count panic if a caller ever under-proves its
        // horizon.
        for r in 0..nr {
            try_start(
                r,
                0.0,
                tg,
                queues,
                resource_free,
                link_active,
                &mut start,
                &mut eff,
                &mut busy,
                events,
            );
        }

        // ---- phase 3: ordinary event loop over the remaining cone.
        completed += drain(
            tg,
            indeg,
            succs,
            ready_at,
            queues,
            resource_free,
            link_active,
            events,
            &mut start,
            &mut finish,
            &mut eff,
            &mut busy,
        );

        assert_eq!(completed, n, "dependency cycle or unreachable tasks");
        let makespan = finish.iter().copied().fold(0.0f64, f64::max);
        Schedule { start, finish, eff, busy, makespan }
    }
}

/// One-shot convenience wrapper around [`Simulator::run`].
pub fn simulate(tg: &TaskGraph) -> Schedule {
    Simulator::new().run(tg)
}

/// One chronological segment of the critical path ([`critical_path`]):
/// a task's execution interval, or an idle gap (`task == None`) the
/// walk could not attribute to any predecessor.
#[derive(Clone, Debug, PartialEq)]
pub struct CriticalSegment {
    pub task: Option<usize>,
    pub start: f64,
    pub end: f64,
}

/// Walk the critical path of a simulated schedule backward from the
/// makespan-defining task, through whichever predecessor released it —
/// its latest-finishing dependency or the task that freed its resource
/// (dispatch is only-ready and serial per resource, so one of the two
/// always bounds the start time).  Returns chronological segments that
/// tile `[0, makespan]`: each occupied segment is exactly its task's
/// `[start, finish]` interval, consecutive segments share endpoints
/// bit-for-bit, and any unattributed remainder becomes an explicit
/// idle segment — so the path's endpoints reproduce the makespan
/// without re-summing floating-point durations.  Deterministic:
/// ties pick the lowest task id at the head and the highest
/// predecessor id on the walk.
pub fn critical_path(tg: &TaskGraph, sched: &Schedule) -> Vec<CriticalSegment> {
    let n = tg.tasks.len();
    if n == 0 {
        return Vec::new();
    }
    // Dispatch order per resource ((start, id)-sorted), so each task
    // knows which task freed its resource.
    let mut by_resource: Vec<Vec<usize>> = vec![Vec::new(); tg.num_resources];
    for (i, task) in tg.tasks.iter().enumerate() {
        by_resource[task.resource].push(i);
    }
    let mut prev_on_resource = vec![usize::MAX; n];
    for list in &mut by_resource {
        list.sort_by(|&a, &b| {
            sched.start[a]
                .partial_cmp(&sched.start[b])
                .unwrap_or(Ordering::Equal)
                .then(a.cmp(&b))
        });
        for w in list.windows(2) {
            prev_on_resource[w[1]] = w[0];
        }
    }

    let mut cur = 0usize;
    for i in 1..n {
        if sched.finish[i] > sched.finish[cur] {
            cur = i;
        }
    }

    let better = |p: usize, b: usize| {
        sched.finish[p] > sched.finish[b] || (sched.finish[p] == sched.finish[b] && p > b)
    };
    let mut segments = Vec::new();
    loop {
        segments.push(CriticalSegment {
            task: Some(cur),
            start: sched.start[cur],
            end: sched.finish[cur],
        });
        let s = sched.start[cur];
        if s <= 0.0 {
            break;
        }
        let mut best: Option<usize> = None;
        for &d in &tg.tasks[cur].deps {
            if best.map_or(true, |b| better(d, b)) {
                best = Some(d);
            }
        }
        let p = prev_on_resource[cur];
        if p != usize::MAX && best.map_or(true, |b| better(p, b)) {
            best = Some(p);
        }
        match best {
            None => {
                segments.push(CriticalSegment { task: None, start: 0.0, end: s });
                break;
            }
            Some(p) => {
                if sched.finish[p] < s {
                    segments.push(CriticalSegment { task: None, start: sched.finish[p], end: s });
                }
                cur = p;
            }
        }
    }
    segments.reverse();
    segments
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{LinkLoad, Task, TaskKind};

    fn t(resource: usize, duration: f64, deps: &[usize]) -> Task {
        Task { resource, duration, deps: deps.to_vec(), kind: TaskKind::Marker, load: None }
    }

    fn loaded(resource: usize, fixed: f64, scalable: f64, links: &[u32]) -> Task {
        Task {
            resource,
            duration: fixed,
            deps: Vec::new(),
            kind: TaskKind::Marker,
            load: Some(LinkLoad { links: links.into(), scalable_s: scalable }),
        }
    }

    fn assert_bit_identical(a: &Schedule, b: &Schedule) {
        assert_eq!(a.start.len(), b.start.len());
        for i in 0..a.start.len() {
            assert_eq!(a.start[i].to_bits(), b.start[i].to_bits(), "start[{i}]");
            assert_eq!(a.finish[i].to_bits(), b.finish[i].to_bits(), "finish[{i}]");
            assert_eq!(a.eff[i].to_bits(), b.eff[i].to_bits(), "eff[{i}]");
        }
        for r in 0..a.busy.len() {
            assert_eq!(a.busy[r].to_bits(), b.busy[r].to_bits(), "busy[{r}]");
        }
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
    }

    #[test]
    fn reused_simulator_matches_one_shot() {
        let mut sim = Simulator::new();
        let mut tg = TaskGraph::new(2);
        let a = tg.push(t(0, 1.0, &[]));
        tg.push(t(1, 2.0, &[a]));
        let s1 = sim.run(&tg);
        // Different graph with the same engine instance.
        let mut tg2 = TaskGraph::new(3);
        let a = tg2.push(t(0, 1.0, &[]));
        let b = tg2.push(t(1, 5.0, &[a]));
        let c = tg2.push(t(2, 2.0, &[a]));
        tg2.push(t(0, 1.0, &[b, c]));
        let s2 = sim.run(&tg2);
        assert_eq!(s1.makespan, simulate(&tg).makespan);
        assert_eq!(s2.makespan, simulate(&tg2).makespan);
        assert_eq!(s2.makespan, 7.0);
        // And the original graph again — buffers fully reset.
        let s3 = sim.run(&tg);
        assert_eq!(s3.makespan, s1.makespan);
        assert_eq!(s3.start, s1.start);
    }

    #[test]
    fn tasks_never_start_before_ready_and_dispatch_in_ready_order() {
        // Two producers on separate resources release consumers onto the
        // shared resource 2 at different times; the engine must dispatch
        // them in ready order and never before their ready times.
        let mut tg = TaskGraph::new(3);
        let p_slow = tg.push(t(0, 4.0, &[]));
        let p_fast = tg.push(t(1, 1.0, &[]));
        let c_late = tg.push(t(2, 1.0, &[p_slow])); // ready at 4
        let c_early = tg.push(t(2, 2.0, &[p_fast])); // ready at 1
        let s = simulate(&tg);
        assert_eq!(s.start[c_early], 1.0);
        assert_eq!(s.start[c_late], 4.0); // early finishes at 3; late waits for ready
        for i in 0..tg.len() {
            for &d in &tg.tasks[i].deps {
                assert!(s.start[i] >= s.finish[d] - 1e-12);
            }
        }
    }

    #[test]
    fn shared_link_contention_stretches_the_later_transfer() {
        // Two transfers on different NICs (resources) share link 0: the
        // first dispatches alone (occupancy 1, full share), the second
        // dispatches while the first is in flight (occupancy 2, half
        // share => twice the scalable time).
        let mut tg = TaskGraph::new(2);
        tg.num_links = 2;
        let a = tg.push(loaded(0, 0.1, 1.0, &[0, 1]));
        let b = tg.push(loaded(1, 0.1, 1.0, &[0]));
        let s = simulate(&tg);
        assert_eq!(s.finish[a], 0.1 + 1.0);
        assert_eq!(s.finish[b], 0.1 + 2.0);
        assert_eq!(s.busy[1], 2.1);
        assert_eq!(s.eff[b], 2.1);
    }

    #[test]
    fn disjoint_links_do_not_contend() {
        let mut tg = TaskGraph::new(2);
        tg.num_links = 2;
        let a = tg.push(loaded(0, 0.0, 1.0, &[0]));
        let b = tg.push(loaded(1, 0.0, 1.0, &[1]));
        let s = simulate(&tg);
        assert_eq!(s.finish[a], 1.0);
        assert_eq!(s.finish[b], 1.0);
    }

    #[test]
    fn occupancy_releases_on_completion() {
        // The second wave of transfers starts after the first completes
        // and must get a full share again (serialized by dependency).
        let mut tg = TaskGraph::new(2);
        tg.num_links = 1;
        let a = tg.push(loaded(0, 0.0, 1.0, &[0]));
        let mut late = loaded(1, 0.0, 1.0, &[0]);
        late.deps.push(a);
        let b = tg.push(late);
        let s = simulate(&tg);
        assert_eq!(s.finish[a], 1.0);
        assert_eq!(s.finish[b], 2.0, "full share after the link frees up");
    }

    #[test]
    fn loadless_graphs_ignore_link_state() {
        // A graph with links declared but no loads behaves exactly like
        // the plain engine.
        let mut tg = TaskGraph::new(1);
        tg.num_links = 4;
        let a = tg.push(t(0, 1.0, &[]));
        tg.push(t(0, 2.0, &[a]));
        let s = simulate(&tg);
        assert_eq!(s.makespan, 3.0);
    }

    /// Chain/diamond graph shared by the resume tests: a changed tail
    /// task after an unchanged prefix.
    fn prefix_suffix_graphs(tail_dur: f64) -> (TaskGraph, TaskGraph) {
        let build = |d: f64| {
            let mut tg = TaskGraph::new(3);
            let a = tg.push(t(0, 2.0, &[]));
            let b = tg.push(t(1, 3.0, &[]));
            let c = tg.push(t(2, 1.0, &[a]));
            let e = tg.push(t(0, d, &[b, c])); // the flipped task
            tg.push(t(1, 1.0, &[e]));
            tg
        };
        (build(1.0), build(tail_dur))
    }

    #[test]
    fn resume_matches_full_run_bit_for_bit() {
        let (old_tg, new_tg) = prefix_suffix_graphs(5.0);
        let mut sim = Simulator::new();
        let prev = sim.run(&old_tg);
        // Tasks 0..3 are identical (id-mapped 1:1); tasks 3,4 diverge.
        // The changed task becomes ready at max(finish[b], finish[c]) = 3.
        let map = [0, 1, 2, usize::MAX, usize::MAX];
        let horizon = 3.0;
        let resumed = sim.resume(&new_tg, &prev, &map, horizon);
        let full = Simulator::new().run(&new_tg);
        assert_bit_identical(&resumed, &full);
        assert_eq!(resumed.makespan, 9.0);
    }

    #[test]
    fn resume_restores_in_flight_link_occupancy() {
        // Transfer `a` holds link 0 across the horizon; a post-horizon
        // transfer must still see the doubled sharing factor.
        let build = |tail: f64| {
            let mut tg = TaskGraph::new(3);
            tg.num_links = 1;
            let long = tg.push(loaded(0, 0.0, 4.0, &[0])); // holds link 0 until t=4
            let gate = tg.push(t(1, 1.0, &[]));
            let mut second = loaded(2, 0.0, 1.0, &[0]);
            second.deps.push(gate);
            let s2 = tg.push(second); // dispatches at 1 with sharing 2
            tg.push(t(1, tail, &[s2, long]));
            tg
        };
        let old_tg = build(1.0);
        let new_tg = build(7.0);
        let mut sim = Simulator::new();
        let prev = sim.run(&old_tg);
        // Divergence: only the tail task differs; it becomes ready at
        // max(finish[s2], finish[long]) = 4.  Everything earlier replays,
        // including the in-flight `long` transfer and its link hold.
        let map = [0, 1, 2, usize::MAX];
        let resumed = sim.resume(&new_tg, &prev, &map, 2.0);
        let full = Simulator::new().run(&new_tg);
        assert_bit_identical(&resumed, &full);
    }

    #[test]
    fn resume_replays_queued_but_undispatched_tasks() {
        // Two tasks contend for resource 0; the second is queued (ready,
        // undispatched) at the horizon and must dispatch at the same
        // instant a full run would.
        let build = |tail: f64| {
            let mut tg = TaskGraph::new(2);
            let first = tg.push(t(0, 5.0, &[]));
            let gate = tg.push(t(1, 1.0, &[]));
            let queued = tg.push(t(0, 2.0, &[gate])); // ready at 1, starts at 5
            tg.push(t(1, tail, &[first, queued]));
            tg
        };
        let old_tg = build(1.0);
        let new_tg = build(3.0);
        let mut sim = Simulator::new();
        let prev = sim.run(&old_tg);
        let map = [0, 1, 2, usize::MAX];
        // Horizon between the queued task's ready time and its start.
        let resumed = sim.resume(&new_tg, &prev, &map, 4.0);
        let full = Simulator::new().run(&new_tg);
        assert_bit_identical(&resumed, &full);
        assert_eq!(resumed.start[2], 5.0);
    }

    /// Segments must tile `[0, makespan]` with shared endpoints, and
    /// every occupied segment must be its task's exact interval.
    fn assert_tiles_makespan(tg: &TaskGraph, sched: &Schedule, segs: &[CriticalSegment]) {
        assert!(!segs.is_empty());
        assert_eq!(segs[0].start.to_bits(), 0.0f64.to_bits());
        assert_eq!(segs.last().unwrap().end.to_bits(), sched.makespan.to_bits());
        for w in segs.windows(2) {
            assert_eq!(w[0].end.to_bits(), w[1].start.to_bits(), "contiguous segments");
        }
        for seg in segs {
            assert!(seg.end >= seg.start);
            if let Some(t) = seg.task {
                assert_eq!(seg.start.to_bits(), sched.start[t].to_bits());
                assert_eq!(seg.end.to_bits(), sched.finish[t].to_bits());
            }
        }
    }

    #[test]
    fn critical_path_follows_the_dependency_chain() {
        let mut tg = TaskGraph::new(2);
        let a = tg.push(t(0, 2.0, &[]));
        tg.push(t(1, 0.5, &[])); // off-path filler
        let b = tg.push(t(1, 3.0, &[a]));
        let c = tg.push(t(0, 1.0, &[b]));
        let sched = simulate(&tg);
        let segs = critical_path(&tg, &sched);
        assert_tiles_makespan(&tg, &sched, &segs);
        let tasks: Vec<_> = segs.iter().filter_map(|s| s.task).collect();
        assert_eq!(tasks, vec![a, b, c]);
    }

    #[test]
    fn critical_path_walks_through_resource_queueing() {
        // The head task is released by the task that freed its
        // resource, not by its (much earlier) dependency.
        let mut tg = TaskGraph::new(2);
        let dep = tg.push(t(1, 0.5, &[]));
        let hog = tg.push(t(0, 4.0, &[]));
        let tail = tg.push(t(0, 1.0, &[dep])); // ready at 0.5, starts at 4
        let sched = simulate(&tg);
        assert_eq!(sched.start[tail], 4.0);
        let segs = critical_path(&tg, &sched);
        assert_tiles_makespan(&tg, &sched, &segs);
        let tasks: Vec<_> = segs.iter().filter_map(|s| s.task).collect();
        assert_eq!(tasks, vec![hog, tail], "path goes through the resource hog");
        assert!(segs.iter().all(|s| s.task.is_some()), "no idle on a packed resource");
    }

    #[test]
    fn critical_path_accounts_contention_stretched_transfers() {
        let mut tg = TaskGraph::new(2);
        tg.num_links = 1;
        let a = tg.push(loaded(0, 0.1, 1.0, &[0]));
        let b = tg.push(loaded(1, 0.1, 1.0, &[0])); // stretched by sharing
        let sched = simulate(&tg);
        let segs = critical_path(&tg, &sched);
        assert_tiles_makespan(&tg, &sched, &segs);
        // The stretched transfer defines the makespan.
        assert_eq!(segs.last().unwrap().task, Some(b));
        let _ = a;
    }

    #[test]
    fn critical_path_of_empty_graph_is_empty() {
        let tg = TaskGraph::new(1);
        let sched = simulate(&tg);
        assert!(critical_path(&tg, &sched).is_empty());
    }
}
