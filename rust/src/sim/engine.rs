//! The event-driven scheduling core: serial resources, FIFO-by-ready-time
//! queues (matching TensorFlow's default executor behaviour that the
//! paper's simulator mimics), deterministic tie-breaking by task id.
//!
//! Two properties the `dist` hot path depends on:
//!
//! * **Only-ready dispatch** — a resource never starts a task before its
//!   ready time.  A task enters its resource's queue at the exact moment
//!   its last dependency finishes, and the event loop only advances time
//!   through those completions, so every queue head is already ready when
//!   the resource looks at it: dispatch is simply `now.max(ready)` (the
//!   `max` is belt-and-braces; `ready <= now` is invariant).  The old
//!   idle-until-ready wake-event machinery this replaces was unreachable
//!   — `rust/tests/properties.rs` keeps it alive as a reference oracle
//!   and checks schedules are identical over the random corpus.
//! * **Buffer reuse** — [`Simulator`] keeps the indegree/successor/queue
//!   buffers across runs; `dist::Lowering` evaluates hundreds of task
//!   graphs per search, and reallocation would dominate the simulation
//!   itself.  [`simulate`] stays as the one-shot convenience wrapper.
//!
//! ## Link contention
//!
//! A task with a [`LinkLoad`](super::LinkLoad) occupies its physical
//! links for its whole execution.  At dispatch the engine bumps each
//! link's occupancy counter and stretches the task's bandwidth-scalable
//! share by the worst counter along the path (including itself):
//! `effective = duration + scalable_s * max_occupancy`.  The share is a
//! *start-time snapshot* — later arrivals slow themselves, not already
//! in-flight transfers — an approximation that keeps the engine
//! single-pass and deterministic.  Tasks without loads (all tasks
//! lowered from flat clique topologies) take `duration` verbatim, so
//! their schedules are bit-identical to the pre-contention engine.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::TaskGraph;

/// Simulation output: per-task schedule + per-resource utilization.
#[derive(Clone, Debug, Default)]
pub struct Schedule {
    pub start: Vec<f64>,
    pub finish: Vec<f64>,
    pub busy: Vec<f64>,
    pub makespan: f64,
}

impl Schedule {
    /// Fraction of the makespan a resource spent idle.
    pub fn idle_fraction(&self, resource: usize) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        (1.0 - self.busy[resource] / self.makespan).clamp(0.0, 1.0)
    }
}

/// Min-heap key: (time, id) with deterministic ordering.
#[derive(PartialEq)]
struct Key(f64, usize);

impl Eq for Key {}
impl PartialOrd for Key {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Key {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed for BinaryHeap (max-heap) -> min-heap behaviour.
        other
            .0
            .partial_cmp(&self.0)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.1.cmp(&self.1))
    }
}

/// Reusable simulation engine.  `run` never allocates the dependency
/// buffers after the first call at a given problem size.
#[derive(Default)]
pub struct Simulator {
    indeg: Vec<usize>,
    succs: Vec<Vec<usize>>,
    ready_at: Vec<f64>,
    queues: Vec<BinaryHeap<Key>>,
    resource_free: Vec<bool>,
    events: BinaryHeap<Key>,
    /// In-flight transfer count per physical link id.
    link_active: Vec<u32>,
}

/// Try to start work on resource `r` at time `now`.  Tasks are enqueued
/// exactly when they become ready, so the head's ready time never lies
/// in the future; `now.max(ready)` keeps only-ready dispatch explicit.
/// Starting a task with a link load bumps its links' occupancy and
/// stretches the scalable share by the worst sharing factor.
#[allow(clippy::too_many_arguments)]
fn try_start(
    r: usize,
    now: f64,
    tg: &TaskGraph,
    queues: &mut [BinaryHeap<Key>],
    resource_free: &mut [bool],
    link_active: &mut [u32],
    start: &mut [f64],
    busy: &mut [f64],
    events: &mut BinaryHeap<Key>,
) {
    if !resource_free[r] {
        return;
    }
    let Some(Key(ready, id)) = queues[r].pop() else {
        return;
    };
    let begin = now.max(ready);
    let task = &tg.tasks[id];
    let mut dur = task.duration;
    if let Some(load) = &task.load {
        let mut sharing = 0u32;
        for &l in load.links.iter() {
            link_active[l as usize] += 1;
            sharing = sharing.max(link_active[l as usize]);
        }
        dur += load.scalable_s * sharing as f64;
    }
    start[id] = begin;
    busy[r] += dur;
    resource_free[r] = false;
    events.push(Key(begin + dur, id));
}

impl Simulator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Run the task graph to completion. Panics on dependency cycles
    /// (impossible for graphs built through `TaskGraph::push`).
    pub fn run(&mut self, tg: &TaskGraph) -> Schedule {
        let n = tg.tasks.len();
        let nr = tg.num_resources;

        let Simulator { indeg, succs, ready_at, queues, resource_free, events, link_active } =
            self;
        indeg.clear();
        indeg.resize(n, 0);
        ready_at.clear();
        ready_at.resize(n, 0.0);
        for s in succs.iter_mut() {
            s.clear();
        }
        if succs.len() < n {
            succs.resize_with(n, Vec::new);
        }
        for q in queues.iter_mut() {
            q.clear();
        }
        if queues.len() < nr {
            queues.resize_with(nr, BinaryHeap::new);
        }
        resource_free.clear();
        resource_free.resize(nr, true);
        events.clear();
        link_active.clear();
        link_active.resize(tg.num_links, 0);

        for (i, t) in tg.tasks.iter().enumerate() {
            indeg[i] = t.deps.len();
            for &d in &t.deps {
                succs[d].push(i);
            }
        }

        let mut start = vec![f64::NAN; n];
        let mut finish = vec![f64::NAN; n];
        let mut busy = vec![0.0; nr];
        let mut completed = 0usize;

        for i in 0..n {
            if indeg[i] == 0 {
                queues[tg.tasks[i].resource].push(Key(0.0, i));
            }
        }
        for r in 0..nr {
            try_start(
                r,
                0.0,
                tg,
                queues,
                resource_free,
                link_active,
                &mut start,
                &mut busy,
                events,
            );
        }

        while let Some(Key(t_ev, id)) = events.pop() {
            let now = t_ev;
            finish[id] = t_ev;
            completed += 1;
            let r = tg.tasks[id].resource;
            resource_free[r] = true;
            if let Some(load) = &tg.tasks[id].load {
                for &l in load.links.iter() {
                    link_active[l as usize] -= 1;
                }
            }
            // Release successors (enqueued exactly at their ready time).
            for &s in &succs[id] {
                indeg[s] -= 1;
                ready_at[s] = ready_at[s].max(t_ev);
                if indeg[s] == 0 {
                    queues[tg.tasks[s].resource].push(Key(ready_at[s], s));
                }
            }
            // Start next work on this resource and any resource whose queue
            // just gained a task.
            try_start(
                r,
                now,
                tg,
                queues,
                resource_free,
                link_active,
                &mut start,
                &mut busy,
                events,
            );
            for &s in &succs[id] {
                let rs = tg.tasks[s].resource;
                try_start(
                    rs,
                    now,
                    tg,
                    queues,
                    resource_free,
                    link_active,
                    &mut start,
                    &mut busy,
                    events,
                );
            }
        }

        assert_eq!(completed, n, "dependency cycle or unreachable tasks");
        let makespan = finish.iter().copied().fold(0.0f64, f64::max);
        Schedule { start, finish, busy, makespan }
    }
}

/// One-shot convenience wrapper around [`Simulator::run`].
pub fn simulate(tg: &TaskGraph) -> Schedule {
    Simulator::new().run(tg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{LinkLoad, Task, TaskKind};

    fn t(resource: usize, duration: f64, deps: &[usize]) -> Task {
        Task { resource, duration, deps: deps.to_vec(), kind: TaskKind::Marker, load: None }
    }

    fn loaded(resource: usize, fixed: f64, scalable: f64, links: &[u32]) -> Task {
        Task {
            resource,
            duration: fixed,
            deps: Vec::new(),
            kind: TaskKind::Marker,
            load: Some(LinkLoad { links: links.into(), scalable_s: scalable }),
        }
    }

    #[test]
    fn reused_simulator_matches_one_shot() {
        let mut sim = Simulator::new();
        let mut tg = TaskGraph::new(2);
        let a = tg.push(t(0, 1.0, &[]));
        tg.push(t(1, 2.0, &[a]));
        let s1 = sim.run(&tg);
        // Different graph with the same engine instance.
        let mut tg2 = TaskGraph::new(3);
        let a = tg2.push(t(0, 1.0, &[]));
        let b = tg2.push(t(1, 5.0, &[a]));
        let c = tg2.push(t(2, 2.0, &[a]));
        tg2.push(t(0, 1.0, &[b, c]));
        let s2 = sim.run(&tg2);
        assert_eq!(s1.makespan, simulate(&tg).makespan);
        assert_eq!(s2.makespan, simulate(&tg2).makespan);
        assert_eq!(s2.makespan, 7.0);
        // And the original graph again — buffers fully reset.
        let s3 = sim.run(&tg);
        assert_eq!(s3.makespan, s1.makespan);
        assert_eq!(s3.start, s1.start);
    }

    #[test]
    fn tasks_never_start_before_ready_and_dispatch_in_ready_order() {
        // Two producers on separate resources release consumers onto the
        // shared resource 2 at different times; the engine must dispatch
        // them in ready order and never before their ready times.
        let mut tg = TaskGraph::new(3);
        let p_slow = tg.push(t(0, 4.0, &[]));
        let p_fast = tg.push(t(1, 1.0, &[]));
        let c_late = tg.push(t(2, 1.0, &[p_slow])); // ready at 4
        let c_early = tg.push(t(2, 2.0, &[p_fast])); // ready at 1
        let s = simulate(&tg);
        assert_eq!(s.start[c_early], 1.0);
        assert_eq!(s.start[c_late], 4.0); // early finishes at 3; late waits for ready
        for i in 0..tg.len() {
            for &d in &tg.tasks[i].deps {
                assert!(s.start[i] >= s.finish[d] - 1e-12);
            }
        }
    }

    #[test]
    fn shared_link_contention_stretches_the_later_transfer() {
        // Two transfers on different NICs (resources) share link 0: the
        // first dispatches alone (occupancy 1, full share), the second
        // dispatches while the first is in flight (occupancy 2, half
        // share => twice the scalable time).
        let mut tg = TaskGraph::new(2);
        tg.num_links = 2;
        let a = tg.push(loaded(0, 0.1, 1.0, &[0, 1]));
        let b = tg.push(loaded(1, 0.1, 1.0, &[0]));
        let s = simulate(&tg);
        assert_eq!(s.finish[a], 0.1 + 1.0);
        assert_eq!(s.finish[b], 0.1 + 2.0);
        assert_eq!(s.busy[1], 2.1);
    }

    #[test]
    fn disjoint_links_do_not_contend() {
        let mut tg = TaskGraph::new(2);
        tg.num_links = 2;
        let a = tg.push(loaded(0, 0.0, 1.0, &[0]));
        let b = tg.push(loaded(1, 0.0, 1.0, &[1]));
        let s = simulate(&tg);
        assert_eq!(s.finish[a], 1.0);
        assert_eq!(s.finish[b], 1.0);
    }

    #[test]
    fn occupancy_releases_on_completion() {
        // The second wave of transfers starts after the first completes
        // and must get a full share again (serialized by dependency).
        let mut tg = TaskGraph::new(2);
        tg.num_links = 1;
        let a = tg.push(loaded(0, 0.0, 1.0, &[0]));
        let mut late = loaded(1, 0.0, 1.0, &[0]);
        late.deps.push(a);
        let b = tg.push(late);
        let s = simulate(&tg);
        assert_eq!(s.finish[a], 1.0);
        assert_eq!(s.finish[b], 2.0, "full share after the link frees up");
    }

    #[test]
    fn loadless_graphs_ignore_link_state() {
        // A graph with links declared but no loads behaves exactly like
        // the plain engine.
        let mut tg = TaskGraph::new(1);
        tg.num_links = 4;
        let a = tg.push(t(0, 1.0, &[]));
        tg.push(t(0, 2.0, &[a]));
        let s = simulate(&tg);
        assert_eq!(s.makespan, 3.0);
    }
}
