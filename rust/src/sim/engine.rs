//! The event-driven scheduling core: serial resources, FIFO-by-ready-time
//! queues (matching TensorFlow's default executor behaviour that the
//! paper's simulator mimics), deterministic tie-breaking by task id.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::TaskGraph;

/// Simulation output: per-task schedule + per-resource utilization.
#[derive(Clone, Debug)]
pub struct Schedule {
    pub start: Vec<f64>,
    pub finish: Vec<f64>,
    pub busy: Vec<f64>,
    pub makespan: f64,
}

impl Schedule {
    /// Fraction of the makespan a resource spent idle.
    pub fn idle_fraction(&self, resource: usize) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        (1.0 - self.busy[resource] / self.makespan).clamp(0.0, 1.0)
    }
}

/// Min-heap key: (time, id) with deterministic ordering.
#[derive(PartialEq)]
struct Key(f64, usize);

impl Eq for Key {}
impl PartialOrd for Key {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Key {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed for BinaryHeap (max-heap) -> min-heap behaviour.
        other
            .0
            .partial_cmp(&self.0)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.1.cmp(&self.1))
    }
}

/// Run the task graph to completion. Panics on dependency cycles
/// (impossible for graphs built through `TaskGraph::push`).
pub fn simulate(tg: &TaskGraph) -> Schedule {
    let n = tg.tasks.len();
    let mut indeg: Vec<usize> = vec![0; n];
    let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, t) in tg.tasks.iter().enumerate() {
        indeg[i] = t.deps.len();
        for &d in &t.deps {
            succs[d].push(i);
        }
    }

    let mut start = vec![f64::NAN; n];
    let mut finish = vec![f64::NAN; n];
    let mut busy = vec![0.0; tg.num_resources];

    // Per-resource FIFO of ready tasks ordered by (ready time, id).
    let mut queues: Vec<BinaryHeap<Key>> =
        (0..tg.num_resources).map(|_| BinaryHeap::new()).collect();
    let mut resource_free: Vec<bool> = vec![true; tg.num_resources];

    // Event heap of task completions.
    let mut events: BinaryHeap<Key> = BinaryHeap::new();
    let mut completed = 0usize;

    let mut ready_at = vec![0.0f64; n];
    for i in 0..n {
        if indeg[i] == 0 {
            queues[tg.tasks[i].resource].push(Key(0.0, i));
        }
    }

    // Try to start a task on `r` at time `now`.
    fn try_start(
        r: usize,
        now: f64,
        tg: &TaskGraph,
        queues: &mut [BinaryHeap<Key>],
        resource_free: &mut [bool],
        start: &mut [f64],
        busy: &mut [f64],
        events: &mut BinaryHeap<Key>,
    ) {
        if !resource_free[r] {
            return;
        }
        if let Some(Key(ready, id)) = queues[r].pop() {
            let s = now.max(ready);
            start[id] = s;
            let f = s + tg.tasks[id].duration;
            busy[r] += tg.tasks[id].duration;
            resource_free[r] = false;
            events.push(Key(f, id));
        }
    }

    for r in 0..tg.num_resources {
        try_start(r, 0.0, tg, &mut queues, &mut resource_free, &mut start, &mut busy, &mut events);
    }

    while let Some(Key(t_fin, id)) = events.pop() {
        let now = t_fin;
        finish[id] = t_fin;
        completed += 1;
        let r = tg.tasks[id].resource;
        resource_free[r] = true;
        // Release successors.
        for &s in &succs[id] {
            indeg[s] -= 1;
            ready_at[s] = ready_at[s].max(t_fin);
            if indeg[s] == 0 {
                queues[tg.tasks[s].resource].push(Key(ready_at[s], s));
            }
        }
        // Start next work on this resource and any resource whose queue
        // just gained a task.
        try_start(r, now, tg, &mut queues, &mut resource_free, &mut start, &mut busy, &mut events);
        for &s in &succs[id] {
            let rs = tg.tasks[s].resource;
            try_start(
                rs,
                now,
                tg,
                &mut queues,
                &mut resource_free,
                &mut start,
                &mut busy,
                &mut events,
            );
        }
    }

    assert_eq!(completed, n, "dependency cycle or unreachable tasks");
    let makespan = finish.iter().copied().fold(0.0f64, f64::max);
    Schedule { start, finish, busy, makespan }
}
