//! # TAG — Topology-Aware Graph deployment (reproduction)
//!
//! Rust implementation of the system described in *"Expediting Distributed
//! DNN Training with Device Topology-Aware Graph Deployment"* (Zhang et al.,
//! 2023): an automatic framework that maps a DNN computation graph onto an
//! arbitrary heterogeneous device topology by combining
//!
//! * a **heterogeneous GNN** (JAX/Pallas, AOT-compiled to HLO and executed
//!   through PJRT — see [`runtime`] and [`gnn`]) that scores candidate
//!   strategy slices,
//! * **Monte-Carlo tree search** ([`mcts`]) over per-op-group placement +
//!   replication decisions,
//! * a **discrete-event simulator** ([`sim`]) that provides rewards and
//!   runtime-feedback features,
//! * a **sufficient-factor-broadcasting optimizer** ([`sfb`]) that solves a
//!   min-cut-style ILP per gradient, and
//! * a **graph compiler** ([`dist`]) that rewrites the computation graph
//!   (Split/Concat/AddN/AllReduce insertion) for a chosen strategy.
//!
//! Substrates the paper depends on are implemented here as well: a METIS
//! replacement ([`partition`]), a model zoo ([`models`]), cluster topology
//! descriptions ([`cluster`]) and profiler cost models ([`profile`]).
//!
//! The layering follows the session architecture: Python/JAX only ever runs
//! at build time (`make artifacts`); the search/serving hot path is pure
//! Rust + PJRT.

pub mod cluster;
pub mod coordinator;
pub mod dist;
pub mod gnn;
pub mod graph;
pub mod mcts;
pub mod models;
pub mod partition;
pub mod profile;
pub mod runtime;
pub mod sfb;
pub mod sim;
pub mod strategy;
pub mod util;
