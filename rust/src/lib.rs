//! # TAG — Topology-Aware Graph deployment (reproduction)
//!
//! Rust implementation of the system described in *"Expediting Distributed
//! DNN Training with Device Topology-Aware Graph Deployment"* (Zhang et al.,
//! 2023): an automatic framework that maps a DNN computation graph onto an
//! arbitrary heterogeneous device topology.
//!
//! ## The deployment surface: [`api`]
//!
//! All consumers — the CLI, the examples, and any serving layer — go
//! through the [`api`] module: build a [`api::PlanRequest`] (model +
//! topology + search budget), hand it to a [`api::Planner`], get back a
//! [`api::DeploymentPlan`] that is deterministic, JSON-serializable and
//! cached by structural fingerprints for repeat traffic:
//!
//! ```no_run
//! use tag::api::{PlanRequest, Planner};
//!
//! let planner = Planner::builder().build();
//! let request = PlanRequest::new(
//!     tag::models::vgg19(48, 0.5),
//!     tag::cluster::presets::testbed(),
//! )
//! .budget(200, 24)
//! .seed(42);
//! let outcome = planner.plan(&request).expect("valid request");
//! println!("{:.2}x over DP-NCCL", outcome.plan.times.speedup);
//! std::fs::write("plan.json", outcome.plan.encode()).unwrap();
//! ```
//!
//! The planner drives a pluggable [`api::SearchBackend`] — GNN-guided
//! MCTS, pure MCTS, or a baseline sweep — over the engine layers below.
//!
//! ## The serving layer: [`serve`]
//!
//! `tag serve` exposes the planner over HTTP/1.1 (std-only, like the
//! rest of the crate): `POST /plan` takes a wire
//! [`api::PlanRequest`] (model/topology by name + knobs), `GET
//! /metrics` reports the plan-cache hit rate, in-flight/coalescing
//! gauges and per-endpoint latency histograms, and `POST /shutdown`
//! drains gracefully.  A fixed worker pool behind a **bounded
//! admission queue** sheds overload with `503 Retry-After`; concurrent
//! identical requests are **coalesced** (singleflight on the request's
//! fingerprint triple) into one search with byte-identical responses —
//! the plan determinism contract (identical request fingerprint ⇒
//! identical plan bytes; `workers == 1` exact, `workers > 1`
//! seed-stable) holds across the network boundary.
//!
//! ## Fleet mode: [`fleet`]
//!
//! One planner, many tenants.  [`fleet`] layers a multi-tenant
//! scheduler over the planner: a [`fleet::ClusterState`] leases
//! exclusive device sets out of one shared topology and materializes a
//! validated residual slice per lease (the [`cluster::residual`] path
//! fault injection uses), so every admitted job is planned on exactly
//! the hardware it holds.  `tag fleet` replays a seeded Poisson job
//! stream ([`fleet::generate_jobs`]) on a deterministic virtual clock
//! under two policies — FIFO whole-cluster exclusive vs residual-aware
//! best-fit with bounded backfill — and reports makespan, mean job
//! completion time and cluster utilization; `tag serve` exposes the
//! same admission logic live as `POST /fleet/submit` / `/fleet/complete`
//! / `GET /fleet/status` with `tag_fleet_*` metrics.
//!
//! ## Observability: [`obs`]
//!
//! The planner is not a black box.  [`obs`] threads hierarchical
//! **spans** (admission → coalesce → cache lookup → prepare → search
//! workers → lowering → simulation → SFB) through the whole request
//! lifecycle on lock-free per-thread buffers; the daemon retains the
//! last N request traces in a bounded **flight recorder** exported as
//! Chrome trace-event JSON (Perfetto-loadable) via `GET /debug/trace`
//! and `tag search --trace-out`.  `tag explain --plan plan.json` /
//! `POST /explain` recompute a plan's simulated schedule and decompose
//! its critical path into named compute/comm/sync/idle components,
//! top-k contended links with sharing factors, per-group SFB savings
//! and memo/fragment/delta attribution ([`obs::explain`]).
//! **Determinism contract**: spans record wall-clock timestamps but
//! never touch plan bytes, fingerprints or RNG streams — every
//! bit-identity property holds with tracing on or off.
//!
//! ## Fault tolerance
//!
//! The planning stack degrades instead of dying.  [`cluster::faults`]
//! injects typed failures (kill a device, sever a link, degrade a
//! link's bandwidth) into any topology and rebuilds a validated
//! *residual* with re-derived routes — stranded hardware is an explicit
//! error.  [`api::PlanRequest`]`::deadline_ms` threads a cooperative
//! [`search::CancelToken`] through every search worker, so an expiring
//! budget returns the best plan found so far (flagged `timed_out` in
//! telemetry, never cached).  [`api::Planner::repair`] re-plans a prior
//! plan on the degraded topology warm-started from its surviving
//! placements (`tag repair`, `POST /repair`).  The daemon isolates
//! handler panics behind `catch_unwind` (`500` + `tag_panics_total`;
//! the worker survives) and enforces socket read/write timeouts.
//!
//! ## The engine underneath
//!
//! * a **heterogeneous GNN** (JAX/Pallas, AOT-compiled to HLO and executed
//!   through PJRT — see [`runtime`] and [`gnn`]) that scores candidate
//!   strategy slices,
//! * **Monte-Carlo tree search** ([`mcts`]) over per-op-group placement +
//!   replication decisions, guided through its [`mcts::PriorProvider`]
//!   injection point,
//! * the **parallel search engine** ([`search`]): tree storage (arena +
//!   atomic edge statistics) split from traversal, so N tree-parallel
//!   workers with virtual loss share one tree, one concurrent
//!   evaluation memo table and the batched GNN evaluator.  Request it
//!   with `PlanRequest::workers(K)` or `tag search --workers K`;
//!   `workers == 1` is byte-identical to the sequential engine, K > 1
//!   is seed-stable in its budgets/streams but explores an
//!   OS-schedule-dependent tree (see [`search`] for the contract),
//! * a **routed device link graph** ([`cluster::linkgraph`]): devices
//!   *and* switches as nodes, typed links with bandwidth/latency, and a
//!   deterministic widest-path route table.  Flat matrix topologies
//!   become clique graphs that reproduce the matrix bit for bit (the
//!   equivalence contract pinned in `rust/tests/api.rs`); hierarchical
//!   topologies (NVLink islands, multi-rack oversubscribed ethernet)
//!   route over switches and contend for shared links,
//! * a **discrete-event simulator** ([`sim`]) that provides rewards and
//!   runtime-feedback features, with per-link occupancy so concurrent
//!   transfers through a shared link split its bandwidth, and a
//!   frontier-restart mode ([`sim::Simulator::resume`]) that replays a
//!   previous schedule up to a proven divergence horizon,
//! * an **incremental-evaluation layer** ([`dist::fragments`]): a
//!   shared fragment store memoizes per-group/per-edge lowered pieces
//!   and neighboring strategies re-simulate only their divergent tail —
//!   bit-identical to full evaluation, property-pinned, `--no-delta` to
//!   disable,
//! * a **sufficient-factor-broadcasting optimizer** ([`sfb`]) that solves a
//!   min-cut-style ILP per gradient,
//! * a **graph compiler** ([`dist`]) that rewrites the computation graph
//!   (Split/Concat/AddN/AllReduce insertion) for a chosen strategy, and
//! * the **[`coordinator`]**: end-to-end search sessions and the
//!   self-play GNN trainer the planner and examples build on.
//!
//! Substrates the paper depends on are implemented here as well: a METIS
//! replacement ([`partition`]), a model zoo ([`models`]), cluster topology
//! descriptions ([`cluster`]) and profiler cost models ([`profile`]).
//!
//! The layering follows the session architecture: Python/JAX only ever runs
//! at build time (`make artifacts`); the search/serving hot path is pure
//! Rust + PJRT.

pub mod api;
pub mod cluster;
pub mod coordinator;
pub mod dist;
pub mod fleet;
pub mod gnn;
pub mod graph;
pub mod mcts;
pub mod models;
pub mod obs;
pub mod partition;
pub mod profile;
pub mod runtime;
pub mod search;
pub mod serve;
pub mod sfb;
pub mod sim;
pub mod strategy;
pub mod util;
