//! Profiler and cost models (paper §4.1.2).
//!
//! The paper's profiler runs each op on each GPU type under a sweep of
//! batch sizes and fits a linear batch model, and measures GRPC / NCCL
//! AllReduce transfer curves (1KB..1GB) fitting segmented linear models.
//! We have no physical GPUs (see DESIGN.md substitutions), so the
//! "measurements" come from a calibrated analytic device model
//! ([`DeviceModel`]) with measurement noise; everything downstream — the
//! linear batch model, the segmented-linear transfer models, the
//! simulator — consumes only the fitted profiles, exactly as in the
//! paper.

pub mod comm;
pub mod seglin;

pub use comm::CommModel;
pub use seglin::SegmentedLinear;

use crate::cluster::GpuType;
use crate::graph::ir::Op;
use crate::graph::OpKind;
use crate::util::stats::linear_fit;
use crate::util::Rng;

/// Per-op kernel-launch overhead (seconds). Dominates tiny ops, exactly
/// why the paper's batch-time model has a non-zero intercept.
pub const LAUNCH_OVERHEAD_S: f64 = 12e-6;

/// Memory bandwidth per GPU generation, bytes/s (roofline second axis).
pub fn mem_bw_bytes(gpu: &GpuType) -> f64 {
    match gpu.name {
        "V100-32G" | "V100-16G" => 900e9,
        "1080Ti" => 484e9,
        "P100" => 732e9,
        "T4" => 300e9,
        _ => 500e9,
    }
}

/// Analytic "ground truth" device model used in place of physical GPUs.
pub struct DeviceModel;

impl DeviceModel {
    /// Execution time of `op` on `gpu` with a fraction `frac` of the
    /// batch (1.0 = full batch): roofline max(compute, memory) + launch.
    pub fn op_time(op: &Op, gpu: &GpuType, frac: f64) -> f64 {
        match op.kind {
            OpKind::Placeholder | OpKind::Variable => return 0.0,
            _ => {}
        }
        let flops = op.flops * frac;
        let bytes = op.output_bytes * frac;
        let compute = flops / gpu.effective_flops();
        let memory = 2.0 * bytes / mem_bw_bytes(gpu);
        LAUNCH_OVERHEAD_S + compute.max(memory)
    }
}

/// The linear batch-time model the profiler fits per (op, GPU type):
/// `time(frac) = intercept + slope * frac` (paper: "computation time is
/// almost linear with the batch size").
#[derive(Clone, Copy, Debug)]
pub struct BatchTimeModel {
    pub intercept: f64,
    pub slope: f64,
}

impl BatchTimeModel {
    pub fn eval(&self, frac: f64) -> f64 {
        (self.intercept + self.slope * frac).max(0.0)
    }
}

/// Profiler output: fitted batch-time models for every (op, gpu-type)
/// pair plus the communication model.
pub struct CostModel {
    /// `models[op][gpu_type_index]`.
    models: Vec<Vec<BatchTimeModel>>,
    gpu_names: Vec<&'static str>,
    pub comm: CommModel,
}

/// "Typical batch sizes below 60" (§4.1.2) — profiled as fractions of the
/// full batch.
const PROFILE_FRACS: [f64; 5] = [0.1, 0.25, 0.5, 0.75, 1.0];
/// Each profile point is measured 5 times (§5.1).
const PROFILE_REPS: usize = 5;

impl CostModel {
    /// Profile the graph's ops on the given GPU types.  `noise` is the
    /// relative measurement noise (0.0 = exact; ~0.03 realistic).
    pub fn profile(ops: &[Op], gpu_types: &[GpuType], noise: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut models = Vec::with_capacity(ops.len());
        for op in ops {
            let mut per_gpu = Vec::with_capacity(gpu_types.len());
            for gpu in gpu_types {
                let mut xs = Vec::new();
                let mut ys = Vec::new();
                for &f in &PROFILE_FRACS {
                    let mut acc = 0.0;
                    for _ in 0..PROFILE_REPS {
                        let t = DeviceModel::op_time(op, gpu, f);
                        acc += t * (1.0 + noise * rng.normal());
                    }
                    xs.push(f);
                    ys.push((acc / PROFILE_REPS as f64).max(0.0));
                }
                let (intercept, slope) = linear_fit(&xs, &ys);
                per_gpu.push(BatchTimeModel { intercept, slope });
            }
            models.push(per_gpu);
        }
        Self {
            models,
            gpu_names: gpu_types.iter().map(|g| g.name).collect(),
            comm: CommModel::fit(seed ^ 0x5f5f),
        }
    }

    fn gpu_index(&self, gpu: &GpuType) -> usize {
        self.gpu_names
            .iter()
            .position(|&n| n == gpu.name)
            .unwrap_or_else(|| panic!("GPU type {} not profiled", gpu.name))
    }

    /// Predicted time of op `op_id` on `gpu` with batch fraction `frac`.
    pub fn op_time(&self, op_id: usize, gpu: &GpuType, frac: f64) -> f64 {
        self.models[op_id][self.gpu_index(gpu)].eval(frac)
    }

    /// The fitted linear batch-time model of (op, gpu) — group-level
    /// costs aggregate these (a sum of linear models is linear).
    pub fn batch_model(&self, op_id: usize, gpu: &GpuType) -> BatchTimeModel {
        self.models[op_id][self.gpu_index(gpu)]
    }

    /// Profile a graph against the distinct GPU types of a topology.
    pub fn profile_for_topology(
        ops: &[crate::graph::ir::Op],
        topo: &crate::cluster::Topology,
        noise: f64,
        seed: u64,
    ) -> Self {
        Self::profile(ops, &unique_gpus(topo), noise, seed)
    }

    /// Full-batch time averaged over all profiled GPU types (a GNN node
    /// feature).
    pub fn op_time_avg(&self, op_id: usize) -> f64 {
        let row = &self.models[op_id];
        row.iter().map(|m| m.eval(1.0)).sum::<f64>() / row.len() as f64
    }

    pub fn num_ops(&self) -> usize {
        self.models.len()
    }
}

/// The distinct GPU types present in a topology.
pub fn unique_gpus(topo: &crate::cluster::Topology) -> Vec<GpuType> {
    let mut out: Vec<GpuType> = Vec::new();
    for g in &topo.groups {
        if !out.iter().any(|x| x.name == g.gpu.name) {
            out.push(g.gpu);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{GTX1080TI, P100, V100_16G};
    use crate::graph::ir::{OpBuilder, OpKind};

    fn conv_op() -> Op {
        OpBuilder::new("conv", "Conv2D").flops(2e9).out_bytes(16e6).build()
    }

    #[test]
    fn device_model_roofline() {
        let op = conv_op();
        let t_v100 = DeviceModel::op_time(&op, &V100_16G, 1.0);
        let t_1080 = DeviceModel::op_time(&op, &GTX1080TI, 1.0);
        assert!(t_v100 < t_1080, "V100 must beat 1080Ti on compute-bound op");
        // Tiny op is launch-overhead dominated.
        let tiny = OpBuilder::new("t", "Add").flops(10.0).out_bytes(64.0).build();
        let t = DeviceModel::op_time(&tiny, &V100_16G, 1.0);
        assert!((t - LAUNCH_OVERHEAD_S).abs() / LAUNCH_OVERHEAD_S < 0.01);
    }

    #[test]
    fn variables_cost_nothing() {
        let v = OpBuilder::new("v", "Variable")
            .kind(OpKind::Variable)
            .param_bytes(1e6)
            .build();
        assert_eq!(DeviceModel::op_time(&v, &P100, 1.0), 0.0);
    }

    #[test]
    fn profile_fits_linear_batch_model() {
        let ops = vec![conv_op()];
        let cm = CostModel::profile(&ops, &[V100_16G, P100], 0.0, 1);
        let full = cm.op_time(0, &V100_16G, 1.0);
        let half = cm.op_time(0, &V100_16G, 0.5);
        let truth_full = DeviceModel::op_time(&ops[0], &V100_16G, 1.0);
        assert!((full - truth_full).abs() / truth_full < 0.02);
        // Linearity: half-batch ~ intercept + half the variable part.
        assert!(half < full && half > 0.4 * full);
    }

    #[test]
    fn profile_with_noise_stays_close() {
        let ops = vec![conv_op()];
        let cm = CostModel::profile(&ops, &[V100_16G], 0.03, 7);
        let truth = DeviceModel::op_time(&ops[0], &V100_16G, 1.0);
        let fit = cm.op_time(0, &V100_16G, 1.0);
        assert!((fit - truth).abs() / truth < 0.1, "fit {fit} truth {truth}");
    }

    #[test]
    fn avg_time_between_extremes() {
        let ops = vec![conv_op()];
        let cm = CostModel::profile(&ops, &[V100_16G, GTX1080TI], 0.0, 1);
        let a = cm.op_time(0, &V100_16G, 1.0);
        let b = cm.op_time(0, &GTX1080TI, 1.0);
        let avg = cm.op_time_avg(0);
        assert!(avg > a.min(b) && avg < a.max(b));
    }

    #[test]
    #[should_panic(expected = "not profiled")]
    fn unknown_gpu_panics() {
        let cm = CostModel::profile(&[conv_op()], &[V100_16G], 0.0, 1);
        cm.op_time(0, &crate::cluster::T4, 1.0);
    }
}
