//! Communication cost models: GRPC point-to-point, ring AllReduce,
//! PS push/pull and SFB broadcast (paper §4.1.2, §4.2.3).
//!
//! Following the paper's methodology, the models are *fitted* segmented
//! linear curves over synthetic measurements from 1KB to 1GB (doubling):
//! time(bytes) at a reference bandwidth, then scaled by the actual link
//! bandwidth.  Small transfers are latency-dominated (the first segment),
//! large ones bandwidth-dominated (the second).
//!
//! All collective formulas are **path-based**: bandwidths come from the
//! topology's routed link graph (for flat cliques these are the matrix
//! entries bit for bit), and routed paths additionally charge their
//! accumulated per-hop latency — zero on clique links, so flat
//! topologies keep their exact pre-link-graph times.  The collective
//! variants taking a [`LinkProfile`] let the `dist` lowering reuse its
//! per-placement-mask cache instead of recomputing the O(n²) bottleneck
//! per evaluation.

use super::seglin::SegmentedLinear;
use crate::cluster::{DeviceId, LinkProfile, Topology};
use crate::util::Rng;

/// Fixed per-message software latency (GRPC serialization + syscalls).
pub const GRPC_LATENCY_S: f64 = 120e-6;
/// Per-step latency of a collective ring step.
pub const RING_STEP_LATENCY_S: f64 = 25e-6;
/// Reference bandwidth the curves are fitted at (bytes/s): 10 Gbps.
const REF_BW: f64 = 10.0e9 / 8.0;
/// Protocol efficiency: achievable goodput fraction of link rate.
pub const GOODPUT: f64 = 0.85;

/// Ground-truth synthetic transfer time at the reference bandwidth.
fn grpc_truth(bytes: f64) -> f64 {
    GRPC_LATENCY_S + bytes / (REF_BW * GOODPUT)
}

#[derive(Clone, Debug)]
pub struct CommModel {
    /// Fitted GRPC curve at the reference bandwidth: time vs bytes.
    grpc_curve: SegmentedLinear,
}

impl CommModel {
    /// Fit transfer curves from synthetic measurements (1KB..1GB,
    /// doubling, small multiplicative noise) — the §4.1.2 procedure.
    pub fn fit(seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        let mut b = 1024.0;
        while b <= 1e9 {
            xs.push(b);
            ys.push(grpc_truth(b) * (1.0 + 0.02 * rng.normal()));
            b *= 2.0;
        }
        Self { grpc_curve: SegmentedLinear::fit(&xs, &ys) }
    }

    /// Point-to-point transfer time of `bytes` over a link of
    /// `bw_bytes_per_s`: evaluate the fitted reference curve and rescale
    /// its bandwidth-dependent part.
    pub fn transfer_time(&self, bytes: f64, bw_bytes_per_s: f64) -> f64 {
        let (lat, bw) = self.transfer_parts(bytes, bw_bytes_per_s);
        lat + bw
    }

    /// The transfer time split into its (fixed software-latency,
    /// bandwidth-scalable) parts — the sum is exactly
    /// [`CommModel::transfer_time`].  The scalable part is what link
    /// contention stretches ([`crate::sim::LinkLoad`]).
    pub fn transfer_parts(&self, bytes: f64, bw_bytes_per_s: f64) -> (f64, f64) {
        if bytes <= 0.0 {
            return (0.0, 0.0);
        }
        if !bw_bytes_per_s.is_finite() {
            return (0.0, 0.0); // same device
        }
        let t_ref = self.grpc_curve.eval(bytes);
        let bw_part = bytes / (REF_BW * GOODPUT);
        let lat_part = (t_ref - bw_part).max(0.0);
        (lat_part, bytes / (bw_bytes_per_s * GOODPUT))
    }

    /// Ring AllReduce across `devs`: 2(n-1)/n * bytes over the routed
    /// bottleneck + 2(n-1) ring steps, each charged its path latency.
    pub fn allreduce_time(&self, bytes: f64, devs: &[DeviceId], topo: &Topology) -> f64 {
        self.allreduce_time_with(bytes, devs.len(), topo.link_profile(devs))
    }

    /// [`CommModel::allreduce_time`] with a precomputed device-set link
    /// profile (the lowering's per-mask cache).
    pub fn allreduce_time_with(&self, bytes: f64, n: usize, profile: LinkProfile) -> f64 {
        if n <= 1 || bytes <= 0.0 {
            return 0.0;
        }
        let bw = profile.bottleneck_gbps * 1e9 / 8.0 * GOODPUT;
        let steps = 2 * (n - 1);
        2.0 * (n - 1) as f64 / n as f64 * bytes / bw
            + steps as f64 * (RING_STEP_LATENCY_S + profile.max_latency_s)
    }

    /// PS synchronization: all workers push to `ps` and pull back.  The
    /// PS NIC serializes: total 2(n-1) transfers of `bytes` through each
    /// worker's routed path to the PS (bandwidth + path latency).
    pub fn ps_time(&self, bytes: f64, devs: &[DeviceId], ps: DeviceId, topo: &Topology) -> f64 {
        let workers: Vec<DeviceId> = devs.iter().copied().filter(|&d| d != ps).collect();
        if workers.is_empty() || bytes <= 0.0 {
            return 0.0;
        }
        let mut total = 0.0;
        for w in &workers {
            let bw = topo.bw_bytes_per_s(*w, ps);
            total += 2.0 * (self.transfer_time(bytes, bw) + topo.route_latency_s(*w, ps));
        }
        total
    }

    /// SFB broadcast of sufficient factors (paper's second objective
    /// term): D(D-1) transfers of `bytes` over the routed bottleneck
    /// bandwidth `tau` among the D devices, each charged the worst path
    /// latency.
    pub fn sfb_broadcast_time(&self, bytes: f64, devs: &[DeviceId], topo: &Topology) -> f64 {
        self.sfb_broadcast_time_with(bytes, devs.len(), topo.link_profile(devs))
    }

    /// [`CommModel::sfb_broadcast_time`] with a precomputed device-set
    /// link profile.
    pub fn sfb_broadcast_time_with(&self, bytes: f64, d: usize, profile: LinkProfile) -> f64 {
        if d <= 1 || bytes <= 0.0 {
            return 0.0;
        }
        let tau = profile.bottleneck_gbps * 1e9 / 8.0 * GOODPUT;
        (d * (d - 1)) as f64 * bytes / tau + (d * (d - 1)) as f64 * profile.max_latency_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets::{nvlink_island, sfb_pair, testbed};

    #[test]
    fn transfer_parts_sum_to_transfer_time() {
        let m = CommModel::fit(1);
        for bytes in [0.0, 1024.0, 1e6, 512e6] {
            for bw in [10e9 / 8.0, 100e9 / 8.0, f64::INFINITY] {
                let (lat, scal) = m.transfer_parts(bytes, bw);
                assert_eq!((lat + scal).to_bits(), m.transfer_time(bytes, bw).to_bits());
                assert!(lat >= 0.0 && scal >= 0.0);
            }
        }
    }

    #[test]
    fn routed_paths_charge_their_latency() {
        let m = CommModel::fit(9);
        let t = nvlink_island();
        let devs = t.devices();
        let p = t.link_profile(&devs);
        assert!(p.max_latency_s > 0.0, "cross-island paths have hop latency");
        let zero_lat = LinkProfile { max_latency_s: 0.0, ..p };
        let b = 1e6;
        assert!(
            m.allreduce_time_with(b, devs.len(), p)
                > m.allreduce_time_with(b, devs.len(), zero_lat)
        );
        assert!(
            m.sfb_broadcast_time_with(b, devs.len(), p)
                > m.sfb_broadcast_time_with(b, devs.len(), zero_lat)
        );
        // Clique profiles are latency-free, so the `_with` variants agree
        // with the device-set forms bit for bit.
        let tb = testbed();
        let cross = tb.mask_devices(0b11);
        assert_eq!(
            m.allreduce_time(b, &cross, &tb).to_bits(),
            m.allreduce_time_with(b, cross.len(), tb.link_profile(&cross)).to_bits()
        );
    }

    #[test]
    fn fitted_curve_close_to_truth() {
        let m = CommModel::fit(1);
        for bytes in [4096.0, 1e6, 64e6, 512e6] {
            let t = m.transfer_time(bytes, REF_BW);
            let truth = grpc_truth(bytes);
            assert!(
                (t - truth).abs() / truth < 0.25,
                "bytes={bytes}: fit {t} vs truth {truth}"
            );
        }
    }

    #[test]
    fn small_transfers_latency_dominated() {
        let m = CommModel::fit(2);
        let t1 = m.transfer_time(1024.0, 100e9 / 8.0);
        let t2 = m.transfer_time(2048.0, 100e9 / 8.0);
        // Doubling tiny payload barely changes the time.
        assert!(t2 < t1 * 1.5);
        assert!(t1 > GRPC_LATENCY_S * 0.5);
    }

    #[test]
    fn large_transfers_scale_with_bandwidth() {
        let m = CommModel::fit(3);
        let slow = m.transfer_time(1e9, 10e9 / 8.0);
        let fast = m.transfer_time(1e9, 100e9 / 8.0);
        let ratio = slow / fast;
        assert!((6.0..11.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn zero_bytes_and_same_device_are_free() {
        let m = CommModel::fit(4);
        assert_eq!(m.transfer_time(0.0, 1e9), 0.0);
        assert_eq!(m.transfer_time(1e6, f64::INFINITY), 0.0);
    }

    #[test]
    fn allreduce_matches_ring_formula() {
        let m = CommModel::fit(5);
        let t = testbed();
        let devs = t.mask_devices(0b1); // 4x V100 NVLink group
        assert_eq!(devs.len(), 4);
        let bytes = 100e6;
        let time = m.allreduce_time(bytes, &devs, &t);
        let bw = 200.0e9 / 8.0 * GOODPUT;
        let expect = 2.0 * 3.0 / 4.0 * bytes / bw + 6.0 * RING_STEP_LATENCY_S;
        assert!((time - expect).abs() / expect < 1e-9);
        // Single device: free.
        assert_eq!(m.allreduce_time(bytes, &devs[..1], &t), 0.0);
    }

    #[test]
    fn allreduce_cross_machine_slower() {
        let m = CommModel::fit(6);
        let t = testbed();
        let intra = t.mask_devices(0b1);
        let cross = t.mask_devices(0b11);
        let b = 100e6;
        assert!(m.allreduce_time(b, &cross, &t) > m.allreduce_time(b, &intra, &t));
    }

    #[test]
    fn ps_time_scales_with_workers() {
        let m = CommModel::fit(7);
        let t = testbed();
        let devs = t.mask_devices(0b11);
        let ps = devs[0];
        let t_all = m.ps_time(1e6, &devs, ps, &t);
        let t_few = m.ps_time(1e6, &devs[..3], ps, &t);
        assert!(t_all > t_few);
        // PS alone: nothing to sync.
        assert_eq!(m.ps_time(1e6, &devs[..1], devs[0], &t), 0.0);
    }

    #[test]
    fn sfb_broadcast_formula() {
        let m = CommModel::fit(8);
        let t = sfb_pair();
        let devs = t.devices();
        let bytes = 1e6;
        let tau = 10.0e9 / 8.0 * GOODPUT;
        let expect = 2.0 * bytes / tau;
        let got = m.sfb_broadcast_time(bytes, &devs, &t);
        assert!((got - expect).abs() / expect < 1e-9);
    }
}
