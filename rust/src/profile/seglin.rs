//! Segmented linear regression — the profiler's transfer-time model
//! (paper §4.1.2: "Segmented linear regression models are built for GRPC
//! transfer and for AllReduce communication" from measurements of 1KB to
//! 1GB, doubling).
//!
//! Fit: given (x, y) samples sorted by x, choose the breakpoint (from the
//! sample xs) that minimizes total squared error of two independent OLS
//! fits, one per segment.  Evaluation clamps below the smallest sample.

use crate::util::stats::linear_fit;

#[derive(Clone, Debug)]
pub struct SegmentedLinear {
    /// Breakpoint in x; below uses (a1, b1), at/above uses (a2, b2).
    pub brk: f64,
    pub a1: f64,
    pub b1: f64,
    pub a2: f64,
    pub b2: f64,
}

/// *Relative* squared error: transfer-time samples span 5+ orders of
/// magnitude (1KB..1GB), so absolute SSE would let the large-message
/// segment dominate breakpoint selection and ruin the latency plateau fit.
fn sse(xs: &[f64], ys: &[f64], a: f64, b: f64) -> f64 {
    xs.iter()
        .zip(ys)
        .map(|(x, y)| {
            let r = (y - (a + b * x)) / y.abs().max(1e-30);
            r * r
        })
        .sum()
}

impl SegmentedLinear {
    /// Fit from samples; requires at least 4 points (2 per segment).
    pub fn fit(xs: &[f64], ys: &[f64]) -> Self {
        assert_eq!(xs.len(), ys.len());
        assert!(xs.len() >= 4, "need >= 4 samples");
        let mut idx: Vec<usize> = (0..xs.len()).collect();
        idx.sort_by(|&i, &j| xs[i].partial_cmp(&xs[j]).unwrap());
        let sx: Vec<f64> = idx.iter().map(|&i| xs[i]).collect();
        let sy: Vec<f64> = idx.iter().map(|&i| ys[i]).collect();

        let mut best: Option<(f64, Self)> = None;
        for k in 2..=(sx.len() - 2) {
            let (a1, b1) = linear_fit(&sx[..k], &sy[..k]);
            let (a2, b2) = linear_fit(&sx[k..], &sy[k..]);
            let err = sse(&sx[..k], &sy[..k], a1, b1) + sse(&sx[k..], &sy[k..], a2, b2);
            let cand = Self { brk: sx[k], a1, b1, a2, b2 };
            if best.as_ref().map_or(true, |(e, _)| err < *e) {
                best = Some((err, cand));
            }
        }
        best.unwrap().1
    }

    pub fn eval(&self, x: f64) -> f64 {
        let y = if x < self.brk { self.a1 + self.b1 * x } else { self.a2 + self.b2 * x };
        y.max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_piecewise_line() {
        // y = 10 + 0x for x<100 ; y = 0 + 0.1x for x>=100
        let xs: Vec<f64> = (0..40).map(|i| i as f64 * 10.0).collect();
        let ys: Vec<f64> =
            xs.iter().map(|&x| if x < 100.0 { 10.0 } else { 0.1 * x }).collect();
        let m = SegmentedLinear::fit(&xs, &ys);
        assert!((m.eval(50.0) - 10.0).abs() < 1.5, "{}", m.eval(50.0));
        assert!((m.eval(300.0) - 30.0).abs() < 1.5, "{}", m.eval(300.0));
    }

    #[test]
    fn monotone_inputs_dont_go_negative() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0];
        let ys = [5.0, 5.1, 5.2, 6.0, 8.0, 12.0];
        let m = SegmentedLinear::fit(&xs, &ys);
        assert!(m.eval(0.0) >= 0.0);
        assert!(m.eval(64.0) > m.eval(32.0) * 0.9);
    }

    #[test]
    fn unsorted_input_ok() {
        let xs = [8.0, 1.0, 4.0, 2.0, 32.0, 16.0];
        let ys = [6.0, 5.0, 5.2, 5.1, 12.0, 8.0];
        let m = SegmentedLinear::fit(&xs, &ys);
        assert!(m.eval(16.0) > 5.0);
    }

    #[test]
    #[should_panic(expected = "need >= 4")]
    fn too_few_samples_panics() {
        SegmentedLinear::fit(&[1.0, 2.0], &[1.0, 2.0]);
    }
}
