//! Monte-Carlo tree search over deployment strategies (paper §4.2.2).
//!
//! A vertex is a partial strategy (the first `depth` op groups — in
//! descending computation-time order — have decided actions); an edge is
//! the action applied to the next group.  Selection uses the PUCT score
//!
//! ```text
//! U(s,a) = Q(s,a) + c * G(s,a) * sqrt(sum_a' N(s,a')) / (1 + N(s,a))
//! ```
//!
//! with prior probabilities `G` from the heterogeneous GNN (or uniform
//! for "pure MCTS").  Leaf evaluation simulates the partial strategy
//! (undecided groups copy the most expensive decided group, footnote 2);
//! the reward is the speed-up over DP-NCCL, or −1 on OOM.

use crate::dist::{Lowering, SimOutcome};
use crate::strategy::{Action, Strategy};
use crate::util::Rng;

/// Supplies prior probabilities over candidate actions for the group
/// being decided at a vertex.  Implemented by the GNN bridge
/// ([`crate::gnn`]) and by [`UniformPrior`].
pub trait PriorProvider {
    /// `state`: the current partial strategy; `group`: the op group being
    /// decided; `outcome`: the simulator feedback for `state`.
    /// Must return one non-negative weight per action (normalized or not).
    fn priors(
        &mut self,
        state: &Strategy,
        group: usize,
        outcome: &SimOutcome,
        actions: &[Action],
    ) -> Vec<f32>;
}

/// Forwarding impl so callers can inject a borrowed (possibly
/// type-erased) provider — e.g. `&mut dyn PriorProvider` through
/// [`crate::coordinator::search_session`] — without giving [`Mcts`]
/// ownership.
impl<P: PriorProvider + ?Sized> PriorProvider for &mut P {
    fn priors(
        &mut self,
        state: &Strategy,
        group: usize,
        outcome: &SimOutcome,
        actions: &[Action],
    ) -> Vec<f32> {
        (**self).priors(state, group, outcome, actions)
    }
}

/// Uniform priors: "Pure MCTS" in Table 7.
pub struct UniformPrior;

impl PriorProvider for UniformPrior {
    fn priors(
        &mut self,
        _state: &Strategy,
        _group: usize,
        _outcome: &SimOutcome,
        actions: &[Action],
    ) -> Vec<f32> {
        vec![1.0 / actions.len() as f32; actions.len()]
    }
}

/// PUCT exploration coefficient.  With ~50-130 candidate actions and
/// budgets of a few hundred iterations, the exploration term must stay
/// competitive with Q; 1.5 * (1/|A|) priors vanish, so we use a larger
/// coefficient than AlphaZero's default.
pub const PUCT_C: f64 = 3.0;
/// Visit-count threshold for extracting training targets (§4.2.2:
/// "vertices with at least 800 visit counts"; scaled to our iteration
/// budgets).
pub const TRAIN_VISIT_THRESHOLD: u32 = 32;

struct Node {
    /// Children indexed by action index; usize::MAX = unexpanded.
    children: Vec<usize>,
    n: Vec<u32>,
    q: Vec<f64>,
    prior: Vec<f32>,
    /// Which op group this node decides.
    depth: usize,
}

/// A (state-features, visit-distribution) example harvested for GNN
/// training.
pub struct TrainExample {
    pub strategy: Strategy,
    pub group: usize,
    pub outcome: SimOutcome,
    /// Normalized visit distribution over the action list.
    pub pi: Vec<f32>,
}

pub struct SearchResult {
    pub best: Strategy,
    pub best_time: f64,
    pub best_reward: f64,
    pub dp_time: f64,
    pub iterations: usize,
    /// Iteration index (1-based) at which the search first found a
    /// strategy strictly better than DP-NCCL; None if never (Table 7).
    pub first_beats_dp: Option<usize>,
    pub examples: Vec<TrainExample>,
}

pub struct Mcts<'a, P: PriorProvider> {
    low: &'a Lowering<'a>,
    actions: Vec<Action>,
    prior: P,
    rng: Rng,
    nodes: Vec<Node>,
    /// Action sequence per node (reconstruction path).
    dp_time: f64,
    pub collect_examples: bool,
    /// Probe every root action once before PUCT (on by default).  The
    /// Table 7 experiment disables it to compare raw prior quality.
    pub root_sweep: bool,
}

impl<'a, P: PriorProvider> Mcts<'a, P> {
    pub fn new(low: &'a Lowering<'a>, actions: Vec<Action>, prior: P, seed: u64) -> Self {
        let dp_time = low.dp_time();
        Self {
            low,
            actions,
            prior,
            rng: Rng::new(seed),
            nodes: Vec::new(),
            dp_time,
            collect_examples: false,
            root_sweep: true,
        }
    }

    /// The injected prior provider (e.g. to read GNN evaluation counts
    /// after a search).
    pub fn prior(&self) -> &P {
        &self.prior
    }

    fn reward(&self, out: &SimOutcome) -> f64 {
        if out.oom {
            return -1.0;
        }
        self.dp_time / out.time - 1.0
    }

    /// Build the strategy corresponding to a path of action indices.
    fn strategy_of(&self, path: &[usize]) -> Strategy {
        let mut s = Strategy::empty(self.low.gg.num_groups());
        for (d, &ai) in path.iter().enumerate() {
            let g = self.low.order[d];
            s.slots[g] = Some(self.actions[ai]);
        }
        s
    }

    fn new_node(&mut self, depth: usize, prior: Vec<f32>) -> usize {
        let a = self.actions.len();
        self.nodes.push(Node {
            children: vec![usize::MAX; a],
            n: vec![0; a],
            q: vec![0.0; a],
            prior,
            depth,
        });
        self.nodes.len() - 1
    }

    /// Run `iterations` of MCTS; returns the best complete strategy seen.
    pub fn search(&mut self, iterations: usize) -> SearchResult {
        let ng = self.low.gg.num_groups();
        let na = self.actions.len();

        // Root node priors from the empty strategy.
        let empty = Strategy::empty(ng);
        let out0 = self.low.evaluate(&empty);
        let root_group = self.low.order[0];
        let pri0 = self.prior.priors(&empty, root_group, &out0, &self.actions);
        let root = self.new_node(0, normalize(&pri0));

        let mut best: Option<(f64, Strategy, f64)> = None; // (reward, strat, time)
        let mut first_beats_dp = None;
        let mut examples = Vec::new();
        let mut it = 0usize;

        // ---- root sweep: evaluate every root action once.  Because the
        // footnote-2 completion rule copies the first decided group's
        // action to all undecided groups, this probes each *uniform*
        // strategy — giving the search the same coarse coverage a greedy
        // one-shot baseline gets, before PUCT refines beyond it.
        for a0 in 0..na {
            if !self.root_sweep || it >= iterations {
                break;
            }
            it += 1;
            let strat = self.strategy_of(&[a0]);
            let out = self.low.evaluate(&strat);
            let r = self.reward(&out);
            if !out.oom {
                let better = best.as_ref().map_or(true, |(br, _, _)| r > *br);
                if better {
                    best = Some((r, strat.clone(), out.time));
                }
                if r > 1e-9 && first_beats_dp.is_none() {
                    first_beats_dp = Some(it);
                }
            }
            let nd = &mut self.nodes[root];
            nd.n[a0] += 1;
            nd.q[a0] = r;
        }

        while it < iterations {
            it += 1;
            // ---- selection
            let mut node = root;
            let mut path: Vec<usize> = Vec::new();
            loop {
                let nd = &self.nodes[node];
                if nd.depth >= ng {
                    break;
                }
                let total_n: u32 = nd.n.iter().sum();
                let mut best_a = 0;
                let mut best_u = f64::NEG_INFINITY;
                for a in 0..na {
                    let u = nd.q[a]
                        + PUCT_C
                            * nd.prior[a] as f64
                            * ((total_n as f64).sqrt() / (1.0 + nd.n[a] as f64));
                    // Deterministic jitter for exact ties.
                    let u = u + 1e-12 * self.rng.next_f64();
                    if u > best_u {
                        best_u = u;
                        best_a = a;
                    }
                }
                path.push(best_a);
                let child = self.nodes[node].children[best_a];
                if child == usize::MAX {
                    break; // unexpanded edge -> expand + evaluate
                }
                node = child;
            }

            // ---- expansion + evaluation
            let strat = self.strategy_of(&path);
            let out = self.low.evaluate(&strat);
            let r = self.reward(&out);
            let depth = path.len();
            if depth >= 1 {
                // Expand the child if the strategy is still partial.
                if depth < ng {
                    let g = self.low.order[depth];
                    let pri = self.prior.priors(&strat, g, &out, &self.actions);
                    let child = self.new_node(depth, normalize(&pri));
                    // Re-walk to attach (node ids shifted by new_node).
                    let mut cur = root;
                    for &ai in &path[..depth - 1] {
                        cur = self.nodes[cur].children[ai];
                    }
                    self.nodes[cur].children[path[depth - 1]] = child;
                } else {
                    // Complete strategy: attach a terminal sentinel so the
                    // tree doesn't re-expand; reuse the node itself.
                }
            }

            // Track the best *complete-by-completion-rule* outcome.
            if !out.oom {
                let better = best.as_ref().map_or(true, |(br, _, _)| r > *br);
                if better {
                    best = Some((r, strat.clone(), out.time));
                }
                if r > 1e-9 && first_beats_dp.is_none() {
                    first_beats_dp = Some(it);
                }
            }

            // ---- back-propagation
            let mut cur = root;
            for &ai in &path {
                let nd = &mut self.nodes[cur];
                nd.n[ai] += 1;
                let n = nd.n[ai] as f64;
                nd.q[ai] += (r - nd.q[ai]) / n;
                let next = nd.children[ai];
                if next == usize::MAX {
                    break;
                }
                cur = next;
            }
        }
        let iterations = it;

        // ---- harvest training examples from well-visited nodes.
        if self.collect_examples {
            let mut stack = vec![(root, Vec::<usize>::new())];
            while let Some((ni, path)) = stack.pop() {
                let nd = &self.nodes[ni];
                let total: u32 = nd.n.iter().sum();
                if total >= TRAIN_VISIT_THRESHOLD && nd.depth < ng {
                    // pi = softmax(ln N) = N / sum N over visited actions.
                    let pi: Vec<f32> = nd
                        .n
                        .iter()
                        .map(|&c| c as f32 / total as f32)
                        .collect();
                    let strat = self.strategy_of(&path);
                    let out = self.low.evaluate(&strat);
                    examples.push(TrainExample {
                        strategy: strat,
                        group: self.low.order[nd.depth],
                        outcome: out,
                        pi,
                    });
                }
                for (ai, &ch) in nd.children.iter().enumerate() {
                    if ch != usize::MAX {
                        let mut p = path.clone();
                        p.push(ai);
                        stack.push((ch, p));
                    }
                }
            }
        }

        let (best_reward, best_strat, best_time) = best.unwrap_or_else(|| {
            let s = Strategy::dp_allreduce(ng, self.low.topo);
            (0.0, s, self.dp_time)
        });
        SearchResult {
            best: best_strat,
            best_time,
            best_reward,
            dp_time: self.dp_time,
            iterations,
            first_beats_dp,
            examples,
        }
    }
}

fn normalize(p: &[f32]) -> Vec<f32> {
    let s: f32 = p.iter().sum();
    if s <= 0.0 {
        return vec![1.0 / p.len() as f32; p.len()];
    }
    p.iter().map(|x| x / s).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets::testbed;
    use crate::graph::grouping::group_ops;
    use crate::models;
    use crate::profile::{unique_gpus, CommModel, CostModel};
    use crate::strategy::enumerate_actions;

    fn run_search(iters: usize, seed: u64) -> (SearchResult, f64) {
        let topo = testbed();
        let m = models::vgg19(8, 0.25);
        let cost = CostModel::profile(&m.ops, &unique_gpus(&topo), 0.0, 1);
        let gg = group_ops(&m, &cost, 12, 7);
        let comm = CommModel::fit(3);
        let low = Lowering::new(&gg, &topo, &cost, &comm);
        let actions = enumerate_actions(&topo);
        let mut mcts = Mcts::new(&low, actions, UniformPrior, seed);
        let dp = low.dp_time();
        (mcts.search(iters), dp)
    }

    #[test]
    fn finds_better_than_dp_on_comm_bound_model() {
        let (res, dp) = run_search(60, 1);
        assert!(res.best_time < dp, "best {} vs dp {}", res.best_time, dp);
        assert!(res.best_reward > 0.0);
        assert!(res.first_beats_dp.is_some());
        assert!(res.best.is_complete() || res.best.decided() > 0);
    }

    #[test]
    fn deterministic_for_seed() {
        let (a, _) = run_search(30, 5);
        let (b, _) = run_search(30, 5);
        assert_eq!(a.best_time, b.best_time);
        assert_eq!(a.first_beats_dp, b.first_beats_dp);
    }

    #[test]
    fn more_iterations_never_worse() {
        let (short, _) = run_search(10, 3);
        let (long, _) = run_search(80, 3);
        assert!(long.best_reward >= short.best_reward - 1e-12);
    }

    #[test]
    fn collects_training_examples() {
        let topo = testbed();
        let m = models::vgg19(8, 0.25);
        let cost = CostModel::profile(&m.ops, &unique_gpus(&topo), 0.0, 1);
        let gg = group_ops(&m, &cost, 8, 7);
        let comm = CommModel::fit(3);
        let low = Lowering::new(&gg, &topo, &cost, &comm);
        let actions = enumerate_actions(&topo);
        let mut mcts = Mcts::new(&low, actions.clone(), UniformPrior, 2);
        mcts.collect_examples = true;
        let res = mcts.search(TRAIN_VISIT_THRESHOLD as usize * 2);
        assert!(!res.examples.is_empty(), "root should qualify");
        for ex in &res.examples {
            assert_eq!(ex.pi.len(), actions.len());
            let s: f32 = ex.pi.iter().sum();
            assert!((s - 1.0).abs() < 1e-4);
        }
    }

    /// A prior provider that strongly prefers one specific action.
    struct Biased(usize);
    impl PriorProvider for Biased {
        fn priors(
            &mut self,
            _s: &Strategy,
            _g: usize,
            _o: &SimOutcome,
            actions: &[Action],
        ) -> Vec<f32> {
            let mut p = vec![1e-3; actions.len()];
            p[self.0] = 1.0;
            p
        }
    }

    #[test]
    fn good_priors_accelerate_search() {
        // Find the action index for "V100-machine-only AllReduce", which
        // the uniform search discovers to be strong for VGG; a biased
        // prior should reach a DP-beating strategy in fewer iterations.
        let topo = testbed();
        let m = models::vgg19(8, 0.25);
        let cost = CostModel::profile(&m.ops, &unique_gpus(&topo), 0.0, 1);
        let gg = group_ops(&m, &cost, 12, 7);
        let comm = CommModel::fit(3);
        let low = Lowering::new(&gg, &topo, &cost, &comm);
        let actions = enumerate_actions(&topo);
        let target = actions
            .iter()
            .position(|a| {
                a.mask == 0b1 && a.option == crate::strategy::ReplOption::AllReduce
            })
            .unwrap();

        let mut uni = Mcts::new(&low, actions.clone(), UniformPrior, 11);
        let r_uni = uni.search(40);
        let mut bia = Mcts::new(&low, actions.clone(), Biased(target), 11);
        let r_bia = bia.search(40);
        let u = r_uni.first_beats_dp.unwrap_or(usize::MAX);
        let b = r_bia.first_beats_dp.unwrap_or(usize::MAX);
        assert!(b <= u, "biased {b} should beat uniform {u}");
    }
}
