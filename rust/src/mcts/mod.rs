//! Monte-Carlo tree search over deployment strategies (paper §4.2.2).
//!
//! A vertex is a partial strategy (the first `depth` op groups — in
//! descending computation-time order — have decided actions); an edge is
//! the action applied to the next group.  Selection uses the PUCT score
//!
//! ```text
//! U(s,a) = Q(s,a) + c * G(s,a) * sqrt(sum_a' N(s,a')) / (1 + N(s,a))
//! ```
//!
//! with prior probabilities `G` from the heterogeneous GNN (or uniform
//! for "pure MCTS").  Leaf evaluation simulates the partial strategy
//! (undecided groups copy the most expensive decided group, footnote 2);
//! the reward is the speed-up over DP-NCCL, or −1 on OOM.
//!
//! Since PR 3 the tree *storage* (arena + atomic per-edge statistics)
//! lives in [`crate::search::tree`] and the *traversal* loop in
//! [`crate::search::worker`]; [`Mcts`] here is the sequential engine —
//! one inline [`Worker`](crate::search::Worker) over a private tree.
//! The tree-parallel engine ([`crate::search::run_search`]) runs the
//! same traversal with K workers over one shared tree and is
//! byte-identical to this one at `workers == 1`.

use crate::dist::{Lowering, SimOutcome};
use crate::search::worker::{finish_result, harvest_examples, Worker};
use crate::search::{CancelToken, SearchTree};
use crate::strategy::{Action, Strategy};
use crate::util::Rng;

/// Supplies prior probabilities over candidate actions for the group
/// being decided at a vertex.  Implemented by the GNN bridge
/// ([`crate::gnn`]) and by [`UniformPrior`].
pub trait PriorProvider {
    /// `state`: the current partial strategy; `group`: the op group being
    /// decided; `outcome`: the simulator feedback for `state`.
    /// Must return one non-negative weight per action (normalized or not).
    fn priors(
        &mut self,
        state: &Strategy,
        group: usize,
        outcome: &SimOutcome,
        actions: &[Action],
    ) -> Vec<f32>;

    /// Named counters the provider wants surfaced in plan telemetry
    /// (e.g. GNN evaluation counts).  Parallel search workers report
    /// these before dropping the provider, since the provider itself
    /// never leaves its worker thread.
    fn metrics(&self) -> Vec<(String, f64)> {
        Vec::new()
    }
}

/// Forwarding impl so callers can inject a borrowed (possibly
/// type-erased) provider — e.g. `&mut dyn PriorProvider` through
/// [`crate::coordinator::search_session`] — without giving [`Mcts`]
/// ownership.
impl<P: PriorProvider + ?Sized> PriorProvider for &mut P {
    fn priors(
        &mut self,
        state: &Strategy,
        group: usize,
        outcome: &SimOutcome,
        actions: &[Action],
    ) -> Vec<f32> {
        (**self).priors(state, group, outcome, actions)
    }

    fn metrics(&self) -> Vec<(String, f64)> {
        (**self).metrics()
    }
}

/// Uniform priors: "Pure MCTS" in Table 7.
pub struct UniformPrior;

impl PriorProvider for UniformPrior {
    fn priors(
        &mut self,
        _state: &Strategy,
        _group: usize,
        _outcome: &SimOutcome,
        actions: &[Action],
    ) -> Vec<f32> {
        vec![1.0 / actions.len() as f32; actions.len()]
    }
}

/// PUCT exploration coefficient.  With ~50-130 candidate actions and
/// budgets of a few hundred iterations, the exploration term must stay
/// competitive with Q; 1.5 * (1/|A|) priors vanish, so we use a larger
/// coefficient than AlphaZero's default.
pub const PUCT_C: f64 = 3.0;
/// Visit-count threshold for extracting training targets (§4.2.2:
/// "vertices with at least 800 visit counts"; scaled to our iteration
/// budgets).
pub const TRAIN_VISIT_THRESHOLD: u32 = 32;

/// A (state-features, visit-distribution) example harvested for GNN
/// training.
pub struct TrainExample {
    pub strategy: Strategy,
    pub group: usize,
    pub outcome: SimOutcome,
    /// Normalized visit distribution over the action list.
    pub pi: Vec<f32>,
}

pub struct SearchResult {
    pub best: Strategy,
    pub best_time: f64,
    pub best_reward: f64,
    pub dp_time: f64,
    pub iterations: usize,
    /// Iteration index (1-based) at which the search first found a
    /// strategy strictly better than DP-NCCL; None if never (Table 7).
    pub first_beats_dp: Option<usize>,
    pub examples: Vec<TrainExample>,
}

pub struct Mcts<'a, P: PriorProvider> {
    low: &'a Lowering<'a>,
    actions: Vec<Action>,
    prior: P,
    rng: Rng,
    /// Private tree; same storage layout the parallel engine shares.
    tree: SearchTree,
    dp_time: f64,
    pub collect_examples: bool,
    /// Probe every root action once before PUCT (on by default).  The
    /// Table 7 experiment disables it to compare raw prior quality.
    pub root_sweep: bool,
    /// Optional cooperative cancellation ([`CancelToken`]): when it
    /// fires mid-search the engine stops early and returns its
    /// best-so-far strategy.  `None` (the default) leaves the trajectory
    /// byte-identical to the pre-deadline engine.
    pub cancel: Option<CancelToken>,
}

impl<'a, P: PriorProvider> Mcts<'a, P> {
    pub fn new(low: &'a Lowering<'a>, actions: Vec<Action>, prior: P, seed: u64) -> Self {
        let dp_time = low.dp_time();
        Self {
            low,
            actions,
            prior,
            rng: Rng::new(seed),
            tree: SearchTree::new(),
            dp_time,
            collect_examples: false,
            root_sweep: true,
            cancel: None,
        }
    }

    /// The injected prior provider (e.g. to read GNN evaluation counts
    /// after a search).
    pub fn prior(&self) -> &P {
        &self.prior
    }

    /// Run `iterations` of MCTS; returns the best complete strategy seen.
    ///
    /// This is one inline [`Worker`] — the identical traversal the
    /// tree-parallel engine ([`crate::search::run_search`]) runs K of.
    pub fn search(&mut self, iterations: usize) -> SearchResult {
        let mut worker = Worker::new(
            &self.tree,
            self.low,
            &self.actions,
            &mut self.prior,
            self.rng.clone(),
            1.0,
        );
        worker.cancel = self.cancel.clone();
        worker.build_root();
        if self.root_sweep {
            worker.root_sweep(iterations);
        }
        worker.run(iterations);
        let examples = if self.collect_examples {
            harvest_examples(&self.tree, worker.root, self.low, &self.actions)
        } else {
            Vec::new()
        };
        let Worker { rng, best, first_beats_dp, iterations: consumed, .. } = worker;
        self.rng = rng;
        finish_result(self.low, best, self.dp_time, consumed, first_beats_dp, examples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets::testbed;
    use crate::graph::grouping::group_ops;
    use crate::models;
    use crate::profile::{unique_gpus, CommModel, CostModel};
    use crate::strategy::enumerate_actions;

    fn run_search(iters: usize, seed: u64) -> (SearchResult, f64) {
        let topo = testbed();
        let m = models::vgg19(8, 0.25);
        let cost = CostModel::profile(&m.ops, &unique_gpus(&topo), 0.0, 1);
        let gg = group_ops(&m, &cost, 12, 7);
        let comm = CommModel::fit(3);
        let low = Lowering::new(&gg, &topo, &cost, &comm);
        let actions = enumerate_actions(&topo);
        let mut mcts = Mcts::new(&low, actions, UniformPrior, seed);
        let dp = low.dp_time();
        (mcts.search(iters), dp)
    }

    #[test]
    fn finds_better_than_dp_on_comm_bound_model() {
        let (res, dp) = run_search(60, 1);
        assert!(res.best_time < dp, "best {} vs dp {}", res.best_time, dp);
        assert!(res.best_reward > 0.0);
        assert!(res.first_beats_dp.is_some());
        assert!(res.best.is_complete() || res.best.decided() > 0);
    }

    #[test]
    fn deterministic_for_seed() {
        let (a, _) = run_search(30, 5);
        let (b, _) = run_search(30, 5);
        assert_eq!(a.best_time, b.best_time);
        assert_eq!(a.first_beats_dp, b.first_beats_dp);
    }

    #[test]
    fn more_iterations_never_worse() {
        let (short, _) = run_search(10, 3);
        let (long, _) = run_search(80, 3);
        assert!(long.best_reward >= short.best_reward - 1e-12);
    }

    #[test]
    fn collects_training_examples() {
        let topo = testbed();
        let m = models::vgg19(8, 0.25);
        let cost = CostModel::profile(&m.ops, &unique_gpus(&topo), 0.0, 1);
        let gg = group_ops(&m, &cost, 8, 7);
        let comm = CommModel::fit(3);
        let low = Lowering::new(&gg, &topo, &cost, &comm);
        let actions = enumerate_actions(&topo);
        let mut mcts = Mcts::new(&low, actions.clone(), UniformPrior, 2);
        mcts.collect_examples = true;
        let res = mcts.search(TRAIN_VISIT_THRESHOLD as usize * 2);
        assert!(!res.examples.is_empty(), "root should qualify");
        for ex in &res.examples {
            assert_eq!(ex.pi.len(), actions.len());
            let s: f32 = ex.pi.iter().sum();
            assert!((s - 1.0).abs() < 1e-4);
        }
    }

    /// A prior provider that strongly prefers one specific action.
    struct Biased(usize);
    impl PriorProvider for Biased {
        fn priors(
            &mut self,
            _s: &Strategy,
            _g: usize,
            _o: &SimOutcome,
            actions: &[Action],
        ) -> Vec<f32> {
            let mut p = vec![1e-3; actions.len()];
            p[self.0] = 1.0;
            p
        }
    }

    #[test]
    fn good_priors_accelerate_search() {
        // Find the action index for "V100-machine-only AllReduce", which
        // the uniform search discovers to be strong for VGG; a biased
        // prior should reach a DP-beating strategy in fewer iterations.
        let topo = testbed();
        let m = models::vgg19(8, 0.25);
        let cost = CostModel::profile(&m.ops, &unique_gpus(&topo), 0.0, 1);
        let gg = group_ops(&m, &cost, 12, 7);
        let comm = CommModel::fit(3);
        let low = Lowering::new(&gg, &topo, &cost, &comm);
        let actions = enumerate_actions(&topo);
        let target = actions
            .iter()
            .position(|a| {
                a.mask == 0b1 && a.option == crate::strategy::ReplOption::AllReduce
            })
            .unwrap();

        let mut uni = Mcts::new(&low, actions.clone(), UniformPrior, 11);
        let r_uni = uni.search(40);
        let mut bia = Mcts::new(&low, actions.clone(), Biased(target), 11);
        let r_bia = bia.search(40);
        let u = r_uni.first_beats_dp.unwrap_or(usize::MAX);
        let b = r_bia.first_beats_dp.unwrap_or(usize::MAX);
        assert!(b <= u, "biased {b} should beat uniform {u}");
    }
}
