//! Live fleet tenancy for `tag serve`: one shared [`ClusterState`]
//! behind a mutex, exposed as `POST /fleet/submit`, `POST
//! /fleet/complete` and `GET /fleet/status`.
//!
//! A submission is an ordinary wire plan request (the `POST /plan`
//! grammar) plus a `"gpus"` demand — and **without** a `"topology"`
//! key: the whole point is that the daemon chooses the slice.  Admission
//! picks devices with [`best_fit_devices`], leases them, and plans the
//! model on the leased slice; the lease is held until the tenant calls
//! `/fleet/complete` (training ran to its end) and its devices return
//! to the pool.  When the free pool cannot fit the demand the
//! submission is shed with `503` and a `Retry-After` scaled by how many
//! tenants must finish first — the same backpressure idiom as the
//! admission queue, one level up.
//!
//! The lock is held only for ledger mutation, never across a search:
//! concurrent submissions plan concurrently on disjoint slices.

use std::sync::Mutex;

use crate::api::json::Json;
use crate::api::{PlanRequest, SharedPlanner};
use crate::cluster::{DeviceId, Topology};
use crate::util::error::Result;

use super::lease::{ClusterState, LeaseId};
use super::sched::best_fit_devices;

/// One admitted tenant: its lease plus what we planned for it.
#[derive(Clone, Debug)]
struct ActiveJob {
    job: u64,
    lease: LeaseId,
    model: String,
    gpus: usize,
    devices: Vec<DeviceId>,
    iter_time_s: f64,
}

#[derive(Debug)]
struct Inner {
    cluster: ClusterState,
    active: Vec<ActiveJob>,
    submitted: u64,
    completed: u64,
    rejected: u64,
    failed: u64,
    next_job: u64,
}

/// The daemon's fleet ledger (cluster + active tenants + counters).
pub struct FleetState {
    inner: Mutex<Inner>,
}

/// What one submission resolved to; the router maps these to HTTP.
#[derive(Debug)]
pub enum SubmitOutcome {
    /// Admitted, leased and planned: the JSON response body (`200`).
    Planned(String),
    /// The free pool cannot fit the demand right now (`503`).
    Busy { reason: String, retry_after_s: u64 },
    /// Malformed or never-satisfiable request (`400`).
    Invalid(String),
    /// Admitted but planning failed; the lease was rolled back (`422`).
    Failed(String),
}

impl FleetState {
    /// Wrap a validated base topology; everything starts free.
    pub fn new(base: Topology) -> Result<Self> {
        Ok(Self {
            inner: Mutex::new(Inner {
                cluster: ClusterState::new(base)?,
                active: Vec::new(),
                submitted: 0,
                completed: 0,
                rejected: 0,
                failed: 0,
                next_job: 0,
            }),
        })
    }

    /// Lock the ledger, recovering from a poisoned mutex (a panicking
    /// handler thread must not take the fleet down with it — counters
    /// are monotone and the lease bitvec is always consistent between
    /// lock sections).
    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        match self.inner.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// `POST /fleet/submit`: decode, admit, lease, plan on the slice.
    pub fn submit(&self, planner: &SharedPlanner, body: &[u8]) -> SubmitOutcome {
        let text = match std::str::from_utf8(body) {
            Ok(text) => text,
            Err(e) => return SubmitOutcome::Invalid(format!("body is not valid utf-8: {e}")),
        };
        let root = match Json::parse(text) {
            Ok(root) => root,
            Err(e) => return SubmitOutcome::Invalid(format!("bad fleet request: {e}")),
        };
        let members = match &root {
            Json::Obj(members) => members,
            _ => return SubmitOutcome::Invalid("fleet request must be a JSON object".to_string()),
        };
        if root.get("topology").is_some() {
            return SubmitOutcome::Invalid(
                "fleet submissions plan on the leased slice; remove `topology`".to_string(),
            );
        }
        let gpus = match root.field("gpus").and_then(|v| v.as_usize()) {
            Ok(gpus) if gpus >= 1 => gpus,
            Ok(gpus) => return SubmitOutcome::Invalid(format!("gpus {gpus} must be >= 1")),
            Err(e) => return SubmitOutcome::Invalid(format!("bad fleet request: {e}")),
        };
        // Everything except `gpus` is an ordinary wire plan request;
        // reuse its decoder (which also rejects unknown fields).  The
        // decoded default topology is discarded for the leased slice.
        let request_obj =
            Json::Obj(members.iter().filter(|(k, _)| k != "gpus").cloned().collect());
        let mut request = match PlanRequest::decode(&request_obj.encode()) {
            Ok(request) => request,
            Err(e) => return SubmitOutcome::Invalid(format!("bad fleet request: {e}")),
        };

        // Admission: lease under the lock, plan outside it.
        let (job, lease) = {
            let mut inner = self.lock();
            let total = inner.cluster.num_devices();
            if gpus > total {
                return SubmitOutcome::Invalid(format!(
                    "gpus {gpus} exceeds the cluster's {total} devices"
                ));
            }
            let devices = match best_fit_devices(&inner.cluster, gpus) {
                Some(devices) => devices,
                None => {
                    inner.rejected += 1;
                    let free = inner.cluster.free_devices();
                    return SubmitOutcome::Busy {
                        reason: format!("{gpus} GPUs requested, {free} free"),
                        retry_after_s: 1 + inner.active.len() as u64,
                    };
                }
            };
            let lease = match inner.cluster.lease(&devices) {
                Ok(lease) => lease,
                Err(e) => {
                    inner.failed += 1;
                    return SubmitOutcome::Failed(format!("lease failed: {e}"));
                }
            };
            inner.submitted += 1;
            let job = inner.next_job;
            inner.next_job += 1;
            (job, lease)
        };

        request.topology = lease.topology.clone();
        let outcome = match planner.plan(&request) {
            Ok(outcome) => outcome,
            Err(e) => {
                let mut inner = self.lock();
                let _ = inner.cluster.release(lease.id);
                inner.failed += 1;
                return SubmitOutcome::Failed(format!("planning failed: {e}"));
            }
        };

        let iter_time_s = outcome.plan.times.final_time;
        let devices_json = Json::Arr(
            lease
                .devices
                .iter()
                .map(|d| Json::Str(format!("{}.{}", d.group, d.idx)))
                .collect(),
        );
        let mut body = Json::Obj(vec![
            ("job".to_string(), Json::Num(job as f64)),
            ("model".to_string(), Json::Str(outcome.plan.model_name.clone())),
            ("gpus".to_string(), Json::Num(gpus as f64)),
            ("devices".to_string(), devices_json),
            ("groups".to_string(), Json::Num(lease.topology.num_groups() as f64)),
            ("iter_time_s".to_string(), Json::Num(iter_time_s)),
            ("speedup".to_string(), Json::Num(outcome.plan.times.speedup)),
            ("cache_hit".to_string(), Json::Bool(outcome.cache_hit)),
        ])
        .encode();
        body.push('\n');

        let mut inner = self.lock();
        inner.active.push(ActiveJob {
            job,
            lease: lease.id,
            model: outcome.plan.model_name.clone(),
            gpus,
            devices: lease.devices,
            iter_time_s,
        });
        SubmitOutcome::Planned(body)
    }

    /// `POST /fleet/complete`: `{"job": N}` returns job `N`'s devices
    /// to the pool.  `(status, body)`.
    pub fn complete(&self, body: &[u8]) -> (u16, String) {
        let job = match std::str::from_utf8(body)
            .map_err(|e| crate::util::error::Error::msg(format!("body is not valid utf-8: {e}")))
            .and_then(Json::parse)
            .and_then(|root| root.field("job").and_then(Json::as_u64))
        {
            Ok(job) => job,
            Err(e) => return (400, format!("bad complete request: {e}\n")),
        };
        let mut inner = self.lock();
        let pos = match inner.active.iter().position(|a| a.job == job) {
            Some(pos) => pos,
            None => return (404, format!("unknown job {job}\n")),
        };
        let done = inner.active.remove(pos);
        if let Err(e) = inner.cluster.release(done.lease) {
            // Unreachable while the ledger invariant holds (every
            // active job owns a live lease), but never panic a worker.
            return (500, format!("release failed: {e}\n"));
        }
        inner.completed += 1;
        let mut body = Json::Obj(vec![
            ("job".to_string(), Json::Num(job as f64)),
            ("released".to_string(), Json::Num(done.devices.len() as f64)),
        ])
        .encode();
        body.push('\n');
        (200, body)
    }

    /// `GET /fleet/status`: the live ledger as JSON.
    pub fn status(&self) -> String {
        let inner = self.lock();
        let active = inner
            .active
            .iter()
            .map(|a| {
                Json::Obj(vec![
                    ("job".to_string(), Json::Num(a.job as f64)),
                    ("model".to_string(), Json::Str(a.model.clone())),
                    ("gpus".to_string(), Json::Num(a.gpus as f64)),
                    (
                        "devices".to_string(),
                        Json::Arr(
                            a.devices
                                .iter()
                                .map(|d| Json::Str(format!("{}.{}", d.group, d.idx)))
                                .collect(),
                        ),
                    ),
                    ("iter_time_s".to_string(), Json::Num(a.iter_time_s)),
                ])
            })
            .collect();
        let mut body = Json::Obj(vec![
            ("topology".to_string(), Json::Str(inner.cluster.base().name.clone())),
            ("devices".to_string(), Json::Num(inner.cluster.num_devices() as f64)),
            ("leased".to_string(), Json::Num(inner.cluster.leased_devices() as f64)),
            ("free".to_string(), Json::Num(inner.cluster.free_devices() as f64)),
            ("active".to_string(), Json::Arr(active)),
            ("submitted".to_string(), Json::Num(inner.submitted as f64)),
            ("completed".to_string(), Json::Num(inner.completed as f64)),
            ("rejected".to_string(), Json::Num(inner.rejected as f64)),
            ("failed".to_string(), Json::Num(inner.failed as f64)),
        ])
        .encode();
        body.push('\n');
        body
    }

    /// Append `tag_fleet_*` lines (with `# HELP`/`# TYPE` metadata) to
    /// a `/metrics` exposition.
    pub fn render_metrics(&self, out: &mut String) {
        let inner = self.lock();
        let total = inner.cluster.num_devices();
        let leased = inner.cluster.leased_devices();
        let mut series = |name: &str, kind: &str, help: &str, value: f64| {
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} {kind}\n{name} {value}\n"
            ));
        };
        series(
            "tag_fleet_submitted_total",
            "counter",
            "Fleet jobs submitted.",
            inner.submitted as f64,
        );
        series(
            "tag_fleet_completed_total",
            "counter",
            "Fleet jobs completed and released.",
            inner.completed as f64,
        );
        series(
            "tag_fleet_rejected_total",
            "counter",
            "Fleet submissions rejected (no feasible lease).",
            inner.rejected as f64,
        );
        series(
            "tag_fleet_failed_total",
            "counter",
            "Fleet submissions whose planning failed.",
            inner.failed as f64,
        );
        series(
            "tag_fleet_active_jobs",
            "gauge",
            "Jobs currently holding a lease.",
            inner.active.len() as f64,
        );
        series("tag_fleet_devices_total", "gauge", "Devices in the fleet.", total as f64);
        series(
            "tag_fleet_devices_leased",
            "gauge",
            "Devices currently leased out.",
            leased as f64,
        );
        series(
            "tag_fleet_devices_free",
            "gauge",
            "Devices currently free.",
            (total - leased) as f64,
        );
        let utilization = if total > 0 { leased as f64 / total as f64 } else { 0.0 };
        out.push_str(&format!(
            "# HELP tag_fleet_utilization Fraction of devices leased.\n\
             # TYPE tag_fleet_utilization gauge\n\
             tag_fleet_utilization {utilization:.6}\n"
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets::testbed;

    const SUBMIT: &[u8] = br#"{"model":"VGG19","iterations":20,"max_groups":8,"seed":1,"gpus":2}"#;

    fn fleet() -> (FleetState, SharedPlanner) {
        (FleetState::new(testbed()).unwrap(), SharedPlanner::builder().build())
    }

    #[test]
    fn submit_leases_plans_and_complete_releases() {
        let (f, p) = fleet();
        let body = match f.submit(&p, SUBMIT) {
            SubmitOutcome::Planned(body) => body,
            other => panic!("expected Planned, got {other:?}"),
        };
        assert!(body.contains("\"job\":0"), "{body}");
        assert!(body.contains("\"gpus\":2"), "{body}");
        assert!(body.contains("\"iter_time_s\":"), "{body}");

        let status = f.status();
        assert!(status.contains("\"leased\":2"), "{status}");
        assert!(status.contains("\"model\":\"VGG19\""), "{status}");
        let mut metrics = String::new();
        f.render_metrics(&mut metrics);
        assert!(metrics.contains("tag_fleet_devices_leased 2\n"), "{metrics}");
        assert!(metrics.contains("tag_fleet_active_jobs 1\n"), "{metrics}");

        let (status, body) = f.complete(br#"{"job":0}"#);
        assert_eq!(status, 200, "{body}");
        assert!(body.contains("\"released\":2"), "{body}");
        let after = f.status();
        assert!(after.contains("\"leased\":0"), "{after}");
        assert!(after.contains("\"completed\":1"), "{after}");
    }

    #[test]
    fn oversubscription_is_busy_and_impossible_demands_are_invalid() {
        let (f, p) = fleet();
        let whole = br#"{"model":"VGG19","iterations":20,"max_groups":8,"gpus":16}"#;
        assert!(matches!(f.submit(&p, whole), SubmitOutcome::Planned(_)));
        match f.submit(&p, SUBMIT) {
            SubmitOutcome::Busy { retry_after_s, .. } => assert_eq!(retry_after_s, 2),
            other => panic!("expected Busy, got {other:?}"),
        }
        let huge = br#"{"model":"VGG19","gpus":999}"#;
        assert!(matches!(f.submit(&p, huge), SubmitOutcome::Invalid(_)));
        let mut metrics = String::new();
        f.render_metrics(&mut metrics);
        assert!(metrics.contains("tag_fleet_rejected_total 1\n"), "{metrics}");
    }

    #[test]
    fn malformed_submissions_and_completions_are_rejected() {
        let (f, p) = fleet();
        for bad in [
            &b"not json"[..],
            br#"{"model":"VGG19"}"#,                      // gpus missing
            br#"{"model":"VGG19","gpus":0}"#,             // zero demand
            br#"{"model":"VGG19","gpus":2,"topology":"testbed"}"#, // slice is ours
            br#"{"model":"VGG19","gpus":2,"turbo":true}"#, // unknown field
            br#"{"gpus":2}"#,                             // model missing
        ] {
            assert!(
                matches!(f.submit(&p, bad), SubmitOutcome::Invalid(_)),
                "accepted {:?}",
                String::from_utf8_lossy(bad)
            );
        }
        assert_eq!(f.complete(b"not json").0, 400);
        assert_eq!(f.complete(br#"{"job":99}"#).0, 404);
        let status = f.status();
        assert!(status.contains("\"leased\":0"), "{status}");
    }
}
