//! The lease/residual layer: one shared cluster, many concurrent
//! holders.
//!
//! [`ClusterState`] tracks which devices of a base [`Topology`] are
//! leased to running jobs.  A [`lease`](ClusterState::lease) grants an
//! explicit device set and materializes a validated *slice* topology —
//! the base minus every device the lease was **not** granted, rebuilt
//! and re-routed through [`crate::cluster::residual`] (the same path
//! fault injection uses) — for the planner to search against.
//! [`release`](ClusterState::release) restores the capacity exactly:
//! the bookkeeping is a per-device bitvec, so lease/release sequences
//! cannot leave residue, and [`free_view`](ClusterState::free_view) of
//! a fully released cluster is bit-identical to the base (the
//! fingerprint-restoration property pinned in `rust/tests/fleet.rs`).
//!
//! Link capacity is handled structurally rather than fractionally: a
//! slice keeps every switch and every link between its surviving
//! nodes, so two leases in the same rack still share (and will each
//! be modeled as owning) the rack uplink.  Fractional link leasing is
//! a later refinement; device exclusivity — the invariant that
//! concurrent leases never overlap — is enforced here.

use crate::cluster::residual::{self, ResidualSpec};
use crate::cluster::{DeviceId, Residual, Topology};
use crate::util::error::Result;

/// Opaque handle identifying one active lease.  Ids are never reused
/// within a [`ClusterState`]'s lifetime.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LeaseId(pub u64);

/// A granted lease: the devices (base coordinates), the validated
/// slice topology to plan on, and the base-group → slice-group map.
#[derive(Clone, Debug)]
pub struct Lease {
    pub id: LeaseId,
    /// Granted devices in base coordinates, sorted.
    pub devices: Vec<DeviceId>,
    /// The leased slice: a re-routed, re-validated topology holding
    /// exactly `devices` (plus all switches), groups renumbered
    /// densely.
    pub topology: Topology,
    /// Base group index → slice group index; `None` when the lease
    /// holds no device of that base group.
    pub group_map: Vec<Option<usize>>,
}

/// The shared cluster: the base topology plus the live lease ledger.
#[derive(Clone, Debug)]
pub struct ClusterState {
    base: Topology,
    /// One flag per flat device index; `true` = leased out.
    leased: Vec<bool>,
    /// Active leases in grant order (deterministic iteration).
    active: Vec<(LeaseId, Vec<DeviceId>)>,
    next_id: u64,
}

impl ClusterState {
    /// Wrap a validated base topology; everything starts free.
    pub fn new(base: Topology) -> Result<Self> {
        base.validate()?;
        let n = base.num_devices();
        Ok(Self { base, leased: vec![false; n], active: Vec::new(), next_id: 0 })
    }

    pub fn base(&self) -> &Topology {
        &self.base
    }

    pub fn num_devices(&self) -> usize {
        self.base.num_devices()
    }

    pub fn leased_devices(&self) -> usize {
        self.leased.iter().filter(|&&l| l).count()
    }

    pub fn free_devices(&self) -> usize {
        self.num_devices() - self.leased_devices()
    }

    pub fn active_leases(&self) -> usize {
        self.active.len()
    }

    pub fn is_free(&self, d: DeviceId) -> bool {
        d.group < self.base.num_groups()
            && d.idx < self.base.groups[d.group].count
            && !self.leased[self.base.device_flat_index(d)]
    }

    /// Free-device count per base group.
    pub fn free_per_group(&self) -> Vec<usize> {
        let mut free = Vec::with_capacity(self.base.num_groups());
        let mut flat = 0usize;
        for g in &self.base.groups {
            let mut n = 0;
            for _ in 0..g.count {
                if !self.leased[flat] {
                    n += 1;
                }
                flat += 1;
            }
            free.push(n);
        }
        free
    }

    /// The residual view of everything currently *free*: what a new
    /// arrival could be planned against.  With no active leases this
    /// is exactly the base (identity `group_map`, cloned topology);
    /// errors when every device is leased out.
    pub fn free_view(&self) -> Result<Residual> {
        if self.active.is_empty() {
            return Ok(Residual {
                topology: self.base.clone(),
                group_map: (0..self.base.num_groups()).map(Some).collect(),
                dead_devices: Vec::new(),
            });
        }
        let name = format!("{}~free", self.base.name);
        residual::build(&self.base, &name, &ResidualSpec::remove_devices(&self.base, &self.leased))
    }

    /// Grant a lease on an explicit device set.  Errors when the set
    /// is empty, names hardware the base does not have, repeats a
    /// device, overlaps an active lease, or when the requested slice
    /// is disconnected (route coverage is re-validated on the rebuild).
    /// On any error the ledger is unchanged.
    pub fn lease(&mut self, devices: &[DeviceId]) -> Result<Lease> {
        crate::ensure!(!devices.is_empty(), "empty lease request");
        let mut granted = vec![false; self.num_devices()];
        for &d in devices {
            crate::ensure!(
                d.group < self.base.num_groups() && d.idx < self.base.groups[d.group].count,
                "lease target ({}, {}) is not a device of `{}`",
                d.group,
                d.idx,
                self.base.name
            );
            let flat = self.base.device_flat_index(d);
            crate::ensure!(!granted[flat], "device ({}, {}) requested twice", d.group, d.idx);
            crate::ensure!(
                !self.leased[flat],
                "device ({}, {}) is already leased",
                d.group,
                d.idx
            );
            granted[flat] = true;
        }
        let id = LeaseId(self.next_id);

        let (topology, group_map) = if devices.len() == self.num_devices() {
            // Whole-cluster lease (the FIFO baseline): the slice *is*
            // the base — skip the rebuild so repeat jobs share the
            // base topology's plan-cache fingerprint.
            (self.base.clone(), (0..self.base.num_groups()).map(Some).collect())
        } else {
            // The slice removes everything NOT granted.
            let keep_out: Vec<bool> = granted.iter().map(|&g| !g).collect();
            let name = format!("{}~lease{}", self.base.name, id.0);
            let r = residual::build(
                &self.base,
                &name,
                &ResidualSpec::remove_devices(&self.base, &keep_out),
            )?;
            (r.topology, r.group_map)
        };

        // Commit only after the rebuild validated.
        let mut sorted: Vec<DeviceId> = devices.to_vec();
        sorted.sort();
        for &d in &sorted {
            self.leased[self.base.device_flat_index(d)] = true;
        }
        self.next_id += 1;
        self.active.push((id, sorted.clone()));
        Ok(Lease { id, devices: sorted, topology, group_map })
    }

    /// Return a lease's devices to the free pool.  Errors on an
    /// unknown (or already released) id.
    pub fn release(&mut self, id: LeaseId) -> Result<Vec<DeviceId>> {
        let pos = self
            .active
            .iter()
            .position(|(l, _)| *l == id)
            .ok_or_else(|| crate::util::error::Error::msg(format!("unknown lease {}", id.0)))?;
        let (_, devices) = self.active.remove(pos);
        for &d in &devices {
            self.leased[self.base.device_flat_index(d)] = false;
        }
        Ok(devices)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::fingerprint;
    use crate::cluster::presets::{multi_rack, testbed};

    #[test]
    fn lease_grants_a_validated_slice_and_release_restores() {
        let mut c = ClusterState::new(multi_rack()).unwrap();
        let before = fingerprint::topology(&c.free_view().unwrap().topology);
        let want = [
            DeviceId { group: 1, idx: 0 },
            DeviceId { group: 1, idx: 1 },
            DeviceId { group: 1, idx: 2 },
            DeviceId { group: 1, idx: 3 },
        ];
        let lease = c.lease(&want).unwrap();
        assert_eq!(lease.topology.num_devices(), 4);
        assert_eq!(lease.topology.num_groups(), 1);
        assert_eq!(lease.group_map[1], Some(0));
        assert_eq!(lease.group_map[0], None);
        lease.topology.validate().unwrap();
        assert_eq!((c.free_devices(), c.leased_devices(), c.active_leases()), (28, 4, 1));
        assert!(!c.is_free(want[0]));

        let returned = c.release(lease.id).unwrap();
        assert_eq!(returned, want.to_vec());
        assert_eq!((c.free_devices(), c.active_leases()), (32, 0));
        let after = fingerprint::topology(&c.free_view().unwrap().topology);
        assert_eq!(before, after, "release restores the base exactly");
    }

    #[test]
    fn whole_cluster_lease_is_the_base_itself() {
        let t = testbed();
        let mut c = ClusterState::new(t.clone()).unwrap();
        let lease = c.lease(&t.devices()).unwrap();
        assert_eq!(
            fingerprint::topology(&lease.topology),
            fingerprint::topology(&t),
            "FIFO whole-cluster slices share the base fingerprint"
        );
        assert_eq!(c.free_devices(), 0);
        assert!(c.free_view().is_err(), "nothing free to view");
        c.release(lease.id).unwrap();
        assert_eq!(c.free_devices(), t.num_devices());
    }

    #[test]
    fn overlapping_and_bogus_leases_are_rejected_without_side_effects() {
        let mut c = ClusterState::new(testbed()).unwrap();
        let d = DeviceId { group: 0, idx: 0 };
        let held = c.lease(&[d]).unwrap();
        assert!(c.lease(&[d]).unwrap_err().to_string().contains("already leased"));
        assert!(c.lease(&[]).is_err());
        assert!(c
            .lease(&[DeviceId { group: 99, idx: 0 }])
            .unwrap_err()
            .to_string()
            .contains("not a device"));
        let twice = [DeviceId { group: 1, idx: 0 }, DeviceId { group: 1, idx: 0 }];
        assert!(c.lease(&twice).unwrap_err().to_string().contains("twice"));
        // Failed grants must not leak into the ledger.
        assert_eq!((c.active_leases(), c.leased_devices()), (1, 1));
        c.release(held.id).unwrap();
        assert!(c.release(held.id).is_err(), "double release is an error");
    }

    #[test]
    fn free_view_excludes_leased_devices() {
        let mut c = ClusterState::new(testbed()).unwrap();
        let lease = c
            .lease(&[DeviceId { group: 0, idx: 0 }, DeviceId { group: 0, idx: 1 }])
            .unwrap();
        let free = c.free_view().unwrap();
        assert_eq!(free.topology.num_devices(), c.free_devices());
        assert_eq!(free.dead_devices, lease.devices);
        free.topology.validate().unwrap();
    }
}
