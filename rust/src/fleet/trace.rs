//! Seeded job-stream generation: Poisson arrivals of heterogeneous
//! planning jobs, in the `cluster::generator` idiom — pure
//! [`Rng`]-driven, no wall clock, fixed `(topology, seed)` reproduces
//! the trace byte for byte.
//!
//! A [`JobSpec`] is everything the fleet scheduler needs to know about
//! one tenant: which model at which scale, how many GPUs it demands,
//! how many training steps it will run (virtual service time = `steps
//! ×` the planned iteration time, so a better placement finishes the
//! job sooner), when it arrives, and the search seed its plan uses.

use crate::cluster::Topology;
use crate::util::Rng;

/// The model slate traces draw from (all comm-heavy enough that
/// placement quality moves the iteration time).
pub const TRACE_MODELS: [&str; 3] = ["VGG19", "ResNet101", "InceptionV3"];

/// One job of the stream.
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    /// Dense job id (== index in the generated trace).
    pub id: usize,
    /// Model name, resolved via [`crate::models::by_name`] at plan
    /// time.
    pub model: String,
    /// Model scale factor.
    pub scale: f64,
    /// Devices the job demands.
    pub gpus: usize,
    /// Training steps to run; virtual service time is `steps *
    /// iter_time` of the plan the job receives.
    pub steps: f64,
    /// Arrival time on the virtual clock, seconds.
    pub arrival_s: f64,
    /// Search seed for this job's plan.
    pub seed: u64,
}

/// Draw `n` jobs with exponential interarrival gaps of mean
/// `mean_interarrival_s` (a Poisson arrival process), GPU demands in
/// `[1, num_devices/4]` and step counts in `[60, 240]`.  Deterministic
/// in `(topo, seed, n, mean_interarrival_s)`; arrivals come out
/// sorted.
pub fn generate_jobs(
    topo: &Topology,
    seed: u64,
    n: usize,
    mean_interarrival_s: f64,
) -> Vec<JobSpec> {
    let mut rng = Rng::new(seed);
    let max_gpus = (topo.num_devices() / 4).max(1);
    let mut at = 0.0f64;
    (0..n)
        .map(|id| {
            // Inverse-CDF exponential draw; `1 - u` is in (0, 1] so the
            // log is finite.
            at += -mean_interarrival_s * (1.0 - rng.next_f64()).ln();
            JobSpec {
                id,
                model: TRACE_MODELS[rng.below(TRACE_MODELS.len())].to_string(),
                scale: 0.25,
                gpus: rng.range(1, max_gpus),
                steps: rng.range(60, 240) as f64,
                arrival_s: at,
                seed: rng.next_u64(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets::multi_rack;

    #[test]
    fn traces_are_deterministic_sorted_and_bounded() {
        let t = multi_rack();
        let a = generate_jobs(&t, 7, 16, 20.0);
        let b = generate_jobs(&t, 7, 16, 20.0);
        assert_eq!(a, b);
        assert_eq!(a.len(), 16);
        let mut last = 0.0;
        for (i, j) in a.iter().enumerate() {
            assert_eq!(j.id, i);
            assert!(j.arrival_s >= last, "arrivals sorted");
            last = j.arrival_s;
            assert!(j.gpus >= 1 && j.gpus <= t.num_devices() / 4);
            assert!((60.0..=240.0).contains(&j.steps));
            assert!(TRACE_MODELS.contains(&j.model.as_str()));
        }
        let c = generate_jobs(&t, 8, 16, 20.0);
        assert_ne!(a, c, "different seeds differ");
    }
}
