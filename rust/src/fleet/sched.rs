//! The fleet scheduler: a deterministic event loop replaying a job
//! stream onto one shared [`ClusterState`], planning every admitted
//! job through the existing [`Planner`] against its leased slice.
//!
//! Time is **virtual**: the loop advances a `f64` clock from event to
//! event (arrivals from the trace, completions from `steps ×` the
//! planned iteration time), never reads a wall clock, and breaks ties
//! deterministically (completions before arrivals at equal time;
//! lower job id first among simultaneous completions).  A fixed
//! `(trace, config)` therefore replays byte-for-byte — asserted in
//! `rust/tests/fleet.rs` — as long as the per-plan determinism
//! contract holds (`workers == 1`, no deadline; both knobs are still
//! plumbed through for throughput runs that trade determinism away).
//!
//! Two policies:
//!
//! * [`Policy::Fifo`] — the naive baseline: each job leases the
//!   **whole cluster** and runs exclusively; arrivals queue behind it
//!   in order.  Planning sees the full topology every time (so repeat
//!   shapes hit the plan cache), but an 8-GPU job still serializes a
//!   32-GPU pod.
//! * [`Policy::BestFit`] — residual-aware: each job leases only the
//!   devices it demands, chosen by [`best_fit_devices`] (tightest
//!   single group first, then greedily fewest groups), and jobs run
//!   concurrently.  A bounded backfill window lets small jobs overtake
//!   a head-of-queue job that does not fit yet — position 0 is always
//!   examined first, so the head is never starved, and the window
//!   bounds how far overtaking reaches.

use std::collections::VecDeque;

use crate::api::{PlanRequest, Planner, SearchBackend};
use crate::cluster::{DeviceId, Topology};
use crate::models;
use crate::util::error::Result;

use super::lease::{ClusterState, LeaseId};
use super::trace::JobSpec;

/// Scheduling policy for [`replay`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Whole-cluster exclusive leases, strict arrival order.
    Fifo,
    /// Demand-sized leases via [`best_fit_devices`], bounded backfill.
    BestFit,
}

impl Policy {
    pub fn name(self) -> &'static str {
        match self {
            Policy::Fifo => "fifo",
            Policy::BestFit => "best-fit",
        }
    }

    /// Parse a CLI/wire policy name.
    pub fn parse(text: &str) -> Option<Policy> {
        match text {
            "fifo" => Some(Policy::Fifo),
            "best-fit" | "bestfit" | "best_fit" => Some(Policy::BestFit),
            _ => None,
        }
    }
}

/// Replay knobs shared by every job of a run.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    pub policy: Policy,
    /// Search iterations per plan.
    pub iterations: usize,
    /// Op-group cap per plan.
    pub max_groups: usize,
    /// Tree-parallel search workers per plan (1 = byte-deterministic).
    pub workers: usize,
    /// Per-plan deadline; `None` runs the full budget
    /// (deterministic).
    pub deadline_ms: Option<u64>,
    /// How many queue positions past the head backfill may examine
    /// (BestFit only; 0 = strict head-of-queue).
    pub backfill: usize,
    /// Run the SFB optimizer on each plan.
    pub sfb: bool,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            policy: Policy::BestFit,
            iterations: 16,
            max_groups: 10,
            workers: 1,
            deadline_ms: None,
            backfill: 4,
            sfb: false,
        }
    }
}

/// Per-job outcome of a replay.
#[derive(Clone, Debug)]
pub struct JobRow {
    pub id: usize,
    pub model: String,
    /// Devices demanded (== leased under BestFit; FIFO leases the
    /// whole cluster regardless).
    pub gpus: usize,
    /// Groups of the leased slice the job planned against.
    pub groups: usize,
    pub arrival_s: f64,
    pub start_s: f64,
    pub finish_s: f64,
    /// Planned iteration time on the leased slice.
    pub iter_time_s: f64,
    /// Whether the plan came from the cache (excluded from
    /// [`FleetReport::render`]: it depends on planner history, not on
    /// the schedule).
    pub cache_hit: bool,
}

/// Everything a replay produced.
#[derive(Clone, Debug)]
pub struct FleetReport {
    pub policy: Policy,
    pub total_devices: usize,
    /// One row per job, in job-id order, all completed.
    pub jobs: Vec<JobRow>,
    /// Virtual time from 0 to the last completion.
    pub makespan_s: f64,
    /// Mean of `finish - arrival` (queue wait included).
    pub mean_jct_s: f64,
    /// Demanded device-seconds over cluster device-seconds:
    /// `Σ gpus·(finish-start) / (total_devices · makespan)`.  The
    /// demand basis is identical across policies, so the FIFO gap to
    /// 1.0 is exactly the capacity its exclusive leases waste.
    pub utilization: f64,
    /// Plans computed (== jobs) and how many were cache hits —
    /// planner-history-dependent, reported but never rendered.
    pub plans: usize,
    pub cache_hits: usize,
}

impl FleetReport {
    /// Deterministic human-readable table: a pure function of the
    /// schedule (no wall times, no cache state), so two replays of the
    /// same trace under the same config render byte-identically.
    pub fn render(&self) -> String {
        let mut out = String::with_capacity(256 + 64 * self.jobs.len());
        out.push_str(&format!(
            "fleet replay: policy={} jobs={} devices={}\n",
            self.policy.name(),
            self.jobs.len(),
            self.total_devices
        ));
        out.push_str(&format!(
            "  {:>3} {:<12} {:>4} {:>6} {:>9} {:>9} {:>9} {:>10}\n",
            "id", "model", "gpus", "groups", "arrive", "start", "finish", "iter(s)"
        ));
        for j in &self.jobs {
            out.push_str(&format!(
                "  {:>3} {:<12} {:>4} {:>6} {:>9.3} {:>9.3} {:>9.3} {:>10.6}\n",
                j.id, j.model, j.gpus, j.groups, j.arrival_s, j.start_s, j.finish_s, j.iter_time_s
            ));
        }
        out.push_str(&format!(
            "  makespan {:.3}s  mean jct {:.3}s  utilization {:.3}\n",
            self.makespan_s, self.mean_jct_s, self.utilization
        ));
        out
    }
}

/// Deterministic best-fit device selection against the current free
/// pool: the single free group that fits the demand most tightly
/// (fewest spare devices, lowest index on ties); otherwise greedily
/// span the fewest groups (most-free first, lowest index on ties).
/// Within a group, lowest free indices are taken first.  `None` when
/// the demand exceeds the free count (or is zero).
pub fn best_fit_devices(state: &ClusterState, gpus: usize) -> Option<Vec<DeviceId>> {
    if gpus == 0 || gpus > state.free_devices() {
        return None;
    }
    let free = state.free_per_group();
    let mut chosen = Vec::with_capacity(gpus);
    let tightest = (0..free.len()).filter(|&g| free[g] >= gpus).min_by_key(|&g| (free[g], g));
    match tightest {
        Some(g) => take_free(state, g, gpus, &mut chosen),
        None => {
            let mut order: Vec<usize> = (0..free.len()).filter(|&g| free[g] > 0).collect();
            order.sort_by(|&a, &b| free[b].cmp(&free[a]).then(a.cmp(&b)));
            let mut need = gpus;
            for g in order {
                if need == 0 {
                    break;
                }
                let n = free[g].min(need);
                take_free(state, g, n, &mut chosen);
                need -= n;
            }
        }
    }
    Some(chosen)
}

/// Append the first `n` free devices of group `g`, ascending index.
fn take_free(state: &ClusterState, g: usize, n: usize, out: &mut Vec<DeviceId>) {
    let mut taken = 0;
    let count = state.base().groups[g].count;
    for idx in 0..count {
        if taken == n {
            break;
        }
        let d = DeviceId { group: g, idx };
        if state.is_free(d) {
            out.push(d);
            taken += 1;
        }
    }
    debug_assert_eq!(taken, n, "free_per_group promised {n} free devices in group {g}");
}

struct Running {
    job: usize,
    lease: LeaseId,
    finish_s: f64,
}

struct Sim<'a, B: SearchBackend + ?Sized> {
    planner: &'a Planner<B>,
    jobs: &'a [JobSpec],
    cfg: &'a FleetConfig,
    cluster: ClusterState,
    queue: VecDeque<usize>,
    running: Vec<Running>,
    rows: Vec<Option<JobRow>>,
    clock: f64,
    plans: usize,
    cache_hits: usize,
}

impl<B: SearchBackend + ?Sized> Sim<'_, B> {
    /// Lease `devices`, plan the job on the slice, and put it on the
    /// run list with its virtual completion time.
    fn start(&mut self, job: usize, devices: &[DeviceId]) -> Result<()> {
        let spec = &self.jobs[job];
        let lease = self.cluster.lease(devices)?;
        let model = models::by_name(&spec.model, spec.scale).ok_or_else(|| {
            crate::util::error::Error::msg(format!("job {}: unknown model {}", job, spec.model))
        })?;
        let mut request = PlanRequest::new(model, lease.topology.clone())
            .budget(self.cfg.iterations, self.cfg.max_groups)
            .seed(spec.seed)
            .sfb(self.cfg.sfb)
            .workers(self.cfg.workers.max(1));
        if let Some(ms) = self.cfg.deadline_ms {
            request = request.deadline_ms(ms.max(1));
        }
        let outcome = {
            let _s = crate::obs::span_arg("fleet.job", spec.id as i64);
            self.planner.plan(&request)?
        };
        self.plans += 1;
        if outcome.cache_hit {
            self.cache_hits += 1;
        }
        let iter_time_s = outcome.plan.times.final_time;
        crate::ensure!(
            iter_time_s.is_finite() && iter_time_s > 0.0,
            "job {job}: degenerate planned iteration time {iter_time_s}"
        );
        let finish_s = self.clock + spec.steps * iter_time_s;
        self.rows[job] = Some(JobRow {
            id: spec.id,
            model: spec.model.clone(),
            gpus: spec.gpus,
            groups: lease.topology.num_groups(),
            arrival_s: spec.arrival_s,
            start_s: self.clock,
            finish_s,
            iter_time_s,
            cache_hit: outcome.cache_hit,
        });
        self.running.push(Running { job, lease: lease.id, finish_s });
        Ok(())
    }

    /// Admit everything the policy allows at the current clock.
    fn admit(&mut self) -> Result<()> {
        match self.cfg.policy {
            Policy::Fifo => {
                // Exclusive tenancy: one whole-cluster lease at a time.
                if self.running.is_empty() {
                    if let Some(&job) = self.queue.front() {
                        let all = self.cluster.base().devices();
                        self.start(job, &all)?;
                        let _ = self.queue.pop_front();
                    }
                }
            }
            Policy::BestFit => {
                let mut i = 0;
                while i < self.queue.len() && i <= self.cfg.backfill {
                    let job = self.queue[i];
                    match best_fit_devices(&self.cluster, self.jobs[job].gpus) {
                        Some(devices) => {
                            self.start(job, &devices)?;
                            let _ = self.queue.remove(i);
                        }
                        None => i += 1,
                    }
                }
            }
        }
        Ok(())
    }

    /// The earliest completion, ties broken by job id.
    fn next_completion(&self) -> Option<usize> {
        (0..self.running.len()).min_by(|&a, &b| {
            let (ra, rb) = (&self.running[a], &self.running[b]);
            ra.finish_s
                .partial_cmp(&rb.finish_s)
                .expect("finish times are finite")
                .then(self.jobs[ra.job].id.cmp(&self.jobs[rb.job].id))
        })
    }
}

/// Replay `jobs` (any order; sorted internally by `(arrival, id)`)
/// onto `base` under `cfg`, planning each admitted job with `planner`.
/// Every job completes or the replay errors — jobs demanding more
/// devices than the cluster has are rejected up front.
pub fn replay<B: SearchBackend + ?Sized>(
    planner: &Planner<B>,
    base: &Topology,
    jobs: &[JobSpec],
    cfg: &FleetConfig,
) -> Result<FleetReport> {
    let cluster = ClusterState::new(base.clone())?;
    let total_devices = cluster.num_devices();
    for j in jobs {
        crate::ensure!(
            j.gpus >= 1 && j.gpus <= total_devices,
            "job {} demands {} GPUs but `{}` has {}",
            j.id,
            j.gpus,
            base.name,
            total_devices
        );
        crate::ensure!(
            j.arrival_s.is_finite() && j.arrival_s >= 0.0 && j.steps.is_finite() && j.steps > 0.0,
            "job {} has a degenerate arrival or step count",
            j.id
        );
    }
    let mut order: Vec<usize> = (0..jobs.len()).collect();
    order.sort_by(|&a, &b| {
        jobs[a]
            .arrival_s
            .partial_cmp(&jobs[b].arrival_s)
            .expect("arrivals are finite")
            .then(jobs[a].id.cmp(&jobs[b].id))
    });

    let mut sim = Sim {
        planner,
        jobs,
        cfg,
        cluster,
        queue: VecDeque::new(),
        running: Vec::new(),
        rows: vec![None; jobs.len()],
        clock: 0.0,
        plans: 0,
        cache_hits: 0,
    };

    let mut next_arrival = 0usize;
    loop {
        sim.admit()?;
        let arrival = order.get(next_arrival).map(|&j| jobs[j].arrival_s);
        let completion = sim.next_completion();
        // Completions win ties: freed capacity admits queued work
        // before the simultaneous arrival joins the queue.
        let take_completion = match (arrival, completion) {
            (None, None) => break,
            (None, Some(_)) => true,
            (Some(_), None) => false,
            (Some(at), Some(ri)) => sim.running[ri].finish_s <= at,
        };
        if take_completion {
            let done = sim.running.swap_remove(completion.expect("checked above"));
            sim.clock = done.finish_s;
            sim.cluster.release(done.lease)?;
        } else {
            sim.clock = arrival.expect("checked above");
            sim.queue.push_back(order[next_arrival]);
            next_arrival += 1;
        }
    }
    crate::ensure!(
        sim.queue.is_empty() && sim.running.is_empty(),
        "replay ended with unfinished jobs"
    );
    crate::ensure!(
        sim.cluster.active_leases() == 0 && sim.cluster.free_devices() == total_devices,
        "replay leaked leases"
    );

    let rows: Vec<JobRow> =
        sim.rows.into_iter().map(|r| r.expect("every job completed")).collect();
    let makespan_s = rows.iter().map(|r| r.finish_s).fold(0.0f64, f64::max);
    let mean_jct_s = if rows.is_empty() {
        0.0
    } else {
        rows.iter().map(|r| r.finish_s - r.arrival_s).sum::<f64>() / rows.len() as f64
    };
    let busy: f64 = rows.iter().map(|r| r.gpus as f64 * (r.finish_s - r.start_s)).sum();
    let utilization = if makespan_s > 0.0 {
        busy / (total_devices as f64 * makespan_s)
    } else {
        0.0
    };
    Ok(FleetReport {
        policy: cfg.policy,
        total_devices,
        jobs: rows,
        makespan_s,
        mean_jct_s,
        utilization,
        plans: sim.plans,
        cache_hits: sim.cache_hits,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets::multi_rack;

    #[test]
    fn best_fit_prefers_the_tightest_group_then_fewest_groups() {
        let mut c = ClusterState::new(multi_rack()).unwrap();
        // Demand 4: the T4 machines (4 free) fit exactly; group 1 is
        // the lowest-indexed tight fit.
        let d = best_fit_devices(&c, 4).unwrap();
        assert!(d.iter().all(|x| x.group == 1));
        let lease = c.lease(&d).unwrap();
        // Demand 2: V100 pairs (2 free) are now the tightest.
        let d2 = best_fit_devices(&c, 2).unwrap();
        assert!(d2.iter().all(|x| x.group == 0));
        // Demand 5: no single group fits; spans the fewest groups,
        // most-free first (a 4-wide T4 machine plus one more device).
        let d5 = best_fit_devices(&c, 5).unwrap();
        assert_eq!(d5.len(), 5);
        assert_eq!(d5.iter().filter(|x| x.group == 4).count(), 4, "{d5:?}");
        // Infeasible demands are None, zero is None.
        assert!(best_fit_devices(&c, 0).is_none());
        assert!(best_fit_devices(&c, 999).is_none());
        c.release(lease.id).unwrap();
    }

    #[test]
    fn policy_names_round_trip() {
        for p in [Policy::Fifo, Policy::BestFit] {
            assert_eq!(Policy::parse(p.name()), Some(p));
        }
        assert_eq!(Policy::parse("bestfit"), Some(Policy::BestFit));
        assert_eq!(Policy::parse("lifo"), None);
    }
}
