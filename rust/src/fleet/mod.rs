//! Fleet scheduling: multi-tenant planning over a leased cluster with
//! online job streams.
//!
//! The planner (one model, one topology, one deployment) answers the
//! question a *single* tenant asks.  A leased GPU fleet faces the
//! harder one: jobs arrive over time, each demanding a few devices of
//! a shared cluster, and the operator chooses **which devices** each
//! job gets before the planner chooses how to use them.  That choice
//! interacts with the device topology exactly the way the paper's
//! placement does — four T4s behind one PCIe bridge beat four devices
//! scattered across racks — so the scheduler and the planner share one
//! vocabulary: a lease materializes a validated residual [`Topology`]
//! (the [`crate::cluster::residual`] path fault injection also uses),
//! and the planner searches that slice as if it were the whole world.
//!
//! Three layers:
//!
//! * [`lease`] — [`ClusterState`]: the capacity ledger.  Leases grant
//!   exclusive device sets, materialize re-routed slice topologies,
//!   and release restores the base bit-for-bit.
//! * [`sched`] + [`trace`] — deterministic offline replay: a seeded
//!   Poisson job stream ([`generate_jobs`]) replayed under a policy
//!   ([`Policy::Fifo`] whole-cluster baseline vs [`Policy::BestFit`]
//!   residual-aware packing with bounded backfill) on a virtual
//!   clock; [`FleetReport`] carries makespan, mean JCT and
//!   utilization.  `tag fleet` is the CLI face.
//! * [`live`] — [`FleetState`]: the same admission logic as a serving
//!   daemon ledger behind `POST /fleet/submit` / `/fleet/complete` /
//!   `GET /fleet/status`.
//!
//! [`Topology`]: crate::cluster::Topology

pub mod lease;
pub mod live;
pub mod sched;
pub mod trace;

pub use lease::{ClusterState, Lease, LeaseId};
pub use live::{FleetState, SubmitOutcome};
pub use sched::{best_fit_devices, replay, FleetConfig, FleetReport, JobRow, Policy};
pub use trace::{generate_jobs, JobSpec, TRACE_MODELS};
