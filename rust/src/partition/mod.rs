//! Multilevel k-way graph partitioner — the METIS replacement used for op
//! grouping (paper §4.1.1: "METIS to partition the computation graph to
//! no more than 60 groups by minimizing the tensor sizes on the cut
//! edges, while keeping the total computation time of each partition
//! balanced with a balance factor of 2") and for the "Model Parallelism"
//! replication option (§4.2).
//!
//! Classic three-phase scheme (Karypis & Kumar):
//! 1. **Coarsening** — heavy-edge matching until the graph is small.
//! 2. **Initial partition** — greedy BFS region growing on the coarse
//!    graph (recursive bisection for k-way).
//! 3. **Refinement** — FM boundary refinement with best-prefix rollback
//!    while projecting back through the levels.

mod fm;

use crate::util::Rng;
use fm::fm_refine;

/// Undirected weighted graph for partitioning.
#[derive(Clone, Debug, Default)]
pub struct PartGraph {
    pub node_w: Vec<f64>,
    /// Adjacency: (neighbor, edge weight); symmetric.
    pub adj: Vec<Vec<(usize, f64)>>,
}

impl PartGraph {
    pub fn new(n: usize) -> Self {
        Self { node_w: vec![1.0; n], adj: vec![Vec::new(); n] }
    }

    pub fn len(&self) -> usize {
        self.node_w.len()
    }

    pub fn is_empty(&self) -> bool {
        self.node_w.is_empty()
    }

    /// Add an undirected edge, merging parallel edges.
    pub fn add_edge(&mut self, a: usize, b: usize, w: f64) {
        if a == b || w <= 0.0 {
            return;
        }
        for half in [(a, b), (b, a)] {
            let (u, v) = half;
            if let Some(e) = self.adj[u].iter_mut().find(|(x, _)| *x == v) {
                e.1 += w;
            } else {
                self.adj[u].push((v, w));
            }
        }
    }

    pub fn total_node_weight(&self) -> f64 {
        self.node_w.iter().sum()
    }

    /// Total weight of edges cut by `labels`.
    pub fn cut(&self, labels: &[usize]) -> f64 {
        let mut c = 0.0;
        for (u, nbrs) in self.adj.iter().enumerate() {
            for &(v, w) in nbrs {
                if u < v && labels[u] != labels[v] {
                    c += w;
                }
            }
        }
        c
    }
}

/// Partition `g` into `k` parts minimizing edge cut with each part's node
/// weight at most `balance` times the average.  Returns labels in
/// `[0, k)`. Deterministic for a given seed.
pub fn partition(g: &PartGraph, k: usize, balance: f64, seed: u64) -> Vec<usize> {
    assert!(k >= 1);
    assert!(balance >= 1.0);
    let n = g.len();
    if n == 0 {
        return Vec::new();
    }
    if k == 1 || n <= k {
        // Trivial cases: everything in one part / one node per part.
        return (0..n).map(|i| i % k).collect();
    }
    let mut rng = Rng::new(seed);
    let mut labels = vec![0usize; n];
    let ids: Vec<usize> = (0..n).collect();
    recurse(g, &ids, k, 0, balance, &mut labels, &mut rng);
    labels
}

/// Recursive bisection: split `ids` into ceil(k/2)/floor(k/2) shares.
fn recurse(
    g: &PartGraph,
    ids: &[usize],
    k: usize,
    label_base: usize,
    balance: f64,
    labels: &mut [usize],
    rng: &mut Rng,
) {
    if k == 1 {
        for &i in ids {
            labels[i] = label_base;
        }
        return;
    }
    let k1 = k / 2;
    let k2 = k - k1;
    let frac = k2 as f64 / k as f64; // weight share of side A (gets k2 parts)
    let (sub, local_ids) = induced(g, ids);
    let side = multilevel_bisect(&sub, frac, balance, rng);
    let mut a = Vec::new();
    let mut b = Vec::new();
    for (li, &orig) in local_ids.iter().enumerate() {
        if side[li] == 0 {
            a.push(orig);
        } else {
            b.push(orig);
        }
    }
    // Degenerate split guard: each side must be able to host its share
    // of parts.
    if a.len() < k2 || b.len() < k1 {
        let mut all: Vec<usize> = ids.to_vec();
        rng.shuffle(&mut all);
        let cut = (all.len() * k2 / k).max(1).min(all.len() - 1);
        a = all[..cut].to_vec();
        b = all[cut..].to_vec();
    }
    recurse(g, &a, k2, label_base, balance, labels, rng);
    recurse(g, &b, k1, label_base + k2, balance, labels, rng);
}

/// Induced subgraph over `ids`; returns (subgraph, local->orig map).
fn induced(g: &PartGraph, ids: &[usize]) -> (PartGraph, Vec<usize>) {
    let mut local = vec![usize::MAX; g.len()];
    for (li, &i) in ids.iter().enumerate() {
        local[i] = li;
    }
    let mut sub = PartGraph::new(ids.len());
    for (li, &i) in ids.iter().enumerate() {
        sub.node_w[li] = g.node_w[i];
        for &(j, w) in &g.adj[i] {
            let lj = local[j];
            if lj != usize::MAX && lj > li {
                sub.add_edge(li, lj, w);
            }
        }
    }
    (sub, ids.to_vec())
}

/// Bisect `g` into sides {0, 1} with side-0 weight ~ frac of total.
///
/// The user-visible balance factor applies to the final k-way partition;
/// individual bisections use a much tighter factor (as METIS does) —
/// imbalance compounds multiplicatively through the recursion and a
/// lopsided early split forces terrible cuts further down.
fn multilevel_bisect(g: &PartGraph, frac: f64, balance: f64, rng: &mut Rng) -> Vec<usize> {
    const COARSE_LIMIT: usize = 96;
    let balance = balance.min(1.2);
    if g.len() <= COARSE_LIMIT {
        let mut side = greedy_grow(g, frac, rng);
        fm_refine(g, &mut side, frac, balance, 8);
        return side;
    }
    // Coarsen one level by heavy-edge matching.
    let (coarse, map) = coarsen(g, rng);
    if coarse.len() >= g.len() {
        // Matching failed to shrink (e.g. no edges): fall back to greedy.
        let mut side = greedy_grow(g, frac, rng);
        fm_refine(g, &mut side, frac, balance, 8);
        return side;
    }
    let coarse_side = multilevel_bisect(&coarse, frac, balance, rng);
    // Project back and refine at this level.
    let mut side: Vec<usize> = (0..g.len()).map(|i| coarse_side[map[i]]).collect();
    fm_refine(g, &mut side, frac, balance, 4);
    side
}

/// Heavy-edge matching coarsening. Returns (coarse graph, fine->coarse).
fn coarsen(g: &PartGraph, rng: &mut Rng) -> (PartGraph, Vec<usize>) {
    let n = g.len();
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut mate = vec![usize::MAX; n];
    for &u in &order {
        if mate[u] != usize::MAX {
            continue;
        }
        let mut best = usize::MAX;
        let mut best_w = -1.0;
        for &(v, w) in &g.adj[u] {
            if mate[v] == usize::MAX && w > best_w {
                best = v;
                best_w = w;
            }
        }
        if best != usize::MAX {
            mate[u] = best;
            mate[best] = u;
        } else {
            mate[u] = u;
        }
    }
    let mut map = vec![usize::MAX; n];
    let mut nc = 0;
    for u in 0..n {
        if map[u] != usize::MAX {
            continue;
        }
        map[u] = nc;
        let m = mate[u];
        if m != u && m != usize::MAX {
            map[m] = nc;
        }
        nc += 1;
    }
    let mut coarse = PartGraph::new(nc);
    for u in 0..n {
        coarse.node_w[map[u]] += g.node_w[u];
    }
    for i in coarse.node_w.iter_mut() {
        *i -= 1.0; // PartGraph::new initializes weights to 1.0
    }
    for u in 0..n {
        for &(v, w) in &g.adj[u] {
            if u < v && map[u] != map[v] {
                coarse.add_edge(map[u], map[v], w);
            }
        }
    }
    (coarse, map)
}

/// Greedy BFS region growing: grow side 0 from a seed picking the
/// frontier node with maximum attachment until reaching `frac` weight.
fn greedy_grow(g: &PartGraph, frac: f64, rng: &mut Rng) -> Vec<usize> {
    let n = g.len();
    let total = g.total_node_weight();
    let target = total * frac;
    let mut side = vec![1usize; n];
    let mut in_a = vec![false; n];
    let mut attach = vec![0.0f64; n];
    let seed = rng.below(n);
    let mut grown = 0.0;
    let mut cur = seed;
    loop {
        in_a[cur] = true;
        side[cur] = 0;
        grown += g.node_w[cur];
        if grown >= target {
            break;
        }
        for &(v, w) in &g.adj[cur] {
            if !in_a[v] {
                attach[v] += w;
            }
        }
        // Pick the most attached unassigned node; fall back to any.
        let mut best = usize::MAX;
        let mut best_a = -1.0;
        for v in 0..n {
            if !in_a[v] && attach[v] > best_a {
                best = v;
                best_a = attach[v];
            }
        }
        if best == usize::MAX || best_a <= 0.0 {
            match (0..n).find(|&v| !in_a[v]) {
                Some(v) => best = v,
                None => break,
            }
        }
        cur = best;
    }
    side
}

/// Verify the balance constraint: every part's weight <= balance * avg.
pub fn check_balance(g: &PartGraph, labels: &[usize], k: usize, balance: f64) -> bool {
    let total = g.total_node_weight();
    let avg = total / k as f64;
    let mut w = vec![0.0; k];
    for (i, &l) in labels.iter().enumerate() {
        w[l] += g.node_w[i];
    }
    w.iter().all(|&x| x <= balance * avg + 1e-9)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A graph of `c` cliques of size `s` connected by single weak edges.
    fn clique_chain(c: usize, s: usize) -> PartGraph {
        let mut g = PartGraph::new(c * s);
        for ci in 0..c {
            for i in 0..s {
                for j in (i + 1)..s {
                    g.add_edge(ci * s + i, ci * s + j, 10.0);
                }
            }
            if ci + 1 < c {
                g.add_edge(ci * s + s - 1, (ci + 1) * s, 0.1);
            }
        }
        g
    }

    #[test]
    fn splits_cliques_on_weak_edges() {
        let g = clique_chain(4, 8);
        let labels = partition(&g, 4, 2.0, 1);
        // The cut should only contain the 3 weak edges: cut weight 0.3.
        let cut = g.cut(&labels);
        assert!(cut <= 0.3 + 1e-9, "cut={cut}");
        // Each clique must land in a single part.
        for ci in 0..4 {
            let l0 = labels[ci * 8];
            for i in 0..8 {
                assert_eq!(labels[ci * 8 + i], l0, "clique {ci} split");
            }
        }
    }

    #[test]
    fn respects_balance_factor() {
        let mut g = PartGraph::new(100);
        for i in 0..99 {
            g.add_edge(i, i + 1, 1.0);
        }
        let labels = partition(&g, 10, 2.0, 2);
        assert!(check_balance(&g, &labels, 10, 2.0));
        let distinct: std::collections::HashSet<usize> = labels.iter().copied().collect();
        assert_eq!(distinct.len(), 10);
    }

    #[test]
    fn weighted_nodes_balanced() {
        let mut g = PartGraph::new(20);
        for i in 0..20 {
            g.node_w[i] = if i < 2 { 50.0 } else { 1.0 };
        }
        for i in 0..19 {
            g.add_edge(i, i + 1, 1.0);
        }
        let labels = partition(&g, 2, 2.0, 3);
        // The two heavy nodes must not be in the same part together with
        // everything else; balance keeps sides within 2x of avg (59).
        assert!(check_balance(&g, &labels, 2, 2.0));
    }

    #[test]
    fn k_equals_one_and_n_less_than_k() {
        let g = clique_chain(1, 5);
        assert!(partition(&g, 1, 2.0, 4).iter().all(|&l| l == 0));
        let labels = partition(&g, 8, 2.0, 4);
        assert_eq!(labels.len(), 5);
        assert!(labels.iter().all(|&l| l < 8));
    }

    #[test]
    fn disconnected_graph_handled() {
        let g = PartGraph::new(16); // no edges at all
        let labels = partition(&g, 4, 2.0, 5);
        assert!(check_balance(&g, &labels, 4, 2.0));
    }

    #[test]
    fn deterministic_per_seed() {
        let g = clique_chain(3, 10);
        assert_eq!(partition(&g, 3, 2.0, 7), partition(&g, 3, 2.0, 7));
    }

    #[test]
    fn large_graph_smoke() {
        // 2000-node mesh partitions quickly into 60 balanced parts.
        let side = 45;
        let mut g = PartGraph::new(side * side);
        for r in 0..side {
            for c in 0..side {
                let i = r * side + c;
                if c + 1 < side {
                    g.add_edge(i, i + 1, 1.0);
                }
                if r + 1 < side {
                    g.add_edge(i, i + side, 1.0);
                }
            }
        }
        let labels = partition(&g, 60, 2.0, 8);
        assert!(check_balance(&g, &labels, 60, 2.0));
        // A mesh 60-way cut should be far below total edge weight.
        let total_w: f64 = 2.0 * side as f64 * (side - 1) as f64;
        assert!(g.cut(&labels) < 0.4 * total_w);
    }
}

