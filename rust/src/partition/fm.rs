//! Fiduccia–Mattheyses boundary refinement for bisections.
//!
//! Single-move-at-a-time passes with best-prefix rollback: each pass
//! tentatively moves every node once (highest gain first, subject to the
//! balance constraint) and finally rolls back to the best cut seen.
//!
//! Move selection uses a lazy max-heap over gains (stale entries are
//! re-pushed on pop; balance-infeasible pops are parked and re-offered
//! after the next applied move), replacing the original O(n) scan per
//! move — the §Perf optimization that took BERT-Large grouping from
//! 1.6 s to well under half (see EXPERIMENTS.md §Perf).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::PartGraph;

/// Max-heap key: (gain, node id), total order on f64.
#[derive(PartialEq)]
struct Key(f64, usize);
impl Eq for Key {}
impl PartialOrd for Key {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Key {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0
            .partial_cmp(&other.0)
            .unwrap_or(Ordering::Equal)
            .then_with(|| self.1.cmp(&other.1))
    }
}

/// Refine `side` (values 0/1) in place. `frac` is side-0's target weight
/// share, `balance` the allowed multiple of the target per side.
pub fn fm_refine(g: &PartGraph, side: &mut [usize], frac: f64, balance: f64, passes: usize) {
    let n = g.len();
    if n < 2 {
        return;
    }
    let total = g.total_node_weight();
    // Plateau guard: only keep passes that improve the cut by more than
    // float noise relative to the total edge weight — otherwise FM walks
    // along zero-gain plateaus (e.g. shifting a whole cluster across) and
    // silently destroys the balance of the bisection.
    let total_edge_w: f64 =
        g.adj.iter().flatten().map(|&(_, w)| w).sum::<f64>() / 2.0;
    let eps = 1e-9 * (1.0 + total_edge_w);
    let target0 = total * frac;
    let target1 = total - target0;
    // Per-side caps: balance * target, but never allow a side to absorb
    // (almost) everything — a bisection with an empty side is degenerate
    // even when the nominal balance constraint would allow it.
    let heaviest = g.node_w.iter().cloned().fold(0.0, f64::max);
    let cap = total - (total / (4.0 * balance)).min(total * 0.125);
    let max0 = (target0 * balance).max(heaviest).min(cap);
    let max1 = (target1 * balance).max(heaviest).min(cap);

    for _ in 0..passes {
        let mut w0: f64 = (0..n).filter(|&i| side[i] == 0).map(|i| g.node_w[i]).sum();
        // gain[i] = cut reduction if i moves to the other side.
        let mut gain: Vec<f64> = (0..n)
            .map(|i| {
                let mut ext = 0.0;
                let mut int = 0.0;
                for &(j, w) in &g.adj[i] {
                    if side[j] == side[i] {
                        int += w;
                    } else {
                        ext += w;
                    }
                }
                ext - int
            })
            .collect();
        let mut locked = vec![false; n];
        let mut moves: Vec<usize> = Vec::with_capacity(n);
        let mut cum_gain = 0.0;
        let mut best_gain = 0.0;
        let mut best_len = 0usize;

        // Lazy max-heap of candidate moves.
        let mut heap: BinaryHeap<Key> = (0..n).map(|i| Key(gain[i], i)).collect();
        // Balance-infeasible pops parked until the next applied move.
        let mut parked: Vec<usize> = Vec::new();

        'pass: loop {
            let mut chosen = usize::MAX;
            while let Some(Key(gk, i)) = heap.pop() {
                if locked[i] {
                    continue;
                }
                if (gk - gain[i]).abs() > 1e-12 {
                    // Stale entry: re-push with the current gain.
                    heap.push(Key(gain[i], i));
                    continue;
                }
                let feasible = if side[i] == 0 {
                    w0 - g.node_w[i] >= 0.0 && (total - w0 + g.node_w[i]) <= max1
                } else {
                    w0 + g.node_w[i] <= max0
                };
                if !feasible {
                    parked.push(i);
                    continue;
                }
                chosen = i;
                break;
            }
            if chosen == usize::MAX {
                break 'pass;
            }
            // Apply the move.
            let i = chosen;
            locked[i] = true;
            cum_gain += gain[i];
            if side[i] == 0 {
                w0 -= g.node_w[i];
                side[i] = 1;
            } else {
                w0 += g.node_w[i];
                side[i] = 0;
            }
            moves.push(i);
            for &(j, w) in &g.adj[i] {
                if side[j] == side[i] {
                    gain[j] -= 2.0 * w;
                } else {
                    gain[j] += 2.0 * w;
                }
                if !locked[j] {
                    heap.push(Key(gain[j], j));
                }
            }
            // Re-offer parked nodes now that the balance moved.
            for p in parked.drain(..) {
                if !locked[p] {
                    heap.push(Key(gain[p], p));
                }
            }
            if cum_gain > best_gain + eps {
                best_gain = cum_gain;
                best_len = moves.len();
            }
        }

        // Roll back to the best prefix.
        for &i in moves.iter().skip(best_len).rev() {
            side[i] = 1 - side[i];
        }
        if best_gain <= eps {
            break; // converged
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn improves_a_bad_bisection() {
        // Two triangles joined by one weak edge; start with a split that
        // cuts a triangle.
        let mut g = PartGraph::new(6);
        for (a, b) in [(0, 1), (1, 2), (0, 2), (3, 4), (4, 5), (3, 5)] {
            g.add_edge(a, b, 10.0);
        }
        g.add_edge(2, 3, 0.5);
        let mut side = vec![0, 0, 1, 1, 1, 1]; // cuts two heavy edges
        let before = g.cut(&side);
        fm_refine(&g, &mut side, 0.5, 2.0, 4);
        let after = g.cut(&side);
        assert!(after < before);
        assert!(after <= 0.5 + 1e-9, "should settle on the weak edge, cut={after}");
    }

    #[test]
    fn respects_balance() {
        let mut g = PartGraph::new(8);
        for i in 0..7 {
            g.add_edge(i, i + 1, 1.0);
        }
        let mut side = vec![0, 0, 0, 0, 1, 1, 1, 1];
        fm_refine(&g, &mut side, 0.5, 1.3, 4);
        let w0 = side.iter().filter(|&&s| s == 0).count();
        assert!((2..=6).contains(&w0), "w0={w0}");
    }

    #[test]
    fn noop_on_optimal() {
        let mut g = PartGraph::new(4);
        g.add_edge(0, 1, 5.0);
        g.add_edge(2, 3, 5.0);
        g.add_edge(1, 2, 0.1);
        let mut side = vec![0, 0, 1, 1];
        fm_refine(&g, &mut side, 0.5, 2.0, 4);
        assert_eq!(g.cut(&side), 0.1);
    }

    #[test]
    fn handles_singleton() {
        let g = PartGraph::new(1);
        let mut side = vec![0];
        fm_refine(&g, &mut side, 0.5, 2.0, 2);
        assert_eq!(side, vec![0]);
    }

    #[test]
    fn heap_matches_semantics_on_random_graphs() {
        // The lazy-heap implementation must still produce valid
        // bisections that never worsen the cut, across random graphs.
        use crate::util::Rng;
        for case in 0..30 {
            let mut rng = Rng::new(case);
            let n = rng.range(4, 80);
            let mut g = PartGraph::new(n);
            for _ in 0..(3 * n) {
                let a = rng.below(n);
                let b = rng.below(n);
                if a != b {
                    g.add_edge(a, b, rng.uniform(0.1, 5.0));
                }
            }
            let mut side: Vec<usize> = (0..n).map(|i| i % 2).collect();
            let before = g.cut(&side);
            fm_refine(&g, &mut side, 0.5, 2.0, 6);
            let after = g.cut(&side);
            assert!(after <= before + 1e-9, "case {case}: {after} > {before}");
            // Both sides non-empty.
            let w0 = side.iter().filter(|&&s| s == 0).count();
            assert!(w0 > 0 && w0 < n, "case {case}");
        }
    }
}
