//! `tag` — the TAG coordinator CLI, a thin shell over [`tag::api`].
//!
//! Subcommands:
//!   search    find a deployment plan for a model on a topology
//!   baselines evaluate all baseline strategies on the same setup
//!   repair    re-plan a saved plan after device/link failures
//!   explain   re-simulate a saved plan and break down where time goes
//!   fleet     replay a multi-tenant job stream (FIFO vs best-fit)
//!   serve     run the HTTP planning daemon (POST /plan, GET /metrics)
//!   train     self-play GNN training (writes a params .bin)
//!   info      list models, topologies and artifact status
//!
//! Examples:
//!   tag search --model VGG19 --topology testbed --iters 200 --scale 0.5
//!   tag search --model BERT-Small --topology random:42 --gnn artifacts/params_init.bin
//!   tag search --model VGG19 --topology multi_rack  # routed + contention
//!   tag search --model VGG19 --topology hier:7      # random hierarchical
//!   tag search --model VGG19 --out plan.json     # persist the plan
//!   tag search --model VGG19 --workers=8         # tree-parallel MCTS
//!   tag search --model VGG19 --deadline-ms 500   # best plan within 500ms
//!   tag search --model VGG19 --out plan.json --trace-out trace.json
//!   tag explain --plan plan.json                  # where does the time go?
//!   tag repair --plan plan.json --faults "kill:0.1;degrade:2*0.5"
//!   tag train --games 30 --steps 4 --out artifacts/params_trained.bin
//!   tag baselines --model InceptionV3 --topology testbed
//!   tag fleet --topology multi_rack --jobs 12 --seed 7 --policy both
//!   tag serve --port 7878 --workers 4 --queue-depth 64
//!   tag serve --gnn artifacts --store /var/lib/tag  # learned backend + warm boots
//!
//! Flags accept both `--key value` and `--key=value`; values may start
//! with `-` (e.g. `--scale -0.5`).  `--workers=K` runs K tree-parallel
//! search workers over a shared tree (K=1, the default, is the exact
//! sequential engine; K>1 is seed-stable but schedule-dependent —
//! `--vloss` tunes the virtual-loss penalty).  `--no-delta` disables
//! incremental (delta) evaluation — plans are bit-identical either
//! way; the flag exists for benchmarking and as an escape hatch.  The `nvlink_island`,
//! `multi_rack` and `hier:SEED` topologies are *routed*: they carry a
//! switch-level link graph, and their simulated times include per-hop
//! latency and shared-link contention.

use tag::api::{
    BaselineSweepBackend, DeploymentPlan, GnnMctsBackend, Parallelism, PlanRequest,
    Planner, SharedPlanner, BASELINE_NAMES,
};
use tag::cluster::{FaultSpec, Topology};
use tag::coordinator::Trainer;
use tag::gnn::{params, GnnService};
use tag::models;
use tag::serve::{ServeConfig, Server};
use tag::strategy::ReplOption;
use tag::util::{fmt_secs, Args};

fn usage() -> ! {
    eprintln!(
        "usage: tag <search|baselines|repair|explain|fleet|serve|train|info> [options]\n\
         run `tag <cmd> --help` for details"
    );
    std::process::exit(2)
}

fn parse_args(tokens: &[String]) -> Args {
    match Args::parse(tokens) {
        Ok(args) => args,
        Err(unexpected) => {
            eprintln!("unexpected argument: {unexpected}");
            usage()
        }
    }
}

fn topology_by_name(name: &str) -> Topology {
    tag::cluster::topology_by_spec(name).unwrap_or_else(|| {
        eprintln!(
            "unknown topology {name} (testbed|cloud|homogeneous|sfb|\
             nvlink_island|multi_rack|random:SEED|hier:SEED)"
        );
        std::process::exit(2)
    })
}

/// Build a request from the shared `--model/--topology/--scale/...`
/// flags.
fn request_from(args: &Args) -> PlanRequest {
    let model_name = args.get("model").unwrap_or("VGG19");
    let scale: f64 = args.num("scale", 0.25);
    let topo = topology_by_name(args.get("topology").unwrap_or("testbed"));
    let model = models::by_name(model_name, scale).unwrap_or_else(|| {
        eprintln!("unknown model {model_name}; see `tag info`");
        std::process::exit(2)
    });
    let mut request = PlanRequest::new(model, topo)
        .budget(args.num("iters", 150), args.num("groups", 24))
        .seed(args.num("seed", 1))
        .sfb(!args.flag("no-sfb"))
        .delta(!args.flag("no-delta"))
        .trace(!args.flag("no-trace"))
        .profile_noise(args.num("noise", 0.0))
        .parallelism(Parallelism {
            workers: args.num("workers", 1usize).max(1),
            virtual_loss: args.num("vloss", 1.0),
        });
    if args.get("deadline-ms").is_some() {
        // A deadline makes the search return its best-so-far when the
        // clock expires instead of running the full iteration budget.
        request = request.deadline_ms(args.num("deadline-ms", 0u64).max(1));
    }
    request
}

fn describe_strategy(plan: &DeploymentPlan, topo: &Topology) {
    println!("\nstrategy ({} op groups):", plan.telemetry.num_groups);
    let mut by_option = [0usize; 4];
    let mut gpu_weighted = vec![0.0f64; topo.num_groups()];
    for (g, slot) in plan.strategy.slots.iter().enumerate() {
        let Some(a) = slot else { continue };
        by_option[a.option as usize] += 1;
        for d in 0..topo.num_groups() {
            if a.mask & (1 << d) != 0 {
                gpu_weighted[d] += plan.groups[g].comp_time;
            }
        }
    }
    println!(
        "  options: AllReduce={} PS={} Duplicate={} ModelParallel={}",
        by_option[0], by_option[1], by_option[2], by_option[3]
    );
    print!("  placement (comp-time-weighted): ");
    let total: f64 = plan.groups.iter().map(|g| g.comp_time).sum();
    for (d, w) in gpu_weighted.iter().enumerate() {
        print!("{}:{:.0}% ", topo.groups[d].gpu.name, 100.0 * w / total.max(1e-12));
    }
    println!();
}

fn cmd_search(args: &Args) {
    let request = request_from(args);
    println!(
        "model={} ({} ops, {:.0} MB params) topology={} ({} machines, {} GPUs)",
        request.model.name,
        request.model.len(),
        request.model.total_param_bytes() / 1e6,
        request.topology.name,
        request.topology.num_groups(),
        request.topology.num_devices()
    );

    let builder = Planner::builder();
    let planner = match args.get("gnn") {
        Some(params_path) => {
            let backend = GnnMctsBackend::from_artifacts("artifacts", params_path)
                .unwrap_or_else(|e| {
                    eprintln!("GNN backend unavailable ({e}); run `make artifacts`");
                    std::process::exit(2)
                });
            builder.backend(backend).build()
        }
        None => builder.build(),
    };

    let topo = request.topology.clone();
    // `--trace-out FILE` records the whole planning lifecycle as a
    // Chrome trace-event file loadable at ui.perfetto.dev.  The tracer
    // only observes (spans never touch plan bytes), so the plan is
    // bit-identical with or without it.
    let tracer = match args.get("trace-out") {
        Some(_) => tag::obs::Tracer::enabled("tag search"),
        None => tag::obs::Tracer::disabled(),
    };
    let outcome = {
        let _g = tracer.install();
        let _root = tag::obs::span("plan");
        planner.plan(&request)
    }
    .unwrap_or_else(|e| {
        eprintln!("planning failed: {e}");
        std::process::exit(1)
    });
    if let (Some(path), Some(trace)) = (args.get("trace-out"), tracer.finish()) {
        let json = tag::obs::chrome_trace_json(&[std::sync::Arc::new(trace)]);
        std::fs::write(path, json).unwrap_or_else(|e| {
            eprintln!("failed to write {path}: {e}");
            std::process::exit(1)
        });
        println!("trace written to {path} (load it at ui.perfetto.dev)");
    }
    let plan = &outcome.plan;
    if topo.is_routed() {
        println!(
            "routed topology: {} nodes, {} links (contention-aware simulation)",
            topo.link_graph().num_nodes(),
            topo.link_graph().num_links()
        );
    }
    println!(
        "DP-NCCL baseline: {}   TAG: {}   speed-up: {:.2}x   (search {}, backend {})",
        fmt_secs(plan.times.dp_time),
        fmt_secs(plan.times.final_time),
        plan.times.speedup,
        fmt_secs(outcome.overhead_s),
        plan.backend,
    );
    if plan.telemetry.metric("timed_out").is_some() {
        println!(
            "deadline expired after {} of {} iterations: plan is the best found so far",
            plan.telemetry.iterations, request.budget.iterations
        );
    }
    if let (Some(sfb), Some(t)) = (&plan.sfb, plan.times.time_with_sfb) {
        println!(
            "SFB: {} of {} gradients covered, predicted saving {}, time with SFB {}",
            sfb.problems_beneficial,
            sfb.problems_solved,
            fmt_secs(sfb.predicted_saving_s),
            fmt_secs(t)
        );
        let top = sfb.top_census(5);
        if !top.is_empty() {
            println!("  top duplicated ops: {top:?}");
        }
    }
    describe_strategy(plan, &topo);

    if let Some(path) = args.get("out") {
        std::fs::write(path, plan.encode()).unwrap_or_else(|e| {
            eprintln!("failed to write {path}: {e}");
            std::process::exit(1)
        });
        println!("\nplan written to {path}");
    }
}

fn cmd_baselines(args: &Args) {
    let request = request_from(args).sfb(false);
    let planner = Planner::builder().backend(BaselineSweepBackend::new()).build();
    let plan = planner
        .plan(&request)
        .unwrap_or_else(|e| {
            eprintln!("planning failed: {e}");
            std::process::exit(1)
        })
        .plan;

    println!("{:<12} {:>14} {:>10}", "baseline", "iter time", "vs DP");
    let dp = plan
        .telemetry
        .metric("DP-NCCL")
        .expect("sweep always reports the DP row");
    for name in BASELINE_NAMES {
        let Some(t) = plan.telemetry.metric(name) else { continue };
        let oom = plan.telemetry.metric(&format!("{name}.oom")).is_some();
        println!(
            "{:<12} {:>14} {:>9.2}x{}",
            name,
            fmt_secs(t),
            dp / t,
            if oom { "  (OOM)" } else { "" }
        );
    }
}

fn cmd_repair(args: &Args) {
    let path = args.get("plan").unwrap_or_else(|| {
        eprintln!("repair needs --plan <file> (a plan written by `tag search --out`)");
        std::process::exit(2)
    });
    let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
        eprintln!("failed to read {path}: {e}");
        std::process::exit(1)
    });
    let prior = DeploymentPlan::decode(&text).unwrap_or_else(|e| {
        eprintln!("{path} is not a deployment plan: {e}");
        std::process::exit(1)
    });
    let spec = args.get("faults").unwrap_or_else(|| {
        eprintln!("repair needs --faults \"kill:G.I;sever:L;degrade:L*F\"");
        std::process::exit(2)
    });
    let faults = FaultSpec::parse(spec).unwrap_or_else(|e| {
        eprintln!("bad fault spec: {e}");
        std::process::exit(2)
    });
    let request = request_from(args);
    let planner = Planner::builder().build();
    let outcome = planner.repair(&request, &prior, &faults).unwrap_or_else(|e| {
        eprintln!("repair failed: {e}");
        std::process::exit(1)
    });
    let plan = &outcome.plan;
    let dead = plan.telemetry.metric("dead_devices").unwrap_or(0.0) as usize;
    println!(
        "faults: {}   residual topology: {} ({} of {} GPUs alive)",
        faults.encode(),
        plan.topology_name,
        request.topology.num_devices() - dead,
        request.topology.num_devices(),
    );
    match outcome.warm_time {
        Some(t) => println!("surviving placements (warm incumbent): {}", fmt_secs(t)),
        None => println!("surviving placements infeasible on the residual; cold restart"),
    }
    println!(
        "repaired: {}   DP on residual: {}   speed-up: {:.2}x   ({} iterations, {})",
        fmt_secs(plan.times.final_time),
        fmt_secs(plan.times.dp_time),
        plan.times.speedup,
        plan.telemetry.iterations,
        fmt_secs(outcome.overhead_s),
    );
    if let Some(warm) = outcome.warm_time {
        let gain = warm / plan.times.final_time;
        println!("repair recovered {gain:.2}x over the degraded survivors");
    }

    if args.flag("cold") {
        // Honest comparison: a from-scratch plan on the same residual
        // topology with the *full* budget (the repair used a quarter).
        let residual = faults.apply(&request.topology).expect("faults applied above");
        let mut cold_request = request.clone();
        cold_request.topology = residual.topology;
        let cold = planner.plan(&cold_request).unwrap_or_else(|e| {
            eprintln!("cold re-plan failed: {e}");
            std::process::exit(1)
        });
        println!(
            "cold re-plan: {} in {} (repair: {} in {})",
            fmt_secs(cold.plan.times.final_time),
            fmt_secs(cold.overhead_s),
            fmt_secs(plan.times.final_time),
            fmt_secs(outcome.overhead_s),
        );
    }

    if let Some(out) = args.get("out") {
        std::fs::write(out, plan.encode()).unwrap_or_else(|e| {
            eprintln!("failed to write {out}: {e}");
            std::process::exit(1)
        });
        println!("repaired plan written to {out}");
    }
}

fn cmd_explain(args: &Args) {
    let path = args.get("plan").unwrap_or_else(|| {
        eprintln!("explain needs --plan <file> (a plan written by `tag search --out`)");
        std::process::exit(2)
    });
    let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
        eprintln!("failed to read {path}: {e}");
        std::process::exit(1)
    });
    let plan = DeploymentPlan::decode(&text).unwrap_or_else(|e| {
        eprintln!("{path} is not a deployment plan: {e}");
        std::process::exit(1)
    });
    // The shared `--model/--topology/...` flags must describe the same
    // problem the plan was searched on; `explain` re-verifies the
    // fingerprints and re-simulates deterministically.
    let request = request_from(args);
    let report = tag::obs::explain::explain(&request, &plan).unwrap_or_else(|e| {
        eprintln!("explain failed: {e}");
        std::process::exit(1)
    });
    println!("{}", report.encode());
}

fn cmd_train(args: &Args) {
    let svc = GnnService::load("artifacts").expect("load artifacts (make artifacts)");
    let init = args.get("init").unwrap_or("artifacts/params_init.bin");
    let p = params::load_params(init).expect("init params");
    let mut tr = Trainer::new(&svc, p, args.num("seed", 1));
    tr.use_feedback = !args.flag("no-feedback");
    tr.model_scale = args.num("scale", 0.25);
    tr.mcts_iterations = args.num("iters", 96);
    let games: usize = args.num("games", 20);
    let steps: usize = args.num("steps", 4);
    for gi in 0..games {
        let n = tr.collect();
        let mut last = None;
        for _ in 0..steps {
            last = tr.train_once();
        }
        println!(
            "game {gi:>3}: +{n} examples, buffer loss {:?}",
            last.map(|l| (l * 1000.0).round() / 1000.0)
        );
    }
    let out = args.get("out").unwrap_or("artifacts/params_trained.bin");
    params::save_params(out, &tr.params).expect("save params");
    println!("saved {} params to {out}", tr.params.len());
}

fn cmd_fleet(args: &Args) {
    use tag::fleet::{generate_jobs, replay, FleetConfig, Policy};

    let topo = topology_by_name(args.get("topology").unwrap_or("multi_rack"));
    let jobs = generate_jobs(
        &topo,
        args.num("seed", 7),
        args.num("jobs", 8usize),
        args.num("mean-arrival", 20.0),
    );
    let policies: Vec<Policy> = match args.get("policy").unwrap_or("both") {
        "both" => vec![Policy::Fifo, Policy::BestFit],
        name => match Policy::parse(name) {
            Some(policy) => vec![policy],
            None => {
                eprintln!("unknown policy {name} (fifo|best-fit|both)");
                std::process::exit(2)
            }
        },
    };
    let mut config = FleetConfig {
        iterations: args.num("iters", 16usize),
        max_groups: args.num("groups", 10usize),
        workers: args.num("workers", 1usize).max(1),
        backfill: args.num("backfill", 4usize),
        sfb: args.flag("sfb"),
        ..FleetConfig::default()
    };
    if args.get("deadline-ms").is_some() {
        config.deadline_ms = Some(args.num("deadline-ms", 0u64).max(1));
    }

    println!(
        "fleet: topology={} ({} GPUs), {} jobs, seed {}",
        topo.name,
        topo.num_devices(),
        jobs.len(),
        args.num::<u64>("seed", 7)
    );
    // One shared planner across policies: FIFO's whole-cluster plans
    // and best-fit's slice plans occupy disjoint cache keys, so the
    // comparison stays fair while repeat shapes within a policy reuse
    // their searches.
    let planner = SharedPlanner::builder().build();
    let mut reports = Vec::new();
    for policy in policies {
        config.policy = policy;
        let report = replay(&planner, &topo, &jobs, &config).unwrap_or_else(|e| {
            eprintln!("fleet replay failed: {e}");
            std::process::exit(1)
        });
        print!("{}", report.render());
        reports.push(report);
    }
    if let [fifo, best] = reports.as_slice() {
        println!(
            "best-fit vs fifo: makespan {:.2}x  mean jct {:.2}x  utilization {:.2}x",
            fifo.makespan_s / best.makespan_s.max(1e-12),
            fifo.mean_jct_s / best.mean_jct_s.max(1e-12),
            best.utilization / fifo.utilization.max(1e-12),
        );
    }
}

fn cmd_serve(args: &Args) {
    let config = ServeConfig {
        addr: args.get("addr").unwrap_or("127.0.0.1").to_string(),
        port: args.num("port", 7878),
        workers: args.num("workers", 4usize).max(1),
        queue_depth: args.num("queue-depth", 64usize).max(1),
        accept_threads: args.num("accept-threads", 2usize).max(1),
        max_requests_per_conn: args.num("keep-alive-requests", 256usize).max(1),
        max_body_bytes: args.num("max-body-kb", 1024usize).max(1) * 1024,
        fleet_topology: args.get("fleet-topology").unwrap_or("multi_rack").to_string(),
        store_dir: args.get("store").map(str::to_string),
        slow_ms: args.get("slow-ms").map(|_| args.num("slow-ms", 0u64)),
        trace_ring: args.num("trace-ring", 64usize).max(1),
        ..ServeConfig::default()
    };
    let builder =
        SharedPlanner::builder().cache_capacity(args.num("cache", 1usize << 10).max(1));
    // The GNN backend is `Send + Sync` (the service sits behind an
    // `Arc`), so one learned backend serves the whole worker pool.
    let planner = match args.get("gnn") {
        Some(dir) => {
            let default_params = format!("{dir}/params_init.bin");
            let params_path = args.get("gnn-params").unwrap_or(&default_params);
            let backend =
                GnnMctsBackend::from_artifacts(dir, params_path).unwrap_or_else(|e| {
                    eprintln!("GNN backend unavailable ({e}); run `make artifacts`");
                    std::process::exit(2)
                });
            builder.backend(backend).build()
        }
        None => builder.build(),
    };
    let backend_name = planner.backend_name();
    let server = Server::bind(config.clone(), planner).unwrap_or_else(|e| {
        eprintln!("bind failed: {e}");
        std::process::exit(1)
    });
    println!(
        "tag serve listening on http://{} ({} workers, queue depth {}, \
         {} acceptors, backend {})",
        server.local_addr(),
        config.workers,
        config.queue_depth,
        config.accept_threads,
        backend_name,
    );
    println!("endpoints: POST /plan  POST /repair  POST /explain  POST /fleet/submit");
    println!("           POST /fleet/complete  GET /fleet/status  GET /healthz");
    println!("           GET /metrics  GET /debug/trace  POST /shutdown");
    println!("fleet topology: {}", config.fleet_topology);
    if let Some(dir) = &config.store_dir {
        println!("plan store: {dir}/plans.journal (warm boot)");
    }
    if let Err(e) = server.run() {
        eprintln!("serve failed: {e}");
        std::process::exit(1);
    }
    println!("drained and shut down");
}

fn cmd_info() {
    println!("models (name: ops at scale 1.0, params):");
    for g in models::all_models() {
        println!(
            "  {:<12} {:>6} ops {:>7.0} MB",
            g.name,
            g.len(),
            g.total_param_bytes() / 1e6
        );
    }
    println!(
        "\ntopologies: testbed, cloud, homogeneous, sfb, random:SEED \
         (flat)\n            nvlink_island, multi_rack, hier:SEED (routed + contention)"
    );
    let ready = std::path::Path::new("artifacts/gnn_infer.hlo.txt").exists();
    println!("\nartifacts: {}", if ready { "ready" } else { "missing (run `make artifacts`)" });
    let _ = ReplOption::ALL;
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else { usage() };
    let rest = parse_args(&argv[1..]);
    match cmd.as_str() {
        "search" => cmd_search(&rest),
        "baselines" => cmd_baselines(&rest),
        "repair" => cmd_repair(&rest),
        "explain" => cmd_explain(&rest),
        "fleet" => cmd_fleet(&rest),
        "serve" => cmd_serve(&rest),
        "train" => cmd_train(&rest),
        "info" => cmd_info(),
        _ => usage(),
    }
}
