//! `tag` — the TAG coordinator CLI.
//!
//! Subcommands:
//!   search    find a deployment strategy for a model on a topology
//!   baselines evaluate all baseline strategies on the same setup
//!   train     self-play GNN training (writes a params .bin)
//!   info      list models, topologies and artifact status
//!
//! Examples:
//!   tag search --model VGG19 --topology testbed --iters 200 --scale 0.5
//!   tag search --model BERT-Small --topology random:42 --gnn artifacts/params_init.bin
//!   tag train --games 30 --steps 4 --out artifacts/params_trained.bin
//!   tag baselines --model InceptionV3 --topology testbed

use tag::cluster::{generator, presets, Topology};
use tag::coordinator::{prepare, search_session, SearchConfig, Trainer};
use tag::dist::Lowering;
use tag::gnn::{params, GnnService};
use tag::models;
use tag::strategy::{baselines, enumerate_actions, ReplOption};
use tag::util::{fmt_secs, Rng};

fn usage() -> ! {
    eprintln!(
        "usage: tag <search|baselines|train|info> [options]\n\
         run `tag <cmd> --help` for details"
    );
    std::process::exit(2)
}

/// Minimal flag parser: --key value pairs (the vendored dep set has no
/// clap; this keeps the CLI self-contained).
struct Args {
    kv: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(args: &[String]) -> Self {
        let mut kv = std::collections::HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    kv.insert(key.to_string(), args[i + 1].clone());
                    i += 2;
                } else {
                    kv.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                eprintln!("unexpected argument: {a}");
                usage();
            }
        }
        Self { kv }
    }
    fn get(&self, k: &str) -> Option<&str> {
        self.kv.get(k).map(|s| s.as_str())
    }
    fn flag(&self, k: &str) -> bool {
        matches!(self.get(k), Some("true") | Some("1") | Some("yes"))
    }
    fn num<T: std::str::FromStr>(&self, k: &str, default: T) -> T {
        self.get(k).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

fn topology_by_name(name: &str) -> Topology {
    match name {
        "testbed" => presets::testbed(),
        "cloud" => presets::cloud(),
        "homogeneous" | "homog" => presets::homogeneous(),
        "sfb" | "sfb_pair" => presets::sfb_pair(),
        other => {
            if let Some(seed) = other.strip_prefix("random:") {
                let seed: u64 = seed.parse().unwrap_or(0);
                let mut rng = Rng::new(seed);
                generator::random_topology(&mut rng)
            } else {
                eprintln!("unknown topology {other} (testbed|cloud|homogeneous|sfb|random:SEED)");
                std::process::exit(2)
            }
        }
    }
}

fn describe_strategy(res: &tag::coordinator::SessionResult, topo: &Topology) {
    let gg = &res.group_graph;
    println!("\nstrategy ({} op groups):", gg.num_groups());
    let mut by_option = [0usize; 4];
    let mut gpu_weighted = vec![0.0f64; topo.num_groups()];
    for (g, slot) in res.strategy.slots.iter().enumerate() {
        let Some(a) = slot else { continue };
        by_option[a.option.index()] += 1;
        for d in 0..topo.num_groups() {
            if a.mask & (1 << d) != 0 {
                gpu_weighted[d] += gg.groups[g].comp_time;
            }
        }
    }
    println!(
        "  options: AllReduce={} PS={} Duplicate={} ModelParallel={}",
        by_option[0], by_option[1], by_option[2], by_option[3]
    );
    print!("  placement (comp-time-weighted): ");
    let total: f64 = gg.groups.iter().map(|g| g.comp_time).sum();
    for (d, w) in gpu_weighted.iter().enumerate() {
        print!("{}:{:.0}% ", topo.groups[d].gpu.name, 100.0 * w / total.max(1e-12));
    }
    println!();
}

fn cmd_search(args: &Args) {
    let model_name = args.get("model").unwrap_or("VGG19");
    let scale: f64 = args.num("scale", 0.25);
    let topo = topology_by_name(args.get("topology").unwrap_or("testbed"));
    let model = models::by_name(model_name, scale).unwrap_or_else(|| {
        eprintln!("unknown model {model_name}; see `tag info`");
        std::process::exit(2)
    });
    let cfg = SearchConfig {
        max_groups: args.num("groups", 24),
        mcts_iterations: args.num("iters", 150),
        seed: args.num("seed", 1),
        apply_sfb: !args.flag("no-sfb"),
        profile_noise: args.num("noise", 0.0),
    };
    println!(
        "model={} ({} ops, {:.0} MB params) topology={} ({} machines, {} GPUs)",
        model.name,
        model.len(),
        model.total_param_bytes() / 1e6,
        topo.name,
        topo.num_groups(),
        topo.num_devices()
    );
    let prep = prepare(model, &topo, &cfg);
    let svc_params = args.get("gnn").map(|p| {
        let svc = GnnService::load("artifacts").expect("load artifacts (make artifacts)");
        let params = params::load_params(p).expect("load params file");
        (svc, params)
    });
    let res = match &svc_params {
        Some((svc, p)) => search_session(&prep, &topo, Some((svc, p.clone())), &cfg),
        None => search_session(&prep, &topo, None, &cfg),
    };
    println!(
        "DP-NCCL baseline: {}   TAG: {}   speed-up: {:.2}x   (search {})",
        fmt_secs(res.dp_time),
        fmt_secs(res.dp_time / res.speedup),
        res.speedup,
        fmt_secs(res.overhead_s),
    );
    if let (Some(plan), Some(t)) = (&res.sfb, res.time_with_sfb) {
        println!(
            "SFB: {} of {} gradients covered, predicted saving {}, time with SFB {}",
            plan.problems_beneficial,
            plan.problems_solved,
            fmt_secs(plan.predicted_saving_s),
            fmt_secs(t)
        );
        let top = plan.top_census(5);
        if !top.is_empty() {
            println!("  top duplicated ops: {top:?}");
        }
    }
    describe_strategy(&res, &topo);
}

fn cmd_baselines(args: &Args) {
    let model_name = args.get("model").unwrap_or("VGG19");
    let scale: f64 = args.num("scale", 0.25);
    let topo = topology_by_name(args.get("topology").unwrap_or("testbed"));
    let model = models::by_name(model_name, scale).expect("model");
    let cfg = SearchConfig {
        max_groups: args.num("groups", 24),
        seed: args.num("seed", 1),
        ..Default::default()
    };
    let prep = prepare(model, &topo, &cfg);
    let low = Lowering::new(&prep.gg, &topo, &prep.cost, &prep.comm);
    let acts = enumerate_actions(&topo);
    let ng = prep.gg.num_groups();

    println!("{:<12} {:>14} {:>10}", "baseline", "iter time", "vs DP");
    let dp = low.evaluate(&baselines::dp_nccl(ng, &topo)).time;
    let rows: Vec<(&str, f64)> = vec![
        ("DP-NCCL", dp),
        ("DP-NCCL-P", low.evaluate(&baselines::dp_nccl_p(ng, &topo)).time),
        ("Horovod", low.evaluate(&baselines::horovod(ng, &topo)).time),
        ("FlexFlow", {
            let s = baselines::flexflow_mcmc(&low, &acts, 200, cfg.seed);
            low.evaluate(&s).time
        }),
        ("Baechi", low.evaluate(&baselines::baechi_msct(&low)).time),
        ("HeteroG", low.evaluate(&baselines::heterog_like(&low)).time),
    ];
    for (name, t) in rows {
        println!("{:<12} {:>14} {:>9.2}x", name, fmt_secs(t), dp / t);
    }
}

fn cmd_train(args: &Args) {
    let svc = GnnService::load("artifacts").expect("load artifacts (make artifacts)");
    let init = args.get("init").unwrap_or("artifacts/params_init.bin");
    let p = params::load_params(init).expect("init params");
    let mut tr = Trainer::new(&svc, p, args.num("seed", 1));
    tr.use_feedback = !args.flag("no-feedback");
    tr.model_scale = args.num("scale", 0.25);
    tr.mcts_iterations = args.num("iters", 96);
    let games: usize = args.num("games", 20);
    let steps: usize = args.num("steps", 4);
    for gi in 0..games {
        let n = tr.collect();
        let mut last = None;
        for _ in 0..steps {
            last = tr.train_once();
        }
        println!(
            "game {gi:>3}: +{n} examples, buffer loss {:?}",
            last.map(|l| (l * 1000.0).round() / 1000.0)
        );
    }
    let out = args.get("out").unwrap_or("artifacts/params_trained.bin");
    params::save_params(out, &tr.params).expect("save params");
    println!("saved {} params to {out}", tr.params.len());
}

fn cmd_info() {
    println!("models (name: ops at scale 1.0, params):");
    for g in models::all_models() {
        println!(
            "  {:<12} {:>6} ops {:>7.0} MB",
            g.name,
            g.len(),
            g.total_param_bytes() / 1e6
        );
    }
    println!("\ntopologies: testbed, cloud, homogeneous, sfb, random:SEED");
    let ready = std::path::Path::new("artifacts/gnn_infer.hlo.txt").exists();
    println!("\nartifacts: {}", if ready { "ready" } else { "missing (run `make artifacts`)" });
    let _ = ReplOption::ALL;
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else { usage() };
    let rest = Args::parse(&argv[1..]);
    match cmd.as_str() {
        "search" => cmd_search(&rest),
        "baselines" => cmd_baselines(&rest),
        "train" => cmd_train(&rest),
        "info" => cmd_info(),
        _ => usage(),
    }
}
