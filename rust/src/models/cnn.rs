//! Convolutional benchmark models: VGG19, ResNet101, InceptionV3.
//!
//! Architectures follow the canonical definitions (channel counts,
//! block repeats, spatial schedule); `scale < 1` shrinks channels and
//! repeats proportionally for fast unit tests while keeping the exact
//! same structure.

use super::builder::NetBuilder;

fn sc(x: usize, scale: f64) -> usize {
    ((x as f64 * scale).round() as usize).max(1)
}

/// VGG19 (Simonyan & Zisserman): 16 conv layers in 5 blocks + 3 FC.
pub fn vgg19(batch: usize, scale: f64) -> crate::graph::CompGraph {
    let mut b = NetBuilder::new("VGG19", batch, 224.0 * 224.0 * 3.0);
    let blocks: [(usize, usize, usize); 5] = [
        // (convs, channels, output spatial after pool)
        (2, 64, 112),
        (2, 128, 56),
        (4, 256, 28),
        (4, 512, 14),
        (4, 512, 7),
    ];
    let mut cin = 3;
    let mut hw = 224;
    for (reps, c, hw_out) in blocks {
        let c = sc(c, scale);
        let reps = if scale < 1.0 { reps.min(2) } else { reps };
        for _ in 0..reps {
            b.conv2d(hw, cin, c, 3);
            b.bias_add(c);
            b.relu();
            b.micro_reshape(28);
            cin = c;
        }
        b.pool("MaxPool", hw_out, c);
        hw = hw_out;
    }
    // Flatten + FC head (4096-4096-1000).
    b.shape_op("Reshape");
    let feat = 7 * 7 * cin;
    let fc = sc(4096, scale);
    b.dense(1, feat, fc);
    b.relu();
    b.micro_reshape(28);
    b.dense(1, fc, fc);
    b.relu();
    b.micro_reshape(28);
    b.finish_classifier(fc, 1000)
}

/// ResNet101 (He et al.): bottleneck blocks [3, 4, 23, 3].
pub fn resnet101(batch: usize, scale: f64) -> crate::graph::CompGraph {
    let mut b = NetBuilder::new("ResNet101", batch, 224.0 * 224.0 * 3.0);
    // Stem.
    b.conv2d(112, 3, sc(64, scale), 7);
    b.batch_norm(sc(64, scale));
    b.relu();
    b.pool("MaxPool", 56, sc(64, scale));

    let stages: [(usize, usize, usize); 4] = [
        // (repeats, bottleneck channels, spatial)
        (3, 64, 56),
        (4, 128, 28),
        (23, 256, 14),
        (3, 512, 7),
    ];
    let mut cin = sc(64, scale);
    for (si, (reps, c, hw)) in stages.into_iter().enumerate() {
        let c = sc(c, scale);
        let cout = 4 * c;
        let reps = if scale < 1.0 { reps.min(2) } else { reps };
        for r in 0..reps {
            if r == 0 {
                // Projection shortcut: bring cin -> cout at this spatial
                // size, then residual blocks preserve shape.
                b.conv2d(hw, cin, cout, 1);
                b.batch_norm(cout);
                cin = cout;
                let _ = si;
            }
            b.residual(|b| {
                b.conv2d(hw, cout, c, 1);
                b.batch_norm(c);
                b.relu();
                b.micro_reshape(22);
                b.conv2d(hw, c, c, 3);
                b.batch_norm(c);
                b.relu();
                b.micro_reshape(22);
                b.conv2d(hw, c, cout, 1);
                b.batch_norm(cout);
                b.micro_reshape(22);
            });
            b.relu();
        }
    }
    b.pool("AvgPool", 1, cin);
    b.shape_op("Reshape");
    b.finish_classifier(cin, 1000)
}

/// InceptionV3 (Szegedy et al.): stem + inception modules A/B/C with
/// reductions, faithful branch structure via `fanout_concat`.
pub fn inception_v3(batch: usize, scale: f64) -> crate::graph::CompGraph {
    let mut b = NetBuilder::new("InceptionV3", batch, 299.0 * 299.0 * 3.0);

    let conv_bn =
        |b: &mut NetBuilder, hw: usize, cin: usize, cout: usize, k: usize| {
            b.conv2d(hw, cin, cout, k);
            b.batch_norm(cout);
            b.relu();
            b.micro_reshape(14);
        };

    // Stem: 299 -> 35 spatial.
    conv_bn(&mut b, 149, 3, sc(32, scale), 3);
    conv_bn(&mut b, 147, sc(32, scale), sc(32, scale), 3);
    conv_bn(&mut b, 147, sc(32, scale), sc(64, scale), 3);
    b.pool("MaxPool", 73, sc(64, scale));
    conv_bn(&mut b, 73, sc(64, scale), sc(80, scale), 1);
    conv_bn(&mut b, 71, sc(80, scale), sc(192, scale), 3);
    b.pool("MaxPool", 35, sc(192, scale));

    // Inception-A x3 at 35x35.
    let mut cin = sc(192, scale);
    let reps_a = if scale < 1.0 { 1 } else { 3 };
    for _ in 0..reps_a {
        let c1 = sc(64, scale);
        let c5 = sc(64, scale);
        let c3 = sc(96, scale);
        let cp = sc(32, scale);
        let cin_b = cin;
        b.fanout_concat(vec![
            Box::new(move |b: &mut NetBuilder| conv_bn(b, 35, cin_b, c1, 1)),
            Box::new(move |b: &mut NetBuilder| {
                conv_bn(b, 35, cin_b, sc(48, 1.0).min(c5), 1);
                conv_bn(b, 35, sc(48, 1.0).min(c5), c5, 5);
            }),
            Box::new(move |b: &mut NetBuilder| {
                conv_bn(b, 35, cin_b, c3, 1);
                conv_bn(b, 35, c3, c3, 3);
                conv_bn(b, 35, c3, c3, 3);
            }),
            Box::new(move |b: &mut NetBuilder| {
                b.pool("AvgPool", 35, cin_b);
                conv_bn(b, 35, cin_b, cp, 1);
            }),
        ]);
        cin = c1 + c5 + c3 + cp;
        b.micro_reshape(6);
    }

    // Reduction-A: 35 -> 17.
    {
        let c3 = sc(384, scale);
        let c96 = sc(96, scale);
        let cin_b = cin;
        b.fanout_concat(vec![
            Box::new(move |b: &mut NetBuilder| conv_bn(b, 17, cin_b, c3, 3)),
            Box::new(move |b: &mut NetBuilder| {
                conv_bn(b, 35, cin_b, sc(64, 1.0).min(c96), 1);
                conv_bn(b, 35, sc(64, 1.0).min(c96), c96, 3);
                conv_bn(b, 17, c96, c96, 3);
            }),
            Box::new(move |b: &mut NetBuilder| b.pool("MaxPool", 17, cin_b)),
        ]);
        cin = c3 + c96 + cin_b;
    }

    // Inception-B x4 at 17x17 (factorized 7x1/1x7 pairs modeled as two
    // k=7-row convolutions of matching cost).
    let reps_b = if scale < 1.0 { 1 } else { 4 };
    for _ in 0..reps_b {
        let c192 = sc(192, scale);
        let c128 = sc(128, scale);
        let cin_b = cin;
        b.fanout_concat(vec![
            Box::new(move |b: &mut NetBuilder| conv_bn(b, 17, cin_b, c192, 1)),
            Box::new(move |b: &mut NetBuilder| {
                conv_bn(b, 17, cin_b, c128, 1);
                conv_bn(b, 17, c128, c128, 1); // 1x7
                conv_bn(b, 17, c128, c192, 1); // 7x1
                b.micro_reshape(4);
            }),
            Box::new(move |b: &mut NetBuilder| {
                conv_bn(b, 17, cin_b, c128, 1);
                conv_bn(b, 17, c128, c128, 1);
                conv_bn(b, 17, c128, c128, 1);
                conv_bn(b, 17, c128, c128, 1);
                conv_bn(b, 17, c128, c192, 1);
                b.micro_reshape(4);
            }),
            Box::new(move |b: &mut NetBuilder| {
                b.pool("AvgPool", 17, cin_b);
                conv_bn(b, 17, cin_b, c192, 1);
            }),
        ]);
        cin = 3 * c192 + c192;
        b.micro_reshape(6);
    }

    // Reduction-B: 17 -> 8.
    {
        let c192 = sc(192, scale);
        let c320 = sc(320, scale);
        let cin_b = cin;
        b.fanout_concat(vec![
            Box::new(move |b: &mut NetBuilder| {
                conv_bn(b, 17, cin_b, c192, 1);
                conv_bn(b, 8, c192, c320, 3);
            }),
            Box::new(move |b: &mut NetBuilder| {
                conv_bn(b, 17, cin_b, c192, 1);
                conv_bn(b, 17, c192, c192, 1);
                conv_bn(b, 8, c192, c192, 3);
            }),
            Box::new(move |b: &mut NetBuilder| b.pool("MaxPool", 8, cin_b)),
        ]);
        cin = c320 + c192 + cin_b;
    }

    // Inception-C x2 at 8x8.
    let reps_c = if scale < 1.0 { 1 } else { 2 };
    for _ in 0..reps_c {
        let c320 = sc(320, scale);
        let c384 = sc(384, scale);
        let c192 = sc(192, scale);
        let cin_b = cin;
        b.fanout_concat(vec![
            Box::new(move |b: &mut NetBuilder| conv_bn(b, 8, cin_b, c320, 1)),
            Box::new(move |b: &mut NetBuilder| {
                conv_bn(b, 8, cin_b, c384, 1);
                // expanded 1x3 + 3x1 pair
                conv_bn(b, 8, c384, c384, 1);
                conv_bn(b, 8, c384, c384, 1);
                b.micro_reshape(4);
            }),
            Box::new(move |b: &mut NetBuilder| {
                conv_bn(b, 8, cin_b, sc(448, 1.0).min(2 * c384), 1);
                conv_bn(b, 8, sc(448, 1.0).min(2 * c384), c384, 3);
                conv_bn(b, 8, c384, c384, 1);
                conv_bn(b, 8, c384, c384, 1);
                b.micro_reshape(4);
            }),
            Box::new(move |b: &mut NetBuilder| {
                b.pool("AvgPool", 8, cin_b);
                conv_bn(b, 8, cin_b, c192, 1);
            }),
        ]);
        cin = c320 + 3 * c384 + 2 * c384 + c192;
        b.micro_reshape(6);
    }

    b.pool("AvgPool", 1, cin);
    b.shape_op("Reshape");
    b.finish_classifier(cin, 1000)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg19_param_size_matches_architecture() {
        let g = vgg19(96, 1.0);
        let mb = g.total_param_bytes() / 1e6;
        // Canonical VGG19: ~143.7M params ~ 575 MB fp32.
        assert!((450.0..650.0).contains(&mb), "{mb}");
    }

    #[test]
    fn resnet101_param_size_matches_architecture() {
        let g = resnet101(96, 1.0);
        let mb = g.total_param_bytes() / 1e6;
        // Canonical ResNet101: ~44.5M params ~ 178 MB fp32.
        assert!((120.0..240.0).contains(&mb), "{mb}");
    }

    #[test]
    fn inception_param_size_matches_architecture() {
        let g = inception_v3(96, 1.0);
        let mb = g.total_param_bytes() / 1e6;
        // Canonical InceptionV3: ~23.8M params ~ 95 MB fp32.
        assert!((55.0..140.0).contains(&mb), "{mb}");
    }

    #[test]
    fn conv_nets_have_conv_backward_ops() {
        let g = vgg19(8, 0.25);
        assert!(g.ops.iter().any(|o| o.op_type == "Conv2DBackpropFilter"));
        assert!(g.ops.iter().any(|o| o.op_type == "Conv2DBackpropInput"));
    }

    #[test]
    fn inception_has_branch_structure() {
        let g = inception_v3(8, 0.25);
        let concats = g.ops.iter().filter(|o| o.op_type == "ConcatV2").count();
        assert!(concats >= 4, "expected inception modules, got {concats} concats");
    }

    #[test]
    fn resnet_has_residual_adds() {
        let g = resnet101(8, 0.25);
        let adds = g.ops.iter().filter(|o| o.op_type == "AddV2").count();
        assert!(adds >= 6, "{adds}");
    }
}
