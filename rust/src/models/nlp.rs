//! Sequence benchmark models: Transformer (Vaswani et al.) and BERT.
//!
//! Both are built from a shared `encoder_layer` helper that emits the
//! full micro-op inventory a TF dump contains: per-head reshape/transpose
//! ops, score scaling, masking, dropout, layer norms, residuals — this is
//! what makes Reshape/Transpose/MatMul the dominant op types, as in the
//! paper's Table 6 SFB census.

use super::builder::NetBuilder;
use crate::graph::CompGraph;

fn sc(x: usize, scale: f64) -> usize {
    ((x as f64 * scale).round() as usize).max(1)
}

/// LayerNorm: statistically like BN but per-token; reuse batch_norm's
/// op inventory with the right parameter size.
fn layer_norm(b: &mut NetBuilder, d: usize) {
    b.batch_norm(d);
    // TF expands LayerNorm into mean/variance/rsqrt/mul/sub chains.
    b.micro_reshape(24);
}

/// Dense as it appears in a TF transformer dump: the matmul plus the
/// reshape/bias/dropout plumbing around it.
fn dense_tf(b: &mut NetBuilder, tokens: usize, din: usize, dout: usize) {
    b.dense(tokens, din, dout);
    b.micro_reshape(30);
}

/// One self-attention sublayer over `tokens` positions, model dim `d`,
/// `heads` heads, wrapped in residual + layer norm.
fn self_attention(b: &mut NetBuilder, tokens: usize, d: usize, heads: usize) {
    let bt = b.batch() as f64 * tokens as f64;
    let f32b = 4.0;
    b.residual(|b| {
        // Q, K, V projections.
        let q_in = b.cur();
        let _ = q_in;
        dense_tf(b, tokens, d, d); // Q
        b.shape_op("Reshape"); // split heads
        b.shape_op("Transpose");
        let q = b.cur();
        let q_bytes = b.cur_bytes();
        // K and V branch from the same input: model as sequential matmuls
        // whose outputs feed the score/context matmuls (TF emits exactly
        // this shape of graph after autodiff, with AddN merges).
        dense_tf(b, tokens, d, d); // K (approximates branch as chain)
        b.shape_op("Reshape");
        b.shape_op("Transpose");
        // scores = Q @ K^T / sqrt(dk): (B*heads, T, T)
        let score_flops = 2.0 * bt * tokens as f64 * d as f64;
        let score_bytes = b.batch() as f64 * heads as f64 * (tokens * tokens) as f64 * f32b;
        b.matmul2(q, q_bytes, score_flops, score_bytes);
        b.micro_reshape(40); // scale + mask add + shape plumbing
        b.softmax();
        b.micro_reshape(30); // dropout
        // V projection feeding context matmul.
        let p = b.cur();
        let p_bytes = b.cur_bytes();
        dense_tf(b, tokens, d, d); // V (chained)
        b.shape_op("Reshape");
        b.shape_op("Transpose");
        let ctx_flops = 2.0 * bt * tokens as f64 * d as f64;
        let ctx_bytes = bt * d as f64 * f32b;
        b.matmul2(p, p_bytes, ctx_flops, ctx_bytes);
        b.shape_op("Transpose"); // merge heads
        b.shape_op("Reshape");
        dense_tf(b, tokens, d, d); // output projection
        b.micro_reshape(20); // dropout
    });
    layer_norm(b, d);
}

/// Position-wise feed-forward sublayer (d -> dff -> d), residual + LN.
fn ffn(b: &mut NetBuilder, tokens: usize, d: usize, dff: usize) {
    b.residual(|b| {
        dense_tf(b, tokens, d, dff);
        b.activation("Gelu", "GeluGrad");
        b.micro_reshape(20); // TF expands gelu into erf/mul/add chains
        dense_tf(b, tokens, dff, d);
        b.micro_reshape(20); // dropout
    });
    layer_norm(b, d);
}

fn encoder_layer(b: &mut NetBuilder, tokens: usize, d: usize, heads: usize, dff: usize) {
    self_attention(b, tokens, d, heads);
    ffn(b, tokens, d, dff);
}

/// Transformer for NMT (paper batch 480 sentences): 6 encoder + 6 decoder
/// layers, d=768, dff=3072 — ~110M parameters (~440 MB), matching the
/// paper's 407 MB within tolerance.
pub fn transformer(batch: usize, scale: f64) -> CompGraph {
    let tokens = 64; // average sentence length
    let d = sc(768, scale);
    let dff = sc(3072, scale);
    let heads = sc(12, scale.max(0.34));
    let vocab = sc(32_000, scale);
    let layers = if scale < 1.0 { 2 } else { 6 };

    let mut b = NetBuilder::new("Transformer", batch, tokens as f64);
    let (table, tbytes) = b.embedding(vocab, d, tokens);
    b.micro_reshape(30); // position encodings, scaling, masks
    for _ in 0..layers {
        encoder_layer(&mut b, tokens, d, heads, dff);
    }
    // Decoder layers: self-attention + cross-attention + ffn.
    for _ in 0..layers {
        self_attention(&mut b, tokens, d, heads);
        self_attention(&mut b, tokens, d, heads); // cross-attn (same cost shape)
        ffn(&mut b, tokens, d, dff);
    }
    // Output projection to vocab, weight-tied to the embedding table
    // (standard for NMT transformers).
    let bt = batch as f64 * tokens as f64;
    b.matmul2(table, tbytes, 2.0 * bt * (d * vocab) as f64, bt * vocab as f64 * 4.0);
    b.softmax();
    b.finish()
}

/// BERT.  `large = false`: BERT-Small (L=4, H=512, A=8);
/// `large = true`: BERT-Large (L=24, H=1024, A=16) with the MLM head.
pub fn bert(batch: usize, large: bool, scale: f64) -> CompGraph {
    let (layers_full, d, heads, name) = if large {
        (24, sc(1024, scale), sc(16, scale.max(0.26)), "BERT-Large")
    } else {
        (4, sc(512, scale), sc(8, scale.max(0.26)), "BERT-Small")
    };
    let layers = if scale < 1.0 { 2 } else { layers_full };
    let tokens = 128;
    let dff = 4 * d;
    let vocab = sc(30_522, scale);

    let mut b = NetBuilder::new(name, batch, tokens as f64);
    let (table, tbytes) = b.embedding(vocab, d, tokens); // word embeddings
    b.micro_reshape(40); // token-type + position embeddings + dropout
    layer_norm(&mut b, d);
    for _ in 0..layers {
        encoder_layer(&mut b, tokens, d, heads, dff);
        b.micro_reshape(20);
    }
    // Pooler + MLM head: transform dense + tied decoder matmul against
    // the embedding table (as in the reference BERT implementation).
    dense_tf(&mut b, tokens, d, d);
    b.activation("Tanh", "TanhGrad");
    let bt = batch as f64 * tokens as f64;
    b.matmul2(table, tbytes, 2.0 * bt * (d * vocab) as f64, bt * vocab as f64 * 4.0);
    b.micro_reshape(30); // output bias, log-softmax plumbing
    b.softmax();
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transformer_param_size() {
        let g = transformer(480, 1.0);
        let mb = g.total_param_bytes() / 1e6;
        // target: paper 407 MB; canonical-ish 6+6 d=768: ~400-500 MB
        assert!((280.0..570.0).contains(&mb), "{mb}");
    }

    #[test]
    fn bert_small_param_size() {
        let g = bert(96, false, 1.0);
        let mb = g.total_param_bytes() / 1e6;
        // BERT-Small ~29M params ~ 115 MB; paper reports 98 MB.
        assert!((60.0..150.0).contains(&mb), "{mb}");
    }

    #[test]
    fn bert_large_param_size() {
        let g = bert(16, true, 1.0);
        let mb = g.total_param_bytes() / 1e6;
        // BERT-Large + MLM head: ~371M params ~ 1.48 GB; paper says
        // 2313 MB (likely including optimizer state) — see EXPERIMENTS.md.
        assert!((1100.0..2400.0).contains(&mb), "{mb}");
    }

    #[test]
    fn attention_emits_reshape_transpose_matmul() {
        let g = bert(8, false, 0.25);
        let count = |t: &str| g.ops.iter().filter(|o| o.op_type == t).count();
        assert!(count("Reshape") > 20);
        assert!(count("Transpose") > 10);
        assert!(count("MatMul") > 10);
        assert!(count("BatchMatMul") >= 4);
    }

    #[test]
    fn bert_large_bigger_than_small() {
        let s = bert(8, false, 0.25);
        let l = bert(4, true, 0.25);
        assert!(l.total_param_bytes() > s.total_param_bytes());
    }
}
