//! Model zoo: programmatic generators for the six benchmark DNNs of the
//! paper (Table 3), emitting full training graphs (forward + backward +
//! Adam apply ops) with realistic op counts, FLOPs, tensor sizes and
//! parameter sizes.
//!
//! Substitution note (DESIGN.md): the paper feeds TensorFlow graph dumps
//! to TAG; we generate structurally equivalent graphs (same layer
//! topology, micro-op inventory per layer — Conv2D/FusedBatchNorm/
//! Reshape/Transpose/..., and backward mirrors as produced by TF
//! autodiff).  TAG never keys on op identities, only on per-op
//! time/size features, so this exercises the same code paths.

pub mod builder;
mod cnn;
mod nlp;

pub use builder::NetBuilder;
pub use cnn::{inception_v3, resnet101, vgg19};
pub use nlp::{bert, transformer};

use crate::graph::CompGraph;

/// Paper Table 3 benchmark set, full size, paper batch sizes.
pub fn all_models() -> Vec<CompGraph> {
    vec![
        inception_v3(96, 1.0),
        resnet101(96, 1.0),
        vgg19(96, 1.0),
        transformer(480, 1.0),
        bert(96, false, 1.0),
        bert(16, true, 1.0),
    ]
}

/// Scaled-down versions (fewer blocks/channels) for unit tests — same
/// structure, two orders of magnitude fewer ops.
pub fn all_models_small() -> Vec<CompGraph> {
    vec![
        inception_v3(8, 0.25),
        resnet101(8, 0.25),
        vgg19(8, 0.25),
        transformer(16, 0.25),
        bert(8, false, 0.25),
        bert(4, true, 0.25),
    ]
}

/// Look up a full-size model generator by (case-insensitive) name.
pub fn by_name(name: &str, scale: f64) -> Option<CompGraph> {
    let scaled_batch = |b: usize| ((b as f64 * scale).round() as usize).max(1);
    match name.to_ascii_lowercase().as_str() {
        "inceptionv3" | "inception" => Some(inception_v3(scaled_batch(96), scale)),
        "resnet101" | "resnet" => Some(resnet101(scaled_batch(96), scale)),
        "vgg19" | "vgg" => Some(vgg19(scaled_batch(96), scale)),
        "transformer" => Some(transformer(scaled_batch(480), scale)),
        "bert-small" | "bertsmall" => Some(bert(scaled_batch(96), false, scale)),
        "bert-large" | "bertlarge" => Some(bert(scaled_batch(16), true, scale)),
        _ => None,
    }
}

pub const MODEL_NAMES: [&str; 6] = [
    "InceptionV3",
    "ResNet101",
    "VGG19",
    "Transformer",
    "BERT-Small",
    "BERT-Large",
];

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table 3 reference statistics: (name, #ops, param MB).
    /// Op counts are TF-1.14 graph dumps; we target the same order of
    /// magnitude (±40%) and exact-architecture parameter sizes.
    const TABLE3: [(&str, usize, f64); 6] = [
        ("InceptionV3", 5312, 90.0),
        ("ResNet101", 7951, 169.0),
        ("VGG19", 1169, 548.0),
        ("Transformer", 16859, 407.0),
        ("BERT-Small", 5061, 98.0),
        ("BERT-Large", 26601, 2313.0),
    ];

    #[test]
    fn table3_op_counts_and_param_sizes() {
        let models = all_models();
        for (g, (name, ops, mb)) in models.iter().zip(TABLE3) {
            assert_eq!(g.name, name);
            let n = g.len() as f64;
            assert!(
                n > ops as f64 * 0.6 && n < ops as f64 * 1.4,
                "{name}: {} ops vs paper {ops}",
                g.len()
            );
            // Parameter sizes come from the canonical architectures; the
            // paper's BERT-Large figure (2313 MB ~ 578M params) exceeds the
            // canonical 340M-param model — likely counting optimizer state.
            // We keep the honest architecture and allow [0.55, 1.45].
            let pmb = g.total_param_bytes() / 1e6;
            assert!(
                pmb > mb * 0.55 && pmb < mb * 1.45,
                "{name}: {pmb:.0} MB params vs paper {mb} MB"
            );
        }
    }

    #[test]
    fn all_graphs_acyclic_and_have_grad_pairs() {
        for g in all_models_small() {
            assert!(g.check_acyclic(), "{}", g.name);
            let pairs = g.grad_apply_pairs();
            assert!(!pairs.is_empty(), "{} has no grad/apply pairs", g.name);
            // Every variable must have exactly one Apply.
            let vars = g.ops.iter().filter(|o| o.is_param()).count();
            let applies = g.ops.iter().filter(|o| o.is_apply()).count();
            assert_eq!(vars, applies, "{}", g.name);
            assert_eq!(pairs.len(), vars, "{}", g.name);
        }
    }

    #[test]
    fn flops_are_positive_and_dominated_by_compute() {
        for g in all_models_small() {
            assert!(g.total_flops() > 0.0);
            let placeholder_flops: f64 = g
                .ops
                .iter()
                .filter(|o| matches!(o.kind, crate::graph::OpKind::Placeholder))
                .map(|o| o.flops)
                .sum();
            assert_eq!(placeholder_flops, 0.0, "{}", g.name);
        }
    }

    #[test]
    fn small_variants_are_much_smaller() {
        for (s, f) in all_models_small().iter().zip(all_models()) {
            assert!(s.len() < f.len(), "{}", s.name);
        }
    }

    #[test]
    fn by_name_roundtrip() {
        for name in MODEL_NAMES {
            let g = by_name(name, 0.25).unwrap();
            assert_eq!(g.name, name);
        }
        assert!(by_name("nope", 1.0).is_none());
    }

    #[test]
    fn backward_flops_roughly_double_forward() {
        // Standard rule of thumb: bwd ~ 2x fwd compute. Our generators
        // should be in a sane band (1.2x..3x).
        for g in all_models_small() {
            let fwd: f64 = g
                .ops
                .iter()
                .filter(|o| !o.is_grad() && !o.name.contains("bwd"))
                .map(|o| o.flops)
                .sum();
            let bwd: f64 = g.total_flops() - fwd;
            let ratio = bwd / fwd.max(1.0);
            assert!(
                (0.8..3.5).contains(&ratio),
                "{}: bwd/fwd flops ratio {ratio:.2}",
                g.name
            );
        }
    }
}
