//! [`NetBuilder`]: a layer-level network builder that emits the *training*
//! graph — forward micro-ops immediately, and a stack of backward hooks
//! that are composed in reverse at [`NetBuilder::finish`], mirroring what
//! TensorFlow's autodiff produces (gradient ops, `AddN` merges at forks,
//! per-variable `Apply` ops).
//!
//! Branch support (residual connections, inception modules) works by
//! composing hooks: `residual`/`fanout` snapshot the activation, build
//! each branch (whose hooks are captured into the branch's own list), and
//! push a merged hook that routes the incoming gradient through each
//! branch's reversed hooks and `AddN`s the results.

use crate::graph::ir::{CompGraph, OpBuilder, OpId, OpKind, Splittability};

/// A backward hook: given the gradient flowing in from downstream,
/// emit the layer's backward ops and return the gradient wrt the
/// layer's input.
pub type BwdHook = Box<dyn FnOnce(&mut CompGraph, OpId) -> OpId>;

pub struct NetBuilder {
    pub g: CompGraph,
    /// Current activation op and its size in bytes (full batch).
    cur: OpId,
    cur_bytes: f64,
    hooks: Vec<BwdHook>,
    /// (gradient producer op, variable op) pairs emitted by hooks.
    batch: usize,
    layer_idx: usize,
}

const F32: f64 = 4.0;

impl NetBuilder {
    /// Start a network with a data placeholder of `elem_per_sample`
    /// elements per sample.
    pub fn new(name: &str, batch: usize, elem_per_sample: f64) -> Self {
        let mut g = CompGraph::new(name, batch);
        let bytes = elem_per_sample * batch as f64 * F32;
        let cur = g.add(
            OpBuilder::new("data", "Placeholder")
                .kind(OpKind::Placeholder)
                .out_bytes(bytes)
                .build(),
        );
        Self { g, cur, cur_bytes: bytes, hooks: Vec::new(), batch, layer_idx: 0 }
    }

    pub fn batch(&self) -> usize {
        self.batch
    }
    pub fn cur(&self) -> OpId {
        self.cur
    }
    pub fn cur_bytes(&self) -> f64 {
        self.cur_bytes
    }

    fn name(&mut self, t: &str) -> String {
        self.layer_idx += 1;
        format!("{t}_{}", self.layer_idx)
    }

    /// Add a variable plus its TF-style `Read` micro-op; returns the
    /// variable id (readable as input).
    pub fn variable(&mut self, tag: &str, bytes: f64) -> OpId {
        let nm = self.name(tag);
        let v = self.g.add(
            OpBuilder::new(format!("{nm}/var"), "Variable")
                .kind(OpKind::Variable)
                .param_bytes(bytes)
                .out_bytes(bytes)
                .build(),
        );
        self.g.add(
            OpBuilder::new(format!("{nm}/read"), "ReadVariableOp")
                .out_bytes(bytes)
                .inputs(&[v])
                .build(),
        );
        v
    }

    /// Emit the Grad + Adam-slot + Apply micro-ops for a variable, the way
    /// a TF-1.x graph dump with the Adam optimizer does (slot variables
    /// `m`/`v` appear as stateful nodes feeding the fused apply).
    fn grad_apply(
        g: &mut CompGraph,
        nm: &str,
        ty: &'static str,
        var: OpId,
        bytes: f64,
        flops: f64,
        inputs: &[OpId],
    ) -> OpId {
        let gr = g.add(
            OpBuilder::new(format!("{nm}/grad"), ty)
                .kind(OpKind::Grad { wrt: var })
                .split(Splittability::Sum)
                .flops(flops)
                .out_bytes(bytes)
                .inputs(inputs)
                .build(),
        );
        let m = g.add(
            OpBuilder::new(format!("{nm}/adam_m"), "VariableV2")
                .out_bytes(bytes)
                .build(),
        );
        let v = g.add(
            OpBuilder::new(format!("{nm}/adam_v"), "VariableV2")
                .out_bytes(bytes)
                .build(),
        );
        g.add(
            OpBuilder::new(format!("{nm}/apply"), "ApplyAdam")
                .kind(OpKind::Apply { var })
                .split(Splittability::NoSplit)
                .flops(bytes / F32 * 4.0) // Adam: ~4 flops per element
                .out_bytes(bytes)
                .inputs(&[gr, var, m, v])
                .build(),
        );
        gr
    }

    /// TF graphs are full of small metadata side-chains
    /// (`Shape -> StridedSlice -> Pack -> Reshape`).  This emits `k` tiny
    /// side ops feeding an inline dynamic `Reshape` of the current
    /// activation, exactly the pattern TF's dynamic-shape handling
    /// produces.  Near-zero flops; keeps op inventories (Table 3) honest.
    pub fn micro_reshape(&mut self, k: usize) {
        const TYPES: [&str; 6] =
            ["Shape", "StridedSlice", "Pack", "Cast", "Mul", "RealDiv"];
        let nm = self.name("reshape");
        let x = self.cur;
        let mut side = x;
        for i in 0..k {
            side = self.g.add(
                OpBuilder::new(format!("{nm}/aux{i}"), TYPES[i % TYPES.len()])
                    .out_bytes(64.0)
                    .inputs(&[side])
                    .build(),
            );
        }
        let bytes = self.cur_bytes;
        let y = self.g.add(
            OpBuilder::new(nm.clone(), "Reshape")
                .out_bytes(bytes)
                .inputs(&[x, side])
                .build(),
        );
        self.cur = y;
        // TF autodiff mirrors the metadata plumbing on the backward pass
        // (Shape/Reshape/BroadcastGradientArgs chains), roughly half as
        // many nodes as forward.
        let bwd_aux = k / 2;
        self.hooks.push(Box::new(move |g, grad_out| {
            let mut side = grad_out;
            for i in 0..bwd_aux {
                side = g.add(
                    OpBuilder::new(format!("{nm}/bwd_aux{i}"), TYPES[i % TYPES.len()])
                        .out_bytes(64.0)
                        .inputs(&[side])
                        .build(),
                );
            }
            g.add(
                OpBuilder::new(format!("{nm}/bwd"), "Reshape")
                    .out_bytes(bytes)
                    .inputs(&[grad_out, side])
                    .build(),
            )
        }));
    }

    /// Generic primary layer: one fwd op with a weight variable, one
    /// bwd-input op, one weight-grad op, one apply. `fwd_flops` for full
    /// batch; `out_bytes` for full batch.
    #[allow(clippy::too_many_arguments)]
    fn primary(
        &mut self,
        tag: &str,
        fwd_ty: &'static str,
        bwd_in_ty: &'static str,
        bwd_w_ty: &'static str,
        w_bytes: f64,
        fwd_flops: f64,
        out_bytes: f64,
    ) {
        let nm = self.name(tag);
        let w = self.variable(&format!("{nm}/w"), w_bytes);
        let x = self.cur;
        let x_bytes = self.cur_bytes;
        let y = self.g.add(
            OpBuilder::new(nm.clone(), fwd_ty)
                .flops(fwd_flops)
                .out_bytes(out_bytes)
                .inputs(&[x, w])
                .build(),
        );
        self.cur = y;
        self.cur_bytes = out_bytes;
        let nm2 = nm.clone();
        self.hooks.push(Box::new(move |g, grad_out| {
            // dX: same cost class as forward.
            let dx = g.add(
                OpBuilder::new(format!("{nm2}/bwd_in"), bwd_in_ty)
                    .flops(fwd_flops)
                    .out_bytes(x_bytes)
                    .inputs(&[grad_out, w])
                    .build(),
            );
            // dW.
            Self::grad_apply(g, &nm2, bwd_w_ty, w, w_bytes, fwd_flops, &[grad_out, x]);
            dx
        }));
    }

    /// 2D convolution (no bias — BN usually follows), NHWC.
    /// `hw`: output spatial size, `cin`/`cout` channels, `k` kernel.
    pub fn conv2d(&mut self, hw: usize, cin: usize, cout: usize, k: usize) {
        let b = self.batch as f64;
        let flops = 2.0 * b * (hw * hw) as f64 * cin as f64 * cout as f64 * (k * k) as f64;
        let out_bytes = b * (hw * hw) as f64 * cout as f64 * F32;
        let w_bytes = (k * k * cin * cout) as f64 * F32;
        self.primary(
            "conv",
            "Conv2D",
            "Conv2DBackpropInput",
            "Conv2DBackpropFilter",
            w_bytes,
            flops,
            out_bytes,
        );
    }

    /// Fully connected layer `din -> dout` over `tokens` positions per
    /// sample (tokens=1 for plain dense heads).
    pub fn dense(&mut self, tokens: usize, din: usize, dout: usize) {
        let b = self.batch as f64 * tokens as f64;
        let flops = 2.0 * b * din as f64 * dout as f64;
        let out_bytes = b * dout as f64 * F32;
        let w_bytes = (din * dout) as f64 * F32;
        self.primary("dense", "MatMul", "MatMul", "MatMul", w_bytes, flops, out_bytes);
        self.bias_add(dout);
    }

    /// BiasAdd with its own variable.
    pub fn bias_add(&mut self, c: usize) {
        let nm = self.name("bias");
        let bbytes = c as f64 * F32;
        let bvar = self.variable(&format!("{nm}/b"), bbytes);
        let x = self.cur;
        let n_elem = self.cur_bytes / F32;
        let y = self.g.add(
            OpBuilder::new(nm.clone(), "BiasAdd")
                .flops(n_elem)
                .out_bytes(self.cur_bytes)
                .inputs(&[x, bvar])
                .build(),
        );
        self.cur = y;
        let bytes = self.cur_bytes;
        self.hooks.push(Box::new(move |g, grad_out| {
            Self::grad_apply(g, &nm, "BiasAddGrad", bvar, bbytes, n_elem, &[grad_out]);
            // gradient passes through unchanged
            let _ = bytes;
            grad_out
        }));
    }

    /// Fused batch norm: 1 fused op + scale/shift variables (+ the
    /// moving-average micro-ops TF emits).
    pub fn batch_norm(&mut self, c: usize) {
        let nm = self.name("bn");
        let pbytes = c as f64 * F32;
        let gamma = self.variable(&format!("{nm}/gamma"), pbytes);
        let beta = self.variable(&format!("{nm}/beta"), pbytes);
        let x = self.cur;
        let n_elem = self.cur_bytes / F32;
        let y = self.g.add(
            OpBuilder::new(nm.clone(), "FusedBatchNorm")
                .flops(8.0 * n_elem)
                .out_bytes(self.cur_bytes)
                .inputs(&[x, gamma, beta])
                .build(),
        );
        // moving mean/var update micro-ops (tiny)
        self.g.add(
            OpBuilder::new(format!("{nm}/moments"), "Mean")
                .flops(n_elem)
                .out_bytes(pbytes)
                .inputs(&[x])
                .build(),
        );
        self.cur = y;
        let x_bytes = self.cur_bytes;
        self.hooks.push(Box::new(move |g, grad_out| {
            let dx = g.add(
                OpBuilder::new(format!("{nm}/bwd"), "FusedBatchNormGrad")
                    .flops(10.0 * n_elem)
                    .out_bytes(x_bytes)
                    .inputs(&[grad_out, x])
                    .build(),
            );
            Self::grad_apply(g, &format!("{nm}/gamma"), "Sum", gamma, pbytes, n_elem, &[grad_out, x]);
            Self::grad_apply(g, &format!("{nm}/beta"), "Sum", beta, pbytes, n_elem, &[grad_out]);
            dx
        }));
    }

    /// Pointwise activation (Relu / Gelu / Tanh...).
    pub fn activation(&mut self, ty: &'static str, bwd_ty: &'static str) {
        let nm = self.name(ty);
        let x = self.cur;
        let n_elem = self.cur_bytes / F32;
        let y = self.g.add(
            OpBuilder::new(nm.clone(), ty)
                .flops(n_elem)
                .out_bytes(self.cur_bytes)
                .inputs(&[x])
                .build(),
        );
        self.cur = y;
        let bytes = self.cur_bytes;
        self.hooks.push(Box::new(move |g, grad_out| {
            g.add(
                OpBuilder::new(format!("{nm}/bwd"), bwd_ty)
                    .flops(n_elem)
                    .out_bytes(bytes)
                    .inputs(&[grad_out, y])
                    .build(),
            )
        }));
    }

    pub fn relu(&mut self) {
        self.activation("Relu", "ReluGrad");
    }

    /// Max/avg pooling with spatial reduction `hw_out`, channels `c`.
    pub fn pool(&mut self, ty: &'static str, hw_out: usize, c: usize) {
        let nm = self.name("pool");
        let b = self.batch as f64;
        let out_bytes = b * (hw_out * hw_out) as f64 * c as f64 * F32;
        let x = self.cur;
        let x_bytes = self.cur_bytes;
        let n_elem = x_bytes / F32;
        let y = self.g.add(
            OpBuilder::new(nm.clone(), ty)
                .flops(n_elem)
                .out_bytes(out_bytes)
                .inputs(&[x])
                .build(),
        );
        self.cur = y;
        self.cur_bytes = out_bytes;
        self.hooks.push(Box::new(move |g, grad_out| {
            g.add(
                OpBuilder::new(format!("{nm}/bwd"), "MaxPoolGrad")
                    .flops(n_elem)
                    .out_bytes(x_bytes)
                    .inputs(&[grad_out, x])
                    .build(),
            )
        }));
    }

    /// Shape-only op (Reshape / Transpose) — near-zero flops but real
    /// nodes in the graph (they matter for the SFB census, Table 6).
    pub fn shape_op(&mut self, ty: &'static str) {
        let nm = self.name(ty);
        let x = self.cur;
        let bytes = self.cur_bytes;
        // Transpose moves data; Reshape is metadata-only.
        let fl = if ty == "Transpose" { bytes / F32 } else { 0.0 };
        let y = self.g.add(
            OpBuilder::new(nm.clone(), ty).flops(fl).out_bytes(bytes).inputs(&[x]).build(),
        );
        self.cur = y;
        self.hooks.push(Box::new(move |g, grad_out| {
            g.add(
                OpBuilder::new(format!("{nm}/bwd"), ty)
                    .flops(fl)
                    .out_bytes(bytes)
                    .inputs(&[grad_out])
                    .build(),
            )
        }));
    }

    /// A batched pairwise matmul without weights (attention scores /
    /// context): cost `flops`, output `out_bytes`, consuming the current
    /// activation and `other`.
    pub fn matmul2(&mut self, other: OpId, other_bytes: f64, flops: f64, out_bytes: f64) {
        let nm = self.name("batchmatmul");
        let x = self.cur;
        let x_bytes = self.cur_bytes;
        let y = self.g.add(
            OpBuilder::new(nm.clone(), "BatchMatMul")
                .flops(flops)
                .out_bytes(out_bytes)
                .inputs(&[x, other])
                .build(),
        );
        self.cur = y;
        self.cur_bytes = out_bytes;
        self.hooks.push(Box::new(move |g, grad_out| {
            // two bwd matmuls (dA, dB); dB's path merges via AddN later —
            // we approximate the second as a local op.
            let da = g.add(
                OpBuilder::new(format!("{nm}/bwd_a"), "BatchMatMul")
                    .flops(flops)
                    .out_bytes(x_bytes)
                    .inputs(&[grad_out, other])
                    .build(),
            );
            g.add(
                OpBuilder::new(format!("{nm}/bwd_b"), "BatchMatMul")
                    .flops(flops)
                    .out_bytes(other_bytes)
                    .inputs(&[grad_out, x])
                    .build(),
            );
            da
        }));
    }

    /// Softmax (attention / classifier head).
    pub fn softmax(&mut self) {
        self.activation("Softmax", "SoftmaxGrad");
    }

    /// Embedding lookup: table `vocab x dim`, output `tokens` per sample.
    pub fn embedding(&mut self, vocab: usize, dim: usize, tokens: usize) -> (crate::graph::ir::OpId, f64) {
        let nm = self.name("embed");
        let tbytes = (vocab * dim) as f64 * F32;
        let table = self.variable(&format!("{nm}/table"), tbytes);
        let b = self.batch as f64 * tokens as f64;
        let out_bytes = b * dim as f64 * F32;
        let x = self.cur;
        let y = self.g.add(
            OpBuilder::new(nm.clone(), "GatherV2")
                .flops(b * dim as f64)
                .out_bytes(out_bytes)
                .inputs(&[x, table])
                .build(),
        );
        self.cur = y;
        self.cur_bytes = out_bytes;
        self.hooks.push(Box::new(move |g, grad_out| {
            Self::grad_apply(
                g,
                &nm,
                "UnsortedSegmentSum",
                table,
                tbytes,
                b * dim as f64,
                &[grad_out, x],
            );
            grad_out // no meaningful input gradient for integer ids
        }));
        (table, tbytes)
    }

    // ----------------------------------------------------------- branches

    /// Snapshot for branch building: (activation, bytes, hook stack len).
    pub fn snapshot(&self) -> (OpId, f64, usize) {
        (self.cur, self.cur_bytes, self.hooks.len())
    }

    /// Residual connection: `body` builds the residual branch from the
    /// current activation; afterwards `cur = body_out + shortcut` and the
    /// backward pass AddNs the two gradient paths.
    pub fn residual<Fb: FnOnce(&mut Self)>(&mut self, body: Fb) {
        let (short, short_bytes, mark) = self.snapshot();
        body(self);
        let body_hooks: Vec<BwdHook> = self.hooks.split_off(mark);
        let body_out = self.cur;
        let out_bytes = self.cur_bytes;
        assert!(
            (out_bytes - short_bytes).abs() < 1.0,
            "residual branch must preserve shape ({out_bytes} vs {short_bytes})"
        );
        let nm = self.name("residual_add");
        let y = self.g.add(
            OpBuilder::new(nm.clone(), "AddV2")
                .flops(out_bytes / F32)
                .out_bytes(out_bytes)
                .inputs(&[short, body_out])
                .build(),
        );
        self.cur = y;
        self.hooks.push(Box::new(move |g, grad_out| {
            // Route grad through the body branch (reverse hook order).
            let mut gcur = grad_out;
            for h in body_hooks.into_iter().rev() {
                gcur = h(g, gcur);
            }
            // Merge with the shortcut gradient (identity path).
            g.add(
                OpBuilder::new(format!("{nm}/bwd_addn"), "AddN")
                    .flops(out_bytes / F32)
                    .out_bytes(out_bytes)
                    .inputs(&[grad_out, gcur])
                    .build(),
            )
        }));
    }

    /// Parallel branches concatenated along channels (inception module).
    /// Each closure builds one branch from the shared input; outputs are
    /// `ConcatV2`-ed. Backward: `Split` the gradient, run each branch's
    /// hooks, `AddN` the input gradients.
    pub fn fanout_concat(&mut self, branches: Vec<Box<dyn FnOnce(&mut Self)>>) {
        let (input, input_bytes, _) = self.snapshot();
        let mut outs = Vec::new();
        let mut hook_sets = Vec::new();
        let mut total_bytes = 0.0;
        for b in branches {
            self.cur = input;
            self.cur_bytes = input_bytes;
            let mark = self.hooks.len();
            b(self);
            hook_sets.push(self.hooks.split_off(mark));
            outs.push(self.cur);
            total_bytes += self.cur_bytes;
        }
        let nm = self.name("concat");
        let y = self.g.add(
            OpBuilder::new(nm.clone(), "ConcatV2")
                .flops(total_bytes / F32)
                .out_bytes(total_bytes)
                .inputs(&outs)
                .build(),
        );
        self.cur = y;
        self.cur_bytes = total_bytes;
        self.hooks.push(Box::new(move |g, grad_out| {
            let split = g.add(
                OpBuilder::new(format!("{nm}/bwd_split"), "Split")
                    .flops(total_bytes / F32)
                    .out_bytes(total_bytes)
                    .inputs(&[grad_out])
                    .build(),
            );
            let mut grads = Vec::new();
            for hooks in hook_sets {
                let mut gcur = split;
                for h in hooks.into_iter().rev() {
                    gcur = h(g, gcur);
                }
                grads.push(gcur);
            }
            g.add(
                OpBuilder::new(format!("{nm}/bwd_addn"), "AddN")
                    .flops(input_bytes / F32 * grads.len() as f64)
                    .out_bytes(input_bytes)
                    .inputs(&grads)
                    .build(),
            )
        }));
    }

    /// Classifier head: global pool + dense(softmax) + cross-entropy loss,
    /// then run all backward hooks and return the finished graph.
    pub fn finish_classifier(mut self, feat: usize, classes: usize) -> CompGraph {
        self.dense(1, feat, classes);
        self.softmax();
        self.finish()
    }

    /// Emit loss + initial gradient, run backward hooks in reverse.
    pub fn finish(mut self) -> CompGraph {
        let b = self.batch as f64;
        let loss = self.g.add(
            OpBuilder::new("loss", "SparseSoftmaxCrossEntropyWithLogits")
                .flops(self.cur_bytes / F32 * 3.0)
                .out_bytes(b * F32)
                .inputs(&[self.cur])
                .build(),
        );
        let mut gcur = self.g.add(
            OpBuilder::new("loss/bwd", "Fill")
                .flops(self.cur_bytes / F32)
                .out_bytes(self.cur_bytes)
                .inputs(&[loss])
                .build(),
        );
        for h in self.hooks.into_iter().rev() {
            gcur = h(&mut self.g, gcur);
        }
        self.g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;

    #[test]
    fn mlp_has_matched_grads_and_applies() {
        let mut b = NetBuilder::new("mlp", 4, 32.0);
        b.dense(1, 8, 16);
        b.relu();
        b.dense(1, 16, 4);
        let g = b.finish();
        assert!(g.check_acyclic());
        let vars = g.ops.iter().filter(|o| o.is_param()).count();
        assert_eq!(vars, 4); // 2 W + 2 bias
        assert_eq!(g.grad_apply_pairs().len(), 4);
    }

    #[test]
    fn residual_adds_and_merges_gradients() {
        let mut b = NetBuilder::new("res", 2, 16.0 * 16.0 * 8.0);
        b.conv2d(16, 8, 8, 3);
        b.residual(|b| {
            b.conv2d(16, 8, 8, 3);
            b.relu();
        });
        let g = b.finish();
        assert!(g.check_acyclic());
        let addn = g.ops.iter().filter(|o| o.op_type == "AddN").count();
        assert!(addn >= 1, "residual backward must AddN gradient paths");
        let adds = g.ops.iter().filter(|o| o.op_type == "AddV2").count();
        assert_eq!(adds, 1);
    }

    #[test]
    fn fanout_concat_splits_gradient() {
        let mut b = NetBuilder::new("inc", 2, 8.0 * 8.0 * 4.0);
        b.conv2d(8, 4, 4, 1);
        b.fanout_concat(vec![
            Box::new(|b: &mut NetBuilder| b.conv2d(8, 4, 8, 1)),
            Box::new(|b: &mut NetBuilder| b.conv2d(8, 4, 16, 3)),
        ]);
        let g = b.finish();
        assert!(g.check_acyclic());
        assert_eq!(g.ops.iter().filter(|o| o.op_type == "ConcatV2").count(), 1);
        assert_eq!(g.ops.iter().filter(|o| o.op_type == "Split").count(), 1);
        // concat output channels 8+16=24
        let concat = g.ops.iter().find(|o| o.op_type == "ConcatV2").unwrap();
        assert_eq!(concat.output_bytes, 2.0 * 8.0 * 8.0 * 24.0 * 4.0);
    }

    #[test]
    fn variables_have_reads() {
        let mut b = NetBuilder::new("v", 2, 8.0);
        b.dense(1, 2, 2);
        let g = b.finish();
        let vars = g.ops.iter().filter(|o| o.is_param()).count();
        let reads = g.ops.iter().filter(|o| o.op_type == "ReadVariableOp").count();
        assert_eq!(vars, reads);
    }

    #[test]
    fn grad_targets_are_variables() {
        let mut b = NetBuilder::new("t", 2, 64.0);
        b.conv2d(4, 4, 8, 3);
        b.batch_norm(8);
        b.relu();
        let g = b.finish();
        for op in &g.ops {
            if let OpKind::Grad { wrt } = op.kind {
                assert!(g.ops[wrt].is_param(), "grad target {} not a variable", wrt);
            }
        }
    }
}
