//! Device topology descriptions (paper §2.2, §5.2).
//!
//! A [`Topology`] is a set of [`DeviceGroup`]s — homogeneous GPUs with
//! uniform pairwise intra-group bandwidth, usually one multi-GPU machine
//! — backed by a routed **link graph** ([`linkgraph`]): devices *and*
//! switches as nodes, typed links with bandwidth and latency, and a
//! cached deterministic route table.  Every bandwidth query
//! ([`Topology::bw_gbps`], [`Topology::bottleneck_bw_gbps`],
//! [`Topology::group_route`]) answers from the routes.
//!
//! Two construction paths:
//!
//! * [`Topology::new`] / [`Topology::try_new`] — the original flat form
//!   (groups + pairwise inter-group matrix).  The matrix becomes a
//!   *clique* link graph whose direct-link routes reproduce the matrix
//!   **bit for bit** (the equivalence contract pinned in
//!   `rust/tests/api.rs`), so flat topologies behave exactly as before
//!   this layer existed.
//! * [`Topology::routed`] — an explicit [`linkgraph::LinkGraph`] with
//!   switches and multi-hop paths.  The `inter_bw_gbps` matrix is then
//!   a *derived view*: entry `[i][j]` is the routed bottleneck between
//!   representative devices of groups `i` and `j`.
//!
//! [`presets`] defines the paper's *testbed*, *cloud* and homogeneous
//! evaluation clusters plus hierarchical clusters (an NVLink-island
//! machine pair, a multi-rack oversubscribed-ethernet pod);
//! [`generator`] samples random flat topologies with the distribution of
//! §5.2 and random hierarchical (switched) topologies for the
//! generalization experiments.  [`faults`] injects failures (killed
//! devices, severed or degraded links) and rebuilds the *residual*
//! topology through these same constructors, so a degraded cluster is
//! re-validated end to end before anything is planned onto it.  The
//! rebuild itself lives in [`residual`] — one deterministic
//! dead-node-removal / link-rebuild / re-route path shared by fault
//! injection and the [`crate::fleet`] lease layer, so the two can
//! never drift apart.

pub mod faults;
pub mod generator;
pub mod linkgraph;
pub mod presets;
pub mod residual;

pub use faults::{generate_trace, Fault, FaultSpec};
pub use residual::{Residual, ResidualSpec};
pub use generator::{random_hierarchical_topology, random_topology};
pub use linkgraph::{Link, LinkGraph, LinkGraphBuilder, LinkKind, NodeKind, Route, RouteTable};
pub use presets::{cloud, homogeneous, multi_rack, nvlink_island, sfb_pair, testbed};

use std::sync::Arc;

use crate::util::error::Result;

/// Resolve a topology *spec* string: a preset name (`testbed`, `cloud`,
/// `homogeneous`/`homog`, `sfb`/`sfb_pair`, `nvlink_island`/`nvlink`,
/// `multi_rack`/`rack`) or a seeded generator (`random:SEED`,
/// `hier:SEED`).  This is the shared vocabulary of the CLI
/// (`--topology`) and the `tag serve` wire request (`"topology"`);
/// `None` means the spec is unknown (a malformed seed is unknown too,
/// never silently seed 0).
pub fn topology_by_spec(spec: &str) -> Option<Topology> {
    match spec {
        "testbed" => Some(presets::testbed()),
        "cloud" => Some(presets::cloud()),
        "homogeneous" | "homog" => Some(presets::homogeneous()),
        "sfb" | "sfb_pair" => Some(presets::sfb_pair()),
        "nvlink_island" | "nvlink" => Some(presets::nvlink_island()),
        "multi_rack" | "rack" => Some(presets::multi_rack()),
        other => {
            if let Some(seed) = other.strip_prefix("random:") {
                let seed: u64 = seed.parse().ok()?;
                Some(random_topology(&mut crate::util::Rng::new(seed)))
            } else if let Some(seed) = other.strip_prefix("hier:") {
                let seed: u64 = seed.parse().ok()?;
                Some(random_hierarchical_topology(&mut crate::util::Rng::new(seed)))
            } else {
                None
            }
        }
    }
}

/// A GPU model with its effective compute rate and memory.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GpuType {
    pub name: &'static str,
    /// Peak fp32 TFLOPS.
    pub peak_tflops: f64,
    /// Fraction of peak achieved on typical DNN kernels (profiler
    /// calibration constant).
    pub efficiency: f64,
    pub mem_gb: f64,
}

impl GpuType {
    /// Effective FLOP/s for cost modeling.
    pub fn effective_flops(&self) -> f64 {
        self.peak_tflops * 1e12 * self.efficiency
    }
}

pub const V100_32G: GpuType =
    GpuType { name: "V100-32G", peak_tflops: 15.7, efficiency: 0.42, mem_gb: 32.0 };
pub const V100_16G: GpuType =
    GpuType { name: "V100-16G", peak_tflops: 15.7, efficiency: 0.42, mem_gb: 16.0 };
pub const GTX1080TI: GpuType =
    GpuType { name: "1080Ti", peak_tflops: 11.3, efficiency: 0.30, mem_gb: 11.0 };
pub const P100: GpuType =
    GpuType { name: "P100", peak_tflops: 9.3, efficiency: 0.35, mem_gb: 16.0 };
pub const T4: GpuType =
    GpuType { name: "T4", peak_tflops: 8.1, efficiency: 0.32, mem_gb: 16.0 };

/// The three representative GPU generations used by the random-topology
/// generator (§5.2: "a GPU type among 3 types").
pub const RANDOM_GPU_TYPES: [GpuType; 3] = [V100_16G, GTX1080TI, P100];

/// A group of homogeneous, uniformly-connected GPUs (typically one
/// machine).
#[derive(Clone, Debug)]
pub struct DeviceGroup {
    pub gpu: GpuType,
    pub count: usize,
    /// Pairwise bandwidth between GPUs in this group, Gbit/s
    /// (NVLink ~ 160+, PCIe ~ 64-128).  For routed topologies this must
    /// equal the routed intra-group path bottleneck (validated).
    pub intra_bw_gbps: f64,
}

/// Globally unique device id: (group index, index within group).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DeviceId {
    pub group: usize,
    pub idx: usize,
}

/// The routed link characteristics of a device set: the bottleneck
/// bandwidth among all pairs (`tau` in the SFB formulation) and the
/// worst pairwise path latency.  Cached per placement mask by
/// [`crate::dist::Lowering`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkProfile {
    pub bottleneck_gbps: f64,
    pub max_latency_s: f64,
}

/// A full device topology: groups + the routed link graph underneath.
///
/// `inter_bw_gbps` is kept as a **derived view** of the routes (for flat
/// topologies it is the constructor's matrix verbatim).  The link graph
/// and route table ride behind `Arc`s, so clones share them.  The public
/// fields exist for inspection and fingerprinting; mutating them leaves
/// the routes stale — rebuild through a constructor instead (the
/// [`Planner`](crate::api::Planner) validates consistency per request).
#[derive(Clone, Debug)]
pub struct Topology {
    pub name: String,
    pub groups: Vec<DeviceGroup>,
    /// `inter_bw[i][j]` in Gbit/s; diagonal unused (use intra_bw).
    /// Derived: equals the routed group-pair bottleneck bandwidth.
    pub inter_bw_gbps: Vec<Vec<f64>>,
    graph: Arc<LinkGraph>,
    routes: Arc<RouteTable>,
    /// Flat device index of each group's first device.
    offsets: Vec<usize>,
}

fn group_offsets(groups: &[DeviceGroup]) -> Vec<usize> {
    let mut offsets = Vec::with_capacity(groups.len());
    let mut at = 0;
    for g in groups {
        offsets.push(at);
        at += g.count;
    }
    offsets
}

impl Topology {
    /// Flat (matrix) construction; panics on malformed input.  Prefer
    /// [`Topology::try_new`] where errors should surface as values.
    pub fn new(name: impl Into<String>, groups: Vec<DeviceGroup>, inter: Vec<Vec<f64>>) -> Self {
        Self::try_new(name, groups, inter).unwrap_or_else(|e| panic!("invalid topology: {e}"))
    }

    /// Flat (matrix) construction: the matrix becomes a clique link
    /// graph whose routes reproduce it bit for bit.
    pub fn try_new(
        name: impl Into<String>,
        groups: Vec<DeviceGroup>,
        inter: Vec<Vec<f64>>,
    ) -> Result<Self> {
        validate_flat(&groups, &inter)?;
        let graph = LinkGraph::clique(&groups, &inter);
        let routes = graph.route_table()?;
        let offsets = group_offsets(&groups);
        Ok(Self {
            name: name.into(),
            groups,
            inter_bw_gbps: inter,
            graph: Arc::new(graph),
            routes: Arc::new(routes),
            offsets,
        })
    }

    /// Routed construction from an explicit link graph (switches,
    /// multi-hop paths).  The inter-group matrix is derived from the
    /// routes; each group's declared `intra_bw_gbps` must match its
    /// routed intra path.
    pub fn routed(
        name: impl Into<String>,
        groups: Vec<DeviceGroup>,
        graph: LinkGraph,
    ) -> Result<Self> {
        validate_groups(&groups)?;
        graph.check()?;
        // The builder must have added devices in flat (group, idx) order.
        let expect: Vec<DeviceId> = groups
            .iter()
            .enumerate()
            .flat_map(|(gi, g)| (0..g.count).map(move |di| DeviceId { group: gi, idx: di }))
            .collect();
        let got: Vec<DeviceId> = graph.device_ids().collect();
        if got != expect {
            crate::ensure!(
                got.len() == expect.len(),
                "link graph must register every group device: got {} devices, \
                 expected {}",
                got.len(),
                expect.len()
            );
            let at = got.iter().zip(&expect).position(|(g, e)| g != e).unwrap_or(0);
            crate::bail!(
                "link graph devices must be added in flat (group, idx) order: \
                 position {at} holds {:?}, expected {:?}",
                got[at],
                expect[at]
            );
        }
        let routes = graph.route_table()?;
        let offsets = group_offsets(&groups);
        check_intra_matches_routes(&groups, &offsets, &routes)?;
        // Derive the inter-group matrix from representative routes.
        let m = groups.len();
        let mut inter = vec![vec![0.0; m]; m];
        for i in 0..m {
            for j in (i + 1)..m {
                let bw = routes.route(offsets[i], offsets[j]).bottleneck_gbps;
                inter[i][j] = bw;
                inter[j][i] = bw;
            }
        }
        Ok(Self {
            name: name.into(),
            groups,
            inter_bw_gbps: inter,
            graph: Arc::new(graph),
            routes: Arc::new(routes),
            offsets,
        })
    }

    /// Check the topology's invariants: matrix shape and symmetry, group
    /// sanity, link-graph structure, and route coverage consistent with
    /// the (publicly mutable) flat fields.  [`crate::api::Planner`]
    /// calls this per request so malformed topologies surface as plan
    /// errors instead of aborts.
    pub fn validate(&self) -> Result<()> {
        validate_flat(&self.groups, &self.inter_bw_gbps)?;
        self.graph.check()?;
        crate::ensure!(
            self.offsets.len() == self.groups.len(),
            "group list mutated after construction ({} groups, {} routed) — rebuild \
             the topology",
            self.groups.len(),
            self.offsets.len()
        );
        crate::ensure!(
            self.graph.num_devices() == self.num_devices()
                && self.routes.num_devices() == self.num_devices(),
            "link graph covers {} devices, route table {}, topology declares {}",
            self.graph.num_devices(),
            self.routes.num_devices(),
            self.num_devices()
        );
        // The flat fields are a derived view of the routes; a mutated
        // matrix, intra bandwidth or group list that no longer matches
        // them is invalid.
        for (i, &oi) in self.offsets.iter().enumerate() {
            for (j, &oj) in self.offsets.iter().enumerate() {
                if i == j {
                    continue;
                }
                let bw = self.routes.route(oi, oj).bottleneck_gbps;
                crate::ensure!(
                    bw.is_finite() && bw > 0.0,
                    "groups {i} and {j} are not connected by any route"
                );
                crate::ensure!(
                    (bw - self.inter_bw_gbps[i][j]).abs() < 1e-9,
                    "inter-bw[{i}][{j}] = {} does not match the routed bottleneck {} \
                     (stale derived view — rebuild the topology)",
                    self.inter_bw_gbps[i][j],
                    bw
                );
            }
        }
        check_intra_matches_routes(&self.groups, &self.offsets, &self.routes)?;
        Ok(())
    }

    pub fn num_groups(&self) -> usize {
        self.groups.len()
    }

    pub fn num_devices(&self) -> usize {
        self.groups.iter().map(|g| g.count).sum()
    }

    pub fn devices(&self) -> Vec<DeviceId> {
        let mut out = Vec::new();
        for (gi, g) in self.groups.iter().enumerate() {
            for di in 0..g.count {
                out.push(DeviceId { group: gi, idx: di });
            }
        }
        out
    }

    /// Flat device index (the link graph / route table coordinate).
    pub fn device_flat_index(&self, d: DeviceId) -> usize {
        self.offsets[d.group] + d.idx
    }

    /// The physical link graph under this topology.
    pub fn link_graph(&self) -> &LinkGraph {
        &self.graph
    }

    /// Whether this topology routes over switches / multi-hop paths
    /// (false for flat clique topologies, whose routes are the direct
    /// links and reproduce the matrix exactly).
    pub fn is_routed(&self) -> bool {
        !self.graph.is_clique()
    }

    /// The cached route between two devices.
    pub fn route(&self, a: DeviceId, b: DeviceId) -> &Route {
        self.routes.route(self.device_flat_index(a), self.device_flat_index(b))
    }

    /// Accumulated path latency between two devices (0 for the same
    /// device and for flat clique links).
    pub fn route_latency_s(&self, a: DeviceId, b: DeviceId) -> f64 {
        self.route(a, b).latency_s
    }

    /// The route between two groups' representative devices — what
    /// inter-machine transfers traverse.
    pub fn group_route(&self, gi: usize, gj: usize) -> &Route {
        self.routes.route(self.offsets[gi], self.offsets[gj])
    }

    /// Routed bandwidth between two groups, Gbit/s (the derived matrix
    /// view; equal to [`Topology::group_route`]'s bottleneck).
    pub fn group_bw_gbps(&self, gi: usize, gj: usize) -> f64 {
        self.inter_bw_gbps[gi][gj]
    }

    /// Routed bandwidth between two devices in Gbit/s.
    pub fn bw_gbps(&self, a: DeviceId, b: DeviceId) -> f64 {
        if a == b {
            return f64::INFINITY;
        }
        self.route(a, b).bottleneck_gbps
    }

    /// Bytes/second between two devices.
    pub fn bw_bytes_per_s(&self, a: DeviceId, b: DeviceId) -> f64 {
        self.bw_gbps(a, b) * 1e9 / 8.0
    }

    /// The bottleneck (minimum) pairwise routed bandwidth among a device
    /// set, Gbit/s — `tau` in the SFB formulation.
    pub fn bottleneck_bw_gbps(&self, devs: &[DeviceId]) -> f64 {
        let mut min_bw = f64::INFINITY;
        for (i, &a) in devs.iter().enumerate() {
            for &b in &devs[i + 1..] {
                min_bw = min_bw.min(self.bw_gbps(a, b));
            }
        }
        min_bw
    }

    /// Bottleneck bandwidth *and* worst pairwise path latency of a
    /// device set in one O(n²) pass.  The bottleneck folds `min` in the
    /// same pair order as [`Topology::bottleneck_bw_gbps`], so the two
    /// agree bit for bit; `dist::Lowering` memoizes this per placement
    /// mask (the satellite of the lowering hot loop).
    pub fn link_profile(&self, devs: &[DeviceId]) -> LinkProfile {
        let mut min_bw = f64::INFINITY;
        let mut max_lat = 0.0f64;
        for (i, &a) in devs.iter().enumerate() {
            for &b in &devs[i + 1..] {
                let r = self.route(a, b);
                min_bw = min_bw.min(r.bottleneck_gbps);
                max_lat = max_lat.max(r.latency_s);
            }
        }
        LinkProfile { bottleneck_gbps: min_bw, max_latency_s: max_lat }
    }

    /// Largest degree among switches attached to the group's devices
    /// (0 for flat cliques) — a GNN topology-structure feature.
    pub fn switch_degree(&self, gi: usize) -> usize {
        (0..self.groups[gi].count)
            .map(|di| self.graph.attached_switch_degree(self.offsets[gi] + di))
            .max()
            .unwrap_or(0)
    }

    /// Mean route length (hops) from group `gi` to every other group —
    /// a GNN topology-structure feature.  0 for single-group topologies.
    pub fn mean_group_hops(&self, gi: usize) -> f64 {
        let m = self.num_groups();
        if m <= 1 {
            return 0.0;
        }
        let total: usize =
            (0..m).filter(|&gj| gj != gi).map(|gj| self.group_route(gi, gj).hops()).sum();
        total as f64 / (m - 1) as f64
    }

    /// Total memory across a group, bytes.
    pub fn group_mem_bytes(&self, gi: usize) -> f64 {
        self.groups[gi].gpu.mem_gb * 1e9 * self.groups[gi].count as f64
    }

    /// Aggregate effective FLOP/s of a device subset given as a group
    /// bitmask (used to rank candidate placements).
    pub fn mask_flops(&self, mask: u16) -> f64 {
        (0..self.groups.len())
            .filter(|gi| mask & (1 << gi) != 0)
            .map(|gi| self.groups[gi].gpu.effective_flops() * self.groups[gi].count as f64)
            .sum()
    }

    /// Expand a group bitmask into concrete devices.
    pub fn mask_devices(&self, mask: u16) -> Vec<DeviceId> {
        let mut out = Vec::new();
        for (gi, g) in self.groups.iter().enumerate() {
            if mask & (1 << gi) != 0 {
                for di in 0..g.count {
                    out.push(DeviceId { group: gi, idx: di });
                }
            }
        }
        out
    }
}

/// Group-inventory invariants shared by both construction paths.
fn validate_groups(groups: &[DeviceGroup]) -> Result<()> {
    let m = groups.len();
    crate::ensure!(m > 0 && m <= 16, "topology needs 1..=16 device groups, got {m}");
    for (gi, g) in groups.iter().enumerate() {
        crate::ensure!(
            g.count > 0 && g.intra_bw_gbps > 0.0,
            "device group {gi} must have devices and positive intra bandwidth"
        );
    }
    Ok(())
}

/// Declared intra bandwidth must be the routed intra-path bottleneck of
/// *every* device pair in the group (DeviceGroup models homogeneous,
/// uniformly-connected GPUs) — checked at routed construction and on
/// every re-validation (a mutated `intra_bw_gbps` is as stale as a
/// mutated inter matrix: routes, and therefore simulated times, still
/// use the physical links).
fn check_intra_matches_routes(
    groups: &[DeviceGroup],
    offsets: &[usize],
    routes: &RouteTable,
) -> Result<()> {
    for (gi, g) in groups.iter().enumerate() {
        for a in 0..g.count {
            for b in (a + 1)..g.count {
                let r = routes.route(offsets[gi] + a, offsets[gi] + b);
                crate::ensure!(
                    (r.bottleneck_gbps - g.intra_bw_gbps).abs() < 1e-9,
                    "group {gi}: declared intra bandwidth {} does not match the routed \
                     path bottleneck {} between its devices {a} and {b} (non-uniform \
                     fabric or stale derived view — rebuild the topology)",
                    g.intra_bw_gbps,
                    r.bottleneck_gbps
                );
            }
        }
    }
    Ok(())
}

/// The flat-field invariants shared by construction and re-validation.
fn validate_flat(groups: &[DeviceGroup], inter: &[Vec<f64>]) -> Result<()> {
    validate_groups(groups)?;
    let m = groups.len();
    crate::ensure!(inter.len() == m, "inter-bw matrix shape: {} rows for {m} groups", inter.len());
    for row in inter {
        crate::ensure!(
            row.len() == m,
            "inter-bw matrix shape: row of {} for {m} groups",
            row.len()
        );
    }
    for i in 0..m {
        for j in 0..m {
            crate::ensure!(
                (inter[i][j] - inter[j][i]).abs() < 1e-9,
                "inter-bw must be symmetric (entry [{i}][{j}])"
            );
            crate::ensure!(
                inter[i][j].is_finite() && inter[i][j] >= 0.0,
                "inter-bw[{i}][{j}] must be finite and non-negative, got {}",
                inter[i][j]
            );
            if i != j {
                crate::ensure!(
                    inter[i][j] > 0.0 || m == 1,
                    "inter-bw[{i}][{j}] must be positive between distinct groups"
                );
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_groups() -> Topology {
        Topology::new(
            "t",
            vec![
                DeviceGroup { gpu: V100_16G, count: 2, intra_bw_gbps: 128.0 },
                DeviceGroup { gpu: P100, count: 4, intra_bw_gbps: 64.0 },
            ],
            vec![vec![0.0, 25.0], vec![25.0, 0.0]],
        )
    }

    #[test]
    fn device_enumeration() {
        let t = two_groups();
        assert_eq!(t.num_devices(), 6);
        assert_eq!(t.devices().len(), 6);
        assert_eq!(t.devices()[2], DeviceId { group: 1, idx: 0 });
        assert_eq!(t.device_flat_index(DeviceId { group: 1, idx: 2 }), 4);
    }

    #[test]
    fn bandwidth_lookup() {
        let t = two_groups();
        let a = DeviceId { group: 0, idx: 0 };
        let b = DeviceId { group: 0, idx: 1 };
        let c = DeviceId { group: 1, idx: 0 };
        assert_eq!(t.bw_gbps(a, b), 128.0);
        assert_eq!(t.bw_gbps(a, c), 25.0);
        assert!(t.bw_gbps(a, a).is_infinite());
        assert_eq!(t.bw_bytes_per_s(a, c), 25.0e9 / 8.0);
        // Clique routes are the direct links: one hop, zero latency.
        assert_eq!(t.route(a, c).hops(), 1);
        assert_eq!(t.route_latency_s(a, c), 0.0);
        assert!(!t.is_routed());
    }

    #[test]
    fn bottleneck_bandwidth() {
        let t = two_groups();
        let all = t.devices();
        assert_eq!(t.bottleneck_bw_gbps(&all), 25.0);
        let intra = &all[2..6];
        assert_eq!(t.bottleneck_bw_gbps(intra), 64.0);
    }

    #[test]
    fn link_profile_agrees_with_bottleneck_bit_for_bit() {
        let t = two_groups();
        let all = t.devices();
        let p = t.link_profile(&all);
        assert_eq!(p.bottleneck_gbps.to_bits(), t.bottleneck_bw_gbps(&all).to_bits());
        assert_eq!(p.max_latency_s, 0.0, "clique paths have zero latency");
        // Single-device profile: free link.
        let solo = t.link_profile(&all[..1]);
        assert!(solo.bottleneck_gbps.is_infinite());
    }

    #[test]
    fn mask_helpers() {
        let t = two_groups();
        assert_eq!(t.mask_devices(0b01).len(), 2);
        assert_eq!(t.mask_devices(0b10).len(), 4);
        assert_eq!(t.mask_devices(0b11).len(), 6);
        assert!(t.mask_flops(0b01) > 0.0);
        assert!(t.mask_flops(0b11) > t.mask_flops(0b10));
    }

    #[test]
    fn derived_matrix_matches_group_routes() {
        let t = two_groups();
        assert_eq!(t.group_bw_gbps(0, 1), 25.0);
        assert_eq!(t.group_route(0, 1).bottleneck_gbps.to_bits(), 25.0f64.to_bits());
        assert!(t.validate().is_ok());
        // Structure features on a clique: no switches, 1-hop everywhere.
        assert_eq!(t.switch_degree(0), 0);
        assert_eq!(t.mean_group_hops(0), 1.0);
    }

    #[test]
    #[should_panic(expected = "symmetric")]
    fn asymmetric_matrix_rejected() {
        Topology::new(
            "bad",
            vec![
                DeviceGroup { gpu: T4, count: 1, intra_bw_gbps: 64.0 },
                DeviceGroup { gpu: T4, count: 1, intra_bw_gbps: 64.0 },
            ],
            vec![vec![0.0, 10.0], vec![20.0, 0.0]],
        );
    }

    #[test]
    fn try_new_reports_errors_as_values() {
        let bad = Topology::try_new(
            "bad",
            vec![DeviceGroup { gpu: T4, count: 0, intra_bw_gbps: 64.0 }],
            vec![vec![0.0]],
        );
        assert!(bad.unwrap_err().to_string().contains("positive intra bandwidth"));
        let shape = Topology::try_new(
            "bad",
            vec![DeviceGroup { gpu: T4, count: 1, intra_bw_gbps: 64.0 }],
            vec![],
        );
        assert!(shape.unwrap_err().to_string().contains("matrix shape"));
    }

    #[test]
    fn stale_derived_view_fails_validation() {
        let mut t = two_groups();
        t.inter_bw_gbps[0][1] = 5.0;
        t.inter_bw_gbps[1][0] = 5.0;
        let err = t.validate().unwrap_err().to_string();
        assert!(err.contains("stale derived view"), "{err}");

        // A mutated intra bandwidth is just as stale: the routes (and
        // simulated times) still use the constructed links.
        let mut t = two_groups();
        t.groups[0].intra_bw_gbps = 50.0;
        let err = t.validate().unwrap_err().to_string();
        assert!(err.contains("intra"), "{err}");

        // And so is a group pushed after construction.
        let mut t = two_groups();
        t.groups.push(DeviceGroup { gpu: T4, count: 1, intra_bw_gbps: 64.0 });
        t.inter_bw_gbps = vec![
            vec![0.0, 25.0, 10.0],
            vec![25.0, 0.0, 10.0],
            vec![10.0, 10.0, 0.0],
        ];
        let err = t.validate().unwrap_err().to_string();
        assert!(err.contains("group list mutated"), "{err}");
    }
}
