//! Device topology descriptions (paper §2.2, §5.2).
//!
//! A [`Topology`] is a set of [`DeviceGroup`]s — homogeneous GPUs with
//! uniform pairwise intra-group bandwidth, usually one multi-GPU machine —
//! plus a pairwise inter-group bandwidth matrix.  This is exactly the
//! "device graph" fed to the strategy creator.
//!
//! [`presets`] defines the paper's *testbed*, *cloud*, and homogeneous
//! evaluation clusters; [`generator`] samples random topologies with the
//! distribution of §5.2 (used for GNN training and the generalization
//! experiments of Tables 7/8).

pub mod generator;
pub mod presets;

pub use generator::random_topology;
pub use presets::{cloud, homogeneous, sfb_pair, testbed};

/// A GPU model with its effective compute rate and memory.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GpuType {
    pub name: &'static str,
    /// Peak fp32 TFLOPS.
    pub peak_tflops: f64,
    /// Fraction of peak achieved on typical DNN kernels (profiler
    /// calibration constant).
    pub efficiency: f64,
    pub mem_gb: f64,
}

impl GpuType {
    /// Effective FLOP/s for cost modeling.
    pub fn effective_flops(&self) -> f64 {
        self.peak_tflops * 1e12 * self.efficiency
    }
}

pub const V100_32G: GpuType =
    GpuType { name: "V100-32G", peak_tflops: 15.7, efficiency: 0.42, mem_gb: 32.0 };
pub const V100_16G: GpuType =
    GpuType { name: "V100-16G", peak_tflops: 15.7, efficiency: 0.42, mem_gb: 16.0 };
pub const GTX1080TI: GpuType =
    GpuType { name: "1080Ti", peak_tflops: 11.3, efficiency: 0.30, mem_gb: 11.0 };
pub const P100: GpuType =
    GpuType { name: "P100", peak_tflops: 9.3, efficiency: 0.35, mem_gb: 16.0 };
pub const T4: GpuType =
    GpuType { name: "T4", peak_tflops: 8.1, efficiency: 0.32, mem_gb: 16.0 };

/// The three representative GPU generations used by the random-topology
/// generator (§5.2: "a GPU type among 3 types").
pub const RANDOM_GPU_TYPES: [GpuType; 3] = [V100_16G, GTX1080TI, P100];

/// A group of homogeneous, uniformly-connected GPUs (typically one
/// machine).
#[derive(Clone, Debug)]
pub struct DeviceGroup {
    pub gpu: GpuType,
    pub count: usize,
    /// Pairwise bandwidth between GPUs in this group, Gbit/s
    /// (NVLink ~ 160+, PCIe ~ 64-128).
    pub intra_bw_gbps: f64,
}

/// A full device topology: groups + pairwise inter-group bandwidth.
#[derive(Clone, Debug)]
pub struct Topology {
    pub name: String,
    pub groups: Vec<DeviceGroup>,
    /// `inter_bw[i][j]` in Gbit/s; diagonal unused (use intra_bw).
    pub inter_bw_gbps: Vec<Vec<f64>>,
}

/// Globally unique device id: (group index, index within group).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DeviceId {
    pub group: usize,
    pub idx: usize,
}

impl Topology {
    pub fn new(name: impl Into<String>, groups: Vec<DeviceGroup>, inter: Vec<Vec<f64>>) -> Self {
        let t = Self { name: name.into(), groups, inter_bw_gbps: inter };
        t.validate();
        t
    }

    pub fn validate(&self) {
        let m = self.groups.len();
        assert_eq!(self.inter_bw_gbps.len(), m, "inter-bw matrix shape");
        for row in &self.inter_bw_gbps {
            assert_eq!(row.len(), m, "inter-bw matrix shape");
        }
        for i in 0..m {
            for j in 0..m {
                assert!(
                    (self.inter_bw_gbps[i][j] - self.inter_bw_gbps[j][i]).abs() < 1e-9,
                    "inter-bw must be symmetric"
                );
            }
        }
        for g in &self.groups {
            assert!(g.count > 0 && g.intra_bw_gbps > 0.0);
        }
    }

    pub fn num_groups(&self) -> usize {
        self.groups.len()
    }

    pub fn num_devices(&self) -> usize {
        self.groups.iter().map(|g| g.count).sum()
    }

    pub fn devices(&self) -> Vec<DeviceId> {
        let mut out = Vec::new();
        for (gi, g) in self.groups.iter().enumerate() {
            for di in 0..g.count {
                out.push(DeviceId { group: gi, idx: di });
            }
        }
        out
    }

    /// Bandwidth between two devices in Gbit/s.
    pub fn bw_gbps(&self, a: DeviceId, b: DeviceId) -> f64 {
        if a.group == b.group {
            if a.idx == b.idx {
                f64::INFINITY
            } else {
                self.groups[a.group].intra_bw_gbps
            }
        } else {
            self.inter_bw_gbps[a.group][b.group]
        }
    }

    /// Bytes/second between two devices.
    pub fn bw_bytes_per_s(&self, a: DeviceId, b: DeviceId) -> f64 {
        self.bw_gbps(a, b) * 1e9 / 8.0
    }

    /// The bottleneck (minimum) pairwise bandwidth among a device set,
    /// Gbit/s — `tau` in the SFB formulation.
    pub fn bottleneck_bw_gbps(&self, devs: &[DeviceId]) -> f64 {
        let mut min_bw = f64::INFINITY;
        for (i, &a) in devs.iter().enumerate() {
            for &b in &devs[i + 1..] {
                min_bw = min_bw.min(self.bw_gbps(a, b));
            }
        }
        min_bw
    }

    /// Total memory across a group, bytes.
    pub fn group_mem_bytes(&self, gi: usize) -> f64 {
        self.groups[gi].gpu.mem_gb * 1e9 * self.groups[gi].count as f64
    }

    /// Aggregate effective FLOP/s of a device subset given as a group
    /// bitmask (used to rank candidate placements).
    pub fn mask_flops(&self, mask: u16) -> f64 {
        (0..self.groups.len())
            .filter(|gi| mask & (1 << gi) != 0)
            .map(|gi| self.groups[gi].gpu.effective_flops() * self.groups[gi].count as f64)
            .sum()
    }

    /// Expand a group bitmask into concrete devices.
    pub fn mask_devices(&self, mask: u16) -> Vec<DeviceId> {
        let mut out = Vec::new();
        for (gi, g) in self.groups.iter().enumerate() {
            if mask & (1 << gi) != 0 {
                for di in 0..g.count {
                    out.push(DeviceId { group: gi, idx: di });
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_groups() -> Topology {
        Topology::new(
            "t",
            vec![
                DeviceGroup { gpu: V100_16G, count: 2, intra_bw_gbps: 128.0 },
                DeviceGroup { gpu: P100, count: 4, intra_bw_gbps: 64.0 },
            ],
            vec![vec![0.0, 25.0], vec![25.0, 0.0]],
        )
    }

    #[test]
    fn device_enumeration() {
        let t = two_groups();
        assert_eq!(t.num_devices(), 6);
        assert_eq!(t.devices().len(), 6);
        assert_eq!(t.devices()[2], DeviceId { group: 1, idx: 0 });
    }

    #[test]
    fn bandwidth_lookup() {
        let t = two_groups();
        let a = DeviceId { group: 0, idx: 0 };
        let b = DeviceId { group: 0, idx: 1 };
        let c = DeviceId { group: 1, idx: 0 };
        assert_eq!(t.bw_gbps(a, b), 128.0);
        assert_eq!(t.bw_gbps(a, c), 25.0);
        assert!(t.bw_gbps(a, a).is_infinite());
        assert_eq!(t.bw_bytes_per_s(a, c), 25.0e9 / 8.0);
    }

    #[test]
    fn bottleneck_bandwidth() {
        let t = two_groups();
        let all = t.devices();
        assert_eq!(t.bottleneck_bw_gbps(&all), 25.0);
        let intra = &all[2..6];
        assert_eq!(t.bottleneck_bw_gbps(intra), 64.0);
    }

    #[test]
    fn mask_helpers() {
        let t = two_groups();
        assert_eq!(t.mask_devices(0b01).len(), 2);
        assert_eq!(t.mask_devices(0b10).len(), 4);
        assert_eq!(t.mask_devices(0b11).len(), 6);
        assert!(t.mask_flops(0b01) > 0.0);
        assert!(t.mask_flops(0b11) > t.mask_flops(0b10));
    }

    #[test]
    #[should_panic(expected = "symmetric")]
    fn asymmetric_matrix_rejected() {
        Topology::new(
            "bad",
            vec![
                DeviceGroup { gpu: T4, count: 1, intra_bw_gbps: 64.0 },
                DeviceGroup { gpu: T4, count: 1, intra_bw_gbps: 64.0 },
            ],
            vec![vec![0.0, 10.0], vec![20.0, 0.0]],
        );
    }
}
