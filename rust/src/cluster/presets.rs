//! Cluster presets: the paper's evaluation clusters (§5.2) in flat
//! (clique) form, plus hierarchical routed clusters — an NVLink-island
//! machine pair and a multi-rack oversubscribed-ethernet pod — that
//! exercise the link-graph routing and contention model.

use super::linkgraph::{LinkGraph, LinkKind};
use super::{DeviceGroup, GpuType, Topology, GTX1080TI, P100, T4, V100_16G, V100_32G};

/// Build a symmetric inter-group matrix where every pair has `bw` Gbps.
fn uniform_inter(m: usize, bw: f64) -> Vec<Vec<f64>> {
    (0..m)
        .map(|i| (0..m).map(|j| if i == j { 0.0 } else { bw }).collect())
        .collect()
}

/// On-premise *testbed*: 7 machines —
/// 1x (4x V100-32G, NVLink), 4x (2x 1080Ti, PCIe), 2x (2x P100, PCIe),
/// all connected by a 100 Gbps switch.
pub fn testbed() -> Topology {
    let mut groups = vec![DeviceGroup {
        gpu: V100_32G,
        count: 4,
        intra_bw_gbps: 200.0, // NVLink
    }];
    for _ in 0..4 {
        groups.push(DeviceGroup { gpu: GTX1080TI, count: 2, intra_bw_gbps: 96.0 });
    }
    for _ in 0..2 {
        groups.push(DeviceGroup { gpu: P100, count: 2, intra_bw_gbps: 96.0 });
    }
    // 100 Gbps switch, but effective per-flow TCP/GRPC goodput is lower.
    Topology::new("testbed", groups, uniform_inter(7, 80.0))
}

/// Public-cloud cluster: 2x (8x V100-16G) + 4x (4x T4), 10 Gbps network.
pub fn cloud() -> Topology {
    let mut groups = vec![
        DeviceGroup { gpu: V100_16G, count: 8, intra_bw_gbps: 200.0 },
        DeviceGroup { gpu: V100_16G, count: 8, intra_bw_gbps: 200.0 },
    ];
    for _ in 0..4 {
        groups.push(DeviceGroup { gpu: T4, count: 4, intra_bw_gbps: 64.0 });
    }
    Topology::new("cloud", groups, uniform_inter(6, 10.0))
}

/// Homogeneous cluster for the Fig. 6 comparison: 2x V100 on one machine.
pub fn homogeneous() -> Topology {
    Topology::new(
        "homog-2xV100",
        vec![DeviceGroup { gpu: V100_16G, count: 2, intra_bw_gbps: 128.0 }],
        uniform_inter(1, 0.0),
    )
}

/// SFB study cluster (Table 5): two machines, one 1080Ti each,
/// commodity network.
pub fn sfb_pair() -> Topology {
    Topology::new(
        "sfb-2x1080Ti",
        vec![
            DeviceGroup { gpu: GTX1080TI, count: 1, intra_bw_gbps: 96.0 },
            DeviceGroup { gpu: GTX1080TI, count: 1, intra_bw_gbps: 96.0 },
        ],
        uniform_inter(2, 10.0),
    )
}

/// A single-GPU "topology" used for baseline profiling.
pub fn single(gpu: GpuType) -> Topology {
    Topology::new(
        format!("single-{}", gpu.name),
        vec![DeviceGroup { gpu, count: 1, intra_bw_gbps: 64.0 }],
        uniform_inter(1, 0.0),
    )
}

/// Hierarchical preset: two DGX-style machines.  Each machine is an
/// NVLink island — 4x V100-32G fully connected at 200 Gbps — whose GPUs
/// also hang off a PCIe host bridge (64 Gbps); the two host bridges meet
/// at a 25 Gbps ethernet switch.  Intra-island traffic routes over
/// NVLink; cross-machine traffic routes GPU → host bridge → ethernet →
/// host bridge → GPU and contends for the shared ethernet links.
pub fn nvlink_island() -> Topology {
    let groups: Vec<DeviceGroup> = (0..2)
        .map(|_| DeviceGroup { gpu: V100_32G, count: 4, intra_bw_gbps: 200.0 })
        .collect();
    let mut b = LinkGraph::builder();
    let devs = b.add_group_devices(&groups);
    let eth = b.add_switch(1);
    for island in &devs {
        let bridge = b.add_switch(0);
        for (i, &a) in island.iter().enumerate() {
            for &c in &island[i + 1..] {
                b.link_default(a, c, 200.0, LinkKind::NvLink);
            }
            b.link_default(a, bridge, 64.0, LinkKind::Pcie);
        }
        b.link_default(bridge, eth, 25.0, LinkKind::Ethernet);
    }
    Topology::routed("nvlink-island-2x4xV100", groups, b.build())
        .expect("nvlink_island preset must be valid")
}

/// Hierarchical preset: a 4-rack pod on oversubscribed ethernet.  Each
/// rack holds 3 machines (2x V100-16G, 4x T4, 2x P100 — all PCIe
/// fabrics at 64 Gbps); machines uplink to their top-of-rack switch at
/// 25 Gbps, and each ToR uplinks to the spine at 20 Gbps — a 3.75:1
/// oversubscription, so the per-flow cross-rack bottleneck (20 Gbps)
/// understates what concurrent cross-rack transfers actually get.  The
/// largest hierarchical preset; `benches/routing.rs` uses it.
pub fn multi_rack() -> Topology {
    const RACKS: usize = 4;
    const MACHINES: usize = 3;
    let machine_specs: [(GpuType, usize); MACHINES] =
        [(V100_16G, 2), (T4, 4), (P100, 2)];
    let mut groups = Vec::new();
    for _ in 0..RACKS {
        for (gpu, count) in machine_specs {
            groups.push(DeviceGroup { gpu, count, intra_bw_gbps: 64.0 });
        }
    }
    let mut b = LinkGraph::builder();
    let dev_nodes = b.add_group_devices(&groups);
    let spine = b.add_switch(2);
    for rack in 0..RACKS {
        let tor = b.add_switch(1);
        b.link_default(tor, spine, 20.0, LinkKind::Ethernet);
        for machine in 0..MACHINES {
            let bridge = b.add_switch(0);
            b.link_default(bridge, tor, 25.0, LinkKind::Ethernet);
            for &d in &dev_nodes[rack * MACHINES + machine] {
                b.link_default(d, bridge, 64.0, LinkKind::Pcie);
            }
        }
    }
    Topology::routed("multi-rack-4x3", groups, b.build())
        .expect("multi_rack preset must be valid")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::DeviceId;

    #[test]
    fn testbed_matches_paper() {
        let t = testbed();
        assert_eq!(t.num_groups(), 7);
        assert_eq!(t.num_devices(), 4 + 8 + 4);
        assert_eq!(t.groups[0].gpu.name, "V100-32G");
        assert!(t.groups[0].intra_bw_gbps > t.groups[1].intra_bw_gbps); // NVLink
    }

    #[test]
    fn cloud_matches_paper() {
        let t = cloud();
        assert_eq!(t.num_groups(), 6);
        assert_eq!(t.num_devices(), 32);
        assert_eq!(t.inter_bw_gbps[0][1], 10.0);
    }

    #[test]
    fn presets_validate() {
        for t in [
            testbed(),
            cloud(),
            homogeneous(),
            sfb_pair(),
            single(P100),
            nvlink_island(),
            multi_rack(),
        ] {
            t.validate().unwrap();
            assert!(t.num_devices() >= 1);
        }
    }

    #[test]
    fn nvlink_island_routes_hierarchically() {
        let t = nvlink_island();
        assert!(t.is_routed());
        assert_eq!(t.num_groups(), 2);
        assert_eq!(t.num_devices(), 8);
        // Intra-island: direct NVLink.
        let a = DeviceId { group: 0, idx: 0 };
        let b = DeviceId { group: 0, idx: 1 };
        assert_eq!(t.bw_gbps(a, b), 200.0);
        assert_eq!(t.route(a, b).hops(), 1);
        // Cross-island: 4 hops through both host bridges + ethernet,
        // ethernet-bottlenecked, with accumulated latency.
        let c = DeviceId { group: 1, idx: 0 };
        assert_eq!(t.bw_gbps(a, c), 25.0);
        assert_eq!(t.route(a, c).hops(), 4);
        assert!(t.route_latency_s(a, c) > 0.0);
        // Derived matrix view matches.
        assert_eq!(t.inter_bw_gbps[0][1], 25.0);
        // Structure features see the switches.
        assert!(t.switch_degree(0) >= 5);
    }

    #[test]
    fn multi_rack_is_oversubscribed() {
        let t = multi_rack();
        assert!(t.is_routed());
        assert_eq!(t.num_groups(), 12);
        assert_eq!(t.num_devices(), 32);
        // In-rack cross-machine: ToR-bottlenecked at 25 Gbps, 4 hops.
        assert_eq!(t.group_bw_gbps(0, 1), 25.0);
        assert_eq!(t.group_route(0, 1).hops(), 4);
        // Cross-rack: spine-bottlenecked at 20 Gbps, 6 hops.
        assert_eq!(t.group_bw_gbps(0, 3), 20.0);
        assert_eq!(t.group_route(0, 3).hops(), 6);
        // Cross-rack routes share the rack uplinks: both groups 0 and 1
        // reach rack 1 over the same ToR-spine link.
        let r0 = t.group_route(0, 3);
        let r1 = t.group_route(1, 3);
        assert!(
            r0.links.iter().any(|l| r1.links.contains(l)),
            "cross-rack routes must share the oversubscribed uplink"
        );
    }
}
