//! Cluster presets from the paper's evaluation (§5.2).

use super::{DeviceGroup, GpuType, Topology, GTX1080TI, P100, T4, V100_16G, V100_32G};

/// Build a symmetric inter-group matrix where every pair has `bw` Gbps.
fn uniform_inter(m: usize, bw: f64) -> Vec<Vec<f64>> {
    (0..m)
        .map(|i| (0..m).map(|j| if i == j { 0.0 } else { bw }).collect())
        .collect()
}

/// On-premise *testbed*: 7 machines —
/// 1x (4x V100-32G, NVLink), 4x (2x 1080Ti, PCIe), 2x (2x P100, PCIe),
/// all connected by a 100 Gbps switch.
pub fn testbed() -> Topology {
    let mut groups = vec![DeviceGroup {
        gpu: V100_32G,
        count: 4,
        intra_bw_gbps: 200.0, // NVLink
    }];
    for _ in 0..4 {
        groups.push(DeviceGroup { gpu: GTX1080TI, count: 2, intra_bw_gbps: 96.0 });
    }
    for _ in 0..2 {
        groups.push(DeviceGroup { gpu: P100, count: 2, intra_bw_gbps: 96.0 });
    }
    // 100 Gbps switch, but effective per-flow TCP/GRPC goodput is lower.
    Topology::new("testbed", groups, uniform_inter(7, 80.0))
}

/// Public-cloud cluster: 2x (8x V100-16G) + 4x (4x T4), 10 Gbps network.
pub fn cloud() -> Topology {
    let mut groups = vec![
        DeviceGroup { gpu: V100_16G, count: 8, intra_bw_gbps: 200.0 },
        DeviceGroup { gpu: V100_16G, count: 8, intra_bw_gbps: 200.0 },
    ];
    for _ in 0..4 {
        groups.push(DeviceGroup { gpu: T4, count: 4, intra_bw_gbps: 64.0 });
    }
    Topology::new("cloud", groups, uniform_inter(6, 10.0))
}

/// Homogeneous cluster for the Fig. 6 comparison: 2x V100 on one machine.
pub fn homogeneous() -> Topology {
    Topology::new(
        "homog-2xV100",
        vec![DeviceGroup { gpu: V100_16G, count: 2, intra_bw_gbps: 128.0 }],
        uniform_inter(1, 0.0),
    )
}

/// SFB study cluster (Table 5): two machines, one 1080Ti each,
/// commodity network.
pub fn sfb_pair() -> Topology {
    Topology::new(
        "sfb-2x1080Ti",
        vec![
            DeviceGroup { gpu: GTX1080TI, count: 1, intra_bw_gbps: 96.0 },
            DeviceGroup { gpu: GTX1080TI, count: 1, intra_bw_gbps: 96.0 },
        ],
        uniform_inter(2, 10.0),
    )
}

/// A single-GPU "topology" used for baseline profiling.
pub fn single(gpu: GpuType) -> Topology {
    Topology::new(
        format!("single-{}", gpu.name),
        vec![DeviceGroup { gpu, count: 1, intra_bw_gbps: 64.0 }],
        uniform_inter(1, 0.0),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn testbed_matches_paper() {
        let t = testbed();
        assert_eq!(t.num_groups(), 7);
        assert_eq!(t.num_devices(), 4 + 8 + 4);
        assert_eq!(t.groups[0].gpu.name, "V100-32G");
        assert!(t.groups[0].intra_bw_gbps > t.groups[1].intra_bw_gbps); // NVLink
    }

    #[test]
    fn cloud_matches_paper() {
        let t = cloud();
        assert_eq!(t.num_groups(), 6);
        assert_eq!(t.num_devices(), 32);
        assert_eq!(t.inter_bw_gbps[0][1], 10.0);
    }

    #[test]
    fn presets_validate() {
        for t in [testbed(), cloud(), homogeneous(), sfb_pair(), single(P100)] {
            t.validate();
            assert!(t.num_devices() >= 1);
        }
    }
}
