//! Shared residual-topology construction: rebuild a [`Topology`]
//! *without* a set of devices (and, for routed topologies, without or
//! with degraded links), through the ordinary constructors so every
//! invariant — route coverage, uniform group fabrics, the derived
//! matrix view — is re-checked from scratch.
//!
//! Two subsystems remove hardware from a topology and must agree on
//! the result bit for bit:
//!
//! * [`crate::cluster::faults`] — hardware *broke*: a [`FaultSpec`]
//!   (kill/sever/degrade) is validated and lowered onto a
//!   [`ResidualSpec`] here.
//! * [`crate::fleet`] — hardware is *taken*: the lease layer removes
//!   devices held by (or not granted to) running jobs to materialize
//!   free-capacity views and per-job slice topologies.
//!
//! Keeping one builder means fault repair and leasing cannot drift
//! apart: both see the same dense renumbering (survivors keep their
//! relative `(group, idx)` order, empty groups drop out), the same
//! link rebuild (switches always survive; a link survives iff both
//! endpoints do), and the same [`Residual`] bookkeeping
//! (`group_map`, [`Residual::remap_mask`]).
//!
//! Determinism contract: node and link iteration order of the source
//! graph is preserved, so a [`build`] that removes *nothing*
//! reproduces the base topology's structural fingerprint exactly
//! (names are display-only and excluded from fingerprints) — the
//! lease/release restoration property in `rust/tests/fleet.rs` rests
//! on this.
//!
//! [`FaultSpec`]: crate::cluster::faults::FaultSpec

use super::linkgraph::NodeKind;
use super::{DeviceGroup, DeviceId, Topology};
use crate::util::error::Result;

/// What to remove or rescale when rebuilding `topo`: per-flat-device
/// removal flags plus per-link sever/degrade vectors.  Built against
/// one topology; applying it to another is a length-mismatch error.
#[derive(Clone, Debug)]
pub struct ResidualSpec {
    /// One flag per flat device index; `true` removes the device and
    /// every link incident to it.
    pub dead: Vec<bool>,
    /// One flag per link id; `true` removes the link (routed
    /// topologies only — a flat clique cannot represent a missing
    /// wire).
    pub severed: Vec<bool>,
    /// One factor per link id in `(0, 1]`; `1.0` leaves the link
    /// untouched.
    pub degrade: Vec<f64>,
}

impl ResidualSpec {
    /// A spec that removes and rescales nothing.
    pub fn clean(topo: &Topology) -> Self {
        let num_links = topo.link_graph().num_links();
        Self {
            dead: vec![false; topo.num_devices()],
            severed: vec![false; num_links],
            degrade: vec![1.0; num_links],
        }
    }

    /// A pure device-removal spec: `remove[flat] == true` drops that
    /// device, all links survive at full bandwidth.
    pub fn remove_devices(topo: &Topology, remove: &[bool]) -> Self {
        let mut spec = Self::clean(topo);
        spec.dead.copy_from_slice(remove);
        spec
    }
}

/// The validated outcome of a residual rebuild ([`build`],
/// [`FaultSpec::apply`]): the shrunken topology plus the bookkeeping
/// that plan repair and the fleet lease layer need to translate
/// old-coordinate placements onto the new cluster.
///
/// [`FaultSpec::apply`]: crate::cluster::faults::FaultSpec::apply
#[derive(Clone, Debug)]
pub struct Residual {
    /// The rebuilt topology, re-validated from scratch.
    pub topology: Topology,
    /// Old group index → new group index; `None` when every device of
    /// the old group was removed.
    pub group_map: Vec<Option<usize>>,
    /// The removed devices, in old coordinates, sorted.
    pub dead_devices: Vec<DeviceId>,
}

impl Residual {
    /// Translate an old-coordinate placement bitmask into residual
    /// coordinates.  Bits of groups that vanished entirely are
    /// dropped; a result of 0 means nothing of the placement
    /// survived.
    pub fn remap_mask(&self, mask: u16) -> u16 {
        let mut out = 0u16;
        for (old, new) in self.group_map.iter().enumerate() {
            if mask & (1 << old) != 0 {
                if let Some(n) = new {
                    out |= 1 << n;
                }
            }
        }
        out
    }
}

/// Rebuild `topo` without the hardware `spec` removes, as `name`.
/// Errors when the spec removes every device or when the remainder is
/// disconnected (the route table's coverage error) — a planner must
/// never receive a topology that would silently place work onto dead
/// or unreachable hardware.
pub fn build(topo: &Topology, name: &str, spec: &ResidualSpec) -> Result<Residual> {
    let num_links = topo.link_graph().num_links();
    crate::ensure!(
        spec.dead.len() == topo.num_devices()
            && spec.severed.len() == num_links
            && spec.degrade.len() == num_links,
        "residual spec was built for a different topology than `{}`",
        topo.name
    );

    // Removed devices in flat order (flat index is monotone in
    // `(group, idx)`, so this comes out sorted).
    let mut dead_devices: Vec<DeviceId> = Vec::new();
    let mut flat = 0usize;
    for (gi, g) in topo.groups.iter().enumerate() {
        for idx in 0..g.count {
            if spec.dead[flat] {
                dead_devices.push(DeviceId { group: gi, idx });
            }
            flat += 1;
        }
    }

    // Survivor counts and the old-group -> new-group mapping.
    let mut survivors: Vec<usize> = topo.groups.iter().map(|g| g.count).collect();
    for d in &dead_devices {
        survivors[d.group] -= 1;
    }
    crate::ensure!(
        survivors.iter().any(|&c| c > 0),
        "removals kill every device of `{}` — nothing left to plan on",
        topo.name
    );
    let mut group_map: Vec<Option<usize>> = Vec::with_capacity(topo.num_groups());
    let mut next = 0;
    for &c in &survivors {
        if c > 0 {
            group_map.push(Some(next));
            next += 1;
        } else {
            group_map.push(None);
        }
    }

    let topology = if topo.is_routed() {
        build_routed(topo, name, spec, &survivors, &group_map)?
    } else {
        build_flat(topo, name, spec, &survivors)?
    };
    Ok(Residual { topology, group_map, dead_devices })
}

/// Routed rebuild: drop removed devices (and their incident links) and
/// severed links, scale degraded links, keep every switch, renumber
/// the survivors densely in the original `(group, idx)` order.
fn build_routed(
    topo: &Topology,
    name: &str,
    spec: &ResidualSpec,
    survivors: &[usize],
    group_map: &[Option<usize>],
) -> Result<Topology> {
    let graph = topo.link_graph();
    let mut b = super::linkgraph::LinkGraphBuilder::default();
    let mut node_map = vec![usize::MAX; graph.num_nodes()];
    let mut next_idx = vec![0usize; topo.num_groups()];
    for (nid, node) in graph.nodes().iter().enumerate() {
        match *node {
            NodeKind::Device(d) => {
                if spec.dead[topo.device_flat_index(d)] {
                    continue;
                }
                let new_group =
                    group_map[d.group].expect("surviving device in a group with no survivors");
                let idx = next_idx[d.group];
                next_idx[d.group] += 1;
                node_map[nid] = b.add_device(DeviceId { group: new_group, idx });
            }
            NodeKind::Switch { level } => {
                node_map[nid] = b.add_switch(level);
            }
        }
    }
    for (lid, l) in graph.links().iter().enumerate() {
        if spec.severed[lid] || node_map[l.a] == usize::MAX || node_map[l.b] == usize::MAX {
            continue;
        }
        b.link(node_map[l.a], node_map[l.b], l.bw_gbps * spec.degrade[lid], l.latency_s, l.kind);
    }
    let groups: Vec<DeviceGroup> = topo
        .groups
        .iter()
        .zip(survivors)
        .filter(|(_, &c)| c > 0)
        .map(|(g, &c)| DeviceGroup { gpu: g.gpu, count: c, intra_bw_gbps: g.intra_bw_gbps })
        .collect();
    Topology::routed(name, groups, b.build())
}

/// Flat rebuild: link effects act on the fabric the link belongs to
/// (the matrix has no individual wires), removals shrink group counts.
fn build_flat(
    topo: &Topology,
    name: &str,
    spec: &ResidualSpec,
    survivors: &[usize],
) -> Result<Topology> {
    let graph = topo.link_graph();
    let mut inter = topo.inter_bw_gbps.clone();
    let mut intra: Vec<f64> = topo.groups.iter().map(|g| g.intra_bw_gbps).collect();
    for (lid, l) in graph.links().iter().enumerate() {
        if spec.severed[lid] {
            crate::bail!(
                "flat topology `{}` has uniform group fabrics; severing clique link \
                 {lid} is not representable — kill a device or degrade the fabric \
                 instead",
                topo.name
            );
        }
        if spec.degrade[lid] == 1.0 {
            continue;
        }
        let (da, db) = match (graph.nodes()[l.a], graph.nodes()[l.b]) {
            (NodeKind::Device(a), NodeKind::Device(b)) => (a, b),
            _ => unreachable!("clique graphs hold only device nodes"),
        };
        if da.group == db.group {
            intra[da.group] *= spec.degrade[lid];
        } else {
            inter[da.group][db.group] *= spec.degrade[lid];
            inter[db.group][da.group] *= spec.degrade[lid];
        }
    }
    let groups: Vec<DeviceGroup> = topo
        .groups
        .iter()
        .zip(survivors)
        .zip(&intra)
        .filter(|((_, &c), _)| c > 0)
        .map(|((g, &c), &bw)| DeviceGroup { gpu: g.gpu, count: c, intra_bw_gbps: bw })
        .collect();
    let keep: Vec<usize> = (0..topo.num_groups()).filter(|&gi| survivors[gi] > 0).collect();
    let inter: Vec<Vec<f64>> =
        keep.iter().map(|&i| keep.iter().map(|&j| inter[i][j]).collect()).collect();
    Topology::try_new(name, groups, inter)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::fingerprint;
    use crate::cluster::presets::{multi_rack, nvlink_island, testbed};

    #[test]
    fn empty_spec_reproduces_the_base_fingerprint() {
        // The restoration property the fleet lease layer depends on:
        // rebuilding with nothing removed is structurally identical to
        // the base, for both construction paths.
        for topo in [testbed(), nvlink_island(), multi_rack()] {
            let r = build(&topo, "copy", &ResidualSpec::clean(&topo)).unwrap();
            assert!(r.dead_devices.is_empty());
            assert!(r.group_map.iter().enumerate().all(|(i, m)| *m == Some(i)));
            assert_eq!(
                fingerprint::topology(&r.topology),
                fingerprint::topology(&topo),
                "no-removal rebuild of `{}` must be bit-identical",
                topo.name
            );
        }
    }

    #[test]
    fn device_removal_renumbers_densely() {
        let t = multi_rack();
        let mut remove = vec![false; t.num_devices()];
        // Remove all of group 1 (the first T4 machine) and one V100.
        remove[t.device_flat_index(DeviceId { group: 0, idx: 1 })] = true;
        for idx in 0..t.groups[1].count {
            remove[t.device_flat_index(DeviceId { group: 1, idx })] = true;
        }
        let r = build(&t, "shrunk", &ResidualSpec::remove_devices(&t, &remove)).unwrap();
        assert_eq!(r.topology.num_groups(), 11);
        assert_eq!(r.topology.num_devices(), t.num_devices() - 5);
        assert_eq!(r.group_map[0], Some(0));
        assert_eq!(r.group_map[1], None);
        assert_eq!(r.group_map[2], Some(1));
        assert_eq!(r.remap_mask(0b111), 0b11);
        assert_eq!(r.dead_devices.len(), 5);
        r.topology.validate().unwrap();
    }

    #[test]
    fn mismatched_spec_is_rejected() {
        let t = testbed();
        let err = build(&t, "x", &ResidualSpec::clean(&multi_rack())).unwrap_err();
        assert!(err.to_string().contains("different topology"), "{err}");
    }

    #[test]
    fn removing_everything_is_an_error() {
        let t = testbed();
        let remove = vec![true; t.num_devices()];
        let err =
            build(&t, "x", &ResidualSpec::remove_devices(&t, &remove)).unwrap_err().to_string();
        assert!(err.contains("kill every device"), "{err}");
    }
}
