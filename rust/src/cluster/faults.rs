//! Failure injection: typed faults applied to a [`Topology`], yielding a
//! validated *residual* topology with re-derived routes.
//!
//! TAG assumes a healthy cluster, but the heterogeneous fleets it
//! targets are exactly where links saturate, NICs flap and machines get
//! preempted.  This module is the substrate of the fault-tolerance
//! layer: a [`FaultSpec`] describes what broke (kill a device, sever a
//! link, degrade a link's bandwidth), [`FaultSpec::apply`] rebuilds the
//! topology *without* the broken hardware — through the ordinary
//! constructors, so every invariant (route coverage, uniform group
//! fabrics, derived matrix view) is re-checked — and the resulting
//! [`Residual`] carries the old-group → new-group mapping that plan
//! repair uses to transplant the surviving portion of an old strategy.
//!
//! Unreachable hardware is an **explicit error**, never a silent
//! exclusion: severing the only uplink of a rack fails with the route
//! table's disconnection error instead of producing a topology that
//! plans traffic into a void.
//!
//! Semantics per construction path:
//!
//! * **Routed topologies** (switched link graphs): faults act on the
//!   physical links themselves.  Killed devices disappear along with
//!   their incident links; severed links disappear; degraded links keep
//!   their latency and kind at `factor ×` bandwidth.  Surviving devices
//!   are renumbered densely in the original `(group, idx)` order, and
//!   the route table and inter-group matrix are re-derived from what is
//!   left.
//! * **Flat topologies** (group list + pairwise matrix): the matrix has
//!   no individual wires, so link faults act on the *fabric* the
//!   targeted link belongs to — degrading an inter-group link scales
//!   that group pair's matrix entry, degrading an intra-group link
//!   scales the group's uniform intra bandwidth.  Severing a single
//!   clique link would make the fabric non-uniform, which the flat form
//!   cannot represent; it is rejected with an explanatory error (kill
//!   the device or degrade the fabric instead).
//!
//! [`generate_trace`] draws deterministic seeded fault specs for tests
//! and benches: every returned spec is guaranteed to apply successfully
//! to the topology it was drawn for.

use super::residual::{self, ResidualSpec};
use super::{DeviceId, Topology};
use crate::util::error::Result;
use crate::util::Rng;

pub use super::residual::Residual;

/// One injected failure.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fault {
    /// Remove a device (machine preempted, GPU dropped off the bus).
    KillDevice(DeviceId),
    /// Remove a link of [`Topology::link_graph`] by link id (NIC died,
    /// cable pulled).
    SeverLink(usize),
    /// Scale a link's bandwidth by `factor` in `(0, 1)` (congestion,
    /// flapping retrains, failed lane).
    DegradeLink { link: usize, factor: f64 },
}

/// An ordered set of faults, parsed from / encoded to the compact
/// `kill:G.I;sever:L;degrade:L*F` grammar shared by the CLI
/// (`tag repair --faults ...`) and the `POST /repair` wire request.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultSpec {
    pub faults: Vec<Fault>,
}

impl FaultSpec {
    /// Parse the `;`-separated fault grammar: `kill:G.I` (device `I` of
    /// group `G`), `sever:L` (link id `L`), `degrade:L*F` (link id `L`
    /// at `F ×` bandwidth, `0 < F < 1`).  Empty segments are ignored;
    /// an entirely empty spec is an error.
    pub fn parse(text: &str) -> Result<Self> {
        let mut faults = Vec::new();
        for part in text.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            if let Some(rest) = part.strip_prefix("kill:") {
                let (g, i) = rest.split_once('.').ok_or_else(|| {
                    crate::util::error::Error::msg(format!(
                        "bad kill fault `{part}`: expected kill:GROUP.INDEX"
                    ))
                })?;
                let group: usize = g
                    .parse()
                    .map_err(|_| crate::util::error::Error::msg(format!("bad group in `{part}`")))?;
                let idx: usize = i
                    .parse()
                    .map_err(|_| crate::util::error::Error::msg(format!("bad index in `{part}`")))?;
                faults.push(Fault::KillDevice(DeviceId { group, idx }));
            } else if let Some(rest) = part.strip_prefix("sever:") {
                let link: usize = rest.parse().map_err(|_| {
                    crate::util::error::Error::msg(format!("bad link id in `{part}`"))
                })?;
                faults.push(Fault::SeverLink(link));
            } else if let Some(rest) = part.strip_prefix("degrade:") {
                let (l, f) = rest.split_once('*').ok_or_else(|| {
                    crate::util::error::Error::msg(format!(
                        "bad degrade fault `{part}`: expected degrade:LINK*FACTOR"
                    ))
                })?;
                let link: usize = l
                    .parse()
                    .map_err(|_| crate::util::error::Error::msg(format!("bad link id in `{part}`")))?;
                let factor: f64 = f
                    .parse()
                    .map_err(|_| crate::util::error::Error::msg(format!("bad factor in `{part}`")))?;
                crate::ensure!(
                    factor > 0.0 && factor < 1.0,
                    "degrade factor must be in (0, 1), got {factor}"
                );
                faults.push(Fault::DegradeLink { link, factor });
            } else {
                crate::bail!(
                    "unknown fault `{part}` (expected kill:G.I, sever:L or degrade:L*F)"
                );
            }
        }
        crate::ensure!(!faults.is_empty(), "empty fault spec");
        Ok(Self { faults })
    }

    /// Render back to the parse grammar (`parse(encode(s)) == s`).
    pub fn encode(&self) -> String {
        self.faults
            .iter()
            .map(|f| match f {
                Fault::KillDevice(d) => format!("kill:{}.{}", d.group, d.idx),
                Fault::SeverLink(l) => format!("sever:{l}"),
                Fault::DegradeLink { link, factor } => format!("degrade:{link}*{factor}"),
            })
            .collect::<Vec<_>>()
            .join(";")
    }

    /// Apply every fault to `topo`, rebuilding the topology through its
    /// ordinary constructors so all invariants are re-validated.  Errors
    /// when a fault targets hardware the topology does not have, when
    /// the faults kill every device, or when the residual cluster is
    /// disconnected (severed the only path between surviving devices) —
    /// the planner must never receive a topology it would silently plan
    /// dead or unreachable hardware onto.
    pub fn apply(&self, topo: &Topology) -> Result<Residual> {
        crate::ensure!(!self.faults.is_empty(), "empty fault spec");
        let graph = topo.link_graph();
        let num_links = graph.num_links();

        // Validate targets and lower the faults onto a residual spec;
        // the rebuild itself is the shared `cluster::residual` path
        // (also used by the fleet lease layer).
        let mut spec = ResidualSpec::clean(topo);
        let mut link_touched = vec![false; num_links];
        for f in &self.faults {
            match *f {
                Fault::KillDevice(d) => {
                    crate::ensure!(
                        d.group < topo.num_groups() && d.idx < topo.groups[d.group].count,
                        "kill target ({}, {}) is not a device of `{}`",
                        d.group,
                        d.idx,
                        topo.name
                    );
                    let flat = topo.device_flat_index(d);
                    let twice = spec.dead[flat];
                    crate::ensure!(!twice, "device ({}, {}) killed twice", d.group, d.idx);
                    spec.dead[flat] = true;
                }
                Fault::SeverLink(l) => {
                    crate::ensure!(l < num_links, "link {l} is not a link of `{}`", topo.name);
                    crate::ensure!(!link_touched[l], "link {l} targeted by two faults");
                    link_touched[l] = true;
                    spec.severed[l] = true;
                }
                Fault::DegradeLink { link, factor } => {
                    crate::ensure!(link < num_links, "link {link} is not a link of `{}`", topo.name);
                    crate::ensure!(!link_touched[link], "link {link} targeted by two faults");
                    crate::ensure!(
                        factor > 0.0 && factor < 1.0,
                        "degrade factor must be in (0, 1), got {factor}"
                    );
                    link_touched[link] = true;
                    spec.degrade[link] = factor;
                }
            }
        }

        let name = format!("{}+{}", topo.name, self.encode());
        residual::build(topo, &name, &spec)
    }
}

/// Draw `n` deterministic fault specs for `topo`: each spec holds 1..=3
/// faults and is guaranteed to apply successfully (draws that would not
/// — severing the only uplink, killing the last device — are discarded
/// and redrawn, boundedly).  Fixed `(topo, seed)` reproduces the trace
/// exactly; tests and benches lean on that.
pub fn generate_trace(topo: &Topology, seed: u64, n: usize) -> Vec<FaultSpec> {
    let mut rng = Rng::new(seed);
    let graph = topo.link_graph();
    let mut out = Vec::with_capacity(n);
    let mut attempts = 0usize;
    while out.len() < n && attempts < n.max(1) * 64 {
        attempts += 1;
        let count = rng.range(1, 3);
        let mut spec = FaultSpec::default();
        for _ in 0..count {
            // Flat topologies cannot represent severed clique links;
            // draw only kills and fabric degradations for them.
            let kinds = if topo.is_routed() { 3 } else { 2 };
            let fault = match rng.below(kinds) {
                0 => {
                    let group = rng.below(topo.num_groups());
                    let idx = rng.below(topo.groups[group].count);
                    Fault::KillDevice(DeviceId { group, idx })
                }
                1 => Fault::DegradeLink {
                    link: rng.below(graph.num_links()),
                    factor: rng.range(1, 9) as f64 / 10.0,
                },
                _ => Fault::SeverLink(rng.below(graph.num_links())),
            };
            spec.faults.push(fault);
        }
        if spec.apply(topo).is_ok() {
            out.push(spec);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::linkgraph::NodeKind;
    use crate::cluster::presets::{multi_rack, sfb_pair, testbed};

    #[test]
    fn spec_grammar_round_trips() {
        let text = "kill:2.0;sever:5;degrade:3*0.5";
        let spec = FaultSpec::parse(text).unwrap();
        assert_eq!(spec.faults.len(), 3);
        assert_eq!(spec.encode(), text);
        assert_eq!(FaultSpec::parse(&spec.encode()).unwrap(), spec);
        // Whitespace and empty segments are tolerated.
        let spec2 = FaultSpec::parse(" kill:2.0 ; ; sever:5;degrade:3*0.5").unwrap();
        assert_eq!(spec2, spec);
    }

    #[test]
    fn spec_grammar_rejects_malformed_input() {
        assert!(FaultSpec::parse("").is_err());
        assert!(FaultSpec::parse("explode:1").is_err());
        assert!(FaultSpec::parse("kill:3").is_err()); // missing .idx
        assert!(FaultSpec::parse("degrade:3*1.5").is_err()); // factor >= 1
        assert!(FaultSpec::parse("degrade:3*0").is_err()); // factor <= 0
        assert!(FaultSpec::parse("sever:x").is_err());
    }

    #[test]
    fn kill_shrinks_a_flat_group() {
        let t = testbed();
        let r = FaultSpec::parse("kill:0.0").unwrap().apply(&t).unwrap();
        assert_eq!(r.topology.num_groups(), 7);
        assert_eq!(r.topology.groups[0].count, 3);
        assert_eq!(r.topology.num_devices(), t.num_devices() - 1);
        assert_eq!(r.dead_devices, vec![DeviceId { group: 0, idx: 0 }]);
        assert!(r.group_map.iter().all(|m| m.is_some()));
        r.topology.validate().unwrap();
    }

    #[test]
    fn killing_a_whole_group_drops_it_and_remaps_masks() {
        let t = sfb_pair();
        let r = FaultSpec::parse("kill:0.0").unwrap().apply(&t).unwrap();
        assert_eq!(r.topology.num_groups(), 1);
        assert_eq!(r.group_map, vec![None, Some(0)]);
        assert_eq!(r.remap_mask(0b11), 0b1);
        assert_eq!(r.remap_mask(0b01), 0); // nothing survived
        r.topology.validate().unwrap();
    }

    #[test]
    fn killing_everything_is_an_error() {
        let t = sfb_pair();
        let err =
            FaultSpec::parse("kill:0.0;kill:1.0").unwrap().apply(&t).unwrap_err().to_string();
        assert!(err.contains("kill every device"), "{err}");
        let dup = FaultSpec::parse("kill:0.0;kill:0.0").unwrap().apply(&t).unwrap_err();
        assert!(dup.to_string().contains("twice"));
        let oob = FaultSpec::parse("kill:9.0").unwrap().apply(&t).unwrap_err();
        assert!(oob.to_string().contains("not a device"));
    }

    #[test]
    fn degrading_a_flat_link_scales_the_fabric() {
        let t = testbed();
        let g = t.link_graph();
        // Find one inter-group and one intra-group clique link.
        let inter = g
            .links()
            .iter()
            .position(|l| match (g.nodes()[l.a], g.nodes()[l.b]) {
                (NodeKind::Device(a), NodeKind::Device(b)) => a.group != b.group,
                _ => false,
            })
            .unwrap();
        let r = FaultSpec::parse(&format!("degrade:{inter}*0.5")).unwrap().apply(&t).unwrap();
        let (da, db) = match (g.nodes()[g.links()[inter].a], g.nodes()[g.links()[inter].b]) {
            (NodeKind::Device(a), NodeKind::Device(b)) => (a, b),
            _ => unreachable!(),
        };
        assert_eq!(
            r.topology.inter_bw_gbps[da.group][db.group],
            t.inter_bw_gbps[da.group][db.group] * 0.5
        );
        r.topology.validate().unwrap();

        let intra = g
            .links()
            .iter()
            .position(|l| match (g.nodes()[l.a], g.nodes()[l.b]) {
                (NodeKind::Device(a), NodeKind::Device(b)) => a.group == b.group,
                _ => false,
            })
            .unwrap();
        let (da, _) = match (g.nodes()[g.links()[intra].a], g.nodes()[g.links()[intra].b]) {
            (NodeKind::Device(a), NodeKind::Device(b)) => (a, b),
            _ => unreachable!(),
        };
        let r = FaultSpec::parse(&format!("degrade:{intra}*0.5")).unwrap().apply(&t).unwrap();
        assert_eq!(
            r.topology.groups[da.group].intra_bw_gbps,
            t.groups[da.group].intra_bw_gbps * 0.5
        );
        r.topology.validate().unwrap();
    }

    #[test]
    fn severing_a_flat_link_is_rejected_with_guidance() {
        let t = testbed();
        let err = FaultSpec::parse("sever:0").unwrap().apply(&t).unwrap_err().to_string();
        assert!(err.contains("not representable"), "{err}");
    }

    #[test]
    fn routed_kill_renumbers_and_revalidates() {
        let t = multi_rack();
        let r = FaultSpec::parse("kill:0.0").unwrap().apply(&t).unwrap();
        assert_eq!(r.topology.num_groups(), 12);
        assert_eq!(r.topology.groups[0].count, 1);
        assert_eq!(r.topology.num_devices(), 31);
        r.topology.validate().unwrap();
        // Surviving cross-rack routes are unchanged by the kill.
        assert_eq!(r.topology.group_bw_gbps(0, 3), t.group_bw_gbps(0, 3));
    }

    #[test]
    fn severing_the_only_uplink_is_a_disconnection_error() {
        let t = multi_rack();
        let g = t.link_graph();
        // ToR-spine uplinks are the only 20 Gbps links.
        let uplink = g.links().iter().position(|l| l.bw_gbps == 20.0).unwrap();
        let err =
            FaultSpec::parse(&format!("sever:{uplink}")).unwrap().apply(&t).unwrap_err();
        assert!(err.to_string().contains("disconnected"), "{err}");
    }

    #[test]
    fn degrading_a_routed_uplink_halves_the_cross_rack_bandwidth() {
        let t = multi_rack();
        let g = t.link_graph();
        let uplink = g.links().iter().position(|l| l.bw_gbps == 20.0).unwrap();
        let r =
            FaultSpec::parse(&format!("degrade:{uplink}*0.5")).unwrap().apply(&t).unwrap();
        // Rack 0's spine uplink at 10 Gbps bottlenecks its cross-rack
        // routes; other racks keep their 20 Gbps pairs.
        assert_eq!(r.topology.group_bw_gbps(0, 3), 10.0);
        assert_eq!(r.topology.group_bw_gbps(3, 6), 20.0);
        r.topology.validate().unwrap();
    }

    #[test]
    fn trace_generation_is_deterministic_and_always_applies() {
        for topo in [testbed(), multi_rack()] {
            let a = generate_trace(&topo, 7, 8);
            let b = generate_trace(&topo, 7, 8);
            assert_eq!(a, b);
            assert_eq!(a.len(), 8);
            for spec in &a {
                let r = spec.apply(&topo).unwrap();
                r.topology.validate().unwrap();
            }
            let c = generate_trace(&topo, 8, 8);
            assert_ne!(a, c, "different seeds must differ");
        }
    }
}
