//! Random device-topology generator (paper §5.2).
//!
//! "A random device topology is produced with a machine number in [1, 6],
//! [1, 8] GPUs per machine of a GPU type among 3 types, intra-machine
//! bandwidth between [64, 160] Gbps (to simulate the absence or presence
//! of NVLink) and inter-machine bandwidth within [20, 50] Gbps."

use super::{DeviceGroup, Topology, RANDOM_GPU_TYPES};
use crate::util::Rng;

pub fn random_topology(rng: &mut Rng) -> Topology {
    let machines = rng.range(1, 6);
    let mut groups = Vec::with_capacity(machines);
    for _ in 0..machines {
        let gpu = RANDOM_GPU_TYPES[rng.below(RANDOM_GPU_TYPES.len())];
        let count = rng.range(1, 8);
        let intra = rng.uniform(64.0, 160.0);
        groups.push(DeviceGroup { gpu, count, intra_bw_gbps: intra });
    }
    let mut inter = vec![vec![0.0; machines]; machines];
    for i in 0..machines {
        for j in (i + 1)..machines {
            let bw = rng.uniform(20.0, 50.0);
            inter[i][j] = bw;
            inter[j][i] = bw;
        }
    }
    Topology::new(format!("random-{machines}m"), groups, inter)
}

/// Sample `n` random topologies from consecutive sub-seeds (deterministic
/// per base seed) — the 100-topology sets used in §5.2 / §5.7.
pub fn random_topologies(base_seed: u64, n: usize) -> Vec<Topology> {
    (0..n)
        .map(|i| {
            let mut rng = Rng::new(base_seed.wrapping_add(i as u64));
            random_topology(&mut rng)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn respects_paper_ranges() {
        for i in 0..200 {
            let mut rng = Rng::new(i);
            let t = random_topology(&mut rng);
            assert!((1..=6).contains(&t.num_groups()));
            for g in &t.groups {
                assert!((1..=8).contains(&g.count));
                assert!((64.0..=160.0).contains(&g.intra_bw_gbps));
                assert!(RANDOM_GPU_TYPES.iter().any(|r| r.name == g.gpu.name));
            }
            for i in 0..t.num_groups() {
                for j in 0..t.num_groups() {
                    if i != j {
                        assert!((20.0..=50.0).contains(&t.inter_bw_gbps[i][j]));
                    }
                }
            }
        }
    }

    #[test]
    fn deterministic_and_diverse() {
        let a = random_topologies(7, 20);
        let b = random_topologies(7, 20);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.num_groups(), y.num_groups());
            assert_eq!(x.num_devices(), y.num_devices());
        }
        // Diversity: not all the same machine count.
        let counts: std::collections::HashSet<usize> =
            a.iter().map(|t| t.num_groups()).collect();
        assert!(counts.len() > 2);
    }
}
