//! Random device-topology generators.
//!
//! [`random_topology`] samples flat topologies with the distribution of
//! §5.2: "a random device topology is produced with a machine number in
//! [1, 6], [1, 8] GPUs per machine of a GPU type among 3 types,
//! intra-machine bandwidth between [64, 160] Gbps (to simulate the
//! absence or presence of NVLink) and inter-machine bandwidth within
//! [20, 50] Gbps."
//!
//! [`random_hierarchical_topology`] samples *routed* topologies —
//! racks of machines behind PCIe host bridges, top-of-rack switches and
//! (for multi-rack samples) a spine — exercising the link-graph routing
//! and contention model on structures the flat matrix cannot express.
//! Machines flip between an NVLink-island fabric (direct device clique)
//! and a PCIe-switch fabric; either way every machine uplinks through
//! its host bridge, so cross-machine routes are genuinely multi-hop.

use super::linkgraph::{LinkGraph, LinkKind};
use super::{DeviceGroup, Topology, RANDOM_GPU_TYPES};
use crate::util::Rng;

pub fn random_topology(rng: &mut Rng) -> Topology {
    let machines = rng.range(1, 6);
    let mut groups = Vec::with_capacity(machines);
    for _ in 0..machines {
        let gpu = RANDOM_GPU_TYPES[rng.below(RANDOM_GPU_TYPES.len())];
        let count = rng.range(1, 8);
        let intra = rng.uniform(64.0, 160.0);
        groups.push(DeviceGroup { gpu, count, intra_bw_gbps: intra });
    }
    let mut inter = vec![vec![0.0; machines]; machines];
    for i in 0..machines {
        for j in (i + 1)..machines {
            let bw = rng.uniform(20.0, 50.0);
            inter[i][j] = bw;
            inter[j][i] = bw;
        }
    }
    Topology::new(format!("random-{machines}m"), groups, inter)
}

/// Sample `n` random topologies from consecutive sub-seeds (deterministic
/// per base seed) — the 100-topology sets used in §5.2 / §5.7.
pub fn random_topologies(base_seed: u64, n: usize) -> Vec<Topology> {
    (0..n)
        .map(|i| {
            let mut rng = Rng::new(base_seed.wrapping_add(i as u64));
            random_topology(&mut rng)
        })
        .collect()
}

/// Sample a random hierarchical (routed) topology:
///
/// * [1, 4] racks x [1, 3] machines per rack (each machine one device
///   group, so at most 12 groups);
/// * per machine: a GPU type among 3 types, [1, 4] GPUs, and a fabric —
///   NVLink island (direct clique, [100, 160] Gbps) or PCIe switch
///   ([32, 64] Gbps) with probability ½ each;
/// * every machine uplinks through its host bridge to the rack's ToR at
///   [10, 40] Gbps ethernet; multi-rack samples add a spine with
///   [10, 40] Gbps rack uplinks (often oversubscribed).
pub fn random_hierarchical_topology(rng: &mut Rng) -> Topology {
    let racks = rng.range(1, 4);
    let per_rack = rng.range(1, 3);
    let machines = racks * per_rack;

    let mut groups = Vec::with_capacity(machines);
    let mut nvlink = Vec::with_capacity(machines);
    for _ in 0..machines {
        let gpu = RANDOM_GPU_TYPES[rng.below(RANDOM_GPU_TYPES.len())];
        let count = rng.range(1, 4);
        let is_nvlink = rng.chance(0.5);
        let intra = if is_nvlink {
            rng.uniform(100.0, 160.0)
        } else {
            rng.uniform(32.0, 64.0)
        };
        nvlink.push(is_nvlink);
        groups.push(DeviceGroup { gpu, count, intra_bw_gbps: intra });
    }

    let mut b = LinkGraph::builder();
    let dev_nodes = b.add_group_devices(&groups);
    let spine = if racks > 1 { Some(b.add_switch(2)) } else { None };
    for rack in 0..racks {
        let tor = b.add_switch(1);
        if let Some(spine) = spine {
            b.link_default(tor, spine, rng.uniform(10.0, 40.0), LinkKind::Ethernet);
        }
        for machine in 0..per_rack {
            let gi = rack * per_rack + machine;
            let bridge = b.add_switch(0);
            b.link_default(bridge, tor, rng.uniform(10.0, 40.0), LinkKind::Ethernet);
            let nodes = &dev_nodes[gi];
            if nvlink[gi] {
                // NVLink island: device clique at the intra bandwidth,
                // PCIe uplinks narrower than NVLink so intra routes stay
                // on the island.
                for (i, &a) in nodes.iter().enumerate() {
                    for &c in &nodes[i + 1..] {
                        b.link_default(a, c, groups[gi].intra_bw_gbps, LinkKind::NvLink);
                    }
                    b.link_default(a, bridge, rng.uniform(32.0, 64.0), LinkKind::Pcie);
                }
            } else {
                // PCIe fabric: devices meet at the host bridge, so the
                // intra path (device-bridge-device) bottlenecks at the
                // declared intra bandwidth.
                for &a in nodes {
                    b.link_default(a, bridge, groups[gi].intra_bw_gbps, LinkKind::Pcie);
                }
            }
        }
    }
    Topology::routed(format!("hier-{racks}r{per_rack}m"), groups, b.build())
        .expect("generated hierarchical topology must be valid")
}

/// Sample `n` random hierarchical topologies from consecutive sub-seeds.
pub fn random_hierarchical_topologies(base_seed: u64, n: usize) -> Vec<Topology> {
    (0..n)
        .map(|i| {
            let mut rng = Rng::new(base_seed.wrapping_add(i as u64));
            random_hierarchical_topology(&mut rng)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn respects_paper_ranges() {
        for i in 0..200 {
            let mut rng = Rng::new(i);
            let t = random_topology(&mut rng);
            assert!((1..=6).contains(&t.num_groups()));
            for g in &t.groups {
                assert!((1..=8).contains(&g.count));
                assert!((64.0..=160.0).contains(&g.intra_bw_gbps));
                assert!(RANDOM_GPU_TYPES.iter().any(|r| r.name == g.gpu.name));
            }
            for i in 0..t.num_groups() {
                for j in 0..t.num_groups() {
                    if i != j {
                        assert!((20.0..=50.0).contains(&t.inter_bw_gbps[i][j]));
                    }
                }
            }
        }
    }

    #[test]
    fn deterministic_and_diverse() {
        let a = random_topologies(7, 20);
        let b = random_topologies(7, 20);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.num_groups(), y.num_groups());
            assert_eq!(x.num_devices(), y.num_devices());
        }
        // Diversity: not all the same machine count.
        let counts: std::collections::HashSet<usize> =
            a.iter().map(|t| t.num_groups()).collect();
        assert!(counts.len() > 2);
    }

    #[test]
    fn hierarchical_respects_ranges_and_routes() {
        let mut saw_multi_rack = false;
        for i in 0..60 {
            let mut rng = Rng::new(900 + i);
            let t = random_hierarchical_topology(&mut rng);
            assert!(t.is_routed());
            assert!((1..=12).contains(&t.num_groups()));
            for g in &t.groups {
                assert!((1..=4).contains(&g.count));
                assert!((32.0..=160.0).contains(&g.intra_bw_gbps));
            }
            t.validate().unwrap();
            if t.num_groups() > 1 {
                // Cross-machine routes are genuinely multi-hop.
                assert!(t.group_route(0, 1).hops() >= 4);
                saw_multi_rack |= t.group_route(0, t.num_groups() - 1).hops() >= 6;
            }
        }
        assert!(saw_multi_rack, "no multi-rack sample in 60 draws");
    }

    #[test]
    fn hierarchical_deterministic_per_seed() {
        let a = random_hierarchical_topologies(3, 8);
        let b = random_hierarchical_topologies(3, 8);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.num_groups(), y.num_groups());
            assert_eq!(x.num_devices(), y.num_devices());
            assert_eq!(x.inter_bw_gbps, y.inter_bw_gbps);
        }
    }
}
