//! The routed device link graph: devices *and* switches as nodes, typed
//! links with bandwidth and latency, and deterministic widest-path
//! routing with a cached per-topology route table.
//!
//! The paper's headline claim is deployment onto *any* device topology,
//! and real clusters are not cliques: GPUs hang off PCIe host bridges,
//! machines hang off top-of-rack switches, racks share an oversubscribed
//! spine.  This module is the physical layer under
//! [`Topology`](super::Topology):
//!
//! * **Flat topologies** (the original group-list + pairwise-matrix
//!   form) become *clique* link graphs: one direct device-device link
//!   per pair, bandwidth straight from the matrix, zero latency.  A
//!   clique routes every pair over its direct link, so every bandwidth
//!   query reproduces the flat matrix **bit for bit** — the
//!   flat-matrix ⇒ clique-graph equivalence contract pinned by
//!   `rust/tests/api.rs`.
//! * **Routed topologies** (built through [`LinkGraphBuilder`]) may
//!   contain switch nodes and multi-hop paths.  Routing is
//!   *widest-path*: maximize the path's bottleneck bandwidth, break
//!   ties by fewest hops, then by lowest accumulated latency, then by
//!   smallest predecessor node id — fully deterministic.  The route
//!   table is computed once per topology and shared (`Arc`) across
//!   clones.
//!
//! Routed links additionally carry *occupancy* in the simulator: the
//! [`crate::dist`] lowering stamps each inter-machine transfer with its
//! route's link ids, and [`crate::sim`] charges concurrent transfers
//! that share a link a proportional bandwidth share (see
//! [`crate::sim::LinkLoad`]).  That is what makes an oversubscribed
//! spine cost more than the per-flow bottleneck suggests.

use crate::cluster::{DeviceGroup, DeviceId};
use crate::util::error::Result;

/// Physical link technology.  For clique (flat-matrix) graphs the kind
/// is decorative; routed presets and the hierarchical generator use it
/// to pick default latencies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkKind {
    NvLink,
    Pcie,
    Ethernet,
}

impl LinkKind {
    /// Per-hop latency used by the routed presets and the hierarchical
    /// generator.
    pub fn default_latency_s(self) -> f64 {
        match self {
            LinkKind::NvLink => 0.7e-6,
            LinkKind::Pcie => 1.5e-6,
            LinkKind::Ethernet => 5.0e-6,
        }
    }

    /// Stable discriminant for fingerprinting.
    pub fn index(self) -> u8 {
        match self {
            LinkKind::NvLink => 0,
            LinkKind::Pcie => 1,
            LinkKind::Ethernet => 2,
        }
    }
}

/// A node of the link graph: a concrete device or a switch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeKind {
    Device(DeviceId),
    /// A switch at a hierarchy level (0 = host bridge, 1 = top-of-rack,
    /// 2 = spine, ...).  Levels are descriptive, not semantic.
    Switch { level: u8 },
}

/// An undirected link between two nodes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Link {
    pub a: usize,
    pub b: usize,
    pub bw_gbps: f64,
    pub latency_s: f64,
    pub kind: LinkKind,
}

/// One routed device-pair path: the traversed link ids, the path's
/// bottleneck bandwidth and its accumulated latency.  The degenerate
/// same-device route has no links, infinite bandwidth and zero latency.
///
/// The link sequence rides behind an `Arc` so the lowering can stamp a
/// transfer task's contention footprint with a refcount bump instead of
/// a per-task heap allocation (the evaluation hot path is otherwise
/// allocation-free by design).
#[derive(Clone, Debug, PartialEq)]
pub struct Route {
    pub links: std::sync::Arc<[u32]>,
    pub bottleneck_gbps: f64,
    pub latency_s: f64,
}

impl Route {
    pub fn hops(&self) -> usize {
        self.links.len()
    }

    fn same_device() -> Self {
        Route { links: Vec::new().into(), bottleneck_gbps: f64::INFINITY, latency_s: 0.0 }
    }
}

/// The cached per-topology routing result: one [`Route`] per ordered
/// device pair (flat device indices).  Symmetric by construction —
/// `route(b, a)` is `route(a, b)` with the link sequence reversed.
#[derive(Clone, Debug)]
pub struct RouteTable {
    n: usize,
    routes: Vec<Route>,
}

impl RouteTable {
    pub fn num_devices(&self) -> usize {
        self.n
    }

    /// The route between two flat device indices.
    pub fn route(&self, a: usize, b: usize) -> &Route {
        &self.routes[a * self.n + b]
    }
}

/// Devices + switches + typed links.
#[derive(Clone, Debug)]
pub struct LinkGraph {
    nodes: Vec<NodeKind>,
    links: Vec<Link>,
    /// `adj[node]` = (peer node, link id), in link-insertion order.
    adj: Vec<Vec<(usize, u32)>>,
    /// Flat device index -> node id (devices in `(group, idx)` order).
    device_nodes: Vec<usize>,
    /// Built by [`LinkGraph::clique`] from a flat matrix: routes are the
    /// direct links and reproduce the matrix bit for bit.
    clique: bool,
}

impl LinkGraph {
    pub fn builder() -> LinkGraphBuilder {
        LinkGraphBuilder::default()
    }

    /// The clique graph of a flat (group list + pairwise matrix)
    /// topology: one zero-latency direct link per device pair, intra
    /// bandwidth within a group, the matrix entry across groups.
    pub fn clique(groups: &[DeviceGroup], inter_bw_gbps: &[Vec<f64>]) -> Self {
        let mut b = LinkGraphBuilder::default();
        let mut flat: Vec<(usize, DeviceId)> = Vec::new();
        for (gi, g) in groups.iter().enumerate() {
            for di in 0..g.count {
                let d = DeviceId { group: gi, idx: di };
                flat.push((b.add_device(d), d));
            }
        }
        for (i, &(ni, di)) in flat.iter().enumerate() {
            for &(nj, dj) in &flat[i + 1..] {
                let (bw, kind) = if di.group == dj.group {
                    (groups[di.group].intra_bw_gbps, LinkKind::Pcie)
                } else {
                    (inter_bw_gbps[di.group][dj.group], LinkKind::Ethernet)
                };
                b.link(ni, nj, bw, 0.0, kind);
            }
        }
        let mut g = b.build();
        g.clique = true;
        g
    }

    pub fn is_clique(&self) -> bool {
        self.clique
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn num_links(&self) -> usize {
        self.links.len()
    }

    pub fn num_devices(&self) -> usize {
        self.device_nodes.len()
    }

    pub fn nodes(&self) -> &[NodeKind] {
        &self.nodes
    }

    pub fn links(&self) -> &[Link] {
        &self.links
    }

    /// Node id of a flat device index.
    pub fn device_node(&self, flat: usize) -> usize {
        self.device_nodes[flat]
    }

    /// The device each flat index maps to (insertion order).
    pub fn device_ids(&self) -> impl Iterator<Item = DeviceId> + '_ {
        self.device_nodes.iter().map(|&n| match self.nodes[n] {
            NodeKind::Device(d) => d,
            NodeKind::Switch { .. } => unreachable!("device_nodes points at a switch"),
        })
    }

    /// Number of links incident to a node.
    pub fn degree(&self, node: usize) -> usize {
        self.adj[node].len()
    }

    /// Largest degree among switches directly attached to a device
    /// (0 when the device attaches to no switch — e.g. in a clique).
    pub fn attached_switch_degree(&self, flat_device: usize) -> usize {
        self.adj[self.device_nodes[flat_device]]
            .iter()
            .filter(|&&(peer, _)| matches!(self.nodes[peer], NodeKind::Switch { .. }))
            .map(|&(peer, _)| self.degree(peer))
            .max()
            .unwrap_or(0)
    }

    /// Sanity-check the graph structure itself (link endpoints in range,
    /// bandwidths positive and finite, latencies non-negative).
    pub fn check(&self) -> Result<()> {
        for l in &self.links {
            crate::ensure!(
                l.a < self.nodes.len() && l.b < self.nodes.len() && l.a != l.b,
                "link endpoints out of range or self-link ({}, {})",
                l.a,
                l.b
            );
            crate::ensure!(
                l.bw_gbps.is_finite() && l.bw_gbps > 0.0,
                "link ({}, {}) bandwidth must be positive and finite, got {}",
                l.a,
                l.b,
                l.bw_gbps
            );
            crate::ensure!(
                l.latency_s.is_finite() && l.latency_s >= 0.0,
                "link ({}, {}) latency must be finite and non-negative, got {}",
                l.a,
                l.b,
                l.latency_s
            );
        }
        for &n in &self.device_nodes {
            crate::ensure!(
                matches!(self.nodes[n], NodeKind::Device(_)),
                "device node table points at a switch"
            );
        }
        Ok(())
    }

    /// Compute the full device-pair route table.
    ///
    /// Cliques route every pair over its direct link (a flat matrix *is*
    /// the route set — the router only chooses among multi-hop paths
    /// when the fabric contains switches).  Routed graphs run the
    /// deterministic widest-path search per source device.  Errors when
    /// some device pair is disconnected.
    pub fn route_table(&self) -> Result<RouteTable> {
        let n = self.device_nodes.len();
        let mut routes = vec![Route::same_device(); n * n];
        if self.clique {
            // Direct links only; every pair has exactly one.
            let mut node_to_flat = vec![usize::MAX; self.nodes.len()];
            for (flat, &node) in self.device_nodes.iter().enumerate() {
                node_to_flat[node] = flat;
            }
            for (lid, l) in self.links.iter().enumerate() {
                let (fa, fb) = (node_to_flat[l.a], node_to_flat[l.b]);
                let direct = Route {
                    links: vec![lid as u32].into(),
                    bottleneck_gbps: l.bw_gbps,
                    latency_s: l.latency_s,
                };
                routes[fa * n + fb] = direct.clone();
                routes[fb * n + fa] = direct;
            }
            for a in 0..n {
                for b in (a + 1)..n {
                    crate::ensure!(
                        !routes[a * n + b].links.is_empty(),
                        "clique graph is missing the ({a}, {b}) direct link"
                    );
                }
            }
            return Ok(RouteTable { n, routes });
        }

        // Widest-path search from each source device; destinations with a
        // smaller flat index reuse the mirrored route so the table is
        // symmetric by construction.
        for src in 0..n {
            let (prev_link, prev_node, bn) = self.widest_from(self.device_nodes[src]);
            for dst in (src + 1)..n {
                let dst_node = self.device_nodes[dst];
                crate::ensure!(
                    bn[dst_node] > 0.0,
                    "no route between devices {src} and {dst} (disconnected link graph)"
                );
                let mut links = Vec::new();
                let mut latency = 0.0;
                let mut at = dst_node;
                while at != self.device_nodes[src] {
                    let lid = prev_link[at];
                    links.push(lid);
                    latency += self.links[lid as usize].latency_s;
                    at = prev_node[at];
                }
                // Collected dst -> src: the unreversed sequence is the
                // mirror route, the reversed one the forward route.
                let rev = Route {
                    links: links.clone().into(),
                    bottleneck_gbps: bn[dst_node],
                    latency_s: latency,
                };
                links.reverse();
                let fwd = Route {
                    links: links.into(),
                    bottleneck_gbps: rev.bottleneck_gbps,
                    latency_s: rev.latency_s,
                };
                routes[src * n + dst] = fwd;
                routes[dst * n + src] = rev;
            }
        }
        Ok(RouteTable { n, routes })
    }

    /// Deterministic widest-path (max-bottleneck) search from `src`:
    /// ties broken by fewest hops, then lowest latency, then smallest
    /// predecessor node id.  Returns per-node (incoming link, previous
    /// node, bottleneck); unreachable nodes keep bottleneck 0.
    fn widest_from(&self, src: usize) -> (Vec<u32>, Vec<usize>, Vec<f64>) {
        let nn = self.nodes.len();
        let mut bn = vec![0.0f64; nn];
        let mut hops = vec![usize::MAX; nn];
        let mut lat = vec![f64::INFINITY; nn];
        let mut prev_node = vec![usize::MAX; nn];
        let mut prev_link = vec![u32::MAX; nn];
        let mut visited = vec![false; nn];
        bn[src] = f64::INFINITY;
        hops[src] = 0;
        lat[src] = 0.0;

        for _ in 0..nn {
            // Select the unvisited node with the widest bottleneck,
            // scanning in ascending id order so ties are deterministic.
            let mut u = usize::MAX;
            for (cand, &v) in visited.iter().enumerate() {
                if v || bn[cand] <= 0.0 {
                    continue;
                }
                if u == usize::MAX
                    || bn[cand] > bn[u]
                    || (bn[cand] == bn[u] && hops[cand] < hops[u])
                {
                    u = cand;
                }
            }
            if u == usize::MAX {
                break;
            }
            visited[u] = true;
            for &(v, lid) in &self.adj[u] {
                if visited[v] {
                    continue;
                }
                let l = &self.links[lid as usize];
                let nb = bn[u].min(l.bw_gbps);
                let nh = hops[u] + 1;
                let nl = lat[u] + l.latency_s;
                let better = nb > bn[v]
                    || (nb == bn[v] && nh < hops[v])
                    || (nb == bn[v] && nh == hops[v] && nl < lat[v])
                    || (nb == bn[v] && nh == hops[v] && nl == lat[v] && u < prev_node[v]);
                if better {
                    bn[v] = nb;
                    hops[v] = nh;
                    lat[v] = nl;
                    prev_node[v] = u;
                    prev_link[v] = lid;
                }
            }
        }
        (prev_link, prev_node, bn)
    }
}

/// Incremental construction of a routed [`LinkGraph`].
///
/// Devices **must** be added in flat `(group, idx)` order — the order
/// [`Topology::devices`](super::Topology::devices) enumerates — which
/// [`Topology::routed`](super::Topology::routed) verifies.
#[derive(Default)]
pub struct LinkGraphBuilder {
    nodes: Vec<NodeKind>,
    links: Vec<Link>,
    device_nodes: Vec<usize>,
}

impl LinkGraphBuilder {
    /// Add a device node; returns its node id.
    pub fn add_device(&mut self, d: DeviceId) -> usize {
        self.nodes.push(NodeKind::Device(d));
        self.device_nodes.push(self.nodes.len() - 1);
        self.nodes.len() - 1
    }

    /// Register every group's devices in the flat `(group, idx)` order
    /// [`Topology::routed`](super::Topology::routed) requires; returns
    /// the node ids per group.  Call this first, before adding switches.
    pub fn add_group_devices(&mut self, groups: &[DeviceGroup]) -> Vec<Vec<usize>> {
        groups
            .iter()
            .enumerate()
            .map(|(gi, g)| {
                (0..g.count)
                    .map(|di| self.add_device(DeviceId { group: gi, idx: di }))
                    .collect()
            })
            .collect()
    }

    /// Add a switch node at a hierarchy level; returns its node id.
    pub fn add_switch(&mut self, level: u8) -> usize {
        self.nodes.push(NodeKind::Switch { level });
        self.nodes.len() - 1
    }

    /// Add an undirected link.
    pub fn link(&mut self, a: usize, b: usize, bw_gbps: f64, latency_s: f64, kind: LinkKind) {
        self.links.push(Link { a, b, bw_gbps, latency_s, kind });
    }

    /// Convenience: link with the kind's default latency.
    pub fn link_default(&mut self, a: usize, b: usize, bw_gbps: f64, kind: LinkKind) {
        self.link(a, b, bw_gbps, kind.default_latency_s(), kind);
    }

    pub fn build(self) -> LinkGraph {
        let mut adj: Vec<Vec<(usize, u32)>> = vec![Vec::new(); self.nodes.len()];
        for (lid, l) in self.links.iter().enumerate() {
            adj[l.a].push((l.b, lid as u32));
            adj[l.b].push((l.a, lid as u32));
        }
        LinkGraph {
            nodes: self.nodes,
            links: self.links,
            adj,
            device_nodes: self.device_nodes,
            clique: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{DeviceGroup, P100, V100_16G};

    fn two_group_flat() -> (Vec<DeviceGroup>, Vec<Vec<f64>>) {
        (
            vec![
                DeviceGroup { gpu: V100_16G, count: 2, intra_bw_gbps: 128.0 },
                DeviceGroup { gpu: P100, count: 2, intra_bw_gbps: 64.0 },
            ],
            vec![vec![0.0, 25.0], vec![25.0, 0.0]],
        )
    }

    #[test]
    fn clique_routes_are_direct_links() {
        let (groups, inter) = two_group_flat();
        let g = LinkGraph::clique(&groups, &inter);
        assert!(g.is_clique());
        assert_eq!(g.num_devices(), 4);
        assert_eq!(g.num_links(), 6); // complete graph on 4 devices
        let rt = g.route_table().unwrap();
        // Intra pair: direct at intra bandwidth, one hop, zero latency.
        let r = rt.route(0, 1);
        assert_eq!(r.hops(), 1);
        assert_eq!(r.bottleneck_gbps, 128.0);
        assert_eq!(r.latency_s, 0.0);
        // Cross pair: the matrix entry, never a relay — even though a
        // two-hop path through the other group would be wider is not
        // possible here; the clique contract pins direct routing.
        let r = rt.route(0, 2);
        assert_eq!(r.hops(), 1);
        assert_eq!(r.bottleneck_gbps, 25.0);
        // Same-device route is free.
        assert!(rt.route(3, 3).bottleneck_gbps.is_infinite());
        assert_eq!(rt.route(3, 3).hops(), 0);
    }

    #[test]
    fn widest_path_prefers_wider_multi_hop_route() {
        // d0 - narrow direct link - d1, but both also hang off a fat
        // switch: the router must take the 2-hop wide path.
        let mut b = LinkGraph::builder();
        let d0 = b.add_device(DeviceId { group: 0, idx: 0 });
        let d1 = b.add_device(DeviceId { group: 1, idx: 0 });
        let sw = b.add_switch(0);
        b.link(d0, d1, 10.0, 1e-6, LinkKind::Ethernet);
        b.link(d0, sw, 100.0, 1e-6, LinkKind::Pcie);
        b.link(sw, d1, 100.0, 1e-6, LinkKind::Pcie);
        let g = b.build();
        g.check().unwrap();
        let rt = g.route_table().unwrap();
        let r = rt.route(0, 1);
        assert_eq!(r.hops(), 2);
        assert_eq!(r.bottleneck_gbps, 100.0);
        assert!((r.latency_s - 2e-6).abs() < 1e-18);
        // Reverse route mirrors the forward one.
        let rev = rt.route(1, 0);
        assert_eq!(rev.bottleneck_gbps, 100.0);
        let back: Vec<u32> = rev.links.iter().rev().copied().collect();
        assert_eq!(&back[..], &r.links[..]);
    }

    #[test]
    fn equal_width_ties_break_by_fewest_hops() {
        // Two equal-bandwidth paths: direct (1 hop) vs through a switch
        // (2 hops) — the direct link must win.
        let mut b = LinkGraph::builder();
        let d0 = b.add_device(DeviceId { group: 0, idx: 0 });
        let d1 = b.add_device(DeviceId { group: 1, idx: 0 });
        let sw = b.add_switch(0);
        b.link(d0, d1, 50.0, 1e-6, LinkKind::Ethernet);
        b.link(d0, sw, 50.0, 1e-6, LinkKind::Ethernet);
        b.link(sw, d1, 50.0, 1e-6, LinkKind::Ethernet);
        let rt = b.build().route_table().unwrap();
        assert_eq!(rt.route(0, 1).hops(), 1);
    }

    #[test]
    fn disconnected_devices_are_an_error() {
        let mut b = LinkGraph::builder();
        b.add_device(DeviceId { group: 0, idx: 0 });
        b.add_device(DeviceId { group: 1, idx: 0 });
        let g = b.build();
        assert!(g.route_table().is_err());
    }

    #[test]
    fn switch_degree_visibility() {
        let mut b = LinkGraph::builder();
        let d0 = b.add_device(DeviceId { group: 0, idx: 0 });
        let d1 = b.add_device(DeviceId { group: 0, idx: 1 });
        let d2 = b.add_device(DeviceId { group: 1, idx: 0 });
        let sw = b.add_switch(0);
        b.link_default(d0, sw, 64.0, LinkKind::Pcie);
        b.link_default(d1, sw, 64.0, LinkKind::Pcie);
        b.link_default(d2, sw, 64.0, LinkKind::Pcie);
        let g = b.build();
        assert_eq!(g.attached_switch_degree(0), 3);
        assert_eq!(g.degree(sw), 3);
        // A clique device attaches to no switch.
        let (groups, inter) = two_group_flat();
        let c = LinkGraph::clique(&groups, &inter);
        assert_eq!(c.attached_switch_degree(0), 0);
    }

    #[test]
    fn invalid_links_rejected_by_check() {
        let mut b = LinkGraph::builder();
        let d0 = b.add_device(DeviceId { group: 0, idx: 0 });
        let d1 = b.add_device(DeviceId { group: 0, idx: 1 });
        b.link(d0, d1, -5.0, 0.0, LinkKind::Ethernet);
        assert!(b.build().check().is_err());
        let mut b = LinkGraph::builder();
        let d0 = b.add_device(DeviceId { group: 0, idx: 0 });
        let d1 = b.add_device(DeviceId { group: 0, idx: 1 });
        b.link(d0, d1, 64.0, f64::NAN, LinkKind::Ethernet);
        assert!(b.build().check().is_err());
    }
}
