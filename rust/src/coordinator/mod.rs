//! The L3 coordinator: end-to-end search sessions, the AlphaZero-style
//! self-play GNN trainer (paper §4.2.2 / Fig. 7) and the batched
//! leaf-evaluation service ([`batch`]).
//!
//! This is the *engine* layer: [`search_session`] runs one
//! prior-injected search and [`assemble_session`] folds a raw search
//! result into times + SFB.  The public deployment surface — typed
//! requests, pluggable backends, plan caching and serialization — is
//! [`crate::api`], which drives these functions.

pub mod batch;

use crate::cluster::{generator::random_topology, Topology};
use crate::dist::Lowering;
use crate::gnn::features::{FeatureBuilder, Position, B_TRAIN, N_CAND};
use crate::gnn::{GnnPrior, GnnService};
use crate::graph::grouping::{group_ops, GroupGraph, DEFAULT_GROUPS};
use crate::graph::CompGraph;
use crate::mcts::{Mcts, PriorProvider, SearchResult, UniformPrior};
use crate::models;
use crate::profile::{unique_gpus, CommModel, CostModel};
use crate::search::{self, Parallelism, SearchProblem};
use crate::sfb::{self, SfbPlan};
use crate::strategy::{enumerate_actions, Strategy};
use crate::util::{Rng, Stopwatch};

/// Configuration for one strategy-search session.
#[derive(Clone, Debug)]
pub struct SearchConfig {
    pub max_groups: usize,
    pub mcts_iterations: usize,
    pub seed: u64,
    /// Run the SFB optimizer on the found strategy (§4.2.3).
    pub apply_sfb: bool,
    /// Profiler measurement noise.
    pub profile_noise: f64,
    /// Tree-parallel search workers + virtual loss ([`crate::search`]).
    pub parallelism: Parallelism,
    /// Wall-clock search budget in milliseconds: when it expires the
    /// search stops early and the best-so-far strategy stands (MCTS is
    /// anytime).  `None` (the default) runs the full iteration budget
    /// and keeps plans fully deterministic.
    pub deadline_ms: Option<u64>,
    /// Incremental (delta) evaluation: fragment-cached lowering +
    /// frontier-restart simulation ([`crate::dist`]).  Purely a
    /// performance knob — outcomes and plans are bit-identical either
    /// way, so it does not enter plan fingerprints.  Default on; the
    /// CLI's `--no-delta` flag clears it.
    pub delta: bool,
}

impl Default for SearchConfig {
    fn default() -> Self {
        Self {
            max_groups: DEFAULT_GROUPS,
            mcts_iterations: 150,
            seed: 1,
            apply_sfb: true,
            profile_noise: 0.0,
            parallelism: Parallelism::default(),
            deadline_ms: None,
            delta: true,
        }
    }
}

/// Everything a search session produces.
pub struct SessionResult {
    pub strategy: Strategy,
    pub time: f64,
    pub time_with_sfb: Option<f64>,
    /// `min(time, time_with_sfb)` — what the deployment would run at;
    /// `speedup` is always `dp_time / final_time`.
    pub final_time: f64,
    pub dp_time: f64,
    pub speedup: f64,
    /// Whether the DP-NCCL reference itself OOMs on this problem (the
    /// Fig. 5 footnote marker).
    pub dp_oom: bool,
    pub sfb: Option<SfbPlan>,
    pub search: SearchResult,
    pub overhead_s: f64,
    pub group_graph: GroupGraph,
}

/// Prepared (profiled + grouped) context, reusable across searches.
pub struct Prepared {
    pub graph: CompGraph,
    pub gg: GroupGraph,
    pub cost: CostModel,
    pub comm: CommModel,
}

/// Profile + simplify + group a model for a topology.
pub fn prepare(model: CompGraph, topo: &Topology, cfg: &SearchConfig) -> Prepared {
    let analysis = crate::graph::analyzer::simplify(&model);
    let graph = analysis.graph;
    let cost = CostModel::profile(&graph.ops, &unique_gpus(topo), cfg.profile_noise, cfg.seed);
    let gg = group_ops(&graph, &cost, cfg.max_groups, cfg.seed);
    let comm = CommModel::fit(cfg.seed ^ 0xc0ffee);
    Prepared { graph, gg, cost, comm }
}

/// Run a full TAG search.  `prior` injects the policy guiding MCTS —
/// a [`GnnPrior`] for the paper's GNN-guided search, any other
/// [`PriorProvider`] for experiments, or `None` for pure MCTS with
/// uniform priors.  (Callers wanting the full request/plan surface —
/// caching, serialization, backend selection — should use
/// [`crate::api::Planner`], which drives this engine.)
///
/// `cfg.parallelism` selects the engine: `workers == 1` runs the
/// sequential [`Mcts`]; `workers > 1` runs the tree-parallel
/// [`crate::search::run_search`] — for pure MCTS only, since a single
/// injected `&mut dyn PriorProvider` cannot be split across workers
/// (per-worker priors are the [`crate::api`] backends' job, which route
/// GNN evaluations through the batched service instead).
pub fn search_session(
    prep: &Prepared,
    topo: &Topology,
    prior: Option<&mut dyn PriorProvider>,
    cfg: &SearchConfig,
) -> SessionResult {
    let watch = Stopwatch::start();
    let low = Lowering::new(&prep.gg, topo, &prep.cost, &prep.comm);
    low.set_delta(cfg.delta);
    let actions = enumerate_actions(topo);
    // The deadline clock starts here, bounding the search itself.
    // (`api::Planner` instead starts its token before prepare, so the
    // full request path is covered when serving.)
    let cancel = cfg.deadline_ms.map(search::CancelToken::with_deadline_ms);

    let search = match prior {
        Some(prior) => {
            let mut mcts = Mcts::new(&low, actions.clone(), prior, cfg.seed);
            mcts.cancel = cancel.clone();
            mcts.search(cfg.mcts_iterations)
        }
        None if cfg.parallelism.workers > 1 => {
            let prob = SearchProblem {
                gg: &prep.gg,
                topo,
                cost: &prep.cost,
                comm: &prep.comm,
                actions: &actions,
            };
            let priors: Vec<UniformPrior> =
                (0..cfg.parallelism.workers).map(|_| UniformPrior).collect();
            search::run_search(
                &prob,
                &low,
                priors,
                cfg.mcts_iterations,
                cfg.seed,
                cfg.parallelism,
                true,
                false,
                cancel.as_ref(),
            )
            .result
        }
        None => {
            let mut mcts = Mcts::new(&low, actions.clone(), UniformPrior, cfg.seed);
            mcts.cancel = cancel.clone();
            mcts.search(cfg.mcts_iterations)
        }
    };
    assemble_session(prep, topo, &low, search, cfg, watch.elapsed_s())
}

/// Finish a session from a raw [`SearchResult`]: evaluate the found
/// strategy, optionally run the SFB optimizer, and aggregate the final
/// times.  Shared by [`search_session`] and the `api::Planner` backends
/// (which own their search loop).
pub fn assemble_session(
    prep: &Prepared,
    topo: &Topology,
    low: &Lowering,
    search: SearchResult,
    cfg: &SearchConfig,
    overhead_s: f64,
) -> SessionResult {
    let dp_time = search.dp_time;
    let strategy = search.best.clone();
    let base_out = low.evaluate(&strategy);
    let dp_oom =
        low.evaluate(&Strategy::dp_allreduce(prep.gg.num_groups(), topo)).oom;

    let (sfb, time_with_sfb) = if cfg.apply_sfb {
        let _s = crate::obs::span("sfb");
        let plan = sfb::optimize(&prep.graph, &prep.gg, topo, &prep.cost, &strategy);
        let t = low.evaluate_with_sfb(&strategy, Some(&plan)).time;
        (Some(plan), Some(t))
    } else {
        (None, None)
    };

    let final_time = time_with_sfb.unwrap_or(base_out.time).min(base_out.time);
    SessionResult {
        speedup: dp_time / final_time,
        strategy,
        time: base_out.time,
        time_with_sfb,
        final_time,
        dp_time,
        dp_oom,
        sfb,
        search,
        overhead_s,
        group_graph: prep.gg.clone(),
    }
}

// ---------------------------------------------------------------- trainer

/// One harvested replay example, featurized.
struct Replay {
    position: Position,
    pi: Vec<f32>,
}

/// Self-play GNN trainer (Fig. 7): alternate MCTS example collection on
/// random (model, topology) pairs with Adam steps on the replay buffer.
pub struct Trainer<'a> {
    svc: &'a GnnService,
    pub params: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    step: f32,
    buffer: Vec<Replay>,
    pub loss_history: Vec<f32>,
    pub use_feedback: bool,
    pub model_scale: f64,
    pub mcts_iterations: usize,
    /// Restrict self-play to these models (None = all 6).
    pub model_filter: Option<Vec<&'static str>>,
    rng: Rng,
}

const REPLAY_CAP: usize = 2048;

impl<'a> Trainer<'a> {
    pub fn new(svc: &'a GnnService, params: Vec<f32>, seed: u64) -> Self {
        let n = params.len();
        Self {
            svc,
            params,
            m: vec![0.0; n],
            v: vec![0.0; n],
            step: 0.0,
            buffer: Vec::new(),
            loss_history: Vec::new(),
            use_feedback: true,
            model_scale: 0.25,
            mcts_iterations: 96,
            model_filter: None,
            rng: Rng::new(seed),
        }
    }

    fn sample_model(&mut self) -> CompGraph {
        let names: Vec<&'static str> = match &self.model_filter {
            Some(f) => f.clone(),
            None => models::MODEL_NAMES.to_vec(),
        };
        let name = *self.rng.choose(&names);
        models::by_name(name, self.model_scale).unwrap()
    }

    /// One self-play game: search a random (model, topology), harvest
    /// (features, visit-distribution) examples into the replay buffer.
    pub fn collect(&mut self) -> usize {
        let model = self.sample_model();
        let mut trng = Rng::new(self.rng.next_u64());
        let topo = random_topology(&mut trng);
        let cfg = SearchConfig {
            max_groups: 24,
            mcts_iterations: self.mcts_iterations,
            seed: self.rng.next_u64(),
            apply_sfb: false,
            profile_noise: 0.0,
            parallelism: Default::default(),
            deadline_ms: None,
            delta: true,
        };
        let prep = prepare(model, &topo, &cfg);
        let low = Lowering::new(&prep.gg, &topo, &prep.cost, &prep.comm);
        let actions = enumerate_actions(&topo);
        let mut builder = FeatureBuilder::new(&prep.gg, &topo, &actions);
        builder.use_feedback = self.use_feedback;
        let prior = GnnPrior::new(self.svc, builder, self.params.clone());
        let mut mcts = Mcts::new(&low, actions.clone(), prior, cfg.seed);
        mcts.collect_examples = true;
        let res = mcts.search(cfg.mcts_iterations);

        let mut fb2 = FeatureBuilder::new(&prep.gg, &topo, &actions);
        fb2.use_feedback = self.use_feedback;
        let n = res.examples.len();
        for ex in res.examples {
            let pos = fb2.build(&ex.strategy, &ex.outcome, ex.group);
            let mut pi = ex.pi.clone();
            pi.resize(N_CAND, 0.0);
            self.buffer.push(Replay { position: pos, pi });
        }
        if self.buffer.len() > REPLAY_CAP {
            let excess = self.buffer.len() - REPLAY_CAP;
            self.buffer.drain(..excess);
        }
        n
    }

    /// One Adam step on a random replay batch; returns the loss.
    pub fn train_once(&mut self) -> Option<f32> {
        if self.buffer.is_empty() {
            return None;
        }
        let bs = B_TRAIN.min(self.buffer.len());
        let mut idx: Vec<usize> = (0..self.buffer.len()).collect();
        self.rng.shuffle(&mut idx);
        idx.truncate(bs);
        let positions: Vec<&Position> =
            idx.iter().map(|&i| &self.buffer[i].position).collect();
        let pis: Vec<Vec<f32>> = idx.iter().map(|&i| self.buffer[i].pi.clone()).collect();
        let mask = vec![1.0f32; bs];
        match self.svc.train_step(
            &self.params,
            &self.m,
            &self.v,
            self.step,
            &positions,
            &pis,
            &mask,
        ) {
            Ok((p, m, v, loss)) => {
                self.params = p;
                self.m = m;
                self.v = v;
                self.step += 1.0;
                self.loss_history.push(loss);
                Some(loss)
            }
            Err(e) => {
                eprintln!("train step failed: {e}");
                None
            }
        }
    }

    /// Run `games` collection rounds with `steps_per_game` train steps
    /// after each; returns the loss history.
    pub fn run(&mut self, games: usize, steps_per_game: usize) -> Vec<f32> {
        for _ in 0..games {
            self.collect();
            for _ in 0..steps_per_game {
                self.train_once();
            }
        }
        self.loss_history.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets::testbed;

    #[test]
    fn pure_mcts_session_end_to_end() {
        let topo = testbed();
        let cfg = SearchConfig {
            max_groups: 10,
            mcts_iterations: 40,
            seed: 3,
            apply_sfb: true,
            profile_noise: 0.0,
            parallelism: Default::default(),
            deadline_ms: None,
            delta: true,
        };
        let prep = prepare(models::vgg19(8, 0.25), &topo, &cfg);
        let res = search_session(&prep, &topo, None, &cfg);
        assert!(res.time.is_finite());
        assert!(res.speedup > 0.9, "speedup {}", res.speedup);
        assert!(res.overhead_s > 0.0);
        assert!(res.sfb.is_some());
    }

    #[test]
    fn sfb_never_hurts_final_time() {
        let topo = testbed();
        let cfg = SearchConfig {
            max_groups: 10,
            mcts_iterations: 30,
            seed: 4,
            apply_sfb: true,
            profile_noise: 0.0,
            parallelism: Default::default(),
            deadline_ms: None,
            delta: true,
        };
        let prep = prepare(models::transformer(8, 0.25), &topo, &cfg);
        let res = search_session(&prep, &topo, None, &cfg);
        if let Some(t_sfb) = res.time_with_sfb {
            // The plan only includes gradients the ILP deems beneficial;
            // the reported final time takes the min anyway.
            assert!(t_sfb.is_finite());
            let final_t = res.dp_time / res.speedup;
            assert!(final_t <= res.time + 1e-12);
        }
    }

    #[test]
    fn gnn_guided_session_runs_when_artifacts_exist() {
        if !std::path::Path::new("artifacts/gnn_infer.hlo.txt").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let svc = GnnService::load("artifacts").unwrap();
        let params = crate::gnn::params::load_params("artifacts/params_init.bin").unwrap();
        let topo = testbed();
        let cfg = SearchConfig {
            max_groups: 10,
            mcts_iterations: 20,
            seed: 5,
            apply_sfb: false,
            profile_noise: 0.0,
            parallelism: Default::default(),
            deadline_ms: None,
            delta: true,
        };
        let prep = prepare(models::vgg19(8, 0.25), &topo, &cfg);
        let actions = enumerate_actions(&topo);
        let builder = FeatureBuilder::new(&prep.gg, &topo, &actions);
        let mut prior = GnnPrior::new(&svc, builder, params);
        let res = search_session(&prep, &topo, Some(&mut prior), &cfg);
        assert!(res.time.is_finite());
        assert!(res.speedup > 0.5);
    }

    #[test]
    fn trainer_collects_and_trains() {
        if !std::path::Path::new("artifacts/gnn_infer.hlo.txt").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let svc = GnnService::load("artifacts").unwrap();
        let params = crate::gnn::params::load_params("artifacts/params_init.bin").unwrap();
        let mut tr = Trainer::new(&svc, params, 7);
        tr.model_scale = 0.25;
        tr.mcts_iterations = 70; // enough visits to harvest the root
        tr.model_filter = Some(vec!["VGG19"]);
        let n = tr.collect();
        assert!(n > 0, "no examples harvested");
        let loss = tr.train_once().expect("train step");
        assert!(loss.is_finite() && loss > 0.0);
    }
}
