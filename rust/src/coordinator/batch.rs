//! Batched leaf-evaluation service.
//!
//! A real PJRT executable is driven through one device queue, so the
//! compiled GNN lives on one *evaluator thread* (the stub service is
//! `Send + Sync`, but centralized evaluation is what makes batching
//! work); search workers (parallel MCTS over different
//! models/topologies) submit [`Position`]s through an MPSC channel and
//! block on a reply channel.  The evaluator drains up to `B_INFER`
//! requests (with a short linger once at least one is pending) and runs
//! them as a single PJRT execution — the inference-side analogue of
//! dynamic batching in serving systems, and what makes the fixed batch
//! axis of the AOT artifact pay off.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

use crate::gnn::features::{Position, B_INFER};
use crate::gnn::GnnService;

/// A pending evaluation: position in, priors out.
pub struct EvalRequest {
    pub position: Box<Position>,
    pub reply: Sender<Vec<f32>>,
}

/// Client handle: cheap to clone into worker threads.
#[derive(Clone)]
pub struct EvalClient {
    tx: Sender<EvalRequest>,
}

impl EvalClient {
    /// Blocking evaluation of one position.
    pub fn eval(&self, position: Position) -> Option<Vec<f32>> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(EvalRequest { position: Box::new(position), reply: reply_tx })
            .ok()?;
        reply_rx.recv().ok()
    }
}

/// Statistics the evaluator reports when it shuts down.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalStats {
    pub requests: usize,
    pub batches: usize,
}

impl EvalStats {
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

/// How long to linger for more requests once one is pending.
const LINGER: Duration = Duration::from_micros(300);

/// Run the evaluation loop until all clients hang up.
/// Call from a dedicated thread that owns the service.
pub fn serve(svc: &GnnService, params: &[f32], rx: Receiver<EvalRequest>) -> EvalStats {
    let mut stats = EvalStats::default();
    loop {
        // Block for the first request.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return stats, // all senders dropped
        };
        let mut pending = vec![first];
        // Linger to fill the batch.
        while pending.len() < B_INFER {
            match rx.recv_timeout(LINGER) {
                Ok(r) => pending.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        stats.requests += pending.len();
        stats.batches += 1;
        let positions: Vec<&Position> =
            pending.iter().map(|r| r.position.as_ref()).collect();
        match svc.infer_batch(params, &positions) {
            Ok(results) => {
                for (req, res) in pending.into_iter().zip(results) {
                    let _ = req.reply.send(res);
                }
            }
            Err(e) => {
                // Warn once per process (see `GnnPrior::priors`): on the
                // stub runtime every batch fails, and a serving daemon
                // must not pay per-batch stderr writes.
                static WARN_ONCE: std::sync::Once = std::sync::Once::new();
                WARN_ONCE.call_once(|| {
                    eprintln!(
                        "batched inference failed: {e} \
                         (warning suppressed after first occurrence)"
                    );
                });
                // Reply with uniform fallbacks so workers don't deadlock.
                for req in pending {
                    let n = crate::gnn::features::N_CAND;
                    let _ = req.reply.send(vec![1.0 / n as f32; n]);
                }
            }
        }
    }
}

/// Create the channel pair for a serve loop.
pub fn eval_channel() -> (EvalClient, Receiver<EvalRequest>) {
    let (tx, rx) = channel();
    (EvalClient { tx }, rx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn service_ready() -> bool {
        std::path::Path::new("artifacts/gnn_infer.hlo.txt").exists()
    }

    #[test]
    fn parallel_clients_get_answers_and_batching_happens() {
        if !service_ready() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let (client, rx) = eval_channel();
        let handle = thread::spawn(move || {
            let svc = GnnService::load("artifacts").unwrap();
            let params =
                crate::gnn::params::load_params("artifacts/params_init.bin").unwrap();
            serve(&svc, &params, rx)
        });

        let workers: Vec<_> = (0..4)
            .map(|_| {
                let c = client.clone();
                thread::spawn(move || {
                    let mut ok = 0;
                    for _ in 0..6 {
                        let pos = Position::zero();
                        let pr = c.eval(pos).expect("reply");
                        assert_eq!(pr.len(), crate::gnn::features::N_CAND);
                        assert!(pr.iter().all(|p| p.is_finite()));
                        ok += 1;
                    }
                    ok
                })
            })
            .collect();
        let total: usize = workers.into_iter().map(|w| w.join().unwrap()).sum();
        drop(client);
        let stats = handle.join().unwrap();
        assert_eq!(total, 24);
        assert_eq!(stats.requests, 24);
        assert!(stats.batches <= 24);
        assert!(stats.mean_batch_size() >= 1.0);
    }
}
