//! Graph analyzer (paper §4.1.1): simplification and splittability checks.
//!
//! * **Simplify** — remove `Identity` / `NoOp` nodes (rewiring their
//!   consumers to their producer) and *dangling* ops that are not
//!   ancestors of any optimizer (`Apply`) op.
//! * **Annotate** — ops carry their [`Splittability`] from the model zoo;
//!   the analyzer validates the annotation invariants that the compiler
//!   relies on (gradients are `Sum`, applies are `NoSplit`).

use super::ir::{CompGraph, Op, OpId, OpKind, Splittability};

/// Result of analysis, mapping old op ids to new ones.
pub struct Analysis {
    pub graph: CompGraph,
    /// old id -> new id (None if the op was removed).
    pub remap: Vec<Option<OpId>>,
    pub removed_identity: usize,
    pub removed_dangling: usize,
}

/// Simplify the graph per §4.1.1.
pub fn simplify(g: &CompGraph) -> Analysis {
    let n = g.len();

    // 1. Resolve identity chains: follow through Identity/NoOp producers.
    let mut through: Vec<OpId> = (0..n).collect();
    for i in 0..n {
        if matches!(g.ops[i].kind, OpKind::Identity | OpKind::NoOp) {
            // An identity forwards its (single) input; a NoOp with no
            // inputs resolves to itself and is later dropped as dangling.
            if let Some(&src) = g.ops[i].inputs.first() {
                through[i] = through[src];
            }
        }
    }

    // 2. Mark ops reachable (as ancestors) from any Apply op, walking
    //    through resolved inputs.  If the graph has no Apply ops at all
    //    (inference graphs), keep ancestors of terminal ops instead.
    let roots: Vec<OpId> = {
        let apply: Vec<OpId> =
            (0..n).filter(|&i| g.ops[i].is_apply()).collect();
        if apply.is_empty() {
            let cons = g.consumers();
            (0..n)
                .filter(|&i| {
                    cons[i].is_empty()
                        && !matches!(g.ops[i].kind, OpKind::Identity | OpKind::NoOp)
                })
                .collect()
        } else {
            apply
        }
    };
    let mut live = vec![false; n];
    let mut stack: Vec<OpId> = roots;
    while let Some(i) = stack.pop() {
        if live[i] {
            continue;
        }
        live[i] = true;
        for &j in &g.ops[i].inputs {
            let r = through[j];
            if !live[r] {
                stack.push(r);
            }
            // Keep walking the chain's own inputs resolved.
        }
    }

    // 3. Emit the simplified graph.
    let mut out = CompGraph::new(g.name.clone(), g.batch_size);
    let mut remap: Vec<Option<OpId>> = vec![None; n];
    let mut removed_identity = 0;
    let mut removed_dangling = 0;
    for i in 0..n {
        if matches!(g.ops[i].kind, OpKind::Identity | OpKind::NoOp) {
            removed_identity += 1;
            continue;
        }
        if !live[i] {
            removed_dangling += 1;
            continue;
        }
        let op = &g.ops[i];
        let new_inputs: Vec<OpId> = op
            .inputs
            .iter()
            .map(|&j| remap[through[j]].expect("topological order violated"))
            .collect();
        let new_kind = match op.kind {
            OpKind::Grad { wrt } => OpKind::Grad {
                wrt: remap[through[wrt]].expect("grad target removed"),
            },
            OpKind::Apply { var } => OpKind::Apply {
                var: remap[through[var]].expect("apply target removed"),
            },
            k => k,
        };
        let id = out.add(Op { kind: new_kind, inputs: new_inputs, ..op.clone() });
        remap[i] = Some(id);
    }

    Analysis { graph: out, remap, removed_identity, removed_dangling }
}

/// Validate splittability invariants the compiler depends on.
/// Returns a list of violations (empty = OK).
pub fn check_annotations(g: &CompGraph) -> Vec<String> {
    let mut errs = Vec::new();
    for (i, op) in g.ops.iter().enumerate() {
        match op.kind {
            OpKind::Grad { .. } => {
                if op.splittability != Splittability::Sum {
                    errs.push(format!(
                        "op {i} ({}): gradient producers must be Sum-splittable",
                        op.name
                    ));
                }
            }
            OpKind::Apply { .. } => {
                if op.splittability != Splittability::NoSplit {
                    errs.push(format!(
                        "op {i} ({}): ApplyGradient must be NoSplit",
                        op.name
                    ));
                }
            }
            OpKind::Variable => {
                if op.param_bytes <= 0.0 {
                    errs.push(format!(
                        "op {i} ({}): Variable with no parameter bytes",
                        op.name
                    ));
                }
            }
            _ => {}
        }
    }
    errs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ir::OpBuilder;

    /// x -> id -> mm(w) -> gw -> apply ; plus a dangling branch.
    fn graph_with_noise() -> CompGraph {
        let mut g = CompGraph::new("noise", 4);
        let x = g.add(OpBuilder::new("x", "Placeholder").kind(OpKind::Placeholder).build());
        let id = g.add(
            OpBuilder::new("id", "Identity").kind(OpKind::Identity).inputs(&[x]).build(),
        );
        let w = g.add(
            OpBuilder::new("w", "Variable").kind(OpKind::Variable).param_bytes(64.0).build(),
        );
        let mm = g.add(
            OpBuilder::new("mm", "MatMul").flops(100.0).out_bytes(32.0).inputs(&[id, w]).build(),
        );
        let gw = g.add(
            OpBuilder::new("gw", "MatMul")
                .kind(OpKind::Grad { wrt: w })
                .split(Splittability::Sum)
                .inputs(&[mm, x])
                .build(),
        );
        g.add(
            OpBuilder::new("ap", "ApplyGradient")
                .kind(OpKind::Apply { var: w })
                .split(Splittability::NoSplit)
                .inputs(&[gw, w])
                .build(),
        );
        // dangling: a summary op nobody applies
        let s = g.add(OpBuilder::new("summary", "Cast").inputs(&[mm]).build());
        g.add(OpBuilder::new("print", "Print").kind(OpKind::NoOp).inputs(&[s]).build());
        g
    }

    #[test]
    fn simplify_removes_identity_and_dangling() {
        let g = graph_with_noise();
        let a = simplify(&g);
        assert_eq!(a.removed_identity, 2); // id + print(NoOp)
        assert_eq!(a.removed_dangling, 1); // summary
        assert_eq!(a.graph.len(), 5);
        assert!(a.graph.check_acyclic());
        // mm's first input must now be x directly.
        let mm = a.remap[3].unwrap();
        let x = a.remap[0].unwrap();
        assert_eq!(a.graph.ops[mm].inputs[0], x);
    }

    #[test]
    fn simplify_preserves_grad_apply_links() {
        let g = graph_with_noise();
        let a = simplify(&g);
        let pairs = a.graph.grad_apply_pairs();
        assert_eq!(pairs.len(), 1);
        let (gw, ap) = pairs[0];
        assert!(a.graph.ops[gw].is_grad());
        assert!(a.graph.ops[ap].is_apply());
    }

    #[test]
    fn simplify_inference_graph_keeps_terminals() {
        let mut g = CompGraph::new("inf", 1);
        let x = g.add(OpBuilder::new("x", "Placeholder").kind(OpKind::Placeholder).build());
        let y = g.add(OpBuilder::new("relu", "Relu").inputs(&[x]).build());
        g.add(OpBuilder::new("out", "Softmax").inputs(&[y]).build());
        let a = simplify(&g);
        assert_eq!(a.graph.len(), 3);
    }

    #[test]
    fn idempotent() {
        let g = graph_with_noise();
        let once = simplify(&g);
        let twice = simplify(&once.graph);
        assert_eq!(once.graph.len(), twice.graph.len());
        assert_eq!(twice.removed_identity, 0);
        assert_eq!(twice.removed_dangling, 0);
    }

    #[test]
    fn annotations_checked() {
        let mut g = CompGraph::new("bad", 1);
        let w = g.add(
            OpBuilder::new("w", "Variable").kind(OpKind::Variable).param_bytes(4.0).build(),
        );
        g.add(
            OpBuilder::new("gw", "MatMul")
                .kind(OpKind::Grad { wrt: w })
                .split(Splittability::Concat) // wrong!
                .inputs(&[w])
                .build(),
        );
        let errs = check_annotations(&g);
        assert_eq!(errs.len(), 1);
        assert!(errs[0].contains("Sum-splittable"));
    }

    #[test]
    fn model_zoo_graphs_are_clean() {
        for g in crate::models::all_models_small() {
            let a = simplify(&g);
            assert!(check_annotations(&a.graph).is_empty(), "{}", g.name);
            assert!(a.graph.check_acyclic(), "{}", g.name);
        }
    }
}
