//! Op grouping (paper §4.1.1 "Grouping ops"): partition the computation
//! graph into at most [`DEFAULT_GROUPS`] op groups using the multilevel
//! partitioner with tensor sizes as edge weights and computation time as
//! node balancing weights (balance factor 2), then build the group-level
//! graph that the strategy creator and the fast simulator consume.

use std::collections::HashMap;

use crate::graph::ir::{CompGraph, OpId, OpKind};
use crate::partition::{partition, PartGraph};
use crate::profile::CostModel;

/// The paper's default partition count ("we find that 60 groups achieve a
/// good trade-off").
pub const DEFAULT_GROUPS: usize = 60;
/// The paper's METIS balance factor.
pub const BALANCE_FACTOR: f64 = 2.0;

/// One op group (a node of the graph handed to the strategy creator).
#[derive(Clone, Debug)]
pub struct OpGroup {
    pub ops: Vec<OpId>,
    /// Full-batch computation time, averaged over profiled GPU types (s).
    pub comp_time: f64,
    /// Trainable parameter bytes held by this group.
    pub param_bytes: f64,
    /// Peak bytes of live activations produced inside the group
    /// (coarse per-group memory estimate).
    pub activation_bytes: f64,
    /// (grad op, apply op) pairs whose grad producer lives here —
    /// the synchronization points if this group is replicated.
    pub grad_pairs: Vec<(OpId, OpId)>,
    /// Sum of gradient tensor bytes of those pairs.
    pub grad_bytes: f64,
}

/// Group-level view of a computation graph.
#[derive(Clone, Debug)]
pub struct GroupGraph {
    pub groups: Vec<OpGroup>,
    /// Directed tensor volume between groups, bytes: `edges[i][j]`
    /// (normalized forward: i < j in schedule order, see below).
    pub edges: Vec<Vec<f64>>,
    /// op -> group.
    pub assignment: Vec<usize>,
    /// Groups are index-ordered by schedule position (average topological
    /// index of member ops), so `edges[i][j]` with `i < j` is forward.
    pub model_name: String,
    pub batch_size: usize,
}

impl GroupGraph {
    pub fn num_groups(&self) -> usize {
        self.groups.len()
    }

    /// Total forward tensor volume crossing group boundaries.
    pub fn total_cut_bytes(&self) -> f64 {
        self.edges.iter().flatten().sum()
    }

    /// Group indices ordered by descending computation time — the order
    /// in which MCTS decides strategies (§4.2.2).
    pub fn by_comp_time_desc(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.groups.len()).collect();
        idx.sort_by(|&a, &b| {
            self.groups[b]
                .comp_time
                .partial_cmp(&self.groups[a].comp_time)
                .unwrap()
        });
        idx
    }
}

/// Build the group graph: partition, then aggregate.
pub fn group_ops(
    g: &CompGraph,
    cost: &CostModel,
    max_groups: usize,
    seed: u64,
) -> GroupGraph {
    let n = g.len();
    let k = max_groups.min(n).max(1);

    // Partitioning graph: node weight = avg comp time (+ epsilon so
    // zero-cost ops still balance), edge weight = tensor bytes.
    let mut pg = PartGraph::new(n);
    for i in 0..n {
        pg.node_w[i] = cost.op_time_avg(i) + 1e-9;
    }
    for (i, op) in g.ops.iter().enumerate() {
        for &j in &op.inputs {
            pg.add_edge(j, i, g.ops[j].output_bytes.max(1.0));
        }
    }
    let raw_labels = partition(&pg, k, BALANCE_FACTOR, seed);

    // Order groups by average topological index so the group index order
    // is a valid schedule order (used to normalize edge directions).
    let mut topo_sum = vec![0.0f64; k];
    let mut count = vec![0usize; k];
    for (i, &l) in raw_labels.iter().enumerate() {
        topo_sum[l] += i as f64;
        count[l] += 1;
    }
    let mut order: Vec<usize> = (0..k).filter(|&l| count[l] > 0).collect();
    order.sort_by(|&a, &b| {
        (topo_sum[a] / count[a] as f64)
            .partial_cmp(&(topo_sum[b] / count[b] as f64))
            .unwrap()
    });
    let mut relabel = vec![usize::MAX; k];
    for (new, &old) in order.iter().enumerate() {
        relabel[old] = new;
    }
    let kk = order.len();
    let assignment: Vec<usize> = raw_labels.iter().map(|&l| relabel[l]).collect();

    // Aggregate group stats.
    let mut groups: Vec<OpGroup> = (0..kk)
        .map(|_| OpGroup {
            ops: Vec::new(),
            comp_time: 0.0,
            param_bytes: 0.0,
            activation_bytes: 0.0,
            grad_pairs: Vec::new(),
            grad_bytes: 0.0,
        })
        .collect();
    for (i, op) in g.ops.iter().enumerate() {
        let gi = assignment[i];
        groups[gi].ops.push(i);
        groups[gi].comp_time += cost.op_time_avg(i);
        groups[gi].param_bytes += op.param_bytes;
        if !matches!(op.kind, OpKind::Variable) {
            groups[gi].activation_bytes += op.output_bytes;
        }
    }
    let grad_pairs = g.grad_apply_pairs();
    let mut grad_of_group: HashMap<usize, Vec<(OpId, OpId)>> = HashMap::new();
    for (grad, apply) in grad_pairs {
        grad_of_group.entry(assignment[grad]).or_default().push((grad, apply));
    }
    for (gi, pairs) in grad_of_group {
        groups[gi].grad_bytes =
            pairs.iter().map(|&(gr, _)| g.ops[gr].output_bytes).sum();
        groups[gi].grad_pairs = pairs;
    }

    // Inter-group tensor volume, normalized to forward direction.
    let mut edges = vec![vec![0.0f64; kk]; kk];
    for (i, op) in g.ops.iter().enumerate() {
        let gi = assignment[i];
        for &j in &op.inputs {
            let gj = assignment[j];
            if gi != gj {
                let (a, b) = if gj < gi { (gj, gi) } else { (gi, gj) };
                edges[a][b] += g.ops[j].output_bytes;
            }
        }
    }

    GroupGraph {
        groups,
        edges,
        assignment,
        model_name: g.name.clone(),
        batch_size: g.batch_size,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{GTX1080TI, V100_16G};
    use crate::models;

    fn grouped(model: crate::graph::CompGraph, k: usize) -> GroupGraph {
        let cost = CostModel::profile(&model.ops, &[V100_16G, GTX1080TI], 0.0, 1);
        group_ops(&model, &cost, k, 42)
    }

    #[test]
    fn respects_group_limit_and_covers_all_ops() {
        let m = models::vgg19(8, 0.25);
        let n = m.len();
        let gg = grouped(m, DEFAULT_GROUPS);
        assert!(gg.num_groups() <= DEFAULT_GROUPS);
        assert_eq!(gg.assignment.len(), n);
        let total_ops: usize = gg.groups.iter().map(|g| g.ops.len()).sum();
        assert_eq!(total_ops, n);
    }

    #[test]
    fn group_stats_conserve_totals() {
        let m = models::bert(4, false, 0.25);
        let total_params = m.total_param_bytes();
        let gg = grouped(m, 30);
        let sum: f64 = gg.groups.iter().map(|g| g.param_bytes).sum();
        assert!((sum - total_params).abs() < 1.0);
        assert!(gg.groups.iter().all(|g| !g.ops.is_empty()));
    }

    #[test]
    fn grad_pairs_assigned_to_producing_group() {
        let m = models::vgg19(8, 0.25);
        let pairs = m.grad_apply_pairs().len();
        let gg = grouped(m, 40);
        let sum: usize = gg.groups.iter().map(|g| g.grad_pairs.len()).sum();
        assert_eq!(sum, pairs);
        let grad_bytes: f64 = gg.groups.iter().map(|g| g.grad_bytes).sum();
        assert!(grad_bytes > 0.0);
    }

    #[test]
    fn edges_are_upper_triangular() {
        let m = models::resnet101(8, 0.25);
        let gg = grouped(m, 24);
        for i in 0..gg.num_groups() {
            for j in 0..=i {
                assert_eq!(gg.edges[i][j], 0.0, "edge {i}->{j} not normalized");
            }
        }
        assert!(gg.total_cut_bytes() > 0.0);
    }

    #[test]
    fn comp_time_order_is_descending() {
        let m = models::inception_v3(8, 0.25);
        let gg = grouped(m, 20);
        let order = gg.by_comp_time_desc();
        for w in order.windows(2) {
            assert!(gg.groups[w[0]].comp_time >= gg.groups[w[1]].comp_time);
        }
    }

    #[test]
    fn fewer_groups_than_requested_when_graph_tiny() {
        let mut g = CompGraph::new("tiny", 1);
        use crate::graph::ir::OpBuilder;
        let a = g.add(OpBuilder::new("a", "Placeholder").build());
        g.add(OpBuilder::new("b", "Relu").flops(10.0).inputs(&[a]).build());
        let cost = CostModel::profile(&g.ops, &[V100_16G], 0.0, 1);
        let gg = group_ops(&g, &cost, 60, 1);
        assert!(gg.num_groups() <= 2);
    }
}
