//! Computation-graph IR, analysis and op grouping (paper §4.1).
//!
//! * [`ir`] — the internal DAG representation that the graph analyzer
//!   builds, independent of any frontend API.
//! * [`analyzer`] — graph simplification (identity/NoOp/dangling removal)
//!   and splittability annotation.
//! * [`grouping`] — METIS-style grouping of tightly coupled ops into at
//!   most [`grouping::DEFAULT_GROUPS`] op groups.

pub mod analyzer;
pub mod grouping;
pub mod ir;

pub use grouping::{GroupGraph, OpGroup};
pub use ir::{CompGraph, Op, OpId, OpKind, Splittability};
