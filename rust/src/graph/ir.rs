//! Internal representation of a DNN computation graph.
//!
//! Mirrors the paper's graph analyzer contract (§4.1.1): each node is an
//! op annotated with its compute cost, the size of the tensor it produces,
//! any parameter storage it owns, and its *splittability* category, which
//! the compiler later uses to insert Split / Concat / AddN ops while
//! preserving mathematical equivalence.

use std::collections::HashMap;

pub type OpId = usize;

/// How an op behaves when its input tensors are split in the batch
/// dimension (paper §4.1.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Splittability {
    /// Output of a split invocation can be concatenated in the batch dim
    /// to recover the full tensor (element-wise ops, batched Conv2D, ...).
    Concat,
    /// Outputs of split invocations must be summed element-wise
    /// (gradient producers, e.g. `Conv2DBackpropFilter`).
    Sum,
    /// Cannot accept split inputs; inputs must be aggregated first
    /// (`ApplyGradient` and friends).
    NoSplit,
}

/// Structural role of an op. `Grad { wrt }` marks gradient producers,
/// which is what the SFB optimizer and the synchronization-insertion
/// logic key on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    /// Training-data input.
    Placeholder,
    /// Trainable parameter storage (its `param_bytes` is the tensor size).
    Variable,
    /// Ordinary forward/backward compute.
    Compute,
    /// Produces the gradient of variable `wrt`.
    Grad { wrt: OpId },
    /// Applies the gradient of variable `var` (consumes grad + variable).
    Apply { var: OpId },
    /// Frontend no-ops removed by the analyzer.
    Identity,
    NoOp,
}

/// One node of the computation graph.
#[derive(Clone, Debug)]
pub struct Op {
    pub name: String,
    /// Frontend op type (`"Conv2D"`, `"MatMul"`, ...) — used for the SFB
    /// duplication census (Table 6) and debugging; the strategy machinery
    /// itself never keys on it (the paper stresses TAG is op-agnostic).
    pub op_type: &'static str,
    pub kind: OpKind,
    /// Forward-pass floating point operations for a *full batch*.
    pub flops: f64,
    /// Size of the produced output tensor in bytes (full batch).
    pub output_bytes: f64,
    /// Parameter bytes owned (only for `Variable` ops).
    pub param_bytes: f64,
    pub splittability: Splittability,
    /// Producers of this op's inputs.
    pub inputs: Vec<OpId>,
}

impl Op {
    pub fn is_param(&self) -> bool {
        matches!(self.kind, OpKind::Variable)
    }
    pub fn is_grad(&self) -> bool {
        matches!(self.kind, OpKind::Grad { .. })
    }
    pub fn is_apply(&self) -> bool {
        matches!(self.kind, OpKind::Apply { .. })
    }
}

/// A DNN computation graph (forward + backward + optimizer ops).
#[derive(Clone, Debug, Default)]
pub struct CompGraph {
    pub name: String,
    /// Global (full) batch size the graph was built for.
    pub batch_size: usize,
    pub ops: Vec<Op>,
}

impl CompGraph {
    pub fn new(name: impl Into<String>, batch_size: usize) -> Self {
        Self { name: name.into(), batch_size, ops: Vec::new() }
    }

    /// Append an op; inputs must already exist (enforces DAG by
    /// construction).
    pub fn add(&mut self, op: Op) -> OpId {
        for &i in &op.inputs {
            assert!(i < self.ops.len(), "input {i} of {} not yet defined", op.name);
        }
        self.ops.push(op);
        self.ops.len() - 1
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Consumers of each op (inverse adjacency).
    pub fn consumers(&self) -> Vec<Vec<OpId>> {
        let mut out = vec![Vec::new(); self.ops.len()];
        for (i, op) in self.ops.iter().enumerate() {
            for &j in &op.inputs {
                out[j].push(i);
            }
        }
        out
    }

    /// Total parameter bytes in the model.
    pub fn total_param_bytes(&self) -> f64 {
        self.ops.iter().map(|o| o.param_bytes).sum()
    }

    /// Total forward+backward flops for a full batch.
    pub fn total_flops(&self) -> f64 {
        self.ops.iter().map(|o| o.flops).sum()
    }

    /// Ids in a topological order (inputs before consumers).
    /// `add` enforces this by construction, so it's just the identity,
    /// but callers should not rely on that detail.
    pub fn topo_order(&self) -> Vec<OpId> {
        (0..self.ops.len()).collect()
    }

    /// Verify the DAG invariant (inputs precede consumers) — used by
    /// property tests.
    pub fn check_acyclic(&self) -> bool {
        self.ops.iter().enumerate().all(|(i, op)| op.inputs.iter().all(|&j| j < i))
    }

    /// All (gradient-producer, apply-op) pairs: the sites where parameter
    /// synchronization happens, and the inputs to the SFB optimizer.
    pub fn grad_apply_pairs(&self) -> Vec<(OpId, OpId)> {
        let mut grad_of: HashMap<OpId, OpId> = HashMap::new(); // var -> grad op
        for (i, op) in self.ops.iter().enumerate() {
            if let OpKind::Grad { wrt } = op.kind {
                grad_of.insert(wrt, i);
            }
        }
        let mut pairs = Vec::new();
        for (i, op) in self.ops.iter().enumerate() {
            if let OpKind::Apply { var } = op.kind {
                if let Some(&g) = grad_of.get(&var) {
                    pairs.push((g, i));
                }
            }
        }
        pairs
    }
}

/// Convenience builder used by the model zoo and tests.
pub struct OpBuilder {
    op: Op,
}

impl OpBuilder {
    pub fn new(name: impl Into<String>, op_type: &'static str) -> Self {
        Self {
            op: Op {
                name: name.into(),
                op_type,
                kind: OpKind::Compute,
                flops: 0.0,
                output_bytes: 0.0,
                param_bytes: 0.0,
                splittability: Splittability::Concat,
                inputs: Vec::new(),
            },
        }
    }
    pub fn kind(mut self, k: OpKind) -> Self {
        self.op.kind = k;
        self
    }
    pub fn flops(mut self, f: f64) -> Self {
        self.op.flops = f;
        self
    }
    pub fn out_bytes(mut self, b: f64) -> Self {
        self.op.output_bytes = b;
        self
    }
    pub fn param_bytes(mut self, b: f64) -> Self {
        self.op.param_bytes = b;
        self
    }
    pub fn split(mut self, s: Splittability) -> Self {
        self.op.splittability = s;
        self
    }
    pub fn inputs(mut self, ins: &[OpId]) -> Self {
        self.op.inputs = ins.to_vec();
        self
    }
    pub fn build(self) -> Op {
        self.op
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_graph() -> CompGraph {
        let mut g = CompGraph::new("tiny", 8);
        let x = g.add(OpBuilder::new("x", "Placeholder").kind(OpKind::Placeholder).build());
        let w = g.add(
            OpBuilder::new("w", "Variable")
                .kind(OpKind::Variable)
                .param_bytes(1024.0)
                .build(),
        );
        let mm = g.add(
            OpBuilder::new("mm", "MatMul")
                .flops(1e6)
                .out_bytes(4096.0)
                .inputs(&[x, w])
                .build(),
        );
        let gw = g.add(
            OpBuilder::new("gw", "MatMul")
                .kind(OpKind::Grad { wrt: w })
                .flops(1e6)
                .out_bytes(1024.0)
                .split(Splittability::Sum)
                .inputs(&[mm, x])
                .build(),
        );
        g.add(
            OpBuilder::new("apply_w", "ApplyGradient")
                .kind(OpKind::Apply { var: w })
                .split(Splittability::NoSplit)
                .inputs(&[gw, w])
                .build(),
        );
        g
    }

    #[test]
    fn build_and_invariants() {
        let g = tiny_graph();
        assert_eq!(g.len(), 5);
        assert!(g.check_acyclic());
        assert_eq!(g.total_param_bytes(), 1024.0);
        assert_eq!(g.total_flops(), 2e6);
    }

    #[test]
    fn consumers_inverse_adjacency() {
        let g = tiny_graph();
        let cons = g.consumers();
        assert_eq!(cons[0], vec![2, 3]); // x feeds mm and gw
        assert_eq!(cons[1], vec![2, 4]); // w feeds mm and apply
        assert_eq!(cons[2], vec![3]);
        assert_eq!(cons[3], vec![4]);
        assert!(cons[4].is_empty());
    }

    #[test]
    fn grad_apply_pairs_found() {
        let g = tiny_graph();
        let pairs = g.grad_apply_pairs();
        assert_eq!(pairs, vec![(3, 4)]);
    }

    #[test]
    #[should_panic(expected = "not yet defined")]
    fn forward_reference_panics() {
        let mut g = CompGraph::new("bad", 1);
        g.add(OpBuilder::new("dangling", "Add").inputs(&[7]).build());
    }
}
