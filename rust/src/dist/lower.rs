//! Group-level strategy lowering + simulation (paper §4.2.2/§4.3.2): the
//! MCTS hot path.
//!
//! ## Simulation model
//!
//! Resources (for a topology with `M` device groups / machines):
//!
//! * `0..M` — one gang-scheduled compute slot per machine (a group's
//!   replicas on the machine's GPUs run in lockstep, so the machine is
//!   the scheduling granularity; per-device batch shares set durations).
//! * `M..2M` — one NIC per machine.  Inter-machine tensor transfers
//!   serialize on a NIC (scatter on the source side, deficit-gathers on
//!   the destination side), which is what makes "spray op groups across
//!   machines" cost what it does on real clusters.
//! * `2M` — the collective channel: gradient AllReduce/PS syncs and SFB
//!   broadcasts serialize here, overlapping compute unless the strategy
//!   sets the in-graph-replication `sync_barrier`.
//!
//! Durations come from the profiler's fitted models: per-(group, GPU)
//! summed linear batch-time models for compute, the fitted GRPC curve
//! for transfers, and the ring/PS formulas for syncs.  Every bandwidth
//! is a **routed query** against the topology's link graph; on flat
//! clique topologies these reproduce the pre-link-graph matrix bit for
//! bit.  On routed (switched) topologies each inter-machine transfer
//! additionally carries its route's link footprint
//! ([`crate::sim::LinkLoad`]) so concurrent transfers sharing a link —
//! an oversubscribed spine, a host bridge — contend in the simulator,
//! and collective times charge their paths' accumulated latency.
//!
//! Per-placement-mask link characteristics (`tau`, worst path latency)
//! are memoized next to the mask's device expansion; hit rates ride in
//! plan telemetry alongside the evaluation memo's.
//!
//! ## Incremental (delta) evaluation
//!
//! An MCTS step typically flips one group's action and re-evaluates; the
//! evaluation memo only helps on exact signature repeats.  Two layers
//! exploit that locality (both default-on, disabled together by
//! [`Lowering::set_delta`] — the `--no-delta` escape hatch):
//!
//! * **Fragment-cached lowering** — everything about lowering one group
//!   (clamped base compute durations, the MP internal-comm task, the
//!   plan-free sync duration) or one inter-group edge (per-consumer-
//!   machine emission decisions and transfer tasks) depends only on the
//!   endpoints' resolved actions and the split mode.  Those pieces are
//!   fetched from the shared [`FragmentStore`]
//!   ([`super::fragments`]), so a re-lowering recomputes only the
//!   flipped groups' fragments and replays every other group's verbatim.
//! * **Frontier-restart simulation** — each evaluation keeps its lowered
//!   graph, per-task construction keys, and [`Schedule`] in a small
//!   neighbor ring.  When a new signature differs from a ring entry in
//!   `1..=`[`DELTA_MAX_FLIPS`] group words, the graphs are matched task
//!   by construction site, a divergence horizon is proven (see
//!   [`divergence_horizon`]), and [`Simulator::resume`] replays the
//!   unchanged schedule prefix instead of re-simulating from t=0.
//!
//! Both layers replay bit-identical values of the same pure
//! computations, so `evaluate` with delta on returns **bit-identical**
//! outcomes (time, OOM, every `Feedback` field) to a from-scratch
//! evaluation — pinned by `rust/tests/properties.rs` over a random flip
//! corpus.  Delta hit counters aggregate in the shared store and ride in
//! plan telemetry as `delta_hit_rate` / `frontier_restart_frac`.
//!
//! ## Batch shares per replication option
//!
//! * `AllReduce`/`Ps` — data parallel over the placement's devices
//!   ([`SplitMode::Even`] or proportional-to-capability), gradients
//!   synchronized on the channel.
//! * `Duplicate` — every device computes the full batch on broadcast
//!   inputs; identical gradients, no sync (the SFB execution vehicle).
//! * `ModelParallel` — the group's ops are partitioned across devices
//!   (capability-proportional, [`MP_IMBALANCE`] slack), full batch, no
//!   replication; an internal-communication task charges the cut tensors
//!   ([`MP_INTERNAL_COMM_FRAC`] of the group's activations) at the
//!   placement's bottleneck bandwidth.
//!
//! ## Memory / OOM
//!
//! Peak per-device memory is estimated analytically: replicated
//! parameters count [`PARAM_MEM_FACTOR`]× (weights + gradients; optimizer
//! slots are part of the activation inventory), live activations count
//! [`ACT_LIVE_FRAC`] of the group's produced-tensor bytes scaled by the
//! device's batch share.  Any device above its capacity marks the
//! outcome OOM (reward −1 in the search).

use std::cell::{Cell, RefCell};
use std::collections::{HashMap, VecDeque};
use std::rc::Rc;
use std::sync::Arc;

use crate::cluster::{DeviceId, LinkProfile, Topology};
use crate::graph::grouping::GroupGraph;
use crate::profile::{CommModel, CostModel};
use crate::sfb::SfbPlan;
use crate::sim::{LinkLoad, Schedule, Simulator, Task, TaskGraph, TaskKind};
use crate::strategy::{full_mask, Action, ReplOption, SplitMode, Strategy};

use super::fragments::{
    DeltaStats, EdgeEmit, EdgeFragment, EdgeKey, EvalCaches, FragmentStore, GroupFragment,
    GroupKey, MaskProfileMemo, PenaltyFragment, TransferFragment,
};
use super::memo::MemoTable;

/// Weights + gradients per replicated parameter byte (Adam slots are
/// already in the activation inventory).
pub const PARAM_MEM_FACTOR: f64 = 2.0;
/// Fraction of a group's produced-tensor bytes live at the peak.
pub const ACT_LIVE_FRAC: f64 = 0.40;
/// Fraction of a group's activation bytes crossing the internal cut when
/// the group is model-parallelized.
pub const MP_INTERNAL_COMM_FRAC: f64 = 0.25;
/// Partition-imbalance slack of the internal METIS split.
pub const MP_IMBALANCE: f64 = 1.10;

/// Maximum number of differing group words for a ring entry to qualify
/// as a delta neighbor (flips beyond this re-lower too much of the graph
/// for frontier restart to pay off).
pub const DELTA_MAX_FLIPS: usize = 4;
/// Recent evaluations kept as frontier-restart candidates.
const NEIGHBOR_RING: usize = 4;

// Construction-site keys: every pushed task gets a stable u64 key
// identifying *where in the lowering* it came from (section tag in the
// top bits), unique within one build.  Matching two lowered graphs by
// key is what lets the delta path align tasks across signature flips.
const KEY_COMP: u64 = 1 << 60;
const KEY_PENALTY: u64 = 2 << 60;
const KEY_EDGE: u64 = 3 << 60;
const KEY_BARRIER: u64 = 4 << 60;
const KEY_SYNC: u64 = 5 << 60;
const KEY_BCAST: u64 = 6 << 60;

/// The evaluation memo's per-group word: `(mask << 3) | option` — also
/// the fragment-store key encoding.
fn action_word(a: Action) -> u32 {
    (a.mask as u32) << 3 | a.option.index() as u32
}

/// Runtime-feedback features extracted from the simulated schedule
/// (part 3 of Table 1; consumed by `gnn::features`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Feedback {
    /// Latest finish time of any task attributed to the group (s).
    pub group_makespan: Vec<f64>,
    /// Worst wait between an outbound tensor being ready and its
    /// transfer starting (NIC contention), per group (s).
    pub group_idle_before_send: Vec<f64>,
    /// Estimated peak memory / capacity per device group.
    pub devgroup_peak_mem_frac: Vec<f64>,
    /// Idle fraction of each machine's compute slot.
    pub devgroup_idle: Vec<f64>,
    /// Idle fraction of the sending NIC for each machine pair `[a][b]`.
    pub link_idle: Vec<Vec<f64>>,
}

/// What one strategy evaluation returns: simulated per-iteration time,
/// the OOM verdict, and the feedback features.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SimOutcome {
    pub time: f64,
    pub oom: bool,
    pub feedback: Feedback,
}

/// Precomputed per-mask placement info (shared across evaluations).
struct MaskInfo {
    devices: Vec<DeviceId>,
    /// Sorted machine (device-group) indices present in the mask.
    machines: Vec<usize>,
    /// Device count per entry of `machines`.
    counts: Vec<usize>,
    /// Total device count.
    dev_count: usize,
    /// Per-device capability share (eff-FLOPs proportional), per machine.
    frac_cap: Vec<f64>,
    /// Routed bottleneck bandwidth + worst path latency of the mask's
    /// devices — the memoized `Topology::bottleneck_bw_gbps` of the
    /// lowering hot loop (previously recomputed O(n²) per evaluation).
    profile: LinkProfile,
}

impl MaskInfo {
    fn machine_pos(&self, dg: usize) -> Option<usize> {
        self.machines.iter().position(|&m| m == dg)
    }
}

/// Per-group lowered fragments, built once in [`Lowering::new`].
struct Fragments {
    /// `lin[g * M + dg]` = (intercept, slope) of the group's summed
    /// batch-time model on machine `dg`'s GPU type.
    lin: Vec<(f64, f64)>,
    /// Forward inter-group edges `(i, j, bytes)` with `i < j`.
    edges: Vec<(usize, usize, f64)>,
    grad_bytes: Vec<f64>,
    act_bytes: Vec<f64>,
    param_bytes: Vec<f64>,
}

/// One evaluation's lowered graph + schedule, kept for frontier restart.
struct EvalRecord {
    /// The evaluation-memo signature this record was built for.
    sig: Vec<u32>,
    tg: TaskGraph,
    /// Construction-site key per task (parallel to `tg.tasks`).
    keys: Vec<u64>,
    /// key → task id of this record's graph.
    index: HashMap<u64, usize>,
    sched: Schedule,
}

impl Default for EvalRecord {
    fn default() -> Self {
        Self {
            sig: Vec::new(),
            tg: TaskGraph::new(0),
            keys: Vec::new(),
            index: HashMap::new(),
            sched: Schedule::default(),
        }
    }
}

/// Ring of recent evaluations (the frontier-restart candidates) plus a
/// spare record recycled as build scratch so the hot path stops
/// allocating task graphs.
#[derive(Default)]
struct Ring {
    records: VecDeque<EvalRecord>,
    spare: Option<EvalRecord>,
}

impl Ring {
    fn take_scratch(&mut self) -> EvalRecord {
        self.spare.take().unwrap_or_default()
    }

    fn give_back(&mut self, rec: EvalRecord) {
        self.spare = Some(rec);
    }

    fn push(&mut self, rec: EvalRecord) {
        if self.records.len() >= NEIGHBOR_RING {
            self.spare = self.records.pop_front();
        }
        self.records.push_back(rec);
    }

    /// The ring entry whose signature differs from `sig` in the fewest
    /// group words, requiring an identical flags word and a distance in
    /// `1..=DELTA_MAX_FLIPS` (distance 0 is the memo's job); ties go to
    /// the most recent entry.
    fn best_neighbor(&self, sig: &[u32]) -> Option<&EvalRecord> {
        let mut best: Option<(&EvalRecord, usize)> = None;
        for rec in self.records.iter().rev() {
            if rec.sig.len() != sig.len() || rec.sig.last() != sig.last() {
                continue;
            }
            let groups = sig.len() - 1;
            let dist = (0..groups).filter(|&g| rec.sig[g] != sig[g]).count();
            if dist == 0 || dist > DELTA_MAX_FLIPS {
                continue;
            }
            if best.map_or(true, |(_, d)| dist < d) {
                best = Some((rec, dist));
            }
        }
        best.map(|(r, _)| r)
    }
}

struct EvalBuffers {
    sim: Simulator,
    /// Compute-task id per (group, machine), `usize::MAX` = absent.
    comp: Vec<usize>,
    /// MP internal-comm task id per group, `usize::MAX` = absent.
    penalty: Vec<usize>,
    /// Group fragments of the build in flight (sync durations are read
    /// back in the sync section).
    gfrags: Vec<Arc<GroupFragment>>,
    /// Scratch of [`divergence_horizon`]: new-task → old-task id.
    delta_map: Vec<usize>,
    /// New tasks bit-identical to their mapped old task (deps included).
    delta_clean: Vec<bool>,
    /// New tasks matching an old construction site and structure but
    /// with a different duration or link load.
    delta_soft: Vec<bool>,
    /// Old tasks matched by some new task.
    delta_matched: Vec<bool>,
}

fn loads_equal(a: &Option<LinkLoad>, b: &Option<LinkLoad>) -> bool {
    match (a, b) {
        (None, None) => true,
        (Some(a), Some(b)) => {
            a.scalable_s.to_bits() == b.scalable_s.to_bits()
                && (Arc::ptr_eq(&a.links, &b.links) || a.links == b.links)
        }
        _ => false,
    }
}

/// Match `rec`'s tasks against neighbor `nb` by construction-site key
/// and compute the divergence horizon T\*: the earliest time at which a
/// from-scratch simulation of `rec.tg` could differ from `nb`'s
/// schedule.  Fills the caller's scratch vectors; `map`/`clean` feed
/// [`Simulator::resume`] afterwards.
///
/// Tasks classify as **clean** (same site, bit-equal content, all deps
/// clean and mapped — replayable), **soft** (same site, resource, kind,
/// and dep structure, but a different duration or link load), or
/// **dirty** (unmatched).  Difference points, whose minimum is T\*:
///
/// * a soft task with all-clean deps diverges no earlier than its old
///   *dispatch* — FIFO queues order on `(ready, id)` only, and the
///   site-keyed match is monotone in task id (both builds emit sections
///   in one canonical order), so the prefix before that dispatch is
///   unaffected by a payload-only change;
/// * a dirty new task with all-clean deps enters its queue at its ready
///   time (the max of its mapped deps' old finishes);
/// * an old task matched by no new task stops influencing the run at
///   its old dispatch (queued-but-undispatched entries never affect
///   which *other* task a resource pops).
///
/// Every other changed task is downstream of one of the above, so its
/// effects land at or after T\*.  `+∞` means the graphs are bit-
/// identical; `<= 0` means divergence at t=0 (caller falls back to a
/// full run).
fn divergence_horizon(
    rec: &EvalRecord,
    nb: &EvalRecord,
    map: &mut Vec<usize>,
    clean: &mut Vec<bool>,
    soft: &mut Vec<bool>,
    matched_old: &mut Vec<bool>,
) -> f64 {
    let n = rec.tg.tasks.len();
    let n_old = nb.tg.tasks.len();
    map.clear();
    map.resize(n, usize::MAX);
    clean.clear();
    clean.resize(n, false);
    soft.clear();
    soft.resize(n, false);
    matched_old.clear();
    matched_old.resize(n_old, false);

    let mut horizon = f64::INFINITY;
    for i in 0..n {
        let t = &rec.tg.tasks[i];
        let o = nb.index.get(&rec.keys[i]).copied().unwrap_or(usize::MAX);
        // Deps precede their task in the push order, so `map`/`clean`/
        // `soft` of every dep are already decided.
        let structure = o != usize::MAX && {
            let p = &nb.tg.tasks[o];
            t.resource == p.resource
                && t.kind == p.kind
                && t.deps.len() == p.deps.len()
                && t.deps
                    .iter()
                    .zip(&p.deps)
                    .all(|(&dn, &dold)| map[dn] == dold && (clean[dn] || soft[dn]))
        };
        let deps_clean = t.deps.iter().all(|&d| clean[d]);
        if structure {
            let p = &nb.tg.tasks[o];
            map[i] = o;
            matched_old[o] = true;
            let same_payload = t.duration.to_bits() == p.duration.to_bits()
                && loads_equal(&t.load, &p.load);
            if same_payload && deps_clean {
                clean[i] = true;
            } else {
                soft[i] = true;
                if deps_clean {
                    horizon = horizon.min(nb.sched.start[o]);
                }
            }
        } else if deps_clean {
            let ready =
                t.deps.iter().map(|&d| nb.sched.finish[map[d]]).fold(0.0f64, f64::max);
            horizon = horizon.min(ready);
        }
    }
    for o in 0..n_old {
        if !matched_old[o] {
            horizon = horizon.min(nb.sched.start[o]);
        }
    }
    horizon
}

/// The strategy → task-graph compiler with its transposition table.
pub struct Lowering<'a> {
    pub gg: &'a GroupGraph,
    pub topo: &'a Topology,
    pub cost: &'a CostModel,
    pub comm: &'a CommModel,
    /// Group indices in descending computation-time order — the order in
    /// which MCTS decides strategies (§4.2.2).
    pub order: Vec<usize>,
    frag: Fragments,
    masks: RefCell<HashMap<u16, Rc<MaskInfo>>>,
    /// Hit/miss counters of the per-mask cache (placement expansion +
    /// link profile), reported alongside the evaluation memo stats.
    mask_hits: Cell<u64>,
    mask_misses: Cell<u64>,
    /// Shared evaluation caches (transposition table, fragment store,
    /// mask-profile memo): per-worker `Lowering`s of a parallel search
    /// clone this bundle so all three tiers are pooled.
    caches: EvalCaches,
    /// Incremental evaluation on/off (fragment store + frontier
    /// restart together; results are bit-identical either way).
    delta: Cell<bool>,
    ring: RefCell<Ring>,
    buffers: RefCell<EvalBuffers>,
    dp_cache: Cell<f64>,
}

impl<'a> Lowering<'a> {
    pub fn new(
        gg: &'a GroupGraph,
        topo: &'a Topology,
        cost: &'a CostModel,
        comm: &'a CommModel,
    ) -> Self {
        Self::with_caches(gg, topo, cost, comm, EvalCaches::new())
    }

    /// Build a lowering that shares `memo` with other lowerings (fresh
    /// fragment/profile tiers).  Prefer [`Lowering::with_caches`], which
    /// shares all three.
    pub fn with_memo(
        gg: &'a GroupGraph,
        topo: &'a Topology,
        cost: &'a CostModel,
        comm: &'a CommModel,
        memo: Arc<MemoTable>,
    ) -> Self {
        Self::with_caches(
            gg,
            topo,
            cost,
            comm,
            EvalCaches {
                memo,
                fragments: Arc::new(FragmentStore::new()),
                profiles: Arc::new(MaskProfileMemo::new()),
            },
        )
    }

    /// Build a lowering that shares the full evaluation-cache bundle
    /// with other lowerings — how the tree-parallel search workers of
    /// [`crate::search`] pool outcomes, lowered fragments, and link
    /// profiles (each worker owns a `Lowering`, all of them one set of
    /// caches).
    pub fn with_caches(
        gg: &'a GroupGraph,
        topo: &'a Topology,
        cost: &'a CostModel,
        comm: &'a CommModel,
        caches: EvalCaches,
    ) -> Self {
        let m = topo.num_groups();
        let k = gg.num_groups();
        let mut lin = vec![(0.0, 0.0); k * m];
        for dg in 0..m {
            let gpu = &topo.groups[dg].gpu;
            for g in 0..k {
                let mut i_sum = 0.0;
                let mut s_sum = 0.0;
                for &op in &gg.groups[g].ops {
                    let bm = cost.batch_model(op, gpu);
                    i_sum += bm.intercept;
                    s_sum += bm.slope;
                }
                lin[g * m + dg] = (i_sum, s_sum);
            }
        }
        let mut edges = Vec::new();
        for i in 0..k {
            for j in (i + 1)..k {
                if gg.edges[i][j] > 0.0 {
                    edges.push((i, j, gg.edges[i][j]));
                }
            }
        }
        let frag = Fragments {
            lin,
            edges,
            grad_bytes: gg.groups.iter().map(|g| g.grad_bytes).collect(),
            act_bytes: gg.groups.iter().map(|g| g.activation_bytes).collect(),
            param_bytes: gg.groups.iter().map(|g| g.param_bytes).collect(),
        };
        Self {
            order: gg.by_comp_time_desc(),
            gg,
            topo,
            cost,
            comm,
            frag,
            masks: RefCell::new(HashMap::new()),
            mask_hits: Cell::new(0),
            mask_misses: Cell::new(0),
            caches,
            delta: Cell::new(true),
            ring: RefCell::new(Ring::default()),
            buffers: RefCell::new(EvalBuffers {
                sim: Simulator::new(),
                comp: Vec::new(),
                penalty: Vec::new(),
                gfrags: Vec::new(),
                delta_map: Vec::new(),
                delta_clean: Vec::new(),
                delta_soft: Vec::new(),
                delta_matched: Vec::new(),
            }),
            dp_cache: Cell::new(f64::NAN),
        }
    }

    /// Fitted computation time of group `g` on one device of machine
    /// `dev_group` processing a `frac` share of the global batch.
    pub fn group_time_on(&self, g: usize, dev_group: usize, frac: f64) -> f64 {
        let (i, s) = self.frag.lin[g * self.topo.num_groups() + dev_group];
        // clamp (not max) so a NaN from a corrupted cost model propagates
        // to the TaskGraph::push guard instead of silently becoming 0.
        (i + s * frac).clamp(0.0, f64::INFINITY)
    }

    /// Simulated time of the DP-NCCL reference strategy (cached).
    pub fn dp_time(&self) -> f64 {
        let cached = self.dp_cache.get();
        if cached.is_finite() {
            return cached;
        }
        let dp = Strategy::dp_allreduce(self.gg.num_groups(), self.topo);
        let t = self.evaluate(&dp).time;
        self.dp_cache.set(t);
        t
    }

    /// (hits, misses) of the evaluation transposition table.
    pub fn memo_stats(&self) -> (u64, u64) {
        self.caches.memo.stats()
    }

    /// (hits, misses) of the per-placement-mask cache (device expansion
    /// + routed link profile — the memoized bottleneck-bandwidth
    /// satellite).
    pub fn mask_memo_stats(&self) -> (u64, u64) {
        (self.mask_hits.get(), self.mask_misses.get())
    }

    /// Hits / (hits + misses) of the per-mask cache (0.0 when never
    /// probed).
    pub fn mask_memo_hit_rate(&self) -> f64 {
        let (h, m) = self.mask_memo_stats();
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    /// (hits, misses) of the shared cross-worker mask-profile tier
    /// (sequential searches only miss here; hits measure reuse across
    /// workers sharing one [`EvalCaches`]).
    pub fn mask_profile_shared_stats(&self) -> (u64, u64) {
        self.caches.profiles.stats()
    }

    /// Hits / (hits + misses) of the transposition table (0.0 when it
    /// has never been probed).
    pub fn memo_hit_rate(&self) -> f64 {
        self.caches.memo.hit_rate()
    }

    /// (hits, misses) of the shared lowered-fragment store.
    pub fn fragment_stats(&self) -> (u64, u64) {
        self.caches.fragments.stats()
    }

    /// Hits / (hits + misses) of the fragment store (0.0 when never
    /// probed).
    pub fn fragment_hit_rate(&self) -> f64 {
        self.caches.fragments.hit_rate()
    }

    /// Snapshot of the shared delta-simulation counters.
    pub fn delta_stats(&self) -> DeltaStats {
        self.caches.fragments.delta_stats()
    }

    /// Enable/disable incremental evaluation (fragment store + frontier
    /// restart).  Purely a performance knob: outcomes are bit-identical
    /// either way.
    pub fn set_delta(&self, on: bool) {
        self.delta.set(on);
    }

    pub fn delta_enabled(&self) -> bool {
        self.delta.get()
    }

    /// Drop all cached evaluations (used by the cold/warm benchmarks).
    pub fn clear_memo(&self) {
        self.caches.memo.clear();
    }

    /// The shared transposition table, for per-worker lowerings built
    /// through [`Lowering::with_memo`].
    pub fn memo_handle(&self) -> Arc<MemoTable> {
        Arc::clone(&self.caches.memo)
    }

    /// The full shared cache bundle, for per-worker lowerings built
    /// through [`Lowering::with_caches`].
    pub fn caches_handle(&self) -> EvalCaches {
        self.caches.clone()
    }

    /// Resolve a (possibly partial) strategy to per-group effective
    /// actions under the footnote-2 completion rule, with the
    /// all-devices AllReduce default.
    fn resolve(&self, s: &Strategy) -> Vec<Action> {
        let default = Action { mask: full_mask(self.topo), option: ReplOption::AllReduce };
        (0..self.gg.num_groups()).map(|g| s.action_for(g, &self.order, default)).collect()
    }

    /// Exact memo key: resolved action per group + a flags word.
    fn signature(&self, acts: &[Action], s: &Strategy) -> Box<[u32]> {
        let mut key = Vec::with_capacity(acts.len() + 1);
        for &a in acts {
            key.push(action_word(a));
        }
        let flags = u32::from(s.split == SplitMode::Proportional)
            | (u32::from(s.sync_barrier) << 1);
        key.push(flags);
        key.into_boxed_slice()
    }

    fn mask_info(&self, mask: u16) -> Rc<MaskInfo> {
        if let Some(info) = self.masks.borrow().get(&mask) {
            self.mask_hits.set(self.mask_hits.get() + 1);
            return Rc::clone(info);
        }
        self.mask_misses.set(self.mask_misses.get() + 1);
        let devices = self.topo.mask_devices(mask);
        assert!(!devices.is_empty(), "action mask {mask:#x} selects no devices");
        let mut machines: Vec<usize> = devices.iter().map(|d| d.group).collect();
        machines.dedup();
        let counts: Vec<usize> =
            machines.iter().map(|&dg| self.topo.groups[dg].count).collect();
        let total_eff: f64 = devices
            .iter()
            .map(|d| self.topo.groups[d.group].gpu.effective_flops())
            .sum();
        let frac_cap: Vec<f64> = machines
            .iter()
            .map(|&dg| self.topo.groups[dg].gpu.effective_flops() / total_eff)
            .collect();
        // The expensive routed-profile computation is shared across
        // workers; this instance's Rc map stays the first-level tier.
        let profile = self.caches.profiles.get_or(mask, || self.topo.link_profile(&devices));
        let info = Rc::new(MaskInfo {
            dev_count: devices.len(),
            devices,
            machines,
            counts,
            frac_cap,
            profile,
        });
        self.masks.borrow_mut().insert(mask, Rc::clone(&info));
        info
    }

    /// Memoized evaluation of a strategy (the MCTS hot path).
    pub fn evaluate(&self, strategy: &Strategy) -> SimOutcome {
        let acts = self.resolve(strategy);
        let key = self.signature(&acts, strategy);
        if let Some(hit) = self.caches.memo.get(&key) {
            return hit;
        }
        let out = self.evaluate_miss(strategy, &acts, &key);
        self.caches.memo.insert(key, out.clone());
        out
    }

    /// Evaluation bypassing the transposition table (bit-identical to
    /// [`Lowering::evaluate`]; used by property tests and the cold/warm
    /// benchmarks).  Never consults the neighbor ring or the delta
    /// counters.
    pub fn evaluate_uncached(&self, strategy: &Strategy) -> SimOutcome {
        let acts = self.resolve(strategy);
        self.lower_and_simulate_full(strategy, &acts, None)
    }

    /// Evaluate with an SFB plan folded in: covered gradients leave the
    /// sync volume, duplicated ops add per-replica compute, and the
    /// sufficient factors are broadcast on the collective channel.
    pub fn evaluate_with_sfb(&self, strategy: &Strategy, plan: Option<&SfbPlan>) -> SimOutcome {
        match plan {
            None => self.evaluate(strategy),
            Some(p) => {
                let acts = self.resolve(strategy);
                self.lower_and_simulate_full(strategy, &acts, Some(p))
            }
        }
    }

    /// Per-device batch share of machine entry `mi` under the action's
    /// replication option and the strategy's split mode.
    fn dev_frac(&self, a: Action, info: &MaskInfo, mi: usize, split: SplitMode) -> f64 {
        match a.option {
            ReplOption::AllReduce | ReplOption::Ps => match split {
                SplitMode::Even => 1.0 / info.dev_count as f64,
                SplitMode::Proportional => info.frac_cap[mi],
            },
            ReplOption::Duplicate => 1.0,
            ReplOption::ModelParallel => info.frac_cap[mi],
        }
    }

    /// Fraction of an inter-group tensor consumed (or produced) at
    /// machine entry `mi` of the action's placement.
    fn machine_frac(&self, a: Action, info: &MaskInfo, mi: usize, split: SplitMode) -> f64 {
        if a.option == ReplOption::Duplicate {
            return 1.0;
        }
        (self.dev_frac(a, info, mi, split) * info.counts[mi] as f64).min(1.0)
    }

    /// Duration + contention footprint of an inter-machine transfer of
    /// `bytes` from group `src` to group `dst`.  Flat cliques keep the
    /// exact fitted-curve duration and no footprint (bit-identical to
    /// the pre-link-graph lowering); routed topologies split the fixed
    /// latency (curve intercept + route latency) from the
    /// bandwidth-scalable share, which the simulator stretches by
    /// per-link occupancy.
    fn transfer_task_parts(&self, bytes: f64, src: usize, dst: usize) -> (f64, Option<LinkLoad>) {
        let bw = self.topo.group_bw_gbps(src, dst) * 1e9 / 8.0;
        let (fixed, scalable) = self.comm.transfer_parts(bytes, bw);
        if self.topo.is_routed() {
            let route = self.topo.group_route(src, dst);
            (
                fixed + route.latency_s,
                Some(LinkLoad { links: route.links.clone(), scalable_s: scalable }),
            )
        } else {
            (fixed + scalable, None)
        }
    }

    /// Everything about lowering group `g` that depends only on its own
    /// resolved action and the split mode — the cacheable fragment.
    fn make_group_fragment(
        &self,
        g: usize,
        a: Action,
        info: &MaskInfo,
        split: SplitMode,
    ) -> GroupFragment {
        let m = self.topo.num_groups();
        let comp: Vec<f64> = info
            .machines
            .iter()
            .enumerate()
            .map(|(mi, &dg)| {
                let (i0, s0) = self.frag.lin[g * m + dg];
                // NaN-preserving clamps: the push-time duration guard must
                // see a corrupted cost model, not a silent 0.
                match a.option {
                    ReplOption::AllReduce | ReplOption::Ps | ReplOption::Duplicate => {
                        (i0 + s0 * self.dev_frac(a, info, mi, split)).clamp(0.0, f64::INFINITY)
                    }
                    ReplOption::ModelParallel => ((i0 + s0) * info.frac_cap[mi] * MP_IMBALANCE)
                        .clamp(0.0, f64::INFINITY),
                }
            })
            .collect();
        let penalty = (a.option == ReplOption::ModelParallel && info.dev_count > 1).then(|| {
            let bytes = MP_INTERNAL_COMM_FRAC * self.frag.act_bytes[g];
            // Memoized routed bottleneck of the placement + worst
            // path latency (0 on cliques).
            let bw = info.profile.bottleneck_gbps * 1e9 / 8.0;
            let src_dg = info.machines[0];
            let dst_dg = *info.machines.last().unwrap();
            let (fixed, scalable) = self.comm.transfer_parts(bytes, bw);
            // On routed topologies the internal cut traffic occupies
            // the representative cross-placement route, so it both
            // suffers and causes shared-link contention (cliques
            // keep the exact pre-link-graph duration).
            let (duration, load) = if self.topo.is_routed() && src_dg != dst_dg {
                let route = self.topo.group_route(src_dg, dst_dg);
                (
                    fixed + info.profile.max_latency_s,
                    Some(LinkLoad { links: route.links.clone(), scalable_s: scalable }),
                )
            } else {
                (fixed + scalable + info.profile.max_latency_s, None)
            };
            PenaltyFragment { duration, src_dg, dst_dg, load }
        });
        let sync = (matches!(a.option, ReplOption::AllReduce | ReplOption::Ps)
            && info.dev_count >= 2
            && self.frag.grad_bytes[g] > 0.0)
            .then(|| match a.option {
                ReplOption::AllReduce => self.comm.allreduce_time_with(
                    self.frag.grad_bytes[g],
                    info.dev_count,
                    info.profile,
                ),
                _ => {
                    let ps = info.devices[g % info.dev_count];
                    self.comm.ps_time(self.frag.grad_bytes[g], &info.devices, ps, self.topo)
                }
            });
        GroupFragment { comp, penalty, sync }
    }

    /// Everything about lowering one inter-group edge that depends only
    /// on the endpoints' resolved actions and the split mode.
    fn make_edge_fragment(
        &self,
        bytes: f64,
        ai: Action,
        aj: Action,
        fi: &MaskInfo,
        fj: &MaskInfo,
        split: SplitMode,
    ) -> EdgeFragment {
        let m = self.topo.num_groups();
        let emits = fj
            .machines
            .iter()
            .enumerate()
            .map(|(mj, &b)| {
                let need = bytes * self.machine_frac(aj, fj, mj, split);
                if let Some(pi_local) = fi.machine_pos(b) {
                    // Local share is free; gather any deficit from the best
                    // remote producer machine on b's inbound NIC.
                    let have = if ai.option == ReplOption::Duplicate {
                        bytes
                    } else {
                        bytes * self.machine_frac(ai, fi, pi_local, split)
                    };
                    let deficit = (need - have).max(0.0);
                    let remotes: Vec<usize> =
                        fi.machines.iter().copied().filter(|&a| a != b).collect();
                    let transfer = (deficit > 1.0 && !remotes.is_empty()).then(|| {
                        let src = remotes
                            .iter()
                            .copied()
                            .max_by(|&x, &y| {
                                self.topo
                                    .group_bw_gbps(x, b)
                                    .partial_cmp(&self.topo.group_bw_gbps(y, b))
                                    .unwrap()
                                    .then(y.cmp(&x))
                            })
                            .unwrap();
                        let (duration, load) = self.transfer_task_parts(deficit, src, b);
                        TransferFragment { resource: m + b, duration, src, load }
                    });
                    EdgeEmit { local: true, transfer }
                } else {
                    // Remote consumer machine: full needed share travels
                    // from the best producer machine over its NIC.
                    let src = fi
                        .machines
                        .iter()
                        .copied()
                        .max_by(|&x, &y| {
                            self.topo
                                .group_bw_gbps(x, b)
                                .partial_cmp(&self.topo.group_bw_gbps(y, b))
                                .unwrap()
                                .then(y.cmp(&x))
                        })
                        .unwrap();
                    let transfer = (need > 1.0).then(|| {
                        let (duration, load) = self.transfer_task_parts(need, src, b);
                        TransferFragment { resource: m + src, duration, src, load }
                    });
                    EdgeEmit { local: false, transfer }
                }
            })
            .collect();
        EdgeFragment { emits }
    }

    /// Lower `strategy` into `rec`'s task graph (+ construction keys and
    /// key index).  With delta on, group/edge fragments come from the
    /// shared store; with delta off they are computed inline — the
    /// emitted graph is bit-identical either way.
    fn lower_into(
        &self,
        strategy: &Strategy,
        acts: &[Action],
        infos: &[Rc<MaskInfo>],
        plan: Option<&SfbPlan>,
        rec: &mut EvalRecord,
    ) {
        let m = self.topo.num_groups();
        let k = self.gg.num_groups();
        let chan = 2 * m;
        let split = strategy.split;
        let prop = split == SplitMode::Proportional;
        let use_store = self.delta.get();

        let mut bufs = self.buffers.borrow_mut();
        let EvalBuffers { comp, penalty, gfrags, .. } = &mut *bufs;
        let EvalRecord { tg, keys, index, .. } = rec;
        tg.tasks.clear();
        tg.num_resources = 2 * m + 1;
        tg.num_links =
            if self.topo.is_routed() { self.topo.link_graph().num_links() } else { 0 };
        keys.clear();
        comp.clear();
        comp.resize(k * m, usize::MAX);
        penalty.clear();
        penalty.resize(k, usize::MAX);
        gfrags.clear();

        // ---- compute tasks (one per group per machine) + MP internal comm
        for g in 0..k {
            let a = acts[g];
            let info = &infos[g];
            let gkey = GroupKey { group: g as u32, action: action_word(a), proportional: prop };
            let frag = if use_store {
                self.caches.fragments.group(gkey, || self.make_group_fragment(g, a, info, split))
            } else {
                Arc::new(self.make_group_fragment(g, a, info, split))
            };
            for (mi, &dg) in info.machines.iter().enumerate() {
                let mut dur = frag.comp[mi];
                if let Some(p) = plan {
                    dur += p.per_group[g].extra_compute_s;
                }
                comp[g * m + dg] = tg.push(Task {
                    resource: dg,
                    duration: dur,
                    deps: Vec::new(),
                    kind: TaskKind::Compute { group: g, dev_group: dg },
                    load: None,
                });
                keys.push(KEY_COMP | (g as u64) << 16 | dg as u64);
            }
            if let Some(pen) = &frag.penalty {
                let deps: Vec<usize> =
                    info.machines.iter().map(|&dg| comp[g * m + dg]).collect();
                penalty[g] = tg.push(Task {
                    resource: m + pen.src_dg,
                    duration: pen.duration,
                    deps,
                    kind: TaskKind::Transfer {
                        from: g,
                        to: g,
                        src_dg: pen.src_dg,
                        dst_dg: pen.dst_dg,
                    },
                    load: pen.load.clone(),
                });
                keys.push(KEY_PENALTY | g as u64);
            }
            gfrags.push(frag);
        }

        // ---- inter-group tensor transfers (NIC-serialized)
        for (e, &(i, j, bytes)) in self.frag.edges.iter().enumerate() {
            let (ai, aj) = (acts[i], acts[j]);
            let (fi, fj) = (&infos[i], &infos[j]);
            let ekey = EdgeKey {
                edge: e as u32,
                producer: action_word(ai),
                consumer: action_word(aj),
                proportional: prop,
            };
            let frag = if use_store {
                self.caches
                    .fragments
                    .edge(ekey, || self.make_edge_fragment(bytes, ai, aj, fi, fj, split))
            } else {
                Arc::new(self.make_edge_fragment(bytes, ai, aj, fi, fj, split))
            };
            for (mj, &b) in fj.machines.iter().enumerate() {
                let emit = &frag.emits[mj];
                let consumer = comp[j * m + b];
                if emit.local {
                    tg.tasks[consumer].deps.push(comp[i * m + b]);
                }
                if let Some(tr) = &emit.transfer {
                    let mut deps = vec![comp[i * m + tr.src]];
                    if penalty[i] != usize::MAX {
                        deps.push(penalty[i]);
                    }
                    let t = tg.push(Task {
                        resource: tr.resource,
                        duration: tr.duration,
                        deps,
                        kind: TaskKind::Transfer { from: i, to: j, src_dg: tr.src, dst_dg: b },
                        load: tr.load.clone(),
                    });
                    keys.push(KEY_EDGE | (e as u64) << 20 | b as u64);
                    tg.tasks[consumer].deps.push(t);
                }
                if penalty[i] != usize::MAX {
                    tg.tasks[consumer].deps.push(penalty[i]);
                }
            }
        }

        // ---- gradient synchronization + SFB broadcast on the channel
        let mut barrier = usize::MAX;
        for g in 0..k {
            let Some(base_sync) = gfrags[g].sync else { continue };
            let a = acts[g];
            let info = &infos[g];
            let (dur, bcast_bytes) = match plan {
                // The fragment caches the plan-free sync duration.
                None => (base_sync, 0.0),
                Some(p) => {
                    let sync_bytes = (self.frag.grad_bytes[g]
                        - p.per_group[g].saved_sync_bytes)
                        .max(0.0);
                    let dur = match a.option {
                        ReplOption::AllReduce => self.comm.allreduce_time_with(
                            sync_bytes,
                            info.dev_count,
                            info.profile,
                        ),
                        _ => {
                            let ps = info.devices[g % info.dev_count];
                            self.comm.ps_time(sync_bytes, &info.devices, ps, self.topo)
                        }
                    };
                    (dur, p.per_group[g].broadcast_bytes)
                }
            };
            let mut deps: Vec<usize> =
                info.machines.iter().map(|&dg| comp[g * m + dg]).collect();
            if strategy.sync_barrier {
                if barrier == usize::MAX {
                    let all: Vec<usize> =
                        comp.iter().copied().filter(|&t| t != usize::MAX).collect();
                    barrier = tg.push(Task {
                        resource: chan,
                        duration: 0.0,
                        deps: all,
                        kind: TaskKind::Marker,
                        load: None,
                    });
                    keys.push(KEY_BARRIER);
                }
                deps.push(barrier);
            }
            tg.push(Task {
                resource: chan,
                duration: dur,
                deps,
                kind: TaskKind::Sync { group: g },
                load: None,
            });
            keys.push(KEY_SYNC | g as u64);
            if bcast_bytes > 0.0 {
                let deps: Vec<usize> =
                    info.machines.iter().map(|&dg| comp[g * m + dg]).collect();
                tg.push(Task {
                    resource: chan,
                    duration: self
                        .comm
                        .sfb_broadcast_time_with(bcast_bytes, info.dev_count, info.profile),
                    deps,
                    kind: TaskKind::Sync { group: g },
                    load: None,
                });
                keys.push(KEY_BCAST | g as u64);
            }
        }

        debug_assert_eq!(keys.len(), tg.tasks.len());
        index.clear();
        index.reserve(keys.len());
        for (t, &key) in keys.iter().enumerate() {
            let dup = index.insert(key, t);
            debug_assert!(dup.is_none(), "construction keys must be unique");
        }
    }

    /// Feedback extraction + analytic memory/OOM over a simulated
    /// schedule (shared by the full and delta simulation paths).
    fn outcome_from(
        &self,
        split: SplitMode,
        acts: &[Action],
        infos: &[Rc<MaskInfo>],
        tg: &TaskGraph,
        sched: &Schedule,
    ) -> SimOutcome {
        let m = self.topo.num_groups();
        let k = self.gg.num_groups();

        let mut fb = Feedback {
            group_makespan: vec![0.0; k],
            group_idle_before_send: vec![0.0; k],
            devgroup_peak_mem_frac: vec![0.0; m],
            devgroup_idle: vec![0.0; m],
            link_idle: vec![vec![0.0; m]; m],
        };
        for (t, task) in tg.tasks.iter().enumerate() {
            match task.kind {
                TaskKind::Compute { group, .. } | TaskKind::Sync { group } => {
                    fb.group_makespan[group] = fb.group_makespan[group].max(sched.finish[t]);
                }
                TaskKind::Transfer { from, .. } => {
                    fb.group_makespan[from] = fb.group_makespan[from].max(sched.finish[t]);
                    let ready = task
                        .deps
                        .iter()
                        .map(|&d| sched.finish[d])
                        .fold(0.0f64, f64::max);
                    let wait = (sched.start[t] - ready).max(0.0);
                    fb.group_idle_before_send[from] = fb.group_idle_before_send[from].max(wait);
                }
                TaskKind::Marker => {}
            }
        }
        for dg in 0..m {
            fb.devgroup_idle[dg] = sched.idle_fraction(dg);
        }
        for a in 0..m {
            let idle = sched.idle_fraction(m + a);
            for b in 0..m {
                if a != b {
                    fb.link_idle[a][b] = idle;
                }
            }
        }

        // ---- analytic peak memory / OOM
        let mut mem = vec![0.0f64; m];
        for g in 0..k {
            let a = acts[g];
            let info = &infos[g];
            for (mi, &dg) in info.machines.iter().enumerate() {
                let params = self.frag.param_bytes[g] * PARAM_MEM_FACTOR;
                let act = self.frag.act_bytes[g] * ACT_LIVE_FRAC;
                mem[dg] += match a.option {
                    ReplOption::AllReduce | ReplOption::Ps => {
                        params + act * self.dev_frac(a, info, mi, split)
                    }
                    ReplOption::Duplicate => params + act,
                    ReplOption::ModelParallel => (params + act) * info.frac_cap[mi],
                };
            }
        }
        let mut oom = false;
        for dg in 0..m {
            let cap = self.topo.groups[dg].gpu.mem_gb * 1e9;
            let frac = mem[dg] / cap;
            fb.devgroup_peak_mem_frac[dg] = frac;
            if frac > 1.0 {
                oom = true;
            }
        }

        SimOutcome { time: sched.makespan.max(1e-9), oom, feedback: fb }
    }

    /// Memo-miss evaluation: lower, try the frontier-restart delta path
    /// against the neighbor ring, fall back to a full simulation, and
    /// retire the record into the ring.
    fn evaluate_miss(&self, strategy: &Strategy, acts: &[Action], sig: &[u32]) -> SimOutcome {
        let infos: Vec<Rc<MaskInfo>> = acts.iter().map(|a| self.mask_info(a.mask)).collect();
        let mut ring = self.ring.borrow_mut();
        let mut rec = ring.take_scratch();
        {
            let _s = crate::obs::span("lower");
            self.lower_into(strategy, acts, &infos, None, &mut rec);
        }
        let n = rec.tg.tasks.len();

        let sim_span = crate::obs::span("simulate");
        let mut simulated = false;
        if self.delta.get() {
            if let Some(nb) = ring.best_neighbor(sig) {
                let mut bufs = self.buffers.borrow_mut();
                let EvalBuffers { sim, delta_map, delta_clean, delta_soft, delta_matched, .. } =
                    &mut *bufs;
                let horizon = divergence_horizon(
                    &rec,
                    nb,
                    delta_map,
                    delta_clean,
                    delta_soft,
                    delta_matched,
                );
                if horizon.is_infinite() {
                    // Bit-identical graphs (the memo entry was evicted):
                    // the schedule replays wholesale.  Feedback and
                    // memory still recompute below — they depend on the
                    // actions, not just the graph.
                    rec.sched.clone_from(&nb.sched);
                    self.caches.fragments.record_delta(n, n);
                    simulated = true;
                } else if horizon > 0.0 {
                    // `resume`'s map must only carry provably identical
                    // tasks; soft-matched entries were mapped for
                    // structure matching only.
                    for i in 0..n {
                        if !delta_clean[i] {
                            delta_map[i] = usize::MAX;
                        }
                    }
                    let replayed = (0..n)
                        .filter(|&i| {
                            delta_map[i] != usize::MAX
                                && nb.sched.start[delta_map[i]] < horizon
                        })
                        .count();
                    rec.sched = sim.resume(&rec.tg, &nb.sched, delta_map, horizon);
                    self.caches.fragments.record_delta(replayed, n);
                    simulated = true;
                }
                // horizon <= 0: divergence at t=0 — nothing to replay.
            }
        }
        if !simulated {
            rec.sched = self.buffers.borrow_mut().sim.run(&rec.tg);
            self.caches.fragments.record_full();
        }
        drop(sim_span);

        let out = self.outcome_from(strategy.split, acts, &infos, &rec.tg, &rec.sched);
        rec.sig.clear();
        rec.sig.extend_from_slice(sig);
        ring.push(rec);
        out
    }

    /// Always-full evaluation path (uncached/SFB callers): lower,
    /// simulate from t=0, recycle the record as scratch without
    /// entering the neighbor ring or touching the delta counters.
    fn lower_and_simulate_full(
        &self,
        strategy: &Strategy,
        acts: &[Action],
        plan: Option<&SfbPlan>,
    ) -> SimOutcome {
        let infos: Vec<Rc<MaskInfo>> = acts.iter().map(|a| self.mask_info(a.mask)).collect();
        let mut ring = self.ring.borrow_mut();
        let mut rec = ring.take_scratch();
        {
            let _s = crate::obs::span("lower");
            self.lower_into(strategy, acts, &infos, plan, &mut rec);
        }
        {
            let _s = crate::obs::span("simulate");
            rec.sched = self.buffers.borrow_mut().sim.run(&rec.tg);
        }
        let out = self.outcome_from(strategy.split, acts, &infos, &rec.tg, &rec.sched);
        ring.give_back(rec);
        out
    }

    /// Lower `strategy` and simulate it from scratch, returning the
    /// lowered task graph and its schedule alongside the outcome — the
    /// plan-explainability path ([`crate::obs::explain`]) that needs
    /// the per-task intervals a [`SimOutcome`] deliberately discards.
    /// Bypasses the memo and the neighbor ring (no counters touched),
    /// and the outcome is bit-identical to [`Lowering::evaluate`] of
    /// the same strategy — the delta layers replay the same pure
    /// computations this path runs in full.
    pub fn explain_schedule(
        &self,
        strategy: &Strategy,
        plan: Option<&SfbPlan>,
    ) -> (TaskGraph, Schedule, SimOutcome) {
        let acts = self.resolve(strategy);
        let infos: Vec<Rc<MaskInfo>> = acts.iter().map(|a| self.mask_info(a.mask)).collect();
        let mut ring = self.ring.borrow_mut();
        let mut rec = ring.take_scratch();
        self.lower_into(strategy, &acts, &infos, plan, &mut rec);
        rec.sched = self.buffers.borrow_mut().sim.run(&rec.tg);
        let out = self.outcome_from(strategy.split, &acts, &infos, &rec.tg, &rec.sched);
        let tg = rec.tg.clone();
        let sched = rec.sched.clone();
        ring.give_back(rec);
        (tg, sched, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets::{sfb_pair, testbed};
    use crate::graph::grouping::group_ops;
    use crate::models;
    use crate::profile::unique_gpus;
    use crate::strategy::enumerate_actions;

    fn setup(topo: &Topology) -> (GroupGraph, CostModel, CommModel) {
        let m = models::vgg19(8, 0.25);
        let cost = CostModel::profile(&m.ops, &unique_gpus(topo), 0.0, 1);
        let gg = group_ops(&m, &cost, 12, 7);
        let comm = CommModel::fit(3);
        (gg, cost, comm)
    }

    /// Bitwise equality over every f64 an outcome carries (== would
    /// accept -0.0 vs 0.0 and reject nothing else, but the delta
    /// contract is exact bit identity).
    fn assert_outcome_bits_eq(a: &SimOutcome, b: &SimOutcome) {
        assert_eq!(a.time.to_bits(), b.time.to_bits());
        assert_eq!(a.oom, b.oom);
        let (fa, fb) = (&a.feedback, &b.feedback);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&fa.group_makespan), bits(&fb.group_makespan));
        assert_eq!(bits(&fa.group_idle_before_send), bits(&fb.group_idle_before_send));
        assert_eq!(bits(&fa.devgroup_peak_mem_frac), bits(&fb.devgroup_peak_mem_frac));
        assert_eq!(bits(&fa.devgroup_idle), bits(&fb.devgroup_idle));
        assert_eq!(fa.link_idle.len(), fb.link_idle.len());
        for (ra, rb) in fa.link_idle.iter().zip(&fb.link_idle) {
            assert_eq!(bits(ra), bits(rb));
        }
    }

    #[test]
    fn dp_strategies_evaluate_and_barrier_never_helps() {
        let topo = testbed();
        let (gg, cost, comm) = setup(&topo);
        let low = Lowering::new(&gg, &topo, &cost, &comm);
        let ng = gg.num_groups();
        let dp = Strategy::dp_allreduce(ng, &topo);
        let mut hv = dp.clone();
        hv.sync_barrier = false;
        let t_dp = low.evaluate(&dp);
        let t_hv = low.evaluate(&hv);
        assert!(t_dp.time.is_finite() && t_dp.time > 0.0);
        assert!(t_hv.time <= t_dp.time + 1e-12, "overlap must not hurt");
        assert!(!t_dp.oom);
        assert_eq!(low.dp_time(), t_dp.time);
    }

    #[test]
    fn memo_hits_on_equivalent_partial_strategies() {
        let topo = testbed();
        let (gg, cost, comm) = setup(&topo);
        let low = Lowering::new(&gg, &topo, &cost, &comm);
        let actions = enumerate_actions(&topo);
        let a0 = actions[0];
        // A depth-1 partial strategy completes (footnote 2) to the uniform
        // strategy of its action — both must share one memo entry.
        let mut partial = Strategy::empty(gg.num_groups());
        partial.slots[low.order[0]] = Some(a0);
        let uniform = Strategy::uniform(gg.num_groups(), a0);
        let o1 = low.evaluate(&partial);
        let (_, misses_before) = low.memo_stats();
        let o2 = low.evaluate(&uniform);
        let (hits, misses) = low.memo_stats();
        assert_eq!(o1, o2);
        assert_eq!(misses, misses_before, "uniform must hit the memo");
        assert!(hits >= 1);
    }

    #[test]
    fn cached_and_uncached_identical() {
        let topo = testbed();
        let (gg, cost, comm) = setup(&topo);
        let low = Lowering::new(&gg, &topo, &cost, &comm);
        for a in enumerate_actions(&topo).into_iter().take(8) {
            let s = Strategy::uniform(gg.num_groups(), a);
            let cold = low.evaluate_uncached(&s);
            let warm1 = low.evaluate(&s);
            let warm2 = low.evaluate(&s);
            assert_eq!(cold, warm1);
            assert_eq!(warm1, warm2);
        }
    }

    #[test]
    fn single_gpu_placement_ooms_large_model() {
        // BERT-Large at paper scale on one 11 GB 1080Ti must OOM; splitting
        // the batch across both machines must fit (the §3.3 scenario).
        let topo = sfb_pair();
        let m = models::bert(16, true, 1.0);
        let cost = CostModel::profile(&m.ops, &unique_gpus(&topo), 0.0, 1);
        let gg = group_ops(&m, &cost, 12, 7);
        let comm = CommModel::fit(3);
        let low = Lowering::new(&gg, &topo, &cost, &comm);
        let ng = gg.num_groups();
        let solo = Strategy::uniform(
            ng,
            Action { mask: 0b1, option: ReplOption::AllReduce },
        );
        let dp = Strategy::uniform(
            ng,
            Action { mask: 0b11, option: ReplOption::AllReduce },
        );
        assert!(low.evaluate(&solo).oom, "solo must exceed 11 GB");
        assert!(!low.evaluate(&dp).oom, "batch-split DP must fit");
    }

    #[test]
    fn feedback_shapes_and_ranges() {
        let topo = testbed();
        let (gg, cost, comm) = setup(&topo);
        let low = Lowering::new(&gg, &topo, &cost, &comm);
        let out = low.evaluate(&Strategy::empty(gg.num_groups()));
        let fbk = &out.feedback;
        assert_eq!(fbk.group_makespan.len(), gg.num_groups());
        assert_eq!(fbk.devgroup_idle.len(), topo.num_groups());
        assert_eq!(fbk.link_idle.len(), topo.num_groups());
        for v in &fbk.devgroup_idle {
            assert!((0.0..=1.0).contains(v));
        }
        for row in &fbk.link_idle {
            for v in row {
                assert!((0.0..=1.0).contains(v));
            }
        }
        for v in &fbk.group_makespan {
            assert!(v.is_finite() && *v >= 0.0);
        }
        assert!(fbk.devgroup_peak_mem_frac.iter().all(|v| v.is_finite() && *v >= 0.0));
    }

    #[test]
    fn mask_cache_memoizes_link_profiles() {
        let topo = testbed();
        let (gg, cost, comm) = setup(&topo);
        let low = Lowering::new(&gg, &topo, &cost, &comm);
        let dp = Strategy::dp_allreduce(gg.num_groups(), &topo);
        let _ = low.evaluate_uncached(&dp);
        let (h1, m1) = low.mask_memo_stats();
        assert!(m1 >= 1, "first evaluation fills the mask cache");
        let _ = low.evaluate_uncached(&dp);
        let (h2, m2) = low.mask_memo_stats();
        assert_eq!(m2, m1, "repeat evaluation computes no new link profiles");
        assert!(h2 > h1);
        assert!(low.mask_memo_hit_rate() > 0.0 && low.mask_memo_hit_rate() <= 1.0);
    }

    #[test]
    fn routed_topology_evaluates_with_contention_footprints() {
        // A hierarchical topology lowers and simulates end to end; its
        // evaluation is deterministic and reports finite times.
        let topo = crate::cluster::presets::multi_rack();
        let (gg, cost, comm) = setup(&topo);
        let low = Lowering::new(&gg, &topo, &cost, &comm);
        let s = Strategy::dp_allreduce(gg.num_groups(), &topo);
        let a = low.evaluate_uncached(&s);
        let b = low.evaluate_uncached(&s);
        assert!(a.time.is_finite() && a.time > 0.0);
        assert_eq!(a, b, "routed evaluation must be deterministic");
    }

    #[test]
    fn proportional_split_not_slower_on_heterogeneous_cluster() {
        let topo = testbed();
        let (gg, cost, comm) = setup(&topo);
        let low = Lowering::new(&gg, &topo, &cost, &comm);
        let mut even = Strategy::dp_allreduce(gg.num_groups(), &topo);
        even.sync_barrier = false;
        let mut prop = even.clone();
        prop.split = SplitMode::Proportional;
        let t_even = low.evaluate(&even).time;
        let t_prop = low.evaluate(&prop).time;
        assert!(t_prop <= t_even + 1e-12, "prop {t_prop} vs even {t_even}");
    }

    #[test]
    fn delta_path_bit_identical_on_single_flips() {
        let topo = testbed();
        let (gg, cost, comm) = setup(&topo);
        let low = Lowering::new(&gg, &topo, &cost, &comm);
        let ng = gg.num_groups();
        let base = Strategy::dp_allreduce(ng, &topo);
        let _ = low.evaluate(&base);
        // Option flips on the full mask first: AllReduce→Ps changes only
        // the group's sync task, so its divergence horizon is the old
        // sync dispatch — a guaranteed frontier restart.  Mask flips from
        // the general enumeration may legitimately fall back (a new
        // compute root diverges at t=0); bit-identity must hold for all.
        let full = full_mask(&topo);
        let mut flips: Vec<Action> =
            [ReplOption::Ps, ReplOption::Duplicate, ReplOption::ModelParallel]
                .into_iter()
                .map(|option| Action { mask: full, option })
                .collect();
        flips.extend(enumerate_actions(&topo).into_iter().take(4));
        for a in flips {
            let mut s = base.clone();
            s.slots[low.order[1]] = Some(a);
            let fast = low.evaluate(&s);
            // A fresh Lowering so the oracle shares nothing with the
            // delta-evaluated instance.
            let oracle = Lowering::new(&gg, &topo, &cost, &comm);
            oracle.set_delta(false);
            let slow = oracle.evaluate_uncached(&s);
            assert_outcome_bits_eq(&fast, &slow);
        }
        let d = low.delta_stats();
        assert!(d.delta_evals >= 1, "some single flip must take the delta path: {d:?}");
        assert!(low.fragment_hit_rate() > 0.0, "flips must reuse unchanged fragments");
    }

    #[test]
    fn delta_disabled_still_exact_and_counts_full() {
        let topo = testbed();
        let (gg, cost, comm) = setup(&topo);
        let low = Lowering::new(&gg, &topo, &cost, &comm);
        low.set_delta(false);
        assert!(!low.delta_enabled());
        let ng = gg.num_groups();
        let base = Strategy::dp_allreduce(ng, &topo);
        let _ = low.evaluate(&base);
        for a in enumerate_actions(&topo).into_iter().take(4) {
            let mut s = base.clone();
            s.slots[low.order[1]] = Some(a);
            let off = low.evaluate(&s);
            let oracle = Lowering::new(&gg, &topo, &cost, &comm);
            let on = oracle.evaluate_uncached(&s);
            assert_outcome_bits_eq(&off, &on);
        }
        let d = low.delta_stats();
        assert_eq!(d.delta_evals, 0, "delta off must never frontier-restart");
        assert!(d.full_evals >= 1);
        assert_eq!(low.fragment_stats(), (0, 0), "delta off must bypass the store");
    }

    #[test]
    fn with_caches_shares_fragments_across_lowerings() {
        let topo = testbed();
        let (gg, cost, comm) = setup(&topo);
        let first = Lowering::new(&gg, &topo, &cost, &comm);
        let dp = Strategy::dp_allreduce(gg.num_groups(), &topo);
        let a = first.evaluate(&dp);
        let (_, misses_first) = first.fragment_stats();
        assert!(misses_first >= 1, "first build fills the store");
        let second =
            Lowering::with_caches(&gg, &topo, &cost, &comm, first.caches_handle());
        // The second lowering's memo hits (shared table), so force the
        // build path to exercise fragment reuse.
        let b = second.evaluate_uncached(&dp);
        assert_eq!(a, b);
        let (hits, misses) = second.fragment_stats();
        assert_eq!(misses, misses_first, "second build computes no new fragments");
        assert!(hits >= misses_first, "every fragment replays from the shared store");
        let (ph, pm) = second.mask_profile_shared_stats();
        assert!(ph >= 1 && pm >= 1, "link profiles shared across lowerings: {ph}/{pm}");
    }
}
