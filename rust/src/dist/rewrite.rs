//! Op-level graph rewriting (paper §4.3.1): compile a deployment
//! strategy into a distributed computation graph.
//!
//! For every op group the resolved action decides how its ops are
//! instantiated:
//!
//! * **AllReduce / Ps** — one replica per placement device, batch work
//!   and batch-dim tensors scaled by the device's share; every gradient
//!   producer gets a synchronization op (`NcclAllReduce` / `PsUpdate`)
//!   reading all replicas, and `Apply` ops consume the synchronized
//!   gradient with their device-local variable copy.
//! * **Duplicate** — full-batch replicas on broadcast inputs: identical
//!   gradients everywhere, no synchronization inserted.
//! * **ModelParallel** — ops are partitioned across the placement
//!   devices (greedy capability-proportional balance), one instance per
//!   op.
//!
//! Auxiliary ops restore mathematical equivalence at placement
//! boundaries: `ConcatV2` reassembles batch-split (`Concat`-splittable)
//! tensors, `AddN` reduces partial-sum (`Sum`-splittable) tensors, and
//! `Split` carves a replica's shard out of a full tensor.  `NoSplit`
//! consumers only ever read full tensors (a synchronized gradient, a
//! device-local stateful tensor, or an aggregation op) — the invariants
//! `rust/tests/equivalence.rs` checks.

use std::collections::HashMap;

use crate::cluster::{DeviceId, Topology};
use crate::graph::grouping::GroupGraph;
use crate::graph::ir::{CompGraph, Op, OpId, OpKind, Splittability};
use crate::strategy::{full_mask, Action, ReplOption, SplitMode, Strategy};

/// The rewritten graph with per-op device placement and a census of the
/// inserted auxiliary ops.
pub struct DistGraph {
    pub graph: CompGraph,
    /// Device of every op in `graph` (same indexing).
    pub placement: Vec<DeviceId>,
    /// op_type -> number of inserted auxiliary ops.
    pub inserted: HashMap<&'static str, usize>,
}

/// How one group's ops are instantiated (resolved, self-contained).
enum GroupPlan {
    /// Batch-split replicas with optional gradient sync op type.
    Replicate { devices: Vec<DeviceId>, fracs: Vec<f64>, sync: Option<&'static str> },
    /// Full-batch replicas, no sync.
    Duplicate { devices: Vec<DeviceId> },
    /// One instance per op; `op_dev[pos]` is the device of the group's
    /// `pos`-th op.
    ModelParallel { devices: Vec<DeviceId>, op_dev: Vec<usize> },
}

/// One materialized instance of an original op.
#[derive(Clone, Copy)]
struct Instance {
    id: OpId,
    device: DeviceId,
    /// Whether this instance carries the full tensor value (as opposed
    /// to a batch shard or a partial sum).
    full: bool,
    /// Batch fraction this instance's output covers (1.0 when `full` or
    /// when the tensor is not batch-sharded).  A same-device consumer may
    /// read a shard directly only when its own fraction matches.
    frac: f64,
}

struct Rewriter<'a> {
    orig: &'a CompGraph,
    out: CompGraph,
    placement: Vec<DeviceId>,
    inserted: HashMap<&'static str, usize>,
    instances: Vec<Vec<Instance>>,
    /// Aggregated full-tensor instance per original op (sync output,
    /// Concat, or AddN), inserted on demand.
    full_of: HashMap<OpId, OpId>,
    /// Shard instance per (orig op, consumer group, replica index).
    shard_of: HashMap<(OpId, usize, usize), OpId>,
}

impl Rewriter<'_> {
    fn insert_aux(
        &mut self,
        name: String,
        op_type: &'static str,
        splittability: Splittability,
        flops: f64,
        output_bytes: f64,
        inputs: Vec<OpId>,
        device: DeviceId,
    ) -> OpId {
        let id = self.out.add(Op {
            name,
            op_type,
            kind: OpKind::Compute,
            flops,
            output_bytes,
            param_bytes: 0.0,
            splittability,
            inputs,
        });
        self.placement.push(device);
        *self.inserted.entry(op_type).or_insert(0) += 1;
        id
    }
}

fn resolve_actions(gg: &GroupGraph, topo: &Topology, strategy: &Strategy) -> Vec<Action> {
    let order = gg.by_comp_time_desc();
    let default = Action { mask: full_mask(topo), option: ReplOption::AllReduce };
    (0..gg.num_groups()).map(|g| strategy.action_for(g, &order, default)).collect()
}

/// Greedy capability-proportional op→device assignment for a
/// model-parallel group ("METIS inside", §4.2).
fn mp_assign(
    ops: &[OpId],
    graph: &CompGraph,
    topo: &Topology,
    devices: &[DeviceId],
) -> Vec<usize> {
    let eff: Vec<f64> =
        devices.iter().map(|d| topo.groups[d.group].gpu.effective_flops()).collect();
    let mut load = vec![0.0f64; devices.len()];
    let mut out = Vec::with_capacity(ops.len());
    for &op in ops {
        let w = graph.ops[op].flops + 1.0;
        // Least normalized load; ties go to the first (deterministic).
        let mut best = 0;
        for d in 1..devices.len() {
            if load[d] / eff[d] < load[best] / eff[best] - 1e-18 {
                best = d;
            }
        }
        load[best] += w;
        out.push(best);
    }
    out
}

fn build_plans(
    gg: &GroupGraph,
    topo: &Topology,
    orig: &CompGraph,
    strategy: &Strategy,
) -> Vec<GroupPlan> {
    resolve_actions(gg, topo, strategy)
        .into_iter()
        .enumerate()
        .map(|(g, a)| {
            let devices = topo.mask_devices(a.mask);
            assert!(!devices.is_empty(), "action mask selects no devices");
            let d = devices.len();
            match a.option {
                ReplOption::AllReduce | ReplOption::Ps => {
                    let fracs = match strategy.split {
                        SplitMode::Even => vec![1.0 / d as f64; d],
                        SplitMode::Proportional => {
                            let tot: f64 = devices
                                .iter()
                                .map(|dev| topo.groups[dev.group].gpu.effective_flops())
                                .sum();
                            devices
                                .iter()
                                .map(|dev| topo.groups[dev.group].gpu.effective_flops() / tot)
                                .collect()
                        }
                    };
                    let sync = if d >= 2 {
                        Some(match a.option {
                            ReplOption::AllReduce => "NcclAllReduce",
                            _ => "PsUpdate",
                        })
                    } else {
                        None
                    };
                    GroupPlan::Replicate { devices, fracs, sync }
                }
                ReplOption::Duplicate => GroupPlan::Duplicate { devices },
                ReplOption::ModelParallel => {
                    let op_dev = mp_assign(&gg.groups[g].ops, orig, topo, &devices);
                    GroupPlan::ModelParallel { devices, op_dev }
                }
            }
        })
        .collect()
}

/// Rewrite the computation graph for a (possibly partial) strategy —
/// undecided groups follow the footnote-2 completion rule.
pub fn rewrite(
    orig: &CompGraph,
    gg: &GroupGraph,
    topo: &Topology,
    strategy: &Strategy,
) -> DistGraph {
    let plans = build_plans(gg, topo, orig, strategy);

    // Position of each op within its group's op list (for MP lookup).
    let mut pos_in_group = vec![0usize; orig.len()];
    for grp in &gg.groups {
        for (p, &op) in grp.ops.iter().enumerate() {
            pos_in_group[op] = p;
        }
    }

    let mut rw = Rewriter {
        orig,
        out: CompGraph::new(format!("{}/dist", orig.name), orig.batch_size),
        placement: Vec::new(),
        inserted: HashMap::new(),
        instances: vec![Vec::new(); orig.len()],
        full_of: HashMap::new(),
        shard_of: HashMap::new(),
    };

    for i in 0..orig.len() {
        let g = gg.assignment[i];
        match &plans[g] {
            GroupPlan::Replicate { devices, fracs, sync } => {
                for (r, (&dev, &frac)) in devices.iter().zip(fracs.iter()).enumerate() {
                    emit_replica(&mut rw, i, g, r, dev, frac, devices.len() > 1);
                }
                if orig.ops[i].is_grad() {
                    if let Some(sync_ty) = *sync {
                        let inputs: Vec<OpId> =
                            rw.instances[i].iter().map(|inst| inst.id).collect();
                        let bytes = orig.ops[i].output_bytes;
                        let dev0 = devices[0];
                        let sid = rw.insert_aux(
                            format!("{}/{}", orig.ops[i].name, sync_ty.to_lowercase()),
                            sync_ty,
                            Splittability::NoSplit,
                            bytes / 4.0,
                            bytes,
                            inputs,
                            dev0,
                        );
                        rw.full_of.insert(i, sid);
                    }
                }
            }
            GroupPlan::Duplicate { devices } => {
                for (r, &dev) in devices.iter().enumerate() {
                    emit_replica(&mut rw, i, g, r, dev, 1.0, devices.len() > 1);
                }
            }
            GroupPlan::ModelParallel { devices, op_dev } => {
                let dev = devices[op_dev[pos_in_group[i]]];
                emit_replica(&mut rw, i, g, 0, dev, 1.0, false);
            }
        }
    }

    DistGraph { graph: rw.out, placement: rw.placement, inserted: rw.inserted }
}

/// Whether an op keeps its full tensor value on every replica even when
/// the batch is split: parameters, and input-less zero-flop stateful
/// tensors (optimizer slots).
fn is_stateful_full(op: &Op) -> bool {
    op.is_param()
        || (matches!(op.kind, OpKind::Compute) && op.flops == 0.0 && op.inputs.is_empty())
}

#[allow(clippy::too_many_arguments)]
fn emit_replica(
    rw: &mut Rewriter,
    i: OpId,
    g: usize,
    r: usize,
    dev: DeviceId,
    frac: f64,
    multi: bool,
) {
    let op = &rw.orig.ops[i];
    let split_batch = frac < 1.0 && !is_stateful_full(op);
    // Batch-scaled work for splittable ops; NoSplit ops run in full.
    let flops = if split_batch && op.splittability != Splittability::NoSplit {
        op.flops * frac
    } else {
        op.flops
    };
    // Batch-dim tensors shrink with the share; Sum tensors (partial
    // gradients) and NoSplit outputs keep their full shape.
    let output_bytes = if split_batch && op.splittability == Splittability::Concat {
        op.output_bytes * frac
    } else {
        op.output_bytes
    };
    let full = !split_batch
        || (op.splittability == Splittability::NoSplit && !op.is_grad());

    let needs_full = op.splittability == Splittability::NoSplit || frac >= 1.0;
    let orig_inputs = op.inputs.clone();
    let op_kind = op.kind;
    let op_name = op.name.clone();
    let op_type = op.op_type;
    let op_split = op.splittability;
    let op_params = op.param_bytes;

    let inputs: Vec<OpId> = orig_inputs
        .into_iter()
        .map(|p| resolve_input(rw, p, g, r, dev, needs_full, frac))
        .collect();

    let kind = match op_kind {
        OpKind::Grad { wrt } => OpKind::Grad { wrt: instance_near(rw, wrt, dev) },
        OpKind::Apply { var } => OpKind::Apply { var: instance_near(rw, var, dev) },
        k => k,
    };
    let name = if multi { format!("{op_name}/rep{r}") } else { op_name };
    let id = rw.out.add(Op {
        name,
        op_type,
        kind,
        flops,
        output_bytes,
        param_bytes: op_params,
        splittability: op_split,
        inputs,
    });
    rw.placement.push(dev);
    let inst_frac = if full { 1.0 } else { frac };
    rw.instances[i].push(Instance { id, device: dev, full, frac: inst_frac });
}

/// The already-emitted instance of `p` nearest to `dev` (same device if
/// possible, else the first replica).
fn instance_near(rw: &Rewriter, p: OpId, dev: DeviceId) -> OpId {
    let insts = &rw.instances[p];
    insts
        .iter()
        .find(|inst| inst.device == dev)
        .or_else(|| insts.first())
        .map(|inst| inst.id)
        .expect("producer emitted before consumer (topological order)")
}

/// Aggregated full-tensor instance of `p`, inserting ConcatV2/AddN over
/// the replicas when needed (memoized).
fn full_instance(rw: &mut Rewriter, p: OpId) -> OpId {
    if let Some(&f) = rw.full_of.get(&p) {
        return f;
    }
    if let Some(inst) = rw.instances[p].iter().find(|inst| inst.full) {
        return inst.id;
    }
    let insts = rw.instances[p].clone();
    assert!(!insts.is_empty(), "producer {p} has no instances");
    let op = &rw.orig.ops[p];
    let (ty, flops) = match op.splittability {
        Splittability::Sum => ("AddN", op.output_bytes / 4.0),
        _ => ("ConcatV2", 0.0),
    };
    let name = format!("{}/{}", op.name, ty.to_lowercase());
    let bytes = op.output_bytes;
    let inputs: Vec<OpId> = insts.iter().map(|inst| inst.id).collect();
    let device = insts[0].device;
    let id = rw.insert_aux(name, ty, Splittability::NoSplit, flops, bytes, inputs, device);
    rw.full_of.insert(p, id);
    id
}

/// Resolve input `p` for replica `r` of a consumer in group `g_cons` on
/// `dev` — device-local instances when valid, otherwise aggregate (and
/// re-shard for batch-split consumers).
fn resolve_input(
    rw: &mut Rewriter,
    p: OpId,
    g_cons: usize,
    r: usize,
    dev: DeviceId,
    needs_full: bool,
    frac: f64,
) -> OpId {
    if needs_full {
        // Synchronized gradients take precedence over local partials.
        if let Some(&f) = rw.full_of.get(&p) {
            return f;
        }
        if let Some(inst) =
            rw.instances[p].iter().find(|inst| inst.device == dev && inst.full)
        {
            return inst.id;
        }
        return full_instance(rw, p);
    }
    // Stateful tensors (weights, optimizer slots) are full everywhere —
    // read the nearest copy, never shard them.
    if is_stateful_full(&rw.orig.ops[p]) {
        return instance_near(rw, p, dev);
    }
    // Batch-split consumer: a same-device batch-split instance carries
    // exactly this replica's shard *only when producer and consumer split
    // the batch identically* — on mixed-mask replicate→replicate edges
    // the fractions differ and the local shard is the wrong slice, so
    // the tensor must be reassembled and re-sharded below.  A same-device
    // full non-partial tensor (variable, broadcast input) is readable
    // directly.
    if let Some(inst) = rw.instances[p].iter().find(|inst| inst.device == dev) {
        let aligned_shard = !inst.full && (inst.frac - frac).abs() <= 1e-12;
        let readable_full = inst.full && rw.orig.ops[p].splittability != Splittability::Sum;
        if aligned_shard || readable_full {
            return inst.id;
        }
    }
    // Otherwise carve the shard out of the aggregated tensor.
    if let Some(&s) = rw.shard_of.get(&(p, g_cons, r)) {
        return s;
    }
    let full = full_instance(rw, p);
    let name = format!("{}/split_g{g_cons}_r{r}", rw.orig.ops[p].name);
    let bytes = rw.orig.ops[p].output_bytes * frac;
    let id =
        rw.insert_aux(name, "Split", Splittability::Concat, 0.0, bytes, vec![full], dev);
    rw.shard_of.insert((p, g_cons, r), id);
    id
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets::sfb_pair;
    use crate::graph::grouping::group_ops;
    use crate::models;
    use crate::profile::{unique_gpus, CostModel};

    fn setup() -> (CompGraph, GroupGraph, Topology) {
        let topo = sfb_pair();
        let m = models::vgg19(4, 0.25);
        let cost = CostModel::profile(&m.ops, &unique_gpus(&topo), 0.0, 1);
        let gg = group_ops(&m, &cost, 8, 3);
        (m, gg, topo)
    }

    #[test]
    fn dp_rewrite_replicates_and_syncs() {
        let (m, gg, topo) = setup();
        let s = Strategy::dp_allreduce(gg.num_groups(), &topo);
        let d = rewrite(&m, &gg, &topo, &s);
        assert!(d.graph.check_acyclic());
        assert_eq!(d.graph.len(), d.placement.len());
        let n_sync = d.inserted.get("NcclAllReduce").copied().unwrap_or(0);
        assert_eq!(n_sync, m.grad_apply_pairs().len());
        // Both devices appear in the placement.
        let machines: std::collections::HashSet<usize> =
            d.placement.iter().map(|dev| dev.group).collect();
        assert_eq!(machines.len(), 2);
    }

    #[test]
    fn solo_placement_inserts_nothing() {
        let (m, gg, topo) = setup();
        let s = Strategy::uniform(
            gg.num_groups(),
            Action { mask: 0b1, option: ReplOption::AllReduce },
        );
        let d = rewrite(&m, &gg, &topo, &s);
        assert!(d.inserted.is_empty(), "{:?}", d.inserted);
        assert_eq!(d.graph.len(), m.len());
        assert!(d.placement.iter().all(|dev| dev.group == 0));
    }

    #[test]
    fn model_parallel_uses_both_devices_without_replication() {
        let (m, gg, topo) = setup();
        let s = Strategy::uniform(
            gg.num_groups(),
            Action { mask: 0b11, option: ReplOption::ModelParallel },
        );
        let d = rewrite(&m, &gg, &topo, &s);
        assert!(d.graph.check_acyclic());
        let vars_orig = m.ops.iter().filter(|o| o.is_param()).count();
        let vars_dist = d.graph.ops.iter().filter(|o| o.is_param()).count();
        assert_eq!(vars_orig, vars_dist);
        let machines: std::collections::HashSet<usize> =
            d.placement.iter().map(|dev| dev.group).collect();
        assert_eq!(machines.len(), 2);
        assert!(d.inserted.get("NcclAllReduce").is_none());
    }

    #[test]
    fn flops_conserved_under_dp() {
        let (m, gg, topo) = setup();
        let s = Strategy::dp_allreduce(gg.num_groups(), &topo);
        let d = rewrite(&m, &gg, &topo, &s);
        let extra: f64 = d
            .graph
            .ops
            .iter()
            .filter(|o| o.op_type == "NcclAllReduce" || o.op_type == "AddN")
            .map(|o| o.flops)
            .sum();
        let ratio = (d.graph.total_flops() - extra) / m.total_flops();
        assert!((0.95..1.25).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn mismatched_batch_fractions_take_the_split_from_full_path() {
        // Mixed-mask replicate→replicate edge (PR-2 review finding):
        // producer group on mask 0b1 (4 V100 devices, even frac 1/4),
        // consumer group on mask 0b11 (6 devices, even frac 1/6).  The
        // consumer replicas on group-0 devices see a *same-device*
        // producer shard of the wrong fraction and must re-shard through
        // ConcatV2 + Split instead of reading the local shard directly.
        use crate::cluster::presets::testbed;
        use crate::graph::grouping::OpGroup;

        let topo = testbed();
        let mut m = CompGraph::new("toy", 8);
        let a = m.add(crate::graph::ir::Op {
            name: "A".into(),
            op_type: "Conv2D",
            kind: crate::graph::ir::OpKind::Compute,
            flops: 1e9,
            output_bytes: 4e6,
            param_bytes: 0.0,
            splittability: Splittability::Concat,
            inputs: vec![],
        });
        let b = m.add(crate::graph::ir::Op {
            name: "B".into(),
            op_type: "Conv2D",
            kind: crate::graph::ir::OpKind::Compute,
            flops: 2e9,
            output_bytes: 4e6,
            param_bytes: 0.0,
            splittability: Splittability::Concat,
            inputs: vec![a],
        });
        let group = |ops: Vec<usize>, comp_time: f64| OpGroup {
            ops,
            comp_time,
            param_bytes: 0.0,
            activation_bytes: 4e6,
            grad_pairs: vec![],
            grad_bytes: 0.0,
        };
        let gg = GroupGraph {
            groups: vec![group(vec![a], 0.5), group(vec![b], 1.0)],
            edges: vec![vec![0.0, 4e6], vec![0.0, 0.0]],
            assignment: vec![0, 1],
            model_name: "toy".into(),
            batch_size: 8,
        };
        let mut s = Strategy::empty(2);
        s.slots[0] = Some(Action { mask: 0b1, option: ReplOption::AllReduce });
        s.slots[1] = Some(Action { mask: 0b11, option: ReplOption::AllReduce });
        let d = rewrite(&m, &gg, &topo, &s);
        assert!(d.graph.check_acyclic());
        // One reassembly of A, then one re-shard per consumer replica —
        // including the four same-device (group-0) replicas that the
        // pre-fix code wired straight to the mismatched 1/4 shard.
        assert_eq!(d.inserted.get("ConcatV2").copied().unwrap_or(0), 1);
        assert_eq!(d.inserted.get("Split").copied().unwrap_or(0), 6);
        for op in &d.graph.ops {
            if op.name.starts_with("B/rep") {
                assert_eq!(op.inputs.len(), 1, "{}", op.name);
                let input = &d.graph.ops[op.inputs[0]];
                assert_eq!(
                    input.op_type, "Split",
                    "{} must read a re-shard, got {}",
                    op.name, input.name
                );
            }
        }
    }

    #[test]
    fn matching_fractions_still_read_the_local_shard() {
        // Same-mask DP edges must keep the zero-copy local read: no
        // Split/Concat machinery on plain data parallelism.
        let (m, gg, topo) = setup();
        let s = Strategy::uniform(
            gg.num_groups(),
            Action { mask: 0b11, option: ReplOption::AllReduce },
        );
        let d = rewrite(&m, &gg, &topo, &s);
        assert!(d.inserted.get("Split").is_none(), "{:?}", d.inserted);
        assert!(d.inserted.get("ConcatV2").is_none(), "{:?}", d.inserted);
    }

    #[test]
    fn duplicate_rewrite_has_no_sync_and_full_flops() {
        let (m, gg, topo) = setup();
        let s = Strategy::uniform(
            gg.num_groups(),
            Action { mask: 0b11, option: ReplOption::Duplicate },
        );
        let d = rewrite(&m, &gg, &topo, &s);
        assert!(d.graph.check_acyclic());
        assert!(d.inserted.get("NcclAllReduce").is_none());
        assert!(d.inserted.get("PsUpdate").is_none());
        // Every replica runs the full batch: ~2x original flops.
        let ratio = d.graph.total_flops() / m.total_flops();
        assert!((1.8..2.2).contains(&ratio), "ratio {ratio}");
    }
}
