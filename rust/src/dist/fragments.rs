//! Shared fragment store for incremental lowering (the delta-evaluation
//! tentpole).
//!
//! Lowering a strategy decomposes into per-group and per-edge pieces
//! whose durations, link footprints, and emission decisions depend only
//! on `(group, resolved action, split mode)` — never on what the *other*
//! groups chose.  Re-lowering a strategy that differs from a previously
//! evaluated one in a single group therefore recomputes `k - 1` groups'
//! worth of fitted-model and routed-bandwidth queries for nothing.  The
//! [`FragmentStore`] memoizes those pieces once, keyed exactly, so every
//! subsequent build replays them verbatim — the cached values are the
//! bit-identical outputs of the same pure computations, which is what
//! keeps the delta path's bit-identity contract trivial on the lowering
//! side.
//!
//! Like the evaluation memo ([`super::MemoTable`]), the store is sharded
//! and `RwLock`-striped with relaxed-atomic hit/miss counters, and every
//! shard evicts by two-generation rotation ([`super::memo`]'s `TwoGen`),
//! so parallel search workers share one instance behind an `Arc` and
//! long-lived daemons never face a cold store after eviction.  The store
//! also carries the **delta-simulation counters** (delta vs full
//! simulations, replayed vs simulated tasks) precisely because it is the
//! one object all workers of a search share — plan telemetry reads one
//! aggregate regardless of parallelism.
//!
//! [`MaskProfileMemo`] is the cross-worker tier of the per-mask
//! `LinkProfile` cache: each `Lowering` keeps its own cheap `Rc` map of
//! fully expanded placements (preserving its exact per-instance hit/miss
//! accounting), but the expensive routed link-profile computation behind
//! it is shared, so per-worker lowerings of a parallel search stop
//! rebuilding identical profiles from scratch.
//!
//! [`EvalCaches`] bundles the three shared handles — evaluation memo,
//! fragment store, mask-profile memo — into the one clone-to-share value
//! that [`super::Lowering::with_caches`] accepts and
//! [`super::Lowering::caches_handle`] returns.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::cluster::LinkProfile;
use crate::sim::LinkLoad;

use super::memo::{MemoTable, TwoGen};

/// Lock stripes per fragment kind (a power of two, masked like the
/// evaluation memo's).
pub const FRAGMENT_SHARDS: usize = 16;

/// Per-shard per-generation entry caps.  Group fragments are bounded by
/// `groups × actions`; edge fragments by `edges × actions²`, hence the
/// larger cap.
const GROUP_SHARD_CAPACITY: usize = 1 << 12;
const EDGE_SHARD_CAPACITY: usize = 1 << 13;

/// Key of a group's lowered fragment: the group index, its resolved
/// action word (`(mask << 3) | option`, the evaluation-memo encoding),
/// and the batch-split mode (which changes per-device shares).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GroupKey {
    pub group: u32,
    pub action: u32,
    pub proportional: bool,
}

/// Key of an inter-group edge's lowered fragment: the edge index in the
/// group graph's forward-edge list plus both endpoints' resolved action
/// words and the split mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct EdgeKey {
    pub edge: u32,
    pub producer: u32,
    pub consumer: u32,
    pub proportional: bool,
}

/// The model-parallel internal-communication task of a group fragment.
#[derive(Clone, Debug)]
pub(crate) struct PenaltyFragment {
    pub(crate) duration: f64,
    pub(crate) src_dg: usize,
    pub(crate) dst_dg: usize,
    pub(crate) load: Option<LinkLoad>,
}

/// Everything about lowering one group that depends only on its own
/// resolved action: clamped base compute durations (per entry of the
/// mask's machine list), the optional MP internal-comm task, and the
/// optional plan-free gradient-sync duration.
#[derive(Clone, Debug, Default)]
pub struct GroupFragment {
    pub(crate) comp: Vec<f64>,
    pub(crate) penalty: Option<PenaltyFragment>,
    pub(crate) sync: Option<f64>,
}

/// One emitted transfer of an edge fragment.
#[derive(Clone, Debug)]
pub(crate) struct TransferFragment {
    pub(crate) resource: usize,
    pub(crate) duration: f64,
    /// Producer machine (device group) the bytes travel from.
    pub(crate) src: usize,
    pub(crate) load: Option<LinkLoad>,
}

/// Per-consumer-machine emission decision of an edge fragment.
#[derive(Clone, Debug, Default)]
pub(crate) struct EdgeEmit {
    /// The consumer machine also hosts the producer: the consumer task
    /// gains a direct dependency on the co-located producer compute.
    pub(crate) local: bool,
    /// The NIC transfer to emit (deficit-gather or full remote fetch),
    /// `None` when the local share suffices or the volume is negligible.
    pub(crate) transfer: Option<TransferFragment>,
}

/// Everything about lowering one inter-group edge that depends only on
/// the two endpoints' resolved actions: one [`EdgeEmit`] per consumer
/// machine, in the consumer mask's machine order.
#[derive(Clone, Debug, Default)]
pub struct EdgeFragment {
    pub(crate) emits: Vec<EdgeEmit>,
}

fn shard_of(words: &[u64]) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &w in words {
        h ^= w;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h >> 32) as usize & (FRAGMENT_SHARDS - 1)
}

/// Aggregate counters of the incremental-evaluation path, shared across
/// all workers of a search (they live in the [`FragmentStore`] every
/// worker's `Lowering` holds).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DeltaStats {
    /// Evaluations served by frontier-restart (or identical-graph)
    /// delta simulation.
    pub delta_evals: u64,
    /// Evaluations that lowered a graph and simulated it from t=0.
    pub full_evals: u64,
    /// Tasks replayed verbatim from a previous schedule across all
    /// delta evaluations.
    pub replayed_tasks: u64,
    /// Total tasks of all delta-evaluated graphs (replayed + re-run).
    pub simulated_tasks: u64,
}

impl DeltaStats {
    /// Delta evaluations over all from-scratch-or-delta evaluations.
    pub fn delta_hit_rate(&self) -> f64 {
        let total = self.delta_evals + self.full_evals;
        if total == 0 {
            0.0
        } else {
            self.delta_evals as f64 / total as f64
        }
    }

    /// Fraction of delta-evaluated tasks replayed from the previous
    /// schedule instead of re-simulated (1.0 = pure replay).
    pub fn frontier_restart_frac(&self) -> f64 {
        if self.simulated_tasks == 0 {
            0.0
        } else {
            self.replayed_tasks as f64 / self.simulated_tasks as f64
        }
    }
}

/// Sharded, lock-striped store of lowered group/edge fragments with
/// exact hit/miss accounting, plus the shared delta-simulation
/// counters.  All methods take `&self`; clone an `Arc<FragmentStore>`
/// (or a whole [`EvalCaches`]) to share it across search workers.
pub struct FragmentStore {
    groups: Vec<RwLock<TwoGen<GroupKey, Arc<GroupFragment>>>>,
    edges: Vec<RwLock<TwoGen<EdgeKey, Arc<EdgeFragment>>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    delta_evals: AtomicU64,
    full_evals: AtomicU64,
    replayed_tasks: AtomicU64,
    simulated_tasks: AtomicU64,
}

impl Default for FragmentStore {
    fn default() -> Self {
        Self::new()
    }
}

impl FragmentStore {
    pub fn new() -> Self {
        Self {
            groups: (0..FRAGMENT_SHARDS)
                .map(|_| RwLock::new(TwoGen::new(GROUP_SHARD_CAPACITY)))
                .collect(),
            edges: (0..FRAGMENT_SHARDS)
                .map(|_| RwLock::new(TwoGen::new(EDGE_SHARD_CAPACITY)))
                .collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            delta_evals: AtomicU64::new(0),
            full_evals: AtomicU64::new(0),
            replayed_tasks: AtomicU64::new(0),
            simulated_tasks: AtomicU64::new(0),
        }
    }

    /// Fetch the fragment for `key`, computing and caching it on a miss.
    pub(crate) fn group(
        &self,
        key: GroupKey,
        make: impl FnOnce() -> GroupFragment,
    ) -> Arc<GroupFragment> {
        let words = [u64::from(key.group) << 33 | u64::from(key.action) << 1
            | u64::from(key.proportional)];
        let shard = &self.groups[shard_of(&words)];
        if let Some(f) = shard.read().unwrap().peek_hot(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(f);
        }
        let mut shard = shard.write().unwrap();
        if let Some(f) = shard.get_promote(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(f);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let f = Arc::new(make());
        shard.insert(key, Arc::clone(&f));
        f
    }

    /// Fetch the fragment for `key`, computing and caching it on a miss.
    pub(crate) fn edge(
        &self,
        key: EdgeKey,
        make: impl FnOnce() -> EdgeFragment,
    ) -> Arc<EdgeFragment> {
        let words = [
            u64::from(key.edge) << 1 | u64::from(key.proportional),
            u64::from(key.producer) << 32 | u64::from(key.consumer),
        ];
        let shard = &self.edges[shard_of(&words)];
        if let Some(f) = shard.read().unwrap().peek_hot(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(f);
        }
        let mut shard = shard.write().unwrap();
        if let Some(f) = shard.get_promote(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(f);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let f = Arc::new(make());
        shard.insert(key, Arc::clone(&f));
        f
    }

    /// (hits, misses) across group and edge lookups since construction.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Hits / (hits + misses), 0.0 when never probed.
    pub fn hit_rate(&self) -> f64 {
        let (h, m) = self.stats();
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    /// Cached fragment count (group + edge, both generations).
    pub fn len(&self) -> usize {
        self.groups.iter().map(|s| s.read().unwrap().len()).sum::<usize>()
            + self.edges.iter().map(|s| s.read().unwrap().len()).sum::<usize>()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub(crate) fn record_delta(&self, replayed: usize, total: usize) {
        self.delta_evals.fetch_add(1, Ordering::Relaxed);
        self.replayed_tasks.fetch_add(replayed as u64, Ordering::Relaxed);
        self.simulated_tasks.fetch_add(total as u64, Ordering::Relaxed);
    }

    pub(crate) fn record_full(&self) {
        self.full_evals.fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot of the shared delta-simulation counters.
    pub fn delta_stats(&self) -> DeltaStats {
        DeltaStats {
            delta_evals: self.delta_evals.load(Ordering::Relaxed),
            full_evals: self.full_evals.load(Ordering::Relaxed),
            replayed_tasks: self.replayed_tasks.load(Ordering::Relaxed),
            simulated_tasks: self.simulated_tasks.load(Ordering::Relaxed),
        }
    }
}

/// Cross-worker tier of the per-mask `LinkProfile` cache: mask →
/// routed bottleneck bandwidth + worst path latency, shared behind an
/// `Arc` so parallel workers compute each profile once.  Unbounded by
/// design — a profile is two `f64`s and masks are 16-bit.
#[derive(Default)]
pub struct MaskProfileMemo {
    map: RwLock<HashMap<u16, LinkProfile>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl MaskProfileMemo {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch the profile for `mask`, computing and caching it on a miss.
    pub(crate) fn get_or(&self, mask: u16, make: impl FnOnce() -> LinkProfile) -> LinkProfile {
        if let Some(p) = self.map.read().unwrap().get(&mask) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return *p;
        }
        let mut map = self.map.write().unwrap();
        if let Some(p) = map.get(&mask) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return *p;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let p = make();
        map.insert(mask, p);
        p
    }

    /// (hits, misses) of the shared tier.  Sequential searches only see
    /// misses here (their per-`Lowering` tier absorbs repeats); hits
    /// measure cross-worker reuse.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The three shared evaluation caches as one clone-to-share bundle:
/// per-worker `Lowering`s of a parallel search clone this so outcomes,
/// lowered fragments, and link profiles are all pooled.
#[derive(Clone, Default)]
pub struct EvalCaches {
    pub memo: Arc<MemoTable>,
    pub fragments: Arc<FragmentStore>,
    pub profiles: Arc<MaskProfileMemo>,
}

impl EvalCaches {
    pub fn new() -> Self {
        Self::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gkey(g: u32, a: u32) -> GroupKey {
        GroupKey { group: g, action: a, proportional: false }
    }

    #[test]
    fn group_fragments_compute_once_and_hit_after() {
        let store = FragmentStore::new();
        let mut built = 0;
        for _ in 0..3 {
            let f = store.group(gkey(1, 9), || {
                built += 1;
                GroupFragment { comp: vec![1.5, 2.5], penalty: None, sync: Some(0.25) }
            });
            assert_eq!(f.comp, vec![1.5, 2.5]);
            assert_eq!(f.sync, Some(0.25));
        }
        assert_eq!(built, 1, "fragment computed exactly once");
        assert_eq!(store.stats(), (2, 1));
        assert!((store.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(store.len(), 1);
    }

    #[test]
    fn distinct_keys_are_distinct_fragments() {
        let store = FragmentStore::new();
        let _ = store.group(gkey(1, 9), || GroupFragment { comp: vec![1.0], ..Default::default() });
        let _ = store.group(gkey(1, 10), || GroupFragment { comp: vec![2.0], ..Default::default() });
        let _ = store.group(
            GroupKey { group: 1, action: 9, proportional: true },
            || GroupFragment { comp: vec![3.0], ..Default::default() },
        );
        let f = store.group(gkey(1, 9), || unreachable!("must hit"));
        assert_eq!(f.comp, vec![1.0]);
        assert_eq!(store.len(), 3);
    }

    #[test]
    fn edge_fragments_key_on_both_endpoint_actions() {
        let store = FragmentStore::new();
        let ek = |p: u32, c: u32| EdgeKey { edge: 4, producer: p, consumer: c, proportional: false };
        let _ = store.edge(ek(9, 10), EdgeFragment::default);
        let _ = store.edge(ek(10, 9), EdgeFragment::default);
        assert_eq!(store.stats(), (0, 2), "swapped endpoints are distinct keys");
        let _ = store.edge(ek(9, 10), || unreachable!("must hit"));
        assert_eq!(store.stats(), (1, 2));
    }

    #[test]
    fn concurrent_lookups_account_exactly() {
        const THREADS: usize = 8;
        const ROUNDS: usize = 50;
        const KEYS: u32 = 32;
        let store = FragmentStore::new();
        std::thread::scope(|s| {
            for _ in 0..THREADS {
                let store = &store;
                s.spawn(move || {
                    for _ in 0..ROUNDS {
                        for k in 0..KEYS {
                            let f = store.group(gkey(k, 7), || GroupFragment {
                                comp: vec![f64::from(k)],
                                ..Default::default()
                            });
                            assert_eq!(f.comp[0], f64::from(k));
                        }
                    }
                });
            }
        });
        let (hits, misses) = store.stats();
        assert_eq!(hits + misses, (THREADS * ROUNDS) as u64 * u64::from(KEYS));
        assert_eq!(misses, u64::from(KEYS), "write lock makes each key miss exactly once");
        assert_eq!(store.len(), KEYS as usize);
    }

    #[test]
    fn mask_profile_memo_shares_and_counts() {
        let memo = MaskProfileMemo::new();
        let mut built = 0;
        for _ in 0..4 {
            let p = memo.get_or(0b1011, || {
                built += 1;
                LinkProfile { bottleneck_gbps: 10.0, max_latency_s: 2e-6 }
            });
            assert_eq!(p.bottleneck_gbps, 10.0);
        }
        assert_eq!(built, 1);
        assert_eq!(memo.stats(), (3, 1));
        assert_eq!(memo.len(), 1);
    }

    #[test]
    fn delta_stats_rates() {
        let store = FragmentStore::new();
        assert_eq!(store.delta_stats().delta_hit_rate(), 0.0);
        assert_eq!(store.delta_stats().frontier_restart_frac(), 0.0);
        store.record_delta(90, 100);
        store.record_delta(60, 100);
        store.record_full();
        let d = store.delta_stats();
        assert_eq!(
            d,
            DeltaStats {
                delta_evals: 2,
                full_evals: 1,
                replayed_tasks: 150,
                simulated_tasks: 200
            }
        );
        assert!((d.delta_hit_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert!((d.frontier_restart_frac() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn eval_caches_clone_shares_all_three_tiers() {
        let caches = EvalCaches::new();
        let clone = caches.clone();
        assert!(Arc::ptr_eq(&caches.memo, &clone.memo));
        assert!(Arc::ptr_eq(&caches.fragments, &clone.fragments));
        assert!(Arc::ptr_eq(&caches.profiles, &clone.profiles));
    }
}
