//! Concurrent transposition table for strategy evaluations.
//!
//! MCTS revisits the same *effective* deployment many times: the
//! footnote-2 completion rule maps every partial strategy to a complete
//! one, and different tree paths frequently complete to identical
//! deployments (every depth-1 vertex is the uniform strategy of its root
//! action, deeper vertices repeat whenever later groups copy the first
//! decided action).  Keying the cache on the *resolved* per-group action
//! vector — not the raw slot vector — therefore collapses all of them
//! onto one entry.
//!
//! The signature is exact (no hashing tricks beyond `HashMap`'s): one
//! `u32` per op group encoding `(mask << 3) | option`, plus one flags
//! word for the batch-split mode and the sync-barrier bit.  Outcomes are
//! stored by value and cloned out; a [`SimOutcome`] is a few short
//! vectors, which is 1–2 orders of magnitude cheaper than re-lowering
//! and re-simulating.
//!
//! ## One implementation for both execution modes
//!
//! The table is **sharded and `RwLock`-striped** so the sequential
//! search path and the tree-parallel workers of [`crate::search`] share
//! a single implementation: a key hashes (FNV-1a over its words) to one
//! of [`MEMO_SHARDS`] stripes, lookups take that stripe's read lock,
//! inserts its write lock, and the hit/miss counters are relaxed
//! atomics.  Uncontended, a stripe lock is a single atomic operation —
//! the sequential path pays nothing measurable for the sharing — while
//! under K workers the stripes keep evaluation traffic from serializing
//! on one lock.  `dist::Lowering` holds the table behind an `Arc`
//! ([`Lowering::memo_handle`](super::Lowering::memo_handle)), so per-worker
//! lowerings can pool their outcomes.
//!
//! ## Eviction: two generations, not a wholesale clear
//!
//! A full shard used to be cleared outright, which left long-lived
//! `tag serve` / `tag fleet` daemons facing a fully cold stripe right
//! after the eviction — dropping exactly the warmest entries.  Shards
//! now rotate through **two generations** ([`TwoGen`], the
//! `api/cache.rs` idiom): when the hot generation fills, it *becomes*
//! the cold generation and a fresh hot one starts; a lookup that misses
//! hot but hits cold promotes the entry back into hot.  At any instant
//! the most recent `SHARD_CAPACITY` insertions are retained exactly,
//! and an entry survives at most two generations without a hit.
//! Searches small enough never to rotate (every bounded MCTS run in the
//! tests and benches) see byte-identical hit/miss sequences to the old
//! single-map table.

use std::borrow::Borrow;
use std::collections::HashMap;
use std::hash::Hash;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

use super::lower::SimOutcome;

/// Number of independently locked stripes.  A power of two comfortably
/// above any realistic worker count, small enough that `len`/`clear`
/// sweeps stay trivial.
pub const MEMO_SHARDS: usize = 16;

/// Soft cap on cached entries across all shards: each shard keeps at
/// most `2 * SHARD_CAPACITY` entries (hot + cold generation), so the
/// table holds at most `2 * MEMO_CAPACITY` outcomes.
pub const MEMO_CAPACITY: usize = 1 << 16;

const SHARD_CAPACITY: usize = MEMO_CAPACITY / MEMO_SHARDS;

/// FNV-1a over the signature words, used only to pick a stripe (the
/// in-shard `HashMap` hashes with its own keyed hasher).
fn shard_index(key: &[u32]) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &w in key {
        h ^= w as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    // High bits are the best-mixed ones; MEMO_SHARDS is a power of two,
    // so reduce with a mask instead of the previous `%`.
    (h >> 32) as usize & (MEMO_SHARDS - 1)
}

/// A two-generation (hot/cold) bounded map: the `api/cache.rs` eviction
/// idiom, factored out so the evaluation memo and the fragment store
/// share it.  When the hot generation reaches `capacity` and a *new*
/// key arrives, hot becomes cold (dropping the previous cold
/// generation) and a fresh hot generation starts.  Reads that miss hot
/// but hit cold promote the entry back into hot, so actively reused
/// entries never age out.
pub(crate) struct TwoGen<K, V> {
    hot: HashMap<K, V>,
    cold: HashMap<K, V>,
    capacity: usize,
}

impl<K: Eq + Hash, V> TwoGen<K, V> {
    pub(crate) fn new(capacity: usize) -> Self {
        Self { hot: HashMap::new(), cold: HashMap::new(), capacity: capacity.max(1) }
    }

    /// Hot-generation lookup only — safe under a shared (read) lock.
    pub(crate) fn peek_hot<Q>(&self, key: &Q) -> Option<&V>
    where
        K: Borrow<Q>,
        Q: Eq + Hash + ?Sized,
    {
        self.hot.get(key)
    }

    /// Full lookup with cold→hot promotion; needs the exclusive lock.
    pub(crate) fn get_promote<Q>(&mut self, key: &Q) -> Option<&V>
    where
        K: Borrow<Q> + Clone,
        Q: Eq + Hash + ?Sized,
    {
        // Double-check hot (the caller may have dropped a read lock
        // between its hot miss and acquiring the write lock).
        if self.hot.contains_key(key) {
            return self.hot.get(key);
        }
        if let Some((k, v)) = self.cold.remove_entry(key) {
            // Promotion does not rotate (that would drop the very
            // generation being read); `insert` re-establishes the bound
            // on its next rotation.
            self.hot.insert(k, v);
            return self.hot.get(key);
        }
        None
    }

    pub(crate) fn insert(&mut self, key: K, value: V) {
        if self.hot.len() >= self.capacity && !self.hot.contains_key(&key) {
            self.cold = std::mem::take(&mut self.hot);
        }
        self.cold.remove(&key);
        self.hot.insert(key, value);
    }

    pub(crate) fn clear(&mut self) {
        self.hot.clear();
        self.cold.clear();
    }

    pub(crate) fn len(&self) -> usize {
        self.hot.len() + self.cold.len()
    }

    pub(crate) fn is_empty(&self) -> bool {
        self.hot.is_empty() && self.cold.is_empty()
    }
}

type Shard = TwoGen<Box<[u32]>, SimOutcome>;

/// Sharded, lock-striped evaluation cache with exact hit/miss
/// accounting and two-generation eviction.  All methods take `&self`;
/// clone an `Arc<MemoTable>` to share it across search workers.
pub struct MemoTable {
    shards: Vec<RwLock<Shard>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Default for MemoTable {
    fn default() -> Self {
        Self::new()
    }
}

impl MemoTable {
    pub fn new() -> Self {
        Self {
            shards: (0..MEMO_SHARDS).map(|_| RwLock::new(TwoGen::new(SHARD_CAPACITY))).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    pub fn get(&self, key: &[u32]) -> Option<SimOutcome> {
        let shard = &self.shards[shard_index(key)];
        // Fast path: hot-generation hit under the shared lock.
        if let Some(v) = shard.read().unwrap().peek_hot(key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Some(v.clone());
        }
        // Slow path: the exclusive lock allows cold→hot promotion.
        let mut shard = shard.write().unwrap();
        match shard.get_promote(key) {
            Some(v) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(v.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    pub fn insert(&self, key: Box<[u32]>, value: SimOutcome) {
        self.shards[shard_index(&key)].write().unwrap().insert(key, value);
    }

    pub fn clear(&self) {
        for shard in &self.shards {
            shard.write().unwrap().clear();
        }
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.read().unwrap().is_empty())
    }

    /// (hits, misses) since construction or the last `clear`.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Hits / (hits + misses), 0.0 when the table has never been probed.
    pub fn hit_rate(&self) -> f64 {
        let (hits, misses) = self.stats();
        let total = hits + misses;
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }

    /// Entry count per stripe (test/diagnostic visibility into striping).
    pub fn shard_lens(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.read().unwrap().len()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(time: f64) -> SimOutcome {
        SimOutcome { time, ..Default::default() }
    }

    #[test]
    fn hit_and_miss_accounting() {
        let m = MemoTable::new();
        let key: Box<[u32]> = vec![1, 2, 3].into_boxed_slice();
        assert!(m.get(&key).is_none());
        m.insert(key.clone(), outcome(1.5));
        let got = m.get(&key).unwrap();
        assert_eq!(got.time, 1.5);
        assert_eq!(m.stats(), (1, 1));
        assert_eq!(m.len(), 1);
        assert!((m.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn distinct_keys_distinct_entries() {
        let m = MemoTable::new();
        m.insert(vec![1].into_boxed_slice(), outcome(1.0));
        m.insert(vec![2].into_boxed_slice(), outcome(2.0));
        assert_eq!(m.get(&[1u32][..]).unwrap().time, 1.0);
        assert_eq!(m.get(&[2u32][..]).unwrap().time, 2.0);
    }

    #[test]
    fn clear_resets_everything() {
        let m = MemoTable::new();
        m.insert(vec![1].into_boxed_slice(), outcome(1.0));
        let _ = m.get(&[1u32][..]);
        m.clear();
        assert!(m.is_empty());
        assert_eq!(m.stats(), (0, 0));
        assert_eq!(m.hit_rate(), 0.0);
    }

    #[test]
    fn keys_spread_across_shards() {
        let m = MemoTable::new();
        for i in 0..256u32 {
            m.insert(vec![i, i ^ 7, 3].into_boxed_slice(), outcome(i as f64));
        }
        let lens = m.shard_lens();
        assert_eq!(lens.len(), MEMO_SHARDS);
        assert_eq!(lens.iter().sum::<usize>(), 256);
        let occupied = lens.iter().filter(|&&l| l > 0).count();
        assert!(occupied > MEMO_SHARDS / 2, "striping degenerate: {lens:?}");
    }

    #[test]
    fn concurrent_hit_miss_accounting_is_exact() {
        // 8 threads × 40 rounds over 64 shared keys: every probe is either
        // a hit or a miss (never lost), inserts never duplicate entries,
        // and each key misses at least once before anyone can hit it.
        const THREADS: usize = 8;
        const ROUNDS: usize = 40;
        const KEYS: usize = 64;
        let m = MemoTable::new();
        let keys: Vec<Box<[u32]>> =
            (0..KEYS as u32).map(|i| vec![i, i.wrapping_mul(31), 5].into_boxed_slice()).collect();
        std::thread::scope(|s| {
            for _ in 0..THREADS {
                let m = &m;
                let keys = &keys;
                s.spawn(move || {
                    for round in 0..ROUNDS {
                        for k in keys {
                            match m.get(k) {
                                Some(v) => assert!(v.time >= 0.0),
                                None => m.insert(k.clone(), outcome(round as f64)),
                            }
                        }
                    }
                });
            }
        });
        let (hits, misses) = m.stats();
        assert_eq!(hits + misses, (THREADS * ROUNDS * KEYS) as u64);
        assert!(misses >= KEYS as u64, "each key must miss at least once");
        assert!(hits > 0, "steady state must hit");
        assert_eq!(m.len(), KEYS);
    }

    #[test]
    fn rotation_keeps_the_previous_generation_warm() {
        // A tiny TwoGen directly: filling hot and inserting one more must
        // not leave the map cold, and unused entries die after two
        // generations while promoted ones survive.
        let mut g: TwoGen<u32, u32> = TwoGen::new(2);
        g.insert(1, 10);
        g.insert(2, 20);
        g.insert(3, 30); // rotates: cold={1,2}, hot={3}
        assert_eq!(g.len(), 3);
        assert_eq!(g.get_promote(&1), Some(&10)); // promotes 1 into hot
        g.insert(4, 40); // rotates: cold={1,3}, hot={4}
        g.insert(5, 50); // hot={4,5}
        assert!(g.get_promote(&1).is_some(), "promoted entry survives");
        assert!(g.get_promote(&2).is_none(), "two generations old: evicted");
        // Re-inserting an existing hot key never rotates.
        let mut g: TwoGen<u32, u32> = TwoGen::new(2);
        g.insert(1, 10);
        g.insert(2, 20);
        g.insert(2, 21);
        assert_eq!(g.len(), 2);
        assert_eq!(g.peek_hot(&2), Some(&21));
    }

    #[test]
    fn memo_eviction_is_generational_not_wholesale() {
        // Overfill one logical table far past capacity: the table must
        // stay bounded by two generations per shard and still serve
        // recently inserted keys (the old wholesale clear dropped them).
        let m = MemoTable::new();
        let total = MEMO_CAPACITY * 3;
        let mut last = Vec::new();
        for i in 0..total as u32 {
            let key: Box<[u32]> = vec![i, i ^ 0x5bd1, 9].into_boxed_slice();
            m.insert(key.clone(), outcome(f64::from(i)));
            if i as usize >= total - 64 {
                last.push(key);
            }
        }
        assert!(m.len() <= 2 * MEMO_CAPACITY);
        for key in &last {
            assert!(m.get(key).is_some(), "freshly inserted keys must survive eviction");
        }
    }
}
