//! Transposition table for strategy evaluations.
//!
//! MCTS revisits the same *effective* deployment many times: the
//! footnote-2 completion rule maps every partial strategy to a complete
//! one, and different tree paths frequently complete to identical
//! deployments (every depth-1 vertex is the uniform strategy of its root
//! action, deeper vertices repeat whenever later groups copy the first
//! decided action).  Keying the cache on the *resolved* per-group action
//! vector — not the raw slot vector — therefore collapses all of them
//! onto one entry.
//!
//! The signature is exact (no hashing tricks beyond `HashMap`'s): one
//! `u32` per op group encoding `(mask << 3) | option`, plus one flags
//! word for the batch-split mode and the sync-barrier bit.  Outcomes are
//! stored by value and cloned out; a [`SimOutcome`] is a few short
//! vectors, which is 1–2 orders of magnitude cheaper than re-lowering
//! and re-simulating.

use std::collections::HashMap;

use super::lower::SimOutcome;

/// Hard cap on cached entries; the table is cleared wholesale when it
/// fills (searches are bounded, so eviction order is irrelevant — this
/// only guards pathological long-lived `Lowering` instances).
pub const MEMO_CAPACITY: usize = 1 << 16;

#[derive(Default)]
pub struct MemoTable {
    map: HashMap<Box<[u32]>, SimOutcome>,
    hits: u64,
    misses: u64,
}

impl MemoTable {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn get(&mut self, key: &[u32]) -> Option<SimOutcome> {
        match self.map.get(key) {
            Some(v) => {
                self.hits += 1;
                Some(v.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    pub fn insert(&mut self, key: Box<[u32]>, value: SimOutcome) {
        if self.map.len() >= MEMO_CAPACITY {
            self.map.clear();
        }
        self.map.insert(key, value);
    }

    pub fn clear(&mut self) {
        self.map.clear();
        self.hits = 0;
        self.misses = 0;
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// (hits, misses) since construction or the last `clear`.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(time: f64) -> SimOutcome {
        SimOutcome { time, ..Default::default() }
    }

    #[test]
    fn hit_and_miss_accounting() {
        let mut m = MemoTable::new();
        let key: Box<[u32]> = vec![1, 2, 3].into_boxed_slice();
        assert!(m.get(&key).is_none());
        m.insert(key.clone(), outcome(1.5));
        let got = m.get(&key).unwrap();
        assert_eq!(got.time, 1.5);
        assert_eq!(m.stats(), (1, 1));
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn distinct_keys_distinct_entries() {
        let mut m = MemoTable::new();
        m.insert(vec![1].into_boxed_slice(), outcome(1.0));
        m.insert(vec![2].into_boxed_slice(), outcome(2.0));
        assert_eq!(m.get(&[1u32][..]).unwrap().time, 1.0);
        assert_eq!(m.get(&[2u32][..]).unwrap().time, 2.0);
    }

    #[test]
    fn clear_resets_everything() {
        let mut m = MemoTable::new();
        m.insert(vec![1].into_boxed_slice(), outcome(1.0));
        let _ = m.get(&[1u32][..]);
        m.clear();
        assert!(m.is_empty());
        assert_eq!(m.stats(), (0, 0));
    }
}
