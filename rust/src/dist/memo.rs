//! Concurrent transposition table for strategy evaluations.
//!
//! MCTS revisits the same *effective* deployment many times: the
//! footnote-2 completion rule maps every partial strategy to a complete
//! one, and different tree paths frequently complete to identical
//! deployments (every depth-1 vertex is the uniform strategy of its root
//! action, deeper vertices repeat whenever later groups copy the first
//! decided action).  Keying the cache on the *resolved* per-group action
//! vector — not the raw slot vector — therefore collapses all of them
//! onto one entry.
//!
//! The signature is exact (no hashing tricks beyond `HashMap`'s): one
//! `u32` per op group encoding `(mask << 3) | option`, plus one flags
//! word for the batch-split mode and the sync-barrier bit.  Outcomes are
//! stored by value and cloned out; a [`SimOutcome`] is a few short
//! vectors, which is 1–2 orders of magnitude cheaper than re-lowering
//! and re-simulating.
//!
//! ## One implementation for both execution modes
//!
//! The table is **sharded and `RwLock`-striped** so the sequential
//! search path and the tree-parallel workers of [`crate::search`] share
//! a single implementation: a key hashes (FNV-1a over its words) to one
//! of [`MEMO_SHARDS`] stripes, lookups take that stripe's read lock,
//! inserts its write lock, and the hit/miss counters are relaxed
//! atomics.  Uncontended, a stripe lock is a single atomic operation —
//! the sequential path pays nothing measurable for the sharing — while
//! under K workers the stripes keep evaluation traffic from serializing
//! on one lock.  `dist::Lowering` holds the table behind an `Arc`
//! ([`Lowering::memo_handle`](super::Lowering::memo_handle)), so per-worker
//! lowerings can pool their outcomes.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

use super::lower::SimOutcome;

/// Number of independently locked stripes.  A power of two comfortably
/// above any realistic worker count, small enough that `len`/`clear`
/// sweeps stay trivial.
pub const MEMO_SHARDS: usize = 16;

/// Hard cap on cached entries across all shards; a shard is cleared
/// wholesale when its share fills (searches are bounded, so eviction
/// order is irrelevant — this only guards pathological long-lived
/// `Lowering` instances).
pub const MEMO_CAPACITY: usize = 1 << 16;

const SHARD_CAPACITY: usize = MEMO_CAPACITY / MEMO_SHARDS;

/// FNV-1a over the signature words, used only to pick a stripe (the
/// in-shard `HashMap` hashes with its own keyed hasher).
fn shard_index(key: &[u32]) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &w in key {
        h ^= w as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    // High bits are the best-mixed ones for a non-power-of-two-agnostic
    // reduction; MEMO_SHARDS is a power of two so a mask would also do.
    (h >> 32) as usize % MEMO_SHARDS
}

#[derive(Default)]
struct Shard {
    map: HashMap<Box<[u32]>, SimOutcome>,
}

/// Sharded, lock-striped evaluation cache with exact hit/miss
/// accounting.  All methods take `&self`; clone an `Arc<MemoTable>` to
/// share it across search workers.
pub struct MemoTable {
    shards: Vec<RwLock<Shard>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Default for MemoTable {
    fn default() -> Self {
        Self::new()
    }
}

impl MemoTable {
    pub fn new() -> Self {
        Self {
            shards: (0..MEMO_SHARDS).map(|_| RwLock::new(Shard::default())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    pub fn get(&self, key: &[u32]) -> Option<SimOutcome> {
        let shard = self.shards[shard_index(key)].read().unwrap();
        match shard.map.get(key) {
            Some(v) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(v.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    pub fn insert(&self, key: Box<[u32]>, value: SimOutcome) {
        let mut shard = self.shards[shard_index(&key)].write().unwrap();
        if shard.map.len() >= SHARD_CAPACITY {
            shard.map.clear();
        }
        shard.map.insert(key, value);
    }

    pub fn clear(&self) {
        for shard in &self.shards {
            shard.write().unwrap().map.clear();
        }
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.read().unwrap().map.is_empty())
    }

    /// (hits, misses) since construction or the last `clear`.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Hits / (hits + misses), 0.0 when the table has never been probed.
    pub fn hit_rate(&self) -> f64 {
        let (hits, misses) = self.stats();
        let total = hits + misses;
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }

    /// Entry count per stripe (test/diagnostic visibility into striping).
    pub fn shard_lens(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.read().unwrap().map.len()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(time: f64) -> SimOutcome {
        SimOutcome { time, ..Default::default() }
    }

    #[test]
    fn hit_and_miss_accounting() {
        let m = MemoTable::new();
        let key: Box<[u32]> = vec![1, 2, 3].into_boxed_slice();
        assert!(m.get(&key).is_none());
        m.insert(key.clone(), outcome(1.5));
        let got = m.get(&key).unwrap();
        assert_eq!(got.time, 1.5);
        assert_eq!(m.stats(), (1, 1));
        assert_eq!(m.len(), 1);
        assert!((m.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn distinct_keys_distinct_entries() {
        let m = MemoTable::new();
        m.insert(vec![1].into_boxed_slice(), outcome(1.0));
        m.insert(vec![2].into_boxed_slice(), outcome(2.0));
        assert_eq!(m.get(&[1u32][..]).unwrap().time, 1.0);
        assert_eq!(m.get(&[2u32][..]).unwrap().time, 2.0);
    }

    #[test]
    fn clear_resets_everything() {
        let m = MemoTable::new();
        m.insert(vec![1].into_boxed_slice(), outcome(1.0));
        let _ = m.get(&[1u32][..]);
        m.clear();
        assert!(m.is_empty());
        assert_eq!(m.stats(), (0, 0));
        assert_eq!(m.hit_rate(), 0.0);
    }

    #[test]
    fn keys_spread_across_shards() {
        let m = MemoTable::new();
        for i in 0..256u32 {
            m.insert(vec![i, i ^ 7, 3].into_boxed_slice(), outcome(i as f64));
        }
        let lens = m.shard_lens();
        assert_eq!(lens.len(), MEMO_SHARDS);
        assert_eq!(lens.iter().sum::<usize>(), 256);
        let occupied = lens.iter().filter(|&&l| l > 0).count();
        assert!(occupied > MEMO_SHARDS / 2, "striping degenerate: {lens:?}");
    }

    #[test]
    fn concurrent_hit_miss_accounting_is_exact() {
        // 8 threads × 40 rounds over 64 shared keys: every probe is either
        // a hit or a miss (never lost), inserts never duplicate entries,
        // and each key misses at least once before anyone can hit it.
        const THREADS: usize = 8;
        const ROUNDS: usize = 40;
        const KEYS: usize = 64;
        let m = MemoTable::new();
        let keys: Vec<Box<[u32]>> =
            (0..KEYS as u32).map(|i| vec![i, i.wrapping_mul(31), 5].into_boxed_slice()).collect();
        std::thread::scope(|s| {
            for _ in 0..THREADS {
                let m = &m;
                let keys = &keys;
                s.spawn(move || {
                    for round in 0..ROUNDS {
                        for k in keys {
                            match m.get(k) {
                                Some(v) => assert!(v.time >= 0.0),
                                None => m.insert(k.clone(), outcome(round as f64)),
                            }
                        }
                    }
                });
            }
        });
        let (hits, misses) = m.stats();
        assert_eq!(hits + misses, (THREADS * ROUNDS * KEYS) as u64);
        assert!(misses >= KEYS as u64, "each key must miss at least once");
        assert!(hits > 0, "steady state must hit");
        assert_eq!(m.len(), KEYS);
    }
}
