//! The strategy compiler (paper §4.3): lowering a (group graph, device
//! topology, deployment strategy) triple into an executable form.
//!
//! Two lowering levels live here:
//!
//! * [`lower`] — the **group-level** lowering that the search hot path
//!   runs: [`Lowering`] compiles a [`Strategy`] into a [`crate::sim`]
//!   task graph (compute replicas per machine, NIC-serialized tensor
//!   transfers, gradient synchronization on a collective channel),
//!   simulates it, and interprets the schedule into a [`SimOutcome`]
//!   (iteration time + the runtime-feedback features of Table 1 + the
//!   peak-memory/OOM estimate).  This is the function called from every
//!   MCTS iteration, every baseline, and the coordinator.
//! * [`rewrite`] — the **op-level** graph compiler (§4.3.1): rewrites the
//!   full computation graph for a chosen strategy, inserting
//!   Split/Concat/AddN/NcclAllReduce auxiliary ops while preserving the
//!   mathematical-equivalence invariants checked in
//!   `rust/tests/equivalence.rs`.
//!
//! ## The performance layer
//!
//! MCTS evaluates hundreds of (mostly repeated) partial strategies per
//! search, so [`Lowering`] is built as a *compiler with a transposition
//! table* rather than a plain function:
//!
//! * [`memo`] — evaluations are memoized under a cheap **strategy
//!   signature**: the per-group *effective* action vector after the
//!   paper's footnote-2 completion rule, so distinct partial strategies
//!   that complete to the same deployment share one cache entry.  The
//!   table is sharded and `RwLock`-striped with atomic counters — the
//!   one implementation behind both the sequential engine and the
//!   tree-parallel workers of [`crate::search`], which share it through
//!   [`Lowering::memo_handle`].
//! * per-group task *fragments* (summed linear batch-time models per
//!   machine, the inter-group edge list, mask → device-set expansions)
//!   are precomputed once in [`Lowering::new`] and stitched per strategy
//!   instead of re-deriving them from the op graph on every call.
//! * the discrete-event simulator's indegree/successor/queue buffers are
//!   preallocated and reused across evaluations
//!   ([`crate::sim::Simulator`]).
//!
//! [`Strategy`]: crate::strategy::Strategy

pub mod lower;
pub mod memo;
pub mod rewrite;

pub use lower::{Feedback, Lowering, SimOutcome};
pub use rewrite::{rewrite as rewrite_graph, DistGraph};
