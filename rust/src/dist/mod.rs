//! The strategy compiler (paper §4.3): lowering a (group graph, device
//! topology, deployment strategy) triple into an executable form.
//!
//! Two lowering levels live here:
//!
//! * [`lower`] — the **group-level** lowering that the search hot path
//!   runs: [`Lowering`] compiles a [`Strategy`] into a [`crate::sim`]
//!   task graph (compute replicas per machine, NIC-serialized tensor
//!   transfers, gradient synchronization on a collective channel),
//!   simulates it, and interprets the schedule into a [`SimOutcome`]
//!   (iteration time + the runtime-feedback features of Table 1 + the
//!   peak-memory/OOM estimate).  This is the function called from every
//!   MCTS iteration, every baseline, and the coordinator.
//! * [`rewrite`] — the **op-level** graph compiler (§4.3.1): rewrites the
//!   full computation graph for a chosen strategy, inserting
//!   Split/Concat/AddN/NcclAllReduce auxiliary ops while preserving the
//!   mathematical-equivalence invariants checked in
//!   `rust/tests/equivalence.rs`.
//!
//! ## The performance layer
//!
//! MCTS evaluates hundreds of (mostly repeated) partial strategies per
//! search, so [`Lowering`] is built as a *compiler with a transposition
//! table* rather than a plain function:
//!
//! * [`memo`] — evaluations are memoized under a cheap **strategy
//!   signature**: the per-group *effective* action vector after the
//!   paper's footnote-2 completion rule, so distinct partial strategies
//!   that complete to the same deployment share one cache entry.  The
//!   table is sharded and `RwLock`-striped with atomic counters, and
//!   evicts by two-generation hot/cold rotation so long-lived daemons
//!   never drop their warmest entries wholesale.
//! * [`fragments`] — the **incremental-evaluation layer**: a shared
//!   [`FragmentStore`] memoizes per-group and per-edge lowered pieces
//!   keyed on the resolved actions, and each `Lowering` keeps a small
//!   ring of recent (graph, schedule) records so a signature differing
//!   from a neighbor in a few groups re-simulates only from its proven
//!   divergence horizon ([`crate::sim::Simulator::resume`]).  Outcomes
//!   are bit-identical with the path on or off; `delta_hit_rate` /
//!   `frontier_restart_frac` ride in plan telemetry.
//! * all three shared tiers (evaluation memo, fragment store, mask
//!   link-profile memo) travel as one [`EvalCaches`] bundle, cloned into
//!   the per-worker `Lowering`s of [`crate::search`] through
//!   [`Lowering::with_caches`].
//! * the discrete-event simulator's indegree/successor/queue buffers are
//!   preallocated and reused across evaluations
//!   ([`crate::sim::Simulator`]).
//!
//! [`Strategy`]: crate::strategy::Strategy

pub mod fragments;
pub mod lower;
pub mod memo;
pub mod rewrite;

pub use fragments::{DeltaStats, EvalCaches, FragmentStore, MaskProfileMemo};
pub use lower::{Feedback, Lowering, SimOutcome, DELTA_MAX_FLIPS};
pub use rewrite::{rewrite as rewrite_graph, DistGraph};
