//! Tiny statistics helpers shared by the profiler, the bench harness and
//! experiment reports.

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// p in `[0, 100]`; linear interpolation between order statistics.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Ordinary least squares fit `y ~ a + b x`; returns `(a, b)`.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
    }
    if sxx.abs() < 1e-30 || n < 2.0 {
        return (my, 0.0);
    }
    let b = sxy / sxx;
    (my - b * mx, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(stddev(&[1.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
        // interpolated
        assert!((percentile(&xs, 10.0) - 1.4).abs() < 1e-12);
    }

    #[test]
    fn linear_fit_exact_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
    }

    #[test]
    fn linear_fit_degenerate_x() {
        let (a, b) = linear_fit(&[2.0, 2.0], &[5.0, 7.0]);
        assert_eq!(b, 0.0);
        assert_eq!(a, 6.0);
    }
}
