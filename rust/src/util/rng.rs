//! Deterministic xoshiro256** RNG.
//!
//! The vendored dependency set has no `rand` crate; this is a small,
//! well-tested generator that gives TAG deterministic searches, topology
//! generation and self-play (a fixed seed reproduces every experiment
//! exactly).

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)`; `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Multiply-shift bounded generation (Lemire) — bias is negligible
        // for the small ranges used here.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    /// Falls back to uniform if all weights are ~0.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 1e-12 {
            return self.below(weights.len());
        }
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            let x = r.below(7);
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(3);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            let x = r.range(3, 5);
            assert!((3..=5).contains(&x));
            lo_seen |= x == 3;
            hi_seen |= x == 5;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn uniform_mean_is_centered() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform(2.0, 4.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy_weights() {
        let mut r = Rng::new(7);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > 5 * counts[0]);
    }

    #[test]
    fn weighted_all_zero_falls_back_to_uniform() {
        let mut r = Rng::new(8);
        let w = [0.0, 0.0, 0.0];
        let mut seen = [false; 3];
        for _ in 0..1000 {
            seen[r.weighted(&w)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
