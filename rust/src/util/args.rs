//! Minimal CLI flag parser (the vendored dependency set has no `clap`).
//!
//! Grammar per token:
//!
//! * `--key=value` — explicit pair; the value may be anything,
//!   including empty or starting with `-`.
//! * `--key value` — pair, where `value` is the next token when it does
//!   not itself look like a flag.  Negative numbers (`-0.5`, `-3`,
//!   `-.25`, `-1e-3`) are values, not flags.
//! * `--key` followed by another flag (or nothing) — boolean `true`.
//!
//! Tokens that are not flags and were not consumed as values are
//! reported through [`Args::parse`]'s error so the CLI can print usage
//! instead of silently ignoring them.

use std::collections::HashMap;

/// Parsed `--key value` / `--key=value` / `--flag` arguments.
#[derive(Debug, Default, PartialEq, Eq)]
pub struct Args {
    kv: HashMap<String, String>,
}

/// Does a token that starts with `-` denote a *value* (negative number)
/// rather than a flag?
fn is_negative_number(token: &str) -> bool {
    let rest = match token.strip_prefix('-') {
        Some(r) if !r.is_empty() => r,
        _ => return false,
    };
    rest.starts_with(|c: char| c.is_ascii_digit() || c == '.')
        && rest.parse::<f64>().is_ok()
}

impl Args {
    /// Parse a token list; `Err` carries the first unexpected
    /// (non-flag, unconsumed) token.
    pub fn parse(args: &[String]) -> Result<Self, String> {
        let mut kv = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let token = &args[i];
            let Some(body) = token.strip_prefix("--") else {
                return Err(token.clone());
            };
            if body.is_empty() {
                return Err(token.clone());
            }
            if let Some((key, value)) = body.split_once('=') {
                kv.insert(key.to_string(), value.to_string());
                i += 1;
                continue;
            }
            let value_next = match args.get(i + 1) {
                Some(next) => !next.starts_with('-') || is_negative_number(next),
                None => false,
            };
            if value_next {
                kv.insert(body.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                kv.insert(body.to_string(), "true".to_string());
                i += 1;
            }
        }
        Ok(Self { kv })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(|s| s.as_str())
    }

    /// Boolean flag: present bare, or with an explicit truthy value.
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Parse a numeric (or any `FromStr`) value, with a default.
    pub fn num<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        let owned: Vec<String> = tokens.iter().map(|s| s.to_string()).collect();
        Args::parse(&owned).expect("parse")
    }

    #[test]
    fn space_separated_pairs() {
        let a = parse(&["--model", "VGG19", "--iters", "200"]);
        assert_eq!(a.get("model"), Some("VGG19"));
        assert_eq!(a.num("iters", 0usize), 200);
    }

    #[test]
    fn equals_form() {
        let a = parse(&["--model=BERT-Small", "--scale=0.5", "--note=a=b"]);
        assert_eq!(a.get("model"), Some("BERT-Small"));
        assert_eq!(a.num("scale", 0.0f64), 0.5);
        // Only the first `=` splits.
        assert_eq!(a.get("note"), Some("a=b"));
    }

    #[test]
    fn negative_values_are_not_swallowed_as_flags() {
        let a = parse(&["--scale", "-0.5", "--offset", "-3", "--eps", "-1e-3"]);
        assert_eq!(a.num("scale", 0.0f64), -0.5);
        assert_eq!(a.num("offset", 0i64), -3);
        assert_eq!(a.num("eps", 0.0f64), -1e-3);
        let b = parse(&["--scale=-0.5"]);
        assert_eq!(b.num("scale", 0.0f64), -0.5);
    }

    #[test]
    fn boolean_flags() {
        let a = parse(&["--no-sfb", "--model", "VGG19", "--verbose"]);
        assert!(a.flag("no-sfb"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("absent"));
        assert_eq!(a.get("model"), Some("VGG19"));
        // A following flag is not consumed as a value.
        let b = parse(&["--no-sfb", "--iters", "10"]);
        assert!(b.flag("no-sfb"));
        assert_eq!(b.num("iters", 0usize), 10);
    }

    #[test]
    fn dashed_non_numbers_stay_flags() {
        // `-x` is not a negative number, so `--mode` is boolean and the
        // stray `-x` is the parse error.
        let owned: Vec<String> = ["--mode", "-x"].iter().map(|s| s.to_string()).collect();
        assert_eq!(Args::parse(&owned), Err("-x".to_string()));
    }

    #[test]
    fn unexpected_positional_reported() {
        let owned: Vec<String> = ["stray"].iter().map(|s| s.to_string()).collect();
        assert_eq!(Args::parse(&owned), Err("stray".to_string()));
        let owned: Vec<String> = ["--"].iter().map(|s| s.to_string()).collect();
        assert_eq!(Args::parse(&owned), Err("--".to_string()));
    }

    #[test]
    fn empty_and_defaults() {
        let a = parse(&[]);
        assert_eq!(a.get("anything"), None);
        assert_eq!(a.num("iters", 7usize), 7);
        let b = parse(&["--name="]);
        assert_eq!(b.get("name"), Some(""));
    }
}
