//! Small shared utilities: deterministic RNG, statistics, a property-test
//! helper macro and simple timers.
//!
//! The crate is fully deterministic (no `rand`, no wall-clock in any
//! decision path): every stochastic component takes an explicit [`Rng`]
//! seeded by the caller, so experiments in EXPERIMENTS.md are exactly
//! reproducible.

pub mod args;
pub mod error;
pub mod rng;
pub mod stats;

pub use args::Args;
pub use rng::Rng;
pub use stats::{mean, percentile, stddev};

/// Mutex lock that shrugs off poisoning.  Everything the crate guards
/// this way (plan cache, prepared memo, in-flight tables, admission
/// queues) is valid after any panic that interrupted a holder — worst
/// case an entry is missing, which only costs recomputation.  A serving
/// daemon must not let one panicked request wedge every later one.
pub fn lock<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Wall-clock stopwatch used by benches and the overhead experiment.
pub struct Stopwatch {
    start: std::time::Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: std::time::Instant::now() }
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Minimal bench harness (the vendored dependency set has no criterion):
/// warm up once, then run until `min_time_s` elapses, reporting mean and
/// standard deviation per iteration.  Returns the mean seconds.
pub fn bench<F: FnMut()>(name: &str, min_time_s: f64, mut f: F) -> f64 {
    f(); // warm-up
    let mut times = Vec::new();
    let total = Stopwatch::start();
    while total.elapsed_s() < min_time_s || times.len() < 3 {
        let w = Stopwatch::start();
        f();
        times.push(w.elapsed_s());
        if times.len() >= 10_000 {
            break;
        }
    }
    let m = stats::mean(&times);
    let sd = stats::stddev(&times);
    println!(
        "{name:<44} {:>12}/iter  ±{:>10}  ({} iters)",
        fmt_secs(m),
        fmt_secs(sd),
        times.len()
    );
    m
}

/// Format a byte count human-readably (used in reports and traces).
pub fn fmt_bytes(b: f64) -> String {
    if b >= 1e9 {
        format!("{:.2} GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.2} MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.2} KB", b / 1e3)
    } else {
        format!("{b:.0} B")
    }
}

/// Format a duration given in seconds (used in reports and traces).
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512.0), "512 B");
        assert_eq!(fmt_bytes(2_500.0), "2.50 KB");
        assert_eq!(fmt_bytes(3_200_000.0), "3.20 MB");
        assert_eq!(fmt_bytes(7.5e9), "7.50 GB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(fmt_secs(1.5), "1.500 s");
        assert_eq!(fmt_secs(0.0123), "12.300 ms");
        assert_eq!(fmt_secs(42e-6), "42.0 us");
    }
}
