//! Minimal error plumbing for the I/O-facing modules ([`crate::runtime`],
//! [`crate::gnn`]): a string-backed error type, a `Context` extension
//! trait and `ensure!`/`bail!` macros.  The vendored dependency set has no
//! `anyhow`; this mirrors the slice of its API the crate uses so the
//! artifact-loading paths keep readable error chains.

use std::fmt;

/// A flat, message-only error.  Context layers are joined with `: `,
/// outermost first, matching the chain formatting callers print.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Self { msg: m.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Self::msg(e.to_string())
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Self {
        Self::msg(e.to_string())
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Self {
        Self::msg(e.to_string())
    }
}

impl From<String> for Error {
    fn from(m: String) -> Self {
        Self::msg(m)
    }
}

impl From<&str> for Error {
    fn from(m: &str) -> Self {
        Self::msg(m)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error (or a missing `Option` value), outermost
/// message first.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Return early with an [`Error`] if a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr) => {
        if !($cond) {
            return Err($crate::util::error::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            ))
            .into());
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::util::error::Error::msg(format!($($arg)+)).into());
        }
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::util::error::Error::msg(format!($($arg)+)).into())
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        let parsed: Result<u32> = "nope".parse::<u32>().map_err(Error::from);
        parsed.context("parsing the answer")
    }

    #[test]
    fn context_chains_outermost_first() {
        let e = fails().unwrap_err();
        assert!(e.to_string().starts_with("parsing the answer: "), "{e}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        assert_eq!(Some(7).context("missing").unwrap(), 7);
    }

    #[test]
    fn ensure_and_bail() {
        fn check(x: u32) -> Result<u32> {
            crate::ensure!(x < 10, "x too big: {x}");
            crate::ensure!(x != 5);
            if x == 3 {
                crate::bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(check(2).unwrap(), 2);
        assert!(check(12).unwrap_err().to_string().contains("x too big"));
        assert!(check(5).unwrap_err().to_string().contains("x != 5"));
        assert!(check(3).unwrap_err().to_string().contains("right out"));
    }
}
