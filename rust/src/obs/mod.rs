//! Observability: hierarchical spans, a bounded flight recorder, and
//! plan explainability (the [`explain`] submodule).
//!
//! The planner's request lifecycle — admission → coalesce → cache
//! lookup → prepare → per-worker MCTS iterations → lowering →
//! simulation → SFB pass — is instrumented with [`span`] guards.  A
//! span is recorded only while a [`Tracer`] is installed on the
//! current thread ([`Tracer::install`]); with no tracer installed a
//! guard costs one thread-local read and a branch, and nothing is
//! allocated.  Recording is lock-free on the hot path: spans land in a
//! per-thread buffer and are flushed to the shared trace in batches.
//!
//! ## Determinism contract
//!
//! Timestamps are monotonic-clock readings and live **only** in traces
//! (`/debug/trace`, `--trace-out`) and in `/metrics` — they never enter
//! a [`DeploymentPlan`](crate::api::DeploymentPlan), a fingerprint, or
//! anything else a plan's bytes are derived from.  Tracing on/off
//! therefore yields byte-identical plans; `rust/tests/properties.rs`
//! pins this at `workers == 1` (full plan bytes) and `workers == 4`
//! (evaluation-layer outcomes).
//!
//! ## Flight recorder
//!
//! The daemon retains the last N request traces in a [`FlightRecorder`]
//! ring; `GET /debug/trace` exports them as Chrome trace-event JSON
//! ([`chrome_trace_json`]) which loads directly in Perfetto or
//! `chrome://tracing`.  Memory is bounded twice over: each trace caps
//! its span count ([`MAX_SPANS_PER_TRACE`], overflow counted, never
//! grown) and the ring evicts its oldest trace once full (evictions
//! surface as `tag_trace_dropped_total`).

use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::api::json::Json;
use crate::util::lock;

pub mod explain;

/// Hard per-trace span cap: spans past it are dropped (and counted in
/// [`Trace::truncated`]) instead of growing the trace without bound —
/// a deep search emits per-iteration spans, and one runaway request
/// must not balloon the daemon's flight-recorder memory.
pub const MAX_SPANS_PER_TRACE: usize = 4096;

/// Per-thread buffer size before spans flush to the shared trace (one
/// mutex acquisition amortized over this many spans).
const FLUSH_BATCH: usize = 64;

/// One closed span: a named interval on one traced thread.  Times are
/// nanoseconds since the owning trace's epoch (a monotonic
/// [`Instant`]), so they order and nest exactly; they carry no
/// wall-clock meaning and never touch plan bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanRecord {
    pub name: &'static str,
    /// Optional integer payload (worker index, fleet job id, …);
    /// negative = none.
    pub arg: i64,
    /// Trace-local thread id, allocated per [`Tracer::install`].
    pub tid: u32,
    /// Nesting depth under the thread's outermost span (0 = root).
    pub depth: u16,
    pub start_ns: u64,
    pub dur_ns: u64,
}

/// Shared state of one in-progress trace.
struct TraceInner {
    label: String,
    epoch: Instant,
    spans: Mutex<Vec<SpanRecord>>,
    truncated: AtomicU64,
    next_tid: AtomicU64,
}

/// A finished trace: what [`Tracer::finish`] returns and the
/// [`FlightRecorder`] retains.
#[derive(Clone, Debug)]
pub struct Trace {
    pub label: String,
    /// Sorted by `(tid, start_ns)`; on one tid spans nest by interval
    /// containment (guard drop order is stack order).
    pub spans: Vec<SpanRecord>,
    /// Spans dropped past [`MAX_SPANS_PER_TRACE`].
    pub truncated: u64,
}

impl Trace {
    /// Total `dur_ns` per span name, in first-appearance order — the
    /// compact phase summary slow-request logging emits.
    pub fn phase_totals(&self) -> Vec<(&'static str, u64)> {
        let mut totals: Vec<(&'static str, u64)> = Vec::new();
        for s in &self.spans {
            match totals.iter_mut().find(|(n, _)| *n == s.name) {
                Some((_, t)) => *t += s.dur_ns,
                None => totals.push((s.name, s.dur_ns)),
            }
        }
        totals
    }

    /// End of the latest span, ns since the trace epoch (0 if empty).
    pub fn total_ns(&self) -> u64 {
        self.spans.iter().map(|s| s.start_ns + s.dur_ns).max().unwrap_or(0)
    }
}

/// A handle to one trace — cheap to clone, `None` inside means
/// disabled (every operation is a no-op).  The ambient tracer of a
/// thread is whatever was last [`install`](Tracer::install)ed on it;
/// worker threads inherit it by capturing [`Tracer::current`] before
/// spawning and installing the clone inside the thread.
#[derive(Clone, Default)]
pub struct Tracer(Option<Arc<TraceInner>>);

impl Tracer {
    /// A tracer that records nothing (the default everywhere tracing
    /// was not explicitly requested).
    pub fn disabled() -> Self {
        Self(None)
    }

    /// Start a new trace; `label` names it in exports (e.g. the
    /// request endpoint).
    pub fn enabled(label: &str) -> Self {
        Self(Some(Arc::new(TraceInner {
            label: label.to_string(),
            epoch: Instant::now(),
            spans: Mutex::new(Vec::new()),
            truncated: AtomicU64::new(0),
            next_tid: AtomicU64::new(0),
        })))
    }

    pub fn is_enabled(&self) -> bool {
        self.0.is_some()
    }

    /// The calling thread's ambient tracer (disabled when none is
    /// installed).  Capture this *before* spawning scoped workers and
    /// [`install`](Tracer::install) the clone inside each.
    pub fn current() -> Self {
        CTX.with(|c| Self(c.borrow().as_ref().map(|ctx| Arc::clone(&ctx.inner))))
    }

    /// Install this tracer on the current thread until the returned
    /// guard drops (which flushes the thread's buffered spans and
    /// restores whatever tracer was installed before).  Disabled
    /// tracers install nothing.
    pub fn install(&self) -> InstallGuard {
        match &self.0 {
            None => InstallGuard { installed: false, prev: None },
            Some(inner) => {
                let tid = inner.next_tid.fetch_add(1, Ordering::Relaxed) as u32;
                let prev = CTX.with(|c| {
                    c.borrow_mut().replace(ThreadCtx {
                        inner: Arc::clone(inner),
                        tid,
                        depth: 0,
                        buf: Vec::with_capacity(FLUSH_BATCH),
                    })
                });
                InstallGuard { installed: true, prev }
            }
        }
    }

    /// Close the trace and take its spans (sorted by `(tid,
    /// start_ns)`).  `None` for a disabled tracer.  Every install
    /// guard must have dropped first — spans still buffered on other
    /// threads are not in the snapshot.
    pub fn finish(self) -> Option<Trace> {
        let inner = self.0?;
        let mut spans = std::mem::take(&mut *lock(&inner.spans));
        spans.sort_by_key(|s| (s.tid, s.start_ns, std::cmp::Reverse(s.dur_ns)));
        Some(Trace {
            label: inner.label.clone(),
            spans,
            truncated: inner.truncated.load(Ordering::Relaxed),
        })
    }
}

/// Per-thread tracing state (the TLS slot [`span`] reads).
struct ThreadCtx {
    inner: Arc<TraceInner>,
    tid: u32,
    depth: u16,
    buf: Vec<SpanRecord>,
}

impl ThreadCtx {
    fn flush(&mut self) {
        if self.buf.is_empty() {
            return;
        }
        let mut spans = lock(&self.inner.spans);
        for s in self.buf.drain(..) {
            if spans.len() < MAX_SPANS_PER_TRACE {
                spans.push(s);
            } else {
                self.inner.truncated.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

thread_local! {
    static CTX: RefCell<Option<ThreadCtx>> = const { RefCell::new(None) };
}

/// Restores the previously installed tracer on drop (see
/// [`Tracer::install`]).
pub struct InstallGuard {
    installed: bool,
    prev: Option<ThreadCtx>,
}

impl Drop for InstallGuard {
    fn drop(&mut self) {
        if !self.installed {
            return;
        }
        let prev = self.prev.take();
        CTX.with(|c| {
            let mut slot = c.borrow_mut();
            if let Some(ctx) = slot.as_mut() {
                ctx.flush();
            }
            *slot = prev;
        });
    }
}

/// Open a span named `name` on the current thread; it closes (and is
/// recorded) when the returned guard drops.  Inert when no tracer is
/// installed.
pub fn span(name: &'static str) -> SpanGuard {
    span_arg(name, -1)
}

/// [`span`] with an integer payload (worker index, fleet job id, …).
pub fn span_arg(name: &'static str, arg: i64) -> SpanGuard {
    let start = CTX.with(|c| {
        let mut slot = c.borrow_mut();
        let ctx = slot.as_mut()?;
        ctx.depth = ctx.depth.saturating_add(1);
        Some(Instant::now())
    });
    SpanGuard { start, name, arg }
}

/// Live span: records itself into the thread buffer on drop.
pub struct SpanGuard {
    start: Option<Instant>,
    name: &'static str,
    arg: i64,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        let end = Instant::now();
        CTX.with(|c| {
            let mut slot = c.borrow_mut();
            let Some(ctx) = slot.as_mut() else { return };
            ctx.depth = ctx.depth.saturating_sub(1);
            ctx.buf.push(SpanRecord {
                name: self.name,
                arg: self.arg,
                tid: ctx.tid,
                depth: ctx.depth,
                start_ns: start.duration_since(ctx.inner.epoch).as_nanos() as u64,
                dur_ns: end.duration_since(start).as_nanos() as u64,
            });
            if ctx.buf.len() >= FLUSH_BATCH {
                ctx.flush();
            }
        });
    }
}

/// Bounded ring of the most recent finished traces — the daemon's
/// flight recorder behind `GET /debug/trace`.
pub struct FlightRecorder {
    ring: Mutex<VecDeque<Arc<Trace>>>,
    cap: usize,
    dropped: AtomicU64,
}

impl FlightRecorder {
    /// A recorder retaining the last `cap` traces (`cap` is clamped to
    /// at least 1).
    pub fn new(cap: usize) -> Self {
        Self { ring: Mutex::new(VecDeque::new()), cap: cap.max(1), dropped: AtomicU64::new(0) }
    }

    /// Retain `trace`, evicting the oldest once full.  Returns whether
    /// an eviction happened (the caller bumps
    /// `tag_trace_dropped_total`).
    pub fn push(&self, trace: Trace) -> bool {
        let mut ring = lock(&self.ring);
        let evicted = ring.len() >= self.cap;
        if evicted {
            ring.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(Arc::new(trace));
        evicted
    }

    /// Traces evicted over the recorder's lifetime.
    pub fn dropped_total(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        lock(&self.ring).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The retained traces, oldest first.
    pub fn snapshot(&self) -> Vec<Arc<Trace>> {
        lock(&self.ring).iter().cloned().collect()
    }

    /// The whole ring as Chrome trace-event JSON.
    pub fn export_chrome(&self) -> String {
        chrome_trace_json(&self.snapshot())
    }
}

/// Encode traces in the Chrome trace-event format (the JSON object
/// form, `{"traceEvents": [...]}`), loadable by Perfetto and
/// `chrome://tracing`.  Each trace becomes its own process (`pid` =
/// position + 1, named by a `process_name` metadata event); spans are
/// complete (`ph: "X"`) events with microsecond `ts`/`dur`.
pub fn chrome_trace_json(traces: &[Arc<Trace>]) -> String {
    let mut events = Vec::new();
    for (i, trace) in traces.iter().enumerate() {
        let pid = (i + 1) as f64;
        events.push(Json::Obj(vec![
            ("ph".to_string(), Json::Str("M".to_string())),
            ("pid".to_string(), Json::Num(pid)),
            ("tid".to_string(), Json::Num(0.0)),
            ("name".to_string(), Json::Str("process_name".to_string())),
            (
                "args".to_string(),
                Json::Obj(vec![("name".to_string(), Json::Str(trace.label.clone()))]),
            ),
        ]));
        for s in &trace.spans {
            let mut args = vec![("depth".to_string(), Json::Num(s.depth as f64))];
            if s.arg >= 0 {
                args.push(("arg".to_string(), Json::Num(s.arg as f64)));
            }
            if trace.truncated > 0 {
                // Stamped on every span so a truncated export is
                // self-describing wherever the viewer lands.
                args.push(("truncated".to_string(), Json::Num(trace.truncated as f64)));
            }
            events.push(Json::Obj(vec![
                ("ph".to_string(), Json::Str("X".to_string())),
                ("name".to_string(), Json::Str(s.name.to_string())),
                ("cat".to_string(), Json::Str("tag".to_string())),
                ("pid".to_string(), Json::Num(pid)),
                ("tid".to_string(), Json::Num(s.tid as f64)),
                ("ts".to_string(), Json::Num(s.start_ns as f64 / 1000.0)),
                ("dur".to_string(), Json::Num(s.dur_ns as f64 / 1000.0)),
                ("args".to_string(), Json::Obj(args)),
            ]));
        }
    }
    Json::Obj(vec![
        ("traceEvents".to_string(), Json::Arr(events)),
        ("displayTimeUnit".to_string(), Json::Str("ms".to_string())),
    ])
    .encode()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_records_nothing_and_spans_are_inert() {
        let tracer = Tracer::disabled();
        assert!(!tracer.is_enabled());
        let _g = tracer.install();
        {
            let _a = span("outer");
            let _b = span("inner");
        }
        drop(_g);
        assert!(tracer.finish().is_none());
        // No ambient tracer: current() is disabled too.
        assert!(!Tracer::current().is_enabled());
    }

    #[test]
    fn spans_nest_by_interval_containment() {
        let tracer = Tracer::enabled("test");
        {
            let _g = tracer.install();
            let _root = span("root");
            {
                let _a = span_arg("child_a", 3);
                let _aa = span("grandchild");
            }
            let _b = span("child_b");
        }
        let trace = tracer.finish().unwrap();
        assert_eq!(trace.label, "test");
        assert_eq!(trace.truncated, 0);
        let names: Vec<_> = trace.spans.iter().map(|s| s.name).collect();
        assert_eq!(names, vec!["root", "child_a", "grandchild", "child_b"]);
        let by_name =
            |n: &str| *trace.spans.iter().find(|s| s.name == n).unwrap();
        let root = by_name("root");
        assert_eq!(root.depth, 0);
        assert_eq!(by_name("child_a").depth, 1);
        assert_eq!(by_name("grandchild").depth, 2);
        assert_eq!(by_name("child_a").arg, 3);
        assert_eq!(root.arg, -1);
        // Every child interval sits inside the root's.
        for s in &trace.spans {
            assert!(s.start_ns >= root.start_ns, "{}", s.name);
            assert!(s.start_ns + s.dur_ns <= root.start_ns + root.dur_ns, "{}", s.name);
        }
        // Phase totals keep first-appearance order and include everything.
        let totals = trace.phase_totals();
        assert_eq!(totals.len(), 4);
        assert_eq!(totals[0].0, "root");
        assert!(trace.total_ns() >= root.dur_ns);
    }

    #[test]
    fn tracer_propagates_into_scoped_threads() {
        let tracer = Tracer::enabled("threads");
        {
            let _g = tracer.install();
            let _root = span("root");
            let ambient = Tracer::current();
            assert!(ambient.is_enabled());
            std::thread::scope(|s| {
                for w in 0..2 {
                    let t = ambient.clone();
                    s.spawn(move || {
                        let _g = t.install();
                        let _s = span_arg("worker", w);
                    });
                }
            });
        }
        let trace = tracer.finish().unwrap();
        let workers: Vec<_> = trace.spans.iter().filter(|s| s.name == "worker").collect();
        assert_eq!(workers.len(), 2);
        // Each install got its own trace-local tid, distinct from the
        // root thread's.
        let root_tid = trace.spans.iter().find(|s| s.name == "root").unwrap().tid;
        assert!(workers.iter().all(|s| s.tid != root_tid));
        assert_ne!(workers[0].tid, workers[1].tid);
    }

    #[test]
    fn install_guard_restores_the_previous_tracer() {
        let outer = Tracer::enabled("outer");
        let inner = Tracer::enabled("inner");
        let _og = outer.install();
        {
            let _ig = inner.install();
            let _s = span("inner_span");
        }
        {
            let _s = span("outer_span");
        }
        drop(_og);
        let it = inner.finish().unwrap();
        let ot = outer.finish().unwrap();
        assert_eq!(it.spans.len(), 1);
        assert_eq!(it.spans[0].name, "inner_span");
        assert_eq!(ot.spans.len(), 1);
        assert_eq!(ot.spans[0].name, "outer_span");
    }

    #[test]
    fn span_cap_truncates_and_counts() {
        let tracer = Tracer::enabled("cap");
        {
            let _g = tracer.install();
            for _ in 0..(MAX_SPANS_PER_TRACE + 10) {
                let _s = span("tick");
            }
        }
        let trace = tracer.finish().unwrap();
        assert_eq!(trace.spans.len(), MAX_SPANS_PER_TRACE);
        assert_eq!(trace.truncated, 10);
    }

    #[test]
    fn flight_recorder_ring_evicts_oldest_and_counts_drops() {
        let rec = FlightRecorder::new(2);
        assert!(rec.is_empty());
        let mk = |label: &str| {
            let t = Tracer::enabled(label);
            {
                let _g = t.install();
                let _s = span("x");
            }
            t.finish().unwrap()
        };
        assert!(!rec.push(mk("a")));
        assert!(!rec.push(mk("b")));
        assert!(rec.push(mk("c")), "third push evicts");
        assert_eq!(rec.len(), 2);
        assert_eq!(rec.dropped_total(), 1);
        let labels: Vec<_> = rec.snapshot().iter().map(|t| t.label.clone()).collect();
        assert_eq!(labels, vec!["b", "c"]);
    }

    #[test]
    fn chrome_export_is_valid_trace_event_json() {
        let rec = FlightRecorder::new(4);
        let t = Tracer::enabled("/plan");
        {
            let _g = t.install();
            let _root = span("request");
            let _child = span_arg("search.worker", 0);
        }
        rec.push(t.finish().unwrap());
        let text = rec.export_chrome();
        let root = Json::parse(&text).unwrap();
        let events = root.field("traceEvents").unwrap().as_arr().unwrap();
        // One metadata event + two spans.
        assert_eq!(events.len(), 3);
        let meta = &events[0];
        assert_eq!(meta.field("ph").unwrap().as_str().unwrap(), "M");
        let span_evs: Vec<_> = events
            .iter()
            .filter(|e| e.field("ph").map(|p| p.as_str().unwrap()) == Ok("X"))
            .collect();
        assert_eq!(span_evs.len(), 2);
        for e in span_evs {
            assert!(e.field("ts").unwrap().as_f64().unwrap() >= 0.0);
            assert!(e.field("dur").unwrap().as_f64().unwrap() >= 0.0);
            assert_eq!(e.field("pid").unwrap().as_u64().unwrap(), 1);
            e.field("tid").unwrap().as_u64().unwrap();
            e.field("name").unwrap().as_str().unwrap();
        }
        // An empty recorder still exports a loadable document.
        let empty = FlightRecorder::new(1).export_chrome();
        let root = Json::parse(&empty).unwrap();
        assert_eq!(root.field("traceEvents").unwrap().as_arr().unwrap().len(), 0);
    }
}
