//! Plan explainability — turn a served [`DeploymentPlan`] back into
//! human-readable answers about *where its iteration time goes*.
//!
//! [`explain`] recomputes the plan's simulated schedule from scratch
//! (same prepare + lowering + simulation path the search used, bypassing
//! every cache) and reports:
//!
//! * a **critical-path decomposition**: the one dependency-or-queueing
//!   chain of tasks that determines the makespan, split into named
//!   compute / communication / sync / idle components, per op group —
//!   the segments tile `[0, makespan]` exactly, so the decomposition
//!   attributes 100% of simulated iteration time and its endpoint
//!   reproduces the plan's reported time bit for bit,
//! * the **top-k contended links**: for every transfer that was
//!   stretched by link sharing, the links its bytes traversed, with
//!   per-link transfer counts, worst sharing factors and the extra
//!   seconds lost to contention (a transfer stretched on a multi-hop
//!   route is charged to each link it traverses — *exposure*, not an
//!   exact single-link blame, which the worst-share contention model
//!   does not define),
//! * **per-group SFB savings**: the SFB optimizer re-run on the plan's
//!   strategy, with saved sync bytes / extra compute / broadcast bytes
//!   per group and a bit-for-bit check against the plan's reported
//!   `time_with_sfb`,
//! * the plan's **search attribution** telemetry (memo/fragment/delta
//!   counters and any backend metrics) passed through verbatim.
//!
//! The caller must present the *same* model, topology and
//! profile-noise knob the plan was produced with (checked by
//! fingerprint); the prepare seed is taken from the plan's telemetry,
//! so a request with a different search seed still reproduces the
//! plan's cost model and grouping.

use crate::api::json::Json;
use crate::api::{fingerprint, DeploymentPlan, PlanRequest};
use crate::coordinator;
use crate::dist::Lowering;
use crate::sim::{critical_path, Schedule, TaskGraph, TaskKind};
use crate::util::error::{Error, Result};

/// How many contended links the report keeps.
pub const TOP_LINKS: usize = 5;

/// How many of the longest critical-path segments the report lists
/// individually (the totals always cover all of them).
pub const TOP_SEGMENTS: usize = 10;

fn num(x: f64) -> Json {
    Json::Num(x)
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Per-group critical-path time, seconds.
#[derive(Clone, Copy, Default)]
struct GroupShare {
    compute_s: f64,
    comm_s: f64,
    sync_s: f64,
}

/// Per-link contention exposure, aggregated over transfers.
#[derive(Clone, Copy, Default)]
struct LinkShare {
    transfers: usize,
    max_sharing: f64,
    /// Extra seconds lost to sharing: `scalable_s * (sharing - 1)`.
    extra_s: f64,
    /// Scalable seconds at an uncontended full share.
    traffic_s: f64,
}

/// Recompute `plan`'s simulated schedule under `request`'s model and
/// topology and explain where its iteration time goes.
///
/// Errors if the request's model or topology fingerprints don't match
/// the plan's (the plan was produced for different hardware or a
/// different graph), or if re-preparation doesn't reproduce the plan's
/// op grouping (a `profile_noise` mismatch).
pub fn explain(request: &PlanRequest, plan: &DeploymentPlan) -> Result<Json> {
    if fingerprint::model(&request.model) != plan.model_fingerprint {
        return Err(Error::msg(format!(
            "plan is for model `{}`, not this request's `{}` (fingerprint mismatch)",
            plan.model_name, request.model.name
        )));
    }
    if fingerprint::topology(&request.topology) != plan.topology_fingerprint {
        return Err(Error::msg(format!(
            "plan was deployed on topology `{}`, not this request's `{}` \
             (fingerprint mismatch)",
            plan.topology_name, request.topology.name
        )));
    }

    // Prepare with the *plan's* seed: the cost model and grouping
    // depend on it, and the request's search seed may legitimately
    // differ from the seed the plan was produced under.
    let mut cfg = request.search_config();
    cfg.seed = plan.telemetry.seed;
    let prep = {
        let _s = crate::obs::span("explain.prepare");
        coordinator::prepare(request.model.clone(), &request.topology, &cfg)
    };
    if prep.gg.num_groups() != plan.telemetry.num_groups {
        return Err(Error::msg(format!(
            "re-preparation produced {} op groups but the plan has {} — \
             the request's profile/grouping knobs differ from the plan's",
            prep.gg.num_groups(),
            plan.telemetry.num_groups
        )));
    }
    let strategy = plan.strategy.to_strategy();
    if strategy.slots.len() != prep.gg.num_groups() {
        return Err(Error::msg(format!(
            "plan strategy has {} slots for {} op groups",
            strategy.slots.len(),
            prep.gg.num_groups()
        )));
    }

    let low = Lowering::new(&prep.gg, &request.topology, &prep.cost, &prep.comm);
    low.set_delta(cfg.delta);
    let (tg, sched, out) = {
        let _s = crate::obs::span("explain.simulate");
        low.explain_schedule(&strategy, None)
    };
    let reproduces = out.time.to_bits() == plan.times.time.to_bits();

    let critical = critical_section(&tg, &sched, out.time, prep.gg.num_groups());
    let links = link_section(&tg, &sched, &request.topology);

    let sfb = if cfg.apply_sfb {
        let _s = crate::obs::span("explain.sfb");
        let sfb_plan = crate::sfb::optimize(
            &prep.graph,
            &prep.gg,
            &request.topology,
            &prep.cost,
            &strategy,
        );
        let with_sfb = low.evaluate_with_sfb(&strategy, Some(&sfb_plan));
        let reproduces_sfb = plan
            .times
            .time_with_sfb
            .map(|t| t.to_bits() == with_sfb.time.to_bits());
        let per_group: Vec<Json> = sfb_plan
            .per_group
            .iter()
            .enumerate()
            .filter(|(_, g)| g.gradients_covered > 0)
            .map(|(i, g)| {
                obj(vec![
                    ("group", num(i as f64)),
                    ("gradients_covered", num(g.gradients_covered as f64)),
                    ("saved_sync_bytes", num(g.saved_sync_bytes)),
                    ("extra_compute_s", num(g.extra_compute_s)),
                    ("broadcast_bytes", num(g.broadcast_bytes)),
                ])
            })
            .collect();
        obj(vec![
            ("predicted_saving_s", num(sfb_plan.predicted_saving_s)),
            ("time_with_sfb_s", num(with_sfb.time)),
            (
                "reproduces_reported_time_with_sfb",
                reproduces_sfb.map_or(Json::Null, Json::Bool),
            ),
            ("per_group", Json::Arr(per_group)),
        ])
    } else {
        Json::Null
    };

    let attribution = Json::Obj(
        plan.telemetry
            .metrics
            .iter()
            .map(|(k, v)| (k.clone(), num(*v)))
            .collect(),
    );

    Ok(obj(vec![
        ("model", Json::Str(plan.model_name.clone())),
        ("topology", Json::Str(plan.topology_name.clone())),
        ("backend", Json::Str(plan.backend.clone())),
        ("num_groups", num(plan.telemetry.num_groups as f64)),
        ("total_s", num(out.time)),
        ("reported_time_s", num(plan.times.time)),
        ("reproduces_reported_time", Json::Bool(reproduces)),
        ("critical_path", critical),
        ("contended_links", links),
        ("sfb", sfb),
        ("attribution", attribution),
    ]))
}

fn kind_label(kind: Option<TaskKind>) -> (&'static str, Option<usize>) {
    match kind {
        Some(TaskKind::Compute { group, .. }) => ("compute", Some(group)),
        Some(TaskKind::Transfer { from, .. }) => ("comm", Some(from)),
        Some(TaskKind::Sync { group }) => ("sync", Some(group)),
        Some(TaskKind::Marker) => ("idle", None),
        None => ("idle", None),
    }
}

fn critical_section(tg: &TaskGraph, sched: &Schedule, total_s: f64, num_groups: usize) -> Json {
    let segments = critical_path(tg, sched);
    let mut compute_s = 0.0;
    let mut comm_s = 0.0;
    let mut sync_s = 0.0;
    let mut idle_s = 0.0;
    let mut per_group = vec![GroupShare::default(); num_groups];
    for seg in &segments {
        let dur = seg.end - seg.start;
        let (label, group) = kind_label(seg.task.map(|t| tg.tasks[t].kind));
        match label {
            "compute" => compute_s += dur,
            "comm" => comm_s += dur,
            "sync" => sync_s += dur,
            _ => idle_s += dur,
        }
        if let Some(g) = group {
            if g < num_groups {
                match label {
                    "compute" => per_group[g].compute_s += dur,
                    "comm" => per_group[g].comm_s += dur,
                    "sync" => per_group[g].sync_s += dur,
                    _ => {}
                }
            }
        }
    }
    // The segments tile [0, makespan] with shared endpoints, so the
    // path's endpoint *is* the simulated time — no float re-summing.
    let end_s = segments.last().map_or(0.0, |s| s.end);
    let attributed = compute_s + comm_s + sync_s + idle_s;
    let attributed_fraction = if total_s > 0.0 { attributed / total_s } else { 1.0 };

    let mut longest: Vec<&crate::sim::CriticalSegment> = segments.iter().collect();
    longest.sort_by(|a, b| {
        (b.end - b.start)
            .partial_cmp(&(a.end - a.start))
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.start.partial_cmp(&b.start).unwrap_or(std::cmp::Ordering::Equal))
    });
    longest.truncate(TOP_SEGMENTS);
    let longest: Vec<Json> = longest
        .iter()
        .map(|seg| {
            let (label, group) = kind_label(seg.task.map(|t| tg.tasks[t].kind));
            obj(vec![
                ("kind", Json::Str(label.to_string())),
                ("group", group.map_or(Json::Null, |g| num(g as f64))),
                ("start_s", num(seg.start)),
                ("dur_s", num(seg.end - seg.start)),
            ])
        })
        .collect();

    let groups: Vec<Json> = per_group
        .iter()
        .enumerate()
        .filter(|(_, s)| s.compute_s > 0.0 || s.comm_s > 0.0 || s.sync_s > 0.0)
        .map(|(g, s)| {
            obj(vec![
                ("group", num(g as f64)),
                ("compute_s", num(s.compute_s)),
                ("comm_s", num(s.comm_s)),
                ("sync_s", num(s.sync_s)),
            ])
        })
        .collect();

    obj(vec![
        ("segments", num(segments.len() as f64)),
        ("end_s", num(end_s)),
        ("compute_s", num(compute_s)),
        ("comm_s", num(comm_s)),
        ("sync_s", num(sync_s)),
        ("idle_s", num(idle_s)),
        ("attributed_fraction", num(attributed_fraction)),
        ("per_group", Json::Arr(groups)),
        ("longest_segments", Json::Arr(longest)),
    ])
}

fn link_section(tg: &TaskGraph, sched: &Schedule, topo: &crate::cluster::Topology) -> Json {
    let lg = topo.link_graph();
    let mut shares = vec![LinkShare::default(); tg.num_links];
    for (t, task) in tg.tasks.iter().enumerate() {
        let Some(load) = &task.load else { continue };
        if load.scalable_s <= 0.0 {
            continue;
        }
        // eff = duration + scalable_s * sharing (worst share along the
        // path at dispatch time) — recover the sharing factor.
        let sharing = (sched.eff[t] - task.duration) / load.scalable_s;
        let extra = load.scalable_s * (sharing - 1.0).max(0.0);
        for &l in load.links.iter() {
            let s = &mut shares[l as usize];
            s.transfers += 1;
            s.max_sharing = s.max_sharing.max(sharing);
            s.extra_s += extra;
            s.traffic_s += load.scalable_s;
        }
    }
    let mut ranked: Vec<(usize, LinkShare)> = shares
        .into_iter()
        .enumerate()
        .filter(|(_, s)| s.transfers > 0)
        .collect();
    ranked.sort_by(|a, b| {
        b.1.extra_s
            .partial_cmp(&a.1.extra_s)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(b.1.traffic_s.partial_cmp(&a.1.traffic_s).unwrap_or(std::cmp::Ordering::Equal))
            .then(a.0.cmp(&b.0))
    });
    ranked.truncate(TOP_LINKS);
    Json::Arr(
        ranked
            .into_iter()
            .map(|(id, s)| {
                let link = lg.links().get(id);
                obj(vec![
                    ("link", num(id as f64)),
                    (
                        "kind",
                        link.map_or(Json::Null, |l| Json::Str(format!("{:?}", l.kind))),
                    ),
                    ("bw_gbps", link.map_or(Json::Null, |l| num(l.bw_gbps))),
                    ("transfers", num(s.transfers as f64)),
                    ("max_sharing", num(s.max_sharing)),
                    ("contention_s", num(s.extra_s)),
                    ("traffic_s", num(s.traffic_s)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Planner;

    fn multi_rack_request() -> PlanRequest {
        PlanRequest::new(crate::models::vgg19(32, 0.5), crate::cluster::presets::multi_rack())
            .budget(30, 8)
            .seed(7)
    }

    #[test]
    fn explain_reproduces_a_multi_rack_plan_bit_for_bit() {
        let planner = Planner::builder().build();
        let request = multi_rack_request();
        let plan = planner.plan(&request).expect("plan").plan;
        let report = explain(&request, &plan).expect("explain");

        assert!(report.field("reproduces_reported_time").unwrap().as_bool().unwrap());
        let total = report.field("total_s").unwrap().as_f64().unwrap();
        assert_eq!(total.to_bits(), plan.times.time.to_bits());

        let cp = report.field("critical_path").unwrap();
        // The decomposition attributes (essentially) all simulated time
        // to named components — the acceptance bar is ≥ 95%.
        let frac = cp.field("attributed_fraction").unwrap().as_f64().unwrap();
        assert!(frac >= 0.95, "attributed {frac}");
        // ... and the path's endpoint is the reported time, bit for bit.
        let end = cp.field("end_s").unwrap().as_f64().unwrap();
        assert_eq!(end.to_bits(), plan.times.time.to_bits());

        // multi_rack routes over an oversubscribed spine: transfers
        // exist, so the contended-links table is populated.
        let sums: f64 = ["compute_s", "comm_s", "sync_s", "idle_s"]
            .iter()
            .map(|k| cp.field(k).unwrap().as_f64().unwrap())
            .sum();
        assert!((sums - total).abs() <= 1e-9 * total.max(1.0));

        // The report round-trips through the crate's JSON encoder.
        let text = report.encode();
        Json::parse(&text).expect("valid JSON");
    }

    #[test]
    fn explain_checks_sfb_reproduction() {
        let planner = Planner::builder().build();
        let request = multi_rack_request();
        let plan = planner.plan(&request).expect("plan").plan;
        let report = explain(&request, &plan).expect("explain");
        let sfb = report.field("sfb").unwrap();
        if plan.times.time_with_sfb.is_some() {
            assert!(sfb.field("reproduces_reported_time_with_sfb").unwrap().as_bool().unwrap());
        }
    }

    #[test]
    fn explain_rejects_a_plan_for_a_different_model() {
        let planner = Planner::builder().build();
        let request = multi_rack_request();
        let plan = planner.plan(&request).expect("plan").plan;
        let other = PlanRequest::new(
            crate::models::vgg19(64, 0.5),
            crate::cluster::presets::multi_rack(),
        );
        let err = explain(&other, &plan).unwrap_err().to_string();
        assert!(err.contains("fingerprint mismatch"), "{err}");
    }

    #[test]
    fn explain_rejects_a_plan_for_a_different_topology() {
        let planner = Planner::builder().build();
        let request = multi_rack_request();
        let plan = planner.plan(&request).expect("plan").plan;
        let other = PlanRequest::new(
            crate::models::vgg19(32, 0.5),
            crate::cluster::presets::testbed(),
        );
        let err = explain(&other, &plan).unwrap_err().to_string();
        assert!(err.contains("fingerprint mismatch"), "{err}");
    }
}
