//! Candidate-action enumeration.
//!
//! The raw action space per op group is (2^M - 1) placements x 4 options
//! (§3.2) — too large to enumerate.  TAG restricts candidates to the
//! placements that matter in practice (this is also what bounds the
//! decoder's fixed AOT candidate axis `N_CAND`):
//!
//! * each single device group,
//! * greedy prefixes of device groups sorted by descending aggregate
//!   effective FLOPs (the "use the fastest k machines" family),
//! * the full cluster,
//!
//! each crossed with the 4 replication options.  For M <= 16 this yields
//! at most (16 + 15) * 4 = 124 candidates, under the decoder's 128.

use super::{Action, ReplOption};
use crate::cluster::Topology;

/// Max candidates (must stay <= gnn N_CAND).
pub const MAX_ACTIONS: usize = 128;

/// Placement masks considered for any op group on this topology.
pub fn placement_masks(topo: &Topology) -> Vec<u16> {
    let m = topo.num_groups();
    assert!(m <= 16, "at most 16 device groups supported");
    let mut masks: Vec<u16> = Vec::new();
    // Singles.
    for gi in 0..m {
        masks.push(1 << gi);
    }
    // Greedy prefixes by aggregate effective FLOPs.
    let mut order: Vec<usize> = (0..m).collect();
    order.sort_by(|&a, &b| {
        let fa = topo.groups[a].gpu.effective_flops() * topo.groups[a].count as f64;
        let fb = topo.groups[b].gpu.effective_flops() * topo.groups[b].count as f64;
        fb.partial_cmp(&fa).unwrap()
    });
    let mut mask = 0u16;
    for &gi in &order {
        mask |= 1 << gi;
        if !masks.contains(&mask) {
            masks.push(mask);
        }
    }
    masks
}

/// Full candidate list: placements x options.
pub fn enumerate_actions(topo: &Topology) -> Vec<Action> {
    let mut out = Vec::new();
    for mask in placement_masks(topo) {
        for option in ReplOption::ALL {
            // Duplicate / MP on a single solo device degenerate to the
            // same single-device execution as AllReduce; keep only one
            // representative to avoid wasted search width.
            let ndev = topo.mask_devices(mask).len();
            if ndev == 1 && option != ReplOption::AllReduce {
                continue;
            }
            out.push(Action { mask, option });
        }
    }
    assert!(out.len() <= MAX_ACTIONS, "{} actions exceed decoder budget", out.len());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets::{cloud, homogeneous, testbed};
    use crate::cluster::random_topology;
    use crate::util::Rng;

    #[test]
    fn testbed_actions_fit_budget() {
        let t = testbed();
        let acts = enumerate_actions(&t);
        assert!(!acts.is_empty());
        assert!(acts.len() <= MAX_ACTIONS);
        // Full-cluster mask must be present.
        let full = crate::strategy::full_mask(&t);
        assert!(acts.iter().any(|a| a.mask == full));
    }

    #[test]
    fn prefixes_start_with_fastest_group() {
        let t = testbed(); // group 0 = 4x V100, by far the fastest
        let masks = placement_masks(&t);
        // First prefix beyond the singles must contain group 0.
        let prefix = masks[t.num_groups()];
        assert!(prefix & 1 != 0);
    }

    #[test]
    fn single_device_topology() {
        let t = homogeneous(); // one group
        let acts = enumerate_actions(&t);
        // one mask x 4 options (2 devices in the group, so all options
        // remain meaningful)
        assert_eq!(acts.len(), 4);
    }

    #[test]
    fn masks_unique_and_valid() {
        for seed in 0..20 {
            let mut rng = Rng::new(seed);
            let t = random_topology(&mut rng);
            let masks = placement_masks(&t);
            let uniq: std::collections::HashSet<u16> = masks.iter().copied().collect();
            assert_eq!(uniq.len(), masks.len());
            for &m in &masks {
                assert!(m != 0);
                assert!(m < (1 << t.num_groups()));
            }
            assert!(enumerate_actions(&t).len() <= MAX_ACTIONS);
        }
    }

    #[test]
    fn cloud_actions_under_budget() {
        assert!(enumerate_actions(&cloud()).len() <= MAX_ACTIONS);
    }
}
