//! Deployment strategies (paper §4.2): per-op-group placement (a bitmask
//! over device groups — the row `P_i`) and replication option (`O_i`),
//! candidate-action enumeration for the decoder/MCTS, and the baseline
//! strategy generators used in the evaluation (DP-NCCL, DP-NCCL-P,
//! Horovod, FlexFlow-MCMC, Baechi mSCT, expert, HeteroG-like).

pub mod baselines;
pub mod candidates;

pub use candidates::enumerate_actions;

use crate::cluster::Topology;

/// The four replication options of §4.2.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ReplOption {
    /// Replicate on all devices of the placement, sync grads by AllReduce.
    AllReduce,
    /// Replicate, sync grads through a parameter server (round-robin GPU).
    Ps,
    /// Copy to all devices with *broadcast* inputs: identical gradients
    /// everywhere, no sync needed (the SFB execution vehicle).
    Duplicate,
    /// Split the group's ops across the placement devices (METIS inside).
    ModelParallel,
}

impl ReplOption {
    pub const ALL: [ReplOption; 4] = [
        ReplOption::AllReduce,
        ReplOption::Ps,
        ReplOption::Duplicate,
        ReplOption::ModelParallel,
    ];

    pub fn index(&self) -> usize {
        match self {
            ReplOption::AllReduce => 0,
            ReplOption::Ps => 1,
            ReplOption::Duplicate => 2,
            ReplOption::ModelParallel => 3,
        }
    }

    pub fn from_index(i: usize) -> Self {
        Self::ALL[i]
    }
}

/// How replicas split the global batch.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SplitMode {
    /// Evenly across replicas (classic DP).
    #[default]
    Even,
    /// Proportional to each device's effective compute rate (DP-NCCL-P).
    Proportional,
}

/// One action of the strategy creator: where to place the next op group
/// and how to replicate it.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Action {
    /// Bitmask over device groups (bit i = device group i).
    pub mask: u16,
    pub option: ReplOption,
}

/// A full (or partial) deployment strategy: one slot per op group.
/// `None` = not yet decided (partial strategies during MCTS).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Strategy {
    pub slots: Vec<Option<Action>>,
    pub split: SplitMode,
    /// Synchronization barriers before gradient sync (in-graph replication
    /// DP-NCCL style) instead of overlapped sync (Horovod/TAG style).
    pub sync_barrier: bool,
}

impl Strategy {
    pub fn empty(num_groups: usize) -> Self {
        Self { slots: vec![None; num_groups], split: SplitMode::Even, sync_barrier: false }
    }

    /// Uniform strategy: every group gets the same action.
    pub fn uniform(num_groups: usize, action: Action) -> Self {
        Self {
            slots: vec![Some(action); num_groups],
            split: SplitMode::Even,
            sync_barrier: false,
        }
    }

    pub fn is_complete(&self) -> bool {
        self.slots.iter().all(|s| s.is_some())
    }

    pub fn decided(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Resolve the action for group `i`, using the paper's footnote-2
    /// completion rule for partial strategies: undecided groups use the
    /// strategy of the most computation-expensive *decided* group (which,
    /// since groups are decided in descending compute order, is the first
    /// decided slot in `order`), or `default` if nothing is decided.
    pub fn action_for(&self, i: usize, order: &[usize], default: Action) -> Action {
        if let Some(a) = self.slots[i] {
            return a;
        }
        for &g in order {
            if let Some(a) = self.slots[g] {
                return a;
            }
        }
        default
    }

    /// The all-devices data-parallel AllReduce baseline (the reward
    /// reference of §4.2.2).
    pub fn dp_allreduce(num_groups: usize, topo: &Topology) -> Self {
        let mask = full_mask(topo);
        let mut s = Self::uniform(
            num_groups,
            Action { mask, option: ReplOption::AllReduce },
        );
        s.sync_barrier = true; // in-graph replication: sync after backward
        s
    }
}

/// Bitmask selecting every device group of the topology.
pub fn full_mask(topo: &Topology) -> u16 {
    debug_assert!(topo.num_groups() <= 16);
    ((1u32 << topo.num_groups()) - 1) as u16
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets::testbed;

    #[test]
    fn option_index_roundtrip() {
        for o in ReplOption::ALL {
            assert_eq!(ReplOption::from_index(o.index()), o);
        }
    }

    #[test]
    fn full_mask_covers_groups() {
        let t = testbed();
        let m = full_mask(&t);
        assert_eq!(m.count_ones() as usize, t.num_groups());
        assert_eq!(t.mask_devices(m).len(), t.num_devices());
    }

    #[test]
    fn partial_strategy_completion_rule() {
        let order = vec![2, 0, 1]; // group 2 is most expensive
        let mut s = Strategy::empty(3);
        let def = Action { mask: 0b1, option: ReplOption::AllReduce };
        // Nothing decided: default everywhere.
        assert_eq!(s.action_for(1, &order, def), def);
        // Decide group 2 (the most expensive): others copy it.
        let a2 = Action { mask: 0b11, option: ReplOption::Ps };
        s.slots[2] = Some(a2);
        assert_eq!(s.action_for(0, &order, def), a2);
        assert_eq!(s.action_for(2, &order, def), a2);
        // Explicit slot wins.
        let a0 = Action { mask: 0b10, option: ReplOption::Duplicate };
        s.slots[0] = Some(a0);
        assert_eq!(s.action_for(0, &order, def), a0);
        assert!(!s.is_complete());
        assert_eq!(s.decided(), 2);
    }

    #[test]
    fn dp_strategy_complete_and_barriered() {
        let t = testbed();
        let s = Strategy::dp_allreduce(10, &t);
        assert!(s.is_complete());
        assert!(s.sync_barrier);
        assert!(s.slots.iter().all(|a| a.unwrap().option == ReplOption::AllReduce));
    }
}
