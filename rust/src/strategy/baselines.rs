//! Baseline strategy generators for the paper's evaluation (§5.2).
//!
//! Each baseline emits a [`Strategy`] that is evaluated on the *same*
//! simulator as TAG, which is what makes Fig. 5 / Fig. 6 comparisons
//! apples-to-apples (see DESIGN.md substitutions):
//!
//! * **DP-NCCL** — replicate everywhere, ring AllReduce, in-graph
//!   replication barrier.
//! * **DP-NCCL-P** — same, but batch shares proportional to device speed.
//! * **Horovod** — DP with AllReduce overlapped with backward compute.
//! * **FlexFlow** — MCMC search over per-group placements; homogeneity
//!   assumption = even batch split regardless of device speed.
//! * **Baechi mSCT** — greedy earliest-finish-time single-device
//!   placement (memory-constrained scheduling, no replication).
//! * **HeteroG-like** — per-group greedy choice among {replicate-all-AR,
//!   replicate-all-PS, best single machine} using simulator feedback.
//! * **Expert** — the human single-strategy default (DP on the machine's
//!   GPUs), used as the Fig. 6 reference.

use super::{full_mask, Action, ReplOption, SplitMode, Strategy};
use crate::dist::Lowering;
use crate::util::Rng;

/// DP-NCCL: classic data parallelism, AllReduce, barrier sync.
pub fn dp_nccl(num_groups: usize, topo: &crate::cluster::Topology) -> Strategy {
    Strategy::dp_allreduce(num_groups, topo)
}

/// DP-NCCL-P: batch sizes inverse-proportional to computation capacity.
pub fn dp_nccl_p(num_groups: usize, topo: &crate::cluster::Topology) -> Strategy {
    let mut s = Strategy::dp_allreduce(num_groups, topo);
    s.split = SplitMode::Proportional;
    s
}

/// Horovod: DP with AllReduce overlapping backward computation.
pub fn horovod(num_groups: usize, topo: &crate::cluster::Topology) -> Strategy {
    let mut s = Strategy::dp_allreduce(num_groups, topo);
    s.sync_barrier = false;
    s
}

/// Expert strategy (Fig. 6 reference on the homogeneous cluster).
pub fn expert(num_groups: usize, topo: &crate::cluster::Topology) -> Strategy {
    Strategy::dp_allreduce(num_groups, topo)
}

/// FlexFlow-style MCMC search (§5.2 baseline 4).  Proposes single-group
/// action flips and accepts with the Metropolis criterion on simulated
/// iteration time.  FlexFlow assumes a homogeneous cluster, so the batch
/// split stays even and device-speed-blind.
pub fn flexflow_mcmc(low: &Lowering, actions: &[Action], iters: usize, seed: u64) -> Strategy {
    let ng = low.gg.num_groups();
    let mut rng = Rng::new(seed);
    let mut cur = Strategy::dp_allreduce(ng, low.topo);
    cur.sync_barrier = false;
    let mut cur_t = low.evaluate(&cur).time;
    let mut best = cur.clone();
    let mut best_t = cur_t;
    // Temperature ~ fraction of current time, annealed.
    for it in 0..iters {
        let temp = 0.05 * cur_t * (1.0 - it as f64 / iters as f64).max(0.05);
        let g = rng.below(ng);
        let a = *rng.choose(actions);
        let mut cand = cur.clone();
        cand.slots[g] = Some(a);
        let out = low.evaluate(&cand);
        let accept = if out.oom {
            false
        } else if out.time < cur_t {
            true
        } else {
            rng.chance((-(out.time - cur_t) / temp).exp())
        };
        if accept {
            cur = cand;
            cur_t = out.time;
            if cur_t < best_t {
                best_t = cur_t;
                best = cur.clone();
            }
        }
    }
    best
}

/// Baechi's mSCT-flavoured placement: schedule groups (topological
/// order) onto single devices by earliest estimated finish time,
/// accounting for inbound tensor transfer from producer placements.
/// No replication — Baechi is a pure device-placement system.
pub fn baechi_msct(low: &Lowering) -> Strategy {
    let topo = low.topo;
    let gg = low.gg;
    let ng = gg.num_groups();
    let devices = topo.devices();
    let nd = devices.len();

    let mut avail = vec![0.0f64; nd]; // device free time
    let mut finish = vec![0.0f64; ng]; // group finish time
    let mut placed_dev = vec![0usize; ng];
    let mut strategy = Strategy::empty(ng);
    strategy.sync_barrier = false;

    for g in 0..ng {
        let mut best_dev = 0;
        let mut best_fin = f64::INFINITY;
        for (di, d) in devices.iter().enumerate() {
            // Inputs must arrive from their producers over their routed
            // paths (bandwidth + path latency; latency is 0 on cliques).
            let mut ready = 0.0f64;
            for p in 0..g {
                let bytes = gg.edges[p][g];
                if bytes <= 0.0 {
                    continue;
                }
                let src = devices[placed_dev[p]];
                let bw = topo.bw_bytes_per_s(src, *d);
                let arrive = finish[p]
                    + low.comm.transfer_time(bytes, bw)
                    + topo.route_latency_s(src, *d);
                ready = ready.max(arrive);
            }
            let start = ready.max(avail[di]);
            let dur = low.group_time_on(g, d.group, 1.0);
            let fin = start + dur;
            if fin < best_fin {
                best_fin = fin;
                best_dev = di;
            }
        }
        placed_dev[g] = best_dev;
        finish[g] = best_fin;
        avail[best_dev] = best_fin;
        strategy.slots[g] = Some(Action {
            mask: 1 << devices[best_dev].group,
            option: ReplOption::ModelParallel,
        });
    }
    strategy
}

/// HeteroG-like greedy: the decision space HeteroG supports is
/// "replicate an op to all devices or put it on a single device"; its
/// GNN picks per-op. We emulate with simulator-greedy decisions per
/// group in descending computation-time order.
pub fn heterog_like(low: &Lowering) -> Strategy {
    let topo = low.topo;
    let ng = low.gg.num_groups();
    let full = full_mask(topo);
    let mut s = Strategy::empty(ng);
    s.sync_barrier = false;

    // Candidate set: replicate-all with AR/PS, or each single machine.
    let mut cands: Vec<Action> = vec![
        Action { mask: full, option: ReplOption::AllReduce },
        Action { mask: full, option: ReplOption::Ps },
    ];
    for m in 0..topo.num_groups() {
        cands.push(Action { mask: 1 << m, option: ReplOption::AllReduce });
    }

    for &g in &low.order {
        let mut best_a = cands[0];
        let mut best_t = f64::INFINITY;
        for &a in &cands {
            s.slots[g] = Some(a);
            let out = low.evaluate(&s);
            if !out.oom && out.time < best_t {
                best_t = out.time;
                best_a = a;
            }
        }
        s.slots[g] = Some(best_a);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets::testbed;
    use crate::graph::grouping::group_ops;
    use crate::models;
    use crate::profile::{unique_gpus, CommModel, CostModel};

    fn setup<'a>(
        m: &'a crate::graph::CompGraph,
        topo: &'a crate::cluster::Topology,
        cost: &'a CostModel,
        comm: &'a CommModel,
        gg: &'a crate::graph::grouping::GroupGraph,
    ) -> Lowering<'a> {
        let _ = (m, cost);
        Lowering::new(gg, topo, cost, comm)
    }

    #[test]
    fn all_baselines_produce_valid_strategies() {
        let topo = testbed();
        let m = models::vgg19(8, 0.25);
        let cost = CostModel::profile(&m.ops, &unique_gpus(&topo), 0.0, 1);
        let gg = group_ops(&m, &cost, 10, 7);
        let comm = CommModel::fit(3);
        let low = setup(&m, &topo, &cost, &comm, &gg);

        let strategies: Vec<(&str, Strategy)> = vec![
            ("dp", dp_nccl(gg.num_groups(), &topo)),
            ("dp-p", dp_nccl_p(gg.num_groups(), &topo)),
            ("horovod", horovod(gg.num_groups(), &topo)),
            ("flexflow", flexflow_mcmc(&low, &crate::strategy::enumerate_actions(&topo), 30, 1)),
            ("baechi", baechi_msct(&low)),
            ("heterog", heterog_like(&low)),
        ];
        for (name, s) in strategies {
            assert!(s.is_complete(), "{name} incomplete");
            let out = low.evaluate(&s);
            assert!(out.time.is_finite() && out.time > 0.0, "{name}");
        }
    }

    #[test]
    fn horovod_not_slower_than_dp() {
        let topo = testbed();
        let m = models::inception_v3(8, 0.25);
        let cost = CostModel::profile(&m.ops, &unique_gpus(&topo), 0.0, 1);
        let gg = group_ops(&m, &cost, 10, 7);
        let comm = CommModel::fit(3);
        let low = setup(&m, &topo, &cost, &comm, &gg);
        let t_dp = low.evaluate(&dp_nccl(gg.num_groups(), &topo)).time;
        let t_hv = low.evaluate(&horovod(gg.num_groups(), &topo)).time;
        assert!(t_hv <= t_dp + 1e-12);
    }

    #[test]
    fn proportional_split_helps_on_heterogeneous_cluster() {
        let topo = testbed();
        let m = models::resnet101(16, 0.25);
        let cost = CostModel::profile(&m.ops, &unique_gpus(&topo), 0.0, 1);
        let gg = group_ops(&m, &cost, 10, 7);
        let comm = CommModel::fit(3);
        let low = setup(&m, &topo, &cost, &comm, &gg);
        let t_dp = low.evaluate(&dp_nccl(gg.num_groups(), &topo)).time;
        let t_p = low.evaluate(&dp_nccl_p(gg.num_groups(), &topo)).time;
        // Load balancing to device speed should not hurt on compute-bound
        // models in a heterogeneous cluster.
        assert!(t_p <= t_dp * 1.02, "dp {t_dp} vs dp-p {t_p}");
    }

    #[test]
    fn flexflow_improves_over_its_start() {
        let topo = testbed();
        let m = models::vgg19(8, 0.25);
        let cost = CostModel::profile(&m.ops, &unique_gpus(&topo), 0.0, 1);
        let gg = group_ops(&m, &cost, 10, 7);
        let comm = CommModel::fit(3);
        let low = setup(&m, &topo, &cost, &comm, &gg);
        let start = {
            let mut s = Strategy::dp_allreduce(gg.num_groups(), &topo);
            s.sync_barrier = false;
            low.evaluate(&s).time
        };
        let found = flexflow_mcmc(&low, &crate::strategy::enumerate_actions(&topo), 60, 2);
        let t = low.evaluate(&found).time;
        assert!(t <= start + 1e-12, "MCMC must not regress: {t} vs {start}");
    }

    #[test]
    fn baechi_uses_single_devices() {
        let topo = testbed();
        let m = models::bert(4, false, 0.25);
        let cost = CostModel::profile(&m.ops, &unique_gpus(&topo), 0.0, 1);
        let gg = group_ops(&m, &cost, 10, 7);
        let comm = CommModel::fit(3);
        let low = setup(&m, &topo, &cost, &comm, &gg);
        let s = baechi_msct(&low);
        for a in s.slots.iter().flatten() {
            assert_eq!(a.mask.count_ones(), 1, "baechi places on one machine");
        }
    }
}
