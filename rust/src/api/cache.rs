//! Session-level plan cache: the `dist::memo` transposition-table idiom
//! lifted from strategy evaluations to whole deployments.
//!
//! Keys are exact `(model, topology, config)` fingerprint triples —
//! repeat traffic for the same deployment problem (the ROADMAP's serving
//! scenario, and the reuse emphasis of Placeto/TopoOpt) is answered with
//! a clone of the stored [`DeploymentPlan`] instead of a search.  Like
//! the memo table, the map is cleared wholesale at capacity: lookups are
//! exact, entries are cheap to rebuild, and eviction order is irrelevant
//! for a bounded serving window.

use std::collections::HashMap;

use super::plan::DeploymentPlan;

/// Default entry cap (a full plan is a few KB; this bounds the cache to
/// low MBs).
pub const DEFAULT_CAPACITY: usize = 1 << 10;

/// Cache key: the three structural fingerprints of a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub model: u64,
    pub topology: u64,
    pub config: u64,
}

/// Hit/miss counters exposed for serving dashboards.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
}

impl CacheStats {
    /// Hits over lookups; 0.0 before any lookup.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Fingerprint-keyed deployment-plan cache.
pub struct PlanCache {
    map: HashMap<PlanKey, DeploymentPlan>,
    capacity: usize,
    hits: u64,
    misses: u64,
}

impl Default for PlanCache {
    fn default() -> Self {
        Self::new(DEFAULT_CAPACITY)
    }
}

impl PlanCache {
    pub fn new(capacity: usize) -> Self {
        Self { map: HashMap::new(), capacity: capacity.max(1), hits: 0, misses: 0 }
    }

    /// Look up a plan, counting the hit or miss.
    pub fn get(&mut self, key: &PlanKey) -> Option<DeploymentPlan> {
        match self.map.get(key) {
            Some(plan) => {
                self.hits += 1;
                Some(plan.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Store a plan; at capacity the table is cleared wholesale (the
    /// `dist::memo` policy — exact keys, order-free eviction).
    pub fn insert(&mut self, key: PlanKey, plan: DeploymentPlan) {
        if self.map.len() >= self.capacity && !self.map.contains_key(&key) {
            self.map.clear();
        }
        self.map.insert(key, plan);
    }

    pub fn clear(&mut self) {
        self.map.clear();
        self.hits = 0;
        self.misses = 0;
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats { hits: self.hits, misses: self.misses, entries: self.map.len() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::plan::tests::sample_plan;

    fn key(n: u64) -> PlanKey {
        PlanKey { model: n, topology: n ^ 1, config: n ^ 2 }
    }

    #[test]
    fn hit_and_miss_accounting() {
        let mut c = PlanCache::new(8);
        assert!(c.get(&key(1)).is_none());
        c.insert(key(1), sample_plan());
        let hit = c.get(&key(1)).unwrap();
        assert_eq!(hit, sample_plan());
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn distinct_fingerprint_components_are_distinct_keys() {
        let mut c = PlanCache::new(8);
        let base = key(10);
        c.insert(base, sample_plan());
        assert!(c.get(&PlanKey { model: 99, ..base }).is_none());
        assert!(c.get(&PlanKey { topology: 99, ..base }).is_none());
        assert!(c.get(&PlanKey { config: 99, ..base }).is_none());
        assert!(c.get(&base).is_some());
    }

    #[test]
    fn capacity_clears_wholesale() {
        let mut c = PlanCache::new(2);
        c.insert(key(1), sample_plan());
        c.insert(key(2), sample_plan());
        assert_eq!(c.len(), 2);
        c.insert(key(3), sample_plan());
        assert_eq!(c.len(), 1, "full table cleared before the new entry");
        assert!(c.get(&key(3)).is_some());
    }

    #[test]
    fn reinserting_existing_key_does_not_clear() {
        let mut c = PlanCache::new(2);
        c.insert(key(1), sample_plan());
        c.insert(key(2), sample_plan());
        c.insert(key(2), sample_plan());
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn clear_resets_stats() {
        let mut c = PlanCache::new(4);
        c.insert(key(1), sample_plan());
        let _ = c.get(&key(1));
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.stats(), CacheStats::default());
        assert_eq!(c.stats().hit_rate(), 0.0);
    }
}
