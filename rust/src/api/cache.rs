//! Session-level plan cache: the `dist::memo` transposition-table idiom
//! lifted from strategy evaluations to whole deployments.
//!
//! Keys are exact `(model, topology, config)` fingerprint triples —
//! repeat traffic for the same deployment problem (the ROADMAP's serving
//! scenario, and the reuse emphasis of Placeto/TopoOpt) is answered with
//! a clone of the stored [`DeploymentPlan`] instead of a search.
//!
//! Eviction is **two-generation** (hot/cold), not the memo table's
//! wholesale clear: when the hot generation fills, it *becomes* the cold
//! generation and a fresh hot one starts.  A lookup that misses hot but
//! hits cold promotes the entry back into hot.  A long-running `tag
//! serve` daemon therefore never faces a fully cold cache after
//! eviction — at any instant the most recent `capacity` insertions are
//! retained exactly, and the generation before them remains servable
//! until a further `capacity` distinct plans displace it.  Entries live
//! for at most two generations without a hit.
//!
//! [`CacheStats`] counters are monotone across generation turnover:
//! rotation never resets `hits`/`misses` (only [`PlanCache::clear`]
//! does), so serving dashboards see a continuous hit-rate series.

use std::collections::HashMap;

use super::plan::DeploymentPlan;

/// Default per-generation entry cap (a full plan is a few KB; two
/// generations bound the cache to low MBs).
pub const DEFAULT_CAPACITY: usize = 1 << 10;

/// Cache key: the three structural fingerprints of a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub model: u64,
    pub topology: u64,
    pub config: u64,
}

/// Hit/miss counters plus generation occupancy, exposed for serving
/// dashboards (`tag_plan_cache_*` in `GET /metrics`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Total live entries (`hot_entries + cold_entries`).
    pub entries: usize,
    /// Entries in the current (hot) generation.
    pub hot_entries: usize,
    /// Entries surviving from the previous (cold) generation.
    pub cold_entries: usize,
    /// Per-generation entry cap (the cache holds at most about
    /// `2 * capacity` plans).
    pub capacity: usize,
    /// Cold-generation hits promoted back into hot (lifetime count).
    pub promotions: u64,
    /// Generation turnovers: hot filled and became cold (lifetime
    /// count).
    pub rotations: u64,
}

impl CacheStats {
    /// Hits over lookups; 0.0 before any lookup.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Live entries over the two-generation bound `2 * capacity`;
    /// 0.0 for a degenerate zero capacity.
    pub fn occupancy(&self) -> f64 {
        if self.capacity == 0 {
            0.0
        } else {
            self.entries as f64 / (2 * self.capacity) as f64
        }
    }
}

/// Fingerprint-keyed deployment-plan cache with two-generation
/// (hot/cold) eviction.
pub struct PlanCache {
    hot: HashMap<PlanKey, DeploymentPlan>,
    cold: HashMap<PlanKey, DeploymentPlan>,
    capacity: usize,
    hits: u64,
    misses: u64,
    promotions: u64,
    rotations: u64,
}

impl Default for PlanCache {
    fn default() -> Self {
        Self::new(DEFAULT_CAPACITY)
    }
}

impl PlanCache {
    /// `capacity` bounds each generation; the cache holds at most about
    /// `2 * capacity` plans (hot + cold).
    pub fn new(capacity: usize) -> Self {
        Self {
            hot: HashMap::new(),
            cold: HashMap::new(),
            capacity: capacity.max(1),
            hits: 0,
            misses: 0,
            promotions: 0,
            rotations: 0,
        }
    }

    /// Look up a plan, counting the hit or miss.  A cold-generation hit
    /// promotes the entry back into the hot generation.
    pub fn get(&mut self, key: &PlanKey) -> Option<DeploymentPlan> {
        if let Some(plan) = self.hot.get(key) {
            self.hits += 1;
            return Some(plan.clone());
        }
        if let Some(plan) = self.cold.remove(key) {
            self.hits += 1;
            self.promotions += 1;
            // Promotion does not rotate (that would drop the very
            // generation being read); `insert` re-establishes the bound
            // on its next rotation.
            self.hot.insert(*key, plan.clone());
            return Some(plan);
        }
        self.misses += 1;
        None
    }

    /// Store a plan.  When the hot generation is full and `key` is new
    /// to it, hot becomes cold (the previous cold generation — entries
    /// unused for two full generations — is dropped) and a fresh hot
    /// generation starts with this entry.
    pub fn insert(&mut self, key: PlanKey, plan: DeploymentPlan) {
        if self.hot.len() >= self.capacity && !self.hot.contains_key(&key) {
            self.cold = std::mem::take(&mut self.hot);
            self.rotations += 1;
        }
        self.cold.remove(&key);
        self.hot.insert(key, plan);
    }

    pub fn clear(&mut self) {
        self.hot.clear();
        self.cold.clear();
        self.hits = 0;
        self.misses = 0;
        self.promotions = 0;
        self.rotations = 0;
    }

    pub fn len(&self) -> usize {
        self.hot.len() + self.cold.len()
    }

    pub fn is_empty(&self) -> bool {
        self.hot.is_empty() && self.cold.is_empty()
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits,
            misses: self.misses,
            entries: self.len(),
            hot_entries: self.hot.len(),
            cold_entries: self.cold.len(),
            capacity: self.capacity,
            promotions: self.promotions,
            rotations: self.rotations,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::plan::tests::sample_plan;

    fn key(n: u64) -> PlanKey {
        PlanKey { model: n, topology: n ^ 1, config: n ^ 2 }
    }

    #[test]
    fn hit_and_miss_accounting() {
        let mut c = PlanCache::new(8);
        assert!(c.get(&key(1)).is_none());
        c.insert(key(1), sample_plan());
        let hit = c.get(&key(1)).unwrap();
        assert_eq!(hit, sample_plan());
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn distinct_fingerprint_components_are_distinct_keys() {
        let mut c = PlanCache::new(8);
        let base = key(10);
        c.insert(base, sample_plan());
        assert!(c.get(&PlanKey { model: 99, ..base }).is_none());
        assert!(c.get(&PlanKey { topology: 99, ..base }).is_none());
        assert!(c.get(&PlanKey { config: 99, ..base }).is_none());
        assert!(c.get(&base).is_some());
    }

    #[test]
    fn rotation_keeps_the_previous_generation_warm() {
        // Capacity 2.  Filling hot and inserting a third plan must NOT
        // leave the cache cold: the displaced generation still serves.
        let mut c = PlanCache::new(2);
        c.insert(key(1), sample_plan());
        c.insert(key(2), sample_plan());
        c.insert(key(3), sample_plan()); // rotates: cold={1,2}, hot={3}
        assert_eq!(c.len(), 3);
        assert!(c.get(&key(1)).is_some(), "previous generation still warm");
        assert!(c.get(&key(2)).is_some());
        assert!(c.get(&key(3)).is_some());
    }

    #[test]
    fn entries_unused_for_two_generations_are_evicted() {
        let mut c = PlanCache::new(2);
        c.insert(key(1), sample_plan());
        c.insert(key(2), sample_plan());
        c.insert(key(3), sample_plan()); // cold={1,2}, hot={3}
        c.insert(key(4), sample_plan()); // hot={3,4}
        c.insert(key(5), sample_plan()); // rotates: cold={3,4}, hot={5}
        assert!(c.get(&key(1)).is_none(), "two generations old: evicted");
        assert!(c.get(&key(2)).is_none());
        assert!(c.get(&key(3)).is_some());
        assert!(c.get(&key(4)).is_some());
        assert!(c.get(&key(5)).is_some());
    }

    #[test]
    fn cold_hits_promote_back_into_the_hot_generation() {
        let mut c = PlanCache::new(2);
        c.insert(key(1), sample_plan());
        c.insert(key(2), sample_plan());
        c.insert(key(3), sample_plan()); // cold={1,2}, hot={3}
        assert!(c.get(&key(1)).is_some()); // promotes 1: hot={1,3}
        c.insert(key(4), sample_plan()); // rotates: cold={1,3}, hot={4}
        c.insert(key(5), sample_plan()); // hot={4,5}
        // 1 was promoted, so it survived the rotation that evicted 2.
        assert!(c.get(&key(1)).is_some(), "promoted entry survives");
        assert!(c.get(&key(2)).is_none(), "unpromoted entry evicted");
    }

    #[test]
    fn stats_stay_monotone_across_generations() {
        let mut c = PlanCache::new(2);
        let mut last = CacheStats::default();
        for n in 0..20u64 {
            let _ = c.get(&key(n)); // miss
            c.insert(key(n), sample_plan());
            let _ = c.get(&key(n)); // hit
            let s = c.stats();
            assert!(s.hits >= last.hits && s.misses >= last.misses, "monotone");
            assert!(s.hits > last.hits || s.misses > last.misses, "advancing");
            assert!(s.entries <= 4, "bounded by two generations");
            last = s;
        }
        assert_eq!((last.hits, last.misses), (20, 20));
        assert!((last.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn reinserting_existing_key_does_not_rotate() {
        let mut c = PlanCache::new(2);
        c.insert(key(1), sample_plan());
        c.insert(key(2), sample_plan());
        c.insert(key(2), sample_plan());
        assert_eq!(c.len(), 2);
        // And a re-insert of a cold key moves it forward instead of
        // leaving a stale duplicate behind.
        c.insert(key(3), sample_plan()); // cold={1,2}, hot={3}
        c.insert(key(1), sample_plan()); // hot={1,3}, cold={2}
        assert_eq!(c.len(), 3);
        assert!(c.get(&key(1)).is_some());
    }

    #[test]
    fn clear_resets_stats() {
        let mut c = PlanCache::new(4);
        c.insert(key(1), sample_plan());
        let _ = c.get(&key(1));
        c.clear();
        assert!(c.is_empty());
        // Everything except the structural capacity resets.
        assert_eq!(c.stats(), CacheStats { capacity: 4, ..CacheStats::default() });
        assert_eq!(c.stats().hit_rate(), 0.0);
    }

    #[test]
    fn generation_stats_track_occupancy_promotions_and_rotations() {
        let mut c = PlanCache::new(2);
        assert_eq!(c.stats().occupancy(), 0.0);
        c.insert(key(1), sample_plan());
        c.insert(key(2), sample_plan());
        let s = c.stats();
        assert_eq!((s.hot_entries, s.cold_entries, s.capacity), (2, 0, 2));
        assert!((s.occupancy() - 0.5).abs() < 1e-12);
        c.insert(key(3), sample_plan()); // rotates: cold={1,2}, hot={3}
        let s = c.stats();
        assert_eq!((s.hot_entries, s.cold_entries, s.rotations), (1, 2, 1));
        assert!((s.occupancy() - 0.75).abs() < 1e-12);
        let _ = c.get(&key(1)); // cold hit promotes
        let s = c.stats();
        assert_eq!((s.hot_entries, s.cold_entries, s.promotions), (2, 1, 1));
        c.clear();
        assert_eq!((c.stats().promotions, c.stats().rotations), (0, 0));
    }
}
