//! Pluggable search backends for the [`Planner`](super::Planner).
//!
//! A backend turns a prepared deployment problem into a
//! [`SearchResult`]; the planner handles everything around it
//! (preparation, SFB, caching, plan assembly).  The three stock
//! backends mirror the paper's evaluation arms:
//!
//! * [`MctsBackend`] — pure MCTS with uniform priors (Table 7's
//!   "Pure MCTS"),
//! * [`GnnMctsBackend`] — MCTS with the compiled heterogeneous GNN as
//!   its prior ("TAG"),
//! * [`BaselineSweepBackend`] — evaluate every `strategy::baselines`
//!   generator and return the best (the Fig. 5 competitor sweep as a
//!   degenerate "search").
//!
//! Backends report deterministic named metrics (baseline rows, memo
//!   counters, GNN evaluation counts) that the planner folds into plan
//!   telemetry.

use std::sync::Arc;

use crate::cluster::Topology;
use crate::coordinator::batch::{eval_channel, serve, EvalStats};
use crate::coordinator::{Prepared, SearchConfig};
use crate::dist::Lowering;
use crate::gnn::{params, FeatureBuilder, GnnPrior, GnnService};
use crate::mcts::{Mcts, SearchResult, UniformPrior};
use crate::search::{
    run_search, run_search_with_service, BatchedGnnPrior, CancelToken, SearchProblem,
};
use crate::strategy::{baselines, Action, Strategy};
use crate::util::error::{Context, Result};

use super::fingerprint::Fnv;

/// Everything a backend may consult: the prepared (profiled + grouped)
/// problem, its lowering, and the candidate action set.
pub struct SearchContext<'a> {
    pub prep: &'a Prepared,
    pub topo: &'a Topology,
    pub low: &'a Lowering<'a>,
    pub actions: &'a [Action],
    pub cfg: &'a SearchConfig,
    /// Cooperative deadline/cancellation token, when the request set
    /// one ([`PlanRequest::deadline_ms`](super::PlanRequest)).  `None`
    /// keeps the search clock-free and byte-deterministic.
    pub cancel: Option<&'a CancelToken>,
}

/// What a backend returns: the search result plus deterministic named
/// metrics for plan telemetry.
pub struct BackendOutcome {
    pub result: SearchResult,
    pub metrics: Vec<(String, f64)>,
}

/// A deployment-strategy search engine the [`Planner`](super::Planner)
/// can drive.
pub trait SearchBackend {
    /// Short name recorded in plans ("mcts", "gnn-mcts", ...).
    fn name(&self) -> &'static str;

    /// Hash of everything that changes this backend's output (search
    /// variant, GNN parameters, ...).  Folded into the cache key so
    /// differently-configured backends never share plans.
    fn fingerprint_token(&self) -> u64;

    /// Run the search on a prepared problem.
    ///
    /// Takes `&self`: backends are stateless across calls (their
    /// configuration is fixed at construction and hashed into
    /// [`fingerprint_token`](Self::fingerprint_token)), which is what
    /// lets a `Send + Sync` backend serve concurrent searches through a
    /// shared [`Planner`](super::Planner) — the `tag serve` worker
    /// pool's contract.
    fn search(&self, ctx: &SearchContext<'_>) -> BackendOutcome;
}

fn memo_metrics(low: &Lowering<'_>) -> Vec<(String, f64)> {
    let (hits, misses) = low.memo_stats();
    let (mask_hits, mask_misses) = low.mask_memo_stats();
    let (frag_hits, frag_misses) = low.fragment_stats();
    let delta = low.delta_stats();
    vec![
        ("memo_hits".to_string(), hits as f64),
        ("memo_misses".to_string(), misses as f64),
        ("memo_hit_rate".to_string(), low.memo_hit_rate()),
        ("mask_memo_hits".to_string(), mask_hits as f64),
        ("mask_memo_misses".to_string(), mask_misses as f64),
        ("mask_memo_hit_rate".to_string(), low.mask_memo_hit_rate()),
        ("fragment_hits".to_string(), frag_hits as f64),
        ("fragment_misses".to_string(), frag_misses as f64),
        ("fragment_hit_rate".to_string(), low.fragment_hit_rate()),
        ("delta_evals".to_string(), delta.delta_evals as f64),
        ("full_evals".to_string(), delta.full_evals as f64),
        ("delta_hit_rate".to_string(), delta.delta_hit_rate()),
        ("frontier_restart_frac".to_string(), delta.frontier_restart_frac()),
    ]
}

/// Worker-count + per-worker iteration telemetry rows, emitted for
/// every MCTS-family plan so sequential and parallel plans share one
/// metric shape.
fn parallel_metrics(per_worker_iterations: &[usize]) -> Vec<(String, f64)> {
    let mut rows =
        vec![("workers".to_string(), per_worker_iterations.len() as f64)];
    for (w, &it) in per_worker_iterations.iter().enumerate() {
        rows.push((format!("worker{w}_iterations"), it as f64));
    }
    rows
}

/// The `timed_out` telemetry row, appended when the request's deadline
/// fired during (or before) the search: the plan is a valid best-so-far
/// under a spent clock, and serving layers use the marker to flag it.
fn timeout_metrics(ctx: &SearchContext<'_>, metrics: &mut Vec<(String, f64)>) {
    if ctx.cancel.map_or(false, |c| c.is_cancelled()) {
        metrics.push(("timed_out".to_string(), 1.0));
    }
}

fn problem_of<'a>(ctx: &'a SearchContext<'a>) -> SearchProblem<'a> {
    SearchProblem {
        gg: &ctx.prep.gg,
        topo: ctx.topo,
        cost: &ctx.prep.cost,
        comm: &ctx.prep.comm,
        actions: ctx.actions,
    }
}

// ---------------------------------------------------------------- MCTS

/// Pure MCTS with uniform priors.
#[derive(Clone, Debug)]
pub struct MctsBackend {
    /// Probe every root action once before PUCT (see [`Mcts`]).
    pub root_sweep: bool,
}

impl Default for MctsBackend {
    fn default() -> Self {
        Self { root_sweep: true }
    }
}

impl MctsBackend {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn root_sweep(mut self, on: bool) -> Self {
        self.root_sweep = on;
        self
    }
}

impl SearchBackend for MctsBackend {
    fn name(&self) -> &'static str {
        "mcts"
    }

    fn fingerprint_token(&self) -> u64 {
        let mut h = Fnv::new();
        h.write_str("mcts").write_bool(self.root_sweep);
        h.finish()
    }

    fn search(&self, ctx: &SearchContext<'_>) -> BackendOutcome {
        let par = ctx.cfg.parallelism;
        let priors: Vec<UniformPrior> =
            (0..par.workers.max(1)).map(|_| UniformPrior).collect();
        let out = run_search(
            &problem_of(ctx),
            ctx.low,
            priors,
            ctx.cfg.mcts_iterations,
            ctx.cfg.seed,
            par,
            self.root_sweep,
            false,
            ctx.cancel,
        );
        let mut metrics = memo_metrics(ctx.low);
        metrics.extend(parallel_metrics(&out.per_worker_iterations));
        timeout_metrics(ctx, &mut metrics);
        BackendOutcome { result: out.result, metrics }
    }
}

// ------------------------------------------------------------ GNN MCTS

/// MCTS guided by the compiled heterogeneous GNN (§4.2.1/§4.2.2).
///
/// The service is shared (`Arc`) so a trainer and a planner can use
/// the same loaded artifacts, and so one backend instance can serve a
/// whole worker pool (`tag serve --gnn` hands a single
/// `SharedPlanner`-wrapped backend to every serving thread); the
/// parameter vector is owned because it is part of the backend's
/// identity (its fingerprint token hashes every weight — plans from
/// different checkpoints never collide in the cache).
pub struct GnnMctsBackend {
    pub svc: Arc<GnnService>,
    /// Private so `params_hash` can never go stale: the checkpoint is
    /// fixed at construction (build a new backend to swap checkpoints).
    params: Vec<f32>,
    /// Hash of the parameter vector, computed once — `fingerprint_token`
    /// runs on every cache lookup and must not be O(|params|).
    params_hash: u64,
    pub root_sweep: bool,
    /// Feed simulator runtime-feedback features (Table 1 part 3).
    pub use_feedback: bool,
}

impl GnnMctsBackend {
    pub fn new(svc: Arc<GnnService>, params: Vec<f32>) -> Self {
        let mut h = Fnv::new();
        h.write_usize(params.len());
        for &p in &params {
            h.write(&p.to_bits().to_le_bytes());
        }
        let params_hash = h.finish();
        Self { svc, params, params_hash, root_sweep: true, use_feedback: true }
    }

    /// The checkpoint this backend searches with.
    pub fn params(&self) -> &[f32] {
        &self.params
    }

    /// Load the AOT artifacts and a parameter checkpoint from disk.
    pub fn from_artifacts(artifact_dir: &str, params_path: &str) -> Result<Self> {
        let svc = GnnService::load(artifact_dir).context("load GNN artifacts")?;
        let p = params::load_params(params_path).context("load GNN params")?;
        Ok(Self::new(Arc::new(svc), p))
    }

    pub fn root_sweep(mut self, on: bool) -> Self {
        self.root_sweep = on;
        self
    }
}

impl SearchBackend for GnnMctsBackend {
    fn name(&self) -> &'static str {
        "gnn-mcts"
    }

    fn fingerprint_token(&self) -> u64 {
        let mut h = Fnv::new();
        h.write_str("gnn-mcts");
        h.write_bool(self.root_sweep);
        h.write_bool(self.use_feedback);
        h.write_u64(self.params_hash);
        h.finish()
    }

    fn search(&self, ctx: &SearchContext<'_>) -> BackendOutcome {
        let par = ctx.cfg.parallelism;
        if par.workers <= 1 {
            // Sequential: the GNN is evaluated in-process, no channels.
            let mut builder = FeatureBuilder::new(&ctx.prep.gg, ctx.topo, ctx.actions);
            builder.use_feedback = self.use_feedback;
            let prior = GnnPrior::new(&self.svc, builder, self.params.clone());
            let mut mcts = Mcts::new(ctx.low, ctx.actions.to_vec(), prior, ctx.cfg.seed);
            mcts.root_sweep = self.root_sweep;
            mcts.cancel = ctx.cancel.cloned();
            let result = mcts.search(ctx.cfg.mcts_iterations);
            let gnn_evals = mcts.prior().evals;
            let mut metrics = memo_metrics(ctx.low);
            metrics.extend(parallel_metrics(&[result.iterations]));
            metrics.push(("gnn_evals".to_string(), gnn_evals as f64));
            timeout_metrics(ctx, &mut metrics);
            return BackendOutcome { result, metrics };
        }

        // Parallel: a single dynamic-batching evaluator runs on this
        // thread while the K workers submit positions through
        // EvalClients.  Centralizing evaluation keeps batching effective
        // and matches how a real PJRT executable (one device queue)
        // would be driven, even though the stub service itself is
        // Send + Sync and shared via `Arc`.
        let (client, rx) = eval_channel();
        let priors: Vec<BatchedGnnPrior<'_>> = (0..par.workers)
            .map(|_| {
                let mut builder =
                    FeatureBuilder::new(&ctx.prep.gg, ctx.topo, ctx.actions);
                builder.use_feedback = self.use_feedback;
                BatchedGnnPrior::new(client.clone(), builder)
            })
            .collect();
        drop(client); // workers hold the only senders: serve() returns on their exit
        let mut eval_stats = EvalStats::default();
        let out = run_search_with_service(
            &problem_of(ctx),
            ctx.low,
            priors,
            ctx.cfg.mcts_iterations,
            ctx.cfg.seed,
            par,
            self.root_sweep,
            false,
            ctx.cancel,
            || {
                eval_stats = serve(&self.svc, &self.params, rx);
            },
        );
        let mut metrics = memo_metrics(ctx.low);
        metrics.extend(parallel_metrics(&out.per_worker_iterations));
        let sum_of = |name: &str| -> f64 {
            out.prior_metrics
                .iter()
                .flatten()
                .filter(|(n, _)| n == name)
                .map(|(_, v)| *v)
                .sum()
        };
        metrics.push(("gnn_evals".to_string(), sum_of("gnn_evals")));
        metrics.push(("eval_cache_hits".to_string(), sum_of("eval_cache_hits")));
        metrics.push(("eval_requests".to_string(), eval_stats.requests as f64));
        metrics.push(("eval_batches".to_string(), eval_stats.batches as f64));
        timeout_metrics(ctx, &mut metrics);
        BackendOutcome { result: out.result, metrics }
    }
}

// The serving pool hands one `GnnMctsBackend` to many worker threads;
// regressing either bound (e.g. by reintroducing `Rc` in `GnnService`)
// must fail at compile time, not at the `SharedPlanner` call site.
const _: fn() = || {
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<GnnMctsBackend>();
};

// ------------------------------------------------------- baseline sweep

/// Evaluate every baseline strategy generator and return the best
/// feasible one.  Each evaluated baseline lands in plan telemetry as a
/// `(name, simulated time)` metric row, with an extra `"<name>.oom"`
/// marker when the strategy overflows device memory.
#[derive(Clone, Copy, Debug, Default)]
pub struct BaselineSweepBackend;

/// The baseline roster, in sweep (and `first_beats_dp` index) order.
pub const BASELINE_NAMES: [&str; 7] =
    ["DP-NCCL", "DP-NCCL-P", "Horovod", "Expert", "FlexFlow", "Baechi", "HeteroG"];

impl BaselineSweepBackend {
    pub fn new() -> Self {
        Self
    }

    fn generate(name: &str, ctx: &SearchContext<'_>) -> Strategy {
        let ng = ctx.low.gg.num_groups();
        match name {
            "DP-NCCL" => baselines::dp_nccl(ng, ctx.topo),
            "DP-NCCL-P" => baselines::dp_nccl_p(ng, ctx.topo),
            "Horovod" => baselines::horovod(ng, ctx.topo),
            "Expert" => baselines::expert(ng, ctx.topo),
            "FlexFlow" => baselines::flexflow_mcmc(
                ctx.low,
                ctx.actions,
                ctx.cfg.mcts_iterations,
                ctx.cfg.seed,
            ),
            "Baechi" => baselines::baechi_msct(ctx.low),
            "HeteroG" => baselines::heterog_like(ctx.low),
            other => unreachable!("unknown baseline {other}"),
        }
    }
}

impl SearchBackend for BaselineSweepBackend {
    fn name(&self) -> &'static str {
        "baseline-sweep"
    }

    fn fingerprint_token(&self) -> u64 {
        let mut h = Fnv::new();
        h.write_str("baseline-sweep");
        h.finish()
    }

    fn search(&self, ctx: &SearchContext<'_>) -> BackendOutcome {
        let dp_time = ctx.low.dp_time();
        let mut metrics = Vec::new();
        let mut best: Option<(f64, Strategy)> = None;
        let mut first_beats_dp = None;
        for (i, name) in BASELINE_NAMES.iter().enumerate() {
            let strategy = Self::generate(name, ctx);
            let out = ctx.low.evaluate(&strategy);
            metrics.push((name.to_string(), out.time));
            if out.oom {
                metrics.push((format!("{name}.oom"), 1.0));
                continue;
            }
            if best.as_ref().map_or(true, |(t, _)| out.time < *t) {
                best = Some((out.time, strategy));
            }
            if out.time < dp_time - 1e-12 && first_beats_dp.is_none() {
                first_beats_dp = Some(i + 1);
            }
        }
        if best.is_none() {
            // Every baseline OOMed; fall back to the DP reference like
            // the MCTS engine does, and say so in telemetry — the
            // resulting speedup of exactly 1.0 is a fallback, not a
            // feasible deployment.
            metrics.push(("all_oom".to_string(), 1.0));
        }
        let (best_time, best_strategy) = best.unwrap_or_else(|| {
            (dp_time, Strategy::dp_allreduce(ctx.low.gg.num_groups(), ctx.topo))
        });
        let result = SearchResult {
            best: best_strategy,
            best_time,
            best_reward: dp_time / best_time - 1.0,
            dp_time,
            iterations: BASELINE_NAMES.len(),
            first_beats_dp,
            examples: Vec::new(),
        };
        BackendOutcome { result, metrics }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets::testbed;
    use crate::coordinator::prepare;
    use crate::models;
    use crate::strategy::enumerate_actions;

    fn with_ctx<R>(f: impl FnOnce(&SearchContext<'_>) -> R) -> R {
        let topo = testbed();
        let cfg = SearchConfig {
            max_groups: 10,
            mcts_iterations: 30,
            seed: 3,
            apply_sfb: false,
            profile_noise: 0.0,
            parallelism: Default::default(),
            deadline_ms: None,
            delta: true,
        };
        let prep = prepare(models::vgg19(8, 0.25), &topo, &cfg);
        let low = Lowering::new(&prep.gg, &topo, &prep.cost, &prep.comm);
        let actions = enumerate_actions(&topo);
        f(&SearchContext {
            prep: &prep,
            topo: &topo,
            low: &low,
            actions: &actions,
            cfg: &cfg,
            cancel: None,
        })
    }

    #[test]
    fn mcts_backend_finds_feasible_strategy() {
        with_ctx(|ctx| {
            let out = MctsBackend::new().search(ctx);
            assert!(out.result.best_time.is_finite());
            assert!(out.result.best_reward >= 0.0);
            assert!(out.metrics.iter().any(|(n, _)| n == "memo_hits"));
        });
    }

    #[test]
    fn baseline_sweep_reports_every_roster_row() {
        with_ctx(|ctx| {
            let out = BaselineSweepBackend::new().search(ctx);
            for name in BASELINE_NAMES {
                let t = out
                    .metrics
                    .iter()
                    .find(|(n, _)| n == name)
                    .map(|(_, t)| *t)
                    .unwrap_or_else(|| panic!("missing metric row {name}"));
                assert!(t.is_finite() && t > 0.0, "{name}: {t}");
            }
            assert_eq!(out.result.iterations, BASELINE_NAMES.len());
            // The sweep's best can never lose to its own DP row.
            assert!(out.result.best_time <= out.result.dp_time + 1e-12);
        });
    }

    #[test]
    fn cancelled_context_returns_best_so_far_with_timed_out_row() {
        with_ctx(|ctx| {
            let token = CancelToken::new();
            token.cancel();
            let cancelled = SearchContext {
                prep: ctx.prep,
                topo: ctx.topo,
                low: ctx.low,
                actions: ctx.actions,
                cfg: ctx.cfg,
                cancel: Some(&token),
            };
            let out = MctsBackend::new().search(&cancelled);
            // No iteration ran, yet the result is a usable fallback.
            assert_eq!(out.result.iterations, 0);
            assert!(out.result.best.is_complete());
            assert!(out.metrics.iter().any(|(n, v)| n == "timed_out" && *v == 1.0));
        });
    }

    #[test]
    fn backend_tokens_distinguish_configurations() {
        let a = MctsBackend::new().fingerprint_token();
        let b = MctsBackend::new().root_sweep(false).fingerprint_token();
        assert_ne!(a, b);
        assert_ne!(a, BaselineSweepBackend::new().fingerprint_token());
    }
}
