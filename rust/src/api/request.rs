//! Typed plan requests: everything the [`Planner`](super::Planner)
//! needs to produce a deployment, in one serializable-by-fingerprint
//! value instead of loose function arguments.

use crate::cluster::Topology;
use crate::coordinator::SearchConfig;
use crate::graph::grouping::DEFAULT_GROUPS;
use crate::graph::CompGraph;
use crate::search::Parallelism;
use crate::util::error::{Error, Result};

use super::fingerprint::Fnv;
use super::json::Json;

/// Admission bounds for [`PlanRequest::decode`]d (network) requests.
/// In-process callers can build arbitrarily heavy requests; a request
/// arriving over the wire is untrusted, and a single absurd budget must
/// not be able to pin a serving worker for hours.  Out-of-bounds values
/// are rejected with `Err`, not clamped — silent clamping would serve a
/// *different* plan than the one requested.
pub mod wire_limits {
    /// Search iterations (`"iterations"`): 1..=this.
    pub const MAX_ITERATIONS: usize = 100_000;
    /// Op-group cap (`"max_groups"`): 2..=this.
    pub const MAX_GROUPS: usize = 128;
    /// Tree-parallel workers (`"workers"`): 1..=this.
    pub const MAX_WORKERS: usize = 64;
    /// Model scale (`"scale"`): within this closed range.
    pub const SCALE_RANGE: (f64, f64) = (0.01, 4.0);
    /// Profiler noise (`"profile_noise"`): within this closed range.
    pub const NOISE_RANGE: (f64, f64) = (0.0, 0.5);
    /// Search deadline (`"deadline_ms"`): 1..=this (one hour).
    pub const MAX_DEADLINE_MS: u64 = 3_600_000;
}

/// How much work the search may spend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SearchBudget {
    /// MCTS iterations (or, for non-MCTS backends, their own unit of
    /// proposals — e.g. FlexFlow-MCMC steps).
    pub iterations: usize,
    /// Maximum number of op groups the grouper may emit.
    pub max_groups: usize,
}

impl Default for SearchBudget {
    fn default() -> Self {
        Self { iterations: 150, max_groups: DEFAULT_GROUPS }
    }
}

/// One deployment-planning request: model + device topology + search
/// knobs.  This is the single argument of [`super::Planner::plan`]; two
/// requests with equal fingerprints are served the same
/// [`DeploymentPlan`](super::DeploymentPlan) from the cache.
#[derive(Clone, Debug)]
pub struct PlanRequest {
    pub model: CompGraph,
    pub topology: Topology,
    pub budget: SearchBudget,
    pub seed: u64,
    /// Run the SFB optimizer (§4.2.3) on the found strategy.
    pub apply_sfb: bool,
    /// Profiler measurement noise (0.0 = exact).
    pub profile_noise: f64,
    /// Tree-parallel search workers + virtual loss ([`crate::search`]).
    /// `workers == 1` (the default) is the sequential engine.
    pub parallelism: Parallelism,
    /// Wall-clock budget for the whole plan call (validation + prepare +
    /// search), milliseconds.  On expiry the search stops and returns
    /// its best-so-far (flagged `timed_out` in plan telemetry) instead
    /// of running to the iteration budget.  `None` (the default) never
    /// consults the clock — the deterministic path.
    pub deadline_ms: Option<u64>,
    /// Incremental (delta) evaluation — fragment-cached lowering plus
    /// frontier-restart simulation ([`crate::dist::fragments`]).  Purely
    /// a performance knob: evaluation outcomes, and therefore plans, are
    /// bit-identical with it on or off, so it does **not** enter
    /// [`config_fingerprint`](Self::config_fingerprint).  Default on;
    /// the CLI's `--no-delta` flag clears it.
    pub delta: bool,
    /// Record an [`crate::obs`] span trace for this request when the
    /// daemon serves it (flight recorder, `GET /debug/trace`).  Purely
    /// observational: spans never touch plan bytes, fingerprints or
    /// RNG streams, so — by the same reasoning as `delta` — this knob
    /// does **not** enter [`config_fingerprint`](Self::config_fingerprint).
    /// Default on; the wire form's `"trace": false` (or the CLI's
    /// `--no-trace`) opts out.
    pub trace: bool,
}

impl PlanRequest {
    /// A request with the default budget, seed 1, SFB on, no noise, one
    /// search worker.
    pub fn new(model: CompGraph, topology: Topology) -> Self {
        Self {
            model,
            topology,
            budget: SearchBudget::default(),
            seed: 1,
            apply_sfb: true,
            profile_noise: 0.0,
            parallelism: Parallelism::default(),
            deadline_ms: None,
            delta: true,
            trace: true,
        }
    }

    pub fn budget(mut self, iterations: usize, max_groups: usize) -> Self {
        self.budget = SearchBudget { iterations, max_groups };
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn sfb(mut self, apply: bool) -> Self {
        self.apply_sfb = apply;
        self
    }

    pub fn profile_noise(mut self, noise: f64) -> Self {
        self.profile_noise = noise;
        self
    }

    /// Run the search with `workers` tree-parallel MCTS workers
    /// (default virtual loss).
    pub fn workers(mut self, workers: usize) -> Self {
        self.parallelism.workers = workers.max(1);
        self
    }

    /// Full parallelism control (worker count + virtual loss).
    pub fn parallelism(mut self, parallelism: Parallelism) -> Self {
        self.parallelism = parallelism;
        self
    }

    /// Bound the plan call by a wall-clock deadline (milliseconds).
    pub fn deadline_ms(mut self, ms: u64) -> Self {
        self.deadline_ms = Some(ms);
        self
    }

    /// Toggle incremental (delta) evaluation (default on).  Off forces
    /// every evaluation down the full lower-and-simulate path; outcomes
    /// are bit-identical either way.
    pub fn delta(mut self, on: bool) -> Self {
        self.delta = on;
        self
    }

    /// Toggle per-request span tracing in the serving daemon (default
    /// on).  Observational only — plans are byte-identical either way.
    pub fn trace(mut self, on: bool) -> Self {
        self.trace = on;
        self
    }

    /// The coordinator-level configuration this request lowers to.
    pub fn search_config(&self) -> SearchConfig {
        SearchConfig {
            max_groups: self.budget.max_groups,
            mcts_iterations: self.budget.iterations,
            seed: self.seed,
            apply_sfb: self.apply_sfb,
            profile_noise: self.profile_noise,
            parallelism: self.parallelism,
            deadline_ms: self.deadline_ms,
            delta: self.delta,
        }
    }

    /// Fingerprint of the search knobs, folded with the backend token
    /// into the cache key's config component.
    ///
    /// The default (sequential) parallelism hashes *nothing*, so
    /// `workers == 1` requests keep the pre-parallelism fingerprints and
    /// their plans stay byte-identical to the sequential engine's.  Any
    /// non-default parallelism is folded in: a `workers > 1` search
    /// explores an OS-schedule-dependent tree, and its cached plan must
    /// never be served for a deterministic sequential request (or for a
    /// different worker count).
    ///
    /// A deadline partitions the cache the same way: a deadline-bounded
    /// search may stop early with a different (best-so-far) plan, so it
    /// must never alias the unbounded request.  `None` hashes nothing —
    /// deadline-free requests keep their pre-deadline fingerprints.
    ///
    /// `delta` is deliberately *not* hashed: incremental evaluation is
    /// bit-identical to the full path (property-pinned in
    /// `tests/properties.rs`), so a delta-off request may soundly be
    /// served the cached plan of a delta-on one — the same reasoning
    /// that keeps `workers == 1` out of the fingerprint.
    ///
    /// `trace` is likewise unhashed: span tracing is observational only
    /// (timestamps never enter plan bytes), so traced and untraced
    /// requests share one cache identity.
    pub fn config_fingerprint(&self, backend_token: u64) -> u64 {
        let mut h = Fnv::new();
        h.write_usize(self.budget.iterations);
        h.write_usize(self.budget.max_groups);
        h.write_u64(self.seed);
        h.write_bool(self.apply_sfb);
        h.write_f64(self.profile_noise);
        h.write_u64(backend_token);
        if self.parallelism != Parallelism::default() {
            h.write_usize(self.parallelism.workers);
            h.write_f64(self.parallelism.virtual_loss);
        }
        if let Some(d) = self.deadline_ms {
            h.write_u64(d);
        }
        h.finish()
    }

    /// Fingerprint of the knobs that shape [`prepare`]d state (profiled
    /// cost model + grouping); used to decide whether the planner's
    /// memoized `Prepared` can be reused for this request.
    ///
    /// [`prepare`]: crate::coordinator::prepare
    pub fn prepare_fingerprint(&self) -> u64 {
        let mut h = Fnv::new();
        h.write_usize(self.budget.max_groups);
        h.write_u64(self.seed);
        h.write_f64(self.profile_noise);
        h.finish()
    }

    /// Decode a *wire* request — the `POST /plan` body of `tag serve` —
    /// into a fully resolved `PlanRequest`.
    ///
    /// The wire form names the model and topology instead of shipping
    /// their graphs (the daemon owns the model zoo and the topology
    /// vocabulary; two tenants asking for `"VGG19"` must resolve to the
    /// same fingerprints, which is what makes coalescing and caching
    /// across tenants sound):
    ///
    /// ```json
    /// {"model":"VGG19","scale":0.25,"topology":"testbed",
    ///  "iterations":150,"max_groups":24,"seed":1,"sfb":true,
    ///  "profile_noise":0.0,"workers":1,"virtual_loss":1.0}
    /// ```
    ///
    /// Only `"model"` is required; every other key has the CLI's
    /// default.  `"seed"` may be a JSON number or a decimal string
    /// (full `u64` range — numbers stop at 2^53).  Unknown keys, wrong
    /// types, out-of-[`wire_limits`] values, unknown models and unknown
    /// topology specs are all `Err` — never a panic, never a silently
    /// adjusted request.
    pub fn decode(text: &str) -> Result<Self> {
        let root = Json::parse(text)?;
        let members = match &root {
            Json::Obj(members) => members,
            _ => return Err(Error::msg("request must be a JSON object")),
        };
        const KNOWN: [&str; 13] = [
            "model",
            "scale",
            "topology",
            "iterations",
            "max_groups",
            "seed",
            "sfb",
            "profile_noise",
            "workers",
            "virtual_loss",
            "deadline_ms",
            "delta",
            "trace",
        ];
        for (key, _) in members {
            if !KNOWN.contains(&key.as_str()) {
                return Err(Error::msg(format!("unknown request field `{key}`")));
            }
        }

        let scale = match root.get("scale") {
            Some(v) => v.as_f64()?,
            None => 0.25,
        };
        let (lo, hi) = wire_limits::SCALE_RANGE;
        if !(lo..=hi).contains(&scale) {
            return Err(Error::msg(format!("scale {scale} outside [{lo}, {hi}]")));
        }
        let model_name = root.field("model")?.as_str()?;
        let model = crate::models::by_name(model_name, scale)
            .ok_or_else(|| Error::msg(format!("unknown model `{model_name}`")))?;

        let spec = match root.get("topology") {
            Some(v) => v.as_str()?,
            None => "testbed",
        };
        let topology = crate::cluster::topology_by_spec(spec)
            .ok_or_else(|| Error::msg(format!("unknown topology spec `{spec}`")))?;

        let bounded = |key: &str, default: usize, min: usize, max: usize| -> Result<usize> {
            let v = match root.get(key) {
                Some(v) => v.as_usize()?,
                None => default,
            };
            if v < min || v > max {
                return Err(Error::msg(format!("{key} {v} outside [{min}, {max}]")));
            }
            Ok(v)
        };
        let iterations = bounded("iterations", 150, 1, wire_limits::MAX_ITERATIONS)?;
        let max_groups = bounded("max_groups", DEFAULT_GROUPS, 2, wire_limits::MAX_GROUPS)?;
        let workers = bounded("workers", 1, 1, wire_limits::MAX_WORKERS)?;

        let seed = match root.get("seed") {
            None => 1,
            Some(Json::Str(s)) => s
                .parse::<u64>()
                .map_err(|e| Error::msg(format!("bad seed `{s}`: {e}")))?,
            Some(v) => v.as_u64()?,
        };
        let apply_sfb = match root.get("sfb") {
            Some(v) => v.as_bool()?,
            None => true,
        };
        let profile_noise = match root.get("profile_noise") {
            Some(v) => v.as_f64()?,
            None => 0.0,
        };
        let (nlo, nhi) = wire_limits::NOISE_RANGE;
        if !(nlo..=nhi).contains(&profile_noise) {
            return Err(Error::msg(format!(
                "profile_noise {profile_noise} outside [{nlo}, {nhi}]"
            )));
        }
        let virtual_loss = match root.get("virtual_loss") {
            Some(v) => v.as_f64()?,
            None => 1.0,
        };
        if !(virtual_loss.is_finite() && virtual_loss > 0.0 && virtual_loss <= 64.0) {
            return Err(Error::msg(format!("virtual_loss {virtual_loss} outside (0, 64]")));
        }
        let deadline_ms = match root.get("deadline_ms") {
            None => None,
            Some(v) => {
                let d = v.as_u64()?;
                if d < 1 || d > wire_limits::MAX_DEADLINE_MS {
                    return Err(Error::msg(format!(
                        "deadline_ms {d} outside [1, {}]",
                        wire_limits::MAX_DEADLINE_MS
                    )));
                }
                Some(d)
            }
        };
        let delta = match root.get("delta") {
            Some(v) => v.as_bool()?,
            None => true,
        };
        let trace = match root.get("trace") {
            Some(v) => v.as_bool()?,
            None => true,
        };

        Ok(Self {
            model,
            topology,
            budget: SearchBudget { iterations, max_groups },
            seed,
            apply_sfb,
            profile_noise,
            parallelism: Parallelism { workers, virtual_loss },
            deadline_ms,
            delta,
            trace,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets::{sfb_pair, testbed};
    use crate::models;

    fn req() -> PlanRequest {
        PlanRequest::new(models::vgg19(8, 0.25), sfb_pair())
    }

    #[test]
    fn builder_chain_sets_fields() {
        let r = req().budget(40, 10).seed(9).sfb(false).profile_noise(0.01);
        assert_eq!(r.budget.iterations, 40);
        assert_eq!(r.budget.max_groups, 10);
        let cfg = r.search_config();
        assert_eq!(cfg.mcts_iterations, 40);
        assert_eq!(cfg.max_groups, 10);
        assert_eq!(cfg.seed, 9);
        assert!(!cfg.apply_sfb);
        assert_eq!(cfg.profile_noise, 0.01);
    }

    #[test]
    fn config_fingerprint_tracks_knobs_and_backend() {
        let base = req().config_fingerprint(1);
        assert_eq!(base, req().config_fingerprint(1));
        assert_ne!(base, req().seed(2).config_fingerprint(1));
        assert_ne!(base, req().budget(151, DEFAULT_GROUPS).config_fingerprint(1));
        assert_ne!(base, req().sfb(false).config_fingerprint(1));
        assert_ne!(base, req().config_fingerprint(2), "backend token matters");
    }

    #[test]
    fn parallelism_fingerprints_back_compatibly() {
        // workers == 1 (the default) must not perturb the fingerprint:
        // sequential plans keep their pre-parallelism cache identity.
        let base = req().config_fingerprint(1);
        assert_eq!(base, req().workers(1).config_fingerprint(1));
        // Any parallel configuration partitions the cache.
        assert_ne!(base, req().workers(4).config_fingerprint(1));
        assert_ne!(
            req().workers(2).config_fingerprint(1),
            req().workers(4).config_fingerprint(1)
        );
        assert_ne!(
            req().workers(4).config_fingerprint(1),
            req()
                .parallelism(Parallelism { workers: 4, virtual_loss: 2.0 })
                .config_fingerprint(1)
        );
        // And the knob reaches the engine config.
        assert_eq!(req().workers(4).search_config().parallelism.workers, 4);
        assert_eq!(req().workers(0).search_config().parallelism.workers, 1);
    }

    #[test]
    fn wire_decode_resolves_names_and_matches_builder_fingerprints() {
        let wire = PlanRequest::decode(
            r#"{"model":"VGG19","scale":0.25,"topology":"sfb","iterations":40,
                "max_groups":10,"seed":9,"sfb":false,"profile_noise":0.0}"#,
        )
        .unwrap();
        let built = PlanRequest::new(models::by_name("VGG19", 0.25).unwrap(), sfb_pair())
            .budget(40, 10)
            .seed(9)
            .sfb(false);
        // Same resolution ⇒ same fingerprints ⇒ same cache identity.
        assert_eq!(wire.config_fingerprint(1), built.config_fingerprint(1));
        assert_eq!(wire.prepare_fingerprint(), built.prepare_fingerprint());
        assert_eq!(
            crate::api::fingerprint::model(&wire.model),
            crate::api::fingerprint::model(&built.model)
        );
        assert_eq!(
            crate::api::fingerprint::topology(&wire.topology),
            crate::api::fingerprint::topology(&built.topology)
        );
    }

    #[test]
    fn wire_decode_defaults_match_the_builder_defaults() {
        let wire = PlanRequest::decode(r#"{"model":"VGG19"}"#).unwrap();
        let built = PlanRequest::new(models::by_name("VGG19", 0.25).unwrap(), testbed());
        assert_eq!(wire.config_fingerprint(7), built.config_fingerprint(7));
        assert_eq!(wire.budget, SearchBudget::default());
        assert_eq!(wire.seed, 1);
        assert!(wire.apply_sfb);
        assert_eq!(wire.parallelism, Parallelism::default());
        // Seeded generator specs and string seeds resolve too.
        let r = PlanRequest::decode(
            r#"{"model":"VGG19","topology":"hier:7","seed":"18446744073709551615"}"#,
        )
        .unwrap();
        assert_eq!(r.seed, u64::MAX);
        assert!(r.topology.is_routed());
    }

    #[test]
    fn wire_decode_rejects_malformed_and_out_of_bounds_requests() {
        for bad in [
            "",                                                  // empty
            "[]",                                                // not an object
            r#"{"scale":0.25}"#,                                 // model missing
            r#"{"model":"NoSuchNet"}"#,                          // unknown model
            r#"{"model":"VGG19","topology":"moon-base"}"#,       // unknown topology
            r#"{"model":"VGG19","topology":"random:zzz"}"#,      // malformed seed
            r#"{"model":"VGG19","turbo":true}"#,                 // unknown field
            r#"{"model":42.0}"#,                                 // wrong type
            r#"{"model":"VGG19","iterations":0}"#,               // below bounds
            r#"{"model":"VGG19","iterations":100001}"#,          // above bounds
            r#"{"model":"VGG19","max_groups":1}"#,               // below bounds
            r#"{"model":"VGG19","workers":65}"#,                 // above bounds
            r#"{"model":"VGG19","scale":5.0}"#,                  // above bounds
            r#"{"model":"VGG19","profile_noise":0.9}"#,          // above bounds
            r#"{"model":"VGG19","virtual_loss":0.0}"#,           // non-positive
            r#"{"model":"VGG19","deadline_ms":0}"#,              // below bounds
            r#"{"model":"VGG19","deadline_ms":3600001}"#,        // above bounds
            r#"{"model":"VGG19","seed":-1.0}"#,                  // negative seed
            r#"{"model":"VGG19","model":"VGG19"}"#,              // duplicate key
        ] {
            assert!(PlanRequest::decode(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn deadline_partitions_the_cache_but_not_prepared_state() {
        // No deadline hashes nothing: fingerprints stay back-compatible.
        let base = req().config_fingerprint(1);
        assert_ne!(base, req().deadline_ms(500).config_fingerprint(1));
        assert_ne!(
            req().deadline_ms(500).config_fingerprint(1),
            req().deadline_ms(501).config_fingerprint(1)
        );
        // Profiling/grouping don't consult the clock: prepared state is
        // shared between bounded and unbounded requests.
        assert_eq!(req().prepare_fingerprint(), req().deadline_ms(500).prepare_fingerprint());
        // The knob reaches the engine config and decodes off the wire.
        assert_eq!(req().deadline_ms(500).search_config().deadline_ms, Some(500));
        let wire =
            PlanRequest::decode(r#"{"model":"VGG19","deadline_ms":5000}"#).unwrap();
        assert_eq!(wire.deadline_ms, Some(5000));
    }

    #[test]
    fn delta_knob_decodes_but_never_partitions_the_cache() {
        // Bit-identical outcomes ⇒ delta on/off share one cache identity.
        let base = req().config_fingerprint(1);
        assert_eq!(base, req().delta(false).config_fingerprint(1));
        assert_eq!(req().prepare_fingerprint(), req().delta(false).prepare_fingerprint());
        // The knob reaches the engine config and decodes off the wire.
        assert!(req().search_config().delta);
        assert!(!req().delta(false).search_config().delta);
        let wire = PlanRequest::decode(r#"{"model":"VGG19","delta":false}"#).unwrap();
        assert!(!wire.delta);
        let default = PlanRequest::decode(r#"{"model":"VGG19"}"#).unwrap();
        assert!(default.delta, "absent wire key keeps the default (on)");
    }

    #[test]
    fn trace_knob_decodes_but_never_partitions_the_cache() {
        // Spans never touch plan bytes ⇒ traced and untraced requests
        // share one cache identity (same reasoning as `delta`).
        let base = req().config_fingerprint(1);
        assert_eq!(base, req().trace(false).config_fingerprint(1));
        assert_eq!(req().prepare_fingerprint(), req().trace(false).prepare_fingerprint());
        let wire = PlanRequest::decode(r#"{"model":"VGG19","trace":false}"#).unwrap();
        assert!(!wire.trace);
        let default = PlanRequest::decode(r#"{"model":"VGG19"}"#).unwrap();
        assert!(default.trace, "absent wire key keeps the default (on)");
    }

    #[test]
    fn prepare_fingerprint_ignores_search_only_knobs() {
        let base = req().prepare_fingerprint();
        // Iterations and SFB don't affect profiling/grouping.
        assert_eq!(base, req().budget(999, DEFAULT_GROUPS).prepare_fingerprint());
        assert_eq!(base, req().sfb(false).prepare_fingerprint());
        // max_groups and noise do.
        assert_ne!(base, req().budget(150, 10).prepare_fingerprint());
        assert_ne!(base, req().profile_noise(0.05).prepare_fingerprint());
    }
}
