//! Typed plan requests: everything the [`Planner`](super::Planner)
//! needs to produce a deployment, in one serializable-by-fingerprint
//! value instead of loose function arguments.

use crate::cluster::Topology;
use crate::coordinator::SearchConfig;
use crate::graph::grouping::DEFAULT_GROUPS;
use crate::graph::CompGraph;
use crate::search::Parallelism;

use super::fingerprint::Fnv;

/// How much work the search may spend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SearchBudget {
    /// MCTS iterations (or, for non-MCTS backends, their own unit of
    /// proposals — e.g. FlexFlow-MCMC steps).
    pub iterations: usize,
    /// Maximum number of op groups the grouper may emit.
    pub max_groups: usize,
}

impl Default for SearchBudget {
    fn default() -> Self {
        Self { iterations: 150, max_groups: DEFAULT_GROUPS }
    }
}

/// One deployment-planning request: model + device topology + search
/// knobs.  This is the single argument of [`super::Planner::plan`]; two
/// requests with equal fingerprints are served the same
/// [`DeploymentPlan`](super::DeploymentPlan) from the cache.
#[derive(Clone, Debug)]
pub struct PlanRequest {
    pub model: CompGraph,
    pub topology: Topology,
    pub budget: SearchBudget,
    pub seed: u64,
    /// Run the SFB optimizer (§4.2.3) on the found strategy.
    pub apply_sfb: bool,
    /// Profiler measurement noise (0.0 = exact).
    pub profile_noise: f64,
    /// Tree-parallel search workers + virtual loss ([`crate::search`]).
    /// `workers == 1` (the default) is the sequential engine.
    pub parallelism: Parallelism,
}

impl PlanRequest {
    /// A request with the default budget, seed 1, SFB on, no noise, one
    /// search worker.
    pub fn new(model: CompGraph, topology: Topology) -> Self {
        Self {
            model,
            topology,
            budget: SearchBudget::default(),
            seed: 1,
            apply_sfb: true,
            profile_noise: 0.0,
            parallelism: Parallelism::default(),
        }
    }

    pub fn budget(mut self, iterations: usize, max_groups: usize) -> Self {
        self.budget = SearchBudget { iterations, max_groups };
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn sfb(mut self, apply: bool) -> Self {
        self.apply_sfb = apply;
        self
    }

    pub fn profile_noise(mut self, noise: f64) -> Self {
        self.profile_noise = noise;
        self
    }

    /// Run the search with `workers` tree-parallel MCTS workers
    /// (default virtual loss).
    pub fn workers(mut self, workers: usize) -> Self {
        self.parallelism.workers = workers.max(1);
        self
    }

    /// Full parallelism control (worker count + virtual loss).
    pub fn parallelism(mut self, parallelism: Parallelism) -> Self {
        self.parallelism = parallelism;
        self
    }

    /// The coordinator-level configuration this request lowers to.
    pub fn search_config(&self) -> SearchConfig {
        SearchConfig {
            max_groups: self.budget.max_groups,
            mcts_iterations: self.budget.iterations,
            seed: self.seed,
            apply_sfb: self.apply_sfb,
            profile_noise: self.profile_noise,
            parallelism: self.parallelism,
        }
    }

    /// Fingerprint of the search knobs, folded with the backend token
    /// into the cache key's config component.
    ///
    /// The default (sequential) parallelism hashes *nothing*, so
    /// `workers == 1` requests keep the pre-parallelism fingerprints and
    /// their plans stay byte-identical to the sequential engine's.  Any
    /// non-default parallelism is folded in: a `workers > 1` search
    /// explores an OS-schedule-dependent tree, and its cached plan must
    /// never be served for a deterministic sequential request (or for a
    /// different worker count).
    pub fn config_fingerprint(&self, backend_token: u64) -> u64 {
        let mut h = Fnv::new();
        h.write_usize(self.budget.iterations);
        h.write_usize(self.budget.max_groups);
        h.write_u64(self.seed);
        h.write_bool(self.apply_sfb);
        h.write_f64(self.profile_noise);
        h.write_u64(backend_token);
        if self.parallelism != Parallelism::default() {
            h.write_usize(self.parallelism.workers);
            h.write_f64(self.parallelism.virtual_loss);
        }
        h.finish()
    }

    /// Fingerprint of the knobs that shape [`prepare`]d state (profiled
    /// cost model + grouping); used to decide whether the planner's
    /// memoized `Prepared` can be reused for this request.
    ///
    /// [`prepare`]: crate::coordinator::prepare
    pub fn prepare_fingerprint(&self) -> u64 {
        let mut h = Fnv::new();
        h.write_usize(self.budget.max_groups);
        h.write_u64(self.seed);
        h.write_f64(self.profile_noise);
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets::sfb_pair;
    use crate::models;

    fn req() -> PlanRequest {
        PlanRequest::new(models::vgg19(8, 0.25), sfb_pair())
    }

    #[test]
    fn builder_chain_sets_fields() {
        let r = req().budget(40, 10).seed(9).sfb(false).profile_noise(0.01);
        assert_eq!(r.budget.iterations, 40);
        assert_eq!(r.budget.max_groups, 10);
        let cfg = r.search_config();
        assert_eq!(cfg.mcts_iterations, 40);
        assert_eq!(cfg.max_groups, 10);
        assert_eq!(cfg.seed, 9);
        assert!(!cfg.apply_sfb);
        assert_eq!(cfg.profile_noise, 0.01);
    }

    #[test]
    fn config_fingerprint_tracks_knobs_and_backend() {
        let base = req().config_fingerprint(1);
        assert_eq!(base, req().config_fingerprint(1));
        assert_ne!(base, req().seed(2).config_fingerprint(1));
        assert_ne!(base, req().budget(151, DEFAULT_GROUPS).config_fingerprint(1));
        assert_ne!(base, req().sfb(false).config_fingerprint(1));
        assert_ne!(base, req().config_fingerprint(2), "backend token matters");
    }

    #[test]
    fn parallelism_fingerprints_back_compatibly() {
        // workers == 1 (the default) must not perturb the fingerprint:
        // sequential plans keep their pre-parallelism cache identity.
        let base = req().config_fingerprint(1);
        assert_eq!(base, req().workers(1).config_fingerprint(1));
        // Any parallel configuration partitions the cache.
        assert_ne!(base, req().workers(4).config_fingerprint(1));
        assert_ne!(
            req().workers(2).config_fingerprint(1),
            req().workers(4).config_fingerprint(1)
        );
        assert_ne!(
            req().workers(4).config_fingerprint(1),
            req()
                .parallelism(Parallelism { workers: 4, virtual_loss: 2.0 })
                .config_fingerprint(1)
        );
        // And the knob reaches the engine config.
        assert_eq!(req().workers(4).search_config().parallelism.workers, 4);
        assert_eq!(req().workers(0).search_config().parallelism.workers, 1);
    }

    #[test]
    fn prepare_fingerprint_ignores_search_only_knobs() {
        let base = req().prepare_fingerprint();
        // Iterations and SFB don't affect profiling/grouping.
        assert_eq!(base, req().budget(999, DEFAULT_GROUPS).prepare_fingerprint());
        assert_eq!(base, req().sfb(false).prepare_fingerprint());
        // max_groups and noise do.
        assert_ne!(base, req().budget(150, 10).prepare_fingerprint());
        assert_ne!(base, req().profile_noise(0.05).prepare_fingerprint());
    }
}
