//! Structural fingerprints for plan-cache keys.
//!
//! A [`PlanCache`](super::PlanCache) entry must be reusable exactly when
//! the *deployment problem* is identical, so keys hash structure, not
//! identity: a model fingerprint covers every op's costs and wiring, a
//! topology fingerprint covers device groups and the bandwidth matrix
//! (but **not** the topology's display name — a renamed identical
//! cluster serves the same plans), and a config fingerprint covers the
//! search knobs plus the backend's own token (so GNN-guided plans with
//! different parameters never collide).
//!
//! The hash is FNV-1a/64 — the same exact-key philosophy as
//! `dist::memo`: no probabilistic tricks beyond the hash width, `f64`s
//! hashed by bit pattern, strings length-prefixed so concatenations
//! can't alias.

use crate::cluster::Topology;
use crate::graph::ir::{CompGraph, OpKind, Splittability};

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Incremental FNV-1a/64 hasher.
#[derive(Clone, Copy, Debug)]
pub struct Fnv(u64);

impl Default for Fnv {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv {
    pub fn new() -> Self {
        Fnv(FNV_OFFSET)
    }

    pub fn write(&mut self, bytes: &[u8]) -> &mut Self {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
        self
    }

    pub fn write_u64(&mut self, x: u64) -> &mut Self {
        self.write(&x.to_le_bytes())
    }

    pub fn write_usize(&mut self, x: usize) -> &mut Self {
        self.write_u64(x as u64)
    }

    /// Hash the bit pattern (distinguishes -0.0/0.0 and preserves NaN
    /// payloads; fingerprint inputs are deterministic values, not math).
    pub fn write_f64(&mut self, x: f64) -> &mut Self {
        self.write_u64(x.to_bits())
    }

    pub fn write_bool(&mut self, b: bool) -> &mut Self {
        self.write(&[b as u8])
    }

    /// Length-prefixed so `"ab" + "c"` never aliases `"a" + "bc"`.
    pub fn write_str(&mut self, s: &str) -> &mut Self {
        self.write_usize(s.len());
        self.write(s.as_bytes())
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// Fingerprint of a computation graph: name, batch size and the full op
/// inventory (type, kind, costs, splittability, wiring).
pub fn model(graph: &CompGraph) -> u64 {
    let mut h = Fnv::new();
    h.write_str(&graph.name);
    h.write_usize(graph.batch_size);
    h.write_usize(graph.len());
    for op in &graph.ops {
        h.write_str(op.op_type);
        match op.kind {
            OpKind::Placeholder => h.write(&[0]),
            OpKind::Variable => h.write(&[1]),
            OpKind::Compute => h.write(&[2]),
            OpKind::Grad { wrt } => h.write(&[3]).write_usize(wrt),
            OpKind::Apply { var } => h.write(&[4]).write_usize(var),
            OpKind::Identity => h.write(&[5]),
            OpKind::NoOp => h.write(&[6]),
        };
        h.write_f64(op.flops);
        h.write_f64(op.output_bytes);
        h.write_f64(op.param_bytes);
        h.write(&[match op.splittability {
            Splittability::Concat => 0,
            Splittability::Sum => 1,
            Splittability::NoSplit => 2,
        }]);
        h.write_usize(op.inputs.len());
        for &i in &op.inputs {
            h.write_usize(i);
        }
    }
    h.finish()
}

/// Fingerprint of a device topology: groups (GPU spec, count, intra
/// bandwidth) and the inter-group bandwidth matrix.  The display name is
/// deliberately excluded.
///
/// Routed topologies additionally fold the full link graph — node
/// inventory and every typed link — because two routed clusters can
/// share a derived matrix yet differ in switch structure (and therefore
/// in contention behavior).  Flat clique topologies fold *nothing*
/// extra: their graph is a pure function of the matrix, so their
/// fingerprints are byte-identical to the pre-link-graph scheme (pinned
/// in `rust/tests/api.rs`).
pub fn topology(topo: &Topology) -> u64 {
    let mut h = Fnv::new();
    h.write_usize(topo.num_groups());
    for g in &topo.groups {
        h.write_str(g.gpu.name);
        h.write_f64(g.gpu.peak_tflops);
        h.write_f64(g.gpu.efficiency);
        h.write_f64(g.gpu.mem_gb);
        h.write_usize(g.count);
        h.write_f64(g.intra_bw_gbps);
    }
    for row in &topo.inter_bw_gbps {
        for &bw in row {
            h.write_f64(bw);
        }
    }
    if topo.is_routed() {
        let g = topo.link_graph();
        h.write_str("linkgraph");
        h.write_usize(g.num_nodes());
        for node in g.nodes() {
            match node {
                crate::cluster::NodeKind::Device(d) => {
                    h.write(&[1]).write_usize(d.group).write_usize(d.idx);
                }
                crate::cluster::NodeKind::Switch { level } => {
                    h.write(&[2]).write(&[*level]);
                }
            }
        }
        h.write_usize(g.num_links());
        for l in g.links() {
            h.write_usize(l.a)
                .write_usize(l.b)
                .write_f64(l.bw_gbps)
                .write_f64(l.latency_s)
                .write(&[l.kind.index()]);
        }
    }
    h.finish()
}

/// Render a fingerprint as the fixed-width hex string used in plan JSON.
pub fn to_hex(fp: u64) -> String {
    format!("{fp:016x}")
}

/// Parse a fingerprint hex string back (inverse of [`to_hex`]).
pub fn from_hex(s: &str) -> Option<u64> {
    u64::from_str_radix(s, 16).ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets::{sfb_pair, testbed};
    use crate::cluster::{DeviceGroup, GTX1080TI};
    use crate::models;

    #[test]
    fn model_fingerprint_is_stable_and_sensitive() {
        let a = model(&models::vgg19(8, 0.25));
        let b = model(&models::vgg19(8, 0.25));
        assert_eq!(a, b, "same generator inputs must fingerprint equal");
        assert_ne!(a, model(&models::vgg19(16, 0.25)), "batch changes fp");
        assert_ne!(a, model(&models::vgg19(8, 0.5)), "scale changes fp");
        assert_ne!(a, model(&models::resnet101(8, 0.25)), "model changes fp");
    }

    #[test]
    fn topology_fingerprint_ignores_name_but_not_structure() {
        let a = sfb_pair();
        let mut renamed = sfb_pair();
        renamed.name = "other-name".into();
        assert_eq!(topology(&a), topology(&renamed));
        assert_ne!(topology(&a), topology(&testbed()));

        let mut slower = sfb_pair();
        slower.inter_bw_gbps[0][1] = 5.0;
        slower.inter_bw_gbps[1][0] = 5.0;
        assert_ne!(topology(&a), topology(&slower), "bandwidth changes fp");

        let mut bigger = sfb_pair();
        bigger.groups.push(DeviceGroup { gpu: GTX1080TI, count: 1, intra_bw_gbps: 96.0 });
        bigger.inter_bw_gbps = vec![
            vec![0.0, 10.0, 10.0],
            vec![10.0, 0.0, 10.0],
            vec![10.0, 10.0, 0.0],
        ];
        assert_ne!(topology(&a), topology(&bigger), "group count changes fp");
    }

    #[test]
    fn routed_link_graph_is_folded_into_the_fingerprint() {
        // Same groups, same *derived* matrix — but one is a physical
        // switch fabric and one is a flattened clique.  They simulate
        // differently (contention, latency), so they must never share
        // cached plans.
        let routed = crate::cluster::presets::nvlink_island();
        let flat = crate::cluster::Topology::new(
            "flattened",
            routed.groups.clone(),
            routed.inter_bw_gbps.clone(),
        );
        assert_eq!(routed.inter_bw_gbps, flat.inter_bw_gbps);
        assert_ne!(topology(&routed), topology(&flat));
        // And routed fingerprints are stable.
        assert_eq!(topology(&routed), topology(&crate::cluster::presets::nvlink_island()));
    }

    #[test]
    fn hex_round_trip() {
        for fp in [0u64, 1, u64::MAX, 0xdead_beef_cafe_f00d] {
            assert_eq!(from_hex(&to_hex(fp)), Some(fp));
        }
        assert_eq!(to_hex(0xff).len(), 16);
        assert!(from_hex("zz").is_none());
    }

    #[test]
    fn length_prefix_prevents_concat_aliasing() {
        let mut a = Fnv::new();
        a.write_str("ab").write_str("c");
        let mut b = Fnv::new();
        b.write_str("a").write_str("bc");
        assert_ne!(a.finish(), b.finish());
    }
}
