//! The deployment API: TAG's single public planning surface.
//!
//! The paper's value proposition (§4.2) is *"give it a model and a
//! device topology, get back an optimized deployment"* — this module is
//! that sentence as types:
//!
//! * [`PlanRequest`] — model + topology + search budget + seed + SFB
//!   toggle, with structural [`fingerprint`]s;
//! * [`Planner`] — owns prepared (profiled + grouped) state, drives the
//!   [`coordinator`](crate::coordinator) engine through a pluggable
//!   [`SearchBackend`] ([`MctsBackend`], [`GnnMctsBackend`],
//!   [`BaselineSweepBackend`]), and memoizes results in a [`PlanCache`]
//!   keyed by `(model, topology, config)` fingerprints;
//! * [`DeploymentPlan`] — the deterministic, owned, JSON-serializable
//!   result that can be persisted and served to repeat traffic.
//!
//! ```no_run
//! use tag::api::{PlanRequest, Planner};
//! use tag::cluster::presets::testbed;
//! use tag::models;
//!
//! let planner = Planner::builder().build();
//! let request = PlanRequest::new(models::vgg19(48, 0.5), testbed())
//!     .budget(200, 24)
//!     .seed(42);
//! let outcome = planner.plan(&request).expect("valid request");
//! println!("speed-up over DP-NCCL: {:.2}x", outcome.plan.times.speedup);
//! let json = outcome.plan.encode(); // persist / serve
//! let back = tag::api::DeploymentPlan::decode(&json).unwrap();
//! assert_eq!(back, outcome.plan);
//! ```
//!
//! [`Planner::plan`] returns a [`Result`](crate::util::error::Result):
//! a malformed topology (asymmetric matrix, empty group, a mutated
//! derived view that no longer matches its link graph) surfaces as a
//! plan error instead of aborting the process.
//!
//! ## Sharing a planner across threads
//!
//! [`Planner::plan`] takes `&self` — the plan cache and the prepared
//! memo live behind internal mutexes, and searches themselves run
//! lock-free — so one planner can serve concurrent callers.  The
//! default [`Planner`] type erases its backend as `dyn SearchBackend`
//! (which keeps `!Send` backends like the `Rc`-sharing
//! [`GnnMctsBackend`] usable); to put a planner behind an `Arc` and
//! hand it to threads — the [`serve`](crate::serve) daemon's worker
//! pool — build a [`SharedPlanner`] instead, whose backend is
//! additionally `Send + Sync`:
//!
//! ```
//! use std::sync::Arc;
//! use tag::api::SharedPlanner;
//!
//! let planner: Arc<SharedPlanner> = Arc::new(SharedPlanner::builder().build());
//! let worker = planner.clone();
//! std::thread::spawn(move || {
//!     let _ = worker.cache_stats();
//! })
//! .join()
//! .unwrap();
//! ```

pub mod backend;
pub mod cache;
pub mod fingerprint;
pub mod json;
pub mod plan;
pub mod request;

pub use backend::{
    BackendOutcome, BaselineSweepBackend, GnnMctsBackend, MctsBackend, SearchBackend,
    SearchContext, BASELINE_NAMES,
};
pub use cache::{CacheStats, PlanCache, PlanKey};
pub use plan::{
    DeploymentPlan, PlanAction, PlanGroup, PlanStrategy, PlanTimes, SfbSummary, Telemetry,
};
pub use request::{PlanRequest, SearchBudget};

pub use crate::search::Parallelism;

use std::sync::{Arc, Mutex};

use crate::cluster::Topology;
use crate::coordinator::{self, Prepared, SessionResult};
use crate::dist::Lowering;
use crate::strategy::enumerate_actions;
use crate::util::error::{Context, Result};
use crate::util::{lock, Stopwatch};

/// A plan plus the per-call serving facts that must stay *outside* the
/// deterministic plan: wall time and cache provenance.
#[derive(Clone, Debug)]
pub struct PlanOutcome {
    pub plan: DeploymentPlan,
    /// Served from the [`PlanCache`] without searching.
    pub cache_hit: bool,
    /// Wall time of this `plan` call (search, or cache lookup).
    pub overhead_s: f64,
}

/// Memoized prepared state: profiling + grouping is reused across plan
/// calls that share the same (model, topology, prepare-knobs).  The
/// prepare knobs include the seed (the cost model and grouper are
/// seeded), so this helps budget/SFB sweeps and repeat traffic, not
/// seed sweeps — those re-profile by design.
struct PreparedEntry {
    model_fp: u64,
    topo_fp: u64,
    prepare_fp: u64,
    prepared: Prepared,
    topology: Topology,
}

/// Builder for [`Planner`]: pick a backend, configure the cache.
///
/// The type parameter is the *erasure target* for the backend:
/// `dyn SearchBackend` (the default — accepts any backend) or
/// `dyn SearchBackend + Send + Sync` (producing a [`SharedPlanner`]
/// that can cross threads).
pub struct PlannerBuilder<B: SearchBackend + ?Sized = dyn SearchBackend> {
    backend: Box<B>,
    cache: Option<usize>,
}

impl Default for PlannerBuilder {
    fn default() -> Self {
        Self { backend: Box::new(MctsBackend::new()), cache: Some(cache::DEFAULT_CAPACITY) }
    }
}

impl Default for PlannerBuilder<dyn SearchBackend + Send + Sync> {
    fn default() -> Self {
        Self { backend: Box::new(MctsBackend::new()), cache: Some(cache::DEFAULT_CAPACITY) }
    }
}

impl PlannerBuilder {
    /// Replace the default [`MctsBackend`].
    pub fn backend(mut self, backend: impl SearchBackend + 'static) -> Self {
        self.backend = Box::new(backend);
        self
    }
}

impl PlannerBuilder<dyn SearchBackend + Send + Sync> {
    /// Replace the default [`MctsBackend`].  The shared builder only
    /// accepts `Send + Sync` backends — a [`GnnMctsBackend`] (which
    /// shares its PJRT service via `Rc`) cannot cross threads and is
    /// rejected at compile time.
    pub fn backend(mut self, backend: impl SearchBackend + Send + Sync + 'static) -> Self {
        self.backend = Box::new(backend);
        self
    }
}

impl<B: SearchBackend + ?Sized> PlannerBuilder<B> {
    /// Cap each plan-cache generation at `capacity` entries.
    pub fn cache_capacity(mut self, capacity: usize) -> Self {
        self.cache = Some(capacity);
        self
    }

    /// Disable plan caching (every call searches).
    pub fn without_cache(mut self) -> Self {
        self.cache = None;
        self
    }

    pub fn build(self) -> Planner<B> {
        Planner {
            backend: self.backend,
            cache: self.cache.map(|cap| Mutex::new(PlanCache::new(cap))),
            prepared: Mutex::new(None),
        }
    }
}

/// The deployment-planning service: request in, plan out.
///
/// [`plan`](Self::plan) takes `&self`; the cache and the prepared memo
/// sit behind internal mutexes held only for map operations, never
/// across a search — concurrent callers search concurrently.
pub struct Planner<B: SearchBackend + ?Sized = dyn SearchBackend> {
    cache: Option<Mutex<PlanCache>>,
    prepared: Mutex<Option<Arc<PreparedEntry>>>,
    backend: Box<B>,
}

/// A [`Planner`] whose backend is `Send + Sync`, so the planner itself
/// can sit behind an `Arc` and serve threads — the type `tag serve`'s
/// worker pool shares.  Build with [`SharedPlanner::builder`].
pub type SharedPlanner = Planner<dyn SearchBackend + Send + Sync>;

impl Default for Planner {
    fn default() -> Self {
        Self::builder().build()
    }
}

impl Planner {
    pub fn builder() -> PlannerBuilder {
        PlannerBuilder::default()
    }
}

impl SharedPlanner {
    /// Builder for a thread-shareable planner ([`SharedPlanner`]).
    pub fn builder() -> PlannerBuilder<dyn SearchBackend + Send + Sync> {
        PlannerBuilder::default()
    }
}

impl<B: SearchBackend + ?Sized> Planner<B> {
    /// The active backend's name (recorded in every plan).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Cache counters, or `None` when built with
    /// [`PlannerBuilder::without_cache`].
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.cache.as_ref().map(|c| lock(c).stats())
    }

    /// The cache key this request resolves to under the current backend.
    pub fn key_for(&self, request: &PlanRequest) -> PlanKey {
        PlanKey {
            model: fingerprint::model(&request.model),
            topology: fingerprint::topology(&request.topology),
            config: request.config_fingerprint(self.backend.fingerprint_token()),
        }
    }

    /// Produce (or serve from cache) a deployment plan for `request`.
    ///
    /// The request's topology is validated first: a malformed topology
    /// (asymmetric matrix, empty group, stale derived view) returns an
    /// `Err` instead of aborting — the planning service stays up.
    ///
    /// With the default sequential search (`workers == 1`) the returned
    /// [`DeploymentPlan`] is a pure function of the request and the
    /// backend configuration: repeat calls are bit-identical whether
    /// they hit the cache or re-search.  With `workers > 1` the search
    /// is tree-parallel and schedule-dependent: the cache still serves
    /// the stored plan byte-for-byte, but an evicted entry may re-search
    /// to a different (equally valid) plan — which is why parallel
    /// requests get their own config fingerprint and never alias
    /// sequential ones.
    pub fn plan(&self, request: &PlanRequest) -> Result<PlanOutcome> {
        let watch = Stopwatch::start();
        request
            .topology
            .validate()
            .with_context(|| format!("invalid topology `{}`", request.topology.name))?;
        let key = self.key_for(request);
        if let Some(cache) = &self.cache {
            if let Some(plan) = lock(cache).get(&key) {
                return Ok(PlanOutcome {
                    plan,
                    cache_hit: true,
                    overhead_s: watch.elapsed_s(),
                });
            }
        }

        let cfg = request.search_config();
        let prepare_fp = request.prepare_fingerprint();
        let matches_request = |e: &PreparedEntry| {
            e.model_fp == key.model && e.topo_fp == key.topology && e.prepare_fp == prepare_fp
        };
        // Clone the memoized prepared state out of the lock (an `Arc`
        // clone), or rebuild it *outside* the lock — preparation is the
        // expensive profiling+grouping pass and must not serialize
        // unrelated concurrent requests.  Two identical racing requests
        // may both prepare; `prepare` is deterministic, so either
        // result is interchangeable and the last store wins.
        let reusable = lock(&self.prepared).as_ref().filter(|e| matches_request(e)).cloned();
        let entry = match reusable {
            Some(entry) => entry,
            None => {
                let prepared =
                    coordinator::prepare(request.model.clone(), &request.topology, &cfg);
                let entry = Arc::new(PreparedEntry {
                    model_fp: key.model,
                    topo_fp: key.topology,
                    prepare_fp,
                    prepared,
                    topology: request.topology.clone(),
                });
                *lock(&self.prepared) = Some(entry.clone());
                entry
            }
        };

        // The Lowering (and its transposition table) is deliberately
        // rebuilt per call rather than memoized in PreparedEntry: plans
        // embed the memo hit/miss counters as telemetry, and a warm
        // table would make a re-searched plan differ from its first
        // production — breaking the bit-identical determinism the cache
        // and the api tests guarantee.
        let low = Lowering::new(
            &entry.prepared.gg,
            &entry.topology,
            &entry.prepared.cost,
            &entry.prepared.comm,
        );
        let actions = enumerate_actions(&entry.topology);
        let ctx = SearchContext {
            prep: &entry.prepared,
            topo: &entry.topology,
            low: &low,
            actions: &actions,
            cfg: &cfg,
        };
        let out = self.backend.search(&ctx);
        let session = coordinator::assemble_session(
            &entry.prepared,
            &entry.topology,
            &low,
            out.result,
            &cfg,
            0.0,
        );
        let plan = assemble_plan(
            request,
            &session,
            &key,
            self.backend.name(),
            actions.len(),
            out.metrics,
        );

        if let Some(cache) = &self.cache {
            lock(cache).insert(key, plan.clone());
        }
        Ok(PlanOutcome { plan, cache_hit: false, overhead_s: watch.elapsed_s() })
    }
}

/// Convert an engine-level [`SessionResult`] into the owned,
/// deterministic [`DeploymentPlan`].
fn assemble_plan(
    request: &PlanRequest,
    session: &SessionResult,
    key: &PlanKey,
    backend: &str,
    num_actions: usize,
    metrics: Vec<(String, f64)>,
) -> DeploymentPlan {
    DeploymentPlan {
        model_name: request.model.name.clone(),
        topology_name: request.topology.name.clone(),
        model_fingerprint: key.model,
        topology_fingerprint: key.topology,
        config_fingerprint: key.config,
        backend: backend.to_string(),
        strategy: PlanStrategy::from_strategy(&session.strategy),
        groups: session
            .group_graph
            .groups
            .iter()
            .map(|g| PlanGroup { comp_time: g.comp_time, grad_bytes: g.grad_bytes })
            .collect(),
        times: PlanTimes {
            time: session.time,
            time_with_sfb: session.time_with_sfb,
            dp_time: session.dp_time,
            final_time: session.final_time,
            speedup: session.speedup,
        },
        sfb: session.sfb.as_ref().map(SfbSummary::from_plan),
        telemetry: Telemetry {
            iterations: session.search.iterations,
            first_beats_dp: session.search.first_beats_dp,
            dp_oom: session.dp_oom,
            num_groups: session.group_graph.num_groups(),
            num_actions,
            seed: request.seed,
            metrics,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets::{sfb_pair, testbed};
    use crate::models;

    fn small_request() -> PlanRequest {
        PlanRequest::new(models::vgg19(8, 0.25), testbed()).budget(30, 10).seed(3)
    }

    #[test]
    fn plan_call_produces_consistent_plan() {
        let planner = Planner::builder().without_cache().build();
        let out = planner.plan(&small_request()).unwrap();
        assert!(!out.cache_hit);
        let p = &out.plan;
        assert_eq!(p.model_name, "VGG19");
        assert_eq!(p.backend, "mcts");
        assert_eq!(p.strategy.slots.len(), p.telemetry.num_groups);
        assert_eq!(p.groups.len(), p.telemetry.num_groups);
        assert!(p.times.final_time <= p.times.time + 1e-15);
        assert!(p.times.speedup >= 1.0 - 1e-9);
        assert!((p.times.dp_time / p.times.speedup - p.times.final_time).abs() < 1e-9);
        assert!(p.sfb.is_some(), "default request applies SFB");
    }

    #[test]
    fn cache_serves_repeat_traffic() {
        let planner = Planner::builder().build();
        let req = small_request();
        let first = planner.plan(&req).unwrap();
        let second = planner.plan(&req).unwrap();
        assert!(!first.cache_hit);
        assert!(second.cache_hit);
        assert_eq!(first.plan, second.plan);
        let stats = planner.cache_stats().unwrap();
        assert_eq!((stats.hits, stats.misses, stats.entries), (1, 1, 1));
        assert!((stats.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn different_request_knobs_miss_the_cache() {
        let planner = Planner::builder().build();
        let _ = planner.plan(&small_request()).unwrap();
        let out = planner.plan(&small_request().seed(4)).unwrap();
        assert!(!out.cache_hit);
        let out = planner.plan(&small_request().sfb(false)).unwrap();
        assert!(!out.cache_hit);
        assert_eq!(planner.cache_stats().unwrap().entries, 3);
    }

    #[test]
    fn prepared_state_reused_across_seed_sweep() {
        // Different seeds share a cache-missing problem only when the
        // prepare knobs differ; a changed seed re-prepares (the cost
        // model is seeded) while a changed topology swaps the entry.
        let planner = Planner::builder().without_cache().build();
        let a = planner.plan(&small_request()).unwrap();
        let b = planner.plan(&small_request()).unwrap();
        assert_eq!(a.plan, b.plan, "same request replans identically");
        let c = planner
            .plan(&PlanRequest::new(models::vgg19(8, 0.25), sfb_pair()).budget(30, 10).seed(3))
            .unwrap();
        assert_ne!(a.plan.topology_fingerprint, c.plan.topology_fingerprint);
    }

    #[test]
    fn baseline_backend_plans_carry_sweep_rows() {
        let planner = Planner::builder().backend(BaselineSweepBackend::new()).build();
        let out = planner.plan(&small_request()).unwrap();
        assert_eq!(out.plan.backend, "baseline-sweep");
        for name in BASELINE_NAMES {
            assert!(out.plan.telemetry.metric(name).is_some(), "{name} row missing");
        }
    }

    #[test]
    fn malformed_topology_surfaces_as_plan_error_not_abort() {
        let planner = Planner::builder().build();
        let mut req = small_request();
        // Corrupt the (publicly mutable) derived matrix: asymmetric.
        req.topology.inter_bw_gbps[0][1] = 1.0;
        let err = planner.plan(&req).unwrap_err().to_string();
        assert!(err.contains("invalid topology"), "{err}");
        assert!(err.contains("symmetric"), "{err}");
        // A symmetric but stale derived view is rejected too.
        let mut req = small_request();
        req.topology.inter_bw_gbps[0][1] = 1.0;
        req.topology.inter_bw_gbps[1][0] = 1.0;
        let err = planner.plan(&req).unwrap_err().to_string();
        assert!(err.contains("stale derived view"), "{err}");
        // The planner still serves valid requests afterwards.
        assert!(planner.plan(&small_request()).is_ok());
    }

    #[test]
    fn shared_planner_serves_concurrent_threads() {
        use std::sync::Arc;

        // A SharedPlanner behind an Arc, hit by racing threads with the
        // same request: every thread gets the same (bit-identical) plan
        // and the cache sees exactly one search (miss) from this key —
        // the property `tag serve`'s coalescing and metrics build on.
        // (Concurrent identical misses may each search; here the plans
        // they produce are identical, so the count of *distinct* plans
        // is what's pinned, plus hits+misses == lookups.)
        let planner: Arc<SharedPlanner> = Arc::new(SharedPlanner::builder().build());
        let warmup = planner.plan(&small_request()).unwrap();
        assert!(!warmup.cache_hit);
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let p = planner.clone();
                std::thread::spawn(move || p.plan(&small_request()).unwrap())
            })
            .collect();
        for h in handles {
            let out = h.join().unwrap();
            assert!(out.cache_hit, "warmed cache serves every thread");
            assert_eq!(out.plan, warmup.plan);
        }
        let stats = planner.cache_stats().unwrap();
        assert_eq!((stats.hits, stats.misses, stats.entries), (4, 1, 1));
    }

    #[test]
    fn mask_memo_hit_rate_rides_in_plan_telemetry() {
        let planner = Planner::builder().without_cache().build();
        let plan = planner.plan(&small_request()).unwrap().plan;
        let rate = plan.telemetry.metric("mask_memo_hit_rate").expect("row present");
        assert!((0.0..=1.0).contains(&rate));
        assert!(plan.telemetry.metric("mask_memo_misses").unwrap() >= 1.0);
        // Deterministic across independent planners (fresh lowering per
        // plan call keeps the counters a pure function of the request).
        let plan2 = Planner::builder()
            .without_cache()
            .build()
            .plan(&small_request())
            .unwrap()
            .plan;
        assert_eq!(plan, plan2);
    }
}
